"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes / group sizes / dtypes; every kernel must match
its `ref.py` oracle to float tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.qmatmul import dequant_matmul_pallas
from compile.kernels.quant import rtn_fake_quant_sym_pallas
from compile.kernels.walsh import (
    fwht_pallas,
    grouped_fwht_pallas,
    rht_pallas,
    walsh_transform_pallas,
)
from compile.rotation import hadamard, walsh

WIDTHS = st.sampled_from([16, 32, 64, 128, 256, 512])
ROWS = st.integers(min_value=1, max_value=33)


def randx(rows, n, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((rows, n)), dtype)


@given(ROWS, WIDTHS, st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_fwht_pallas_matches_ref(rows, n, seed):
    x = randx(rows, n, seed)
    np.testing.assert_allclose(
        np.asarray(fwht_pallas(x)), np.asarray(ref.fwht(x)), atol=1e-5
    )


@given(ROWS, st.sampled_from([(64, 16), (128, 32), (256, 64), (512, 64)]), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_grouped_fwht_pallas_matches_ref(rows, ng, seed):
    n, g = ng
    x = randx(rows, n, seed)
    np.testing.assert_allclose(
        np.asarray(grouped_fwht_pallas(x, g)),
        np.asarray(ref.grouped_fwht(x, g)),
        atol=1e-5,
    )


def test_fwht_equals_dense_hadamard():
    x = randx(7, 128, 3)
    np.testing.assert_allclose(
        np.asarray(ref.fwht(x)), np.asarray(x) @ hadamard(128), atol=1e-5
    )


def test_walsh_transform_equals_dense():
    x = randx(5, 64, 4)
    np.testing.assert_allclose(
        np.asarray(walsh_transform_pallas(x)),
        np.asarray(x) @ walsh(64).T,
        atol=1e-5,
    )


@given(ROWS, WIDTHS, st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_rht_pallas_matches_ref(rows, n, seed):
    rng = np.random.default_rng(seed + 1)
    s = jnp.asarray(rng.integers(0, 2, n) * 2 - 1, jnp.float32)
    x = randx(rows, n, seed)
    expect = np.asarray(ref.fwht(x)) * np.asarray(s)
    np.testing.assert_allclose(np.asarray(rht_pallas(x, s)), expect, atol=1e-5)


@given(
    ROWS,
    st.sampled_from([(64, 16), (128, 32), (256, 64)]),
    st.sampled_from([4, 8]),
    st.floats(0.5, 1.0),
    st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_rtn_sym_pallas_matches_ref(rows, ng, bits, clip, seed):
    n, g = ng
    x = randx(rows, n, seed)
    np.testing.assert_allclose(
        np.asarray(rtn_fake_quant_sym_pallas(x, bits, g, clip)),
        np.asarray(ref.rtn_fake_quant_sym(x, bits, g, clip)),
        atol=1e-5,
    )


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_pack_unpack_roundtrip(seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 4, (64, 24)), jnp.int32)
    assert np.array_equal(np.asarray(ref.unpack2(ref.pack2(codes))), np.asarray(codes))


@given(
    ROWS,
    st.sampled_from([(64, 16, 32), (128, 32, 64), (256, 64, 128), (512, 64, 256)]),
    st.integers(0, 10_000),
)
@settings(max_examples=20, deadline=None)
def test_dequant_matmul_pallas_matches_ref(rows, kgh, seed):
    k, g, h = kgh
    rng = np.random.default_rng(seed)
    x = randx(rows, k, seed)
    w = jnp.asarray(rng.standard_normal((k, h)), jnp.float32)
    codes, scale, zero = ref.rtn_quant_asym(w, 2, g)
    packed = ref.pack2(codes)
    np.testing.assert_allclose(
        np.asarray(dequant_matmul_pallas(x, packed, scale, zero, g)),
        np.asarray(ref.dequant_matmul(x, packed, scale, zero, g)),
        atol=2e-3,
    )


def test_dequant_matmul_vs_dense():
    # Dequantized matmul equals x @ dequant(W) computed densely.
    rng = np.random.default_rng(11)
    x = randx(9, 128, 12)
    w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    codes, scale, zero = ref.rtn_quant_asym(w, 2, 32)
    wd = ref.dequant(codes, scale, zero, 32)
    packed = ref.pack2(codes)
    np.testing.assert_allclose(
        np.asarray(dequant_matmul_pallas(x, packed, scale, zero, 32)),
        np.asarray(x @ wd),
        atol=2e-3,
    )


def test_quant_error_bounded():
    rng = np.random.default_rng(13)
    w = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    codes, scale, zero = ref.rtn_quant_asym(w, 2, 32)
    wd = np.asarray(ref.dequant(codes, scale, zero, 32))
    err = np.abs(wd - np.asarray(w))
    # Per-element error ≤ half a quantization step of its group.
    steps = np.repeat(np.asarray(scale), 32, axis=0)
    assert np.all(err <= steps * 0.5 + 1e-6)
