"""Corpus generator contract (mirrored by rust/src/data/corpus.rs)."""

import numpy as np

from compile.corpus import (
    SEED_CORPUS,
    CorpusGenerator,
    SplitMix64,
    generate_corpus,
)


def test_splitmix64_reference_vectors():
    # Published SplitMix64 outputs for seed 0 — the cross-language anchor.
    r = SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4


def test_deterministic():
    assert generate_corpus(4096) == generate_corpus(4096)


def test_alphabet():
    data = generate_corpus(1 << 14)
    assert set(data) <= set(b"abcdefghijklmnopqrstuvwxyz. ")


def test_zipf_head_dominates():
    gen = CorpusGenerator(SEED_CORPUS)
    counts = np.zeros(256, np.int64)
    for _ in range(20_000):
        counts[gen.next_word_idx()] += 1
    assert counts[:8].sum() > 3 * counts[128:136].sum()


def test_sentences_terminate():
    data = generate_corpus(1 << 14)
    assert data.count(b". ") > 100


def test_word_lengths():
    gen = CorpusGenerator(SEED_CORPUS)
    assert all(2 <= len(w) <= 7 for w in gen.lexicon)
    assert len(gen.lexicon) == 256
