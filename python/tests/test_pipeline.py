"""Quantization-pipeline integration (tiny config; no artifacts needed)."""

import jax.numpy as jnp
import numpy as np

import compile.quantize as qz
from compile.corpus import generate_corpus
from compile.model import ModelCfg, init_params
from compile.quantize import (
    all_variants,
    calib_tokens,
    capture_fp_sites,
    quantize_variant,
    sanity_ppl,
    shared_rotations,
    variant_name,
    write_blob,
)

CFG = ModelCfg(d_model=64, n_layers=2, n_heads=2, d_ffn=128, group=16)


def test_variant_grid_is_complete():
    vs = all_variants()
    # 3 methods × 2 bit configs × 4 R1 + 2 bits × 2 extra R4-LH cells.
    assert len(vs) == 24 + 4
    names = {variant_name(v["method"], v["bits"], v["r1"], v["r4"]) for v in vs}
    assert len(names) == len(vs), "variant names must be unique"
    assert "quarot_w2a16_gsr_r4gh" in names
    assert "quarot_w2a4_gsr_r4lh" in names


def test_quarot_variant_end_to_end_tiny():
    params = init_params(CFG, seed=0)
    corpus = generate_corpus(1 << 16)
    n_train = int(len(corpus) * 0.9)
    shared = shared_rotations(CFG)
    calib = calib_tokens(corpus, n_train)[:4]
    spec_v = {"method": "quarot", "bits": "w2a16", "r1": "GSR", "r4": "GH"}
    qp, meta = quantize_variant(params, CFG, spec_v, shared, calib)
    # Codes packed, scales finite, blob writes at the declared size.
    for layer in qp["layers"]:
        for name in CFG.LINEARS:
            assert layer[f"{name}_packed"].dtype == np.uint8
            assert np.isfinite(layer[f"{name}_scale"]).all()
    import tempfile

    with tempfile.NamedTemporaryFile(suffix=".bin") as f:
        n = write_blob(qp, CFG, "GH", f.name)
        from compile.model import quant_param_spec

        expect = 0
        for _, shape, dt in quant_param_spec(CFG, "GH"):
            expect += int(np.prod(shape)) * (4 if dt == "f32" else 1)
        assert n == expect
    # The quantized model still predicts (finite PPL, not absurd).
    ppl = sanity_ppl(qp, CFG, corpus, None, "GH", n_train)
    assert np.isfinite(ppl) and ppl < 1e5  # untrained host: near-vocab-size PPL, quant inflates further
    assert meta["gptq_weight_sse"] > 0


def test_sequential_gptq_uses_propagated_activations(monkeypatch):
    # The capture must run once per layer (sequential discipline).
    calls = []
    orig = qz.capture_linear_inputs

    def spy(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(qz, "capture_linear_inputs", spy)
    params = init_params(CFG, seed=1)
    corpus = generate_corpus(1 << 15)
    shared = shared_rotations(CFG)
    calib = calib_tokens(corpus, len(corpus))[:2]
    spec_v = {"method": "quarot", "bits": "w2a16", "r1": "GH", "r4": "GH"}
    quantize_variant(params, CFG, spec_v, shared, calib)
    assert len(calls) == CFG.n_layers


def test_fp_sites_capture_shapes():
    params = init_params(CFG, seed=2)
    tokens = jnp.zeros((2, 16), jnp.int32)
    sites = capture_fp_sites(params, CFG, tokens)
    assert len(sites["h_attn"]) == CFG.n_layers
    assert sites["h_attn"][0].shape[1] == CFG.d_model
    assert sites["z"][0].shape[1] == CFG.d_ffn
