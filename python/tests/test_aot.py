"""AOT artifact consistency (runs only when `make artifacts` has built)."""

import json
import os

import numpy as np
import pytest

from compile.model import ModelCfg, fp_param_spec, quant_param_spec

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)


def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_cfg_matches_code():
    m = manifest()
    cfg = ModelCfg()
    for key in ["vocab", "d_model", "n_layers", "n_heads", "d_ffn", "group"]:
        assert m["cfg"][key] == getattr(cfg, key), key


def test_manifest_specs_match_code():
    m = manifest()
    cfg = ModelCfg()
    assert m["graphs"]["fp"]["params"] == [
        [n, list(s), d] for n, s, d in fp_param_spec(cfg)
    ]
    for r4 in ["gh", "lh"]:
        for bits in ["w2a16", "w2a4"]:
            g = m["graphs"][f"{bits}_r4{r4}"]["params"]
            assert g == [
                [n, list(s), d] for n, s, d in quant_param_spec(cfg, r4.upper())
            ]


def test_variant_blobs_have_declared_size():
    m = manifest()
    cfg = ModelCfg()
    sizes = {}
    for r4 in ["GH", "LH"]:
        total = 0
        for _, shape, dt in quant_param_spec(cfg, r4):
            total += int(np.prod(shape)) * (4 if dt == "f32" else 1)
        sizes[r4] = total
    for v in m["variants"]:
        path = os.path.join(ART, v["weights"])
        r4 = v["r4"]
        assert os.path.getsize(path) == sizes[r4], v["name"]


def test_all_28_variants_present():
    m = manifest()
    assert len(m["variants"]) == 28
    names = {v["name"] for v in m["variants"]}
    assert "quarot_w2a16_gsr_r4gh" in names
    assert "ostquant_w2a4_gsr_r4gh" in names
    assert "quarot_w2a4_gsr_r4lh" in names


def test_hlo_files_exist_and_are_text():
    m = manifest()
    for g in m["graphs"].values():
        path = os.path.join(ART, g["hlo"])
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, path


def test_sanity_ppls_recorded_and_finite():
    m = manifest()
    for v in m["variants"]:
        assert np.isfinite(v["sanity_ppl"]), v["name"]
        assert 1.0 < v["sanity_ppl"] < 1000.0
