"""Model shapes, specs, and quantized-forward smoke (L2)."""

import jax.numpy as jnp
import numpy as np

from compile import rotation as rot
from compile.gptq import gptq_quantize, pack2
from compile.model import (
    ModelCfg,
    forward_fp,
    fp_param_spec,
    fuse_r4,
    fuse_rotations,
    init_params,
    loss_fn,
    make_quant_forward,
    num_params,
    quant_param_spec,
    unflatten_quant_params,
)

CFG = ModelCfg(d_model=64, n_layers=2, n_heads=2, d_ffn=128, group=16)


def test_forward_shapes():
    params = init_params(CFG, seed=0)
    tokens = jnp.zeros((2, 10), jnp.int32)
    logits = forward_fp(params, tokens, CFG)
    assert logits.shape == (2, 10, CFG.vocab)


def test_loss_finite_and_near_uniform_at_init():
    params = init_params(CFG, seed=0)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, 256, (4, 33)), jnp.int32)
    loss = float(loss_fn(params, tokens, CFG))
    assert np.isfinite(loss)
    assert abs(loss - np.log(256)) < 2.0  # random init ≈ uniform predictor


def test_param_spec_matches_init():
    params = init_params(CFG, seed=0)
    spec = fp_param_spec(CFG)
    total_spec = sum(int(np.prod(s)) for _, s, _ in spec)
    assert total_spec == num_params(params)


def test_quant_spec_deterministic_order():
    a = quant_param_spec(CFG, "GH")
    b = quant_param_spec(CFG, "GH")
    assert a == b
    names = [n for n, _, _ in a]
    assert names[0] == "embed" and names[2] == "r3"
    assert any("ascale_down" in n for n in names)


def test_quant_forward_lowering_roundtrip():
    """End-to-end L2 smoke: quantize a tiny model, run the exported-fn
    path (flat params → logits) that aot.py lowers to HLO."""
    params = init_params(CFG, seed=1)
    rng = np.random.default_rng(2)
    r1 = rot.build_r1("GSR", CFG.d_model, CFG.group, rng)
    r2 = rot.build_r2(CFG.head_dim, rng)
    r3 = rot.rht(CFG.head_dim, rng)
    signs = rng.integers(0, 2, CFG.d_ffn) * 2.0 - 1.0
    r4 = rot.hadamard(CFG.d_ffn) * signs[None, :]
    fused = fuse_r4(fuse_rotations(params, CFG, r1, r2), r4)

    fn, spec = make_quant_forward(CFG, a_bits=None, r4_kind="GH")
    flat = []
    qstate = {}
    for layer in fused["layers"]:
        for name in CFG.LINEARS:
            w = np.asarray(layer[name])
            q = gptq_quantize(w, np.eye(w.shape[0]), 2, CFG.group, mse_clip=False)
            qstate[id(layer), name] = q
    for name, shape, dt in spec:
        if name == "embed":
            flat.append(jnp.asarray(fused["embed"], jnp.float32))
        elif name == "lm_head":
            flat.append(jnp.asarray(fused["lm_head"], jnp.float32))
        elif name == "r3":
            flat.append(jnp.asarray(r3, jnp.float32))
        elif name == "r4_signs":
            flat.append(jnp.asarray(signs, jnp.float32))
        elif "ascale" in name:
            flat.append(jnp.ones(shape, jnp.float32))
        else:
            _, idx, field = name.split(".")
            base = field.rsplit("_", 1)[0]
            q = qstate[id(fused["layers"][int(idx)]), base]
            if field.endswith("_packed"):
                flat.append(jnp.asarray(pack2(q.codes), jnp.uint8))
            elif field.endswith("_scale"):
                flat.append(jnp.asarray(q.scale, jnp.float32))
            else:
                flat.append(jnp.asarray(q.zero, jnp.float32))
    tokens = jnp.zeros((2, 16), jnp.int32)
    (logits,) = fn(tokens, *flat)
    assert logits.shape == (2, 16, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # Sanity: unflatten round-trips the spec structure.
    qp = unflatten_quant_params(CFG, spec, flat)
    assert len(qp["layers"]) == CFG.n_layers
