"""Learned-transform pipelines (SpinQuant / OSTQuant miniatures).

The key regression here is the straight-through-estimator trap: the
reconstruction objective must have a *nonzero gradient* w.r.t. the
Cayley parameters, and a short optimization must strictly reduce the
quantization proxy loss (this failed silently before — see
spinquant.ste_fake_quant_asym docstring).
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import rotation as rot
from compile.model import ModelCfg, init_params
from compile.ostquant import learn_ost
from compile.quantize import capture_fp_sites
from compile.spinquant import cayley, learn_rotation, ste_fake_quant_asym

CFG = ModelCfg(d_model=64, n_layers=2, n_heads=2, d_ffn=128, group=16)


def shared():
    rng = np.random.default_rng(1)
    r2 = rot.build_r2(CFG.head_dim, rng)
    signs = rng.integers(0, 2, CFG.d_ffn) * 2.0 - 1.0
    r4 = rot.hadamard(CFG.d_ffn) * signs[None, :]
    return r2, r4


def test_cayley_is_orthogonal():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((16, 16)) * 0.1, jnp.float32)
    q = np.asarray(cayley(a), np.float64)
    assert np.allclose(q @ q.T, np.eye(16), atol=1e-5)


def test_objective_gradient_nonzero():
    # The STE trap regression: d(loss)/d(A) must not be identically zero.
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)

    def loss(a):
        r = cayley(a)
        rw = r.T @ w
        return jnp.mean((rw - ste_fake_quant_asym(rw, 2, 8)) ** 2)

    g = jax.grad(loss)(jnp.zeros((32, 32), jnp.float32))
    assert float(jnp.abs(g).max()) > 1e-8, "objective gradient is zero (STE trap)"


def test_spinquant_reduces_proxy_loss_and_stays_orthogonal():
    params = init_params(CFG, seed=4)
    r2, r4 = shared()
    rng = np.random.default_rng(5)
    r1_init = rot.build_r1("GH", CFG.d_model, CFG.group, rng)
    r1, log = learn_rotation(params, CFG, r1_init, r2, r4, w_bits=2, steps=40)
    assert np.allclose(r1 @ r1.T, np.eye(CFG.d_model), atol=1e-8)
    assert log[-1] < log[0], f"loss did not decrease: {log}"
    # And the rotation actually moved away from the init.
    assert not np.allclose(r1, r1_init, atol=1e-6)


def test_ostquant_learns_scales_and_rotation():
    params = init_params(CFG, seed=6)
    r2, r4 = shared()
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, (2, 32)), jnp.int32)
    sites = capture_fp_sites(params, CFG, tokens)
    r1_init = rot.build_r1("GSR", CFG.d_model, CFG.group, rng)
    r1, scales, log = learn_ost(
        params, CFG, r1_init, r2, r4, sites, w_bits=2, a_bits=4, steps=30
    )
    assert np.allclose(r1 @ r1.T, np.eye(CFG.d_model), atol=1e-8)
    assert log[-1] < log[0]
    assert len(scales) == CFG.n_layers
    for sl in scales:
        for key in ["ascale_attn", "ascale_o", "ascale_ffn", "ascale_down"]:
            assert np.all(sl[key] > 0), "scales must stay positive"
    # Scales must have actually moved off the all-ones init.
    moved = max(
        float(np.abs(sl["ascale_ffn"] - 1.0).max()) for sl in scales
    )
    assert moved > 1e-4
