"""GPTQ / RTN quantizer properties."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.gptq import QuantizedLinear, gptq_quantize, pack2, rtn_quantize


def correlated_activations(n, c, seed, outlier_frac=0.1):
    rng = np.random.default_rng(seed)
    amp = np.where(rng.random(c) < outlier_frac, 6.0, 1.0)
    base = rng.standard_normal((n, 1))
    return (0.6 * base + 0.4 * rng.standard_normal((n, c))) * amp[None, :]


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_rtn_roundtrip_error_bounded(seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((64, 16))
    q = rtn_quantize(w, 4, 16, mse_clip=False)
    err = np.abs(q.dequant() - w)
    steps = np.repeat(q.scale, 16, axis=0)
    assert np.all(err <= 0.5 * steps + 1e-9)


def test_mse_clip_never_hurts_reconstruction():
    rng = np.random.default_rng(3)
    w = rng.standard_normal((128, 32)) * (1 + 4 * (rng.random((128, 1)) < 0.05))
    plain = rtn_quantize(w, 2, 32, mse_clip=False)
    clipped = rtn_quantize(w, 2, 32, mse_clip=True)
    mse = lambda q: float(((q.dequant() - w) ** 2).mean())
    assert mse(clipped) <= mse(plain) + 1e-12


@pytest.mark.parametrize("bits", [2, 4])
def test_gptq_beats_rtn_on_hessian_loss(bits):
    rng = np.random.default_rng(5)
    c, h = 64, 32
    w = rng.standard_normal((c, h))
    x = correlated_activations(512, c, 6)
    hess = x.T @ x / len(x)
    qg = gptq_quantize(w, hess, bits, 16)
    qr = rtn_quantize(w, bits, 16)
    loss = lambda q: float(
        np.einsum("ch,cd,dh->", q.dequant() - w, hess, q.dequant() - w)
    )
    assert loss(qg) < loss(qr), f"{loss(qg)} !< {loss(qr)}"


def test_gptq_codes_in_range():
    rng = np.random.default_rng(7)
    w = rng.standard_normal((32, 8))
    x = correlated_activations(128, 32, 8)
    q = gptq_quantize(w, x.T @ x, 2, 8)
    assert q.codes.min() >= 0 and q.codes.max() <= 3


def test_gptq_handles_dead_channels():
    rng = np.random.default_rng(9)
    w = rng.standard_normal((16, 4))
    x = correlated_activations(64, 16, 10)
    x[:, 3] = 0.0  # dead input channel
    q = gptq_quantize(w, x.T @ x, 2, 8)
    assert np.isfinite(q.dequant()).all()


def test_pack2_matches_kernel_ref():
    import jax.numpy as jnp

    from compile.kernels import ref

    rng = np.random.default_rng(11)
    codes = rng.integers(0, 4, (64, 12)).astype(np.int32)
    a = pack2(codes)
    b = np.asarray(ref.pack2(jnp.asarray(codes)))
    assert np.array_equal(a, b)


def test_quantized_linear_dequant_shape():
    q = QuantizedLinear(
        codes=np.zeros((8, 2), np.int32),
        scale=np.ones((2, 2)),
        zero=np.zeros((2, 2)),
        group=4,
        bits=2,
    )
    assert q.dequant().shape == (8, 2)
