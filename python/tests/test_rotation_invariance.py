"""Fig.-1 wiring check: the fused/rotated model is exactly the fp model.

Orthogonal invariance of the full R1–R4 fusion (model.fuse_rotations /
fuse_r4) must hold in fp arithmetic for every R1 kind and both R4 kinds —
this validates the entire rotation scheme before any quantization.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import rotation as rot
from compile.model import (
    ModelCfg,
    forward_fp,
    forward_rotated,
    fuse_r4,
    fuse_rotations,
    init_params,
)

CFG = ModelCfg(d_model=64, n_layers=2, n_heads=2, d_ffn=128, group=16)


def build_qparams(fused, r3, r4_signs):
    return {
        "embed": jnp.asarray(fused["embed"], jnp.float32),
        "lm_head": jnp.asarray(fused["lm_head"], jnp.float32),
        "r3": jnp.asarray(r3, jnp.float32),
        "r4_signs": jnp.asarray(r4_signs, jnp.float32),
        "layers": [
            {k: jnp.asarray(v, jnp.float32) for k, v in l.items()}
            for l in fused["layers"]
        ],
    }


@pytest.mark.parametrize("r1_kind", rot.R1_KINDS)
@pytest.mark.parametrize("r4_kind", ["GH", "LH"])
def test_rotated_model_equals_fp(r1_kind, r4_kind):
    rng = np.random.default_rng(42)
    params = init_params(CFG, seed=1)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, (2, 24)), jnp.int32)
    expect = forward_fp(params, tokens, CFG)

    r1 = rot.build_r1(r1_kind, CFG.d_model, CFG.group, rng)
    r2 = rot.build_r2(CFG.head_dim, rng)
    r3 = rot.rht(CFG.head_dim, rng)
    if r4_kind == "GH":
        signs = rng.integers(0, 2, CFG.d_ffn) * 2.0 - 1.0
        r4 = rot.hadamard(CFG.d_ffn) * signs[None, :]
    else:
        signs = rng.integers(0, 2, CFG.group) * 2.0 - 1.0
        r4 = rot.block_diag(rot.hadamard(CFG.group) * signs[None, :], CFG.d_ffn)

    fused = fuse_r4(fuse_rotations(params, CFG, r1, r2), r4)
    qp = build_qparams(fused, r3, signs)
    got = forward_rotated(qp, tokens, CFG, a_bits=None, r4_kind=r4_kind, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=2e-3)


def test_pallas_and_ref_paths_agree():
    rng = np.random.default_rng(7)
    params = init_params(CFG, seed=2)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, (2, 16)), jnp.int32)
    r1 = rot.build_r1("GSR", CFG.d_model, CFG.group, rng)
    r2 = rot.build_r2(CFG.head_dim, rng)
    r3 = rot.rht(CFG.head_dim, rng)
    signs = rng.integers(0, 2, CFG.d_ffn) * 2.0 - 1.0
    r4 = rot.hadamard(CFG.d_ffn) * signs[None, :]
    fused = fuse_r4(fuse_rotations(params, CFG, r1, r2), r4)
    qp = build_qparams(fused, r3, signs)
    a = forward_rotated(qp, tokens, CFG, a_bits=4, r4_kind="GH", use_pallas=False)
    b = forward_rotated(qp, tokens, CFG, a_bits=4, r4_kind="GH", use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_r3_does_not_change_function():
    # R3 rotates Q and K identically after RoPE — scores are invariant.
    rng = np.random.default_rng(8)
    params = init_params(CFG, seed=3)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, (1, 12)), jnp.int32)
    r1 = rot.build_r1("GH", CFG.d_model, CFG.group, rng)
    r2 = rot.build_r2(CFG.head_dim, rng)
    signs = rng.integers(0, 2, CFG.d_ffn) * 2.0 - 1.0
    r4 = rot.hadamard(CFG.d_ffn) * signs[None, :]
    fused = fuse_r4(fuse_rotations(params, CFG, r1, r2), r4)
    rng2 = np.random.default_rng(9)
    out_a = forward_rotated(
        build_qparams(fused, rot.rht(CFG.head_dim, rng2), signs),
        tokens, CFG, use_pallas=False,
    )
    out_b = forward_rotated(
        build_qparams(fused, np.eye(CFG.head_dim), signs),
        tokens, CFG, use_pallas=False,
    )
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b), atol=2e-3)


def test_outlier_gamma_is_heavy_tailed():
    params = init_params(ModelCfg(), seed=0)
    g = np.asarray(params["layers"][0]["ln1"])
    assert g.max() / np.median(g) > 3.0, "outlier γ substitution missing"
