"""Rotation-matrix construction properties (paper §2.1/§3.1)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.rotation import (
    R1_KINDS,
    block_diag,
    build_r1,
    build_r4,
    hadamard,
    rht,
    sequency,
    sequency_of_natural_row,
    walsh,
    walsh_permutation,
)

SIZES = st.sampled_from([2, 4, 8, 16, 32, 64, 128, 256])


@given(SIZES)
@settings(max_examples=20, deadline=None)
def test_hadamard_orthonormal(n):
    h = hadamard(n)
    assert np.allclose(h @ h.T, np.eye(n), atol=1e-10)


@given(SIZES)
@settings(max_examples=20, deadline=None)
def test_walsh_row_i_has_sequency_i(n):
    w = walsh(n)
    for i in range(n):
        assert sequency(w[i]) == i


def test_paper_sequency_example_n8():
    # §2.1: natural rows of H8 have sequencies 0, 7, 3, 4, 1, 6, 2, 5.
    assert [sequency_of_natural_row(i, 8) for i in range(8)] == [0, 7, 3, 4, 1, 6, 2, 5]


@given(SIZES)
@settings(max_examples=20, deadline=None)
def test_closed_form_matches_counted(n):
    h = hadamard(n)
    for i in range(n):
        assert sequency_of_natural_row(i, n) == sequency(h[i])


@given(SIZES)
@settings(max_examples=10, deadline=None)
def test_walsh_permutation_is_bijection(n):
    p = walsh_permutation(n)
    assert sorted(p.tolist()) == list(range(n))


def test_rht_randomizes_but_stays_orthonormal():
    rng = np.random.default_rng(5)
    m = rht(64, rng)
    assert np.allclose(m @ m.T, np.eye(64), atol=1e-10)
    assert np.allclose(np.abs(m), 1 / 8.0)


def test_rht_column_flips_preserve_row_sequency_set():
    # §3.2 "Comparing RHT and Walsh": sign flips on columns change each
    # row's measured sequency, but the matrix stays a signed Hadamard —
    # the Walsh re-ordering is an independent axis. Check RHT = H diag(s).
    rng = np.random.default_rng(6)
    m = rht(16, rng)
    h = hadamard(16)
    s = m[0] / h[0]
    assert np.allclose(np.abs(s), 1.0)
    assert np.allclose(h * s[None, :], m)


@pytest.mark.parametrize("kind", R1_KINDS)
def test_build_r1_orthonormal(kind):
    rng = np.random.default_rng(7)
    r = build_r1(kind, 256, 64, rng)
    assert np.allclose(r @ r.T, np.eye(256), atol=1e-9)


@pytest.mark.parametrize("kind", ["LH", "GSR"])
def test_local_kinds_block_diagonal(kind):
    rng = np.random.default_rng(8)
    r = build_r1(kind, 128, 32, rng)
    for bi in range(4):
        for bj in range(4):
            blk = r[bi * 32 : (bi + 1) * 32, bj * 32 : (bj + 1) * 32]
            if bi != bj:
                assert np.all(blk == 0.0)


def test_gsr_blocks_are_walsh():
    rng = np.random.default_rng(9)
    r = build_r1("GSR", 128, 32, rng)
    w = walsh(32)
    for b in range(4):
        assert np.allclose(r[b * 32 : (b + 1) * 32, b * 32 : (b + 1) * 32], w)


def test_block_diag_validates():
    with pytest.raises(ValueError):
        block_diag(walsh(32), 100)  # 32 does not divide 100


def test_build_r4_kinds():
    rng = np.random.default_rng(10)
    for kind in ["GH", "LH"]:
        r = build_r4(kind, 512, 64, rng)
        assert np.allclose(r @ r.T, np.eye(512), atol=1e-9)
    with pytest.raises(ValueError):
        build_r4("GSR", 512, 64, rng)
