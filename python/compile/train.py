"""Build-time pretraining of llama_mini on the synthetic corpus.

Produces the fp32 checkpoint that every quantized variant is derived
from. Hand-rolled Adam (no optax in this offline image), cosine LR with
warmup, next-byte cross-entropy. Runs once under ``make artifacts``;
~300 jitted steps on CPU.

The training loss curve is written to ``artifacts/train_log.json`` and
summarized in EXPERIMENTS.md (the end-to-end validation requirement).
"""

from __future__ import annotations

import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .corpus import generate_corpus
from .model import ModelCfg, init_params, loss_fn, num_params

TRAIN_SEED = 11
DEFAULT_STEPS = 300
BATCH = 16
SEQ = 129  # 128 predictions per row
LR_PEAK = 3e-3
WARMUP = 30


def adam_init(params: Any) -> dict[str, Any]:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    mhat_scale = 1.0 / (1 - b1**tf)
    vhat_scale = 1.0 / (1 - b2**tf)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def lr_schedule(step: jnp.ndarray, total: int) -> jnp.ndarray:
    warm = jnp.minimum(step / WARMUP, 1.0)
    prog = jnp.clip((step - WARMUP) / max(total - WARMUP, 1), 0.0, 1.0)
    return LR_PEAK * warm * (0.5 * (1 + jnp.cos(np.pi * prog)))


def batch_iterator(corpus: bytes, batch: int, seq: int, seed: int):
    """Random contiguous windows from the train split."""
    data = np.frombuffer(corpus, np.uint8)
    rng = np.random.default_rng(seed)
    n = len(data) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield np.stack([data[s : s + seq] for s in starts]).astype(np.int32)


def train(
    cfg: ModelCfg,
    corpus: bytes,
    steps: int = DEFAULT_STEPS,
    seed: int = TRAIN_SEED,
    log_every: int = 20,
) -> tuple[dict[str, Any], list[dict[str, float]]]:
    """Train llama_mini; returns (params, loss log)."""
    params = init_params(cfg, seed=seed)
    print(f"[train] llama_mini params={num_params(params):,}")
    state = adam_init(params)

    # The outlier-γ vectors are architectural constants (see
    # model.outlier_gamma): freeze them by zeroing their gradients.
    def freeze_norms(grads):
        for layer in grads["layers"]:
            layer["ln1"] = jnp.zeros_like(layer["ln1"])
            layer["ln2"] = jnp.zeros_like(layer["ln2"])
        grads["ln_f"] = jnp.zeros_like(grads["ln_f"])
        return grads

    @jax.jit
    def step_fn(params, state, tokens, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, cfg)
        grads = freeze_norms(grads)
        lr = lr_schedule(step.astype(jnp.float32), steps)
        params, state = adam_update(params, grads, state, lr)
        return params, state, loss

    it = batch_iterator(corpus, BATCH, SEQ, seed + 1)
    log: list[dict[str, float]] = []
    t0 = time.time()
    for s in range(steps):
        tokens = jnp.asarray(next(it))
        params, state, loss = step_fn(params, state, tokens, jnp.asarray(s))
        if s % log_every == 0 or s == steps - 1:
            lv = float(loss)
            log.append({"step": s, "loss": lv, "elapsed_s": time.time() - t0})
            print(f"[train] step {s:4d} loss {lv:.4f} ({time.time()-t0:.1f}s)")
    return params, log


def evaluate_ppl_fp(params, cfg: ModelCfg, corpus: bytes, n_windows: int = 32, seq: int = 129) -> float:
    """Validation byte-level perplexity of the fp model (python-side sanity;
    the authoritative eval is the Rust engine over the PJRT artifacts)."""
    from .model import forward_fp

    data = np.frombuffer(corpus, np.uint8)
    total_nll, total_tok = 0.0, 0

    @jax.jit
    def nll_fn(tokens):
        logits = forward_fp(params, tokens[:, :-1], cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        t = tokens[:, 1:]
        return -jnp.take_along_axis(logp, t[..., None], axis=-1)[..., 0].sum()

    for i in range(n_windows):
        s = i * seq
        if s + seq > len(data):
            break
        tokens = jnp.asarray(data[s : s + seq][None].astype(np.int32))
        total_nll += float(nll_fn(tokens))
        total_tok += seq - 1
    return float(np.exp(total_nll / max(total_tok, 1)))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=DEFAULT_STEPS)
    ap.add_argument("--corpus-bytes", type=int, default=1 << 20)
    ap.add_argument("--out", default="../artifacts/train_log.json")
    args = ap.parse_args()
    cfg = ModelCfg()
    corpus = generate_corpus(args.corpus_bytes)
    params, log = train(cfg, corpus, steps=args.steps)
    ppl = evaluate_ppl_fp(params, cfg, corpus)
    print(f"[train] byte PPL (train-dist sample): {ppl:.3f}")
    with open(args.out, "w") as f:
        json.dump({"log": log, "ppl": ppl}, f, indent=1)
