"""PTQ variant sweep: rotate → (learn) → GPTQ → pack → write blobs.

Drives the paper's entire experimental grid at build time:

* **Table 1**: {QuaRot, SpinQuant, OSTQuant} × {W2A16, W2A4} ×
  R1 ∈ {GH, GW, LH, GSR}  (R4 = GH)              → 24 variants
* **Table 2**: QuaRot × {W2A16, W2A4} × R1 ∈ {LH, GSR} × R4 ∈ {GH, LH}
  (the R1×R4-GH cells are shared with Table 1)   → +4 variants

Each variant directory under ``artifacts/variants/<name>/`` holds
``weights.bin`` (flat blobs in ``model.quant_param_spec`` order) and
``meta.json``. The Rust runtime consumes these; nothing here runs at
request time.

GPTQ calibration is **sequential**: layer *l*'s Hessians are computed
from a forward pass in which layers ``< l`` already carry their
quantized (dequantized-dense) weights, so cross-layer error propagation
is accounted for — the same discipline as the QuaRot reference code.
"""

from __future__ import annotations

import functools
import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import rotation as rot
from .gptq import gptq_quantize, pack2
from .model import (
    ModelCfg,
    forward_fp,
    forward_rotated,
    fuse_r4,
    fuse_rotations,
    quant_param_spec,
    rmsnorm,
)

SEED_ROT = 2025
W_BITS = 2
A_BITS = {"w2a16": None, "w2a4": 4}
CALIB_SEQS = 16
CALIB_SEQ_LEN = 128
EVAL_WINDOWS_SANITY = 8

TABLE1_METHODS = ("quarot", "spinquant", "ostquant")
TABLE1_R1 = rot.R1_KINDS  # GH, GW, LH, GSR
TABLE2_GRID = (("LH", "GH"), ("LH", "LH"), ("GSR", "GH"), ("GSR", "LH"))


def variant_name(method: str, bits: str, r1: str, r4: str) -> str:
    return f"{method}_{bits}_{r1.lower()}_r4{r4.lower()}"


def all_variants() -> list[dict[str, str]]:
    out = []
    for method in TABLE1_METHODS:
        for bits in A_BITS:
            for r1 in TABLE1_R1:
                out.append({"method": method, "bits": bits, "r1": r1, "r4": "GH"})
    for bits in A_BITS:
        for r1, r4 in TABLE2_GRID:
            if r4 == "GH":
                continue  # shared with Table 1 (quarot, r4=GH)
            out.append({"method": "quarot", "bits": bits, "r1": r1, "r4": r4})
    return out


# ---------------------------------------------------------------------------
# Shared rotation ingredients (fixed across variants for fair comparison)
# ---------------------------------------------------------------------------


def shared_rotations(cfg: ModelCfg):
    rng = np.random.default_rng(SEED_ROT)
    r2 = rot.build_r2(cfg.head_dim, rng)
    r3 = rot.rht(cfg.head_dim, rng)
    s4_gh = rng.integers(0, 2, cfg.d_ffn) * 2.0 - 1.0
    s4_lh = rng.integers(0, 2, cfg.group) * 2.0 - 1.0
    r4_gh = rot.hadamard(cfg.d_ffn) * s4_gh[None, :]
    r4_lh = rot.block_diag(rot.hadamard(cfg.group) * s4_lh[None, :], cfg.d_ffn)
    return {
        "r2": r2,
        "r3": r3,
        "r4": {"GH": r4_gh, "LH": r4_lh},
        "r4_signs": {"GH": s4_gh, "LH": s4_lh},
    }


def r1_for(kind: str, cfg: ModelCfg) -> np.ndarray:
    # Per-kind deterministic seed so GH/LH sign draws are stable run-to-run.
    rng = np.random.default_rng(SEED_ROT + hash(kind) % 1000)
    return rot.build_r1(kind, cfg.d_model, cfg.group, rng)


# ---------------------------------------------------------------------------
# Calibration capture (jitted; structure constant across variants)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _capture_fn(cfg: ModelCfg, r4_kind: str):
    def fn(qparams, tokens):
        taps: dict[str, jnp.ndarray] = {}

        def tap(name, x):
            taps[name] = x.reshape(-1, x.shape[-1])

        forward_rotated(
            qparams, tokens, cfg, a_bits=None, r4_kind=r4_kind, use_pallas=False, tap=tap
        )
        return taps

    return jax.jit(fn)


def capture_linear_inputs(qparams_dense, tokens, cfg: ModelCfg, r4_kind: str):
    taps = _capture_fn(cfg, r4_kind)(qparams_dense, tokens)
    return {k: np.asarray(v, np.float64) for k, v in taps.items()}


def calib_tokens(corpus: bytes, n_train: int) -> np.ndarray:
    data = np.frombuffer(corpus, np.uint8)[:n_train]
    step = (n_train - CALIB_SEQ_LEN - 1) // CALIB_SEQS
    return np.stack(
        [data[i * step : i * step + CALIB_SEQ_LEN] for i in range(CALIB_SEQS)]
    ).astype(np.int32)


# ---------------------------------------------------------------------------
# fp-model activation capture for the learned pipelines (OSTQuant)
# ---------------------------------------------------------------------------


def capture_fp_sites(params, cfg: ModelCfg, tokens: jnp.ndarray):
    """Per-layer fp activations at the four quantized-input site families.

    Exact rotation equivalence makes these valid calibration tensors for
    any rotated variant (the rotated model's internal values are the fp
    values times the fused orthogonal maps — applied inside the learned
    objectives). Returns numpy [N, dim] arrays, subsampled rows.
    """
    h_attn, h_ffn, o_sites, z_sites = [], [], [], []
    x = params["embed"][tokens]
    from .model import _merge_heads, _split_heads, apply_rope, attention, rope_tables

    cos, sin = rope_tables(tokens.shape[1], cfg.head_dim, cfg.rope_base)
    for layer in params["layers"]:
        hn = rmsnorm(x, cfg.norm_eps)
        h_attn.append(np.asarray(hn.reshape(-1, cfg.d_model)))
        h = hn * layer["ln1"]
        q = _split_heads(h @ layer["wq"], cfg.n_heads)
        k = _split_heads(h @ layer["wk"], cfg.n_heads)
        v = _split_heads(h @ layer["wv"], cfg.n_heads)
        o = _merge_heads(attention(apply_rope(q, cos, sin), apply_rope(k, cos, sin), v))
        o_sites.append(np.asarray(o.reshape(-1, cfg.d_model)))
        x = x + o @ layer["wo"]
        hn = rmsnorm(x, cfg.norm_eps)
        h_ffn.append(np.asarray(hn.reshape(-1, cfg.d_model)))
        h = hn * layer["ln2"]
        z = jax.nn.silu(h @ layer["wgate"]) * (h @ layer["wup"])
        z_sites.append(np.asarray(z.reshape(-1, cfg.d_ffn)))
        x = x + z @ layer["wdown"]
    sub = slice(0, None, 4)  # subsample rows to keep the learned loops light
    return {
        "h_attn": [a[sub] for a in h_attn],
        "h_ffn": [a[sub] for a in h_ffn],
        "o": [a[sub] for a in o_sites],
        "z": [a[sub] for a in z_sites],
    }


# ---------------------------------------------------------------------------
# Variant quantization
# ---------------------------------------------------------------------------

_SITE_OF = {
    "wq": "wq", "wk": "wq", "wv": "wq",
    "wo": "wo",
    "wgate": "wgate", "wup": "wgate",
    "wdown": "wdown",
}


def to_dense_qparams(fused, cfg: ModelCfg, r3, r4_signs, scales=None):
    """Numpy fused params → jnp dense qparams for forward_rotated."""
    qp = {
        "embed": jnp.asarray(fused["embed"], jnp.float32),
        "lm_head": jnp.asarray(fused["lm_head"], jnp.float32),
        "r3": jnp.asarray(r3, jnp.float32),
        "r4_signs": jnp.asarray(r4_signs, jnp.float32),
        "layers": [],
    }
    for li, layer in enumerate(fused["layers"]):
        ql = {k: jnp.asarray(v, jnp.float32) for k, v in layer.items()}
        if scales is not None:
            for key, val in scales[li].items():
                ql[key] = jnp.asarray(val, jnp.float32)
        qp["layers"].append(ql)
    return qp


def apply_ost_weight_scales(fused, scales):
    """W̃ = diag(s)⁻¹ W at each scaled input site (function-preserving
    with the in-graph ``x ⊙ s``)."""
    out = {"embed": fused["embed"], "lm_head": fused["lm_head"], "layers": []}
    for layer, sl in zip(fused["layers"], scales):
        sa = sl["ascale_attn"][:, None]
        so = sl["ascale_o"][:, None]
        sf = sl["ascale_ffn"][:, None]
        sd = sl["ascale_down"][:, None]
        out["layers"].append(
            {
                "wq": layer["wq"] / sa,
                "wk": layer["wk"] / sa,
                "wv": layer["wv"] / sa,
                "wo": layer["wo"] / so,
                "wgate": layer["wgate"] / sf,
                "wup": layer["wup"] / sf,
                "wdown": layer["wdown"] / sd,
            }
        )
    return out


def quantize_variant(
    params: dict[str, Any],
    cfg: ModelCfg,
    spec_v: dict[str, str],
    shared: dict[str, Any],
    calib: np.ndarray,
    fp_sites=None,
) -> tuple[dict[str, Any], dict[str, Any]]:
    """Full pipeline for one variant → (quantized qparams dict, meta)."""
    method, bits, r1k, r4k = spec_v["method"], spec_v["bits"], spec_v["r1"], spec_v["r4"]
    a_bits = A_BITS[bits]
    r4 = shared["r4"][r4k]
    meta: dict[str, Any] = dict(spec_v)

    r1_init = r1_for(r1k, cfg)
    scales = None
    if method == "quarot":
        r1 = r1_init
    elif method == "spinquant":
        from .spinquant import learn_rotation

        pooled = None
        if fp_sites is not None:
            pooled = np.concatenate(fp_sites["h_attn"] + fp_sites["h_ffn"], axis=0)[::4]
        r1, log = learn_rotation(
            params, cfg, r1_init, shared["r2"], r4, w_bits=W_BITS, a_bits=a_bits, calib_h=pooled
        )
        meta["learn_log"] = log
    elif method == "ostquant":
        from .ostquant import learn_ost

        r1, scales, log = learn_ost(
            params, cfg, r1_init, shared["r2"], r4, fp_sites, w_bits=W_BITS, a_bits=a_bits
        )
        meta["learn_log"] = log
    else:
        raise ValueError(method)

    fused = fuse_r4(fuse_rotations(params, cfg, r1, shared["r2"]), r4)
    if scales is not None:
        fused = apply_ost_weight_scales(fused, scales)

    # Sequential GPTQ over layers.
    dense_qp = to_dense_qparams(fused, cfg, shared["r3"], shared["r4_signs"][r4k], scales)
    tokens = jnp.asarray(calib)
    qlayers: list[dict[str, Any]] = []
    total_err = 0.0
    for li in range(cfg.n_layers):
        taps = capture_linear_inputs(dense_qp, tokens, cfg, r4k)
        qlayer: dict[str, Any] = {}
        new_dense: dict[str, Any] = {}
        for name in cfg.LINEARS:
            x = taps[f"layers.{li}.{_SITE_OF[name]}"]
            hess = x.T @ x / x.shape[0]
            w = np.asarray(fused["layers"][li][name], np.float64)
            ql = gptq_quantize(w, hess, W_BITS, cfg.group, mse_clip=True)
            deq = ql.dequant()
            total_err += float(((deq - w) ** 2).sum())
            qlayer[f"{name}_packed"] = pack2(ql.codes)
            qlayer[f"{name}_scale"] = ql.scale.astype(np.float32)
            qlayer[f"{name}_zero"] = ql.zero.astype(np.float32)
            new_dense[name] = jnp.asarray(deq, jnp.float32)
        if scales is not None:
            for key, val in scales[li].items():
                qlayer[key] = np.asarray(val, np.float32)
        else:
            for key, dim in (
                ("ascale_attn", cfg.d_model),
                ("ascale_o", cfg.d_model),
                ("ascale_ffn", cfg.d_model),
                ("ascale_down", cfg.d_ffn),
            ):
                qlayer[key] = np.ones(dim, np.float32)
        qlayers.append(qlayer)
        # Propagate: replace layer li with its dequantized weights.
        merged = dict(dense_qp["layers"][li])
        merged.update(new_dense)
        dense_qp["layers"][li] = merged
    meta["gptq_weight_sse"] = total_err

    qparams = {
        "embed": np.asarray(fused["embed"], np.float32),
        "lm_head": np.asarray(fused["lm_head"], np.float32),
        "r3": np.asarray(shared["r3"], np.float32),
        "r4_signs": np.asarray(shared["r4_signs"][r4k], np.float32),
        "layers": qlayers,
    }
    return qparams, meta


# ---------------------------------------------------------------------------
# Blob I/O (mirrors rust/src/runtime/artifact.rs)
# ---------------------------------------------------------------------------

_DT = {"f32": np.float32, "u8": np.uint8}


def write_blob(qparams: dict[str, Any], cfg: ModelCfg, r4_kind: str, path: str) -> int:
    """Flat little-endian blob in quant_param_spec order."""
    spec = quant_param_spec(cfg, r4_kind)
    with open(path, "wb") as f:
        for name, shape, dt in spec:
            if name.startswith("layers."):
                _, idx, field = name.split(".")
                t = qparams["layers"][int(idx)][field]
            else:
                t = qparams[name]
            arr = np.ascontiguousarray(np.asarray(t, _DT[dt]).reshape(shape))
            f.write(arr.tobytes())
        return f.tell()


def sanity_ppl(
    qparams, cfg: ModelCfg, corpus: bytes, a_bits, r4_kind: str, test_start: int
) -> float:
    """Quick python-side PPL on a few test-split windows (ref path)."""
    data = np.frombuffer(corpus, np.uint8)
    qp = {
        "embed": jnp.asarray(qparams["embed"]),
        "lm_head": jnp.asarray(qparams["lm_head"]),
        "r3": jnp.asarray(qparams["r3"]),
        "r4_signs": jnp.asarray(qparams["r4_signs"]),
        "layers": [
            {k: jnp.asarray(v) for k, v in ql.items()} for ql in qparams["layers"]
        ],
    }

    @jax.jit
    def nll(tokens):
        logits = forward_rotated(
            qp, tokens[:, :-1], cfg, a_bits=a_bits, r4_kind=r4_kind, use_pallas=False
        )
        logp = jax.nn.log_softmax(logits, axis=-1)
        t = tokens[:, 1:]
        return -jnp.take_along_axis(logp, t[..., None], axis=-1)[..., 0].sum()

    total, count = 0.0, 0
    seq = CALIB_SEQ_LEN + 1
    for i in range(EVAL_WINDOWS_SANITY):
        s = test_start + i * seq
        tok = jnp.asarray(data[s : s + seq][None].astype(np.int32))
        total += float(nll(tok))
        count += seq - 1
    return float(np.exp(total / count))
