"""OSTQuant-style learned orthogonal + scaling transformation (simplified).

OSTQuant (Hu et al., ICLR 2025) refines rotation-based PTQ by jointly
learning an **o**rthogonal transform and per-channel **s**caling
**t**ransformations that reshape weight/activation distributions before
quantization. Our miniature (DESIGN.md §2) keeps both learned objects:

* R1 via the Cayley parametrization (init = the Table-1 R1 variant), and
* per-layer, per-site positive scale vectors ``s`` applied between the
  activation and the weight: ``x̃ = x ⊙ s``, ``W̃ = diag(s)⁻¹ W`` —
  function-preserving, folded into the deployed graph as the
  ``ascale_*`` parameters of model.forward_rotated.

Objective = STE weight-quant MSE (on scaled rotated weights) + STE
activation-quant MSE (on scaled rotated calibration activations) — the
"distribution fitting" loss, minimized with Adam.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .model import ModelCfg
from .spinquant import cayley, prefold_gamma, ste_fake_quant_asym, ste_fake_quant_sym
from .train import adam_init, adam_update

DEFAULT_STEPS = 80
LR = 2e-3


def learn_ost(
    params: dict[str, Any],
    cfg: ModelCfg,
    r1_init: np.ndarray,
    r2: np.ndarray,
    r4: np.ndarray,
    calib: dict[str, list[np.ndarray]],
    *,
    w_bits: int = 2,
    a_bits: int | None = None,
    steps: int = DEFAULT_STEPS,
    lr: float = LR,
) -> tuple[np.ndarray, list[dict[str, np.ndarray]], list[float]]:
    """Learn (R1, per-layer scales) jointly.

    ``calib``: fp-model activation samples per site family —
    ``{"h_attn": [per-layer [N,d]], "h_ffn": [...], "o": [...], "z": [per-layer [N,ffn]]}``
    (exact-equivalence makes fp activations valid calibration for the
    rotated model; see quantize.py).

    Returns ``(R1 fp64-orthogonal, scales per layer
    {ascale_attn, ascale_o, ascale_ffn, ascale_down}, loss log)``.
    """
    d, f = cfg.d_model, cfg.d_ffn
    nl = cfg.n_layers
    b2 = jnp.asarray(np.kron(np.eye(cfg.n_heads), r2), jnp.float32)
    r1_0 = jnp.asarray(r1_init, jnp.float32)
    r4_j = jnp.asarray(r4, jnp.float32)
    folded = prefold_gamma(params, cfg, np.asarray(r4, np.float64).T)

    cal = {
        "h_attn": [jnp.asarray(a, jnp.float32) for a in calib["h_attn"]],
        "h_ffn": [jnp.asarray(a, jnp.float32) for a in calib["h_ffn"]],
        "o": [jnp.asarray(a, jnp.float32) for a in calib["o"]],
        "z": [jnp.asarray(a, jnp.float32) for a in calib["z"]],
    }

    def split_theta(theta):
        a = theta["a"]
        # log-parametrized scales → strictly positive
        scales = [
            {
                "ascale_attn": jnp.exp(theta["s_attn"][l]),
                "ascale_o": jnp.exp(theta["s_o"][l]),
                "ascale_ffn": jnp.exp(theta["s_ffn"][l]),
                "ascale_down": jnp.exp(theta["s_down"][l]),
            }
            for l in range(nl)
        ]
        return a, scales

    def objective(theta):
        a, scales = split_theta(theta)
        r1 = cayley(a) @ r1_0
        loss = 0.0
        for l, layer in enumerate(folded["layers"]):
            sa = scales[l]["ascale_attn"][:, None]
            so = scales[l]["ascale_o"][:, None]
            sf = scales[l]["ascale_ffn"][:, None]
            sd = scales[l]["ascale_down"][:, None]
            ws = [
                (r1.T @ layer["wq_g"]) / sa,
                (r1.T @ layer["wk_g"]) / sa,
                (r1.T @ layer["wv_g"] @ b2) / sa,
                (b2.T @ layer["wo"] @ r1) / so,
                (r1.T @ layer["wgate_g"]) / sf,
                (r1.T @ layer["wup_g"]) / sf,
                (layer["wdown_r4"] @ r1) / sd,
            ]
            for w in ws:
                loss = loss + jnp.mean((w - ste_fake_quant_asym(w, w_bits, cfg.group)) ** 2)
            if a_bits is not None:
                acts = [
                    (cal["h_attn"][l] @ r1) * sa[:, 0],
                    (cal["o"][l] @ b2) * so[:, 0],
                    (cal["h_ffn"][l] @ r1) * sf[:, 0],
                    (cal["z"][l] @ r4_j) * sd[:, 0],
                ]
                for x in acts:
                    loss = loss + 0.25 * jnp.mean(
                        (x - ste_fake_quant_sym(x, a_bits, cfg.group)) ** 2
                    )
        return loss

    theta = {
        "a": jnp.zeros((d, d), jnp.float32),
        "s_attn": jnp.zeros((nl, d), jnp.float32),
        "s_o": jnp.zeros((nl, d), jnp.float32),
        "s_ffn": jnp.zeros((nl, d), jnp.float32),
        "s_down": jnp.zeros((nl, f), jnp.float32),
    }
    state = adam_init(theta)

    @jax.jit
    def step(theta, state):
        loss, grad = jax.value_and_grad(objective)(theta)
        theta, state = adam_update(theta, grad, state, lr)
        return theta, state, loss

    log = []
    for s in range(steps):
        theta, state, loss = step(theta, state)
        if s % 10 == 0 or s == steps - 1:
            log.append(float(loss))

    a64 = np.asarray(theta["a"], np.float64)
    s64 = a64 - a64.T
    eye = np.eye(d)
    r1_learned = np.linalg.solve((eye + s64).T, (eye - s64).T).T @ np.asarray(
        r1_init, np.float64
    )
    _, scales_j = split_theta(theta)
    scales = [
        {k: np.asarray(v, np.float64) for k, v in sl.items()} for sl in scales_j
    ]
    return r1_learned, scales, log
