"""SpinQuant-style learned rotation (simplified; DESIGN.md §2).

The real SpinQuant optimizes R1/R2 on the Stiefel manifold against the
network loss with quantization in the loop. Our miniature keeps the two
defining ingredients — (a) a *learned orthogonal* R1 via the Cayley
parametrization, (b) quantization-aware objective with a straight-through
estimator — but optimizes the layerwise proxy

    L(R1) = Σ_linears ‖W'(R1) − fq(W'(R1))‖²  (+ activation term under A4)

over the rotated-fused weights W'(R1) from model.fuse_rotations. This
preserves the paper's comparison structure: the learned method beats its
own initialization, and a GSR initialization beats a GH one (Table 1's
SpinQuant block).

The orthogonality invariant R1 R1ᵀ = I holds *exactly* throughout (Cayley
maps skew-symmetric A to orthogonal Q), asserted by tests.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .model import ModelCfg
from .train import adam_init, adam_update

DEFAULT_STEPS = 60
LR = 1e-3


def cayley(a: jnp.ndarray) -> jnp.ndarray:
    """Skew(A) → orthogonal: ``(I − S)(I + S)⁻¹`` with ``S = A − Aᵀ``."""
    s = a - a.T
    n = a.shape[0]
    eye = jnp.eye(n, dtype=a.dtype)
    return jnp.linalg.solve((eye + s).T, (eye - s).T).T


def ste_fake_quant_asym(w: jnp.ndarray, bits: int, group: int) -> jnp.ndarray:
    """Asymmetric group fake-quant along axis 0, output detached.

    For the reconstruction objective ``‖w − fq(w)‖²`` the quantized value
    must be a *constant* w.r.t. the learned transform: the gradient
    ``2(w − fq(w))`` then pulls the rotated weights toward their current
    grid points. (A value-STE ``w + sg(fq(w) − w)`` makes the residual a
    pure stop_gradient and kills the gradient entirely — the classic
    trap; caught by tests/test_learned.py.)
    """
    c, h = w.shape
    qmax = (1 << bits) - 1
    wg = w.reshape(c // group, group, h)
    lo = jnp.min(wg, axis=1, keepdims=True)
    hi = jnp.max(wg, axis=1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-12)
    zero = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(wg / scale) + zero, 0, qmax)
    deq = ((q - zero) * scale).reshape(c, h)
    return jax.lax.stop_gradient(deq)


def ste_fake_quant_sym(x: jnp.ndarray, bits: int, group: int, clip: float = 0.9) -> jnp.ndarray:
    """Symmetric group fake-quant along the last axis, output detached
    (see :func:`ste_fake_quant_asym` for why)."""
    qmax = (1 << (bits - 1)) - 1
    orig = x.shape
    xg = x.reshape(*orig[:-1], orig[-1] // group, group)
    scale = jnp.maximum(clip * jnp.max(jnp.abs(xg), axis=-1, keepdims=True) / qmax, 1e-12)
    q = jnp.clip(jnp.round(xg / scale), -qmax, qmax)
    deq = (q * scale).reshape(orig)
    return jax.lax.stop_gradient(deq)


def _rotated_weights(params_f64: dict[str, Any], cfg: ModelCfg, r1: jnp.ndarray, b2: jnp.ndarray):
    """Differentiable re-statement of model.fuse_rotations for the R1 slots.

    Yields (name, W', quant_axis0_group_relevant) for every quantized
    linear. γ is pre-folded into the float weights by the caller.
    """
    ws = []
    for layer in params_f64["layers"]:
        ws.append(r1.T @ layer["wq_g"])
        ws.append(r1.T @ layer["wk_g"])
        ws.append(r1.T @ layer["wv_g"] @ b2)
        ws.append(b2.T @ layer["wo"] @ r1)
        ws.append(r1.T @ layer["wgate_g"])
        ws.append(r1.T @ layer["wup_g"])
        ws.append(layer["wdown_r4"] @ r1)
    return ws


def prefold_gamma(params: dict[str, Any], cfg: ModelCfg, r4t: np.ndarray) -> dict[str, Any]:
    """Fold RMSNorm γ (and R4ᵀ into wdown) once, outside the learned loop."""
    out = {"layers": []}
    for layer in params["layers"]:
        g1 = np.asarray(layer["ln1"], np.float64)[:, None]
        g2 = np.asarray(layer["ln2"], np.float64)[:, None]
        out["layers"].append(
            {
                "wq_g": jnp.asarray(g1 * np.asarray(layer["wq"], np.float64), jnp.float32),
                "wk_g": jnp.asarray(g1 * np.asarray(layer["wk"], np.float64), jnp.float32),
                "wv_g": jnp.asarray(g1 * np.asarray(layer["wv"], np.float64), jnp.float32),
                "wo": jnp.asarray(layer["wo"], jnp.float32),
                "wgate_g": jnp.asarray(g2 * np.asarray(layer["wgate"], np.float64), jnp.float32),
                "wup_g": jnp.asarray(g2 * np.asarray(layer["wup"], np.float64), jnp.float32),
                "wdown_r4": jnp.asarray(r4t @ np.asarray(layer["wdown"], np.float64), jnp.float32),
            }
        )
    return out


def learn_rotation(
    params: dict[str, Any],
    cfg: ModelCfg,
    r1_init: np.ndarray,
    r2: np.ndarray,
    r4: np.ndarray,
    *,
    w_bits: int = 2,
    a_bits: int | None = None,
    calib_h: np.ndarray | None = None,
    steps: int = DEFAULT_STEPS,
    lr: float = LR,
) -> tuple[np.ndarray, list[float]]:
    """Learn R1 = cayley(A) @ R1_init minimizing the STE quant proxy.

    ``calib_h``: optional [N, d_model] pre-norm hidden samples for the
    activation-quantization term under A4 (the rotated activation
    ``h @ R1`` is what gets RTN-quantized at the linear inputs).
    Returns the learned R1 (fp64, exactly orthogonal) and the loss log.
    """
    d = cfg.d_model
    b2 = jnp.asarray(np.kron(np.eye(cfg.n_heads), r2), jnp.float32)
    r1_0 = jnp.asarray(r1_init, jnp.float32)
    folded = prefold_gamma(params, cfg, np.asarray(r4, np.float64).T)
    hcal = None if calib_h is None else jnp.asarray(calib_h, jnp.float32)

    def objective(a):
        r1 = cayley(a) @ r1_0
        loss = 0.0
        for w in _rotated_weights(folded, cfg, r1, b2):
            loss = loss + jnp.mean((w - ste_fake_quant_asym(w, w_bits, cfg.group)) ** 2)
        if a_bits is not None and hcal is not None:
            hr = hcal @ r1
            loss = loss + jnp.mean((hr - ste_fake_quant_sym(hr, a_bits, cfg.group)) ** 2)
        return loss

    a = jnp.zeros((d, d), jnp.float32)
    state = adam_init(a)

    @jax.jit
    def step(a, state):
        loss, grad = jax.value_and_grad(objective)(a)
        a, state = adam_update(a, grad, state, lr)
        return a, state, loss

    log = []
    for s in range(steps):
        a, state, loss = step(a, state)
        if s % 10 == 0 or s == steps - 1:
            log.append(float(loss))
    # Exact orthogonalization in fp64 (Cayley in fp64 of the learned skew).
    a64 = np.asarray(a, np.float64)
    s64 = a64 - a64.T
    eye = np.eye(d)
    r1_learned = np.linalg.solve((eye + s64).T, (eye - s64).T).T @ np.asarray(r1_init, np.float64)
    return r1_learned, log
