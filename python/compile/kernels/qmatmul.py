"""Pallas kernel: fused 2-bit dequantize → matmul (the W2 linear layer).

The quantized model's hot path: every transformer linear is
``x[M,K] @ dequant(packed[K/4,N], scale[K/G,N], zero[K/G,N])``.

TPU mapping (DESIGN.md §5): the grid tiles (M, N); each step owns the
full K reduction in VMEM (K ≤ 512 here → a 512×128 f32 tile is 256 KiB,
comfortably inside the ~16 MiB VMEM budget). Codes are unpacked from
uint8 with shift/mask VPU ops, dequantized to the activation dtype, and
fed to the MXU-shaped ``dot``. Per-group scales broadcast along K in
G-aligned spans so a quantization group never straddles a tile boundary.

interpret=True throughout — see walsh.py header.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 32
DEFAULT_BLOCK_N = 128


def _dequant_matmul_kernel(x_ref, p_ref, s_ref, z_ref, o_ref, *, group: int):
    x = x_ref[...]  # (bm, K)
    p = p_ref[...].astype(jnp.int32)  # (K/4, bn) packed codes
    kq, bn = p.shape
    k = kq * 4
    # Unpack 4 codes per byte along K (VPU shift/mask).
    codes = jnp.stack(
        [(p >> 0) & 3, (p >> 2) & 3, (p >> 4) & 3, (p >> 6) & 3], axis=1
    ).reshape(k, bn)
    # Dequantize with per-(group, out-channel) scale/zero.
    s = s_ref[...]  # (K/G, bn)
    z = z_ref[...]  # (K/G, bn)
    cg = codes.reshape(k // group, group, bn).astype(x.dtype)
    w = (cg - z[:, None, :]) * s[:, None, :]
    w = w.reshape(k, bn)
    o_ref[...] = jnp.dot(x, w, preferred_element_type=x.dtype)


@functools.partial(jax.jit, static_argnames=("group", "block_m", "block_n"))
def dequant_matmul_pallas(
    x: jnp.ndarray,
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    group: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
) -> jnp.ndarray:
    """``x @ dequant(packed)`` with 2-bit packed weights (Pallas).

    * ``x``      f32 ``[..., K]``
    * ``packed`` uint8 ``[K/4, N]`` (4 codes/byte, LSB-first — ref.pack2)
    * ``scale``  f32 ``[K/G, N]``, ``zero`` f32 ``[K/G, N]``

    Matches ``ref.dequant_matmul`` exactly.
    """
    orig = x.shape
    k = orig[-1]
    kq, n = packed.shape
    assert kq * 4 == k, f"packed K mismatch: {kq}*4 != {k}"
    assert k % group == 0
    rows = 1
    for d in orig[:-1]:
        rows *= d
    x2 = x.reshape(rows, k)
    bm = min(block_m, rows)
    bn = min(block_n, n)
    pad_m = (-rows) % bm
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    assert n % bn == 0, "block_n must divide N"
    m = x2.shape[0]
    kernel = functools.partial(_dequant_matmul_kernel, group=group)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((kq, bn), lambda i, j: (0, j)),
            pl.BlockSpec((k // group, bn), lambda i, j: (0, j)),
            pl.BlockSpec((k // group, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x2, packed, scale, zero)
    return out[:rows].reshape(*orig[:-1], n)
