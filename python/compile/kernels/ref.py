"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here is the mathematically-obvious implementation of the
corresponding kernel in ``walsh.py`` / ``quant.py`` / ``qmatmul.py``.
``python/tests/`` asserts kernel ≡ oracle over hypothesis-driven sweeps of
shapes, dtypes and group sizes; the oracles themselves are validated
against numpy/rotation.py in ``test_rotation_invariance.py``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..rotation import walsh_permutation


# ---------------------------------------------------------------------------
# Walsh–Hadamard transforms
# ---------------------------------------------------------------------------


def fwht(x: jnp.ndarray) -> jnp.ndarray:
    """Fast Walsh–Hadamard transform along the last axis (natural order).

    Equivalent to ``x @ hadamard(n)`` (the Sylvester matrix is symmetric),
    computed with the O(n log n) butterfly. Orthonormal scaling.
    """
    n = x.shape[-1]
    assert n & (n - 1) == 0, "FWHT size must be a power of two"
    orig = x.shape
    h = 1
    while h < n:
        x = x.reshape(*orig[:-1], n // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    return x.reshape(orig) / jnp.sqrt(jnp.asarray(n, x.dtype))


def walsh_transform(x: jnp.ndarray) -> jnp.ndarray:
    """``x @ walsh(n).T`` — FWHT followed by the sequency permutation.

    ``walsh(n) = hadamard(n)[p]`` (rows permuted), so
    ``x @ walsh.T = (x @ hadamard)[..., p]``.
    """
    p = np.asarray(walsh_permutation(x.shape[-1]))
    return fwht(x)[..., p]


def grouped_fwht(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """Block-diagonal FWHT: ``x @ (I ⊗ H_G)`` — the local-rotation fast path.

    The paper's Appendix A.2 notes local online rotation defeats the CUDA
    fast-hadamard-transform; on TPU (and here) each block is simply an
    independent small butterfly, so the grouped transform is *cheaper*
    than the global one.
    """
    n = x.shape[-1]
    assert n % group == 0, "group must divide the transform size"
    xg = x.reshape(*x.shape[:-1], n // group, group)
    return fwht(xg).reshape(x.shape)


def rotate_online(x: jnp.ndarray, rot: jnp.ndarray) -> jnp.ndarray:
    """Dense-matmul reference for an arbitrary online rotation ``x @ R``."""
    return x @ rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# Group quantizers
# ---------------------------------------------------------------------------


def rtn_fake_quant_sym(
    x: jnp.ndarray, bits: int, group: int, clip_ratio: float = 1.0
) -> jnp.ndarray:
    """Symmetric round-to-nearest fake quantization along the last axis.

    QuaRot's activation quantizer: per-group absmax scaling with a clip
    ratio (paper A.1 uses clip 0.9); values round to
    ``{-qmax, …, qmax}`` and dequantize back to float.
    """
    qmax = (1 << (bits - 1)) - 1
    orig = x.shape
    xg = x.reshape(*orig[:-1], orig[-1] // group, group)
    scale = clip_ratio * jnp.max(jnp.abs(xg), axis=-1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, jnp.ones_like(scale), scale)
    q = jnp.clip(jnp.round(xg / scale), -qmax, qmax)
    return (q * scale).reshape(orig)


def rtn_quant_asym(
    w: jnp.ndarray, bits: int, group: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Asymmetric per-group weight quantization along axis 0 (input dim).

    Returns ``(codes, scale, zero)`` with
    ``w ≈ (codes - zero) * scale`` broadcast over groups:
    ``codes`` int32 ``[C, H]``, ``scale``/``zero`` f32 ``[C/G, H]``.
    """
    c, h = w.shape
    qmax = (1 << bits) - 1
    wg = w.reshape(c // group, group, h)
    lo = jnp.min(wg, axis=1)
    hi = jnp.max(wg, axis=1)
    scale = (hi - lo) / qmax
    scale = jnp.where(scale == 0, jnp.ones_like(scale), scale)
    zero = jnp.round(-lo / scale)
    codes = jnp.clip(jnp.round(wg / scale[:, None, :]) + zero[:, None, :], 0, qmax)
    return codes.reshape(c, h).astype(jnp.int32), scale, zero


def dequant(
    codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray, group: int
) -> jnp.ndarray:
    """Inverse of :func:`rtn_quant_asym` — expand codes back to float."""
    c, h = codes.shape
    cg = codes.reshape(c // group, group, h).astype(scale.dtype)
    w = (cg - zero[:, None, :]) * scale[:, None, :]
    return w.reshape(c, h)


# ---------------------------------------------------------------------------
# Packed 2-/4-bit storage + dequant-matmul
# ---------------------------------------------------------------------------


def pack2(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack 2-bit codes ``[C, H]`` (values 0..3) into uint8 ``[C/4, H]``.

    Codes for input channels ``4b .. 4b+3`` live in bits
    ``[0:2] [2:4] [4:6] [6:8]`` of byte ``b`` — matching
    ``rust/src/quant/pack.rs``.
    """
    c, h = codes.shape
    assert c % 4 == 0
    u = codes.astype(jnp.uint8).reshape(c // 4, 4, h)
    return u[:, 0] | (u[:, 1] << 2) | (u[:, 2] << 4) | (u[:, 3] << 6)


def unpack2(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack2` — uint8 ``[C/4, H]`` → int32 codes ``[C, H]``."""
    cb, h = packed.shape
    p = packed.astype(jnp.int32)
    parts = jnp.stack(
        [(p >> 0) & 3, (p >> 2) & 3, (p >> 4) & 3, (p >> 6) & 3], axis=1
    )
    return parts.reshape(cb * 4, h)


def pack4(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack 4-bit codes ``[C, H]`` (values 0..15) into uint8 ``[C/2, H]``.

    Codes for input channels ``2b`` / ``2b+1`` live in bits ``[0:4]`` /
    ``[4:8]`` of byte ``b`` — the same LSB-first rule as :func:`pack2`,
    matching ``rust/src/quant/pack.rs::pack4``.
    """
    c, h = codes.shape
    assert c % 2 == 0
    u = codes.astype(jnp.uint8).reshape(c // 2, 2, h)
    return u[:, 0] | (u[:, 1] << 4)


def unpack4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack4` — uint8 ``[C/2, H]`` → int32 codes ``[C, H]``."""
    cb, h = packed.shape
    p = packed.astype(jnp.int32)
    parts = jnp.stack([(p >> 0) & 15, (p >> 4) & 15], axis=1)
    return parts.reshape(cb * 2, h)


def dequant_matmul(
    x: jnp.ndarray,
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    group: int,
) -> jnp.ndarray:
    """``x @ dequant(unpack2(packed))`` — the W2 linear-layer oracle."""
    w = dequant(unpack2(packed), scale, zero, group)
    return x @ w.astype(x.dtype)
