"""Pallas kernel for group RTN activation fake-quantization.

QuaRot-style A4: symmetric round-to-nearest per feature group with a clip
ratio (paper A.1: symmetric RTN, clip 0.9, grouped). Runs *inside* the
forward graph for the W2A4 configs, so it is part of the request path the
Rust runtime executes.

Tiling: the grid walks (row tiles × feature groups); a tile is one
``(block_rows, group)`` VMEM block — the per-group absmax reduction never
crosses a tile, so no cross-step communication is needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8


def _rtn_sym_kernel(x_ref, o_ref, *, qmax: float, clip_ratio: float):
    x = x_ref[...]
    scale = clip_ratio * jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, jnp.ones_like(scale), scale)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    o_ref[...] = q * scale


@functools.partial(
    jax.jit, static_argnames=("bits", "group", "clip_ratio", "block_rows")
)
def rtn_fake_quant_sym_pallas(
    x: jnp.ndarray,
    bits: int,
    group: int,
    clip_ratio: float = 1.0,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jnp.ndarray:
    """Symmetric per-group fake quant along the last axis (Pallas).

    Matches ``ref.rtn_fake_quant_sym`` exactly. A *group* here is a
    contiguous span of features, aligned with the weight-quant groups so
    a group never straddles a matmul K-tile (DESIGN.md §5).
    """
    orig = x.shape
    n = orig[-1]
    assert n % group == 0, "group must divide the feature width"
    qmax = float((1 << (bits - 1)) - 1)
    rows = int(np.prod(orig[:-1])) if len(orig) > 1 else 1
    x2 = x.reshape(rows, n)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    kernel = functools.partial(_rtn_sym_kernel, qmax=qmax, clip_ratio=clip_ratio)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        grid=(x2.shape[0] // br, n // group),
        in_specs=[pl.BlockSpec((br, group), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, group), lambda i, j: (i, j)),
        interpret=True,
    )(x2)
    return out[:rows].reshape(orig)
