"""L1 Pallas kernels + pure-jnp oracles (ref.py)."""
