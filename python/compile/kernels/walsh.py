"""Pallas kernels for the (grouped) Walsh–Hadamard transform.

These are the online-rotation hot paths of the paper's system: R4 rotates
the down-projection input on every forward pass (QuaRot's CUDA
``fast-hadamard-transform``); GSR's block-diagonal structure maps to a
*grouped* transform.

TPU adaptation (DESIGN.md §5): instead of warp-level shared-memory
butterflies, each grid step owns a ``(block_rows, width)`` VMEM tile and
runs the O(n log n) add/sub butterfly entirely in registers/VMEM — pure
VPU work, leaving the MXU free for the matmuls. The grouped variant tiles
the *block* dimension too, so a local rotation is strictly more parallel
than a global one (the inverse of the paper's Appendix A.2 GPU
limitation).

All kernels are lowered with ``interpret=True``: the CPU PJRT client
cannot execute Mosaic custom-calls, and interpret mode lowers to plain
HLO that the Rust runtime runs directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..rotation import walsh_permutation

# Rows per grid step. 8×width f32 tiles keep VMEM usage trivial
# (8·512·4B = 16 KiB) while amortizing grid overhead.
DEFAULT_BLOCK_ROWS = 8


def _butterfly(x: jnp.ndarray) -> jnp.ndarray:
    """In-tile orthonormal FWHT butterfly over the last axis."""
    n = x.shape[-1]
    lead = x.shape[:-1]
    h = 1
    while h < n:
        x = x.reshape(*lead, n // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    return x.reshape(*lead, n) * (1.0 / np.sqrt(n)).astype(x.dtype)


def _fwht_kernel(x_ref, o_ref):
    o_ref[...] = _butterfly(x_ref[...])


def _grouped_fwht_kernel(x_ref, o_ref):
    # The tile *is* one (rows × group) block of the block-diagonal
    # transform; blocks never interact, so the kernel body is identical —
    # the grid supplies the locality.
    o_ref[...] = _butterfly(x_ref[...])


def _signed_fwht_kernel(s_ref, x_ref, o_ref):
    # RHT: x @ (H · diag(s)) = fwht(x) ⊙ s  — the sign row rides along in
    # VMEM as a (1, width) tile.
    o_ref[...] = _butterfly(x_ref[...]) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def fwht_pallas(x: jnp.ndarray, block_rows: int = DEFAULT_BLOCK_ROWS) -> jnp.ndarray:
    """Global FWHT along the last axis (natural ordering), Pallas-tiled.

    ``x`` is flattened to ``(rows, n)``; the grid walks row tiles.
    Matches ``ref.fwht`` exactly.
    """
    orig = x.shape
    n = orig[-1]
    rows = int(np.prod(orig[:-1])) if len(orig) > 1 else 1
    x2 = x.reshape(rows, n)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _fwht_kernel,
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        grid=(x2.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        interpret=True,
    )(x2)
    return out[:rows].reshape(orig)


@functools.partial(jax.jit, static_argnames=("group", "block_rows"))
def grouped_fwht_pallas(
    x: jnp.ndarray, group: int, block_rows: int = DEFAULT_BLOCK_ROWS
) -> jnp.ndarray:
    """Block-diagonal FWHT ``x @ (I ⊗ H_G)`` — the GSR/local fast path.

    Grid = (row tiles × blocks); each step transforms one
    ``(block_rows, group)`` VMEM tile independently.
    """
    orig = x.shape
    n = orig[-1]
    assert n % group == 0, "group must divide the transform width"
    rows = int(np.prod(orig[:-1])) if len(orig) > 1 else 1
    x2 = x.reshape(rows, n)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _grouped_fwht_kernel,
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        grid=(x2.shape[0] // br, n // group),
        in_specs=[pl.BlockSpec((br, group), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, group), lambda i, j: (i, j)),
        interpret=True,
    )(x2)
    return out[:rows].reshape(orig)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def rht_pallas(
    x: jnp.ndarray, signs: jnp.ndarray, block_rows: int = DEFAULT_BLOCK_ROWS
) -> jnp.ndarray:
    """Randomized Hadamard transform ``x @ (H · diag(signs))``."""
    orig = x.shape
    n = orig[-1]
    rows = int(np.prod(orig[:-1])) if len(orig) > 1 else 1
    x2 = x.reshape(rows, n)
    s2 = signs.reshape(1, n).astype(x.dtype)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _signed_fwht_kernel,
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        grid=(x2.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        interpret=True,
    )(s2, x2)
    return out[:rows].reshape(orig)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def walsh_transform_pallas(
    x: jnp.ndarray, block_rows: int = DEFAULT_BLOCK_ROWS
) -> jnp.ndarray:
    """Sequency-ordered transform ``x @ walsh(n).T``.

    FWHT butterfly + in-tile sequency gather (the permutation is a
    compile-time constant — zero runtime cost beyond the gather).
    """
    n = x.shape[-1]
    perm = jnp.asarray(np.asarray(walsh_permutation(n)), dtype=jnp.int32).reshape(1, n)

    def kernel(p_ref, x_ref, o_ref):
        o_ref[...] = _butterfly(x_ref[...])[..., p_ref[0, :]]

    orig = x.shape
    rows = int(np.prod(orig[:-1])) if len(orig) > 1 else 1
    x2 = x.reshape(rows, n)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        grid=(x2.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        interpret=True,
    )(perm, x2)
    return out[:rows].reshape(orig)
