"""Build-time compile path: JAX model, Pallas kernels, PTQ pipelines, AOT export.

Nothing in this package runs at request time — `make artifacts` invokes it
once; the Rust coordinator consumes only `artifacts/`.
"""
