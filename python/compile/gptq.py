"""GPTQ (Frantar et al., 2022) with group quantization and MSE clipping.

The weight quantizer used by all three pipelines in the paper's Table 1
(QuaRot applies it directly; our SpinQuant/OSTQuant reimplementations
apply it after their learned transforms — see DESIGN.md §2).

Conventions (matching model.py): a linear is ``out = x @ W`` with
``W ∈ R^{C×H}`` (C input channels, H output channels). Quantization
groups span ``G`` consecutive *input* channels per output channel —
the grouping Observation #1 in the paper reasons about. GPTQ therefore
walks input channels in order, propagating the quantization error of
channel ``c`` into the not-yet-quantized channels ``c+1..`` through the
inverse Hessian (``Hess = Xᵀ X`` over calibration activations).

Mirrored (RTN + pack + dequant parts) by ``rust/src/quant/``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

DAMP_FRAC = 0.01
CLIP_GRID = np.linspace(0.4, 1.0, 13)


@dataclasses.dataclass
class QuantizedLinear:
    """GPTQ output for one linear: codes + per-group affine params."""

    codes: np.ndarray  # int32 [C, H], values in [0, 2^bits)
    scale: np.ndarray  # f32  [C/G, H]
    zero: np.ndarray  # f32  [C/G, H]
    group: int
    bits: int

    def dequant(self) -> np.ndarray:
        c, h = self.codes.shape
        g = self.group
        cg = self.codes.reshape(c // g, g, h).astype(np.float64)
        w = (cg - self.zero[:, None, :]) * self.scale[:, None, :]
        return w.reshape(c, h)


def _group_params(
    wg: np.ndarray, bits: int, mse_clip: bool
) -> tuple[np.ndarray, np.ndarray]:
    """Scale/zero for one ``[G, H]`` group (asymmetric, optional MSE clip).

    The MSE clip searches a shrink factor per output channel over
    ``CLIP_GRID`` minimizing reconstruction MSE (paper A.1: "asymmetric
    weight quantization, MSE-based clipping").
    """
    qmax = (1 << bits) - 1
    lo = wg.min(axis=0)  # [H]
    hi = wg.max(axis=0)
    best_scale = np.maximum((hi - lo) / qmax, 1e-12)
    best_zero = np.round(-lo / best_scale)
    if not mse_clip:
        return best_scale, best_zero
    best_err = np.full(wg.shape[1], np.inf)
    out_scale = best_scale.copy()
    out_zero = best_zero.copy()
    for k in CLIP_GRID:
        scale = np.maximum((hi * k - lo * k) / qmax, 1e-12)
        zero = np.round(-lo * k / scale)
        q = np.clip(np.round(wg / scale) + zero, 0, qmax)
        deq = (q - zero) * scale
        err = ((deq - wg) ** 2).sum(axis=0)
        better = err < best_err
        best_err = np.where(better, err, best_err)
        out_scale = np.where(better, scale, out_scale)
        out_zero = np.where(better, zero, out_zero)
    return out_scale, out_zero


def rtn_quantize(
    w: np.ndarray, bits: int, group: int, mse_clip: bool = True
) -> QuantizedLinear:
    """Plain round-to-nearest group quantization (the GPTQ-less baseline)."""
    c, h = w.shape
    assert c % group == 0
    qmax = (1 << bits) - 1
    n = c // group
    codes = np.empty((c, h), np.int32)
    scale = np.empty((n, h), np.float64)
    zero = np.empty((n, h), np.float64)
    for g in range(n):
        wg = w[g * group : (g + 1) * group]
        s, z = _group_params(wg, bits, mse_clip)
        scale[g] = s
        zero[g] = z
        codes[g * group : (g + 1) * group] = np.clip(
            np.round(wg / s) + z, 0, qmax
        ).astype(np.int32)
    return QuantizedLinear(codes, scale, zero, group, bits)


def gptq_quantize(
    w: np.ndarray,
    hessian: np.ndarray,
    bits: int,
    group: int,
    mse_clip: bool = True,
    damp_frac: float = DAMP_FRAC,
) -> QuantizedLinear:
    """GPTQ: quantize input channels in order with error feedback.

    ``hessian`` is ``Xᵀ X`` (``[C, C]``) over calibration inputs. Per
    channel ``c``: quantize row ``W[c]`` against its group's scale/zero,
    then push the weighted residual into rows ``c+1..C`` via the Cholesky
    inverse — the standard OBQ/GPTQ update.
    """
    w = np.asarray(w, np.float64).copy()
    c, h = w.shape
    assert c % group == 0
    qmax = (1 << bits) - 1

    hess = np.asarray(hessian, np.float64).copy()
    dead = np.diag(hess) == 0
    hess[dead, dead] = 1.0
    w[dead, :] = 0.0
    damp = damp_frac * float(np.mean(np.diag(hess)))
    hess[np.diag_indices(c)] += damp
    # GPTQ uses U = cholesky(Hinv, upper=True), i.e. Hinv = Uᵀ U with U
    # upper-triangular — equivalently the transpose of the lower factor.
    hinv = np.linalg.inv(hess)
    hinv_u = np.linalg.cholesky(hinv).T
    assert np.allclose(np.tril(hinv_u, -1), 0.0), "upper factor expected"

    n = c // group
    codes = np.empty((c, h), np.int32)
    scale = np.empty((n, h), np.float64)
    zero = np.empty((n, h), np.float64)

    for g in range(n):
        lo_c, hi_c = g * group, (g + 1) * group
        # Group params from the *current* (error-compensated) weights.
        s, z = _group_params(w[lo_c:hi_c], bits, mse_clip)
        scale[g] = s
        zero[g] = z
        for cc in range(lo_c, hi_c):
            wrow = w[cc]
            q = np.clip(np.round(wrow / s) + z, 0, qmax)
            codes[cc] = q.astype(np.int32)
            deq = (q - z) * s
            d = hinv_u[cc, cc]
            err = (wrow - deq) / d
            # Propagate into all remaining channels.
            if cc + 1 < c:
                w[cc + 1 :] -= np.outer(hinv_u[cc, cc + 1 :], err)
            w[cc] = deq
    return QuantizedLinear(codes, scale, zero, group, bits)


def pack2(codes: np.ndarray) -> np.ndarray:
    """2-bit pack, LSB-first along input channels (= kernels/ref.pack2)."""
    c, h = codes.shape
    assert c % 4 == 0
    u = codes.astype(np.uint8).reshape(c // 4, 4, h)
    return u[:, 0] | (u[:, 1] << 2) | (u[:, 2] << 4) | (u[:, 3] << 6)


def quant_error(w: np.ndarray, q: QuantizedLinear, hessian: np.ndarray | None = None) -> float:
    """Proxy loss: plain MSE, or Hessian-weighted ``tr(ΔWᵀ H ΔW)`` if given."""
    dw = q.dequant() - w
    if hessian is None:
        return float((dw**2).mean())
    return float(np.einsum("ch,cd,dh->", dw, hessian, dw) / dw.size)
