"""AOT build driver: corpus → train → quantize sweep → HLO text + manifest.

``make artifacts`` runs ``python -m compile.aot --out-dir ../artifacts``
exactly once; every product is cached (re-runs are incremental no-ops
unless ``--force``). The Rust binary consumes only the output directory.

Interchange format is **HLO text** (not serialized HloModuleProto): the
image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-instruction-id
protos, while the text parser reassigns ids (see /opt/xla-example).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .corpus import SEED_CORPUS, generate_corpus
from .model import (
    ModelCfg,
    fp_param_spec,
    make_fp_forward,
    make_quant_forward,
    quant_param_spec,
)
from .quantize import (
    A_BITS,
    all_variants,
    calib_tokens,
    capture_fp_sites,
    quantize_variant,
    sanity_ppl,
    shared_rotations,
    variant_name,
    write_blob,
)
from .train import train

BATCH = 4
SEQ = 128
CORPUS_BYTES = 1 << 20
TRAIN_FRAC = 0.9


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_graph(fn, spec, out_path: str) -> None:
    """Lower ``fn(tokens, *params)`` at the fixed eval shape → HLO text."""
    dt = {"f32": jnp.float32, "u8": jnp.uint8}
    tokens_spec = jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32)
    param_specs = [jax.ShapeDtypeStruct(shape, dt[d]) for _, shape, d in spec]
    lowered = jax.jit(fn).lower(tokens_spec, *param_specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {out_path} ({len(text)/1e6:.1f} MB)")


def write_fp_blob(params, cfg: ModelCfg, path: str) -> None:
    with open(path, "wb") as f:
        for name, shape, _dt in fp_param_spec(cfg):
            if name.startswith("layers."):
                _, idx, field = name.split(".")
                t = params["layers"][int(idx)][field]
            else:
                t = params[name]
            f.write(np.ascontiguousarray(np.asarray(t, np.float32).reshape(shape)).tobytes())


def read_fp_blob(path: str, cfg: ModelCfg):
    params: dict = {"layers": [{} for _ in range(cfg.n_layers)]}
    with open(path, "rb") as f:
        for name, shape, _dt in fp_param_spec(cfg):
            n = int(np.prod(shape))
            arr = np.frombuffer(f.read(n * 4), np.float32).reshape(shape)
            t = jnp.asarray(arr)
            if name.startswith("layers."):
                _, idx, field = name.split(".")
                params["layers"][int(idx)][field] = t
            else:
                params[name] = t
    return params


def spec_json(spec):
    return [[name, list(shape), dt] for name, shape, dt in spec]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=600, help="training steps")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variants", default="", help="comma list to restrict (debug)")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    os.makedirs(f"{out}/variants", exist_ok=True)
    cfg = ModelCfg()
    t_start = time.time()

    # 1. Corpus ------------------------------------------------------------
    corpus_path = f"{out}/corpus.bin"
    if args.force or not os.path.exists(corpus_path):
        corpus = generate_corpus(CORPUS_BYTES)
        with open(corpus_path, "wb") as f:
            f.write(corpus)
        print(f"[aot] corpus {len(corpus)} bytes")
    else:
        corpus = open(corpus_path, "rb").read()
    n_train = int(len(corpus) * TRAIN_FRAC)

    # 2. Train (cached) ----------------------------------------------------
    fp_path = f"{out}/model_fp.bin"
    train_log_path = f"{out}/train_log.json"
    if args.force or not os.path.exists(fp_path):
        params, log = train(cfg, corpus[:n_train], steps=args.steps)
        write_fp_blob(params, cfg, fp_path)
        with open(train_log_path, "w") as f:
            json.dump({"steps": args.steps, "log": log}, f, indent=1)
    else:
        params = read_fp_blob(fp_path, cfg)
        print("[aot] loaded cached fp checkpoint")

    # 3. HLO graphs ----------------------------------------------------------
    graphs: dict[str, dict] = {}
    fp_fn, fp_spec = make_fp_forward(cfg)
    fp_hlo = "llama_mini_fp.hlo.txt"
    if args.force or not os.path.exists(f"{out}/{fp_hlo}"):
        export_graph(fp_fn, fp_spec, f"{out}/{fp_hlo}")
    graphs["fp"] = {"hlo": fp_hlo, "params": spec_json(fp_spec)}
    for bits, a_bits in A_BITS.items():
        for r4k in ("GH", "LH"):
            gname = f"{bits}_r4{r4k.lower()}"
            hlo = f"llama_mini_{gname}.hlo.txt"
            qfn, qspec = make_quant_forward(cfg, a_bits, r4k)
            if args.force or not os.path.exists(f"{out}/{hlo}"):
                export_graph(qfn, qspec, f"{out}/{hlo}")
            graphs[gname] = {"hlo": hlo, "params": spec_json(qspec)}

    # 4. Variant sweep -------------------------------------------------------
    shared = shared_rotations(cfg)
    calib = calib_tokens(corpus, n_train)
    fp_sites = capture_fp_sites(params, cfg, jnp.asarray(calib))
    only = set(filter(None, args.variants.split(",")))
    variants_meta = []
    for vs in all_variants():
        name = variant_name(vs["method"], vs["bits"], vs["r1"], vs["r4"])
        if only and name not in only:
            continue
        vdir = f"{out}/variants/{name}"
        os.makedirs(vdir, exist_ok=True)
        meta_path = f"{vdir}/meta.json"
        if not args.force and os.path.exists(meta_path):
            variants_meta.append(json.load(open(meta_path)))
            print(f"[aot] cached {name}")
            continue
        t0 = time.time()
        qparams, meta = quantize_variant(params, cfg, vs, shared, calib, fp_sites)
        write_blob(qparams, cfg, vs["r4"], f"{vdir}/weights.bin")
        meta["name"] = name
        meta["graph"] = f"{vs['bits']}_r4{vs['r4'].lower()}"
        meta["weights"] = f"variants/{name}/weights.bin"
        meta["sanity_ppl"] = sanity_ppl(
            qparams, cfg, corpus, A_BITS[vs["bits"]], vs["r4"], n_train
        )
        meta["quantize_s"] = round(time.time() - t0, 1)
        with open(meta_path, "w") as f:
            json.dump(meta, f, indent=1)
        variants_meta.append(meta)
        print(
            f"[aot] {name}: sanity PPL {meta['sanity_ppl']:.2f} "
            f"({meta['quantize_s']}s)"
        )

    # 5. Manifest ------------------------------------------------------------
    manifest = {
        "cfg": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ffn": cfg.d_ffn,
            "group": cfg.group,
            "rope_base": cfg.rope_base,
            "norm_eps": cfg.norm_eps,
        },
        "batch": BATCH,
        "seq": SEQ,
        "corpus": {
            "path": "corpus.bin",
            "bytes": len(corpus),
            "seed": SEED_CORPUS,
            "train_end": n_train,
            "test_start": n_train,
        },
        "fp_weights": "model_fp.bin",
        "graphs": graphs,
        "variants": variants_meta,
    }
    with open(f"{out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest with {len(variants_meta)} variants "
          f"({time.time()-t_start:.0f}s total)")


if __name__ == "__main__":
    main()
