"""Rotation-matrix construction for GSR (paper §2.1, §3.1).

Implements every rotation family compared in the paper:

* ``hadamard(n)``       — Sylvester-construction Hadamard, natural ordering.
* ``walsh(n)``          — the same rows re-ordered to ascending *sequency*
                          (number of sign flips per row), i.e. the Walsh or
                          "sequency-ordered" Hadamard matrix.
* ``rht(n, key)``       — Randomized Hadamard Transform: ``H @ diag(s)``
                          with iid Rademacher signs (QuIP# / QuaRot).
* ``block_diag(B, n)``  — local rotation ``I_{n/G} ⊗ B`` (paper Eq. 3).
* ``build_r1(kind, n, G, key)`` — the paper's four R1 variants:
                          GH, GW, LH, GSR.

All matrices are orthonormal (scaled by ``1/sqrt(block)``), fp64 numpy —
these are *build-time* objects that get fused into weights or exported as
HLO parameters; nothing here runs at request time.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "hadamard",
    "walsh",
    "sequency",
    "sequency_of_natural_row",
    "walsh_permutation",
    "rht",
    "block_diag",
    "build_r1",
    "build_r2",
    "build_r4",
    "R1_KINDS",
]

R1_KINDS = ("GH", "GW", "LH", "GSR")


def _check_pow2(n: int) -> None:
    if n <= 0 or (n & (n - 1)) != 0:
        raise ValueError(f"size must be a positive power of two, got {n}")


def hadamard(n: int, *, normalized: bool = True) -> np.ndarray:
    """Sylvester Hadamard matrix of size ``n`` (power of two).

    Natural (Hadamard) ordering: ``H_{2^k} = H_2 ⊗ H_{2^{k-1}}`` (paper
    Eq. 1). With ``normalized=True`` the matrix is orthonormal.
    """
    _check_pow2(n)
    h = np.ones((1, 1), dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    if normalized:
        h = h / np.sqrt(n)
    return h


def sequency(row: np.ndarray) -> int:
    """Number of sign flips along a ±1 row — the row's *sequency*."""
    signs = np.sign(row)
    return int(np.count_nonzero(signs[1:] != signs[:-1]))


def sequency_of_natural_row(i: int, n: int) -> int:
    """Sequency (sign-flip count) of row ``i`` of the size-``n``
    natural-ordered Sylvester Hadamard matrix.

    Closed form: bit-reverse ``i`` over log₂(n) bits, then Gray-to-binary
    decode (prefix XOR) — the classical bit-reversal + Gray-code
    relation (Tam & Goulet 1972). For n=8 this yields the paper §2.1
    example: rows have sequencies 0, 7, 3, 4, 1, 6, 2, 5.

    (The paper's Eq. 2 ``bit_count(i ⊕ (i >> 1))`` is the *binary-to-Gray
    popcount*, which does not reproduce the example; we implement the
    construction that does, and verify it against directly-counted sign
    flips in tests.)
    """
    _check_pow2(n)
    bits = n.bit_length() - 1
    rev = int(bin(i)[2:].zfill(bits)[::-1], 2) if bits else 0
    # Gray → binary: prefix XOR of all more-significant bits.
    b = rev
    shift = 1
    while (rev >> shift) != 0:
        b ^= rev >> shift
        shift += 1
    return b


def walsh_permutation(n: int) -> np.ndarray:
    """Permutation ``p`` with ``walsh(n) == hadamard(n)[p]``.

    Sorts natural rows by closed-form sequency; the key is a bijection
    on 0..n-1, so the permutation is exactly the textbook bit-reversal +
    Gray-code ordering.
    """
    _check_pow2(n)
    seq = np.array([sequency_of_natural_row(i, n) for i in range(n)])
    return np.argsort(seq, kind="stable")


def walsh(n: int, *, normalized: bool = True) -> np.ndarray:
    """Walsh (sequency-ordered Hadamard) matrix of size ``n``.

    Row ``i`` has exactly ``i`` sign flips — ascending sequency. This is
    the paper's drop-in replacement for the Hadamard matrix: same row set,
    different arrangement, which under group quantization reduces the
    intra-group sequency variance of the front rotation (paper §3.2).
    """
    h = hadamard(n, normalized=normalized)
    return h[walsh_permutation(n)]


def rht(n: int, rng: np.random.Generator, *, normalized: bool = True) -> np.ndarray:
    """Randomized Hadamard Transform ``H @ diag(s)``, ``s ∈ {±1}^n``.

    QuaRot/QuIP# incoherence processing. Sign flips on *columns* keep the
    row-sequency arrangement intact (paper §3.2 "Comparing RHT and
    Walsh"), which is why the Walsh re-ordering is orthogonal to (and
    stacks with) randomization.
    """
    h = hadamard(n, normalized=normalized)
    s = rng.integers(0, 2, size=n) * 2 - 1
    return h * s[None, :].astype(np.float64)


def block_diag(block: np.ndarray, n: int) -> np.ndarray:
    """Local rotation ``I_{n/G} ⊗ block`` (paper Eq. 3).

    ``block`` is a ``G×G`` orthonormal matrix; ``G`` must divide ``n``.
    """
    g = block.shape[0]
    if block.shape != (g, g):
        raise ValueError("block must be square")
    if n % g != 0:
        raise ValueError(f"group size {g} must divide dimension {n}")
    out = np.zeros((n, n), dtype=block.dtype)
    for b in range(n // g):
        out[b * g : (b + 1) * g, b * g : (b + 1) * g] = block
    return out


def build_r1(kind: str, n: int, group: int, rng: np.random.Generator) -> np.ndarray:
    """Build the paper's four R1 variants (Table 1 ``R_1`` column).

    * ``GH``  — global randomized Hadamard (QuaRot default).
    * ``GW``  — global Walsh (sequency-ordered, *not* randomized; paper
      §4 "when constructing Walsh matrices, the original Hadamard matrix
      is used").
    * ``LH``  — local (block-diagonal) randomized Hadamard, block = group
      size.
    * ``GSR`` — Grouped Sequency-arranged Rotation: block-diagonal Walsh,
      block = group size (the paper's contribution).
    """
    if kind == "GH":
        return rht(n, rng)
    if kind == "GW":
        return walsh(n)
    if kind == "LH":
        return block_diag(rht(group, rng), n)
    if kind == "GSR":
        return block_diag(walsh(group), n)
    raise ValueError(f"unknown R1 kind {kind!r}; expected one of {R1_KINDS}")


def build_r2(head_dim: int, rng: np.random.Generator) -> np.ndarray:
    """Per-head value rotation (fused offline into W_v / W_o)."""
    return rht(head_dim, rng)


def build_r4(kind: str, n: int, group: int, rng: np.random.Generator) -> np.ndarray:
    """Online down-projection input rotation (paper Table 2 ablation).

    ``GH`` (global Hadamard, QuaRot default) or ``LH`` (local Hadamard,
    the ablation that helps under W2A4).
    """
    if kind == "GH":
        return rht(n, rng)
    if kind == "LH":
        return block_diag(rht(group, rng), n)
    raise ValueError(f"unknown R4 kind {kind!r}; expected GH or LH")
