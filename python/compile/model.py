"""L2 — llama_mini: the JAX model whose quantized forward is AOT-exported.

A Llama-style pre-norm decoder (RMSNorm, RoPE, MHA, SwiGLU) at laptop
scale (DESIGN.md §2: d=128, 4 layers, 2 heads × 64, ffn=256, byte vocab).
Three forward paths share one block structure:

* :func:`forward_fp`      — fp32 training/reference model (with RMSNorm γ).
* :func:`forward_rotated` — the QuaRot-style rotated model. All hidden
  states live in the R1-rotated basis; γ and R1/R2 are *fused into the
  weights offline* (:func:`fuse_rotations`), R3 is applied online after
  RoPE, R4 online before the down projection via the fast (grouped)
  Hadamard Pallas kernel. Weights are either dense fp32 (for the exact
  fp-invariance check, Fig. 1) or 2-bit packed (the deployed W2 path via
  the fused dequant-matmul kernel).

The W2 forward is what ``aot.py`` lowers to HLO text; every weight tensor
is a *parameter* of the lowered computation so one HLO serves all 24
quantized variants (the Rust runtime feeds each variant's blobs).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.qmatmul import dequant_matmul_pallas
from .kernels.quant import rtn_fake_quant_sym_pallas
from .kernels.walsh import grouped_fwht_pallas, rht_pallas


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """llama_mini architecture + quantization geometry."""

    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ffn: int = 512
    group: int = 64  # quantization group size G (weights & activations)
    rope_base: float = 10_000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    LINEARS = ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown")

    def linear_shape(self, name: str) -> tuple[int, int]:
        d, f = self.d_model, self.d_ffn
        return {
            "wq": (d, d),
            "wk": (d, d),
            "wv": (d, d),
            "wo": (d, d),
            "wgate": (d, f),
            "wup": (d, f),
            "wdown": (f, d),
        }[name]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def outlier_gamma(dim: int, rng: np.random.Generator, sigma: float = 0.6) -> jnp.ndarray:
    """Heavy-tailed RMSNorm scale vector (massive-channel substitution).

    Real LLMs develop strongly anisotropic per-channel scales (massive
    activations / outlier γ) — the regime all rotation-based PTQ methods
    target. A from-scratch 3M-param model trained for minutes stays
    near-isotropic, and rotations of isotropic weights are
    distribution-invariant (no rotation can help or hurt). We therefore
    bake a *fixed, non-learnable* log-normal γ with ~dim/32 boosted
    channels into the architecture; training adapts around it, producing
    fused weights `diag(γ)W` with realistic outlier rows. Documented in
    DESIGN.md §2; identical for every quantized variant, so all Table-1
    comparisons stay apples-to-apples.
    """
    g = np.exp(rng.standard_normal(dim) * sigma)
    n_out = max(dim // 32, 1)
    idx = rng.choice(dim, n_out, replace=False)
    g[idx] *= rng.uniform(4.0, 12.0, n_out)
    return jnp.asarray(g, jnp.float32)


def init_params(cfg: ModelCfg, seed: int = 0) -> dict[str, Any]:
    """fp32 training parameters (scaled-normal init, fixed outlier γ)."""
    rng = np.random.default_rng(seed)

    def dense(shape, scale):
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    d = cfg.d_model
    layers = []
    for _ in range(cfg.n_layers):
        layer = {"ln1": outlier_gamma(d, rng), "ln2": outlier_gamma(d, rng)}
        for name in cfg.LINEARS:
            shp = cfg.linear_shape(name)
            layer[name] = dense(shp, 1.0 / np.sqrt(shp[0]))
        layers.append(layer)
    return {
        "embed": dense((cfg.vocab, d), 1.0),
        "layers": layers,
        "ln_f": outlier_gamma(d, rng),
        "lm_head": dense((d, cfg.vocab), 1.0 / np.sqrt(d)),
    }


def num_params(params: dict[str, Any]) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, eps: float) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope_tables(seq: int, head_dim: int, base: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    half = head_dim // 2
    inv = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(seq, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(t), jnp.sin(t)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, H, dh] — rotate feature pairs (x0..half | half..dh)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def attention(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q,k,v: [B, T, H, dh] → [B, T, H, dh]; fp32 softmax, causal mask."""
    dh = q.shape[-1]
    scores = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        t = q.shape[1]
        mask = jnp.tril(jnp.ones((t, t), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", probs, v)


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, t, h, dh = x.shape
    return x.reshape(b, t, h * dh)


# ---------------------------------------------------------------------------
# fp32 reference / training forward
# ---------------------------------------------------------------------------


def forward_fp(params: dict[str, Any], tokens: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    """Standard fp32 forward. tokens: int32 [B, T] → logits [B, T, V]."""
    x = params["embed"][tokens]
    cos, sin = rope_tables(tokens.shape[1], cfg.head_dim, cfg.rope_base)
    for layer in params["layers"]:
        h = rmsnorm(x, cfg.norm_eps) * layer["ln1"]
        q = _split_heads(h @ layer["wq"], cfg.n_heads)
        k = _split_heads(h @ layer["wk"], cfg.n_heads)
        v = _split_heads(h @ layer["wv"], cfg.n_heads)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        o = _merge_heads(attention(q, k, v)) @ layer["wo"]
        x = x + o
        h = rmsnorm(x, cfg.norm_eps) * layer["ln2"]
        z = jax.nn.silu(h @ layer["wgate"]) * (h @ layer["wup"])
        x = x + z @ layer["wdown"]
    x = rmsnorm(x, cfg.norm_eps) * params["ln_f"]
    return x @ params["lm_head"]


def loss_fn(params: dict[str, Any], tokens: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    """Next-byte cross-entropy (mean over positions)."""
    logits = forward_fp(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Rotation fusion (offline, QuaRot/SpinQuant R1–R4 wiring — Fig. 1)
# ---------------------------------------------------------------------------


def fuse_rotations(
    params: dict[str, Any],
    cfg: ModelCfg,
    r1: np.ndarray,
    r2: np.ndarray | None = None,
) -> dict[str, Any]:
    """Fuse RMSNorm γ and the offline rotations R1/R2 into the weights.

    Returns a *rotated-basis* parameter dict (numpy fp64 for exactness):
    ``embed' = E R1``, ``W_in' = R1ᵀ diag(γ) W_in``, ``W_o' = B₂ᵀ W_o R1``,
    ``W_v' = R1ᵀ diag(γ) W_v B₂``, ``W_down' = W_down R1`` (R4 fusion is
    applied separately per R4 kind), ``lm_head' = R1ᵀ diag(γ_f) W_lm``,
    where ``B₂ = I_heads ⊗ R2``.

    The fused model is *exactly* equivalent in fp arithmetic (orthogonal
    invariance) — asserted by tests/test_rotation_invariance.py and the
    Fig.-1 cargo test.
    """
    d = cfg.d_model
    r1 = np.asarray(r1, np.float64)
    assert r1.shape == (d, d)
    if r2 is None:
        b2 = np.eye(d)
    else:
        r2 = np.asarray(r2, np.float64)
        assert r2.shape == (cfg.head_dim, cfg.head_dim)
        b2 = np.kron(np.eye(cfg.n_heads), r2)

    def npf(x):
        return np.asarray(x, np.float64)

    out: dict[str, Any] = {
        "embed": npf(params["embed"]) @ r1,
        "lm_head": r1.T @ (npf(params["ln_f"])[:, None] * npf(params["lm_head"])),
        "layers": [],
    }
    for layer in params["layers"]:
        g1 = npf(layer["ln1"])[:, None]
        g2 = npf(layer["ln2"])[:, None]
        out["layers"].append(
            {
                "wq": r1.T @ (g1 * npf(layer["wq"])),
                "wk": r1.T @ (g1 * npf(layer["wk"])),
                "wv": r1.T @ (g1 * npf(layer["wv"])) @ b2,
                "wo": b2.T @ npf(layer["wo"]) @ r1,
                "wgate": r1.T @ (g2 * npf(layer["wgate"])),
                "wup": r1.T @ (g2 * npf(layer["wup"])),
                # R4ᵀ is folded in later (depends on the R4 ablation kind).
                "wdown": npf(layer["wdown"]) @ r1,
            }
        )
    return out


def fuse_r4(rot_params: dict[str, Any], r4: np.ndarray) -> dict[str, Any]:
    """Fold the online-rotation transpose into W_down: ``W_down' = R4ᵀ W_down``."""
    out = dict(rot_params)
    out["layers"] = [
        {**layer, "wdown": np.asarray(r4, np.float64).T @ layer["wdown"]}
        for layer in rot_params["layers"]
    ]
    return out


# ---------------------------------------------------------------------------
# Rotated / quantized forward (the deployed graph)
# ---------------------------------------------------------------------------


def _act_quant(x: jnp.ndarray, cfg: ModelCfg, a_bits: int | None, use_pallas: bool):
    """QuaRot A-quant: symmetric RTN, clip 0.9, grouped (paper A.1)."""
    if a_bits is None:
        return x
    if use_pallas:
        return rtn_fake_quant_sym_pallas(x, a_bits, cfg.group, 0.9)
    return ref.rtn_fake_quant_sym(x, a_bits, cfg.group, 0.9)


def _linear(x, qlayer, name, cfg: ModelCfg, use_pallas: bool):
    """Dense (fp check) or packed-W2 (deployed) linear dispatch."""
    if name in qlayer:  # dense fp path
        return x @ qlayer[name].astype(x.dtype)
    packed = qlayer[f"{name}_packed"]
    scale = qlayer[f"{name}_scale"]
    zero = qlayer[f"{name}_zero"]
    if use_pallas:
        return dequant_matmul_pallas(x, packed, scale, zero, cfg.group)
    return ref.dequant_matmul(x, packed, scale, zero, cfg.group)


def _apply_r4_online(z, r4_signs, cfg: ModelCfg, r4_kind: str, use_pallas: bool):
    """Online R4 via the fast (grouped) Hadamard kernel.

    GH: ``z @ (H diag(s))`` — global butterfly then signs.
    LH: ``z @ (I ⊗ H_G diag(s_G))`` — grouped butterfly then tiled signs.
    ``r4_signs`` is a runtime parameter, so one HLO serves any sign draw.
    """
    if r4_kind == "GH":
        if use_pallas:
            return rht_pallas(z, r4_signs)
        return ref.fwht(z) * r4_signs.astype(z.dtype)
    if r4_kind == "LH":
        n = z.shape[-1]
        reps = n // cfg.group
        s_full = jnp.tile(r4_signs.astype(z.dtype), reps)
        if use_pallas:
            return grouped_fwht_pallas(z, cfg.group) * s_full
        return ref.grouped_fwht(z, cfg.group) * s_full
    raise ValueError(f"unknown r4_kind {r4_kind!r}")


def _ascale(h: jnp.ndarray, qlayer, key: str) -> jnp.ndarray:
    """OSTQuant per-channel smoothing scale (ones for other pipelines)."""
    s = qlayer.get(key)
    return h if s is None else h * s.astype(h.dtype)


def forward_rotated(
    qparams: dict[str, Any],
    tokens: jnp.ndarray,
    cfg: ModelCfg,
    *,
    a_bits: int | None = None,
    r4_kind: str = "GH",
    use_pallas: bool = True,
    tap=None,
) -> jnp.ndarray:
    """Rotated (and optionally quantized) forward — the deployed graph.

    ``qparams``: ``embed``/``lm_head`` fp32, ``r3`` [dh,dh], ``r4_signs``
    ([d_ffn] for GH, [G] for LH), per-layer ``ascale_*`` smoothing
    vectors (OSTQuant; ones otherwise), and layer weights either dense or
    ``*_packed/_scale/_zero``. RMSNorm carries no γ (fused).

    ``tap(name, tensor)`` — optional instrumentation hook receiving every
    linear-layer input (used by quantize.py for GPTQ calibration).
    """
    x = qparams["embed"][tokens]
    cos, sin = rope_tables(tokens.shape[1], cfg.head_dim, cfg.rope_base)
    r3 = qparams["r3"]
    for li, qlayer in enumerate(qparams["layers"]):
        h = rmsnorm(x, cfg.norm_eps)
        hq = _act_quant(_ascale(h, qlayer, "ascale_attn"), cfg, a_bits, use_pallas)
        if tap is not None:
            tap(f"layers.{li}.wq", hq)
        q = _split_heads(_linear(hq, qlayer, "wq", cfg, use_pallas), cfg.n_heads)
        k = _split_heads(_linear(hq, qlayer, "wk", cfg, use_pallas), cfg.n_heads)
        v = _split_heads(_linear(hq, qlayer, "wv", cfg, use_pallas), cfg.n_heads)
        # R3 after RoPE (scores invariant; enables KV-cache quantization).
        q = apply_rope(q, cos, sin) @ r3.astype(x.dtype)
        k = apply_rope(k, cos, sin) @ r3.astype(x.dtype)
        o = _merge_heads(attention(q, k, v))
        oq = _act_quant(_ascale(o, qlayer, "ascale_o"), cfg, a_bits, use_pallas)
        if tap is not None:
            tap(f"layers.{li}.wo", oq)
        x = x + _linear(oq, qlayer, "wo", cfg, use_pallas)
        h = rmsnorm(x, cfg.norm_eps)
        hq = _act_quant(_ascale(h, qlayer, "ascale_ffn"), cfg, a_bits, use_pallas)
        if tap is not None:
            tap(f"layers.{li}.wgate", hq)
        z = jax.nn.silu(_linear(hq, qlayer, "wgate", cfg, use_pallas)) * _linear(
            hq, qlayer, "wup", cfg, use_pallas
        )
        z = _apply_r4_online(z, qparams["r4_signs"], cfg, r4_kind, use_pallas)
        zq = _act_quant(_ascale(z, qlayer, "ascale_down"), cfg, a_bits, use_pallas)
        if tap is not None:
            tap(f"layers.{li}.wdown", zq)
        x = x + _linear(zq, qlayer, "wdown", cfg, use_pallas)
    x = rmsnorm(x, cfg.norm_eps)
    return x @ qparams["lm_head"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Lowering entry points (used by aot.py)
# ---------------------------------------------------------------------------


def make_quant_forward(cfg: ModelCfg, a_bits: int | None, r4_kind: str):
    """Return ``f(flat_params..., tokens) -> (logits,)`` for jax.jit.lower.

    The flat parameter order is defined by :func:`quant_param_spec` and
    recorded in the artifact manifest for the Rust runtime.
    """
    spec = quant_param_spec(cfg, r4_kind)

    def fn(tokens, *flat):
        qparams = unflatten_quant_params(cfg, spec, flat)
        return (
            forward_rotated(
                qparams, tokens, cfg, a_bits=a_bits, r4_kind=r4_kind, use_pallas=True
            ),
        )

    return fn, spec


def quant_param_spec(cfg: ModelCfg, r4_kind: str) -> list[tuple[str, tuple[int, ...], str]]:
    """Deterministic flat parameter order: (name, shape, dtype) triples.

    Mirrored by the Rust manifest loader — do not reorder.
    """
    d, v, g = cfg.d_model, cfg.vocab, cfg.group
    spec: list[tuple[str, tuple[int, ...], str]] = [
        ("embed", (v, d), "f32"),
        ("lm_head", (d, v), "f32"),
        ("r3", (cfg.head_dim, cfg.head_dim), "f32"),
        ("r4_signs", (cfg.d_ffn if r4_kind == "GH" else g,), "f32"),
    ]
    for l in range(cfg.n_layers):
        spec.append((f"layers.{l}.ascale_attn", (d,), "f32"))
        spec.append((f"layers.{l}.ascale_o", (d,), "f32"))
        spec.append((f"layers.{l}.ascale_ffn", (d,), "f32"))
        spec.append((f"layers.{l}.ascale_down", (cfg.d_ffn,), "f32"))
        for name in cfg.LINEARS:
            c, h = cfg.linear_shape(name)
            spec.append((f"layers.{l}.{name}_packed", (c // 4, h), "u8"))
            spec.append((f"layers.{l}.{name}_scale", (c // g, h), "f32"))
            spec.append((f"layers.{l}.{name}_zero", (c // g, h), "f32"))
    return spec


def unflatten_quant_params(cfg: ModelCfg, spec, flat) -> dict[str, Any]:
    assert len(flat) == len(spec), f"{len(flat)} != {len(spec)}"
    qparams: dict[str, Any] = {"layers": [{} for _ in range(cfg.n_layers)]}
    for (name, _shape, _dt), tensor in zip(spec, flat):
        if name.startswith("layers."):
            _, idx, field = name.split(".")
            qparams["layers"][int(idx)][field] = tensor
        else:
            qparams[name] = tensor
    return qparams


def make_fp_forward(cfg: ModelCfg):
    """``f(flat_params..., tokens)`` for the W16A16 reference HLO."""
    spec = fp_param_spec(cfg)

    def fn(tokens, *flat):
        params = unflatten_fp_params(cfg, spec, flat)
        return (forward_fp(params, tokens, cfg),)

    return fn, spec


def fp_param_spec(cfg: ModelCfg) -> list[tuple[str, tuple[int, ...], str]]:
    d, v = cfg.d_model, cfg.vocab
    spec = [("embed", (v, d), "f32")]
    for l in range(cfg.n_layers):
        spec.append((f"layers.{l}.ln1", (d,), "f32"))
        spec.append((f"layers.{l}.ln2", (d,), "f32"))
        for name in cfg.LINEARS:
            spec.append((f"layers.{l}.{name}", cfg.linear_shape(name), "f32"))
    spec.append(("ln_f", (d,), "f32"))
    spec.append(("lm_head", (d, v), "f32"))
    return spec


def unflatten_fp_params(cfg: ModelCfg, spec, flat) -> dict[str, Any]:
    assert len(flat) == len(spec)
    params: dict[str, Any] = {"layers": [{} for _ in range(cfg.n_layers)]}
    for (name, _s, _d), tensor in zip(spec, flat):
        if name.startswith("layers."):
            _, idx, field = name.split(".")
            params["layers"][int(idx)][field] = tensor
        else:
            params[name] = tensor
    return params
