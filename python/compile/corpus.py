"""Synthetic Zipf–Markov byte corpus (WikiText-2 stand-in).

The paper evaluates PPL on WikiText-2; this environment has no external
data, so we generate a deterministic corpus with enough structure that a
small trained LM has meaningful perplexity and quantization damage is
measurable (see DESIGN.md §2).

The generator is specified exactly — SplitMix64 PRNG, fixed lexicon and
bigram-preference construction — and is mirrored bit-for-bit by
``rust/src/data/corpus.rs`` so the Rust evaluator and the zero-shot task
suite sample from the same language. Cross-language equality is asserted
by ``rust/tests/integration.rs`` against ``artifacts/corpus.bin``.

Language model structure:
* 256-word lexicon, lengths 2–7, letters a–z. Unigram frequencies are
  Zipfian with exponent 0.7 (``w_i ∝ 1/(i+1)^0.7`` — flatter than
  classic Zipf, keeping per-token entropy high so that quantization
  damage lands on real prediction margins rather than being absorbed by
  a saturated model).
* Bigram grammar: each word has 12 preferred successors; with
  probability 1/2 the next word is one of them (uniform), else a fresh
  Zipf draw.
* Sentences of 4–12 words joined by ``' '`` and terminated by ``'. '``.
"""

from __future__ import annotations

import numpy as np

MASK64 = (1 << 64) - 1

LEXICON_SIZE = 256
N_SUCC = 12
ZIPF_EXP = 0.7
SEED_CORPUS = 0x5EED_C0DE_2025


class SplitMix64:
    """SplitMix64 — tiny, seedable, trivially portable PRNG.

    Mirrored in ``rust/src/rng.rs``; both sides must produce identical
    streams for corpus/task determinism across languages.
    """

    def __init__(self, seed: int) -> None:
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def next_below(self, n: int) -> int:
        """Unbiased-enough modular draw (n << 2^64 here)."""
        return self.next_u64() % n

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53-bit mantissa."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


def build_lexicon(rng: SplitMix64) -> list[bytes]:
    """The fixed 256-word lexicon (drawn first from the corpus stream)."""
    words = []
    for _ in range(LEXICON_SIZE):
        length = 2 + rng.next_below(6)
        words.append(bytes(ord("a") + rng.next_below(26) for _ in range(length)))
    return words


def build_bigram(rng: SplitMix64) -> list[list[int]]:
    """Preferred-successor table: ``N_SUCC`` successors per word."""
    return [
        [rng.next_below(LEXICON_SIZE) for _ in range(N_SUCC)]
        for _ in range(LEXICON_SIZE)
    ]


def zipf_cumulative() -> np.ndarray:
    w = 1.0 / np.arange(1, LEXICON_SIZE + 1, dtype=np.float64) ** ZIPF_EXP
    c = np.cumsum(w)
    return c / c[-1]


def zipf_draw(rng: SplitMix64, cum: np.ndarray) -> int:
    return int(np.searchsorted(cum, rng.next_f64(), side="right"))


class CorpusGenerator:
    """Streaming generator of corpus bytes (see module docstring)."""

    def __init__(self, seed: int = SEED_CORPUS) -> None:
        rng = SplitMix64(seed)
        self.lexicon = build_lexicon(rng)
        self.bigram = build_bigram(rng)
        self.cum = zipf_cumulative()
        self.rng = rng
        self.prev = 0

    def next_word_idx(self) -> int:
        if self.rng.next_below(2) < 1:  # p = 1/2: grammar-preferred successor
            idx = self.bigram[self.prev][self.rng.next_below(N_SUCC)]
        else:  # p = 1/2: fresh Zipf draw
            idx = zipf_draw(self.rng, self.cum)
        self.prev = idx
        return idx

    def sentence(self) -> bytes:
        n = 4 + self.rng.next_below(9)
        words = [self.lexicon[self.next_word_idx()] for _ in range(n)]
        return b" ".join(words) + b". "

    def generate(self, n_bytes: int) -> bytes:
        parts: list[bytes] = []
        total = 0
        while total < n_bytes:
            s = self.sentence()
            parts.append(s)
            total += len(s)
        return b"".join(parts)[:n_bytes]


def generate_corpus(n_bytes: int, seed: int = SEED_CORPUS) -> bytes:
    return CorpusGenerator(seed).generate(n_bytes)


if __name__ == "__main__":
    import sys

    n = int(sys.argv[1]) if len(sys.argv) > 1 else 1 << 20
    out = sys.argv[2] if len(sys.argv) > 2 else "artifacts/corpus.bin"
    data = generate_corpus(n)
    with open(out, "wb") as f:
        f.write(data)
    print(f"wrote {len(data)} bytes to {out}; sample: {data[:80]!r}")
