//! Ablation example (paper Appendix A.2 / Table 2): local rotation on
//! the *online* R4 — helps under activation quantization (W2A4), ~noise
//! under weight-only (W2). Prints the 2×2 grid plus the per-config PPL
//! deltas, and notes the TPU-systems observation from DESIGN.md §5
//! (grouped transforms tile *better* than global ones, unlike on GPU).
//!
//! Run after `make artifacts`:
//!   `cargo run --release --example ablation_r4 [windows]`

use std::path::Path;

use gsr::eval::tables::{table2, EvalOpts};

fn main() {
    let windows = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let opts = EvalOpts { windows, tasks_per_kind: 0 };
    match table2(dir, opts) {
        Ok(table) => {
            println!("{}", table.render());
            println!("Reading: R4 GH→LH should move the W2A4 column much more than W2.");
            println!();
            println!("Systems note (DESIGN.md §5): the paper reports local R4 defeats the");
            println!("CUDA fast-hadamard-transform; with VMEM/BlockSpec tiling the grouped");
            println!("butterfly is *more* parallel — see `cargo bench --bench transform_perf`.");
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
