//! Serving example: run the L3 coordinator with multiple quantized
//! variants resident, fire a mixed request load, and report batching
//! efficiency + latency percentiles — the vLLM-router-shaped deployment
//! story for GSR-quantized models.
//!
//! Run after `make artifacts`:
//!   `cargo run --release --example serve_quantized [n_requests]`

use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use gsr::coordinator::{BatchPolicy, Request, RoutePolicy, Router, Server};
use gsr::runtime::Artifacts;

fn main() {
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(48);
    let dir = Path::new("artifacts");
    let arts = match Artifacts::load(dir) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("run `make artifacts` first ({e})");
            std::process::exit(1);
        }
    };
    // Serve fp next to the best training-free variant (GSR) and the
    // QuaRot baseline — a realistic A/B deployment.
    let mut variants = vec!["fp".to_string()];
    for name in ["quarot_w2a16_gsr_r4gh", "quarot_w2a16_gh_r4gh"] {
        if arts.variant(name).is_some() {
            variants.push(name.to_string());
        }
    }
    println!("starting server with {} resident variants: {variants:?}", variants.len());
    let policy = BatchPolicy { max_batch: arts.batch, max_wait: Duration::from_millis(3) };
    let server = Server::start(dir, &variants, policy).expect("server start");

    // Router assigns unpinned requests round-robin across variants.
    let mut router = Router::new(RoutePolicy::RoundRobin);
    for v in &variants {
        router.register(v);
    }

    let seq = arts.seq;
    let text = arts.test_split().to_vec();
    let t0 = Instant::now();
    let mut replies = Vec::new();
    for i in 0..n_requests {
        let variant = router.route(None).unwrap();
        let start = (i * 53) % (text.len() - seq - 1);
        let tokens: Vec<i32> = text[start..start + seq].iter().map(|&b| b as i32).collect();
        let (tx, rx) = mpsc::channel();
        server
            .submit(Request { variant: variant.clone(), tokens, reply: tx })
            .expect("submit");
        replies.push((variant, rx));
    }
    let mut ok = 0;
    for (variant, rx) in replies {
        let resp = rx.recv().expect("reply");
        match resp.logits {
            Ok(logits) => {
                assert_eq!(logits.len(), seq * arts.cfg.vocab);
                ok += 1;
            }
            Err(e) => eprintln!("{variant}: {e}"),
        }
        router.complete(&variant);
    }
    let wall = t0.elapsed();
    let metrics = server.shutdown();
    println!("completed {ok}/{n_requests} requests in {wall:?}");
    println!("{}", metrics.report(wall));
    println!(
        "router drained cleanly: total in-flight = {}",
        router.total_in_flight()
    );
}
