//! Quickstart: the GSR library in five minutes, no artifacts needed.
//!
//! Builds the paper's four R1 rotations, shows the sequency structure,
//! quantizes a structured weight under each, and prints why GSR wins —
//! the whole §3.2/Fig.2 story through the public API.
//!
//! Run: `cargo run --release --example quickstart`

use gsr::analysis::sequency::structured_weight;
use gsr::analysis::{outlier_spread, sequency_variance_report};
use gsr::quant::{gptq_quantize, rtn_quantize};
use gsr::rng::SplitMix64;
use gsr::transform::{build_r1, hadamard, walsh, Mat, R1Kind};

fn main() {
    // 1. Sequency ordering: Walsh = Hadamard rows sorted by sign flips.
    let h = hadamard(8);
    let w = walsh(8);
    println!("Hadamard (natural order) row sequencies:");
    let seq = |m: &Mat| -> Vec<u32> {
        (0..8).map(|i| gsr::transform::sequency::sequency_of_row(m.row(i))).collect()
    };
    println!("  H8: {:?}  (the paper's 0,7,3,4,1,6,2,5 example)", seq(&h));
    println!("  W8: {:?}  (ascending — the Walsh re-ordering)\n", seq(&w));

    // 2. The four R1 kinds of Table 1.
    let (n, group) = (256, 64);
    println!("R1 kinds on d={n}, group={group}:");
    for kind in R1Kind::ALL {
        let mut rng = SplitMix64::new(42);
        let r = build_r1(kind, n, group, &mut rng);
        println!(
            "  {kind:4}  orthogonality defect {:.1e}  local={}",
            r.orthogonality_defect(),
            kind.is_local()
        );
    }

    // 3. §3.2 — sequency variance drives group-quant error.
    println!("\nIntra-group sequency variance → 2-bit group-RTN error:");
    for r in sequency_variance_report(n, group, 64, 2, 7) {
        println!(
            "  {:4}  variance {:>8.2}   rotated-weight MSE {:.4e}",
            r.kind.to_string(),
            r.mean_group_variance,
            r.rotated_quant_mse
        );
    }

    // 4. Fig. 2 — outlier confinement.
    println!("\nOutlier energy spread (participation ratio / in-group fraction):");
    for s in outlier_spread(n, group, 11) {
        println!(
            "  {:4}  PR {:>6.1}   in-group {:.3}",
            s.kind.to_string(),
            s.participation_ratio,
            s.in_group_energy
        );
    }

    // 5. End to end on one weight: rotate → GPTQ → measure.
    println!("\n2-bit GPTQ error on a structured weight (identity Hessian):");
    let weight = structured_weight(n, 64, 5);
    let base = rtn_quantize(&weight, 2, group, true).mse(&weight);
    println!("  no rotation: {base:.4e}");
    for kind in R1Kind::ALL {
        let mut rng = SplitMix64::new(77);
        let r1 = build_r1(kind, n, group, &mut rng);
        let rotated = r1.transpose().matmul(&weight);
        let q = gptq_quantize(&rotated, &Mat::identity(n), 2, group, true);
        println!("  {kind:4}       : {:.4e}", q.mse(&rotated));
    }
    println!("\nNext: `make artifacts` then `cargo run --release --example reproduce_table1`");
}
