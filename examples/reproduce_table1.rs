//! **End-to-end driver** (DESIGN.md §4): loads the AOT-quantized model
//! variants through the PJRT runtime, evaluates perplexity on the
//! held-out corpus split and zero-shot accuracy on the task suite, and
//! prints the paper's Table 1 — the headline experiment of the
//! reproduction. Also records fp (W16A16) as the ceiling row.
//!
//! Run after `make artifacts`:
//!   `cargo run --release --example reproduce_table1 [windows] [tasks]`

use std::path::Path;

use gsr::eval::tables::{table1, EvalOpts};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let windows = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let tasks = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let opts = EvalOpts { windows, tasks_per_kind: tasks };
    let t0 = std::time::Instant::now();
    match table1(dir, opts, true) {
        Ok(table) => {
            println!("{}", table.render());
            println!("evaluated in {:?} with {opts:?}", t0.elapsed());
            println!();
            println!("Shape expectations (paper, Llama-2-7B):");
            println!("  within each method/bits block, PPL: GH ≥ GW ≥ LH ≥ GSR;");
            println!("  0-shot accuracy reversed; GSR training-free ≈ learned pipelines.");
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
