#!/usr/bin/env python3
"""Diff fresh BENCH_*.json runs against the committed baselines.

Workflow:

    cd rust && cargo bench --bench decode_throughput --bench paged_serve ...
    python3 scripts/bench_diff.py            # per-metric deltas vs baselines
    python3 scripts/bench_diff.py --update   # adopt the fresh runs as baselines

Benches write `BENCH_<name>.json` into the directory they run from
(`rust/` under `cargo bench`); the committed baselines live in
`rust/benches/baselines/`. The differ pairs rows of `results` arrays by
their identity keys (variant/prompt_len/batch/...), walks every numeric
leaf, and prints old -> new with the relative delta. Direction-aware
marking: throughput-like metrics (tok_s, tok_per_s, speedup,
acceptance) regress when they drop; latency-like metrics (_us, _p50,
_p99) regress when they rise; counters are informational.

stdlib only — no third-party imports.
"""

import argparse
import json
import shutil
import sys
from pathlib import Path

# Keys that identify a results row rather than measure it.
IDENTITY_KEYS = ("variant", "prompt_len", "new_tokens", "batch", "seq")

HIGHER_IS_BETTER = ("tok_s", "tok_per_s", "speedup", "acceptance")
LOWER_IS_BETTER = ("_us", "_p50", "_p99", "latency")


def direction(metric):
    """+1 if higher is better, -1 if lower is better, 0 if neutral."""
    for suffix in HIGHER_IS_BETTER:
        if metric.endswith(suffix):
            return 1
    for pat in LOWER_IS_BETTER:
        if pat in metric:
            return -1
    return 0


def row_identity(row):
    return tuple((k, row[k]) for k in IDENTITY_KEYS if k in row)


def numeric_leaves(node, prefix=""):
    """Flatten nested dicts to (dotted-path, number) pairs."""
    out = []
    if isinstance(node, dict):
        for k, v in node.items():
            out.extend(numeric_leaves(v, f"{prefix}{k}." if prefix else f"{k}."))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out.append((prefix.rstrip("."), float(node)))
    return out


def fmt_num(x):
    return f"{x:.3f}".rstrip("0").rstrip(".") if x != int(x) else f"{int(x)}"


def diff_metrics(label, base, fresh, rows):
    """Append per-metric delta rows for one paired scope."""
    base_leaves = dict(numeric_leaves(base))
    fresh_leaves = dict(numeric_leaves(fresh))
    for metric in sorted(set(base_leaves) | set(fresh_leaves)):
        if metric in IDENTITY_KEYS:
            continue
        old = base_leaves.get(metric)
        new = fresh_leaves.get(metric)
        if old is None or new is None:
            rows.append((label, metric, old, new, None, "  (metric added)" if old is None else "  (metric dropped)"))
            continue
        delta = (new - old) / old if old else 0.0
        mark = ""
        d = direction(metric)
        if d and abs(delta) >= 0.02:
            better = (delta > 0) == (d > 0)
            mark = "  improved" if better else "  REGRESSED"
        rows.append((label, metric, old, new, delta, mark))


def pair_results(base_doc, fresh_doc):
    """Yield (scope-label, baseline-node, fresh-node) pairs to diff."""
    base_res = base_doc.get("results")
    fresh_res = fresh_doc.get("results")
    if isinstance(base_res, dict) and isinstance(fresh_res, dict):
        yield "results", base_res, fresh_res
        return
    base_rows = base_res if isinstance(base_res, list) else []
    fresh_by_id = {
        row_identity(r): r for r in (fresh_res if isinstance(fresh_res, list) else [])
    }
    for row in base_rows:
        ident = row_identity(row)
        label = " ".join(f"{k}={v}" for k, v in ident) or "results[]"
        fresh_row = fresh_by_id.pop(ident, None)
        if fresh_row is None:
            print(f"    MISSING in fresh run: {label}")
            continue
        yield label, row, fresh_row
    for ident in fresh_by_id:
        print(f"    new row (no baseline): {' '.join(f'{k}={v}' for k, v in ident)}")


def diff_bench(base_path, fresh_path):
    base_doc = json.loads(base_path.read_text())
    fresh_doc = json.loads(fresh_path.read_text())
    rows = []
    for label, base, fresh in pair_results(base_doc, fresh_doc):
        diff_metrics(label, base, fresh, rows)
    regressions = 0
    for label, metric, old, new, delta, mark in rows:
        old_s = fmt_num(old) if old is not None else "-"
        new_s = fmt_num(new) if new is not None else "-"
        delta_s = f"{delta:+.1%}" if delta is not None else "     "
        print(f"    {label:<34} {metric:<26} {old_s:>12} -> {new_s:>12}  {delta_s:>8}{mark}")
        regressions += mark.strip() == "REGRESSED"
    return regressions


def main():
    repo = Path(__file__).resolve().parent.parent
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", type=Path, default=repo / "rust", help="directory with fresh BENCH_*.json runs")
    ap.add_argument("--baseline", type=Path, default=repo / "rust" / "benches" / "baselines", help="directory with committed baselines")
    ap.add_argument("--update", action="store_true", help="copy fresh runs over the committed baselines")
    ap.add_argument("--fail-on-regression", action="store_true", help="exit 1 if any direction-aware metric regressed >= 2%%")
    args = ap.parse_args()

    baselines = sorted(args.baseline.glob("BENCH_*.json"))
    if not baselines:
        print(f"no baselines under {args.baseline}", file=sys.stderr)
        return 2

    regressions = 0
    compared = 0
    for base_path in baselines:
        fresh_path = args.fresh / base_path.name
        print(f"\n{base_path.name}")
        if not fresh_path.exists():
            print(f"    no fresh run (expected {fresh_path}) — run the matching `cargo bench`")
            continue
        if args.update:
            shutil.copyfile(fresh_path, base_path)
            print(f"    baseline updated from {fresh_path}")
            continue
        regressions += diff_bench(base_path, fresh_path)
        compared += 1

    if not args.update:
        print(f"\ncompared {compared}/{len(baselines)} benches; {regressions} regressed metric(s)")
        if args.fail_on_regression and regressions:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
