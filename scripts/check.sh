#!/usr/bin/env bash
# Tier-1 verification in one command: format, build, test, lint.
#
#   ./scripts/check.sh
#
# Runs from any working directory. rustfmt/clippy are skipped (with a
# notice) on toolchains that don't ship them.
set -euo pipefail
cd "$(dirname "$0")/../rust"

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --all -- --check
else
  echo "rustfmt unavailable on this toolchain — skipped"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Observability smoke: a tiny synthetic generate run must produce a
# loadable Chrome trace and a metrics snapshot containing the serving
# families, and `gsr trace` must accept its own output.
echo "== observability smoke (--trace / --metrics-dump) =="
OBS_TMP="$(mktemp -d)"
trap 'rm -rf "$OBS_TMP"' EXIT
./target/release/gsr generate --synthetic --seq 32 --requests 2 --max-new 4 \
  --threads 2 --trace "$OBS_TMP/trace.json" --metrics-dump "$OBS_TMP/metrics.json" \
  >/dev/null
./target/release/gsr trace "$OBS_TMP/trace.json" | grep -q "0 unclosed"
if command -v python3 >/dev/null 2>&1; then
  python3 - "$OBS_TMP/trace.json" "$OBS_TMP/metrics.json" <<'PY'
import json, sys
trace = json.load(open(sys.argv[1]))
events = trace["traceEvents"] if isinstance(trace, dict) else trace
assert any(e.get("ph") == "b" for e in events), "no request spans in trace"
metrics = json.load(open(sys.argv[2]))
for family in ("gsr_requests_total", "gsr_generations_total", "gsr_request_latency_us"):
    assert family in metrics, f"missing metric family {family}"
print("observability smoke OK")
PY
else
  grep -q "gsr_requests_total" "$OBS_TMP/metrics.json"
  echo "observability smoke OK (python3 unavailable — grep only)"
fi

# Speculative-decoding smoke: the same tiny synthetic run with and
# without --speculate must emit identical completions (greedy and
# seeded sampling), and the speculative run must actually report
# draft/verify rounds. Full token-for-token parity is covered by the
# serve_native e2e tests; this guards the CLI wiring end to end.
echo "== speculative decoding smoke (--speculate) =="
spec_smoke() { # spec_smoke <outfile> <extra args...>
  local out="$1"; shift
  ./target/release/gsr generate --synthetic --seq 32 --requests 3 --max-new 6 \
    --threads 2 "$@" > "$out"
  grep -E '^first completion|^\[' "$out" > "$out.tokens"
}
for mode in "greedy" "sampled"; do
  SAMPLING=()
  [ "$mode" = sampled ] && SAMPLING=(--temperature 0.8 --top-k 32 --seed 11)
  spec_smoke "$OBS_TMP/base_$mode.txt" "${SAMPLING[@]}"
  spec_smoke "$OBS_TMP/spec_$mode.txt" --speculate w2:3 "${SAMPLING[@]}"
  diff "$OBS_TMP/base_$mode.txt.tokens" "$OBS_TMP/spec_$mode.txt.tokens" \
    || { echo "speculative $mode output diverged from non-speculative"; exit 1; }
  grep -q "spec: rounds=" "$OBS_TMP/spec_$mode.txt" \
    || { echo "speculative $mode run reported no draft/verify rounds"; exit 1; }
  grep -q "spec: rounds=" "$OBS_TMP/base_$mode.txt" \
    && { echo "non-speculative $mode run unexpectedly speculated"; exit 1; }
  echo "speculative smoke OK ($mode)"
done

# Search smoke: calibrate a tiny synthetic checkpoint, then run
# `gsr search` over the expanded candidate grid (fixed GSR plus the
# parametric Givens/butterfly families) under both Hessian proxies.
# The calibrate defaults (seed, synthetic config, uniform-GSR basis)
# match the search defaults, so the artifact is directly consumable.
echo "== search smoke (expanded grid, --proxy diag|full) =="
./target/release/gsr calibrate --synthetic --seqs 4 --seq-len 16 --threads 2 \
  --out "$OBS_TMP/hessians.bin" >/dev/null
./target/release/gsr search --synthetic --threads 2 \
  --r1 GSR,GIV,BFLY --blocks 64 --r4 GH \
  --proxy diag --out "$OBS_TMP/plan_diag.json" >/dev/null
./target/release/gsr search --synthetic --threads 2 \
  --r1 GSR,GIV,BFLY --blocks 64 --r4 GH \
  --calib "$OBS_TMP/hessians.bin" \
  --proxy full --out "$OBS_TMP/plan_full.json" >/dev/null
grep -q '"layers"' "$OBS_TMP/plan_diag.json"
grep -q '"layers"' "$OBS_TMP/plan_full.json"
if ./target/release/gsr search --synthetic --threads 2 \
  --r1 GSR,GIV,BFLY --blocks 64 --r4 GH \
  --proxy full --out "$OBS_TMP/plan_bad.json" >/dev/null 2>&1; then
  echo "--proxy full without --calib must fail loudly"; exit 1
fi
echo "search smoke OK"

# Benches are not run in tier-1 (wall-clock noise), but they must keep
# compiling — they double as integration surface for the public API.
echo "== cargo bench --no-run =="
cargo bench --no-run

# Guard committed bench baselines: the differ is a no-op when no fresh
# BENCH_*.json runs exist (tier-1 never runs benches), but when a run
# is present it fails the build on any >=2% direction-aware regression.
if command -v python3 >/dev/null 2>&1; then
  echo "== bench_diff --fail-on-regression =="
  python3 ../scripts/bench_diff.py --fail-on-regression
else
  echo "python3 unavailable — bench baseline diff skipped"
fi

# Scalar-fallback pass: the fast kernels must build and hold their
# conformance bound without the `simd` feature (non-x86_64 targets,
# or any build with --no-default-features).
echo "== cargo build --release --no-default-features (scalar kernels) =="
cargo build --release --no-default-features

echo "== cargo test -q --no-default-features (scalar kernels) =="
cargo test -q --no-default-features

echo "== cargo doc --no-deps (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy unavailable on this toolchain — skipped"
fi

echo "OK"
