#!/usr/bin/env bash
# Tier-1 verification in one command: format, build, test, lint.
#
#   ./scripts/check.sh
#
# Runs from any working directory. rustfmt/clippy are skipped (with a
# notice) on toolchains that don't ship them.
set -euo pipefail
cd "$(dirname "$0")/../rust"

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --all -- --check
else
  echo "rustfmt unavailable on this toolchain — skipped"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Benches are not run in tier-1 (wall-clock noise), but they must keep
# compiling — they double as integration surface for the public API.
echo "== cargo bench --no-run =="
cargo bench --no-run

# Scalar-fallback pass: the fast kernels must build and hold their
# conformance bound without the `simd` feature (non-x86_64 targets,
# or any build with --no-default-features).
echo "== cargo build --release --no-default-features (scalar kernels) =="
cargo build --release --no-default-features

echo "== cargo test -q --no-default-features (scalar kernels) =="
cargo test -q --no-default-features

echo "== cargo doc --no-deps (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy unavailable on this toolchain — skipped"
fi

echo "OK"
