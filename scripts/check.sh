#!/usr/bin/env bash
# Tier-1 verification in one command: build, test, lint.
#
#   ./scripts/check.sh
#
# Runs from any working directory. Clippy is skipped (with a notice) on
# toolchains that don't ship it.
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy unavailable on this toolchain — skipped"
fi

echo "OK"
