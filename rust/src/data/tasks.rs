//! Synthetic zero-shot task suite (lm-eval stand-in, DESIGN.md §2).
//!
//! Eight deterministic multiple-choice task families over the corpus
//! grammar, named for the benchmark each replaces in the paper's tables.
//! Every instance carries a byte context, 2–4 byte-string choices and a
//! gold index; `eval::zeroshot` scores choices by length-normalized
//! log-likelihood given the context — exactly lm-eval's method.
//!
//! The suite measures the same thing the paper's Table 3/4 does: how
//! much quantization degrades the model's grasp of its training
//! distribution, relative to the fp16 ceiling and the 1/k chance floor.

use super::corpus::{CorpusGenerator, LEXICON_SIZE, N_SUCC};
use crate::rng::SplitMix64;

/// Task families, ordered as reported in the Table 3/4 benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// ARC-easy analogue: next-word from grammar successors, easy distractors.
    NextWord,
    /// ARC-challenge analogue: distractors are other words' successors.
    NextWordHard,
    /// HellaSwag analogue: choose the grammatical 3-word continuation.
    Continuation,
    /// LAMBADA analogue: predict the final word of a long context.
    LastWord,
    /// PIQA analogue: complete a repeated template pattern.
    Template,
    /// WinoGrande analogue: binary — correct vs swapped word order.
    WordOrder,
    /// OpenBookQA analogue: next-word after a *rare* (tail-rank) word.
    RareRecall,
    /// BoolQ analogue: binary — grammatical vs impossible continuation.
    Grammatical,
}

impl TaskKind {
    pub const ALL: [TaskKind; 8] = [
        TaskKind::NextWord,
        TaskKind::NextWordHard,
        TaskKind::Continuation,
        TaskKind::LastWord,
        TaskKind::Template,
        TaskKind::WordOrder,
        TaskKind::RareRecall,
        TaskKind::Grammatical,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::NextWord => "next-word(arc-e)",
            TaskKind::NextWordHard => "next-word-hard(arc-c)",
            TaskKind::Continuation => "continuation(hella)",
            TaskKind::LastWord => "last-word(lambada)",
            TaskKind::Template => "template(piqa)",
            TaskKind::WordOrder => "word-order(wino)",
            TaskKind::RareRecall => "rare-recall(obqa)",
            TaskKind::Grammatical => "grammatical(boolq)",
        }
    }
}

/// One multiple-choice instance.
#[derive(Debug, Clone)]
pub struct Task {
    pub kind: TaskKind,
    /// Context bytes (ends with a space; choices append directly).
    pub context: Vec<u8>,
    pub choices: Vec<Vec<u8>>,
    pub answer: usize,
}

/// Deterministic generator for the whole suite.
pub struct TaskSuite {
    lexicon: Vec<Vec<u8>>,
    bigram: Vec<[usize; N_SUCC]>,
    rng: SplitMix64,
}

const TASK_SEED: u64 = 0x7A5C_2026;

impl TaskSuite {
    /// Build from the corpus seed (grammar must match the training data).
    pub fn new(corpus_seed: u64) -> Self {
        let gen = CorpusGenerator::new(corpus_seed);
        Self { lexicon: gen.lexicon, bigram: gen.bigram, rng: SplitMix64::new(TASK_SEED) }
    }

    fn word(&self, idx: usize) -> &[u8] {
        &self.lexicon[idx]
    }

    fn random_word(&mut self) -> usize {
        self.rng.next_below(LEXICON_SIZE as u64) as usize
    }

    /// A word that is NOT a grammar successor of `prev`.
    fn non_successor(&mut self, prev: usize) -> usize {
        loop {
            let cand = self.random_word();
            if !self.bigram[prev].contains(&cand) {
                return cand;
            }
        }
    }

    /// A non-successor of `prev` with the same surface length and a
    /// similar Zipf rank as `gold`. Matching removes the per-byte
    /// lexical-frequency signal, so the scorer can only win through the
    /// *grammar* (the quantity quantization damages). Falls back to a
    /// same-length word, then to any non-successor.
    fn matched_distractor(&mut self, prev: usize, gold: usize) -> usize {
        let gold_len = self.lexicon[gold].len();
        for window in [32usize, 96, LEXICON_SIZE] {
            for _ in 0..64 {
                let lo = gold.saturating_sub(window / 2);
                let cand = (lo + self.rng.next_below(window as u64) as usize) % LEXICON_SIZE;
                if cand != gold
                    && self.lexicon[cand].len() == gold_len
                    && !self.bigram[prev].contains(&cand)
                {
                    return cand;
                }
            }
        }
        self.non_successor(prev)
    }

    /// Grammar walk of `n` words starting after `start`.
    fn walk(&mut self, start: usize, n: usize) -> Vec<usize> {
        let mut prev = start;
        (0..n)
            .map(|_| {
                let next =
                    self.bigram[prev][self.rng.next_below(N_SUCC as u64) as usize];
                prev = next;
                next
            })
            .collect()
    }

    fn join(&self, idxs: &[usize]) -> Vec<u8> {
        let mut out = Vec::new();
        for (i, &w) in idxs.iter().enumerate() {
            if i > 0 {
                out.push(b' ');
            }
            out.extend_from_slice(self.word(w));
        }
        out
    }

    /// Generate `n` instances of one task family.
    pub fn generate(&mut self, kind: TaskKind, n: usize) -> Vec<Task> {
        (0..n).map(|_| self.instance(kind)).collect()
    }

    /// Generate the full suite: `n` instances per family.
    pub fn suite(&mut self, n: usize) -> Vec<(TaskKind, Vec<Task>)> {
        TaskKind::ALL.iter().map(|&k| (k, self.generate(k, n))).collect()
    }

    fn instance(&mut self, kind: TaskKind) -> Task {
        match kind {
            TaskKind::NextWord => {
                let start = self.random_word();
                let ctx_words = self.walk(start, 5);
                let prev = *ctx_words.last().unwrap();
                let gold = self.bigram[prev][self.rng.next_below(N_SUCC as u64) as usize];
                self.choice_task(kind, &ctx_words, gold, 4, |s| s.matched_distractor(prev, gold))
            }
            TaskKind::NextWordHard => {
                let start = self.random_word();
                let ctx_words = self.walk(start, 5);
                let prev = *ctx_words.last().unwrap();
                let gold = self.bigram[prev][self.rng.next_below(N_SUCC as u64) as usize];
                // Distractors: successors of *other* random words — high
                // surface plausibility, wrong bigram.
                self.choice_task(kind, &ctx_words, gold, 4, |s| {
                    for _ in 0..64 {
                        let other = s.random_word();
                        let cand = s.bigram[other][s.rng.next_below(N_SUCC as u64) as usize];
                        if !s.bigram[prev].contains(&cand)
                            && s.lexicon[cand].len() == s.lexicon[gold].len()
                        {
                            return cand;
                        }
                    }
                    s.matched_distractor(prev, gold)
                })
            }
            TaskKind::Continuation => {
                let start = self.random_word();
                let ctx_words = self.walk(start, 6);
                let prev = *ctx_words.last().unwrap();
                let gold_cont = self.walk(prev, 3);
                let context = {
                    let mut c = self.join(&ctx_words);
                    c.push(b' ');
                    c
                };
                let mut choices = vec![self.join(&gold_cont)];
                for _ in 0..3 {
                    // Locally-plausible but contextually wrong: a grammar
                    // walk from an unrelated start word.
                    let other = self.random_word();
                    let bad = self.walk(other, 3);
                    choices.push(self.join(&bad));
                }
                self.shuffle_task(kind, context, choices)
            }
            TaskKind::LastWord => {
                let start = self.random_word();
                let ctx_words = self.walk(start, 10);
                let prev = *ctx_words.last().unwrap();
                let gold = self.bigram[prev][self.rng.next_below(N_SUCC as u64) as usize];
                self.choice_task(kind, &ctx_words, gold, 4, |s| s.matched_distractor(prev, gold))
            }
            TaskKind::Template => {
                // Pattern "a b a b a" → next is "b".
                let a = self.random_word();
                let b = self.bigram[a][self.rng.next_below(N_SUCC as u64) as usize];
                let ctx_words = vec![a, b, a, b, a];
                self.choice_task(kind, &ctx_words, b, 4, |s| s.matched_distractor(a, b))
            }
            TaskKind::WordOrder => {
                let a = self.random_word();
                let b = self.bigram[a][self.rng.next_below(N_SUCC as u64) as usize];
                let fwd = self.join(&[a, b]);
                let rev = self.join(&[b, a]);
                let lead = self.random_word();
                let mut context = self.join(&[lead]);
                context.push(b' ');
                let answer = self.rng.next_below(2) as usize;
                let choices =
                    if answer == 0 { vec![fwd, rev] } else { vec![rev, fwd] };
                Task { kind, context, choices, answer: if answer == 0 { 0 } else { 1 } }
            }
            TaskKind::RareRecall => {
                // Context ends on a tail-rank (rarely sampled) word.
                let rare = 128 + self.rng.next_below((LEXICON_SIZE - 128) as u64) as usize;
                let start = self.random_word();
                let lead = self.walk(start, 3);
                let mut ctx_words = lead;
                ctx_words.push(rare);
                let gold = self.bigram[rare][self.rng.next_below(N_SUCC as u64) as usize];
                self.choice_task(kind, &ctx_words, gold, 4, |s| s.matched_distractor(rare, gold))
            }
            TaskKind::Grammatical => {
                let start = self.random_word();
                let ctx_words = self.walk(start, 4);
                let prev = *ctx_words.last().unwrap();
                let gold = self.bigram[prev][self.rng.next_below(N_SUCC as u64) as usize];
                let bad = self.matched_distractor(prev, gold);
                let mut context = self.join(&ctx_words);
                context.push(b' ');
                let answer = self.rng.next_below(2) as usize;
                let (c0, c1) = if answer == 0 { (gold, bad) } else { (bad, gold) };
                Task {
                    kind,
                    context,
                    choices: vec![self.word(c0).to_vec(), self.word(c1).to_vec()],
                    answer,
                }
            }
        }
    }


    fn choice_task(
        &mut self,
        kind: TaskKind,
        ctx_words: &[usize],
        gold: usize,
        n_choices: usize,
        mut distractor: impl FnMut(&mut Self) -> usize,
    ) -> Task {
        let mut context = self.join(ctx_words);
        context.push(b' ');
        let mut choices = vec![self.word(gold).to_vec()];
        while choices.len() < n_choices {
            let d = distractor(self);
            let w = self.word(d).to_vec();
            if w != choices[0] && !choices.contains(&w) {
                choices.push(w);
            }
        }
        self.shuffle_task(kind, context, choices)
    }

    /// Shuffle choices (gold currently at 0) and record the new gold idx.
    fn shuffle_task(&mut self, kind: TaskKind, context: Vec<u8>, mut choices: Vec<Vec<u8>>) -> Task {
        let n = choices.len();
        let mut answer = 0usize;
        for i in (1..n).rev() {
            let j = self.rng.next_below((i + 1) as u64) as usize;
            choices.swap(i, j);
            if answer == i {
                answer = j;
            } else if answer == j {
                answer = i;
            }
        }
        Task { kind, context, choices, answer }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SEED_CORPUS;

    #[test]
    fn deterministic_suite() {
        let a = TaskSuite::new(SEED_CORPUS).suite(10);
        let b = TaskSuite::new(SEED_CORPUS).suite(10);
        for ((ka, ta), (kb, tb)) in a.iter().zip(&b) {
            assert_eq!(ka, kb);
            for (x, y) in ta.iter().zip(tb) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.choices, y.choices);
                assert_eq!(x.answer, y.answer);
            }
        }
    }

    #[test]
    fn answers_in_range_and_choices_distinct() {
        let suite = TaskSuite::new(SEED_CORPUS).suite(25);
        for (_, tasks) in &suite {
            for t in tasks {
                assert!(t.answer < t.choices.len());
                for i in 0..t.choices.len() {
                    for j in i + 1..t.choices.len() {
                        assert_ne!(t.choices[i], t.choices[j], "{:?}", t.kind);
                    }
                }
            }
        }
    }

    #[test]
    fn gold_is_grammar_consistent_for_next_word() {
        let gen = CorpusGenerator::new(SEED_CORPUS);
        let mut suite = TaskSuite::new(SEED_CORPUS);
        for t in suite.generate(TaskKind::NextWord, 30) {
            // Last context word's successor set must contain the gold.
            let ctx = String::from_utf8(t.context.clone()).unwrap();
            let last_word = ctx.trim_end().rsplit(' ').next().unwrap().as_bytes().to_vec();
            let prev_idx = gen.lexicon.iter().position(|w| *w == last_word);
            // Lexicon may contain duplicate surface forms; when the index
            // is unambiguous, check grammar consistency.
            if let Some(p) = prev_idx {
                let gold_word = &t.choices[t.answer];
                let ok = gen.bigram[p]
                    .iter()
                    .any(|&s| gen.lexicon[s] == *gold_word);
                if gen.lexicon.iter().filter(|w| **w == last_word).count() == 1 {
                    assert!(ok, "gold not a successor of unambiguous prev");
                }
            }
        }
    }

    #[test]
    fn binary_tasks_have_two_choices() {
        let mut suite = TaskSuite::new(SEED_CORPUS);
        for t in suite.generate(TaskKind::WordOrder, 10) {
            assert_eq!(t.choices.len(), 2);
        }
        for t in suite.generate(TaskKind::Grammatical, 10) {
            assert_eq!(t.choices.len(), 2);
        }
    }

    #[test]
    fn eight_families() {
        assert_eq!(TaskKind::ALL.len(), 8);
        let suite = TaskSuite::new(SEED_CORPUS).suite(2);
        assert_eq!(suite.len(), 8);
    }
}
