//! Synthetic Zipf–Markov byte corpus (WikiText-2 stand-in).
//!
//! Exact mirror of `python/compile/corpus.py` — see that module for the
//! language specification. Equality with `artifacts/corpus.bin` is
//! asserted by the integration tests.

use crate::rng::SplitMix64;

pub const SEED_CORPUS: u64 = 0x5EED_C0DE_2025;
pub const LEXICON_SIZE: usize = 256;
pub const N_SUCC: usize = 12;
/// Flattened-Zipf exponent (see the Python module docstring).
pub const ZIPF_EXP: f64 = 0.7;

/// Streaming generator of corpus bytes.
pub struct CorpusGenerator {
    pub lexicon: Vec<Vec<u8>>,
    pub bigram: Vec<[usize; N_SUCC]>,
    cum: Vec<f64>,
    rng: SplitMix64,
    prev: usize,
}

impl CorpusGenerator {
    pub fn new(seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        // Lexicon first, then bigram — same draw order as Python.
        let lexicon: Vec<Vec<u8>> = (0..LEXICON_SIZE)
            .map(|_| {
                let len = 2 + rng.next_below(6) as usize;
                (0..len).map(|_| b'a' + rng.next_below(26) as u8).collect()
            })
            .collect();
        let bigram: Vec<[usize; N_SUCC]> = (0..LEXICON_SIZE)
            .map(|_| {
                let mut succ = [0usize; N_SUCC];
                for s in succ.iter_mut() {
                    *s = rng.next_below(LEXICON_SIZE as u64) as usize;
                }
                succ
            })
            .collect();
        let mut cum = Vec::with_capacity(LEXICON_SIZE);
        let mut acc = 0.0;
        for i in 0..LEXICON_SIZE {
            acc += 1.0 / (i as f64 + 1.0).powf(ZIPF_EXP);
            cum.push(acc);
        }
        let total = acc;
        for c in cum.iter_mut() {
            *c /= total;
        }
        Self { lexicon, bigram, cum, rng, prev: 0 }
    }

    /// Zipf draw via binary search on the cumulative weights
    /// (numpy `searchsorted(side="right")` semantics).
    fn zipf_draw(&mut self) -> usize {
        let u = self.rng.next_f64();
        self.cum.partition_point(|&c| c <= u)
    }

    pub fn next_word_idx(&mut self) -> usize {
        let idx = if self.rng.next_below(2) < 1 {
            self.bigram[self.prev][self.rng.next_below(N_SUCC as u64) as usize]
        } else {
            self.zipf_draw()
        };
        self.prev = idx;
        idx
    }

    /// Next sentence: 4–12 words joined by spaces, terminated `". "`.
    pub fn sentence(&mut self) -> Vec<u8> {
        let n = 4 + self.rng.next_below(9) as usize;
        let mut out = Vec::new();
        for i in 0..n {
            if i > 0 {
                out.push(b' ');
            }
            let idx = self.next_word_idx();
            out.extend_from_slice(&self.lexicon[idx]);
        }
        out.extend_from_slice(b". ");
        out
    }

    pub fn generate(&mut self, n_bytes: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n_bytes + 64);
        while out.len() < n_bytes {
            let s = self.sentence();
            out.extend_from_slice(&s);
        }
        out.truncate(n_bytes);
        out
    }
}

/// Deterministic calibration windows over a byte split: `n` windows of
/// `len` tokens at SplitMix64-drawn offsets, bytes clamped into
/// `[0, vocab)`. Shared by `gsr calibrate` and the calibration tests so
/// both sides draw the exact same sequences for a given seed.
pub fn draw_token_windows(
    bytes: &[u8],
    n: usize,
    len: usize,
    vocab: usize,
    seed: u64,
) -> Vec<Vec<i32>> {
    let mut rng = SplitMix64::new(seed);
    let vocab = vocab.max(1);
    let max_start = bytes.len().saturating_sub(len);
    (0..n)
        .map(|_| {
            let start =
                if max_start == 0 { 0 } else { rng.next_below(max_start as u64 + 1) as usize };
            bytes[start..(start + len).min(bytes.len())]
                .iter()
                .map(|&b| (b as usize % vocab) as i32)
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = CorpusGenerator::new(SEED_CORPUS).generate(4096);
        let b = CorpusGenerator::new(SEED_CORPUS).generate(4096);
        assert_eq!(a, b);
    }

    #[test]
    fn structure_words_and_sentences() {
        let text = CorpusGenerator::new(SEED_CORPUS).generate(1 << 14);
        // Only lowercase letters, spaces and periods.
        assert!(text.iter().all(|&b| b.is_ascii_lowercase() || b == b' ' || b == b'.'));
        // Periods exist (sentences terminate).
        assert!(text.iter().filter(|&&b| b == b'.').count() > 10);
    }

    #[test]
    fn zipf_head_is_frequent() {
        // Word 0 must appear far more often than a mid-rank word, via
        // both the Zipf draws and bigram pointers.
        let mut g = CorpusGenerator::new(SEED_CORPUS);
        let mut counts = vec![0usize; LEXICON_SIZE];
        for _ in 0..20_000 {
            counts[g.next_word_idx()] += 1;
        }
        let head: usize = counts[..8].iter().sum();
        let tail: usize = counts[128..136].iter().sum();
        assert!(head > tail * 3, "head {head} vs tail {tail}");
    }

    #[test]
    fn draw_token_windows_shapes_and_range() {
        let text = CorpusGenerator::new(SEED_CORPUS).generate(4096);
        let a = draw_token_windows(&text, 5, 32, 64, 7);
        let b = draw_token_windows(&text, 5, 32, 64, 7);
        assert_eq!(a, b, "window draw must be seed-deterministic");
        assert_eq!(a.len(), 5);
        for w in &a {
            assert_eq!(w.len(), 32);
            assert!(w.iter().all(|&t| (0..64).contains(&t)));
        }
        // Short split degrades gracefully (one truncated window).
        let short = draw_token_windows(&text[..10], 2, 32, 256, 1);
        assert!(short.iter().all(|w| w.len() == 10));
    }

    #[test]
    fn lexicon_word_lengths_in_range() {
        let g = CorpusGenerator::new(SEED_CORPUS);
        assert!(g.lexicon.iter().all(|w| (2..=7).contains(&w.len())));
    }
}
