//! Data substrate: synthetic corpus, byte tokenizer, zero-shot task suite.
//!
//! The corpus generator is a bit-for-bit mirror of
//! `python/compile/corpus.py` (same SplitMix64 stream) so the Rust
//! evaluator, the task suite, and the Python trainer all see one
//! language. The task suite replaces the paper's lm-eval benchmarks
//! (DESIGN.md §2) with deterministic multiple-choice tasks over the same
//! grammar, scored by length-normalized log-likelihood exactly like
//! lm-eval.

pub mod corpus;
pub mod tasks;
pub mod tokenizer;

pub use corpus::{draw_token_windows, CorpusGenerator, SEED_CORPUS};
pub use tasks::{Task, TaskKind, TaskSuite};
pub use tokenizer::ByteTokenizer;
