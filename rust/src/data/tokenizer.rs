//! Byte-level tokenizer (vocab 256) — the model's input interface.
//!
//! Deliberately trivial (one token per byte) but carried as a real
//! component so the coordinator's request path has the same
//! encode → execute → decode shape as a production server.

/// Byte-level tokenizer: token id = byte value.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &[u8]) -> Vec<i32> {
        text.iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> Vec<u8> {
        tokens.iter().map(|&t| t as u8).collect()
    }

    /// Split a token stream into fixed windows of `seq + 1` tokens
    /// (inputs + next-token targets), stride `seq` — the PPL windowing.
    pub fn windows<'a>(&self, tokens: &'a [i32], seq: usize) -> Vec<&'a [i32]> {
        let mut out = Vec::new();
        let mut start = 0;
        while start + seq + 1 <= tokens.len() {
            out.push(&tokens[start..start + seq + 1]);
            start += seq;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = ByteTokenizer;
        let text = b"hello world. abc";
        assert_eq!(t.decode(&t.encode(text)), text);
    }

    #[test]
    fn windows_cover_with_overlap_one() {
        let t = ByteTokenizer;
        let tokens: Vec<i32> = (0..26).collect();
        let w = t.windows(&tokens, 8);
        assert_eq!(w.len(), 3); // 0..9, 8..17, 16..25
        assert_eq!(w[0], &tokens[0..9]);
        assert_eq!(w[1][0], tokens[8]);
        assert_eq!(w[2][0], tokens[16]);
        for win in w {
            assert_eq!(win.len(), 9);
        }
    }

    #[test]
    fn short_stream_yields_nothing() {
        let t = ByteTokenizer;
        assert!(t.windows(&[1, 2, 3], 8).is_empty());
    }
}
