//! `gsr` — CLI for the GSR reproduction.
//!
//! Subcommands:
//! * `inspect`            — artifact/manifest summary.
//! * `eval`               — PPL (+ zero-shot) of one variant or all.
//! * `table1|table2|table3` — regenerate the paper's tables.
//! * `analyze`            — §3.2 sequency variance + Fig. 2 outlier spread.
//! * `serve`              — start the batching server and run a demo load.
//! * `generate`           — incremental decoding (paged KV, continuous
//!                          batching) on the native backend: greedy by
//!                          default, seeded sampling via `--temperature`;
//!                          reports decode tok/s and tail latency.
//! * `gen-corpus`         — write the synthetic corpus (native generator).
//! * `search`             — training-free per-layer rotation auto-config:
//!                          emit a rotation plan JSON for `quantize-native`.
//! * `calibrate`          — stream corpus activations through the fused
//!                          forward and write a reusable Hessian artifact
//!                          for `--calib` on quantize-native and search.
//! * `trace`              — summarize an exported flight-recorder trace
//!                          (`--trace` output from serve/generate/search).
//!
//! Observability: `--trace FILE` records a flight-recorder trace
//! (Chrome trace-event JSON for Perfetto, or JSONL), `--metrics-addr`
//! serves the Prometheus text exposition while the command runs, and
//! `--metrics-dump FILE` writes a JSON metrics snapshot at exit.

use std::path::Path;
use std::sync::Arc;

use gsr::config::cli::Args;
use gsr::coordinator::{BatchPolicy, Server};
use gsr::data::CorpusGenerator;
use gsr::eval::tables;
use gsr::eval::EvalOpts;
use gsr::obs::{MetricsServer, Obs, TraceEvent};
use gsr::runtime::{Artifacts, Engine};
use gsr::sched::{SamplingParams, SchedConfig, SpecConfig};

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_str() {
        "inspect" => cmd_inspect(&args),
        "eval" => cmd_eval(&args),
        "table1" => cmd_table(&args, 1),
        "table2" => cmd_table(&args, 2),
        "table3" => cmd_table(&args, 3),
        "analyze" => cmd_analyze(&args),
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "gen-corpus" => cmd_gen_corpus(&args),
        "quantize-native" => cmd_quantize_native(&args),
        "search" => cmd_search(&args),
        "calibrate" => cmd_calibrate(&args),
        "trace" => cmd_trace(&args),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?} (try `gsr help`)")),
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn print_help() {
    println!(
        "gsr — Grouped Sequency-arranged Rotation (ACL 2025 SRW reproduction)\n\
         \n\
         USAGE: gsr <subcommand> [--artifacts DIR] [options]\n\
         \n\
         SUBCOMMANDS:\n\
           inspect                     artifact summary\n\
           eval [--variant NAME|--all] PPL / zero-shot evaluation\n\
           table1 | table2 | table3    regenerate the paper's tables\n\
           analyze                     sequency variance + Fig.2 spread\n\
           serve [--requests N]        batching server + demo load\n\
                 [--backend pjrt|native] execution backend (default pjrt)\n\
                 [--plan F [--calib F]]  (native) quantize + serve a searched\n\
                                         heterogeneous rotation plan in-process\n\
                 [--variants A,B] [--batch N] [--threads N] [--bits N]\n\
                 [--kernels reference|fast] (native) quantized-kernel mode\n\
                 [--page-size N] [--kv-blocks N] [--prefill-chunk N]\n\
                                         (native) paged-KV scheduler knobs\n\
                 [--synthetic [--seq N]] artifact-free fp demo (native)\n\
           generate [--requests N]     KV-cached decoding demo load\n\
                 [--prompt-len N] [--max-new N]   (native backend only)\n\
                 [--temperature T] [--top-k K] [--top-p P] [--seed N]\n\
                                         seeded sampling (default: greedy)\n\
                 [--page-size N] [--kv-blocks N] [--prefill-chunk N]\n\
                 [--speculate DRAFT[:k]] self-speculative decoding: resident\n\
                                         variant DRAFT proposes k tokens per\n\
                                         round (default 4), verified by the\n\
                                         target — output is token-for-token\n\
                                         identical to non-speculative decode\n\
                 [--plan F [--calib F]] [--variants A,B] [--batch N]\n\
                 [--threads N] [--bits N] [--kernels reference|fast]\n\
                 [--synthetic [--seq N]] artifact-free fp demo; with\n\
                                         --speculate, the draft variant is\n\
                                         quantized in-process (default W2)\n\
           gen-corpus [--bytes N]      write the synthetic corpus\n\
           quantize-native [--r1 K --r4 K --seed N]\n\
                                       pure-Rust W2 quantization (no Python)\n\
                           [--plan F]  ...from a searched rotation plan JSON\n\
                           [--calib F] ...with real Hessians from `calibrate`\n\
                           [--bits N] [--windows N]\n\
                           [--kernels reference|fast] eval kernel mode\n\
           search [--out F] [--calib F] training-free per-layer rotation search\n\
           calibrate [--out F]         stream corpus activations -> Hessian\n\
                                       artifact for --calib (reusable)\n\
           trace FILE                  summarize an exported trace (--trace\n\
                                       output, Chrome JSON or JSONL)\n\
         \n\
         COMMON OPTIONS:\n\
           --artifacts DIR   artifact directory (default: artifacts)\n\
           --windows N       PPL windows per variant (default 24)\n\
           --tasks N         zero-shot instances per family (default 12)\n\
           --markdown        render tables as markdown\n\
         \n\
         OBSERVABILITY (serve, generate, quantize-native, search):\n\
           --trace FILE      record a flight-recorder trace; `.jsonl` writes\n\
                             JSONL, anything else Chrome trace-event JSON\n\
                             (load in Perfetto / chrome://tracing)\n\
           --metrics-addr A  serve the Prometheus text exposition on A\n\
                             (e.g. 127.0.0.1:9184) while the command runs\n\
           --metrics-dump F  write a JSON metrics snapshot at exit\n\
         \n\
         SEARCH OPTIONS:\n\
           --out FILE        plan output path (default rotation_plan.json)\n\
           --bits N          proxy quantizer weight bits (default 2)\n\
           --blocks LIST     R1 block sizes, e.g. 32,64,128,256\n\
           --r1 LIST         R1 kinds, e.g. GH,GW,LH,GSR,GIV,BFLY\n\
           --r4 LIST         R4 kinds, e.g. GH,LH\n\
           --proxy KIND      diag (default) or full: full-Hessian\n\
                             tr(ΔWᵀ·RᵀHR·ΔW) objective, requires --calib\n\
           --budget N        max candidates per layer (0 = whole grid)\n\
           --threads N       worker threads (default: available cores)\n\
           --seed N          rotation-build seed (default 2025)\n\
           --calib FILE      Hessian artifact: diag(H)-weighted objective\n\
           --synthetic       search a synthetic checkpoint (no artifacts)\n\
         \n\
         CALIBRATE OPTIONS:\n\
           --out FILE        Hessian artifact path (default hessians.bin)\n\
           --plan F          capture in a searched plan's basis (default:\n\
                             uniform basis from --r1/--r4/--seed)\n\
           --seqs N          calibration sequences (default 32)\n\
           --seq-len N       tokens per sequence (default 64)\n\
           --calib-seed N    sequence-draw seed (default 0xCA11B)\n\
           --threads N       capture worker threads\n\
           --synthetic       calibrate the synthetic checkpoint"
    );
}

fn opts_from(args: &Args) -> EvalOpts {
    EvalOpts {
        windows: args.opt_usize("windows", 24),
        tasks_per_kind: args.opt_usize("tasks", 12),
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.opt_or("artifacts", "artifacts").to_string()
}

/// Observability wiring resolved from `--trace`, `--metrics-addr` and
/// `--metrics-dump`: the bundle the server/quantizer records into, the
/// optional Prometheus exposition server (alive until dropped), and
/// the output paths written by [`ObsWiring::finish`] after shutdown.
struct ObsWiring {
    obs: Obs,
    http: Option<MetricsServer>,
    trace_path: Option<String>,
    dump_path: Option<String>,
}

fn obs_from_args(args: &Args) -> Result<ObsWiring, String> {
    let obs = Obs::new();
    let trace_path = args.opt("trace").map(String::from);
    if trace_path.is_some() {
        obs.recorder.enable();
    }
    let http = match args.opt("metrics-addr") {
        Some(addr) => {
            let server = MetricsServer::serve(addr, Arc::clone(&obs.registry))?;
            println!("metrics: Prometheus exposition on http://{}/metrics", server.addr());
            Some(server)
        }
        None => None,
    };
    Ok(ObsWiring {
        obs,
        http,
        trace_path,
        dump_path: args.opt("metrics-dump").map(String::from),
    })
}

impl ObsWiring {
    /// Write the requested outputs — after server shutdown, so the
    /// executor's final events and counts are included — then stop the
    /// exposition server.
    fn finish(self) -> Result<(), String> {
        if let Some(p) = &self.trace_path {
            self.obs.recorder.write(Path::new(p))?;
            let events: usize =
                self.obs.recorder.snapshot().iter().map(|(_, _, r)| r.len()).sum();
            let dropped = self.obs.recorder.dropped_total();
            println!("trace: wrote {events} event(s) to {p} ({dropped} dropped)");
            println!("       inspect with `gsr trace {p}` or load in Perfetto");
        }
        if let Some(p) = &self.dump_path {
            self.obs.registry.write_snapshot(Path::new(p))?;
            println!("metrics: wrote snapshot to {p}");
        }
        drop(self.http);
        Ok(())
    }
}

/// `gsr trace FILE` — summarize an exported flight-recorder trace.
fn cmd_trace(args: &Args) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.opt("file"))
        .ok_or("usage: gsr trace FILE (Chrome trace-event JSON or JSONL)")?;
    print!("{}", gsr::obs::trace::inspect(Path::new(path))?);
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), String> {
    let arts = Artifacts::load(Path::new(&artifacts_dir(args)))?;
    println!("model: d={} layers={} heads={} ffn={} group={} vocab={}",
        arts.cfg.d_model, arts.cfg.n_layers, arts.cfg.n_heads,
        arts.cfg.d_ffn, arts.cfg.group, arts.cfg.vocab);
    println!("graphs: {}", arts.graph_names().join(", "));
    println!("corpus: {} bytes (test split {} bytes)",
        arts.corpus().len(), arts.test_split().len());
    println!("variants ({}):", arts.variants.len());
    for v in &arts.variants {
        println!(
            "  {:34} graph={:12} sanity_ppl={:.2}",
            v.name, v.graph, v.sanity_ppl
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<(), String> {
    let dir = artifacts_dir(args);
    let arts = Artifacts::load(Path::new(&dir))?;
    let opts = opts_from(args);
    let mut engine = Engine::new()?;
    println!("platform: {}", engine.platform());
    let names: Vec<String> = if args.has_flag("all") {
        let mut n = vec!["fp".to_string()];
        n.extend(arts.variants.iter().map(|v| v.name.clone()));
        n
    } else {
        vec![args.opt_or("variant", "fp").to_string()]
    };
    for name in names {
        let ev = tables::eval_variant(&mut engine, &arts, &name, opts)?;
        println!(
            "{name}: ppl={:.3} zero-shot={:.2}",
            ev.ppl, ev.zero_shot_avg
        );
    }
    Ok(())
}

fn cmd_table(args: &Args, which: usize) -> Result<(), String> {
    let dir = artifacts_dir(args);
    let opts = opts_from(args);
    let table = match which {
        1 => tables::table1(Path::new(&dir), opts, args.has_flag("verbose"))?,
        2 => tables::table2(Path::new(&dir), opts)?,
        _ => tables::table3(Path::new(&dir), args.opt_or("method", "quarot"), opts)?,
    };
    if args.has_flag("markdown") {
        println!("{}", table.render_markdown());
    } else {
        println!("{}", table.render());
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<(), String> {
    let n = args.opt_usize("dim", 256);
    let group = args.opt_usize("group", 64);
    let seq_table = tables::sequency_table(n, group);
    let fig2 = tables::fig2_table(n, group);
    if args.has_flag("markdown") {
        println!("{}", seq_table.render_markdown());
        println!("{}", fig2.render_markdown());
    } else {
        println!("{}", seq_table.render());
        println!("{}", fig2.render());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let wiring = obs_from_args(args)?;
    let (server, variants, seq, test) = if args.has_flag("synthetic") {
        if args.opt_or("backend", "native") != "native" {
            return Err("--synthetic serves on the native backend only".to_string());
        }
        let seq = args.opt_usize("seq", 32).max(2);
        let policy = BatchPolicy {
            max_batch: args.opt_usize("batch", 4).max(1),
            ..BatchPolicy::default()
        };
        let (server, corpus) = synthetic_server(args, policy, seq, &wiring.obs)?;
        if corpus.len() < seq + 2 {
            return Err(format!("--seq {seq} exceeds the synthetic corpus"));
        }
        (server, vec!["fp".to_string()], seq, corpus)
    } else {
        let dir = artifacts_dir(args);
        let arts = Artifacts::load(Path::new(&dir))?;
        let backend = args.opt_or("backend", "pjrt").to_string();
        let policy = BatchPolicy {
            max_batch: args.opt_usize("batch", arts.batch.max(1)).max(1),
            ..BatchPolicy::default()
        };
        let (server, variants) = match backend.as_str() {
            "pjrt" => {
                if args.opt("plan").is_some() || args.opt("calib").is_some() {
                    return Err(
                        "--plan/--calib need `--backend native`: the PJRT graphs cannot \
                         serve searched rotation plans"
                            .to_string(),
                    );
                }
                if args.opt("kernels").is_some() {
                    return Err(
                        "--kernels needs `--backend native`: kernel-mode selection only \
                         applies to the native execution path"
                            .to_string(),
                    );
                }
                let variants: Vec<String> = match args.opt("variants") {
                    Some(list) => list.split(',').map(String::from).collect(),
                    None => {
                        let mut v = vec!["fp".to_string()];
                        if let Some(m) = arts.variant("quarot_w2a16_gsr_r4gh") {
                            v.push(m.name.clone());
                        }
                        v
                    }
                };
                let pjrt_dir = Path::new(&dir).to_path_buf();
                let names = variants.clone();
                let server = Server::start_set_obs(
                    move || gsr::exec::PjrtSet::load(&pjrt_dir, &names),
                    policy,
                    SchedConfig::default(),
                    &wiring.obs,
                )?;
                (server, variants)
            }
            "native" => start_native_server(args, &arts, policy, &wiring.obs)?,
            other => return Err(format!("unknown --backend {other:?} (pjrt|native)")),
        };
        println!("serving {} variant(s) on the {backend} backend: {variants:?}", variants.len());
        let seq = arts.seq;
        let test = arts.test_split().to_vec();
        if test.len() < seq + 2 {
            return Err(format!(
                "test split of {} bytes is too small for the serving demo load \
                 (need at least seq + 2 = {})",
                test.len(),
                seq + 2
            ));
        }
        (server, variants, seq, test)
    };
    // Demo load: score random corpus windows round-robin over variants.
    let n_requests = args.opt_usize("requests", if args.has_flag("synthetic") { 16 } else { 32 });
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        let variant = &variants[i % variants.len()];
        let start = (i * 37) % (test.len() - seq - 1);
        let tokens: Vec<i32> = test[start..start + seq].iter().map(|&b| b as i32).collect();
        let logits = server.score(variant, tokens)?;
        if i == 0 {
            println!("first response: {} logits", logits.len());
        }
    }
    let wall = t0.elapsed();
    let metrics = server.shutdown();
    println!("{}", metrics.report(wall));
    wiring.finish()
}

/// Artifact-free serving: the structured synthetic checkpoint `gsr
/// search --synthetic` uses, served on the native backend against a
/// freshly generated corpus — the CI/smoke path for the observability
/// outputs (`--trace`, `--metrics-addr`, `--metrics-dump`) with no
/// PJRT or artifact dependency. With `--speculate DRAFT[:k]` the named
/// draft variant is quantized in-process from the same checkpoint
/// (default W2), so the self-speculative decode path runs with no
/// artifacts either.
fn synthetic_server(
    args: &Args,
    policy: BatchPolicy,
    seq: usize,
    obs: &Obs,
) -> Result<(Server, Vec<u8>), String> {
    use gsr::exec::{ExecPool, NativeBackend, NativeSet};
    use gsr::model::{DenseModel, FpParams, ModelCfg};
    use gsr::quant::{build_plan_rotations, quantize_native_plan_with};

    if args.opt("plan").is_some() || args.opt("variants").is_some() {
        return Err(
            "--synthetic serves the fp synthetic checkpoint only (no --plan/--variants)"
                .to_string(),
        );
    }
    let sched = sched_from_args(args)?;
    let cfg = ModelCfg::default();
    let seed = args.opt_usize("seed", 2025) as u64;
    let fp = FpParams::synthetic(&cfg, seed);
    let pool = Arc::new(ExecPool::new(args.opt_threads()));
    let mut set = NativeSet::new();
    if let Some(spec) = &sched.speculate {
        let plan = plan_from_args(args, &cfg)?;
        let rots = build_plan_rotations(&cfg, &plan)?;
        let bits = args.opt_usize("bits", 2) as u32;
        let (mut qp, sse, _) = quantize_native_plan_with(&fp, &cfg, &rots, bits, None)?;
        qp.kernels = kernel_mode_from_args(args)?;
        println!(
            "quantized W{bits} draft variant {:?} in-process for --speculate \
             (weight SSE {sse:.2})",
            spec.draft
        );
        let model = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None };
        set.insert(
            &spec.draft,
            NativeBackend::with_pool(Arc::new(model), policy.max_batch, seq, Arc::clone(&pool)),
        );
    }
    let model = DenseModel::Fp { cfg: cfg.clone(), params: fp };
    set.insert("fp", NativeBackend::with_pool(Arc::new(model), policy.max_batch, seq, pool));
    let corpus = CorpusGenerator::new(gsr::data::SEED_CORPUS).generate(1 << 14);
    let server = Server::start_native_obs(set, policy, sched, obs)?;
    Ok((server, corpus))
}

/// Build and start the native serving path: fp plus any artifact
/// variants from `--variants`, plus — the bit PJRT cannot do — a
/// searched (possibly heterogeneous) `--plan`, quantized in-process
/// (optionally Hessian-calibrated via `--calib`) and served from the
/// same shared worker pool.
fn start_native_server(
    args: &Args,
    arts: &Artifacts,
    policy: BatchPolicy,
    obs: &Obs,
) -> Result<(Server, Vec<String>), String> {
    use gsr::calib::HessianSet;
    use gsr::exec::{ExecPool, NativeBackend, NativeSet};
    use gsr::model::{DenseModel, FpParams, QuantParams};
    use gsr::quant::{build_plan_rotations, quantize_native_plan_with, RotationPlan};

    let (b, s) = (policy.max_batch, arts.seq);
    let kernels = kernel_mode_from_args(args)?;
    let pool = Arc::new(ExecPool::new(args.opt_threads()));
    let mut set = NativeSet::new();
    let mut variants = vec!["fp".to_string()];
    let fp = FpParams::load(&arts.fp_weights_path(), &arts.cfg)?;
    set.insert(
        "fp",
        NativeBackend::with_pool(
            Arc::new(DenseModel::Fp { cfg: arts.cfg.clone(), params: fp.clone() }),
            b,
            s,
            Arc::clone(&pool),
        ),
    );
    if let Some(list) = args.opt("variants") {
        for name in list.split(',').filter(|n| !n.is_empty() && *n != "fp") {
            let meta = arts
                .variant(name)
                .ok_or_else(|| format!("unknown variant {name}"))?
                .clone();
            let mut qp = QuantParams::load(&arts.weights_path(&meta), &arts.cfg, meta.r4_kind())?;
            qp.kernels = kernels;
            let model = DenseModel::Quant {
                cfg: arts.cfg.clone(),
                params: qp,
                a_bits: meta.a_bits(),
            };
            set.insert(name, NativeBackend::with_pool(Arc::new(model), b, s, Arc::clone(&pool)));
            variants.push(name.to_string());
        }
    }
    if let Some(plan_path) = args.opt("plan") {
        let plan = RotationPlan::load(Path::new(plan_path))?;
        let calib = match args.opt("calib") {
            Some(path) => {
                let hessians = HessianSet::load(Path::new(path))?;
                hessians.check_model(&arts.cfg)?;
                hessians.check_basis(plan.fingerprint())?;
                Some(hessians)
            }
            None => None,
        };
        let bits = args.opt_usize("bits", 2) as u32;
        let rots = build_plan_rotations(&arts.cfg, &plan)?;
        let t0 = std::time::Instant::now();
        let (mut qp, sse, _) =
            quantize_native_plan_with(&fp, &arts.cfg, &rots, bits, calib.as_ref())?;
        qp.kernels = kernels;
        println!(
            "quantized searched plan {} for serving in {:?} ({}; weight SSE {sse:.2})",
            tables::plan_summary(&plan),
            t0.elapsed(),
            tables::calib_label(calib.as_ref()),
        );
        let model = DenseModel::Quant { cfg: arts.cfg.clone(), params: qp, a_bits: None };
        set.insert("searched", NativeBackend::with_pool(Arc::new(model), b, s, pool));
        variants.push("searched".to_string());
    }
    Ok((Server::start_native_obs(set, policy, sched_from_args(args)?, obs)?, variants))
}

/// Paged-KV scheduler knobs for the native serving path: `--page-size`
/// (tokens per KV block), `--kv-blocks` (pool size per variant, 0 =
/// auto-size to the backend's contiguous capacity), `--prefill-chunk`
/// (prompt tokens absorbed per scheduling round), `--speculate
/// DRAFT[:k]` (self-speculative decoding: the named resident variant
/// drafts k tokens per round, verified bit-exactly by the target).
fn sched_from_args(args: &Args) -> Result<SchedConfig, String> {
    let d = SchedConfig::default();
    let speculate = match args.opt("speculate") {
        Some(s) => Some(SpecConfig::parse(s)?),
        None => None,
    };
    Ok(SchedConfig {
        page_size: args.opt_usize("page-size", d.page_size).max(1),
        kv_blocks: args.opt_usize("kv-blocks", d.kv_blocks),
        prefill_chunk: args.opt_usize("prefill-chunk", d.prefill_chunk).max(1),
        speculate,
    })
}

/// Sampling configuration from `--temperature/--top-k/--top-p/--seed`.
/// The default is greedy (temperature 0), which consumes no RNG and
/// ignores the seed.
fn sampling_from_args(args: &Args) -> SamplingParams {
    let g = SamplingParams::greedy();
    SamplingParams {
        temperature: args.opt_f64("temperature", g.temperature),
        top_k: args.opt_usize("top-k", g.top_k),
        top_p: args.opt_f64("top-p", g.top_p),
        seed: args.opt_u64("seed", g.seed),
    }
}

/// `gsr generate` — incremental decoding through the serving
/// coordinator: prompts drawn from the held-out test split are
/// chunk-prefilled into paged KV, then decoded token by token on the
/// native backend — greedy by default, seeded temperature / top-k /
/// top-p sampling via the CLI. All requests are submitted up front so
/// the continuous-batching rounds interleave prefill chunks with
/// decodes; metrics report decode tok/s, tail latency and block-pool
/// pressure.
fn cmd_generate(args: &Args) -> Result<(), String> {
    use gsr::coordinator::GenerateRequest;
    use std::sync::mpsc;

    let backend = args.opt_or("backend", "native");
    if backend != "native" {
        return Err(format!(
            "generate needs --backend native: the {backend} backend does not export \
             an incremental decode path"
        ));
    }
    let wiring = obs_from_args(args)?;
    let (server, variants, seq, test) = if args.has_flag("synthetic") {
        let seq = args.opt_usize("seq", 32).max(2);
        let policy = BatchPolicy {
            max_batch: args.opt_usize("batch", 4).max(1),
            ..BatchPolicy::default()
        };
        let (server, corpus) = synthetic_server(args, policy, seq, &wiring.obs)?;
        (server, vec!["fp".to_string()], seq, corpus)
    } else {
        let dir = artifacts_dir(args);
        let arts = Artifacts::load(Path::new(&dir))?;
        let policy = BatchPolicy {
            max_batch: args.opt_usize("batch", arts.batch.max(1)).max(1),
            ..BatchPolicy::default()
        };
        let (server, variants) = start_native_server(args, &arts, policy, &wiring.obs)?;
        (server, variants, arts.seq, arts.test_split().to_vec())
    };
    let n_requests = args.opt_usize("requests", 8);
    let prompt_len = args.opt_usize("prompt-len", (seq / 2).max(1));
    let default_new = (seq + 1).saturating_sub(prompt_len).clamp(1, 32);
    let max_new = args.opt_usize("max-new", default_new).max(1);
    if prompt_len == 0 {
        return Err("--prompt-len must be >= 1".to_string());
    }
    // Admission happens server-side against the variant's block pool
    // (peak occupancy must fit its total token inventory, not be
    // contiguously free) — rejections come back per request.
    let sampling = sampling_from_args(args);
    let mode = if sampling.is_greedy() {
        "greedy".to_string()
    } else {
        format!("T={} seed={}", sampling.temperature, sampling.seed)
    };
    if test.len() < prompt_len + 2 {
        return Err("test split too small for the requested prompt length".to_string());
    }
    println!(
        "generating {n_requests} completion(s) over {} variant(s) on the native backend \
         (prompt {prompt_len} tokens, up to {max_new} new, {mode})",
        variants.len()
    );
    let t0 = std::time::Instant::now();
    // Submit everything up front so the executor batches decode rounds
    // across concurrently active sequences.
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let variant = variants[i % variants.len()].clone();
        let start = (i * 131) % (test.len() - prompt_len - 1);
        let prompt: Vec<i32> =
            test[start..start + prompt_len].iter().map(|&b| b as i32).collect();
        let (reply, rx) = mpsc::channel();
        server.submit_generate(GenerateRequest {
            variant: variant.clone(),
            prompt,
            max_new,
            stop: None,
            sampling: sampling.clone(),
            stream: None,
            reply,
        })?;
        pending.push((variant, rx));
    }
    for (i, (variant, rx)) in pending.into_iter().enumerate() {
        let out = rx.recv().map_err(|_| "no response".to_string())?.result?;
        if i == 0 {
            println!("first completion ({variant}): {:?}", render_tokens(&out.tokens));
        }
        println!(
            "[{i}] {variant}: {} prompt + {} generated tokens",
            out.prompt_len,
            out.tokens.len()
        );
    }
    let wall = t0.elapsed();
    let metrics = server.shutdown();
    println!("{}", metrics.report(wall));
    wiring.finish()
}

/// Byte-vocab tokens as readable text (non-printable bytes → '·').
fn render_tokens(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| match u8::try_from(t) {
            Ok(b) if (32..127).contains(&b) => b as char,
            _ => '·',
        })
        .collect()
}

/// Resolve `--kernels {reference,fast}` (default `reference`). The
/// reference mode is the bit-exact f64-accumulation path; `fast`
/// switches quantized variants to the packed-domain kernels
/// (`model::kernels`), which relax accumulation order within the
/// tolerance pinned by `tests/kernels.rs`.
fn kernel_mode_from_args(args: &Args) -> Result<gsr::model::KernelMode, String> {
    let raw = args.opt_or("kernels", "reference");
    gsr::model::KernelMode::parse(raw)
        .ok_or_else(|| format!("bad --kernels {raw:?} (reference|fast)"))
}

/// Resolve the rotation plan a `--calib`-capable subcommand works in:
/// an explicit `--plan` file, or the uniform plan the `--r1/--r4/--seed`
/// flags describe. `gsr calibrate` and the `--calib` consumers share
/// this one resolution so their basis fingerprints can only agree or
/// loudly mismatch.
fn plan_from_args(args: &Args, cfg: &gsr::model::ModelCfg) -> Result<gsr::quant::RotationPlan, String> {
    use gsr::model::R4Kind;
    use gsr::quant::{RotationPlan, RotationSpec};
    use gsr::transform::R1Kind;

    if let Some(plan_path) = args.opt("plan") {
        return RotationPlan::load(Path::new(plan_path));
    }
    let r1 = R1Kind::parse(args.opt_or("r1", "GSR")).ok_or("bad --r1 (GH|GW|LH|GSR|GIV|BFLY)")?;
    let r4 = R4Kind::parse(args.opt_or("r4", "GH")).ok_or("bad --r4 (GH|LH)")?;
    let seed = args.opt_usize("seed", 2025) as u64;
    let spec = RotationSpec {
        r1,
        r1_block: cfg.group,
        r4,
        r4_block: if r4 == R4Kind::GH { cfg.d_ffn } else { cfg.group },
        r1_angles: gsr::transform::default_angles(r1, cfg.group),
    }
    .canonical(cfg);
    Ok(RotationPlan::uniform(spec, cfg.n_layers, seed))
}

fn cmd_quantize_native(args: &Args) -> Result<(), String> {
    use gsr::calib::HessianSet;
    use gsr::eval::EvalOpts;
    use gsr::exec::NativeBackend;
    use gsr::model::{DenseModel, FpParams};
    use gsr::quant::{build_plan_rotations, quantize_native_plan_telemetry};

    let wiring = obs_from_args(args)?;
    let arts = Artifacts::load(Path::new(&artifacts_dir(args)))?;
    let fp = FpParams::load(&arts.fp_weights_path(), &arts.cfg)?;
    let bits = args.opt_usize("bits", 2) as u32;
    let calib = match args.opt("calib") {
        Some(path) => Some(HessianSet::load(Path::new(path))?),
        None => None,
    };
    // One plan resolution and ONE rotation-build path (the plan
    // pipeline) regardless of calibration, so `quantize-native` and
    // `quantize-native --calib` with identical flags quantize the
    // identical rotated model and their PPLs are directly comparable.
    let plan = plan_from_args(args, &arts.cfg)?;
    if let Some(set) = &calib {
        set.check_model(&arts.cfg)?;
        set.check_basis(plan.fingerprint())?;
    }
    let rots = build_plan_rotations(&arts.cfg, &plan)?;
    println!(
        "native W{bits} quantization ({}): {} ({} distinct rotation builds)",
        tables::calib_label(calib.as_ref()),
        tables::plan_summary(&plan),
        rots.distinct
    );
    let t0 = std::time::Instant::now();
    let (mut qp, sse, _q, layers) =
        quantize_native_plan_telemetry(&fp, &arts.cfg, &rots, bits, calib.as_ref())?;
    qp.kernels = kernel_mode_from_args(args)?;
    println!("quantized {} linears in {:?}; weight SSE {sse:.2}",
        arts.cfg.n_layers * 7, t0.elapsed());
    // Per-layer rotation telemetry: proxy MSE + chosen spec for every
    // layer, recorded into the flight recorder (and printed with
    // `--verbose`) so quantization quality is inspectable offline.
    if wiring.obs.recorder.is_enabled() {
        let h = wiring.obs.recorder.handle("quantize");
        for t in &layers {
            h.record(TraceEvent::QuantLayer {
                layer: t.layer,
                spec: t.spec.label(),
                mse: t.mse(),
            });
        }
    }
    if args.has_flag("verbose") {
        for t in &layers {
            println!(
                "  layer {:>2}  {:24}  mse {:.4e}  |w|max {:.3}  rms {:.4}",
                t.layer,
                t.spec.label(),
                t.mse(),
                t.max_abs_weight,
                t.rms_weight
            );
        }
    }
    let model = DenseModel::Quant { cfg: arts.cfg.clone(), params: qp, a_bits: None };
    let native = NativeBackend::new(
        std::sync::Arc::new(model),
        arts.batch.max(1),
        arts.seq,
        args.opt_threads(),
    );
    let opts = EvalOpts { windows: args.opt_usize("windows", 4), tasks_per_kind: 0 };
    let ev = gsr::eval::tables::eval_model(&native, &arts, opts)?;
    println!(
        "native-quantized PPL ({}): {:.3}",
        tables::calib_label(calib.as_ref()),
        ev.ppl
    );
    wiring.finish()
}

fn cmd_calibrate(args: &Args) -> Result<(), String> {
    use gsr::calib::{capture_hessians_on, checkpoint_fingerprint, CalibCfg, CaptureKey};
    use gsr::data::{draw_token_windows, CorpusGenerator};
    use gsr::exec::NativeBackend;
    use gsr::model::{DenseModel, FpParams, ModelCfg};
    use gsr::quant::{build_plan_rotations, fuse_to_dense_plan};

    let seed = args.opt_usize("seed", 2025) as u64;
    let (cfg, fp, corpus): (ModelCfg, FpParams, Vec<u8>) = if args.has_flag("synthetic") {
        // Demo/CI path: the same structured synthetic checkpoint `gsr
        // search --synthetic` uses, calibrated on freshly drawn corpus.
        let cfg = ModelCfg::default();
        let fp = FpParams::synthetic(&cfg, seed);
        let corpus = CorpusGenerator::new(gsr::data::SEED_CORPUS).generate(1 << 16);
        (cfg, fp, corpus)
    } else {
        let arts = Artifacts::load(Path::new(&artifacts_dir(args)))?;
        let fp = FpParams::load(&arts.fp_weights_path(), &arts.cfg)?;
        // Train split only: PPL eval runs on the held-out test split.
        (arts.cfg.clone(), fp, arts.calib_split().to_vec())
    };
    let plan = plan_from_args(args, &cfg)?;
    plan.validate(&cfg)?;
    let ccfg = CalibCfg {
        n_seqs: args.opt_usize("seqs", 32),
        seq_len: args.opt_usize("seq-len", 64),
        seed: args.opt_usize("calib-seed", 0xCA11B) as u64,
        threads: args.opt_threads(),
    };
    let rots = build_plan_rotations(&cfg, &plan)?;
    let params = fuse_to_dense_plan(&fp, &cfg, &rots);
    let seqs = std::sync::Arc::new(draw_token_windows(
        &corpus,
        ccfg.n_seqs,
        ccfg.seq_len,
        cfg.vocab,
        ccfg.seed,
    ));
    let key = CaptureKey {
        calib_seed: ccfg.seed,
        basis_fingerprint: plan.fingerprint(),
        checkpoint_fingerprint: checkpoint_fingerprint(&fp),
        plan_json: plan.to_json().to_string_pretty(),
    };
    let t0 = std::time::Instant::now();
    // Capture runs on the same batched execution backend that serves
    // eval and the coordinator — one pool, reusable per-thread scratch.
    let model = DenseModel::Quant { cfg: cfg.clone(), params, a_bits: None };
    let backend = NativeBackend::new(
        std::sync::Arc::new(model),
        1,
        ccfg.seq_len.max(1),
        ccfg.threads,
    );
    let set = capture_hessians_on(&backend, std::sync::Arc::clone(&seqs), &key)?;
    let out = args.opt_or("out", "hessians.bin");
    set.save(Path::new(out))?;
    println!(
        "captured {} activation rows over {} sequences in {:?} ({} layers x 4 Hessians)",
        set.tokens,
        seqs.len(),
        t0.elapsed(),
        cfg.n_layers
    );
    println!(
        "basis: {} (fingerprint {:016x}); wrote {out}",
        tables::plan_summary(&plan),
        set.basis_fingerprint
    );
    println!("next: gsr quantize-native --calib {out}   |   gsr search --calib {out}");
    Ok(())
}

fn parse_list_usize(s: &str) -> Result<Vec<usize>, String> {
    s.split(',')
        .map(|p| p.trim().parse::<usize>().map_err(|_| format!("bad number {p:?}")))
        .collect()
}

fn cmd_search(args: &Args) -> Result<(), String> {
    use gsr::calib::HessianSet;
    use gsr::model::{FpParams, ModelCfg, R4Kind};
    use gsr::search::{search_plan_calibrated, CalibWeights, GridCfg, ProxyKind, SearchCfg};
    use gsr::transform::R1Kind;

    let wiring = obs_from_args(args)?;
    let seed = args.opt_usize("seed", 2025) as u64;
    let (cfg, fp) = if args.has_flag("synthetic") {
        // Demo/CI path: a structured synthetic checkpoint, no artifacts.
        let cfg = ModelCfg::default();
        (cfg.clone(), FpParams::synthetic(&cfg, seed))
    } else {
        let arts = Artifacts::load(Path::new(&artifacts_dir(args)))?;
        let fp = FpParams::load(&arts.fp_weights_path(), &arts.cfg)?;
        (arts.cfg.clone(), fp)
    };
    let mut grid = GridCfg::default();
    if let Some(s) = args.opt("blocks") {
        grid.blocks = parse_list_usize(s)?;
    }
    if let Some(s) = args.opt("r1") {
        grid.r1_kinds = s
            .split(',')
            .map(|k| R1Kind::parse(k.trim()).ok_or_else(|| format!("bad r1 kind {k:?}")))
            .collect::<Result<_, _>>()?;
    }
    if let Some(s) = args.opt("r4") {
        grid.r4_kinds = s
            .split(',')
            .map(|k| R4Kind::parse(k.trim()).ok_or_else(|| format!("bad r4 kind {k:?}")))
            .collect::<Result<_, _>>()?;
    }
    let proxy_str = args.opt_or("proxy", "diag");
    let proxy = ProxyKind::parse(proxy_str)
        .ok_or_else(|| format!("bad --proxy {proxy_str:?} (diag|full)"))?;
    if proxy == ProxyKind::Full && args.opt("calib").is_none() {
        return Err("--proxy full needs --calib: the full-Hessian quadratic \
                    form tr(ΔWᵀ·RᵀHR·ΔW) has no uncalibrated fallback"
            .into());
    }
    let scfg = SearchCfg {
        grid,
        bits: args.opt_usize("bits", 2) as u32,
        budget: args.opt_usize("budget", 0),
        threads: args.opt_threads(),
        seed,
        proxy,
    };
    let calib = match args.opt("calib") {
        Some(path) => {
            let set = HessianSet::load(Path::new(path))?;
            let weights = CalibWeights::from_hessian_set(&set, &cfg)?;
            println!(
                "calibration-aware objective: {} from {path} ({} activation rows)",
                match proxy {
                    ProxyKind::Diag => "diag(H) weighting",
                    ProxyKind::Full => "full RᵀHR quadratic form",
                },
                weights.tokens
            );
            Some(weights)
        }
        None => None,
    };
    let t0 = std::time::Instant::now();
    let outcome = search_plan_calibrated(&fp, &cfg, &scfg, calib.as_ref())?;
    // Per-layer search telemetry: winning spec + proxy MSE against the
    // fixed-GSR baseline, one event per layer.
    if wiring.obs.recorder.is_enabled() {
        let h = wiring.obs.recorder.handle("search");
        for l in &outcome.layers {
            h.record(TraceEvent::SearchLayer {
                layer: l.layer,
                spec: l.best.spec.label(),
                mse: l.best.quant_mse,
                baseline_mse: l.baseline.quant_mse,
            });
        }
    }
    let table = tables::search_table(&outcome);
    if args.has_flag("markdown") {
        println!("{}", table.render_markdown());
    } else {
        println!("{}", table.render());
    }
    let objective = match (proxy, calib.is_some()) {
        (ProxyKind::Full, _) => "full-Hessian tr(ΔWᵀ·RᵀHR·ΔW)",
        (ProxyKind::Diag, true) => "diag(H)-weighted group-RTN",
        (ProxyKind::Diag, false) => "group-RTN",
    };
    println!(
        "searched {} layers in {:?} on {} threads: mean {objective} MSE {:.4e} \
         vs fixed-GSR {:.4e} ({} layer(s) strictly improved)",
        outcome.layers.len(),
        t0.elapsed(),
        scfg.threads,
        outcome.mean_mse(),
        outcome.mean_baseline_mse(),
        outcome.improved_layers()
    );
    let out = args.opt_or("out", "rotation_plan.json");
    outcome.plan.save(Path::new(out))?;
    println!("wrote plan to {out}: {}", tables::plan_summary(&outcome.plan));
    println!("next: gsr quantize-native --plan {out}");
    wiring.finish()
}

fn cmd_gen_corpus(args: &Args) -> Result<(), String> {
    let n = args.opt_usize("bytes", 1 << 20);
    let out = args.opt_or("out", "corpus_native.bin").to_string();
    let data = CorpusGenerator::new(gsr::data::SEED_CORPUS).generate(n);
    std::fs::write(&out, &data).map_err(|e| e.to_string())?;
    println!("wrote {} bytes to {out}", data.len());
    Ok(())
}
