//! Weight-blob decoding (flat little-endian tensors in spec order).

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use super::config::{Dtype, ModelCfg, ParamSpec, R4Kind};
use super::kernels::{BasisFast, KernelMode, PackedLinear, R1Desc};
use crate::quant::unpack2;
use crate::rng::SplitMix64;

/// A raw tensor decoded from a blob.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(Vec<f32>),
    U8(Vec<u8>),
}

impl Tensor {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32(v) => v,
            Tensor::U8(_) => panic!("expected f32 tensor"),
        }
    }

    pub fn as_u8(&self) -> &[u8] {
        match self {
            Tensor::U8(v) => v,
            Tensor::F32(_) => panic!("expected u8 tensor"),
        }
    }
}

/// Decode a flat blob into named tensors per `spec`.
pub fn decode_blob(bytes: &[u8], spec: &[ParamSpec]) -> Result<BTreeMap<String, Tensor>, String> {
    let expect: usize = spec.iter().map(|s| s.nbytes()).sum();
    if bytes.len() != expect {
        return Err(format!("blob size {} != spec size {expect}", bytes.len()));
    }
    let mut out = BTreeMap::new();
    let mut off = 0;
    for s in spec {
        let nb = s.nbytes();
        let chunk = &bytes[off..off + nb];
        let t = match s.dtype {
            Dtype::F32 => Tensor::F32(
                chunk
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
            ),
            Dtype::U8 => Tensor::U8(chunk.to_vec()),
        };
        out.insert(s.name.clone(), t);
        off += nb;
    }
    Ok(out)
}

/// fp32 checkpoint parameters (training-model layout, with norms).
#[derive(Debug, Clone)]
pub struct FpParams {
    pub embed: Vec<f32>,
    pub lm_head: Vec<f32>,
    pub ln_f: Vec<f32>,
    pub layers: Vec<FpLayer>,
}

#[derive(Debug, Clone)]
pub struct FpLayer {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub wgate: Vec<f32>,
    pub wup: Vec<f32>,
    pub wdown: Vec<f32>,
}

impl FpParams {
    /// Deterministic synthetic checkpoint with structured, outlier-
    /// bearing norm scales — the massive-channel analogue the rotation
    /// literature targets. Outlier positions and magnitudes vary by
    /// layer so the best rotation configuration genuinely differs per
    /// layer; used by `gsr search --synthetic`, the search bench, and
    /// tests when no trained artifact is available.
    pub fn synthetic(cfg: &ModelCfg, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let d = cfg.d_model;
        let mut dense = |c: usize, h: usize| -> Vec<f32> {
            (0..c * h)
                .map(|_| (rng.next_normal() / (c as f64).sqrt()) as f32)
                .collect()
        };
        let layers: Vec<FpLayer> = (0..cfg.n_layers)
            .map(|l| {
                let mut ln1: Vec<f32> =
                    (0..d).map(|i| 1.0 + 0.1 * ((i + l) % 5) as f32).collect();
                let mut ln2: Vec<f32> =
                    (0..d).map(|i| 1.0 + 0.05 * ((i + 2 * l) % 7) as f32).collect();
                ln1[(7 * l + 3) % d] = 6.0 + 2.0 * l as f32;
                ln1[(31 * l + 17) % d] = 9.0;
                ln2[(13 * l + 8) % d] = 4.0 + 3.0 * l as f32;
                FpLayer {
                    ln1,
                    ln2,
                    wq: dense(d, d),
                    wk: dense(d, d),
                    wv: dense(d, d),
                    wo: dense(d, d),
                    wgate: dense(d, cfg.d_ffn),
                    wup: dense(d, cfg.d_ffn),
                    wdown: dense(cfg.d_ffn, d),
                }
            })
            .collect();
        Self {
            embed: dense(cfg.vocab, d),
            lm_head: dense(d, cfg.vocab),
            ln_f: vec![1.0; d],
            layers,
        }
    }

    pub fn load(path: &Path, cfg: &ModelCfg) -> Result<Self, String> {
        let bytes = fs::read(path).map_err(|e| format!("{path:?}: {e}"))?;
        let map = decode_blob(&bytes, &cfg.fp_param_spec())?;
        let get = |name: &str| -> Vec<f32> { map[name].as_f32().to_vec() };
        let layers = (0..cfg.n_layers)
            .map(|l| FpLayer {
                ln1: get(&format!("layers.{l}.ln1")),
                ln2: get(&format!("layers.{l}.ln2")),
                wq: get(&format!("layers.{l}.wq")),
                wk: get(&format!("layers.{l}.wk")),
                wv: get(&format!("layers.{l}.wv")),
                wo: get(&format!("layers.{l}.wo")),
                wgate: get(&format!("layers.{l}.wgate")),
                wup: get(&format!("layers.{l}.wup")),
                wdown: get(&format!("layers.{l}.wdown")),
            })
            .collect();
        Ok(Self { embed: get("embed"), lm_head: get("lm_head"), ln_f: get("ln_f"), layers })
    }
}

/// Quantized-variant parameters: dequantized dense linears plus the
/// rotation/scale runtime tensors. Dense form feeds both the native
/// reference forward and (as raw blobs) the PJRT path.
#[derive(Debug, Clone)]
pub struct QuantParams {
    pub embed: Vec<f32>,
    pub lm_head: Vec<f32>,
    pub r3: Vec<f32>,
    pub r4_signs: Vec<f32>,
    pub r4_kind: R4Kind,
    pub layers: Vec<QuantLayer>,
    /// Which kernel implementation the forward runs through. Defaults
    /// to [`KernelMode::Reference`] (bit-exact f64 accumulation); the
    /// execution layer flips this to `Fast` on `--kernels fast`.
    pub kernels: KernelMode,
    /// Fast-path form of `r3` (FWHT + signs), present when the dense
    /// tensor was recognized as a randomized Hadamard — exact
    /// verification happens at construction, see
    /// [`R1Desc::from_dense_rht`].
    pub r3_fast: Option<R1Desc>,
}

/// Per-layer online-R4 override used by heterogeneous rotation plans.
/// `None` on a layer means "use the variant-global `r4_kind`/`r4_signs`".
#[derive(Debug, Clone)]
pub struct LayerR4 {
    pub kind: R4Kind,
    /// Sign vector: length `d_ffn` for GH, the local block size for LH.
    pub signs: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct QuantLayer {
    pub ascale_attn: Vec<f32>,
    pub ascale_o: Vec<f32>,
    pub ascale_ffn: Vec<f32>,
    pub ascale_down: Vec<f32>,
    /// Dequantized dense weights, keyed by linear name.
    pub dense: BTreeMap<String, Vec<f32>>,
    /// Residual-stream change of basis applied on layer entry
    /// (`R_{l-1}ᵀ R_l`, row-major `[d, d]`) when a heterogeneous plan
    /// switches R1 between consecutive layers; `None` = same basis.
    pub basis_change: Option<Vec<f32>>,
    /// Per-layer online-R4 override; `None` = use the global fields.
    pub r4: Option<LayerR4>,
    /// Packed-domain form of each linear (same key set as `dense` when
    /// populated). Only consulted in [`KernelMode::Fast`]; a missing
    /// entry falls back to the dense reference matmul.
    pub packed: BTreeMap<String, PackedLinear>,
    /// Fast-path form of `basis_change` (two structured O(n log n)
    /// passes); built alongside it by the quantization pipeline.
    pub basis_fast: Option<BasisFast>,
}

/// Static kernel-path selection summary for one quantized variant:
/// which structures the fast path can consume directly and how many
/// per-linear dense fallbacks it would take. A pure function of the
/// loaded `QuantParams` (recognition happens at construction), so it
/// can be probed once at executor start and exported as telemetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FastPathStats {
    /// The kernel mode the variant runs under.
    pub mode: KernelMode,
    /// Linears with a resident packed-domain form (fused fast matmul).
    pub packed_linears: usize,
    /// Residual-stream basis changes with a recognized structured
    /// (FWHT-based) fast form.
    pub fast_basis_changes: usize,
    /// Dense fallbacks the fast path takes: linears without a packed
    /// form, basis changes without a structured form, and an
    /// unrecognized R3 rotation. Only consulted in fast mode, but
    /// counted unconditionally.
    pub dense_fallbacks: usize,
    /// Whether the global R3 rotation was recognized (FWHT + signs).
    pub r3_fast: bool,
}

impl QuantParams {
    /// Count the fast-path coverage of this variant's resident
    /// structures — see [`FastPathStats`].
    pub fn fast_path_stats(&self) -> FastPathStats {
        let mut packed_linears = 0;
        let mut fast_basis_changes = 0;
        let mut dense_fallbacks = 0;
        for layer in &self.layers {
            for name in super::config::LINEARS {
                if layer.packed.contains_key(name) {
                    packed_linears += 1;
                } else {
                    dense_fallbacks += 1;
                }
            }
            if layer.basis_change.is_some() {
                if layer.basis_fast.is_some() {
                    fast_basis_changes += 1;
                } else {
                    dense_fallbacks += 1;
                }
            }
        }
        let r3_fast = self.r3_fast.is_some();
        if !r3_fast {
            dense_fallbacks += 1;
        }
        FastPathStats {
            mode: self.kernels,
            packed_linears,
            fast_basis_changes,
            dense_fallbacks,
            r3_fast,
        }
    }

    pub fn load(path: &Path, cfg: &ModelCfg, r4_kind: R4Kind) -> Result<Self, String> {
        let bytes = fs::read(path).map_err(|e| format!("{path:?}: {e}"))?;
        let spec = cfg.quant_param_spec(r4_kind);
        let map = decode_blob(&bytes, &spec)?;
        let getf = |name: &str| -> Vec<f32> { map[name].as_f32().to_vec() };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let mut dense = BTreeMap::new();
            let mut packed_map = BTreeMap::new();
            for name in super::config::LINEARS {
                let (c, h) = cfg.linear_shape(name);
                let packed = map[&format!("layers.{l}.{name}_packed")].as_u8();
                let scale = map[&format!("layers.{l}.{name}_scale")].as_f32();
                let zero = map[&format!("layers.{l}.{name}_zero")].as_f32();
                let codes = unpack2(packed, c, h);
                let g = cfg.group;
                let mut w = vec![0f32; c * h];
                for row in 0..c {
                    let grp = row / g;
                    for col in 0..h {
                        let s = scale[grp * h + col];
                        let z = zero[grp * h + col];
                        w[row * h + col] = (codes[row * h + col] as f32 - z) * s;
                    }
                }
                dense.insert(name.to_string(), w);
                // Keep the artifact's packed representation resident so
                // the fast kernels can consume it without re-packing.
                packed_map.insert(
                    name.to_string(),
                    PackedLinear::from_packed2(packed, c, h, g, scale, zero),
                );
            }
            layers.push(QuantLayer {
                ascale_attn: getf(&format!("layers.{l}.ascale_attn")),
                ascale_o: getf(&format!("layers.{l}.ascale_o")),
                ascale_ffn: getf(&format!("layers.{l}.ascale_ffn")),
                ascale_down: getf(&format!("layers.{l}.ascale_down")),
                dense,
                basis_change: None,
                r4: None,
                packed: packed_map,
                basis_fast: None,
            });
        }
        let r3 = getf("r3");
        let r3_fast = R1Desc::from_dense_rht(&r3, cfg.head_dim());
        Ok(Self {
            embed: getf("embed"),
            lm_head: getf("lm_head"),
            r3,
            r4_signs: getf("r4_signs"),
            r4_kind,
            layers,
            kernels: KernelMode::default(),
            r3_fast,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_blob_roundtrip() {
        let spec = vec![
            ParamSpec { name: "a".into(), shape: vec![2, 2], dtype: Dtype::F32 },
            ParamSpec { name: "b".into(), shape: vec![3], dtype: Dtype::U8 },
        ];
        let mut bytes = Vec::new();
        for v in [1.0f32, -2.0, 0.5, 4.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[7, 8, 9]);
        let map = decode_blob(&bytes, &spec).unwrap();
        assert_eq!(map["a"].as_f32(), &[1.0, -2.0, 0.5, 4.0]);
        assert_eq!(map["b"].as_u8(), &[7, 8, 9]);
    }

    #[test]
    fn decode_blob_size_mismatch_is_error() {
        let spec =
            vec![ParamSpec { name: "a".into(), shape: vec![4], dtype: Dtype::F32 }];
        assert!(decode_blob(&[0u8; 15], &spec).is_err());
    }
}
