//! The packed-domain fast kernel layer (`--kernels fast`).
//!
//! The reference forward dequantizes every packed linear into a dense
//! f32 matrix up front and runs all matmuls with bit-exact f64
//! accumulation. That is the determinism contract the serving stack is
//! pinned on — and it pays full dense price for weights that are 2 or 4
//! bits wide. This module is the opt-in alternative:
//!
//! * [`PackedLinear`] keeps the `pack2`/`pack4` byte layout resident and
//!   [`packed_matmul_into`] consumes it directly, dequantizing one
//!   `PK_BK × PK_BJ` tile at a time into a stack buffer that stays
//!   cache-hot while every activation row sweeps it. Inner products run
//!   in f32 (AVX2+FMA when the `simd` feature is on and the CPU has it;
//!   a scalar loop otherwise), with per-tile partials widened into an
//!   f64 accumulator across k-tiles — so the relaxed-order error stays
//!   bounded by one ≤`PK_BK`-term f32 reduction per tile.
//! * [`R1Desc`] recognizes the structure of the dense rotation tensors
//!   (randomized Hadamard, sequency-ordered Walsh, and their
//!   block-diagonal local forms, the paper's GSR) and applies them in
//!   O(n log n) via the FWHT plus sign flips / sequency permutations,
//!   replacing the dense per-head R3 matmul and the dense
//!   residual-stream basis-change matmul of heterogeneous plans.
//!
//! Nothing here runs unless a variant opts in through
//! [`KernelMode::Fast`]; the reference path stays byte-identical. The
//! conformance bound the fast path must stay inside is pinned by
//! `tests/kernels.rs` ([`FAST_LOGIT_TOL`]).

use crate::quant::{pack2, pack4, QuantizedLinear};
use crate::transform::{walsh_permutation, Mat, R1Kind};

use super::forward::fwht_f32;

// ---------------------------------------------------------------------------
// Kernel mode
// ---------------------------------------------------------------------------

/// Which kernel implementation a quantized variant runs its linears and
/// online rotations through.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// Bit-exact f64-accumulation dense kernels — the default, and the
    /// arithmetic every parity guarantee in the repo is stated against.
    #[default]
    Reference,
    /// Packed-domain fused dequant-matmul + FWHT rotations. Relaxes the
    /// accumulation order (f32 tile partials); logits stay within the
    /// test-pinned [`FAST_LOGIT_TOL`] of the reference forward.
    Fast,
}

impl KernelMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelMode::Reference => "reference",
            KernelMode::Fast => "fast",
        }
    }

    pub fn parse(s: &str) -> Option<KernelMode> {
        match s.to_ascii_lowercase().as_str() {
            "reference" => Some(KernelMode::Reference),
            "fast" => Some(KernelMode::Fast),
            _ => None,
        }
    }
}

/// Pinned conformance bound for the fast path: per-logit absolute error
/// versus the f64-reference forward, normalized by `max(1, |logit|)`.
/// The observed error is ~1e-5 (one f32 tile reduction per k-tile, f64
/// across tiles); the bound leaves two orders of margin so it fails on
/// wrong math, not on benign reassociation.
pub const FAST_LOGIT_TOL: f32 = 1e-3;

// ---------------------------------------------------------------------------
// Packed linear storage
// ---------------------------------------------------------------------------

/// Code width of a packed linear.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackedBits {
    /// 2-bit codes, 4 per byte (`pack2` layout).
    B2,
    /// 4-bit codes, 2 per byte (`pack4` layout).
    B4,
}

impl PackedBits {
    pub fn bits(&self) -> u32 {
        match self {
            PackedBits::B2 => 2,
            PackedBits::B4 => 4,
        }
    }
}

/// A group-quantized linear kept in its packed byte form: codes in the
/// `pack2`/`pack4` layout plus the per-group affine, everything the
/// fused kernel needs to dequantize tiles on the fly.
#[derive(Debug, Clone)]
pub struct PackedLinear {
    pub bits: PackedBits,
    /// Input channels.
    pub c: usize,
    /// Output channels.
    pub h: usize,
    /// Quantization group (consecutive input channels).
    pub group: usize,
    /// Packed codes: `[C/4, H]` bytes for 2-bit, `[C/2, H]` for 4-bit.
    pub data: Vec<u8>,
    /// Per-group scales, `[C/G, H]`.
    pub scale: Vec<f32>,
    /// Per-group zero points, `[C/G, H]`.
    pub zero: Vec<f32>,
}

impl PackedLinear {
    /// Pack integer codes (the quantizer's output) into kernel form.
    /// Returns `None` for unsupported bit widths or geometry the byte
    /// layouts cannot represent — callers then simply keep the dense
    /// path for that linear.
    pub fn from_codes(
        codes: &[i32],
        c: usize,
        h: usize,
        group: usize,
        scale: Vec<f32>,
        zero: Vec<f32>,
        bits: u32,
    ) -> Option<PackedLinear> {
        debug_assert_eq!(codes.len(), c * h);
        debug_assert_eq!(scale.len(), c / group * h);
        debug_assert_eq!(zero.len(), c / group * h);
        let (bits, data) = match bits {
            2 if c % 4 == 0 => (PackedBits::B2, pack2(codes, c, h)),
            4 if c % 2 == 0 => (PackedBits::B4, pack4(codes, c, h)),
            _ => return None,
        };
        Some(PackedLinear { bits, c, h, group, data, scale, zero })
    }

    /// Pack a [`QuantizedLinear`] straight out of the native pipeline.
    pub fn from_qlinear(q: &QuantizedLinear) -> Option<PackedLinear> {
        let scale: Vec<f32> = q.scale.iter().map(|&s| s as f32).collect();
        let zero: Vec<f32> = q.zero.iter().map(|&z| z as f32).collect();
        PackedLinear::from_codes(&q.codes, q.c, q.h, q.group, scale, zero, q.bits)
    }

    /// Wrap an already-packed 2-bit artifact blob (the AOT weight
    /// format) without a round trip through integer codes.
    pub fn from_packed2(
        data: &[u8],
        c: usize,
        h: usize,
        group: usize,
        scale: &[f32],
        zero: &[f32],
    ) -> PackedLinear {
        assert_eq!(data.len(), c / 4 * h);
        PackedLinear {
            bits: PackedBits::B2,
            c,
            h,
            group,
            data: data.to_vec(),
            scale: scale.to_vec(),
            zero: zero.to_vec(),
        }
    }

    /// Code of input channel `k`, output column `j`.
    #[inline]
    fn code(&self, k: usize, j: usize) -> u8 {
        match self.bits {
            PackedBits::B2 => (self.data[(k >> 2) * self.h + j] >> (2 * (k & 3))) & 3,
            PackedBits::B4 => (self.data[(k >> 1) * self.h + j] >> (4 * (k & 1))) & 0xF,
        }
    }

    /// Dequantize to a dense `[C, H]` f32 matrix — the baseline the
    /// fused kernel is benched against, and (for artifact blobs) exactly
    /// the dense tensor `QuantParams::load` materializes.
    pub fn dequant_dense(&self) -> Vec<f32> {
        let mut w = vec![0f32; self.c * self.h];
        for k in 0..self.c {
            let grow = k / self.group * self.h;
            for j in 0..self.h {
                let code = self.code(k, j) as f32;
                w[k * self.h + j] = (code - self.zero[grow + j]) * self.scale[grow + j];
            }
        }
        w
    }

    /// Dequantize the `(kb..ke, jb..je)` tile into `tile`, row-major
    /// `[ke-kb, je-jb]`. The per-channel byte row and affine row are
    /// contiguous slices, so the unpack walks memory linearly.
    fn dequant_tile(&self, kb: usize, ke: usize, jb: usize, je: usize, tile: &mut [f32]) {
        let bj = je - jb;
        let h = self.h;
        for k in kb..ke {
            let grow = k / self.group * h;
            let dst = &mut tile[(k - kb) * bj..(k - kb) * bj + bj];
            let ss = &self.scale[grow + jb..grow + je];
            let zz = &self.zero[grow + jb..grow + je];
            match self.bits {
                PackedBits::B2 => {
                    let src = &self.data[(k >> 2) * h + jb..(k >> 2) * h + je];
                    let shift = 2 * (k & 3) as u32;
                    for (((d, &b), &s), &z) in dst.iter_mut().zip(src).zip(ss).zip(zz) {
                        *d = (((b >> shift) & 3) as f32 - z) * s;
                    }
                }
                PackedBits::B4 => {
                    let src = &self.data[(k >> 1) * h + jb..(k >> 1) * h + je];
                    let shift = 4 * (k & 1) as u32;
                    for (((d, &b), &s), &z) in dst.iter_mut().zip(src).zip(ss).zip(zz) {
                        *d = (((b >> shift) & 0xF) as f32 - z) * s;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fused dequant-matmul
// ---------------------------------------------------------------------------

/// Tile sizes of the packed kernel (match the reference matmul's so the
/// cache behavior is comparable; the dequant buffer is 32 KiB of f32).
const PK_BK: usize = 64;
const PK_BJ: usize = 128;

/// Is the AVX2+FMA inner loop usable on this build and CPU?
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn simd_enabled() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
fn simd_enabled() -> bool {
    false
}

/// Scalar f32 tile accumulation: `part[j] += Σ_k xr[k] · tile[k, j]`.
fn accumulate_tile_scalar(xr: &[f32], tile: &[f32], bj: usize, part: &mut [f32]) {
    for (kk, &xv) in xr.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let trow = &tile[kk * bj..(kk + 1) * bj];
        for (p, &tv) in part.iter_mut().zip(trow) {
            *p += xv * tv;
        }
    }
}

/// AVX2+FMA tile accumulation — same reduction as the scalar loop, 8
/// lanes at a time.
///
/// # Safety
/// Caller must have verified `avx2` and `fma` are available.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2,fma")]
unsafe fn accumulate_tile_avx2(xr: &[f32], tile: &[f32], bj: usize, part: &mut [f32]) {
    use std::arch::x86_64::*;
    for (kk, &xv) in xr.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let xvv = _mm256_set1_ps(xv);
        let trow = tile.as_ptr().add(kk * bj);
        let mut j = 0;
        while j + 8 <= bj {
            let tv = _mm256_loadu_ps(trow.add(j));
            let pv = _mm256_loadu_ps(part.as_ptr().add(j));
            _mm256_storeu_ps(part.as_mut_ptr().add(j), _mm256_fmadd_ps(xvv, tv, pv));
            j += 8;
        }
        while j < bj {
            *part.get_unchecked_mut(j) += xv * *tile.get_unchecked(kk * bj + j);
            j += 1;
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn accumulate_tile(use_simd: bool, xr: &[f32], tile: &[f32], bj: usize, part: &mut [f32]) {
    if use_simd {
        // SAFETY: `use_simd` is only true after runtime detection.
        unsafe { accumulate_tile_avx2(xr, tile, bj, part) }
    } else {
        accumulate_tile_scalar(xr, tile, bj, part);
    }
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
fn accumulate_tile(_use_simd: bool, xr: &[f32], tile: &[f32], bj: usize, part: &mut [f32]) {
    accumulate_tile_scalar(xr, tile, bj, part);
}

/// The one fused kernel both packed entry points run: accumulate
/// `x[T,C] @ dequant(w)[C, jb0..je0]` into `acc` (packed
/// `[T, je0-jb0]`, assumed zeroed). Each `(k, j)` tile of `w` is
/// dequantized once into a stack buffer; every activation row then
/// reduces against it in f32 and the ≤[`PK_BK`]-term tile partial is
/// widened into the f64 accumulator. Column partitions reassemble to
/// the same values by construction — the per-element reduction tree
/// does not depend on `(jb0, je0)`.
fn packed_matmul_core(
    x: &[f32],
    w: &PackedLinear,
    t: usize,
    jb0: usize,
    je0: usize,
    acc: &mut [f64],
) {
    let (c, wj) = (w.c, je0 - jb0);
    debug_assert_eq!(x.len(), t * c);
    debug_assert_eq!(acc.len(), t * wj);
    let use_simd = simd_enabled();
    let mut tile = [0f32; PK_BK * PK_BJ];
    let mut part = [0f32; PK_BJ];
    for kb in (0..c).step_by(PK_BK) {
        let ke = (kb + PK_BK).min(c);
        for jb in (jb0..je0).step_by(PK_BJ) {
            let je = (jb + PK_BJ).min(je0);
            let bj = je - jb;
            w.dequant_tile(kb, ke, jb, je, &mut tile[..(ke - kb) * bj]);
            for row in 0..t {
                let xr = &x[row * c + kb..row * c + ke];
                part[..bj].fill(0.0);
                accumulate_tile(use_simd, xr, &tile[..(ke - kb) * bj], bj, &mut part[..bj]);
                let arow = &mut acc[row * wj + (jb - jb0)..row * wj + (je - jb0)];
                for (a, &p) in arow.iter_mut().zip(&part[..bj]) {
                    *a += p as f64;
                }
            }
        }
    }
}

/// `out[T,H] = x[T,C] @ dequant(w)` through the fused packed kernel.
/// Buffers follow the `matmul_into` convention (cleared and resized, so
/// steady-state callers allocate nothing).
pub fn packed_matmul_into(
    x: &[f32],
    w: &PackedLinear,
    t: usize,
    out: &mut Vec<f32>,
    acc: &mut Vec<f64>,
) {
    acc.clear();
    acc.resize(t * w.h, 0.0);
    packed_matmul_core(x, w, t, 0, w.h, acc);
    out.clear();
    out.extend(acc.iter().map(|&a| a as f32));
}

/// Column-restricted packed matmul: `x[T,C] @ dequant(w)[C, jb0..je0]`,
/// returned packed `[T, je0-jb0]` — the form one decode shard runs.
pub fn packed_matmul_cols(
    x: &[f32],
    w: &PackedLinear,
    t: usize,
    jb0: usize,
    je0: usize,
) -> Vec<f32> {
    let mut acc = vec![0f64; t * (je0 - jb0)];
    packed_matmul_core(x, w, t, jb0, je0, &mut acc);
    acc.iter().map(|&a| a as f32).collect()
}

// ---------------------------------------------------------------------------
// Fast structured rotations
// ---------------------------------------------------------------------------

/// A structured-rotation descriptor: the information needed to apply a
/// dense R1-family rotation (or its transpose) in O(n log n) — FWHT
/// butterflies plus column signs (randomized Hadamard kinds) or the
/// sequency permutation (Walsh kinds), per block for the local kinds.
///
/// Built by *recognizing* the structure in the dense tensor the model
/// already carries ([`R1Desc::from_mat`] / [`R1Desc::from_dense_rht`]):
/// recovery is verified entry-by-entry against the closed form, so a
/// tensor that is not exactly the claimed structure yields `None` and
/// the caller keeps the dense matmul. That makes the fast rotation path
/// impossible to enable on mismatched data.
#[derive(Debug, Clone)]
pub struct R1Desc {
    kind: R1Kind,
    /// Transform size of one block (= `n` for the global kinds).
    block: usize,
    /// Total dimension.
    n: usize,
    /// Column signs of one block (Hadamard kinds; empty for Walsh kinds).
    signs: Vec<f32>,
    /// `walsh_permutation(block)` (Walsh kinds; empty for Hadamard kinds).
    perm: Vec<usize>,
}

/// `(-1)^popcount(i & j)` — the Sylvester Hadamard sign closed form.
#[inline]
fn hadamard_sign(i: usize, j: usize) -> f64 {
    if (i & j).count_ones() % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

impl R1Desc {
    pub fn kind(&self) -> R1Kind {
        self.kind
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Recognize the structure of a dense f64 rotation matrix of the
    /// given `kind` / `block`. Verification is exact: every entry must
    /// equal the closed-form reconstruction bit for bit (the builders in
    /// `transform` produce entries of exactly `±1/√block` and exact
    /// zeros off-block), so `Some` means the fast application computes
    /// the same rotation.
    pub fn from_mat(kind: R1Kind, block: usize, m: &Mat) -> Option<R1Desc> {
        let n = m.rows;
        if m.cols != n || block == 0 || n % block != 0 || !block.is_power_of_two() {
            return None;
        }
        if !kind.is_local() && block != n {
            return None;
        }
        Self::recover(kind, block, n, |r, c| m[(r, c)])
    }

    /// [`R1Desc::from_mat`] for the f32 tensors the model carries (the
    /// dense R3 blob): same exact verification, after casting the f64
    /// closed form to f32 — which is precisely how those tensors were
    /// produced.
    pub fn from_dense_f32(kind: R1Kind, block: usize, r: &[f32], n: usize) -> Option<R1Desc> {
        if r.len() != n * n || block == 0 || n % block != 0 || !block.is_power_of_two() {
            return None;
        }
        if !kind.is_local() && block != n {
            return None;
        }
        Self::recover_f32(kind, block, n, r)
    }

    /// Recognize a randomized-Hadamard tensor (`rht(n)` — the R3 shape).
    pub fn from_dense_rht(r: &[f32], n: usize) -> Option<R1Desc> {
        Self::from_dense_f32(R1Kind::GH, n, r, n)
    }

    /// Sign/permutation recovery + exact f64 verification.
    fn recover(
        kind: R1Kind,
        block: usize,
        n: usize,
        at: impl Fn(usize, usize) -> f64,
    ) -> Option<R1Desc> {
        let scale = 1.0 / (block as f64).sqrt();
        let (signs, perm) = Self::structure(kind, block, &at, scale)?;
        // Verify every entry against the closed form.
        for r in 0..n {
            for c in 0..n {
                if at(r, c) != Self::expect(kind, block, &signs, &perm, scale, r, c) {
                    return None;
                }
            }
        }
        let signs32 = signs.iter().map(|&s| s as f32).collect();
        Some(R1Desc { kind, block, n, signs: signs32, perm })
    }

    /// f32 variant of [`R1Desc::recover`]: the closed form is computed
    /// in f64 and cast, matching how the dense f32 tensors were built.
    fn recover_f32(kind: R1Kind, block: usize, n: usize, m: &[f32]) -> Option<R1Desc> {
        let scale = 1.0 / (block as f64).sqrt();
        let at = |r: usize, c: usize| m[r * n + c] as f64;
        let (signs, perm) = Self::structure(kind, block, &at, scale)?;
        for r in 0..n {
            for c in 0..n {
                let e = Self::expect(kind, block, &signs, &perm, scale, r, c) as f32;
                if m[r * n + c] != e {
                    return None;
                }
            }
        }
        let signs32 = signs.iter().map(|&s| s as f32).collect();
        Some(R1Desc { kind, block, n, signs: signs32, perm })
    }

    /// Recover the candidate signs / permutation from the matrix data.
    fn structure(
        kind: R1Kind,
        block: usize,
        at: &impl Fn(usize, usize) -> f64,
        scale: f64,
    ) -> Option<(Vec<f64>, Vec<usize>)> {
        match kind {
            R1Kind::GH | R1Kind::LH => {
                // Row 0 of a Hadamard block is all +scale, so entry
                // (0, c) of the block is `scale · sign(c)`.
                let mut signs = Vec::with_capacity(block);
                for c in 0..block {
                    let v = at(0, c);
                    if v == scale {
                        signs.push(1.0);
                    } else if v == -scale {
                        signs.push(-1.0);
                    } else {
                        return None;
                    }
                }
                // Local kinds replicate one signed block; verification
                // below checks the replication, nothing to recover here.
                Some((signs, Vec::new()))
            }
            R1Kind::GW | R1Kind::GSR => Some((Vec::new(), walsh_permutation(block))),
            // Parametric (angle-carrying) kinds have no sign/perm
            // structure an FWHT can exploit — refuse recognition so the
            // serving path takes the dense fallback (counted in
            // `FastPathStats::dense_fallbacks`), never a silent
            // mis-structured transform.
            R1Kind::GIV | R1Kind::BFLY => None,
        }
    }

    /// Closed-form entry `(r, c)` of the structured matrix.
    fn expect(
        kind: R1Kind,
        block: usize,
        signs: &[f64],
        perm: &[usize],
        scale: f64,
        r: usize,
        c: usize,
    ) -> f64 {
        if r / block != c / block {
            return 0.0;
        }
        let (br, bc) = (r % block, c % block);
        match kind {
            R1Kind::GH | R1Kind::LH => hadamard_sign(br, bc) * scale * signs[bc],
            R1Kind::GW | R1Kind::GSR => hadamard_sign(perm[br], bc) * scale,
            // `structure()` never recovers these, so no R1Desc with a
            // parametric kind can exist to be verified.
            R1Kind::GIV | R1Kind::BFLY => unreachable!("parametric kinds are never structured"),
        }
    }

    /// In-place `row ← row @ R` for one length-`n` row.
    ///
    /// Hadamard kinds: `x @ (H·diag(s)) = fwht(x) ⊙ s`. Walsh kinds
    /// (`W` = `H` rows in sequency order, `H` symmetric):
    /// `(x @ W)[j] = Σ_k x_k H[p_k, j]`, i.e. FWHT of `x` scattered
    /// through the permutation. Local kinds apply per block.
    pub fn forward_row(&self, row: &mut [f32], tmp: &mut Vec<f32>) {
        debug_assert_eq!(row.len(), self.n);
        for chunk in row.chunks_mut(self.block) {
            match self.kind {
                R1Kind::GH | R1Kind::LH => {
                    fwht_f32(chunk);
                    for (v, &s) in chunk.iter_mut().zip(&self.signs) {
                        *v *= s;
                    }
                }
                R1Kind::GW | R1Kind::GSR => {
                    tmp.clear();
                    tmp.resize(self.block, 0.0);
                    for (k, &p) in self.perm.iter().enumerate() {
                        tmp[p] = chunk[k];
                    }
                    fwht_f32(tmp);
                    chunk.copy_from_slice(tmp);
                }
                R1Kind::GIV | R1Kind::BFLY => {
                    unreachable!("parametric kinds are never structured")
                }
            }
        }
    }

    /// In-place `row ← row @ Rᵀ` for one length-`n` row.
    ///
    /// Hadamard kinds: `x @ (H·diag(s))ᵀ = fwht(x ⊙ s)`. Walsh kinds:
    /// `(x @ Wᵀ)[j] = fwht(x)[p_j]` — a gather after the transform.
    pub fn inverse_row(&self, row: &mut [f32], tmp: &mut Vec<f32>) {
        debug_assert_eq!(row.len(), self.n);
        for chunk in row.chunks_mut(self.block) {
            match self.kind {
                R1Kind::GH | R1Kind::LH => {
                    for (v, &s) in chunk.iter_mut().zip(&self.signs) {
                        *v *= s;
                    }
                    fwht_f32(chunk);
                }
                R1Kind::GW | R1Kind::GSR => {
                    fwht_f32(chunk);
                    tmp.clear();
                    tmp.extend(self.perm.iter().map(|&p| chunk[p]));
                    chunk.copy_from_slice(tmp);
                }
                R1Kind::GIV | R1Kind::BFLY => {
                    unreachable!("parametric kinds are never structured")
                }
            }
        }
    }

    /// Apply [`R1Desc::forward_row`] to each row of `[rows, n]`.
    pub fn forward_rows(&self, x: &mut [f32], tmp: &mut Vec<f32>) {
        for row in x.chunks_mut(self.n) {
            self.forward_row(row, tmp);
        }
    }

    /// Apply [`R1Desc::inverse_row`] to each row of `[rows, n]`.
    pub fn inverse_rows(&self, x: &mut [f32], tmp: &mut Vec<f32>) {
        for row in x.chunks_mut(self.n) {
            self.inverse_row(row, tmp);
        }
    }
}

/// Fast form of a heterogeneous plan's residual-stream basis change
/// `x ← x · R_{l-1}ᵀ · R_l`: apply the previous layer's rotation
/// transposed, then the next layer's forward — two O(n log n) passes
/// replacing one dense `[d, d]` matmul.
#[derive(Debug, Clone)]
pub struct BasisFast {
    pub prev: R1Desc,
    pub next: R1Desc,
}

impl BasisFast {
    /// Both descriptors, or `None` if either dense factor was not
    /// recognized (the caller keeps the dense product matmul).
    pub fn from_mats(
        prev_kind: R1Kind,
        prev_block: usize,
        prev: &Mat,
        next_kind: R1Kind,
        next_block: usize,
        next: &Mat,
    ) -> Option<BasisFast> {
        Some(BasisFast {
            prev: R1Desc::from_mat(prev_kind, prev_block, prev)?,
            next: R1Desc::from_mat(next_kind, next_block, next)?,
        })
    }

    /// In-place basis change over `[rows, n]`.
    pub fn apply_rows(&self, x: &mut [f32], tmp: &mut Vec<f32>) {
        self.prev.inverse_rows(x, tmp);
        self.next.forward_rows(x, tmp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::matmul;
    use crate::rng::SplitMix64;
    use crate::transform::build_r1;

    fn rand_x(rng: &mut SplitMix64, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.next_normal() as f32).collect()
    }

    fn rand_packed(
        rng: &mut SplitMix64,
        c: usize,
        h: usize,
        group: usize,
        bits: u32,
    ) -> PackedLinear {
        let qmax = (1i32 << bits) - 1;
        let codes: Vec<i32> =
            (0..c * h).map(|_| rng.next_below(qmax as u64 + 1) as i32).collect();
        let ng = c / group;
        let scale: Vec<f32> =
            (0..ng * h).map(|_| 0.01 + rng.next_f64() as f32 * 0.05).collect();
        let zero: Vec<f32> =
            (0..ng * h).map(|_| rng.next_below(qmax as u64 + 1) as f32).collect();
        PackedLinear::from_codes(&codes, c, h, group, scale, zero, bits).unwrap()
    }

    /// Per-element bound for a single fused matmul against the f64
    /// reference: one f32 tile reduction per k-tile.
    fn assert_close(fast: &[f32], reference: &[f32]) {
        for (a, b) in fast.iter().zip(reference) {
            let tol = 1e-4 * b.abs().max(1.0);
            assert!((a - b).abs() <= tol, "fused kernel diverged: {a} vs {b}");
        }
    }

    #[test]
    fn packed_matmul_matches_dense_reference() {
        let mut rng = SplitMix64::new(11);
        let shapes: [(usize, usize, usize, usize, u32); 4] =
            [(3, 64, 48, 16, 2), (2, 64, 130, 32, 2), (5, 128, 96, 64, 4), (1, 32, 200, 16, 4)];
        for &(t, c, h, group, bits) in &shapes {
            let w = rand_packed(&mut rng, c, h, group, bits);
            let x = rand_x(&mut rng, t * c);
            let dense = w.dequant_dense();
            let reference = matmul(&x, &dense, t, c, h);
            let (mut out, mut acc) = (Vec::new(), Vec::new());
            packed_matmul_into(&x, &w, t, &mut out, &mut acc);
            assert_close(&out, &reference);
        }
    }

    #[test]
    fn packed_cols_partition_reassembles() {
        let mut rng = SplitMix64::new(12);
        let (t, c, h, group) = (4, 64, 96, 16);
        let w = rand_packed(&mut rng, c, h, group, 2);
        let x = rand_x(&mut rng, t * c);
        let (mut full, mut acc) = (Vec::new(), Vec::new());
        packed_matmul_into(&x, &w, t, &mut full, &mut acc);
        for &split in &[1usize, 33, 64, 95] {
            let left = packed_matmul_cols(&x, &w, t, 0, split);
            let right = packed_matmul_cols(&x, &w, t, split, h);
            for row in 0..t {
                for j in 0..h {
                    let v = if j < split {
                        left[row * split + j]
                    } else {
                        right[row * (h - split) + (j - split)]
                    };
                    let want = full[row * h + j];
                    assert_eq!(v.to_bits(), want.to_bits(), "split {split} ({row},{j})");
                }
            }
        }
    }

    #[test]
    fn dequant_dense_matches_unpacked_affine() {
        let mut rng = SplitMix64::new(13);
        for bits in [2u32, 4] {
            let (c, h, group) = (16usize, 6usize, 8usize);
            let qmax = (1i32 << bits) - 1;
            let codes: Vec<i32> =
                (0..c * h).map(|_| rng.next_below(qmax as u64 + 1) as i32).collect();
            let scale: Vec<f32> = (0..c / group * h).map(|_| 0.5).collect();
            let zero: Vec<f32> = (0..c / group * h).map(|_| 1.0).collect();
            let w = PackedLinear::from_codes(&codes, c, h, group, scale, zero, bits).unwrap();
            let dense = w.dequant_dense();
            for k in 0..c {
                for j in 0..h {
                    let expect = (codes[k * h + j] as f32 - 1.0) * 0.5;
                    assert_eq!(dense[k * h + j], expect);
                }
            }
        }
    }

    #[test]
    fn from_codes_rejects_unsupported() {
        let codes = vec![0i32; 6 * 4];
        let mk = |bits| {
            PackedLinear::from_codes(&codes, 6, 4, 2, vec![1.0; 12], vec![0.0; 12], bits)
        };
        // 3-bit has no packed layout; 2-bit needs c % 4 == 0.
        assert!(mk(3).is_none());
        assert!(mk(2).is_none());
        assert!(mk(4).is_some());
    }

    #[test]
    fn r1_desc_recognizes_all_kinds_and_matches_dense() {
        let (n, block) = (64usize, 16usize);
        for kind in R1Kind::ALL {
            let mut rng = SplitMix64::new(21);
            let m = build_r1(kind, n, block, &mut rng);
            let b = if kind.is_local() { block } else { n };
            let desc = R1Desc::from_mat(kind, b, &m)
                .unwrap_or_else(|| panic!("{kind} not recognized"));
            let mut rng2 = SplitMix64::new(22);
            let x: Vec<f32> = (0..n).map(|_| rng2.next_normal() as f32).collect();
            // Dense reference in f64.
            let xd: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            let fwd = m.apply_right(&xd);
            let inv = m.transpose().apply_right(&xd);
            let mut tmp = Vec::new();
            let mut got_fwd = x.clone();
            desc.forward_row(&mut got_fwd, &mut tmp);
            let mut got_inv = x.clone();
            desc.inverse_row(&mut got_inv, &mut tmp);
            for (a, b) in got_fwd.iter().zip(&fwd) {
                assert!((*a as f64 - b).abs() < 1e-5, "{kind} forward: {a} vs {b}");
            }
            for (a, b) in got_inv.iter().zip(&inv) {
                assert!((*a as f64 - b).abs() < 1e-5, "{kind} inverse: {a} vs {b}");
            }
        }
    }

    #[test]
    fn r1_desc_rejects_non_structured_matrix() {
        let mut rng = SplitMix64::new(31);
        let mut m = build_r1(R1Kind::GH, 16, 16, &mut rng);
        m[(3, 5)] += 0.25; // break the structure
        assert!(R1Desc::from_mat(R1Kind::GH, 16, &m).is_none());
        // Wrong claimed kind must also be rejected: a Walsh matrix is a
        // row permutation of the Hadamard, not a column-signed one.
        let w = build_r1(R1Kind::GW, 16, 16, &mut SplitMix64::new(1));
        assert!(R1Desc::from_mat(R1Kind::GH, 16, &w).is_none());
    }

    #[test]
    fn rht_sign_recovery_from_f32() {
        let n = 16;
        let mut rng = SplitMix64::new(41);
        let m = crate::transform::rht(n, &mut rng);
        let r32: Vec<f32> = m.data.iter().map(|&v| v as f32).collect();
        let desc = R1Desc::from_dense_rht(&r32, n).expect("rht recognized");
        let mut rng2 = SplitMix64::new(42);
        let x: Vec<f32> = (0..n).map(|_| rng2.next_normal() as f32).collect();
        let xd: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let want = m.apply_right(&xd);
        let mut got = x;
        let mut tmp = Vec::new();
        desc.forward_row(&mut got, &mut tmp);
        for (a, b) in got.iter().zip(&want) {
            assert!((*a as f64 - b).abs() < 1e-5);
        }
        // A perturbed tensor is rejected.
        let mut bad = r32;
        bad[7] = 0.123;
        assert!(R1Desc::from_dense_rht(&bad, n).is_none());
    }

    #[test]
    fn basis_fast_matches_dense_product() {
        let n = 64;
        let prev = build_r1(R1Kind::LH, n, 32, &mut SplitMix64::new(51));
        let next = build_r1(R1Kind::GSR, n, 16, &mut SplitMix64::new(52));
        let bf = BasisFast::from_mats(R1Kind::LH, 32, &prev, R1Kind::GSR, 16, &next).unwrap();
        let product = prev.transpose().matmul(&next);
        let mut rng = SplitMix64::new(53);
        let x: Vec<f32> = (0..2 * n).map(|_| rng.next_normal() as f32).collect();
        let mut got = x.clone();
        let mut tmp = Vec::new();
        bf.apply_rows(&mut got, &mut tmp);
        for row in 0..2 {
            let xd: Vec<f64> = x[row * n..(row + 1) * n].iter().map(|&v| v as f64).collect();
            let want = product.apply_right(&xd);
            for (a, b) in got[row * n..(row + 1) * n].iter().zip(&want) {
                assert!((*a as f64 - b).abs() < 1e-5, "row {row}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn kernel_mode_parse_roundtrip() {
        for mode in [KernelMode::Reference, KernelMode::Fast] {
            assert_eq!(KernelMode::parse(mode.as_str()), Some(mode));
        }
        assert_eq!(KernelMode::parse("FAST"), Some(KernelMode::Fast));
        assert_eq!(KernelMode::parse("nope"), None);
        assert_eq!(KernelMode::default(), KernelMode::Reference);
    }
}
