//! Model layer: configuration, weight-blob decoding, native forward.
//!
//! The native (pure-Rust) forward pass is the *reference implementation*
//! used to validate the PJRT execution path end-to-end: both consume the
//! same artifact blobs and must agree to float tolerance. It also powers
//! the Fig.-1 rotation-invariance test and a PJRT-free fallback eval.

pub mod config;
pub mod forward;
pub mod weights;

pub use config::{ModelCfg, ParamSpec, R4Kind};
pub use forward::{
    forward_quant_tapped, forward_quant_tapped_with, ActivationTap, DenseModel, ForwardScratch,
    TapSite,
};
pub use weights::{FpParams, LayerR4, QuantParams};
