//! Model layer: configuration, weight-blob decoding, native forward.
//!
//! The native (pure-Rust) forward pass is the *reference implementation*
//! used to validate the PJRT execution path end-to-end: both consume the
//! same artifact blobs and must agree to float tolerance. It also powers
//! the Fig.-1 rotation-invariance test and a PJRT-free fallback eval.
//!
//! Beyond the full-sequence pass, [`forward`] provides the incremental
//! decoding primitives: a per-sequence [`KvCache`] plus
//! `DenseModel::forward_cached`, whose per-step logits are bit-identical
//! to a full re-forward of the prefix, and the [`ShardRunner`] hook the
//! execution layer uses to parallelize a single decode step.

pub mod config;
pub mod forward;
pub mod kernels;
pub mod weights;

pub use config::{tokens_in_vocab, ModelCfg, ParamSpec, R4Kind};
pub use forward::{
    forward_quant_tapped, forward_quant_tapped_with, ActivationTap, DecodePar, DenseModel,
    ForwardScratch, KvBlock, KvCache, ShardJob, ShardRunner, TapSite,
};
pub use kernels::{
    packed_matmul_cols, packed_matmul_into, BasisFast, KernelMode, PackedBits, PackedLinear,
    R1Desc, FAST_LOGIT_TOL,
};
pub use weights::{FastPathStats, FpParams, LayerR4, QuantParams};
