//! Native reference forward pass (pure Rust, no PJRT).
//!
//! Mirrors `python/compile/model.py::forward_fp` / `forward_rotated` on
//! single sequences. Used to (a) cross-validate the PJRT path against an
//! independent implementation, (b) run the Fig.-1 rotation-invariance
//! cargo test, and (c) back the batched native execution engine
//! (`exec::NativeBackend`) that serves eval, calibration and the
//! coordinator.
//!
//! Every intermediate lives in a caller-supplied [`ForwardScratch`] so a
//! long-lived worker thread pays zero allocation per forward call, and
//! every linear runs through the cache-blocked tiled [`matmul_into`].
//! Both are bit-transparent: per output element the f64 accumulation
//! order is unchanged, so `forward` produces logits bit-identical to the
//! original straight-line implementation — the invariant the batched
//! engine's "same logits for any batch composition / thread count"
//! guarantee rests on.
//!
//! ## Incremental decoding
//!
//! [`DenseModel::forward_cached`] is the same forward in *incremental*
//! form: a [`KvCache`] holds every previous position's attention keys
//! and values (post-RoPE — and post-R3 on the quantized path — exactly
//! the tensors attention consumes), so absorbing a chunk costs
//! `O(chunk)` linears plus attention against the cache instead of a
//! full-prefix re-forward. Because the fused SpinQuant-style rotations
//! (R1/R2 and any per-layer R4 override) are folded into the weights
//! *before* the cached tensors are produced, cached rows stay valid
//! across steps even for heterogeneous searched plans. Every primitive
//! is shared with the full forward and keeps its per-element f64
//! accumulation order, so cached logits are **bit-identical** to a full
//! re-forward of the prefix at every step (pinned by the decode parity
//! proptests).
//!
//! [`DenseModel::forward_cached_par`] adds intra-sequence parallelism:
//! large linears are column-sharded and attention is head-sharded over a
//! [`ShardRunner`] (the exec layer's worker pool). Each output element's
//! accumulation is unchanged by any partition, so sharding is
//! bit-transparent too — `--threads` never changes decode logits.

use super::config::{ModelCfg, R4Kind};
use super::kernels::{packed_matmul_cols, packed_matmul_into, KernelMode, PackedLinear, R1Desc};
use super::weights::{FpParams, QuantParams};

/// A runnable dense model: fp checkpoint or dequantized variant.
pub enum DenseModel {
    Fp { cfg: ModelCfg, params: FpParams },
    Quant { cfg: ModelCfg, params: QuantParams, a_bits: Option<u32> },
}

const ACT_CLIP: f32 = 0.9;

// ---------------------------------------------------------------------------
// Activation taps (calibration capture)
// ---------------------------------------------------------------------------

/// Where in the rotated forward an activation tap fires: each site is
/// the exact input matrix one or more fused linears consume, **in the
/// basis that linear quantizes in** (after norms, activation scales and
/// fake-quant, immediately before the matmul).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapSite {
    /// Input of `wq`/`wk`/`wv`: post-norm residual stream, layer R1 basis.
    AttnIn,
    /// Input of `wo`: attention output in the B2/R3 head basis.
    OIn,
    /// Input of `wgate`/`wup`: post-norm residual stream, layer R1 basis.
    FfnIn,
    /// Input of `wdown`: FFN activation after the online R4 rotation.
    DownIn,
}

impl TapSite {
    pub const ALL: [TapSite; 4] =
        [TapSite::AttnIn, TapSite::OIn, TapSite::FfnIn, TapSite::DownIn];
}

/// Observer of per-linear input activations during
/// [`forward_quant_tapped`] — the hook the `calib` subsystem uses to
/// accumulate streaming `XᵀX` Hessians without copying activations.
pub trait ActivationTap {
    /// `rows` is a row-major `[T, width]` activation matrix.
    fn record(&mut self, layer: usize, site: TapSite, rows: &[f32], width: usize);
}

// ---------------------------------------------------------------------------
// Reusable scratch
// ---------------------------------------------------------------------------

/// Reusable buffers for one forward call. A worker thread keeps one of
/// these alive across calls so the steady state allocates nothing: every
/// buffer is `clear()`+`resize()`d (capacity retained) and fully
/// overwritten before it is read, so no state leaks between sequences —
/// results are bit-identical whether a scratch is fresh or reused.
#[derive(Default)]
pub struct ForwardScratch {
    /// Residual stream `[T, d]`.
    x: Vec<f32>,
    /// Basis-change double buffer for `x`.
    xt: Vec<f32>,
    /// Post-norm linear input `[T, d]`.
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention output `[T, d]`.
    o: Vec<f32>,
    /// FFN gate / up projections `[T, d_ffn]`.
    g: Vec<f32>,
    u: Vec<f32>,
    /// FFN activation `[T, d_ffn]`.
    z: Vec<f32>,
    /// Output of `wo` / `wdown` `[T, d]`.
    zd: Vec<f32>,
    /// f64 matmul accumulator (the tiled fast path sums here).
    acc: Vec<f64>,
    /// Attention score row (f64, one per key position).
    scores: Vec<f64>,
    cos: Vec<f32>,
    sin: Vec<f32>,
    /// Per-head rotation temp (`head_dim` wide).
    head_tmp: Vec<f32>,
}

impl ForwardScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

// ---------------------------------------------------------------------------
// KV cache (incremental decoding)
// ---------------------------------------------------------------------------

/// One fixed-size page of KV storage: `page` token rows per layer, for
/// every layer of the model. Blocks are minted by the scheduler's block
/// pool (`sched::BlockPool`), granted to a sequence's paged [`KvCache`],
/// and physically move back to the pool on reclaim — storage ownership
/// is explicit, never shared.
pub struct KvBlock {
    id: u32,
    /// Per-layer key rows, each buffer `page * width` floats.
    k: Vec<Vec<f32>>,
    /// Per-layer value rows, same shape as `k`.
    v: Vec<Vec<f32>>,
}

impl KvBlock {
    /// Zero-filled block holding `page` token rows of width `width` for
    /// `n_layers` layers.
    pub fn new(id: u32, n_layers: usize, page: usize, width: usize) -> Self {
        Self {
            id,
            k: (0..n_layers).map(|_| vec![0.0; page * width]).collect(),
            v: (0..n_layers).map(|_| vec![0.0; page * width]).collect(),
        }
    }

    /// Pool-assigned identity; allocation order is deterministic
    /// (lowest free id first), so block-id sequences are replayable.
    pub fn id(&self) -> u32 {
        self.id
    }
}

/// Physical KV storage behind a [`KvCache`]: either one contiguous
/// `[capacity, width]` buffer per layer, or a table of granted
/// fixed-size [`KvBlock`]s (paged mode).
enum KvStore {
    Contig(Vec<LayerKv>),
    Paged {
        /// Token rows per block.
        page: usize,
        /// Block table, position order: row `p` lives in
        /// `blocks[p / page]` at offset `p % page`.
        blocks: Vec<KvBlock>,
        /// Contiguous gather scratch for attention (keys / values).
        gather_k: Vec<f32>,
        gather_v: Vec<f32>,
    },
}

/// Per-sequence attention state for incremental decoding: one logical
/// `[len, d_model]` key and value buffer per layer.
///
/// Cached rows are the exact tensors attention consumes — keys after
/// RoPE (and after the R3 head rotation on the quantized path), values
/// straight out of `wv` — so a row written at position `p` never needs
/// to be touched again: all R1/R2 and per-layer R4 rotations are fused
/// into the weights *upstream* of these tensors, which is what makes a
/// cached decode path valid for heterogeneous searched plans too.
///
/// Storage is either contiguous ([`KvCache::new`], capacity fixed up
/// front) or paged ([`KvCache::paged`], capacity grows block-by-block
/// as [`KvBlock`]s are granted). The layout is invisible to the math:
/// before attention, a paged cache gathers its rows into contiguous
/// scratch in position order, so the bits consumed — and hence every
/// decode logit — are identical across layouts.
pub struct KvCache {
    store: KvStore,
    /// Positions already absorbed (prompt + decoded tokens).
    len: usize,
    /// Maximum positions this cache may hold (paged: grows with grants).
    capacity: usize,
    /// Row width (`d_model`) — part of the geometry check.
    width: usize,
    /// Layer count — part of the geometry check.
    n_layers: usize,
}

struct LayerKv {
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    /// Empty contiguous cache for `cfg`'s geometry holding up to
    /// `capacity` tokens (buffers are pre-reserved so steady-state
    /// decode never reallocates).
    ///
    /// ```
    /// use gsr::model::{KvCache, ModelCfg};
    /// let cache = KvCache::new(&ModelCfg::default(), 8);
    /// assert_eq!((cache.len(), cache.remaining()), (0, 8));
    /// ```
    pub fn new(cfg: &ModelCfg, capacity: usize) -> Self {
        let width = cfg.d_model;
        let layers = (0..cfg.n_layers)
            .map(|_| LayerKv {
                k: Vec::with_capacity(capacity * width),
                v: Vec::with_capacity(capacity * width),
            })
            .collect();
        Self {
            store: KvStore::Contig(layers),
            len: 0,
            capacity,
            width,
            n_layers: cfg.n_layers,
        }
    }

    /// Empty paged cache for `cfg`'s geometry with `page`-token blocks.
    /// Starts with zero capacity: every `page` tokens of headroom must
    /// be granted via [`KvCache::grant`] before they can be absorbed.
    pub fn paged(cfg: &ModelCfg, page: usize) -> Self {
        Self {
            store: KvStore::Paged {
                page: page.max(1),
                blocks: Vec::new(),
                gather_k: Vec::new(),
                gather_v: Vec::new(),
            },
            len: 0,
            capacity: 0,
            width: cfg.d_model,
            n_layers: cfg.n_layers,
        }
    }

    /// Whether this cache reads/writes through a block table.
    pub fn is_paged(&self) -> bool {
        matches!(self.store, KvStore::Paged { .. })
    }

    /// Token rows per block (`None` for contiguous caches).
    pub fn page_size(&self) -> Option<usize> {
        match &self.store {
            KvStore::Paged { page, .. } => Some(*page),
            KvStore::Contig(_) => None,
        }
    }

    /// Append `block` to the block table, extending capacity by one
    /// page. Rejects contiguous caches and geometry mismatches (the
    /// block is returned to the caller inside the error in neither
    /// case — it is simply dropped — so callers should check geometry
    /// at pool construction, not per grant).
    pub fn grant(&mut self, block: KvBlock) -> Result<(), String> {
        let (w, nl) = (self.width, self.n_layers);
        match &mut self.store {
            KvStore::Contig(_) => Err("cannot grant a kv block to a contiguous cache".to_string()),
            KvStore::Paged { page, blocks, .. } => {
                let page = *page;
                if block.k.len() != nl
                    || block.v.len() != nl
                    || block.k.iter().chain(block.v.iter()).any(|b| b.len() != page * w)
                {
                    return Err(format!(
                        "kv block geometry does not match cache [{nl} layers x {page} x {w}]"
                    ));
                }
                blocks.push(block);
                self.capacity += page;
                Ok(())
            }
        }
    }

    /// Take every granted block back (preempt/evict/complete): the cache
    /// returns to zero capacity and zero length; cached rows are
    /// recomputed on resume, never migrated. Contiguous caches return
    /// an empty vec and are otherwise untouched.
    pub fn reclaim_blocks(&mut self) -> Vec<KvBlock> {
        match &mut self.store {
            KvStore::Contig(_) => Vec::new(),
            KvStore::Paged { blocks, .. } => {
                self.len = 0;
                self.capacity = 0;
                std::mem::take(blocks)
            }
        }
    }

    /// Ids of the granted blocks, table order (empty for contiguous).
    pub fn block_ids(&self) -> Vec<u32> {
        match &self.store {
            KvStore::Contig(_) => Vec::new(),
            KvStore::Paged { blocks, .. } => blocks.iter().map(|b| b.id).collect(),
        }
    }

    /// Tokens currently cached — the sequence position decode resumes at.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum tokens this cache may hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Tokens that can still be absorbed before the cache is full.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// Reset for a new sequence, keeping the allocations (contiguous) or
    /// the granted blocks (paged — rows are positional, so stale data is
    /// simply overwritten).
    pub fn clear(&mut self) {
        if let KvStore::Contig(layers) = &mut self.store {
            for layer in layers {
                layer.k.clear();
                layer.v.clear();
            }
        }
        self.len = 0;
    }

    /// Roll back to `len` cached positions, discarding every row past
    /// that point. This is the speculative-decoding rollback: after a
    /// verify forward absorbed k drafted tokens, the cache truncates to
    /// the last *accepted* position and decode resumes as if the
    /// rejected tokens were never fed — rows are positional, so the
    /// discarded entries are overwritten by the next append and the
    /// resulting logits are bit-identical to never having drafted.
    ///
    /// Rolling "back" past the current length is refused (it would
    /// silently fabricate cache rows). Capacity is untouched; paged
    /// callers release surplus tail blocks separately via
    /// [`KvCache::release_tail_blocks`].
    pub fn rollback(&mut self, len: usize) -> Result<(), String> {
        if len > self.len {
            return Err(format!(
                "kv rollback target {len} exceeds cached length {}",
                self.len
            ));
        }
        self.truncate(len);
        Ok(())
    }

    /// Return the granted tail blocks that hold no live rows — every
    /// block wholly past `ceil(len / page)` — shrinking capacity
    /// accordingly, so a rolled-back sequence hands its surplus pages
    /// straight back to the block pool instead of squatting on them.
    /// Contiguous caches (and caches whose last block is partially
    /// live) return an empty vec.
    pub fn release_tail_blocks(&mut self) -> Vec<KvBlock> {
        match &mut self.store {
            KvStore::Contig(_) => Vec::new(),
            KvStore::Paged { page, blocks, .. } => {
                let live = self.len.div_ceil(*page);
                if live >= blocks.len() {
                    return Vec::new();
                }
                let freed = blocks.split_off(live);
                self.capacity = blocks.len() * *page;
                freed
            }
        }
    }

    /// Roll back to `len` cached positions (error-path cleanup: a failed
    /// chunk must not leave half-appended rows behind). Paged storage is
    /// positional, so rollback is just the length reset — rows past
    /// `len` become dead and are overwritten by the next append.
    fn truncate(&mut self, len: usize) {
        if let KvStore::Contig(layers) = &mut self.store {
            for layer in layers {
                layer.k.truncate(len * self.width);
                layer.v.truncate(len * self.width);
            }
        }
        self.len = len;
    }

    /// Append layer `l`'s `[t, width]` key/value rows at positions
    /// `self.len..self.len + t` (`self.len` advances once per forward
    /// call, after every layer has appended).
    fn append_layer(&mut self, l: usize, k: &[f32], v: &[f32]) {
        let w = self.width;
        match &mut self.store {
            KvStore::Contig(layers) => {
                let lk = &mut layers[l];
                lk.k.extend_from_slice(k);
                lk.v.extend_from_slice(v);
            }
            KvStore::Paged { page, blocks, .. } => {
                let page = *page;
                for row in 0..k.len() / w {
                    let pos = self.len + row;
                    let (b, off) = (pos / page, pos % page);
                    blocks[b].k[l][off * w..(off + 1) * w]
                        .copy_from_slice(&k[row * w..(row + 1) * w]);
                    blocks[b].v[l][off * w..(off + 1) * w]
                        .copy_from_slice(&v[row * w..(row + 1) * w]);
                }
            }
        }
    }

    /// Layer `l`'s first `rows` cached key/value rows as contiguous
    /// slices — the exact tensors attention consumes. Contiguous caches
    /// return their buffers directly; paged caches gather block rows
    /// into scratch in position order, so the values and their order —
    /// hence attention's f64 accumulation and every resulting bit — are
    /// independent of the block layout.
    fn layer_view(&mut self, l: usize, rows: usize) -> (&[f32], &[f32]) {
        let w = self.width;
        match &mut self.store {
            KvStore::Contig(layers) => {
                let lk = &layers[l];
                (&lk.k[..rows * w], &lk.v[..rows * w])
            }
            KvStore::Paged { page, blocks, gather_k, gather_v } => {
                let page = *page;
                gather_k.clear();
                gather_v.clear();
                gather_k.reserve(rows * w);
                gather_v.reserve(rows * w);
                let mut pos = 0;
                while pos < rows {
                    let take = (rows - pos).min(page);
                    gather_k.extend_from_slice(&blocks[pos / page].k[l][..take * w]);
                    gather_v.extend_from_slice(&blocks[pos / page].v[l][..take * w]);
                    pos += take;
                }
                (gather_k.as_slice(), gather_v.as_slice())
            }
        }
    }

    fn check(&self, cfg: &ModelCfg, t: usize) -> Result<(), String> {
        if self.n_layers != cfg.n_layers || self.width != cfg.d_model {
            return Err(format!(
                "kv cache geometry [{} layers x {}] does not match model [{} layers x {}]",
                self.n_layers, self.width, cfg.n_layers, cfg.d_model
            ));
        }
        if t == 0 {
            return Err("cached forward needs at least one token".to_string());
        }
        if self.len + t > self.capacity {
            return Err(format!(
                "kv cache full: {} cached + {t} new tokens exceeds capacity {}",
                self.len, self.capacity
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Intra-sequence work sharding (decode parallelism hook)
// ---------------------------------------------------------------------------

/// One shard of a decode-step linear or attention call: produces its
/// packed output slice. Boxed so [`ShardRunner`] stays object-safe.
pub type ShardJob<'env> = Box<dyn FnOnce() -> Vec<f32> + Send + 'env>;

/// Executes a set of independent [`ShardJob`]s — possibly concurrently —
/// and returns their outputs **in job order**. Implemented by the exec
/// layer's worker pool (`exec::ExecPool`); defined here so the forward
/// pass can shard work without depending on the execution layer.
///
/// Implementations must not return until every job has finished or been
/// dropped: jobs borrow the caller's stack frame.
pub trait ShardRunner {
    fn run<'env>(&self, jobs: Vec<ShardJob<'env>>) -> Result<Vec<Vec<f32>>, String>;
}

/// Intra-sequence parallelism for [`DenseModel::forward_cached_par`]:
/// large linears split their output columns and attention splits its
/// heads into at most `shards` jobs on `runner`. Sharding never changes
/// bits — each output element's f64 accumulation order is identical for
/// every partition — so any `shards` value yields the same logits.
pub struct DecodePar<'a> {
    pub runner: &'a dyn ShardRunner,
    /// Upper bound on concurrent shards (typically the pool's workers).
    pub shards: usize,
}

/// Columns below this stay serial: a shard must amortize its dispatch.
const MIN_SHARD_COLS: usize = 32;

/// Split `0..n` into at most `max_shards` contiguous near-equal ranges
/// of at least `min_chunk` elements; `None` when sharding isn't worth it.
fn shard_ranges(n: usize, min_chunk: usize, max_shards: usize) -> Option<Vec<(usize, usize)>> {
    let shards = max_shards.min(n / min_chunk);
    if shards < 2 {
        return None;
    }
    let (base, rem) = (n / shards, n % shards);
    let mut ranges = Vec::with_capacity(shards);
    let mut start = 0;
    for i in 0..shards {
        let end = start + base + usize::from(i < rem);
        ranges.push((start, end));
        start = end;
    }
    Some(ranges)
}

impl DenseModel {
    pub fn cfg(&self) -> &ModelCfg {
        match self {
            DenseModel::Fp { cfg, .. } => cfg,
            DenseModel::Quant { cfg, .. } => cfg,
        }
    }

    /// Forward a single token sequence → logits `[T, vocab]` (row-major).
    pub fn forward(&self, tokens: &[i32]) -> Vec<f32> {
        self.forward_with(tokens, &mut ForwardScratch::new())
    }

    /// [`DenseModel::forward`] with caller-owned scratch buffers —
    /// allocation-free in steady state, bit-identical results.
    pub fn forward_with(&self, tokens: &[i32], scratch: &mut ForwardScratch) -> Vec<f32> {
        match self {
            DenseModel::Fp { cfg, params } => {
                forward_fp_impl(cfg, params, tokens, scratch, None, None)
            }
            DenseModel::Quant { cfg, params, a_bits } => {
                forward_quant_impl(cfg, params, *a_bits, tokens, None, scratch, None, None)
            }
        }
        // With no cache and no shard runner every fallible path is
        // unreachable: the serial forward cannot error.
        .expect("serial uncached forward is infallible")
    }

    /// Incremental (KV-cached) forward: absorb `tokens` at positions
    /// `cache.len()..` and return row-major `[tokens.len(), vocab]`
    /// logits for exactly the absorbed positions.
    ///
    /// Prefill is a call on an empty cache with the whole prompt; decode
    /// is a call with one token. At every step the logits are
    /// **bit-identical** to [`DenseModel::forward`] over the full prefix
    /// — the cached rows and the chunk rows run the exact per-position
    /// arithmetic of the full pass. On error the cache is rolled back to
    /// its pre-call state.
    pub fn forward_cached(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        scratch: &mut ForwardScratch,
    ) -> Result<Vec<f32>, String> {
        self.forward_cached_par(tokens, cache, scratch, None)
    }

    /// [`DenseModel::forward_cached`] with optional intra-sequence
    /// parallelism: linears column-shard and attention head-shards over
    /// `par`'s workers. Bit-transparent — any shard count (including
    /// `None`) produces the same logits.
    pub fn forward_cached_par(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        scratch: &mut ForwardScratch,
        par: Option<&DecodePar>,
    ) -> Result<Vec<f32>, String> {
        let cfg = self.cfg();
        cache.check(cfg, tokens.len())?;
        super::config::tokens_in_vocab(tokens, cfg.vocab)?;
        let checkpoint = cache.len;
        let res = match self {
            DenseModel::Fp { cfg, params } => {
                forward_fp_impl(cfg, params, tokens, scratch, Some(&mut *cache), par)
            }
            DenseModel::Quant { cfg, params, a_bits } => forward_quant_impl(
                cfg,
                params,
                *a_bits,
                tokens,
                None,
                scratch,
                Some(&mut *cache),
                par,
            ),
        };
        if res.is_err() {
            cache.truncate(checkpoint);
        }
        res
    }
}

// ---------------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------------

/// Matmul tile sizes shared by [`matmul_into`] and [`matmul_cols`].
const MM_BK: usize = 64;
const MM_BJ: usize = 128;

/// `out[T,H] = x[T,C] @ w[C,H]` with f64 accumulation, cache-blocked
/// over `(k, j)` like `transform::Mat::matmul`: a `MM_BK × MM_BJ` tile
/// of `w` stays cache-resident while every token row sweeps it, cutting
/// B-matrix traffic by ~`MM_BK`× once `w` outgrows L2. Per output
/// element the summation order is k ascending — `kb` blocks ascend and
/// `k` ascends within each block — identical to the naive loop, so
/// results are bit-for-bit unchanged. Zero activations are skipped
/// (padding rows stay cheap).
pub fn matmul_into(
    x: &[f32],
    w: &[f32],
    t: usize,
    c: usize,
    h: usize,
    out: &mut Vec<f32>,
    acc: &mut Vec<f64>,
) {
    debug_assert_eq!(x.len(), t * c);
    debug_assert_eq!(w.len(), c * h);
    acc.clear();
    acc.resize(t * h, 0.0);
    matmul_core(x, w, t, c, h, 0, h, acc);
    out.clear();
    out.extend(acc.iter().map(|&a| a as f32));
}

/// The one tiled kernel both matmul entry points run: accumulate
/// `x[T,C] @ w[C, jb0..je0]` into `acc` (packed `[T, je0-jb0]`, assumed
/// zeroed). Keeping a single copy is what makes the "sharding never
/// changes bits" guarantee structural: per output element the f64
/// summation order is k-ascending (`kb` blocks ascend, `k` ascends
/// within each) regardless of the `(jb0, je0)` column range, so the
/// full-range call and any column partition agree bit for bit.
#[allow(clippy::too_many_arguments)]
fn matmul_core(
    x: &[f32],
    w: &[f32],
    t: usize,
    c: usize,
    h: usize,
    jb0: usize,
    je0: usize,
    acc: &mut [f64],
) {
    let wj = je0 - jb0;
    for kb in (0..c).step_by(MM_BK) {
        let ke = (kb + MM_BK).min(c);
        for jb in (jb0..je0).step_by(MM_BJ) {
            let je = (jb + MM_BJ).min(je0);
            for row in 0..t {
                let xr = &x[row * c + kb..row * c + ke];
                let arow = &mut acc[row * wj + (jb - jb0)..row * wj + (je - jb0)];
                for (k, &xv) in xr.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let xv = xv as f64;
                    let wrow = &w[(kb + k) * h + jb..(kb + k) * h + je];
                    for (a, &wv) in arow.iter_mut().zip(wrow) {
                        *a += xv * wv as f64;
                    }
                }
            }
        }
    }
}

/// Allocating wrapper around [`matmul_into`].
pub fn matmul(x: &[f32], w: &[f32], t: usize, c: usize, h: usize) -> Vec<f32> {
    let mut out = Vec::new();
    let mut acc = Vec::new();
    matmul_into(x, w, t, c, h, &mut out, &mut acc);
    out
}

/// Column-restricted [`matmul_into`]: `x[T,C] @ w[C, jb0..je0]`,
/// returned packed as `[T, je0-jb0]` — the form one decode shard runs.
/// Shares [`matmul_core`] with the full matmul, so any column partition
/// reassembles bit-identically by construction.
#[allow(clippy::too_many_arguments)]
fn matmul_cols(
    x: &[f32],
    w: &[f32],
    t: usize,
    c: usize,
    h: usize,
    jb0: usize,
    je0: usize,
) -> Vec<f32> {
    let mut acc = vec![0f64; t * (je0 - jb0)];
    matmul_core(x, w, t, c, h, jb0, je0, &mut acc);
    acc.iter().map(|&a| a as f32).collect()
}

/// One forward linear, serial or column-sharded over the decode pool.
/// Sharding cannot change bits (see [`matmul_cols`]), so callers may
/// freely mix sharded and serial execution of the same model.
///
/// Shard outputs and per-shard f64 accumulators are freshly allocated
/// (results must be owned to cross the pool boundary); that cost is
/// small next to the `O(t·c·cols)` multiply each shard amortizes, and
/// the serial path stays allocation-free through `scratch`.
#[allow(clippy::too_many_arguments)]
fn mm(
    par: Option<&DecodePar>,
    x: &[f32],
    w: &[f32],
    t: usize,
    c: usize,
    h: usize,
    out: &mut Vec<f32>,
    acc: &mut Vec<f64>,
) -> Result<(), String> {
    if let Some(p) = par {
        if let Some(ranges) = shard_ranges(h, MIN_SHARD_COLS, p.shards) {
            let jobs: Vec<ShardJob<'_>> = ranges
                .iter()
                .map(|&(jb, je)| {
                    let (x, w) = (&*x, &*w);
                    Box::new(move || matmul_cols(x, w, t, c, h, jb, je)) as ShardJob<'_>
                })
                .collect();
            let parts = p.runner.run(jobs)?;
            out.clear();
            out.resize(t * h, 0.0);
            for (part, &(jb, je)) in parts.iter().zip(&ranges) {
                let wj = je - jb;
                for row in 0..t {
                    out[row * h + jb..row * h + je]
                        .copy_from_slice(&part[row * wj..(row + 1) * wj]);
                }
            }
            return Ok(());
        }
    }
    matmul_into(x, w, t, c, h, out, acc);
    Ok(())
}

/// Quant-path linear: the packed fused kernel when the variant runs in
/// [`KernelMode::Fast`] and a packed form exists (serial or
/// column-sharded — the packed kernel's column partitions reassemble to
/// identical values by construction), the dense reference [`mm`]
/// otherwise. Callers pass `packed: None` in reference mode, so that
/// path executes byte-for-byte the pre-kernel-layer code.
#[allow(clippy::too_many_arguments)]
fn mm_quant(
    par: Option<&DecodePar>,
    packed: Option<&PackedLinear>,
    x: &[f32],
    w: &[f32],
    t: usize,
    c: usize,
    h: usize,
    out: &mut Vec<f32>,
    acc: &mut Vec<f64>,
) -> Result<(), String> {
    let pl = match packed {
        Some(pl) => pl,
        None => return mm(par, x, w, t, c, h, out, acc),
    };
    debug_assert_eq!((pl.c, pl.h), (c, h));
    if let Some(p) = par {
        if let Some(ranges) = shard_ranges(h, MIN_SHARD_COLS, p.shards) {
            let jobs: Vec<ShardJob<'_>> = ranges
                .iter()
                .map(|&(jb, je)| {
                    let x = &*x;
                    Box::new(move || packed_matmul_cols(x, pl, t, jb, je)) as ShardJob<'_>
                })
                .collect();
            let parts = p.runner.run(jobs)?;
            out.clear();
            out.resize(t * h, 0.0);
            for (part, &(jb, je)) in parts.iter().zip(&ranges) {
                let wj = je - jb;
                for row in 0..t {
                    out[row * h + jb..row * h + je]
                        .copy_from_slice(&part[row * wj..(row + 1) * wj]);
                }
            }
            return Ok(());
        }
    }
    packed_matmul_into(x, pl, t, out, acc);
    Ok(())
}

fn rmsnorm_rows(x: &mut [f32], d: usize, eps: f64) {
    for row in x.chunks_mut(d) {
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let r = 1.0 / (ms + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v as f64 * r) as f32;
        }
    }
}

fn scale_rows(x: &mut [f32], scale: &[f32]) {
    let d = scale.len();
    for row in x.chunks_mut(d) {
        for (v, &s) in row.iter_mut().zip(scale) {
            *v *= s;
        }
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Symmetric per-group activation fake-quant (matches kernels/quant.py).
fn act_fake_quant(x: &mut [f32], group: usize, bits: u32) {
    let qmax = ((1u32 << (bits - 1)) - 1) as f32;
    for chunk in x.chunks_mut(group) {
        let absmax = chunk.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let mut scale = ACT_CLIP * absmax / qmax;
        if scale == 0.0 {
            scale = 1.0;
        }
        for v in chunk.iter_mut() {
            let q = (*v / scale).round().clamp(-qmax, qmax);
            *v = q * scale;
        }
    }
}

/// Orthonormal in-place FWHT over an f32 slice (shared with the fast
/// kernel layer's structured-rotation application).
pub(crate) fn fwht_f32(x: &mut [f32]) {
    let n = x.len();
    let mut h = 1;
    while h < n {
        for start in (0..n).step_by(2 * h) {
            for i in start..start + h {
                let a = x[i];
                let b = x[i + h];
                x[i] = a + b;
                x[i + h] = a - b;
            }
        }
        h *= 2;
    }
    let s = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// RoPE tables into scratch: `(cos, sin)` each `[T, head_dim/2]`, for
/// the `t` **absolute** positions `pos0..pos0+t` (row `r` holds position
/// `pos0 + r`). Each entry is computed independently, so a decode step's
/// single-row table is bit-identical to the matching row of a full
/// prefix table.
fn rope_tables_into(
    pos0: usize,
    t: usize,
    head_dim: usize,
    base: f64,
    cos: &mut Vec<f32>,
    sin: &mut Vec<f32>,
) {
    let half = head_dim / 2;
    cos.clear();
    cos.resize(t * half, 0.0);
    sin.clear();
    sin.resize(t * half, 0.0);
    for rel in 0..t {
        for i in 0..half {
            let inv = 1.0 / base.powf(i as f64 / half as f64);
            let angle = (pos0 + rel) as f64 * inv;
            cos[rel * half + i] = angle.cos() as f32;
            sin[rel * half + i] = angle.sin() as f32;
        }
    }
}

/// Apply RoPE in-place to `[T, n_heads, head_dim]` (paired halves layout,
/// matching model.py::apply_rope).
fn apply_rope(x: &mut [f32], t: usize, n_heads: usize, dh: usize, cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    for pos in 0..t {
        for head in 0..n_heads {
            let off = (pos * n_heads + head) * dh;
            for i in 0..half {
                let c = cos[pos * half + i];
                let s = sin[pos * half + i];
                let x1 = x[off + i];
                let x2 = x[off + half + i];
                x[off + i] = x1 * c - x2 * s;
                x[off + half + i] = x1 * s + x2 * c;
            }
        }
    }
}

/// Fast-path head rotation: per-head FWHT + signs via a verified
/// [`R1Desc`] instead of the dense `[dh, dh]` matmul of
/// [`rotate_heads`]. Same rotation, O(dh log dh) per head.
fn rotate_heads_desc(
    x: &mut [f32],
    t: usize,
    n_heads: usize,
    dh: usize,
    desc: &R1Desc,
    tmp: &mut Vec<f32>,
) {
    for pos in 0..t {
        for head in 0..n_heads {
            let off = (pos * n_heads + head) * dh;
            desc.forward_row(&mut x[off..off + dh], tmp);
        }
    }
}

/// Per-head right-multiplication by `r [dh, dh]` over `[T, heads, dh]`.
fn rotate_heads(x: &mut [f32], t: usize, n_heads: usize, dh: usize, r: &[f32], tmp: &mut Vec<f32>) {
    tmp.clear();
    tmp.resize(dh, 0.0);
    for pos in 0..t {
        for head in 0..n_heads {
            let off = (pos * n_heads + head) * dh;
            for (j, tv) in tmp.iter_mut().enumerate() {
                let mut acc = 0f64;
                for k in 0..dh {
                    acc += x[off + k] as f64 * r[k * dh + j] as f64;
                }
                *tv = acc as f32;
            }
            x[off..off + dh].copy_from_slice(tmp);
        }
    }
}

/// Causal attention over `[T, heads, dh]` tensors → same layout,
/// written into `out` (fully overwritten). Test-only convenience: the
/// forward paths call [`attention_cached`] / [`attention_heads_packed`].
#[cfg(test)]
fn attention_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    t: usize,
    n_heads: usize,
    dh: usize,
    out: &mut Vec<f32>,
    scores: &mut Vec<f64>,
) {
    attention_heads_packed(q, k, v, t, 0, n_heads, dh, 0, n_heads, out, scores);
}

/// Causal attention core over the contiguous head range `h0..h1`:
/// queries are the `t` chunk rows `[t, n_heads, dh]` at absolute
/// positions `pos0..pos0+t`; keys/values span **all** `pos0 + t` cached
/// rows. Output is packed `[t, h1-h0, dh]` (the standard layout when
/// the range covers every head). Per output element the f64
/// accumulation order is key-ascending and independent of `(h0, h1)`,
/// so head-sharded attention reassembles bit-identically to the serial
/// pass, and a cached decode step (`pos0 > 0`) matches the same query
/// row of a full-prefix pass exactly.
#[allow(clippy::too_many_arguments)]
fn attention_heads_packed(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    t: usize,
    pos0: usize,
    n_heads: usize,
    dh: usize,
    h0: usize,
    h1: usize,
    out: &mut Vec<f32>,
    scores: &mut Vec<f64>,
) {
    let lh = h1 - h0;
    out.clear();
    out.resize(t * lh * dh, 0.0);
    scores.clear();
    scores.resize(pos0 + t, 0.0);
    let scale = 1.0 / (dh as f64).sqrt();
    for head in h0..h1 {
        for qi in 0..t {
            let aq = pos0 + qi;
            let qoff = (qi * n_heads + head) * dh;
            let mut maxs = f64::NEG_INFINITY;
            for (ki, sc) in scores.iter_mut().enumerate().take(aq + 1) {
                let koff = (ki * n_heads + head) * dh;
                let mut dot = 0f64;
                for d in 0..dh {
                    dot += q[qoff + d] as f64 * k[koff + d] as f64;
                }
                *sc = dot * scale;
                maxs = maxs.max(*sc);
            }
            let mut denom = 0f64;
            for sc in scores.iter_mut().take(aq + 1) {
                *sc = (*sc - maxs).exp();
                denom += *sc;
            }
            let ooff = (qi * lh + (head - h0)) * dh;
            for d in 0..dh {
                let mut acc = 0f64;
                for (ki, sc) in scores.iter().enumerate().take(aq + 1) {
                    let voff = (ki * n_heads + head) * dh;
                    acc += sc * v[voff + d] as f64;
                }
                out[ooff + d] = (acc / denom) as f32;
            }
        }
    }
}

/// Cached-path attention: chunk queries against the full cached K/V,
/// serial or head-sharded over the decode pool (bit-transparent either
/// way — see [`attention_heads_packed`]).
#[allow(clippy::too_many_arguments)]
fn attention_cached(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    t: usize,
    pos0: usize,
    n_heads: usize,
    dh: usize,
    out: &mut Vec<f32>,
    scores: &mut Vec<f64>,
    par: Option<&DecodePar>,
) -> Result<(), String> {
    if let Some(p) = par {
        if let Some(ranges) = shard_ranges(n_heads, 1, p.shards) {
            let jobs: Vec<ShardJob<'_>> = ranges
                .iter()
                .map(|&(h0, h1)| {
                    let (q, k, v) = (&*q, &*k, &*v);
                    Box::new(move || {
                        let (mut part, mut sc) = (Vec::new(), Vec::new());
                        attention_heads_packed(
                            q, k, v, t, pos0, n_heads, dh, h0, h1, &mut part, &mut sc,
                        );
                        part
                    }) as ShardJob<'_>
                })
                .collect();
            let parts = p.runner.run(jobs)?;
            out.clear();
            out.resize(t * n_heads * dh, 0.0);
            for (part, &(h0, h1)) in parts.iter().zip(&ranges) {
                let lh = h1 - h0;
                for qi in 0..t {
                    for head in h0..h1 {
                        let src = (qi * lh + (head - h0)) * dh;
                        let dst = (qi * n_heads + head) * dh;
                        out[dst..dst + dh].copy_from_slice(&part[src..src + dh]);
                    }
                }
            }
            return Ok(());
        }
    }
    attention_heads_packed(q, k, v, t, pos0, n_heads, dh, 0, n_heads, out, scores);
    Ok(())
}

/// Gather embedding rows for `tokens` into `x` `[T, d]`.
fn embed_into(x: &mut Vec<f32>, embed: &[f32], tokens: &[i32], d: usize) {
    x.clear();
    for &tok in tokens {
        let tok = tok as usize;
        x.extend_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
}

/// `x += y` elementwise.
fn add_assign(x: &mut [f32], y: &[f32]) {
    for (xv, yv) in x.iter_mut().zip(y) {
        *xv += yv;
    }
}

// ---------------------------------------------------------------------------
// fp forward (training layout)
// ---------------------------------------------------------------------------

/// fp forward, full-sequence (`kv: None`) or incremental (`kv: Some`,
/// where `tokens` is the chunk appended at positions `cache.len()..`).
/// With `kv: None` and `par: None` this is the exact pre-cache serial
/// pass — every branch below degenerates to the original straight-line
/// code, which is why one implementation can back both paths without a
/// parity gap.
fn forward_fp_impl(
    cfg: &ModelCfg,
    p: &FpParams,
    tokens: &[i32],
    scratch: &mut ForwardScratch,
    mut kv: Option<&mut KvCache>,
    par: Option<&DecodePar>,
) -> Result<Vec<f32>, String> {
    let (t, d) = (tokens.len(), cfg.d_model);
    let (nh, dh) = (cfg.n_heads, cfg.head_dim());
    let pos0 = kv.as_deref().map_or(0, |c| c.len);
    let ForwardScratch { x, h, q, k, v, o, g, u, z, zd, acc, scores, cos, sin, .. } = scratch;
    embed_into(x, &p.embed, tokens, d);
    rope_tables_into(pos0, t, dh, cfg.rope_base, cos, sin);
    for (l, layer) in p.layers.iter().enumerate() {
        h.clear();
        h.extend_from_slice(x);
        rmsnorm_rows(h, d, cfg.norm_eps);
        scale_rows(h, &layer.ln1);
        mm(par, h, &layer.wq, t, d, d, q, acc)?;
        mm(par, h, &layer.wk, t, d, d, k, acc)?;
        mm(par, h, &layer.wv, t, d, d, v, acc)?;
        apply_rope(q, t, nh, dh, cos, sin);
        apply_rope(k, t, nh, dh, cos, sin);
        match kv.as_deref_mut() {
            Some(cache) => {
                cache.append_layer(l, k, v);
                let (ck, cv) = cache.layer_view(l, pos0 + t);
                attention_cached(q, ck, cv, t, pos0, nh, dh, o, scores, par)?;
            }
            None => attention_cached(q, k, v, t, 0, nh, dh, o, scores, par)?,
        }
        mm(par, o, &layer.wo, t, d, d, zd, acc)?;
        add_assign(x, zd);
        h.clear();
        h.extend_from_slice(x);
        rmsnorm_rows(h, d, cfg.norm_eps);
        scale_rows(h, &layer.ln2);
        mm(par, h, &layer.wgate, t, d, cfg.d_ffn, g, acc)?;
        mm(par, h, &layer.wup, t, d, cfg.d_ffn, u, acc)?;
        z.clear();
        z.extend(g.iter().zip(u.iter()).map(|(&gv, &uv)| silu(gv) * uv));
        mm(par, z, &layer.wdown, t, cfg.d_ffn, d, zd, acc)?;
        add_assign(x, zd);
    }
    rmsnorm_rows(x, d, cfg.norm_eps);
    scale_rows(x, &p.ln_f);
    let mut logits = Vec::new();
    mm(par, x, &p.lm_head, t, d, cfg.vocab, &mut logits, acc)?;
    if let Some(cache) = kv {
        cache.len += t;
    }
    Ok(logits)
}

// ---------------------------------------------------------------------------
// rotated/quantized forward (deployed layout)
// ---------------------------------------------------------------------------

/// Rotated/quantized forward with an [`ActivationTap`] observing every
/// linear's input matrix (calibration capture). With `a_bits = None` on
/// fused-but-unquantized params the tapped activations are exactly the
/// rotated-basis fp activations (Fig.-1 equivalence).
pub fn forward_quant_tapped(
    cfg: &ModelCfg,
    p: &QuantParams,
    a_bits: Option<u32>,
    tokens: &[i32],
    tap: &mut dyn ActivationTap,
) -> Vec<f32> {
    forward_quant_impl(cfg, p, a_bits, tokens, Some(tap), &mut ForwardScratch::new(), None, None)
        .expect("serial uncached forward is infallible")
}

/// [`forward_quant_tapped`] with caller-owned scratch — the form the
/// pooled calibration capture runs so long-lived workers allocate
/// nothing per sequence.
pub fn forward_quant_tapped_with(
    cfg: &ModelCfg,
    p: &QuantParams,
    a_bits: Option<u32>,
    tokens: &[i32],
    tap: &mut dyn ActivationTap,
    scratch: &mut ForwardScratch,
) -> Vec<f32> {
    forward_quant_impl(cfg, p, a_bits, tokens, Some(tap), scratch, None, None)
        .expect("serial uncached forward is infallible")
}

/// Rotated/quantized forward, full-sequence (`kv: None`) or incremental
/// (`kv: Some`, `tokens` = the chunk at positions `cache.len()..`). See
/// [`forward_fp_impl`] — same unification, plus the rotated-path
/// specifics: cached keys are post-RoPE *and* post-R3, and the online
/// per-layer R4 override runs on the chunk's FFN activations exactly as
/// in the full pass (R4 lives upstream of `wdown`, never in the cache).
#[allow(clippy::too_many_arguments)]
fn forward_quant_impl(
    cfg: &ModelCfg,
    p: &QuantParams,
    a_bits: Option<u32>,
    tokens: &[i32],
    mut tap: Option<&mut dyn ActivationTap>,
    scratch: &mut ForwardScratch,
    mut kv: Option<&mut KvCache>,
    par: Option<&DecodePar>,
) -> Result<Vec<f32>, String> {
    let (t, d) = (tokens.len(), cfg.d_model);
    let (nh, dh) = (cfg.n_heads, cfg.head_dim());
    let grp = cfg.group;
    let pos0 = kv.as_deref().map_or(0, |c| c.len);
    let ForwardScratch { x, xt, h, q, k, v, o, g, u, z, zd, acc, scores, cos, sin, head_tmp } =
        scratch;
    // Fast mode routes linears through the packed fused kernel and
    // structured rotations through FWHT descriptors; with it off every
    // `pk(..)` is `None` and the loop below is the exact reference pass.
    let fast = p.kernels == KernelMode::Fast;
    embed_into(x, &p.embed, tokens, d);
    rope_tables_into(pos0, t, dh, cfg.rope_base, cos, sin);
    for (l, layer) in p.layers.iter().enumerate() {
        // Heterogeneous plans: transition the residual stream from the
        // previous layer's R1 basis into this layer's (`x ← x R_{l-1}ᵀ R_l`).
        if let Some(tr) = &layer.basis_change {
            match &layer.basis_fast {
                Some(bf) if fast => bf.apply_rows(x, head_tmp),
                _ => {
                    mm(par, x, tr, t, d, d, xt, acc)?;
                    std::mem::swap(x, xt);
                }
            }
        }
        let w = |name: &str| layer.dense[name].as_slice();
        let pk = |name: &str| if fast { layer.packed.get(name) } else { None };
        h.clear();
        h.extend_from_slice(x);
        rmsnorm_rows(h, d, cfg.norm_eps);
        scale_rows(h, &layer.ascale_attn);
        if let Some(bits) = a_bits {
            act_fake_quant(h, grp, bits);
        }
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::AttnIn, h, d);
        }
        mm_quant(par, pk("wq"), h, w("wq"), t, d, d, q, acc)?;
        mm_quant(par, pk("wk"), h, w("wk"), t, d, d, k, acc)?;
        mm_quant(par, pk("wv"), h, w("wv"), t, d, d, v, acc)?;
        apply_rope(q, t, nh, dh, cos, sin);
        apply_rope(k, t, nh, dh, cos, sin);
        match &p.r3_fast {
            Some(desc) if fast => {
                rotate_heads_desc(q, t, nh, dh, desc, head_tmp);
                rotate_heads_desc(k, t, nh, dh, desc, head_tmp);
            }
            _ => {
                rotate_heads(q, t, nh, dh, &p.r3, head_tmp);
                rotate_heads(k, t, nh, dh, &p.r3, head_tmp);
            }
        }
        match kv.as_deref_mut() {
            Some(cache) => {
                cache.append_layer(l, k, v);
                let (ck, cv) = cache.layer_view(l, pos0 + t);
                attention_cached(q, ck, cv, t, pos0, nh, dh, o, scores, par)?;
            }
            None => attention_cached(q, k, v, t, 0, nh, dh, o, scores, par)?,
        }
        scale_rows(o, &layer.ascale_o);
        if let Some(bits) = a_bits {
            act_fake_quant(o, grp, bits);
        }
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::OIn, o, d);
        }
        mm_quant(par, pk("wo"), o, w("wo"), t, d, d, zd, acc)?;
        add_assign(x, zd);
        h.clear();
        h.extend_from_slice(x);
        rmsnorm_rows(h, d, cfg.norm_eps);
        scale_rows(h, &layer.ascale_ffn);
        if let Some(bits) = a_bits {
            act_fake_quant(h, grp, bits);
        }
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::FfnIn, h, d);
        }
        mm_quant(par, pk("wgate"), h, w("wgate"), t, d, cfg.d_ffn, g, acc)?;
        mm_quant(par, pk("wup"), h, w("wup"), t, d, cfg.d_ffn, u, acc)?;
        z.clear();
        z.extend(g.iter().zip(u.iter()).map(|(&gv, &uv)| silu(gv) * uv));
        // Online R4: fast (grouped) Hadamard + signs — the L1 kernel's
        // math. A heterogeneous plan overrides kind/signs per layer; the
        // LH block size is carried by the sign-vector length (legacy
        // variants store `group` signs, plans may pick any valid block).
        let (r4_kind, r4_signs) = match &layer.r4 {
            Some(ov) => (ov.kind, ov.signs.as_slice()),
            None => (p.r4_kind, p.r4_signs.as_slice()),
        };
        match r4_kind {
            R4Kind::GH => {
                for row in z.chunks_mut(cfg.d_ffn) {
                    fwht_f32(row);
                    for (zv, &s) in row.iter_mut().zip(r4_signs) {
                        *zv *= s;
                    }
                }
            }
            R4Kind::LH => {
                let blk = r4_signs.len();
                for row in z.chunks_mut(cfg.d_ffn) {
                    for chunk in row.chunks_mut(blk) {
                        fwht_f32(chunk);
                        for (zv, &s) in chunk.iter_mut().zip(r4_signs) {
                            *zv *= s;
                        }
                    }
                }
            }
        }
        scale_rows(z, &layer.ascale_down);
        if let Some(bits) = a_bits {
            act_fake_quant(z, grp, bits);
        }
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::DownIn, z, cfg.d_ffn);
        }
        mm_quant(par, pk("wdown"), z, w("wdown"), t, cfg.d_ffn, d, zd, acc)?;
        add_assign(x, zd);
    }
    rmsnorm_rows(x, d, cfg.norm_eps);
    let mut logits = Vec::new();
    mm(par, x, &p.lm_head, t, d, cfg.vocab, &mut logits, acc)?;
    if let Some(cache) = kv {
        cache.len += t;
    }
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_f32_matches_f64() {
        let mut a = vec![1.0f32, -2.0, 3.0, 0.5, -1.5, 2.5, 0.0, 4.0];
        let mut b: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        fwht_f32(&mut a);
        crate::transform::fwht(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((*x as f64 - y).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_is_causal() {
        // Changing a future value must not affect earlier outputs.
        let (t, nh, dh) = (4, 1, 4);
        let mut q = vec![0.1f32; t * nh * dh];
        let k = vec![0.2f32; t * nh * dh];
        let mut v: Vec<f32> = (0..t * nh * dh).map(|i| i as f32 * 0.01).collect();
        for (i, qv) in q.iter_mut().enumerate() {
            *qv += (i % 3) as f32 * 0.05;
        }
        let attn = |q: &[f32], k: &[f32], v: &[f32]| {
            let (mut out, mut scores) = (Vec::new(), Vec::new());
            attention_into(q, k, v, t, nh, dh, &mut out, &mut scores);
            out
        };
        let out1 = attn(&q, &k, &v);
        for d in 0..dh {
            v[(t - 1) * dh + d] = 99.0; // mutate last position's value
        }
        let out2 = attn(&q, &k, &v);
        assert_eq!(&out1[..(t - 1) * dh], &out2[..(t - 1) * dh]);
        assert_ne!(&out1[(t - 1) * dh..], &out2[(t - 1) * dh..]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let mut x = vec![3.0f32, -4.0]; // rms = sqrt(12.5)
        rmsnorm_rows(&mut x, 2, 0.0);
        let rms: f32 = (x.iter().map(|v| v * v).sum::<f32>() / 2.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn act_fake_quant_reduces_resolution() {
        let mut x: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.1).collect();
        let orig = x.clone();
        act_fake_quant(&mut x, 32, 4);
        // Values change but stay within the clip envelope.
        assert!(x.iter().zip(&orig).any(|(a, b)| a != b));
        let m0 = orig.iter().fold(0f32, |m, &v| m.max(v.abs()));
        assert!(x.iter().all(|&v| v.abs() <= m0 + 1e-6));
    }

    #[test]
    fn matmul_small_known() {
        // [1,2;3,4] @ [1,0;0,1] = same
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&x, &w, 2, 2, 2), x);
    }

    /// The blocked matmul must agree bit-for-bit with the straight
    /// k-ascending reference at tile-unaligned sizes — the invariant the
    /// "same logits regardless of batching" guarantee rests on.
    #[test]
    fn blocked_matmul_bit_identical_to_naive() {
        let naive = |x: &[f32], w: &[f32], t: usize, c: usize, h: usize| -> Vec<f32> {
            let mut out = vec![0f32; t * h];
            for row in 0..t {
                let mut acc = vec![0f64; h];
                for (kk, &xv) in x[row * c..(row + 1) * c].iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    for (a, &wv) in acc.iter_mut().zip(&w[kk * h..(kk + 1) * h]) {
                        *a += xv as f64 * wv as f64;
                    }
                }
                for (ov, &a) in out[row * h..(row + 1) * h].iter_mut().zip(&acc) {
                    *ov = a as f32;
                }
            }
            out
        };
        let mut rng = crate::rng::SplitMix64::new(17);
        for (t, c, h) in [(3, 70, 130), (5, 64, 128), (1, 200, 7), (4, 1, 300)] {
            let x: Vec<f32> = (0..t * c).map(|_| rng.next_normal() as f32).collect();
            let w: Vec<f32> = (0..c * h).map(|_| rng.next_normal() as f32).collect();
            let fast = matmul(&x, &w, t, c, h);
            let slow = naive(&x, &w, t, c, h);
            assert_eq!(fast.len(), slow.len());
            for (a, b) in fast.iter().zip(&slow) {
                assert_eq!(a.to_bits(), b.to_bits(), "blocked matmul is not bit-identical");
            }
        }
    }

    #[test]
    fn shard_ranges_cover_exactly_once() {
        assert_eq!(shard_ranges(10, 32, 4), None, "too small to shard");
        assert_eq!(shard_ranges(64, 32, 1), None, "one worker never shards");
        let r = shard_ranges(100, 32, 4).unwrap();
        assert_eq!(r.len(), 3); // 100/32 = 3 shards
        assert_eq!(r.first().unwrap().0, 0);
        assert_eq!(r.last().unwrap().1, 100);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
        }
        let r = shard_ranges(4, 1, 8).unwrap();
        assert_eq!(r, vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
    }

    #[test]
    fn matmul_cols_partition_reassembles_bit_identical() {
        let mut rng = crate::rng::SplitMix64::new(23);
        let (t, c, h) = (3, 70, 130);
        let x: Vec<f32> = (0..t * c).map(|_| rng.next_normal() as f32).collect();
        let w: Vec<f32> = (0..c * h).map(|_| rng.next_normal() as f32).collect();
        let full = matmul(&x, &w, t, c, h);
        for splits in [vec![(0, 130)], vec![(0, 50), (50, 130)], vec![(0, 1), (1, 64), (64, 130)]]
        {
            let mut out = vec![0f32; t * h];
            for &(jb, je) in &splits {
                let part = matmul_cols(&x, &w, t, c, h, jb, je);
                let wj = je - jb;
                for row in 0..t {
                    out[row * h + jb..row * h + je]
                        .copy_from_slice(&part[row * wj..(row + 1) * wj]);
                }
            }
            for (a, b) in out.iter().zip(&full) {
                assert_eq!(a.to_bits(), b.to_bits(), "column partition changed bits");
            }
        }
    }

    fn kv_test_model() -> DenseModel {
        let cfg = ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        };
        DenseModel::Fp { cfg: cfg.clone(), params: FpParams::synthetic(&cfg, 21) }
    }

    /// The decode-path invariant: prefill + per-token decode produces,
    /// at every step, logits bit-identical to a full re-forward of the
    /// whole prefix. (Plan-kind coverage lives in `tests/proptests.rs`.)
    #[test]
    fn cached_decode_bit_identical_to_full_forward() {
        let model = kv_test_model();
        let seq: Vec<i32> = (0..12).map(|i| ((i * 13 + 5) % 64) as i32).collect();
        let prompt_len = 5;
        let mut cache = KvCache::new(model.cfg(), seq.len());
        let mut scratch = ForwardScratch::new();
        let prefill = model.forward_cached(&seq[..prompt_len], &mut cache, &mut scratch).unwrap();
        let full = model.forward(&seq[..prompt_len]);
        assert_eq!(prefill.len(), full.len());
        for (a, b) in prefill.iter().zip(&full) {
            assert_eq!(a.to_bits(), b.to_bits(), "prefill diverged from full forward");
        }
        let v = model.cfg().vocab;
        for step in prompt_len..seq.len() {
            let got = model.forward_cached(&seq[step..step + 1], &mut cache, &mut scratch).unwrap();
            let full = model.forward(&seq[..step + 1]);
            let want = &full[step * v..(step + 1) * v];
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(want) {
                assert_eq!(a.to_bits(), b.to_bits(), "decode step {step} diverged");
            }
            assert_eq!(cache.len(), step + 1);
        }
    }

    /// Sharded execution (column-split linears, head-split attention)
    /// must reproduce the serial bits for any shard bound.
    #[test]
    fn sharded_cached_forward_bit_identical_to_serial() {
        struct InlineRunner;
        impl ShardRunner for InlineRunner {
            fn run<'env>(&self, jobs: Vec<ShardJob<'env>>) -> Result<Vec<Vec<f32>>, String> {
                Ok(jobs.into_iter().map(|j| j()).collect())
            }
        }
        let model = kv_test_model();
        let seq: Vec<i32> = (0..9).map(|i| ((i * 7 + 2) % 64) as i32).collect();
        let serial = {
            let mut cache = KvCache::new(model.cfg(), seq.len());
            let mut scratch = ForwardScratch::new();
            let mut out = model.forward_cached(&seq[..4], &mut cache, &mut scratch).unwrap();
            for step in 4..seq.len() {
                out = model.forward_cached(&seq[step..step + 1], &mut cache, &mut scratch).unwrap();
            }
            out
        };
        for shards in [2, 3, 8] {
            let par = DecodePar { runner: &InlineRunner, shards };
            let mut cache = KvCache::new(model.cfg(), seq.len());
            let mut scratch = ForwardScratch::new();
            let mut out = model
                .forward_cached_par(&seq[..4], &mut cache, &mut scratch, Some(&par))
                .unwrap();
            for step in 4..seq.len() {
                out = model
                    .forward_cached_par(&seq[step..step + 1], &mut cache, &mut scratch, Some(&par))
                    .unwrap();
            }
            assert_eq!(out.len(), serial.len());
            for (a, b) in out.iter().zip(&serial) {
                assert_eq!(a.to_bits(), b.to_bits(), "sharding ({shards}) changed decode bits");
            }
        }
    }

    /// Misuse is an error, never a panic or a corrupted cache: overflow
    /// and bad token ids reject cleanly and leave the cache untouched.
    #[test]
    fn cached_forward_validates_and_rolls_back() {
        let model = kv_test_model();
        let mut cache = KvCache::new(model.cfg(), 4);
        let mut scratch = ForwardScratch::new();
        assert!(model.forward_cached(&[], &mut cache, &mut scratch).is_err());
        let err = model.forward_cached(&[1, 2, 3, 4, 5], &mut cache, &mut scratch).unwrap_err();
        assert!(err.contains("kv cache full"), "{err}");
        let err = model.forward_cached(&[1, 99], &mut cache, &mut scratch).unwrap_err();
        assert!(err.contains("outside vocab"), "{err}");
        assert_eq!(cache.len(), 0, "failed calls must not grow the cache");
        model.forward_cached(&[1, 2, 3, 4], &mut cache, &mut scratch).unwrap();
        assert_eq!(cache.remaining(), 0);
        assert!(model.forward_cached(&[1], &mut cache, &mut scratch).is_err());
        cache.clear();
        assert_eq!(cache.len(), 0);
        assert!(model.forward_cached(&[1], &mut cache, &mut scratch).is_ok());
    }

    /// The block layout must be invisible to the math: a paged cache
    /// (blocks granted on demand, page smaller than any chunk boundary
    /// alignment) produces bit-identical prefill and decode logits to
    /// the contiguous cache.
    #[test]
    fn paged_cache_bit_identical_to_contiguous() {
        let model = kv_test_model();
        let cfg = model.cfg().clone();
        let seq: Vec<i32> = (0..11).map(|i| ((i * 17 + 3) % 64) as i32).collect();
        let mut contig = KvCache::new(&cfg, seq.len());
        let mut paged = KvCache::paged(&cfg, 4);
        assert!(paged.is_paged() && !contig.is_paged());
        assert_eq!(paged.page_size(), Some(4));
        let mut next_id = 0u32;
        let mut grant_until = |cache: &mut KvCache, want: usize| {
            while cache.capacity() < want {
                cache.grant(KvBlock::new(next_id, cfg.n_layers, 4, cfg.d_model)).unwrap();
                next_id += 1;
            }
        };
        let (mut s1, mut s2) = (ForwardScratch::new(), ForwardScratch::new());
        let a = model.forward_cached(&seq[..5], &mut contig, &mut s1).unwrap();
        grant_until(&mut paged, 5);
        let b = model.forward_cached(&seq[..5], &mut paged, &mut s2).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "paged prefill diverged");
        }
        for step in 5..seq.len() {
            let a = model.forward_cached(&seq[step..step + 1], &mut contig, &mut s1).unwrap();
            grant_until(&mut paged, step + 1);
            let b = model.forward_cached(&seq[step..step + 1], &mut paged, &mut s2).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "paged decode step {step} diverged");
            }
        }
        assert_eq!(paged.block_ids(), vec![0, 1, 2]);
        let blocks = paged.reclaim_blocks();
        assert_eq!(blocks.len(), 3);
        assert_eq!((paged.len(), paged.capacity()), (0, 0));
        assert!(contig.reclaim_blocks().is_empty(), "contig caches own no blocks");
    }

    /// Paged-cache boundary behaviour at block edges: failed chunks roll
    /// back without corrupting rows, overflow past granted capacity is a
    /// clean error, `clear` keeps the granted blocks and overwrites
    /// stale rows, and geometry-mismatched grants are rejected.
    #[test]
    fn paged_rollback_and_clear_at_block_edges() {
        let model = kv_test_model();
        let cfg = model.cfg().clone();
        let mut cache = KvCache::paged(&cfg, 4);
        for id in 0..2 {
            cache.grant(KvBlock::new(id, cfg.n_layers, 4, cfg.d_model)).unwrap();
        }
        let mut scratch = ForwardScratch::new();
        model.forward_cached(&[1, 2, 3, 4], &mut cache, &mut scratch).unwrap();
        assert_eq!(cache.remaining(), 4);
        // A failing chunk crossing the block edge must roll back cleanly.
        let err = model.forward_cached(&[5, 99], &mut cache, &mut scratch).unwrap_err();
        assert!(err.contains("outside vocab"), "{err}");
        assert_eq!(cache.len(), 4, "failed chunk must not grow the cache");
        // Overflow past granted capacity is "kv cache full", not a panic.
        let err = model.forward_cached(&[1, 1, 1, 1, 1], &mut cache, &mut scratch).unwrap_err();
        assert!(err.contains("kv cache full"), "{err}");
        // The next good chunk lands exactly where the failed one would
        // have — bit-identical to an uninterrupted contiguous run.
        let reference = {
            let mut c = KvCache::new(&cfg, 8);
            let mut s = ForwardScratch::new();
            model.forward_cached(&[1, 2, 3, 4], &mut c, &mut s).unwrap();
            model.forward_cached(&[5, 6], &mut c, &mut s).unwrap()
        };
        let got = model.forward_cached(&[5, 6], &mut cache, &mut scratch).unwrap();
        for (x, y) in got.iter().zip(&reference) {
            assert_eq!(x.to_bits(), y.to_bits(), "post-rollback decode diverged");
        }
        // clear keeps blocks and capacity; stale rows are overwritten.
        cache.clear();
        assert_eq!((cache.len(), cache.capacity()), (0, 8));
        model.forward_cached(&[1, 2, 3, 4], &mut cache, &mut scratch).unwrap();
        let again = model.forward_cached(&[5, 6], &mut cache, &mut scratch).unwrap();
        for (x, y) in again.iter().zip(&reference) {
            assert_eq!(x.to_bits(), y.to_bits(), "post-clear reuse diverged");
        }
        // Bad grants are rejected: wrong geometry, or a contiguous cache.
        let err = cache.grant(KvBlock::new(9, cfg.n_layers, 2, cfg.d_model)).unwrap_err();
        assert!(err.contains("geometry"), "{err}");
        let mut contig = KvCache::new(&cfg, 4);
        let err = contig.grant(KvBlock::new(9, cfg.n_layers, 4, cfg.d_model)).unwrap_err();
        assert!(err.contains("contiguous"), "{err}");
    }

    /// Scratch reuse must not change results: a warm scratch that just
    /// ran a different sequence yields the same bits as a fresh one.
    #[test]
    fn scratch_reuse_is_bit_transparent() {
        let cfg = ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        };
        let model = DenseModel::Fp { cfg: cfg.clone(), params: FpParams::synthetic(&cfg, 5) };
        let a: Vec<i32> = (0..9).map(|i| (i * 5 % 64) as i32).collect();
        let b: Vec<i32> = (0..14).map(|i| (i * 11 % 64) as i32).collect();
        let fresh = model.forward(&b);
        let mut scratch = ForwardScratch::new();
        let _ = model.forward_with(&a, &mut scratch); // warm with another length
        let warm = model.forward_with(&b, &mut scratch);
        assert_eq!(fresh.len(), warm.len());
        for (x, y) in fresh.iter().zip(&warm) {
            assert_eq!(x.to_bits(), y.to_bits(), "scratch reuse changed logits");
        }
    }
}
