//! Native reference forward pass (pure Rust, no PJRT).
//!
//! Mirrors `python/compile/model.py::forward_fp` / `forward_rotated` on
//! single sequences. Used to (a) cross-validate the PJRT path against an
//! independent implementation, (b) run the Fig.-1 rotation-invariance
//! cargo test, and (c) provide a PJRT-free eval fallback.

use super::config::{ModelCfg, R4Kind};
use super::weights::{FpParams, QuantParams};

/// A runnable dense model: fp checkpoint or dequantized variant.
pub enum DenseModel {
    Fp { cfg: ModelCfg, params: FpParams },
    Quant { cfg: ModelCfg, params: QuantParams, a_bits: Option<u32> },
}

const ACT_CLIP: f32 = 0.9;

// ---------------------------------------------------------------------------
// Activation taps (calibration capture)
// ---------------------------------------------------------------------------

/// Where in the rotated forward an activation tap fires: each site is
/// the exact input matrix one or more fused linears consume, **in the
/// basis that linear quantizes in** (after norms, activation scales and
/// fake-quant, immediately before the matmul).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapSite {
    /// Input of `wq`/`wk`/`wv`: post-norm residual stream, layer R1 basis.
    AttnIn,
    /// Input of `wo`: attention output in the B2/R3 head basis.
    OIn,
    /// Input of `wgate`/`wup`: post-norm residual stream, layer R1 basis.
    FfnIn,
    /// Input of `wdown`: FFN activation after the online R4 rotation.
    DownIn,
}

impl TapSite {
    pub const ALL: [TapSite; 4] =
        [TapSite::AttnIn, TapSite::OIn, TapSite::FfnIn, TapSite::DownIn];
}

/// Observer of per-linear input activations during
/// [`forward_quant_tapped`] — the hook the `calib` subsystem uses to
/// accumulate streaming `XᵀX` Hessians without copying activations.
pub trait ActivationTap {
    /// `rows` is a row-major `[T, width]` activation matrix.
    fn record(&mut self, layer: usize, site: TapSite, rows: &[f32], width: usize);
}

impl DenseModel {
    pub fn cfg(&self) -> &ModelCfg {
        match self {
            DenseModel::Fp { cfg, .. } => cfg,
            DenseModel::Quant { cfg, .. } => cfg,
        }
    }

    /// Forward a single token sequence → logits `[T, vocab]` (row-major).
    pub fn forward(&self, tokens: &[i32]) -> Vec<f32> {
        match self {
            DenseModel::Fp { cfg, params } => forward_fp(cfg, params, tokens),
            DenseModel::Quant { cfg, params, a_bits } => {
                forward_quant(cfg, params, *a_bits, tokens)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------------

/// `out[T,H] = x[T,C] @ w[C,H]` with f64 accumulation.
pub fn matmul(x: &[f32], w: &[f32], t: usize, c: usize, h: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), t * c);
    debug_assert_eq!(w.len(), c * h);
    let mut out = vec![0f32; t * h];
    for row in 0..t {
        let xr = &x[row * c..(row + 1) * c];
        let or = &mut out[row * h..(row + 1) * h];
        let mut acc = vec![0f64; h];
        for (k, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w[k * h..(k + 1) * h];
            let xv = xv as f64;
            for (a, &wv) in acc.iter_mut().zip(wr) {
                *a += xv * wv as f64;
            }
        }
        for (o, a) in or.iter_mut().zip(&acc) {
            *o = *a as f32;
        }
    }
    out
}

fn rmsnorm_rows(x: &mut [f32], d: usize, eps: f64) {
    for row in x.chunks_mut(d) {
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let r = 1.0 / (ms + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v as f64 * r) as f32;
        }
    }
}

fn scale_rows(x: &mut [f32], scale: &[f32]) {
    let d = scale.len();
    for row in x.chunks_mut(d) {
        for (v, &s) in row.iter_mut().zip(scale) {
            *v *= s;
        }
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Symmetric per-group activation fake-quant (matches kernels/quant.py).
fn act_fake_quant(x: &mut [f32], group: usize, bits: u32) {
    let qmax = ((1u32 << (bits - 1)) - 1) as f32;
    for chunk in x.chunks_mut(group) {
        let absmax = chunk.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let mut scale = ACT_CLIP * absmax / qmax;
        if scale == 0.0 {
            scale = 1.0;
        }
        for v in chunk.iter_mut() {
            let q = (*v / scale).round().clamp(-qmax, qmax);
            *v = q * scale;
        }
    }
}

/// Orthonormal in-place FWHT over an f32 slice.
fn fwht_f32(x: &mut [f32]) {
    let n = x.len();
    let mut h = 1;
    while h < n {
        for start in (0..n).step_by(2 * h) {
            for i in start..start + h {
                let a = x[i];
                let b = x[i + h];
                x[i] = a + b;
                x[i + h] = a - b;
            }
        }
        h *= 2;
    }
    let s = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// RoPE tables: `(cos, sin)` each `[T, head_dim/2]`.
fn rope_tables(t: usize, head_dim: usize, base: f64) -> (Vec<f32>, Vec<f32>) {
    let half = head_dim / 2;
    let mut cos = vec![0f32; t * half];
    let mut sin = vec![0f32; t * half];
    for pos in 0..t {
        for i in 0..half {
            let inv = 1.0 / base.powf(i as f64 / half as f64);
            let angle = pos as f64 * inv;
            cos[pos * half + i] = angle.cos() as f32;
            sin[pos * half + i] = angle.sin() as f32;
        }
    }
    (cos, sin)
}

/// Apply RoPE in-place to `[T, n_heads, head_dim]` (paired halves layout,
/// matching model.py::apply_rope).
fn apply_rope(x: &mut [f32], t: usize, n_heads: usize, dh: usize, cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    for pos in 0..t {
        for head in 0..n_heads {
            let off = (pos * n_heads + head) * dh;
            for i in 0..half {
                let c = cos[pos * half + i];
                let s = sin[pos * half + i];
                let x1 = x[off + i];
                let x2 = x[off + half + i];
                x[off + i] = x1 * c - x2 * s;
                x[off + half + i] = x1 * s + x2 * c;
            }
        }
    }
}

/// Per-head right-multiplication by `r [dh, dh]` over `[T, heads, dh]`.
fn rotate_heads(x: &mut [f32], t: usize, n_heads: usize, dh: usize, r: &[f32]) {
    let mut tmp = vec![0f32; dh];
    for pos in 0..t {
        for head in 0..n_heads {
            let off = (pos * n_heads + head) * dh;
            for (j, tv) in tmp.iter_mut().enumerate() {
                let mut acc = 0f64;
                for k in 0..dh {
                    acc += x[off + k] as f64 * r[k * dh + j] as f64;
                }
                *tv = acc as f32;
            }
            x[off..off + dh].copy_from_slice(&tmp);
        }
    }
}

/// Causal attention over `[T, heads, dh]` tensors → same layout.
fn attention(q: &[f32], k: &[f32], v: &[f32], t: usize, n_heads: usize, dh: usize) -> Vec<f32> {
    let mut out = vec![0f32; t * n_heads * dh];
    let scale = 1.0 / (dh as f64).sqrt();
    let mut scores = vec![0f64; t];
    for head in 0..n_heads {
        for qi in 0..t {
            let qoff = (qi * n_heads + head) * dh;
            let mut maxs = f64::NEG_INFINITY;
            for (ki, sc) in scores.iter_mut().enumerate().take(qi + 1) {
                let koff = (ki * n_heads + head) * dh;
                let mut dot = 0f64;
                for d in 0..dh {
                    dot += q[qoff + d] as f64 * k[koff + d] as f64;
                }
                *sc = dot * scale;
                maxs = maxs.max(*sc);
            }
            let mut denom = 0f64;
            for sc in scores.iter_mut().take(qi + 1) {
                *sc = (*sc - maxs).exp();
                denom += *sc;
            }
            let ooff = (qi * n_heads + head) * dh;
            for d in 0..dh {
                let mut acc = 0f64;
                for ki in 0..=qi {
                    let voff = (ki * n_heads + head) * dh;
                    acc += scores[ki] * v[voff + d] as f64;
                }
                out[ooff + d] = (acc / denom) as f32;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// fp forward (training layout)
// ---------------------------------------------------------------------------

fn forward_fp(cfg: &ModelCfg, p: &FpParams, tokens: &[i32]) -> Vec<f32> {
    let (t, d) = (tokens.len(), cfg.d_model);
    let (nh, dh) = (cfg.n_heads, cfg.head_dim());
    let mut x = vec![0f32; t * d];
    for (i, &tok) in tokens.iter().enumerate() {
        x[i * d..(i + 1) * d].copy_from_slice(&p.embed[tok as usize * d..(tok as usize + 1) * d]);
    }
    let (cos, sin) = rope_tables(t, dh, cfg.rope_base);
    for layer in &p.layers {
        let mut h = x.clone();
        rmsnorm_rows(&mut h, d, cfg.norm_eps);
        scale_rows(&mut h, &layer.ln1);
        let mut q = matmul(&h, &layer.wq, t, d, d);
        let mut k = matmul(&h, &layer.wk, t, d, d);
        let v = matmul(&h, &layer.wv, t, d, d);
        apply_rope(&mut q, t, nh, dh, &cos, &sin);
        apply_rope(&mut k, t, nh, dh, &cos, &sin);
        let o = attention(&q, &k, &v, t, nh, dh);
        let o = matmul(&o, &layer.wo, t, d, d);
        for (xv, ov) in x.iter_mut().zip(&o) {
            *xv += ov;
        }
        let mut h = x.clone();
        rmsnorm_rows(&mut h, d, cfg.norm_eps);
        scale_rows(&mut h, &layer.ln2);
        let g = matmul(&h, &layer.wgate, t, d, cfg.d_ffn);
        let u = matmul(&h, &layer.wup, t, d, cfg.d_ffn);
        let z: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
        let zd = matmul(&z, &layer.wdown, t, cfg.d_ffn, d);
        for (xv, zv) in x.iter_mut().zip(&zd) {
            *xv += zv;
        }
    }
    rmsnorm_rows(&mut x, d, cfg.norm_eps);
    scale_rows(&mut x, &p.ln_f);
    matmul(&x, &p.lm_head, t, d, cfg.vocab)
}

// ---------------------------------------------------------------------------
// rotated/quantized forward (deployed layout)
// ---------------------------------------------------------------------------

fn forward_quant(
    cfg: &ModelCfg,
    p: &QuantParams,
    a_bits: Option<u32>,
    tokens: &[i32],
) -> Vec<f32> {
    forward_quant_impl(cfg, p, a_bits, tokens, None)
}

/// [`forward_quant`] with an [`ActivationTap`] observing every linear's
/// input matrix (calibration capture). With `a_bits = None` on
/// fused-but-unquantized params the tapped activations are exactly the
/// rotated-basis fp activations (Fig.-1 equivalence).
pub fn forward_quant_tapped(
    cfg: &ModelCfg,
    p: &QuantParams,
    a_bits: Option<u32>,
    tokens: &[i32],
    tap: &mut dyn ActivationTap,
) -> Vec<f32> {
    forward_quant_impl(cfg, p, a_bits, tokens, Some(tap))
}

fn forward_quant_impl(
    cfg: &ModelCfg,
    p: &QuantParams,
    a_bits: Option<u32>,
    tokens: &[i32],
    mut tap: Option<&mut dyn ActivationTap>,
) -> Vec<f32> {
    let (t, d) = (tokens.len(), cfg.d_model);
    let (nh, dh) = (cfg.n_heads, cfg.head_dim());
    let g = cfg.group;
    let maybe_quant = |x: &mut Vec<f32>| {
        if let Some(bits) = a_bits {
            act_fake_quant(x, g, bits);
        }
    };
    let mut x = vec![0f32; t * d];
    for (i, &tok) in tokens.iter().enumerate() {
        x[i * d..(i + 1) * d].copy_from_slice(&p.embed[tok as usize * d..(tok as usize + 1) * d]);
    }
    let (cos, sin) = rope_tables(t, dh, cfg.rope_base);
    for (l, layer) in p.layers.iter().enumerate() {
        // Heterogeneous plans: transition the residual stream from the
        // previous layer's R1 basis into this layer's (`x ← x R_{l-1}ᵀ R_l`).
        if let Some(tr) = &layer.basis_change {
            x = matmul(&x, tr, t, d, d);
        }
        let w = |name: &str| layer.dense[name].as_slice();
        let mut h = x.clone();
        rmsnorm_rows(&mut h, d, cfg.norm_eps);
        scale_rows(&mut h, &layer.ascale_attn);
        maybe_quant(&mut h);
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::AttnIn, &h, d);
        }
        let mut q = matmul(&h, w("wq"), t, d, d);
        let mut k = matmul(&h, w("wk"), t, d, d);
        let v = matmul(&h, w("wv"), t, d, d);
        apply_rope(&mut q, t, nh, dh, &cos, &sin);
        apply_rope(&mut k, t, nh, dh, &cos, &sin);
        rotate_heads(&mut q, t, nh, dh, &p.r3);
        rotate_heads(&mut k, t, nh, dh, &p.r3);
        let mut o = attention(&q, &k, &v, t, nh, dh);
        scale_rows(&mut o, &layer.ascale_o);
        maybe_quant(&mut o);
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::OIn, &o, d);
        }
        let o = matmul(&o, w("wo"), t, d, d);
        for (xv, ov) in x.iter_mut().zip(&o) {
            *xv += ov;
        }
        let mut h = x.clone();
        rmsnorm_rows(&mut h, d, cfg.norm_eps);
        scale_rows(&mut h, &layer.ascale_ffn);
        maybe_quant(&mut h);
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::FfnIn, &h, d);
        }
        let gx = matmul(&h, w("wgate"), t, d, cfg.d_ffn);
        let ux = matmul(&h, w("wup"), t, d, cfg.d_ffn);
        let mut z: Vec<f32> = gx.iter().zip(&ux).map(|(&gv, &uv)| silu(gv) * uv).collect();
        // Online R4: fast (grouped) Hadamard + signs — the L1 kernel's
        // math. A heterogeneous plan overrides kind/signs per layer; the
        // LH block size is carried by the sign-vector length (legacy
        // variants store `group` signs, plans may pick any valid block).
        let (r4_kind, r4_signs) = match &layer.r4 {
            Some(o) => (o.kind, o.signs.as_slice()),
            None => (p.r4_kind, p.r4_signs.as_slice()),
        };
        match r4_kind {
            R4Kind::GH => {
                for row in z.chunks_mut(cfg.d_ffn) {
                    fwht_f32(row);
                    for (zv, &s) in row.iter_mut().zip(r4_signs) {
                        *zv *= s;
                    }
                }
            }
            R4Kind::LH => {
                let blk = r4_signs.len();
                for row in z.chunks_mut(cfg.d_ffn) {
                    for chunk in row.chunks_mut(blk) {
                        fwht_f32(chunk);
                        for (zv, &s) in chunk.iter_mut().zip(r4_signs) {
                            *zv *= s;
                        }
                    }
                }
            }
        }
        scale_rows(&mut z, &layer.ascale_down);
        maybe_quant(&mut z);
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::DownIn, &z, cfg.d_ffn);
        }
        let zd = matmul(&z, w("wdown"), t, cfg.d_ffn, d);
        for (xv, zv) in x.iter_mut().zip(&zd) {
            *xv += zv;
        }
    }
    rmsnorm_rows(&mut x, d, cfg.norm_eps);
    matmul(&x, &p.lm_head, t, d, cfg.vocab)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_f32_matches_f64() {
        let mut a = vec![1.0f32, -2.0, 3.0, 0.5, -1.5, 2.5, 0.0, 4.0];
        let mut b: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        fwht_f32(&mut a);
        crate::transform::fwht(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((*x as f64 - y).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_is_causal() {
        // Changing a future value must not affect earlier outputs.
        let (t, nh, dh) = (4, 1, 4);
        let mut q = vec![0.1f32; t * nh * dh];
        let k = vec![0.2f32; t * nh * dh];
        let mut v: Vec<f32> = (0..t * nh * dh).map(|i| i as f32 * 0.01).collect();
        for (i, qv) in q.iter_mut().enumerate() {
            *qv += (i % 3) as f32 * 0.05;
        }
        let out1 = attention(&q, &k, &v, t, nh, dh);
        for d in 0..dh {
            v[(t - 1) * dh + d] = 99.0; // mutate last position's value
        }
        let out2 = attention(&q, &k, &v, t, nh, dh);
        assert_eq!(&out1[..(t - 1) * dh], &out2[..(t - 1) * dh]);
        assert_ne!(&out1[(t - 1) * dh..], &out2[(t - 1) * dh..]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let mut x = vec![3.0f32, -4.0]; // rms = sqrt(12.5)
        rmsnorm_rows(&mut x, 2, 0.0);
        let rms: f32 = (x.iter().map(|v| v * v).sum::<f32>() / 2.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn act_fake_quant_reduces_resolution() {
        let mut x: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.1).collect();
        let orig = x.clone();
        act_fake_quant(&mut x, 32, 4);
        // Values change but stay within the clip envelope.
        assert!(x.iter().zip(&orig).any(|(a, b)| a != b));
        let m0 = orig.iter().fold(0f32, |m, &v| m.max(v.abs()));
        assert!(x.iter().all(|&v| v.abs() <= m0 + 1e-6));
    }

    #[test]
    fn matmul_small_known() {
        // [1,2;3,4] @ [1,0;0,1] = same
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&x, &w, 2, 2, 2), x);
    }
}
