//! Native reference forward pass (pure Rust, no PJRT).
//!
//! Mirrors `python/compile/model.py::forward_fp` / `forward_rotated` on
//! single sequences. Used to (a) cross-validate the PJRT path against an
//! independent implementation, (b) run the Fig.-1 rotation-invariance
//! cargo test, and (c) back the batched native execution engine
//! (`exec::NativeBackend`) that serves eval, calibration and the
//! coordinator.
//!
//! Every intermediate lives in a caller-supplied [`ForwardScratch`] so a
//! long-lived worker thread pays zero allocation per forward call, and
//! every linear runs through the cache-blocked tiled [`matmul_into`].
//! Both are bit-transparent: per output element the f64 accumulation
//! order is unchanged, so `forward` produces logits bit-identical to the
//! original straight-line implementation — the invariant the batched
//! engine's "same logits for any batch composition / thread count"
//! guarantee rests on.

use super::config::{ModelCfg, R4Kind};
use super::weights::{FpParams, QuantParams};

/// A runnable dense model: fp checkpoint or dequantized variant.
pub enum DenseModel {
    Fp { cfg: ModelCfg, params: FpParams },
    Quant { cfg: ModelCfg, params: QuantParams, a_bits: Option<u32> },
}

const ACT_CLIP: f32 = 0.9;

// ---------------------------------------------------------------------------
// Activation taps (calibration capture)
// ---------------------------------------------------------------------------

/// Where in the rotated forward an activation tap fires: each site is
/// the exact input matrix one or more fused linears consume, **in the
/// basis that linear quantizes in** (after norms, activation scales and
/// fake-quant, immediately before the matmul).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapSite {
    /// Input of `wq`/`wk`/`wv`: post-norm residual stream, layer R1 basis.
    AttnIn,
    /// Input of `wo`: attention output in the B2/R3 head basis.
    OIn,
    /// Input of `wgate`/`wup`: post-norm residual stream, layer R1 basis.
    FfnIn,
    /// Input of `wdown`: FFN activation after the online R4 rotation.
    DownIn,
}

impl TapSite {
    pub const ALL: [TapSite; 4] =
        [TapSite::AttnIn, TapSite::OIn, TapSite::FfnIn, TapSite::DownIn];
}

/// Observer of per-linear input activations during
/// [`forward_quant_tapped`] — the hook the `calib` subsystem uses to
/// accumulate streaming `XᵀX` Hessians without copying activations.
pub trait ActivationTap {
    /// `rows` is a row-major `[T, width]` activation matrix.
    fn record(&mut self, layer: usize, site: TapSite, rows: &[f32], width: usize);
}

// ---------------------------------------------------------------------------
// Reusable scratch
// ---------------------------------------------------------------------------

/// Reusable buffers for one forward call. A worker thread keeps one of
/// these alive across calls so the steady state allocates nothing: every
/// buffer is `clear()`+`resize()`d (capacity retained) and fully
/// overwritten before it is read, so no state leaks between sequences —
/// results are bit-identical whether a scratch is fresh or reused.
#[derive(Default)]
pub struct ForwardScratch {
    /// Residual stream `[T, d]`.
    x: Vec<f32>,
    /// Basis-change double buffer for `x`.
    xt: Vec<f32>,
    /// Post-norm linear input `[T, d]`.
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention output `[T, d]`.
    o: Vec<f32>,
    /// FFN gate / up projections `[T, d_ffn]`.
    g: Vec<f32>,
    u: Vec<f32>,
    /// FFN activation `[T, d_ffn]`.
    z: Vec<f32>,
    /// Output of `wo` / `wdown` `[T, d]`.
    zd: Vec<f32>,
    /// f64 matmul accumulator (the tiled fast path sums here).
    acc: Vec<f64>,
    /// Attention score row (f64, one per key position).
    scores: Vec<f64>,
    cos: Vec<f32>,
    sin: Vec<f32>,
    /// Per-head rotation temp (`head_dim` wide).
    head_tmp: Vec<f32>,
}

impl ForwardScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

impl DenseModel {
    pub fn cfg(&self) -> &ModelCfg {
        match self {
            DenseModel::Fp { cfg, .. } => cfg,
            DenseModel::Quant { cfg, .. } => cfg,
        }
    }

    /// Forward a single token sequence → logits `[T, vocab]` (row-major).
    pub fn forward(&self, tokens: &[i32]) -> Vec<f32> {
        self.forward_with(tokens, &mut ForwardScratch::new())
    }

    /// [`DenseModel::forward`] with caller-owned scratch buffers —
    /// allocation-free in steady state, bit-identical results.
    pub fn forward_with(&self, tokens: &[i32], scratch: &mut ForwardScratch) -> Vec<f32> {
        match self {
            DenseModel::Fp { cfg, params } => forward_fp(cfg, params, tokens, scratch),
            DenseModel::Quant { cfg, params, a_bits } => {
                forward_quant_impl(cfg, params, *a_bits, tokens, None, scratch)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------------

/// `out[T,H] = x[T,C] @ w[C,H]` with f64 accumulation, cache-blocked
/// over `(k, j)` like `transform::Mat::matmul`: a `MM_BK × MM_BJ` tile
/// of `w` stays cache-resident while every token row sweeps it, cutting
/// B-matrix traffic by ~`MM_BK`× once `w` outgrows L2. Per output
/// element the summation order is k ascending — `kb` blocks ascend and
/// `k` ascends within each block — identical to the naive loop, so
/// results are bit-for-bit unchanged. Zero activations are skipped
/// (padding rows stay cheap).
pub fn matmul_into(
    x: &[f32],
    w: &[f32],
    t: usize,
    c: usize,
    h: usize,
    out: &mut Vec<f32>,
    acc: &mut Vec<f64>,
) {
    debug_assert_eq!(x.len(), t * c);
    debug_assert_eq!(w.len(), c * h);
    const MM_BK: usize = 64;
    const MM_BJ: usize = 128;
    acc.clear();
    acc.resize(t * h, 0.0);
    for kb in (0..c).step_by(MM_BK) {
        let ke = (kb + MM_BK).min(c);
        for jb in (0..h).step_by(MM_BJ) {
            let je = (jb + MM_BJ).min(h);
            for row in 0..t {
                let xr = &x[row * c + kb..row * c + ke];
                let arow = &mut acc[row * h + jb..row * h + je];
                for (k, &xv) in xr.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let xv = xv as f64;
                    let wrow = &w[(kb + k) * h + jb..(kb + k) * h + je];
                    for (a, &wv) in arow.iter_mut().zip(wrow) {
                        *a += xv * wv as f64;
                    }
                }
            }
        }
    }
    out.clear();
    out.extend(acc.iter().map(|&a| a as f32));
}

/// Allocating wrapper around [`matmul_into`].
pub fn matmul(x: &[f32], w: &[f32], t: usize, c: usize, h: usize) -> Vec<f32> {
    let mut out = Vec::new();
    let mut acc = Vec::new();
    matmul_into(x, w, t, c, h, &mut out, &mut acc);
    out
}

fn rmsnorm_rows(x: &mut [f32], d: usize, eps: f64) {
    for row in x.chunks_mut(d) {
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let r = 1.0 / (ms + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v as f64 * r) as f32;
        }
    }
}

fn scale_rows(x: &mut [f32], scale: &[f32]) {
    let d = scale.len();
    for row in x.chunks_mut(d) {
        for (v, &s) in row.iter_mut().zip(scale) {
            *v *= s;
        }
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Symmetric per-group activation fake-quant (matches kernels/quant.py).
fn act_fake_quant(x: &mut [f32], group: usize, bits: u32) {
    let qmax = ((1u32 << (bits - 1)) - 1) as f32;
    for chunk in x.chunks_mut(group) {
        let absmax = chunk.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let mut scale = ACT_CLIP * absmax / qmax;
        if scale == 0.0 {
            scale = 1.0;
        }
        for v in chunk.iter_mut() {
            let q = (*v / scale).round().clamp(-qmax, qmax);
            *v = q * scale;
        }
    }
}

/// Orthonormal in-place FWHT over an f32 slice.
fn fwht_f32(x: &mut [f32]) {
    let n = x.len();
    let mut h = 1;
    while h < n {
        for start in (0..n).step_by(2 * h) {
            for i in start..start + h {
                let a = x[i];
                let b = x[i + h];
                x[i] = a + b;
                x[i + h] = a - b;
            }
        }
        h *= 2;
    }
    let s = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// RoPE tables into scratch: `(cos, sin)` each `[T, head_dim/2]`.
fn rope_tables_into(t: usize, head_dim: usize, base: f64, cos: &mut Vec<f32>, sin: &mut Vec<f32>) {
    let half = head_dim / 2;
    cos.clear();
    cos.resize(t * half, 0.0);
    sin.clear();
    sin.resize(t * half, 0.0);
    for pos in 0..t {
        for i in 0..half {
            let inv = 1.0 / base.powf(i as f64 / half as f64);
            let angle = pos as f64 * inv;
            cos[pos * half + i] = angle.cos() as f32;
            sin[pos * half + i] = angle.sin() as f32;
        }
    }
}

/// Apply RoPE in-place to `[T, n_heads, head_dim]` (paired halves layout,
/// matching model.py::apply_rope).
fn apply_rope(x: &mut [f32], t: usize, n_heads: usize, dh: usize, cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    for pos in 0..t {
        for head in 0..n_heads {
            let off = (pos * n_heads + head) * dh;
            for i in 0..half {
                let c = cos[pos * half + i];
                let s = sin[pos * half + i];
                let x1 = x[off + i];
                let x2 = x[off + half + i];
                x[off + i] = x1 * c - x2 * s;
                x[off + half + i] = x1 * s + x2 * c;
            }
        }
    }
}

/// Per-head right-multiplication by `r [dh, dh]` over `[T, heads, dh]`.
fn rotate_heads(x: &mut [f32], t: usize, n_heads: usize, dh: usize, r: &[f32], tmp: &mut Vec<f32>) {
    tmp.clear();
    tmp.resize(dh, 0.0);
    for pos in 0..t {
        for head in 0..n_heads {
            let off = (pos * n_heads + head) * dh;
            for (j, tv) in tmp.iter_mut().enumerate() {
                let mut acc = 0f64;
                for k in 0..dh {
                    acc += x[off + k] as f64 * r[k * dh + j] as f64;
                }
                *tv = acc as f32;
            }
            x[off..off + dh].copy_from_slice(tmp);
        }
    }
}

/// Causal attention over `[T, heads, dh]` tensors → same layout,
/// written into `out` (fully overwritten).
fn attention_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    t: usize,
    n_heads: usize,
    dh: usize,
    out: &mut Vec<f32>,
    scores: &mut Vec<f64>,
) {
    out.clear();
    out.resize(t * n_heads * dh, 0.0);
    scores.clear();
    scores.resize(t, 0.0);
    let scale = 1.0 / (dh as f64).sqrt();
    for head in 0..n_heads {
        for qi in 0..t {
            let qoff = (qi * n_heads + head) * dh;
            let mut maxs = f64::NEG_INFINITY;
            for (ki, sc) in scores.iter_mut().enumerate().take(qi + 1) {
                let koff = (ki * n_heads + head) * dh;
                let mut dot = 0f64;
                for d in 0..dh {
                    dot += q[qoff + d] as f64 * k[koff + d] as f64;
                }
                *sc = dot * scale;
                maxs = maxs.max(*sc);
            }
            let mut denom = 0f64;
            for sc in scores.iter_mut().take(qi + 1) {
                *sc = (*sc - maxs).exp();
                denom += *sc;
            }
            let ooff = (qi * n_heads + head) * dh;
            for d in 0..dh {
                let mut acc = 0f64;
                for (ki, sc) in scores.iter().enumerate().take(qi + 1) {
                    let voff = (ki * n_heads + head) * dh;
                    acc += sc * v[voff + d] as f64;
                }
                out[ooff + d] = (acc / denom) as f32;
            }
        }
    }
}

/// Gather embedding rows for `tokens` into `x` `[T, d]`.
fn embed_into(x: &mut Vec<f32>, embed: &[f32], tokens: &[i32], d: usize) {
    x.clear();
    for &tok in tokens {
        let tok = tok as usize;
        x.extend_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
}

/// `x += y` elementwise.
fn add_assign(x: &mut [f32], y: &[f32]) {
    for (xv, yv) in x.iter_mut().zip(y) {
        *xv += yv;
    }
}

// ---------------------------------------------------------------------------
// fp forward (training layout)
// ---------------------------------------------------------------------------

fn forward_fp(
    cfg: &ModelCfg,
    p: &FpParams,
    tokens: &[i32],
    scratch: &mut ForwardScratch,
) -> Vec<f32> {
    let (t, d) = (tokens.len(), cfg.d_model);
    let (nh, dh) = (cfg.n_heads, cfg.head_dim());
    let ForwardScratch { x, h, q, k, v, o, g, u, z, zd, acc, scores, cos, sin, .. } = scratch;
    embed_into(x, &p.embed, tokens, d);
    rope_tables_into(t, dh, cfg.rope_base, cos, sin);
    for layer in &p.layers {
        h.clear();
        h.extend_from_slice(x);
        rmsnorm_rows(h, d, cfg.norm_eps);
        scale_rows(h, &layer.ln1);
        matmul_into(h, &layer.wq, t, d, d, q, acc);
        matmul_into(h, &layer.wk, t, d, d, k, acc);
        matmul_into(h, &layer.wv, t, d, d, v, acc);
        apply_rope(q, t, nh, dh, cos, sin);
        apply_rope(k, t, nh, dh, cos, sin);
        attention_into(q, k, v, t, nh, dh, o, scores);
        matmul_into(o, &layer.wo, t, d, d, zd, acc);
        add_assign(x, zd);
        h.clear();
        h.extend_from_slice(x);
        rmsnorm_rows(h, d, cfg.norm_eps);
        scale_rows(h, &layer.ln2);
        matmul_into(h, &layer.wgate, t, d, cfg.d_ffn, g, acc);
        matmul_into(h, &layer.wup, t, d, cfg.d_ffn, u, acc);
        z.clear();
        z.extend(g.iter().zip(u.iter()).map(|(&gv, &uv)| silu(gv) * uv));
        matmul_into(z, &layer.wdown, t, cfg.d_ffn, d, zd, acc);
        add_assign(x, zd);
    }
    rmsnorm_rows(x, d, cfg.norm_eps);
    scale_rows(x, &p.ln_f);
    let mut logits = Vec::new();
    matmul_into(x, &p.lm_head, t, d, cfg.vocab, &mut logits, acc);
    logits
}

// ---------------------------------------------------------------------------
// rotated/quantized forward (deployed layout)
// ---------------------------------------------------------------------------

/// Rotated/quantized forward with an [`ActivationTap`] observing every
/// linear's input matrix (calibration capture). With `a_bits = None` on
/// fused-but-unquantized params the tapped activations are exactly the
/// rotated-basis fp activations (Fig.-1 equivalence).
pub fn forward_quant_tapped(
    cfg: &ModelCfg,
    p: &QuantParams,
    a_bits: Option<u32>,
    tokens: &[i32],
    tap: &mut dyn ActivationTap,
) -> Vec<f32> {
    forward_quant_impl(cfg, p, a_bits, tokens, Some(tap), &mut ForwardScratch::new())
}

/// [`forward_quant_tapped`] with caller-owned scratch — the form the
/// pooled calibration capture runs so long-lived workers allocate
/// nothing per sequence.
pub fn forward_quant_tapped_with(
    cfg: &ModelCfg,
    p: &QuantParams,
    a_bits: Option<u32>,
    tokens: &[i32],
    tap: &mut dyn ActivationTap,
    scratch: &mut ForwardScratch,
) -> Vec<f32> {
    forward_quant_impl(cfg, p, a_bits, tokens, Some(tap), scratch)
}

fn forward_quant_impl(
    cfg: &ModelCfg,
    p: &QuantParams,
    a_bits: Option<u32>,
    tokens: &[i32],
    mut tap: Option<&mut dyn ActivationTap>,
    scratch: &mut ForwardScratch,
) -> Vec<f32> {
    let (t, d) = (tokens.len(), cfg.d_model);
    let (nh, dh) = (cfg.n_heads, cfg.head_dim());
    let grp = cfg.group;
    let ForwardScratch { x, xt, h, q, k, v, o, g, u, z, zd, acc, scores, cos, sin, head_tmp } =
        scratch;
    embed_into(x, &p.embed, tokens, d);
    rope_tables_into(t, dh, cfg.rope_base, cos, sin);
    for (l, layer) in p.layers.iter().enumerate() {
        // Heterogeneous plans: transition the residual stream from the
        // previous layer's R1 basis into this layer's (`x ← x R_{l-1}ᵀ R_l`).
        if let Some(tr) = &layer.basis_change {
            matmul_into(x, tr, t, d, d, xt, acc);
            std::mem::swap(x, xt);
        }
        let w = |name: &str| layer.dense[name].as_slice();
        h.clear();
        h.extend_from_slice(x);
        rmsnorm_rows(h, d, cfg.norm_eps);
        scale_rows(h, &layer.ascale_attn);
        if let Some(bits) = a_bits {
            act_fake_quant(h, grp, bits);
        }
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::AttnIn, h, d);
        }
        matmul_into(h, w("wq"), t, d, d, q, acc);
        matmul_into(h, w("wk"), t, d, d, k, acc);
        matmul_into(h, w("wv"), t, d, d, v, acc);
        apply_rope(q, t, nh, dh, cos, sin);
        apply_rope(k, t, nh, dh, cos, sin);
        rotate_heads(q, t, nh, dh, &p.r3, head_tmp);
        rotate_heads(k, t, nh, dh, &p.r3, head_tmp);
        attention_into(q, k, v, t, nh, dh, o, scores);
        scale_rows(o, &layer.ascale_o);
        if let Some(bits) = a_bits {
            act_fake_quant(o, grp, bits);
        }
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::OIn, o, d);
        }
        matmul_into(o, w("wo"), t, d, d, zd, acc);
        add_assign(x, zd);
        h.clear();
        h.extend_from_slice(x);
        rmsnorm_rows(h, d, cfg.norm_eps);
        scale_rows(h, &layer.ascale_ffn);
        if let Some(bits) = a_bits {
            act_fake_quant(h, grp, bits);
        }
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::FfnIn, h, d);
        }
        matmul_into(h, w("wgate"), t, d, cfg.d_ffn, g, acc);
        matmul_into(h, w("wup"), t, d, cfg.d_ffn, u, acc);
        z.clear();
        z.extend(g.iter().zip(u.iter()).map(|(&gv, &uv)| silu(gv) * uv));
        // Online R4: fast (grouped) Hadamard + signs — the L1 kernel's
        // math. A heterogeneous plan overrides kind/signs per layer; the
        // LH block size is carried by the sign-vector length (legacy
        // variants store `group` signs, plans may pick any valid block).
        let (r4_kind, r4_signs) = match &layer.r4 {
            Some(ov) => (ov.kind, ov.signs.as_slice()),
            None => (p.r4_kind, p.r4_signs.as_slice()),
        };
        match r4_kind {
            R4Kind::GH => {
                for row in z.chunks_mut(cfg.d_ffn) {
                    fwht_f32(row);
                    for (zv, &s) in row.iter_mut().zip(r4_signs) {
                        *zv *= s;
                    }
                }
            }
            R4Kind::LH => {
                let blk = r4_signs.len();
                for row in z.chunks_mut(cfg.d_ffn) {
                    for chunk in row.chunks_mut(blk) {
                        fwht_f32(chunk);
                        for (zv, &s) in chunk.iter_mut().zip(r4_signs) {
                            *zv *= s;
                        }
                    }
                }
            }
        }
        scale_rows(z, &layer.ascale_down);
        if let Some(bits) = a_bits {
            act_fake_quant(z, grp, bits);
        }
        if let Some(tp) = tap.as_mut() {
            tp.record(l, TapSite::DownIn, z, cfg.d_ffn);
        }
        matmul_into(z, w("wdown"), t, cfg.d_ffn, d, zd, acc);
        add_assign(x, zd);
    }
    rmsnorm_rows(x, d, cfg.norm_eps);
    let mut logits = Vec::new();
    matmul_into(x, &p.lm_head, t, d, cfg.vocab, &mut logits, acc);
    logits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_f32_matches_f64() {
        let mut a = vec![1.0f32, -2.0, 3.0, 0.5, -1.5, 2.5, 0.0, 4.0];
        let mut b: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        fwht_f32(&mut a);
        crate::transform::fwht(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((*x as f64 - y).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_is_causal() {
        // Changing a future value must not affect earlier outputs.
        let (t, nh, dh) = (4, 1, 4);
        let mut q = vec![0.1f32; t * nh * dh];
        let k = vec![0.2f32; t * nh * dh];
        let mut v: Vec<f32> = (0..t * nh * dh).map(|i| i as f32 * 0.01).collect();
        for (i, qv) in q.iter_mut().enumerate() {
            *qv += (i % 3) as f32 * 0.05;
        }
        let attn = |q: &[f32], k: &[f32], v: &[f32]| {
            let (mut out, mut scores) = (Vec::new(), Vec::new());
            attention_into(q, k, v, t, nh, dh, &mut out, &mut scores);
            out
        };
        let out1 = attn(&q, &k, &v);
        for d in 0..dh {
            v[(t - 1) * dh + d] = 99.0; // mutate last position's value
        }
        let out2 = attn(&q, &k, &v);
        assert_eq!(&out1[..(t - 1) * dh], &out2[..(t - 1) * dh]);
        assert_ne!(&out1[(t - 1) * dh..], &out2[(t - 1) * dh..]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let mut x = vec![3.0f32, -4.0]; // rms = sqrt(12.5)
        rmsnorm_rows(&mut x, 2, 0.0);
        let rms: f32 = (x.iter().map(|v| v * v).sum::<f32>() / 2.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn act_fake_quant_reduces_resolution() {
        let mut x: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.1).collect();
        let orig = x.clone();
        act_fake_quant(&mut x, 32, 4);
        // Values change but stay within the clip envelope.
        assert!(x.iter().zip(&orig).any(|(a, b)| a != b));
        let m0 = orig.iter().fold(0f32, |m, &v| m.max(v.abs()));
        assert!(x.iter().all(|&v| v.abs() <= m0 + 1e-6));
    }

    #[test]
    fn matmul_small_known() {
        // [1,2;3,4] @ [1,0;0,1] = same
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&x, &w, 2, 2, 2), x);
    }

    /// The blocked matmul must agree bit-for-bit with the straight
    /// k-ascending reference at tile-unaligned sizes — the invariant the
    /// "same logits regardless of batching" guarantee rests on.
    #[test]
    fn blocked_matmul_bit_identical_to_naive() {
        let naive = |x: &[f32], w: &[f32], t: usize, c: usize, h: usize| -> Vec<f32> {
            let mut out = vec![0f32; t * h];
            for row in 0..t {
                let mut acc = vec![0f64; h];
                for (kk, &xv) in x[row * c..(row + 1) * c].iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    for (a, &wv) in acc.iter_mut().zip(&w[kk * h..(kk + 1) * h]) {
                        *a += xv as f64 * wv as f64;
                    }
                }
                for (ov, &a) in out[row * h..(row + 1) * h].iter_mut().zip(&acc) {
                    *ov = a as f32;
                }
            }
            out
        };
        let mut rng = crate::rng::SplitMix64::new(17);
        for (t, c, h) in [(3, 70, 130), (5, 64, 128), (1, 200, 7), (4, 1, 300)] {
            let x: Vec<f32> = (0..t * c).map(|_| rng.next_normal() as f32).collect();
            let w: Vec<f32> = (0..c * h).map(|_| rng.next_normal() as f32).collect();
            let fast = matmul(&x, &w, t, c, h);
            let slow = naive(&x, &w, t, c, h);
            assert_eq!(fast.len(), slow.len());
            for (a, b) in fast.iter().zip(&slow) {
                assert_eq!(a.to_bits(), b.to_bits(), "blocked matmul is not bit-identical");
            }
        }
    }

    /// Scratch reuse must not change results: a warm scratch that just
    /// ran a different sequence yields the same bits as a fresh one.
    #[test]
    fn scratch_reuse_is_bit_transparent() {
        let cfg = ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        };
        let model = DenseModel::Fp { cfg: cfg.clone(), params: FpParams::synthetic(&cfg, 5) };
        let a: Vec<i32> = (0..9).map(|i| (i * 5 % 64) as i32).collect();
        let b: Vec<i32> = (0..14).map(|i| (i * 11 % 64) as i32).collect();
        let fresh = model.forward(&b);
        let mut scratch = ForwardScratch::new();
        let _ = model.forward_with(&a, &mut scratch); // warm with another length
        let warm = model.forward_with(&b, &mut scratch);
        assert_eq!(fresh.len(), warm.len());
        for (x, y) in fresh.iter().zip(&warm) {
            assert_eq!(x.to_bits(), y.to_bits(), "scratch reuse changed logits");
        }
    }
}
