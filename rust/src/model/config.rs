//! Model configuration and flat parameter specs.
//!
//! Mirrors `python/compile/model.py::ModelCfg` and its
//! `fp_param_spec` / `quant_param_spec` orderings exactly — the AOT
//! weight blobs are flat concatenations in this order.

use crate::config::Json;

/// Tensor dtype in the artifact blobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U8,
}

impl Dtype {
    pub fn size(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::U8 => 1,
        }
    }

    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "u8" => Some(Dtype::U8),
            _ => None,
        }
    }
}

/// One entry of a flat parameter spec.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn nbytes(&self) -> usize {
        self.numel() * self.dtype.size()
    }
}

/// The online R4 rotation kind baked into a graph (Table 2 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum R4Kind {
    GH,
    LH,
}

impl R4Kind {
    pub fn as_str(&self) -> &'static str {
        match self {
            R4Kind::GH => "GH",
            R4Kind::LH => "LH",
        }
    }

    pub fn parse(s: &str) -> Option<R4Kind> {
        match s.to_ascii_uppercase().as_str() {
            "GH" => Some(R4Kind::GH),
            "LH" => Some(R4Kind::LH),
            _ => None,
        }
    }
}

/// llama_mini architecture + quantization geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    pub group: usize,
    pub rope_base: f64,
    pub norm_eps: f64,
}

impl Default for ModelCfg {
    fn default() -> Self {
        Self {
            vocab: 256,
            d_model: 256,
            n_layers: 4,
            n_heads: 4,
            d_ffn: 512,
            group: 64,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }
}

pub const LINEARS: [&str; 7] = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];

/// The one token-range rule: ids must lie in `0..vocab`. Every layer
/// that validates tokens — the cached forward, the native backend, the
/// serving admission, calibration capture — delegates here so rejection
/// behavior and wording can never diverge.
pub fn tokens_in_vocab(tokens: &[i32], vocab: usize) -> Result<(), String> {
    if let Some(&bad) = tokens.iter().find(|&&t| t < 0 || t as usize >= vocab) {
        return Err(format!("token id {bad} outside vocab 0..{vocab}"));
    }
    Ok(())
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        Ok(Self {
            vocab: j.at("vocab")?.as_usize().ok_or("vocab")?,
            d_model: j.at("d_model")?.as_usize().ok_or("d_model")?,
            n_layers: j.at("n_layers")?.as_usize().ok_or("n_layers")?,
            n_heads: j.at("n_heads")?.as_usize().ok_or("n_heads")?,
            d_ffn: j.at("d_ffn")?.as_usize().ok_or("d_ffn")?,
            group: j.at("group")?.as_usize().ok_or("group")?,
            rope_base: j.at("rope_base")?.as_f64().ok_or("rope_base")?,
            norm_eps: j.at("norm_eps")?.as_f64().ok_or("norm_eps")?,
        })
    }

    /// `(input channels, output channels)` of a named linear.
    pub fn linear_shape(&self, name: &str) -> (usize, usize) {
        let (d, f) = (self.d_model, self.d_ffn);
        match name {
            "wq" | "wk" | "wv" | "wo" => (d, d),
            "wgate" | "wup" => (d, f),
            "wdown" => (f, d),
            other => panic!("unknown linear {other}"),
        }
    }

    /// Mirror of python `fp_param_spec`.
    pub fn fp_param_spec(&self) -> Vec<ParamSpec> {
        let (d, v) = (self.d_model, self.vocab);
        let mut spec = vec![ParamSpec { name: "embed".into(), shape: vec![v, d], dtype: Dtype::F32 }];
        for l in 0..self.n_layers {
            for norm in ["ln1", "ln2"] {
                spec.push(ParamSpec {
                    name: format!("layers.{l}.{norm}"),
                    shape: vec![d],
                    dtype: Dtype::F32,
                });
            }
            for name in LINEARS {
                let (c, h) = self.linear_shape(name);
                spec.push(ParamSpec {
                    name: format!("layers.{l}.{name}"),
                    shape: vec![c, h],
                    dtype: Dtype::F32,
                });
            }
        }
        spec.push(ParamSpec { name: "ln_f".into(), shape: vec![d], dtype: Dtype::F32 });
        spec.push(ParamSpec { name: "lm_head".into(), shape: vec![d, v], dtype: Dtype::F32 });
        spec
    }

    /// Mirror of python `quant_param_spec`.
    pub fn quant_param_spec(&self, r4: R4Kind) -> Vec<ParamSpec> {
        let (d, v, g) = (self.d_model, self.vocab, self.group);
        let mut spec = vec![
            ParamSpec { name: "embed".into(), shape: vec![v, d], dtype: Dtype::F32 },
            ParamSpec { name: "lm_head".into(), shape: vec![d, v], dtype: Dtype::F32 },
            ParamSpec {
                name: "r3".into(),
                shape: vec![self.head_dim(), self.head_dim()],
                dtype: Dtype::F32,
            },
            ParamSpec {
                name: "r4_signs".into(),
                shape: vec![if r4 == R4Kind::GH { self.d_ffn } else { g }],
                dtype: Dtype::F32,
            },
        ];
        for l in 0..self.n_layers {
            for (key, dim) in [
                ("ascale_attn", d),
                ("ascale_o", d),
                ("ascale_ffn", d),
                ("ascale_down", self.d_ffn),
            ] {
                spec.push(ParamSpec {
                    name: format!("layers.{l}.{key}"),
                    shape: vec![dim],
                    dtype: Dtype::F32,
                });
            }
            for name in LINEARS {
                let (c, h) = self.linear_shape(name);
                spec.push(ParamSpec {
                    name: format!("layers.{l}.{name}_packed"),
                    shape: vec![c / 4, h],
                    dtype: Dtype::U8,
                });
                spec.push(ParamSpec {
                    name: format!("layers.{l}.{name}_scale"),
                    shape: vec![c / g, h],
                    dtype: Dtype::F32,
                });
                spec.push(ParamSpec {
                    name: format!("layers.{l}.{name}_zero"),
                    shape: vec![c / g, h],
                    dtype: Dtype::F32,
                });
            }
        }
        spec
    }

    /// Parse a spec list out of the manifest's `graphs.<g>.params` array
    /// (authoritative over the locally-computed mirror; both are checked
    /// for equality by tests).
    pub fn spec_from_json(arr: &[Json]) -> Result<Vec<ParamSpec>, String> {
        arr.iter()
            .map(|item| {
                let triple = item.as_arr().ok_or("spec entry not an array")?;
                let name = triple[0].as_str().ok_or("spec name")?.to_string();
                let shape = triple[1]
                    .as_arr()
                    .ok_or("spec shape")?
                    .iter()
                    .map(|v| v.as_usize().ok_or("dim"))
                    .collect::<Result<Vec<_>, _>>()?;
                let dtype = Dtype::parse(triple[2].as_str().ok_or("dtype")?)
                    .ok_or("unknown dtype")?;
                Ok(ParamSpec { name, shape, dtype })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_sizes_consistent() {
        let cfg = ModelCfg::default();
        let fp = cfg.fp_param_spec();
        // embed + 4*(2 norms + 7 linears) + ln_f + lm_head
        assert_eq!(fp.len(), 1 + cfg.n_layers * 9 + 2);
        let q = cfg.quant_param_spec(R4Kind::GH);
        // 4 globals + per-layer (4 scales + 7*3 weights)
        assert_eq!(q.len(), 4 + cfg.n_layers * (4 + 21));
    }

    #[test]
    fn quant_blob_is_much_smaller_than_fp() {
        let cfg = ModelCfg::default();
        let fp_bytes: usize = cfg.fp_param_spec().iter().map(|s| s.nbytes()).sum();
        let q_bytes: usize = cfg
            .quant_param_spec(R4Kind::GH)
            .iter()
            .filter(|s| s.name.contains("_packed") || s.name.contains("_scale") || s.name.contains("_zero"))
            .map(|s| s.nbytes())
            .sum();
        // 2-bit + per-64 group affine ≈ 12.25× smaller than f32 linears.
        let fp_linears: usize = cfg
            .fp_param_spec()
            .iter()
            .filter(|s| s.name.contains(".w"))
            .map(|s| s.nbytes())
            .sum();
        assert!(q_bytes * 8 < fp_linears, "q {q_bytes} vs fp {fp_linears}");
        assert!(fp_bytes > q_bytes);
    }

    #[test]
    fn r4_kind_changes_sign_length() {
        let cfg = ModelCfg::default();
        let gh = cfg.quant_param_spec(R4Kind::GH);
        let lh = cfg.quant_param_spec(R4Kind::LH);
        let f = |spec: &[ParamSpec]| {
            spec.iter().find(|s| s.name == "r4_signs").unwrap().shape[0]
        };
        assert_eq!(f(&gh), cfg.d_ffn);
        assert_eq!(f(&lh), cfg.group);
    }
}
