//! Continuous-batching round composition: pure, deterministic helpers
//! the serving executor drives each scheduling turn.
//!
//! The scheduler's unit of work is the *pending feed*: a sequence's
//! token stream is `prompt ++ produced`, and `pending` counts how many
//! of those tokens the KV cache has not absorbed yet. A sequence with
//! exactly one pending token is decode-ready (the classic one-token
//! step); more than one pending means prefill — a fresh admission (the
//! whole prompt) or a preempted sequence recomputing its cache. When
//! the last pending token lands, that position's logits yield the next
//! pick — prefill and decode are one mechanism observed at different
//! depths.
//!
//! Composition is deterministic: inputs are scanned in the caller's
//! order (admission FIFO), decode members are the first `max_decode`
//! decode-ready sequences, and at most **one** prefill chunk (the
//! oldest prefilling sequence, clamped to `prefill_chunk` tokens) runs
//! per round — long prompts therefore never convoy the decode batch,
//! they trickle in beside it.

/// One sequence's scheduling-relevant state, in admission-FIFO order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SeqDesc {
    /// Admission id (monotone; ties impossible).
    pub id: u64,
    /// Tokens of `prompt ++ produced` not yet absorbed by the cache.
    pub pending: usize,
}

/// What one scheduling round executes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundPlan {
    /// Sequences stepping one decode token, FIFO order.
    pub decode: Vec<u64>,
    /// At most one `(id, chunk_len)` prefill chunk.
    pub prefill: Option<(u64, usize)>,
}

impl RoundPlan {
    pub fn is_empty(&self) -> bool {
        self.decode.is_empty() && self.prefill.is_none()
    }
}

/// Compose one round from `seqs` (admission-FIFO order): the first
/// `max_decode` decode-ready sequences step together, and the oldest
/// sequence still prefilling gets one chunk of at most `prefill_chunk`
/// tokens. Pure and order-preserving — identical inputs always compose
/// identical rounds.
pub fn compose_round(seqs: &[SeqDesc], max_decode: usize, prefill_chunk: usize) -> RoundPlan {
    let mut decode = Vec::new();
    for s in seqs {
        if s.pending == 1 && decode.len() < max_decode {
            decode.push(s.id);
        }
    }
    let prefill = seqs
        .iter()
        .find(|s| s.pending > 1)
        .map(|s| (s.id, s.pending.min(prefill_chunk.max(1))));
    RoundPlan { decode, prefill }
}

/// Blocks needed to hold `tokens` rows with `page`-row blocks.
pub fn blocks_for(tokens: usize, page: usize) -> usize {
    tokens.div_ceil(page.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(id: u64, pending: usize) -> SeqDesc {
        SeqDesc { id, pending }
    }

    #[test]
    fn decode_is_fifo_and_budgeted() {
        let seqs = [d(1, 1), d(2, 5), d(3, 1), d(4, 1), d(5, 1)];
        let plan = compose_round(&seqs, 3, 8);
        assert_eq!(plan.decode, vec![1, 3, 4], "first max_decode ready seqs, FIFO");
        assert_eq!(plan.prefill, Some((2, 5)));
    }

    #[test]
    fn one_prefill_chunk_per_round_oldest_first() {
        let seqs = [d(7, 10), d(8, 30), d(9, 1)];
        let plan = compose_round(&seqs, 4, 4);
        assert_eq!(plan.decode, vec![9]);
        assert_eq!(plan.prefill, Some((7, 4)), "oldest prefiller, chunk-clamped");
        // Chunk never exceeds what's pending.
        let plan = compose_round(&[d(7, 3)], 4, 4);
        assert_eq!(plan.prefill, Some((7, 3)));
    }

    #[test]
    fn empty_and_idle_inputs() {
        assert!(compose_round(&[], 4, 8).is_empty());
        let plan = compose_round(&[d(1, 0)], 4, 8);
        assert!(plan.is_empty(), "nothing pending composes nothing");
        // Zero chunk size is clamped to 1 rather than starving prefill.
        let plan = compose_round(&[d(1, 9)], 4, 0);
        assert_eq!(plan.prefill, Some((1, 1)));
    }

    #[test]
    fn identical_inputs_compose_identical_rounds() {
        let seqs = [d(3, 1), d(4, 6), d(5, 1)];
        assert_eq!(compose_round(&seqs, 2, 4), compose_round(&seqs, 2, 4));
    }

    #[test]
    fn blocks_for_rounds_up() {
        assert_eq!(blocks_for(0, 4), 0);
        assert_eq!(blocks_for(1, 4), 1);
        assert_eq!(blocks_for(4, 4), 1);
        assert_eq!(blocks_for(5, 4), 2);
        assert_eq!(blocks_for(9, 0), 9, "degenerate page clamps to 1");
    }
}
