//! Deterministic per-request sampling: temperature / top-k / top-p over
//! a private seeded [`SplitMix64`] stream.
//!
//! Replayability is the design constraint, not a side effect. Decode
//! logits are bit-identical for any batch composition, thread count and
//! KV layout (the repo's core invariant), so the only remaining source
//! of nondeterminism in a generation is the sampler. This one removes
//! it: candidates are ranked by a total order (logit descending via
//! `f32::total_cmp`, token id ascending on ties), probabilities are
//! computed in f64 with a fixed summation order, and **exactly one**
//! RNG draw is consumed per sampled token — so a request's picks depend
//! only on `(seed, prefix)` and never on co-scheduled traffic,
//! preemption, or round composition.

use crate::rng::SplitMix64;

/// Per-request sampling configuration. `temperature <= 0` (the
/// [`Default`]) means greedy argmax, which consumes no RNG.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `<= 0` (or non-finite) selects greedy.
    pub temperature: f64,
    /// Keep only the `top_k` highest-probability tokens (`0` = all).
    pub top_k: usize,
    /// Nucleus cut: smallest candidate prefix with cumulative
    /// probability `>= top_p` (`>= 1` or non-finite = no cut).
    pub top_p: f64,
    /// Seed of the request's private RNG stream.
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self::greedy()
    }
}

impl SamplingParams {
    /// Greedy decoding — argmax picks, no RNG consumption.
    pub fn greedy() -> Self {
        Self { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }

    /// Whether these parameters reduce to greedy argmax.
    pub fn is_greedy(&self) -> bool {
        !(self.temperature.is_finite() && self.temperature > 0.0)
    }

    /// Clamp out-of-range values instead of rejecting the request:
    /// non-finite or non-positive temperature → greedy; `top_p` outside
    /// `(0, 1)` → no nucleus cut.
    fn normalized(&self) -> Self {
        let temperature = if self.is_greedy() { 0.0 } else { self.temperature };
        let top_p = if self.top_p.is_finite() && self.top_p > 0.0 && self.top_p < 1.0 {
            self.top_p
        } else {
            1.0
        };
        Self { temperature, top_k: self.top_k, top_p, seed: self.seed }
    }
}

/// A request's sampling state: normalized parameters plus the private
/// RNG stream. Lives with the sequence across preemption/resume —
/// recomputing the KV cache replays the same logits, and the stream
/// position is untouched, so resumed picks are bit-identical.
pub struct Sampler {
    params: SamplingParams,
    rng: SplitMix64,
}

impl Sampler {
    pub fn new(params: &SamplingParams) -> Self {
        let params = params.normalized();
        let rng = SplitMix64::new(params.seed);
        Self { params, rng }
    }

    /// Whether picks are greedy (and therefore RNG-free).
    pub fn is_greedy(&self) -> bool {
        self.params.is_greedy()
    }

    /// Pick the next token from one position's logits. Greedy consumes
    /// no RNG; every non-greedy pick consumes exactly one draw, however
    /// the candidate set was truncated.
    pub fn pick(&mut self, logits: &[f32]) -> i32 {
        if self.params.is_greedy() || logits.len() < 2 {
            return crate::exec::greedy_argmax(logits);
        }
        // Total candidate order: logit descending, token id ascending.
        let mut ids: Vec<usize> = (0..logits.len()).collect();
        ids.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
        if self.params.top_k > 0 {
            ids.truncate(self.params.top_k.max(1));
        }
        // Softmax over the kept candidates: f64, max-subtracted, summed
        // in rank order.
        let m = logits[ids[0]] as f64;
        let t = self.params.temperature;
        let weights: Vec<f64> = ids.iter().map(|&i| ((logits[i] as f64 - m) / t).exp()).collect();
        let mut keep = weights.len();
        if self.params.top_p < 1.0 {
            let target = self.params.top_p * weights.iter().sum::<f64>();
            let mut cum = 0.0;
            for (i, w) in weights.iter().enumerate() {
                cum += w;
                if cum >= target {
                    keep = i + 1;
                    break;
                }
            }
        }
        let total: f64 = weights[..keep].iter().sum();
        let r = self.rng.next_f64() * total;
        let mut cum = 0.0;
        for (&idx, w) in ids[..keep].iter().zip(&weights[..keep]) {
            cum += w;
            if r < cum {
                return idx as i32;
            }
        }
        // Numeric edge (r lands on the total): last kept candidate.
        ids[keep - 1] as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_matches_argmax_and_consumes_no_rng() {
        let logits = vec![0.1f32, 2.0, -1.0, 2.0];
        let mut s = Sampler::new(&SamplingParams::greedy());
        assert!(s.is_greedy());
        let before = s.rng.clone().next_u64();
        assert_eq!(s.pick(&logits), 1, "first max wins");
        assert_eq!(s.rng.clone().next_u64(), before, "greedy must not touch the stream");
    }

    #[test]
    fn invalid_params_degrade_to_safe_values() {
        let p = SamplingParams { temperature: f64::NAN, top_k: 3, top_p: -2.0, seed: 7 };
        assert!(p.is_greedy());
        let mut s = Sampler::new(&p);
        assert_eq!(s.pick(&[0.0, 5.0, 1.0]), 1);
    }

    #[test]
    fn top_k_one_is_argmax_but_still_draws() {
        let p = SamplingParams { temperature: 0.7, top_k: 1, top_p: 1.0, seed: 3 };
        let mut s = Sampler::new(&p);
        for _ in 0..20 {
            assert_eq!(s.pick(&[0.0, 1.0, 3.0, 2.0]), 2);
        }
    }

    #[test]
    fn replay_is_bit_identical_and_one_draw_per_pick() {
        let p = SamplingParams { temperature: 1.0, top_k: 0, top_p: 0.9, seed: 42 };
        let logits: Vec<Vec<f32>> = (0..32)
            .map(|i| (0..16).map(|j| (((i * 31 + j * 17) % 23) as f32) * 0.3 - 2.0).collect())
            .collect();
        let run = |p: &SamplingParams| -> Vec<i32> {
            let mut s = Sampler::new(p);
            logits.iter().map(|l| s.pick(l)).collect()
        };
        assert_eq!(run(&p), run(&p), "same seed must replay identically");
        // One draw per pick: a sampler that made N picks sits exactly N
        // draws into its stream.
        let mut s = Sampler::new(&p);
        let mut reference = SplitMix64::new(42);
        for l in &logits {
            s.pick(l);
            reference.next_f64();
        }
        assert_eq!(s.rng.next_u64(), reference.next_u64(), "stream must advance one draw per pick");
    }

    #[test]
    fn nucleus_cut_excludes_tail_tokens() {
        // One dominant token: tiny top_p can only ever pick it.
        let p = SamplingParams { temperature: 0.5, top_k: 0, top_p: 0.5, seed: 11 };
        let mut s = Sampler::new(&p);
        let logits = vec![10.0f32, 0.0, 0.0, 0.0];
        for _ in 0..50 {
            assert_eq!(s.pick(&logits), 0);
        }
    }

    #[test]
    fn samples_spread_over_flat_distribution() {
        let p = SamplingParams { temperature: 1.0, top_k: 0, top_p: 1.0, seed: 5 };
        let mut s = Sampler::new(&p);
        let logits = vec![0.0f32; 8];
        let mut seen = [false; 8];
        for _ in 0..256 {
            seen[s.pick(&logits) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "uniform logits should hit every token");
    }
}
