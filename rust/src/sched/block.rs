//! Paged-KV block pool: a fixed inventory of [`KvBlock`]s granted to
//! sequences and reclaimed on completion, preemption or eviction.
//!
//! Allocation is deterministic — the free list is ordered by block id
//! and `alloc` always hands out the lowest free id — so two runs that
//! issue the same alloc/release stream receive identical block-id
//! sequences. Blocks physically move (by value) between the pool and a
//! sequence's paged `KvCache`; nothing is shared, so a granted block
//! can be written by its owner while the pool is untouched.

use crate::model::KvBlock;
use std::collections::BTreeMap;

/// Inventory of KV blocks for one serving variant.
pub struct BlockPool {
    n_layers: usize,
    width: usize,
    page: usize,
    total: usize,
    /// Free blocks keyed by id — `BTreeMap` iteration order makes the
    /// lowest-id-first policy (and thus allocation) deterministic.
    free: BTreeMap<u32, KvBlock>,
    in_use: usize,
    peak: usize,
}

impl BlockPool {
    /// Mint `total_blocks` zero-filled blocks (ids `0..total_blocks`) of
    /// `page` token rows each for the given model geometry.
    pub fn new(n_layers: usize, width: usize, page: usize, total_blocks: usize) -> Self {
        let page = page.max(1);
        let free = (0..total_blocks as u32)
            .map(|id| (id, KvBlock::new(id, n_layers, page, width)))
            .collect();
        Self { n_layers, width, page, total: total_blocks, free, in_use: 0, peak: 0 }
    }

    /// Token rows per block.
    pub fn page_size(&self) -> usize {
        self.page
    }

    /// Total inventory, in blocks.
    pub fn total_blocks(&self) -> usize {
        self.total
    }

    /// Total inventory, in token rows — the admission bound for peak
    /// sequence occupancy.
    pub fn total_tokens(&self) -> usize {
        self.total * self.page
    }

    /// Blocks currently available.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently granted out.
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// High-water mark of granted blocks.
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Model geometry the pool's blocks were minted for.
    pub fn geometry(&self) -> (usize, usize) {
        (self.n_layers, self.width)
    }

    /// Grant the lowest-id free block, or `None` when the pool is dry.
    pub fn alloc(&mut self) -> Option<KvBlock> {
        let id = *self.free.keys().next()?;
        let block = self.free.remove(&id)?;
        self.in_use += 1;
        self.peak = self.peak.max(self.in_use);
        Some(block)
    }

    /// Return a block to the free list.
    ///
    /// Panics (debug assertion) on double-free of an id — block ids are
    /// unique within a pool, so a collision means a block was cloned or
    /// forged rather than round-tripped.
    pub fn release(&mut self, block: KvBlock) {
        let prev = self.free.insert(block.id(), block);
        debug_assert!(prev.is_none(), "block released twice");
        self.in_use = self.in_use.saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_lowest_id_first_and_conserving() {
        let mut pool = BlockPool::new(2, 8, 4, 3);
        assert_eq!((pool.total_blocks(), pool.total_tokens()), (3, 12));
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!((a.id(), b.id()), (0, 1));
        assert_eq!((pool.free_blocks(), pool.in_use()), (1, 2));
        pool.release(a);
        // Lowest id again, even though 0 was released after 1 was taken.
        let c = pool.alloc().unwrap();
        assert_eq!(c.id(), 0);
        let d = pool.alloc().unwrap();
        assert_eq!(d.id(), 2);
        assert!(pool.alloc().is_none(), "pool must run dry at total_blocks");
        pool.release(b);
        pool.release(c);
        pool.release(d);
        assert_eq!((pool.free_blocks(), pool.in_use()), (3, 0));
        assert_eq!(pool.peak(), 3);
    }

    #[test]
    fn empty_pool_allocs_nothing() {
        let mut pool = BlockPool::new(1, 4, 2, 0);
        assert!(pool.alloc().is_none());
        assert_eq!(pool.total_tokens(), 0);
    }
}
