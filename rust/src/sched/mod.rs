//! Paged-KV serving primitives: block pool, continuous-batching round
//! policy, and deterministic sampling.
//!
//! This module holds the *mechanisms* the serving coordinator composes
//! into a continuous-batching scheduler:
//!
//! * [`BlockPool`] — a fixed inventory of `KvBlock`s with a
//!   deterministic (lowest-free-id) allocator; sequences are admitted
//!   against the pool's **total** token inventory instead of reserving
//!   peak occupancy up front, and under pressure the scheduler preempts
//!   the youngest block-holding sequence (recompute-on-resume) so the
//!   oldest always makes progress.
//! * [`compose_round`] / [`SeqDesc`] / [`RoundPlan`] — pure FIFO+budget
//!   round composition: decode-ready sequences batch together, and at
//!   most one bounded prefill chunk rides along per round so long
//!   prompts never convoy decodes.
//! * [`Sampler`] / [`SamplingParams`] — temperature / top-k / top-p
//!   sampling over a per-request seeded stream, consuming exactly one
//!   draw per pick; combined with bit-deterministic decode logits this
//!   makes every generation replayable regardless of co-scheduled
//!   traffic.
//!
//! Everything here is pure or locally-owned state — no threads, no
//! channels — which is what keeps the scheduler's decisions replayable
//! and unit-testable.

pub mod block;
pub mod policy;
pub mod sampler;

pub use block::BlockPool;
pub use policy::{blocks_for, compose_round, RoundPlan, SeqDesc};
pub use sampler::{Sampler, SamplingParams};

/// Self-speculative decoding configuration: a cheap resident variant
/// drafts `k` tokens per round and the request's target variant
/// verifies them in one batched forward. Acceptance replays the
/// target's own sampling decision against the verify logits, so output
/// is token-for-token identical to non-speculative decode — speculation
/// only changes *when* forwards run, never what is emitted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecConfig {
    /// Resident variant that proposes draft tokens (greedy argmax).
    pub draft: String,
    /// Draft tokens proposed per draft/verify round.
    pub k: usize,
}

impl SpecConfig {
    /// Parse the CLI form `DRAFT[:k]` (default k = 4). Rejects empty
    /// names and `k == 0` — a zero-token draft round cannot progress.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let (draft, k) = match spec.rsplit_once(':') {
            Some((name, k)) => {
                let k = k
                    .parse::<usize>()
                    .map_err(|_| format!("--speculate: bad draft length {k:?} in {spec:?}"))?;
                (name, k)
            }
            None => (spec, 4),
        };
        if draft.is_empty() {
            return Err("--speculate needs a draft variant name (DRAFT[:k])".to_string());
        }
        if k == 0 {
            return Err("--speculate: draft length k must be at least 1".to_string());
        }
        Ok(Self { draft: draft.to_string(), k })
    }
}

/// Scheduler configuration carried from the CLI into the serving
/// executor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SchedConfig {
    /// Token rows per KV block.
    pub page_size: usize,
    /// Total blocks in each variant's pool (`0` = auto-size: enough
    /// blocks for `batch` sequences of `seq` tokens each).
    pub kv_blocks: usize,
    /// Maximum prompt tokens absorbed per prefill chunk.
    pub prefill_chunk: usize,
    /// Speculative decoding (`None` = plain one-token decode rounds).
    pub speculate: Option<SpecConfig>,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self { page_size: 16, kv_blocks: 0, prefill_chunk: 32, speculate: None }
    }
}

impl SchedConfig {
    /// Pool size in blocks for a backend with `batch` concurrent
    /// sequences of up to `seq` tokens: the configured count, or the
    /// auto-size that matches the old per-sequence contiguous capacity.
    pub fn pool_blocks(&self, batch: usize, seq: usize) -> usize {
        if self.kv_blocks > 0 {
            self.kv_blocks
        } else {
            batch.max(1) * blocks_for(seq, self.page_size)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_auto_size_matches_contiguous_capacity() {
        let cfg = SchedConfig::default();
        // 4 seqs x 64 tokens at page 16 = 4 blocks per seq.
        assert_eq!(cfg.pool_blocks(4, 64), 16);
        // Explicit count wins.
        let cfg = SchedConfig { kv_blocks: 5, ..SchedConfig::default() };
        assert_eq!(cfg.pool_blocks(4, 64), 5);
        // Unaligned seq rounds up.
        let cfg = SchedConfig { page_size: 16, ..SchedConfig::default() };
        assert_eq!(cfg.pool_blocks(1, 17), 2);
    }

    #[test]
    fn spec_config_parses_draft_and_k() {
        assert_eq!(SpecConfig::parse("q2").unwrap(), SpecConfig { draft: "q2".into(), k: 4 });
        assert_eq!(
            SpecConfig::parse("searched:6").unwrap(),
            SpecConfig { draft: "searched".into(), k: 6 }
        );
        assert!(SpecConfig::parse("").is_err(), "empty spec");
        assert!(SpecConfig::parse(":3").is_err(), "missing draft name");
        assert!(SpecConfig::parse("q2:0").is_err(), "zero draft length");
        assert!(SpecConfig::parse("q2:x").is_err(), "non-numeric draft length");
    }
}
