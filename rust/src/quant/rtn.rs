//! Round-to-nearest group quantization with MSE-based clipping.

use super::QuantizedLinear;
use crate::transform::Mat;

/// Clip-factor search grid (paper A.1: MSE-based clipping).
pub const CLIP_GRID: [f64; 13] = [
    0.4, 0.45, 0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95, 1.0,
];

/// Scale/zero for one `[G, H]` group slice (rows `rows`, row-major with
/// stride `h`). Asymmetric; per-output-channel MSE clip search when
/// `mse_clip`. Returns `(scale, zero)` each of length `h`.
pub fn group_params(rows: &[&[f64]], h: usize, bits: u32, mse_clip: bool) -> (Vec<f64>, Vec<f64>) {
    let qmax = ((1u32 << bits) - 1) as f64;
    let mut lo = vec![f64::INFINITY; h];
    let mut hi = vec![f64::NEG_INFINITY; h];
    for row in rows {
        for (c, &v) in row.iter().enumerate() {
            lo[c] = lo[c].min(v);
            hi[c] = hi[c].max(v);
        }
    }
    let base: Vec<(f64, f64)> = (0..h)
        .map(|c| {
            let s = ((hi[c] - lo[c]) / qmax).max(1e-12);
            (s, (-lo[c] / s).round())
        })
        .collect();
    if !mse_clip {
        return (base.iter().map(|p| p.0).collect(), base.iter().map(|p| p.1).collect());
    }
    let mut best_err = vec![f64::INFINITY; h];
    let mut out_s: Vec<f64> = base.iter().map(|p| p.0).collect();
    let mut out_z: Vec<f64> = base.iter().map(|p| p.1).collect();
    for &k in CLIP_GRID.iter() {
        for c in 0..h {
            let s = ((hi[c] * k - lo[c] * k) / qmax).max(1e-12);
            let z = (-lo[c] * k / s).round();
            let mut err = 0.0;
            for row in rows {
                let q = (row[c] / s + z).round().clamp(0.0, qmax);
                let deq = (q - z) * s;
                err += (deq - row[c]) * (deq - row[c]);
            }
            if err < best_err[c] {
                best_err[c] = err;
                out_s[c] = s;
                out_z[c] = z;
            }
        }
    }
    (out_s, out_z)
}

/// Plain RTN group quantization of `w` (`[C, H]`, groups along C).
pub fn rtn_quantize(w: &Mat, bits: u32, group: usize, mse_clip: bool) -> QuantizedLinear {
    let (c, h) = (w.rows, w.cols);
    assert_eq!(c % group, 0, "group must divide input channels");
    let qmax = ((1u32 << bits) - 1) as f64;
    let n_groups = c / group;
    let mut codes = vec![0i32; c * h];
    let mut scale = vec![0.0; n_groups * h];
    let mut zero = vec![0.0; n_groups * h];
    for g in 0..n_groups {
        let rows: Vec<&[f64]> = (0..group).map(|r| w.row(g * group + r)).collect();
        let (s, z) = group_params(&rows, h, bits, mse_clip);
        scale[g * h..(g + 1) * h].copy_from_slice(&s);
        zero[g * h..(g + 1) * h].copy_from_slice(&z);
        for r in 0..group {
            let row = g * group + r;
            for col in 0..h {
                let q = (w[(row, col)] / s[col] + z[col]).round().clamp(0.0, qmax);
                codes[row * h + col] = q as i32;
            }
        }
    }
    QuantizedLinear { codes, scale, zero, c, h, group, bits }
}

/// Symmetric per-group activation fake-quant along a vector (last axis),
/// QuaRot-style with a clip ratio. In-place.
pub fn fake_quant_sym(x: &mut [f64], bits: u32, group: usize, clip_ratio: f64) {
    assert_eq!(x.len() % group, 0);
    let qmax = ((1u32 << (bits - 1)) - 1) as f64;
    for chunk in x.chunks_mut(group) {
        let absmax = chunk.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        let scale = (clip_ratio * absmax / qmax).max(1e-30);
        for v in chunk.iter_mut() {
            let q = (*v / scale).round().clamp(-qmax, qmax);
            *v = q * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn random_mat(c: usize, h: usize, seed: u64) -> Mat {
        let mut rng = SplitMix64::new(seed);
        Mat::from_fn(c, h, |_, _| rng.next_normal())
    }

    #[test]
    fn rtn_error_bounded_by_half_step_unclipped() {
        let w = random_mat(32, 8, 1);
        let q = rtn_quantize(&w, 4, 8, false);
        let deq = q.dequant();
        for g in 0..4 {
            for r in 0..8 {
                for c in 0..8 {
                    let row = g * 8 + r;
                    let s = q.scale[g * 8 + c];
                    assert!(
                        (deq[(row, c)] - w[(row, c)]).abs() <= s * 0.5 + 1e-9,
                        "error exceeds half step"
                    );
                }
            }
        }
    }

    #[test]
    fn mse_clip_never_hurts() {
        let w = random_mat(64, 16, 2);
        let plain = rtn_quantize(&w, 2, 16, false).mse(&w);
        let clipped = rtn_quantize(&w, 2, 16, true).mse(&w);
        assert!(clipped <= plain + 1e-12, "clip {clipped} > plain {plain}");
    }

    #[test]
    fn codes_in_range() {
        let w = random_mat(16, 4, 3);
        for bits in [2u32, 3, 4] {
            let q = rtn_quantize(&w, bits, 4, true);
            let qmax = (1i32 << bits) - 1;
            assert!(q.codes.iter().all(|&c| (0..=qmax).contains(&c)));
        }
    }

    #[test]
    fn fake_quant_sym_idempotent_at_full_range() {
        // With clip 1.0 the grid absmax is attained, so re-quantizing is
        // a fixed point. (With clip < 1 the envelope keeps shrinking —
        // that is why the clip is applied once, in-graph, not iterated.)
        let mut rng = SplitMix64::new(4);
        let mut x: Vec<f64> = (0..64).map(|_| rng.next_normal()).collect();
        fake_quant_sym(&mut x, 4, 16, 1.0);
        let once = x.clone();
        fake_quant_sym(&mut x, 4, 16, 1.0);
        for (a, b) in once.iter().zip(&x) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_vector_is_fixed_point() {
        let mut x = vec![0.0; 32];
        fake_quant_sym(&mut x, 4, 8, 0.9);
        assert!(x.iter().all(|&v| v == 0.0));
    }
}
