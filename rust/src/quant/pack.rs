//! 2-/4-bit code packing — LSB-first along input channels.
//!
//! Must match `python/compile/kernels/ref.py::pack2`/`pack4` bit-for-bit
//! (the AOT weight blobs are produced by the Python side and consumed
//! here, and the packed-domain kernels in `model::kernels` index these
//! layouts directly).

/// Pack codes `[C, H]` (values 0..3, row-major) into `[C/4, H]` bytes.
/// Byte `b` of a column holds channels `4b..4b+4` in bits
/// `[0:2] [2:4] [4:6] [6:8]`.
pub fn pack2(codes: &[i32], c: usize, h: usize) -> Vec<u8> {
    assert_eq!(codes.len(), c * h);
    assert_eq!(c % 4, 0, "input channels must be a multiple of 4");
    let mut out = vec![0u8; c / 4 * h];
    for cb in 0..c / 4 {
        for col in 0..h {
            let mut byte = 0u8;
            for k in 0..4 {
                let code = codes[(cb * 4 + k) * h + col];
                debug_assert!((0..4).contains(&code), "code {code} out of 2-bit range");
                byte |= ((code as u8) & 3) << (2 * k);
            }
            out[cb * h + col] = byte;
        }
    }
    out
}

/// Inverse of [`pack2`].
pub fn unpack2(packed: &[u8], c: usize, h: usize) -> Vec<i32> {
    assert_eq!(packed.len(), c / 4 * h);
    let mut out = vec![0i32; c * h];
    for cb in 0..c / 4 {
        for col in 0..h {
            let byte = packed[cb * h + col];
            for k in 0..4 {
                out[(cb * 4 + k) * h + col] = ((byte >> (2 * k)) & 3) as i32;
            }
        }
    }
    out
}

/// Pack codes `[C, H]` (values 0..15, row-major) into `[C/2, H]` bytes.
/// Byte `b` of a column holds channels `2b..2b+2` in bits `[0:4] [4:8]`.
pub fn pack4(codes: &[i32], c: usize, h: usize) -> Vec<u8> {
    assert_eq!(codes.len(), c * h);
    assert_eq!(c % 2, 0, "input channels must be a multiple of 2");
    let mut out = vec![0u8; c / 2 * h];
    for cb in 0..c / 2 {
        for col in 0..h {
            let mut byte = 0u8;
            for k in 0..2 {
                let code = codes[(cb * 2 + k) * h + col];
                debug_assert!((0..16).contains(&code), "code {code} out of 4-bit range");
                byte |= ((code as u8) & 0xF) << (4 * k);
            }
            out[cb * h + col] = byte;
        }
    }
    out
}

/// Inverse of [`pack4`].
pub fn unpack4(packed: &[u8], c: usize, h: usize) -> Vec<i32> {
    assert_eq!(packed.len(), c / 2 * h);
    let mut out = vec![0i32; c * h];
    for cb in 0..c / 2 {
        for col in 0..h {
            let byte = packed[cb * h + col];
            for k in 0..2 {
                out[(cb * 2 + k) * h + col] = ((byte >> (4 * k)) & 0xF) as i32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    #[test]
    fn roundtrip_random() {
        let mut rng = SplitMix64::new(1);
        let (c, h) = (64, 24);
        let codes: Vec<i32> = (0..c * h).map(|_| rng.next_below(4) as i32).collect();
        assert_eq!(unpack2(&pack2(&codes, c, h), c, h), codes);
    }

    #[test]
    fn bit_layout_lsb_first() {
        // Channels (3, 2, 1, 0) for one column → byte 0b00_01_10_11.
        let codes = vec![3, 2, 1, 0];
        let packed = pack2(&codes, 4, 1);
        assert_eq!(packed, vec![0b00_01_10_11]);
    }

    #[test]
    fn compression_ratio() {
        let codes = vec![0i32; 128 * 16];
        assert_eq!(pack2(&codes, 128, 16).len() * 4, codes.len());
    }

    #[test]
    fn roundtrip_random_int4() {
        let mut rng = SplitMix64::new(2);
        let (c, h) = (64, 24);
        let codes: Vec<i32> = (0..c * h).map(|_| rng.next_below(16) as i32).collect();
        assert_eq!(unpack4(&pack4(&codes, c, h), c, h), codes);
    }

    #[test]
    fn bit_layout_lsb_first_int4() {
        // Channels (0xA, 0x5) for one column → byte 0b0101_1010 = 0x5A
        // (channel 0 in the low nibble — same LSB-first rule as pack2).
        let codes = vec![0xA, 0x5];
        let packed = pack4(&codes, 2, 1);
        assert_eq!(packed, vec![0x5A]);
    }

    #[test]
    fn compression_ratio_int4() {
        let codes = vec![0i32; 128 * 16];
        assert_eq!(pack4(&codes, 128, 16).len() * 2, codes.len());
    }

    #[test]
    fn pack4_multi_column_layout() {
        // Two columns, four channels: byte (cb, col) holds channels
        // (2cb, 2cb+1) of that column.
        let codes = vec![
            1, 2, // channels 0
            3, 4, // channels 1
            5, 6, // channels 2
            7, 8, // channels 3
        ];
        let packed = pack4(&codes, 4, 2);
        assert_eq!(packed, vec![0x31, 0x42, 0x75, 0x86]);
    }
}
