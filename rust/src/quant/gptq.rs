//! Native GPTQ (Frantar et al., 2022) — error-feedback group quantization.
//!
//! Mirrors `python/compile/gptq.py::gptq_quantize`; used by the analysis
//! benches (rotated-weight quantization error per R1 kind) and available
//! as a standalone API. See `quant/mod.rs` for conventions.

use super::linalg::{cholesky_upper, spd_inverse};
use super::rtn::group_params;
use super::QuantizedLinear;
use crate::transform::Mat;

/// Hessian dampening fraction (matches the Python pipeline).
pub const DAMP_FRAC: f64 = 0.01;

/// The weight-independent part of GPTQ: the damped Hessian's inverse
/// Cholesky factor plus the dead-channel mask. Precompute once per
/// Hessian and share across every linear quantized against it — the
/// calibrated pipeline feeds one activation Hessian to wq/wk/wv (and
/// one to wgate/wup), so hoisting the O(C³) inversion out of
/// [`gptq_quantize`] removes the dominant duplicated cost.
pub struct GptqFactor {
    /// Upper Cholesky factor of the damped Hessian's inverse, `[C, C]`.
    pub hinv_u: Mat,
    /// Channels whose Hessian diagonal was exactly zero (their weights
    /// are pinned to 0 during quantization).
    pub dead: Vec<bool>,
}

/// Factor a calibration Hessian (`Xᵀ X`, `[C, C]`) for GPTQ.
pub fn gptq_factor(hessian: &Mat) -> GptqFactor {
    let c = hessian.rows;
    assert_eq!((hessian.rows, hessian.cols), (c, c));
    let mut hess = hessian.clone();
    // Dead channels: zero diagonal → pin to 1 (weights zeroed later).
    let mut dead = vec![false; c];
    for i in 0..c {
        if hess[(i, i)] == 0.0 {
            hess[(i, i)] = 1.0;
            dead[i] = true;
        }
    }
    let mean_diag: f64 = (0..c).map(|i| hess[(i, i)]).sum::<f64>() / c as f64;
    for i in 0..c {
        hess[(i, i)] += DAMP_FRAC * mean_diag;
    }
    let hinv = spd_inverse(&hess).expect("damped Hessian must be SPD");
    let hinv_u = cholesky_upper(&hinv).expect("inverse Hessian must be SPD");
    GptqFactor { hinv_u, dead }
}

/// GPTQ: walk input channels in order; quantize each against its group's
/// scale/zero, then propagate the weighted residual into not-yet-
/// quantized channels through the inverse-Hessian Cholesky factor.
///
/// `hessian` is `Xᵀ X` over calibration inputs (`[C, C]`). To quantize
/// several linears against one Hessian, call [`gptq_factor`] once and
/// use [`gptq_quantize_factored`].
pub fn gptq_quantize(
    w: &Mat,
    hessian: &Mat,
    bits: u32,
    group: usize,
    mse_clip: bool,
) -> QuantizedLinear {
    assert_eq!((hessian.rows, hessian.cols), (w.rows, w.rows));
    gptq_quantize_factored(w, &gptq_factor(hessian), bits, group, mse_clip)
}

/// [`gptq_quantize`] against a prefactored Hessian.
pub fn gptq_quantize_factored(
    w: &Mat,
    factor: &GptqFactor,
    bits: u32,
    group: usize,
    mse_clip: bool,
) -> QuantizedLinear {
    let (c, h) = (w.rows, w.cols);
    assert_eq!(c % group, 0);
    assert_eq!((factor.hinv_u.rows, factor.hinv_u.cols), (c, c));
    let qmax = ((1u32 << bits) - 1) as f64;

    let mut work = w.clone();
    for (i, &is_dead) in factor.dead.iter().enumerate() {
        if is_dead {
            for col in 0..h {
                work[(i, col)] = 0.0;
            }
        }
    }
    let hinv_u = &factor.hinv_u;

    let n_groups = c / group;
    let mut codes = vec![0i32; c * h];
    let mut scale = vec![0.0; n_groups * h];
    let mut zero = vec![0.0; n_groups * h];

    for g in 0..n_groups {
        let lo = g * group;
        let hi = (g + 1) * group;
        // Group params from the *current* (error-compensated) weights.
        let rows: Vec<&[f64]> = (lo..hi).map(|r| work.row(r)).collect();
        let (s, z) = group_params(&rows, h, bits, mse_clip);
        scale[g * h..(g + 1) * h].copy_from_slice(&s);
        zero[g * h..(g + 1) * h].copy_from_slice(&z);
        for cc in lo..hi {
            let d = hinv_u[(cc, cc)];
            let mut err = vec![0.0; h];
            for col in 0..h {
                let wv = work[(cc, col)];
                let q = (wv / s[col] + z[col]).round().clamp(0.0, qmax);
                codes[cc * h + col] = q as i32;
                let deq = (q - z[col]) * s[col];
                err[col] = (wv - deq) / d;
                work[(cc, col)] = deq;
            }
            // Propagate into all remaining channels.
            for rr in cc + 1..c {
                let u = hinv_u[(cc, rr)];
                if u == 0.0 {
                    continue;
                }
                let row = work.row_mut(rr);
                for (col, &e) in err.iter().enumerate() {
                    row[col] -= u * e;
                }
            }
        }
    }
    QuantizedLinear { codes, scale, zero, c, h, group, bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn_quantize;
    use crate::rng::SplitMix64;

    fn correlated_inputs(n: usize, c: usize, seed: u64) -> Vec<Vec<f64>> {
        // Activations with channel correlation + a couple of outlier
        // channels — the regime where GPTQ beats RTN.
        let mut rng = SplitMix64::new(seed);
        (0..n)
            .map(|_| {
                let base = rng.next_normal();
                (0..c)
                    .map(|j| {
                        let amp = if j % 17 == 0 { 8.0 } else { 1.0 };
                        amp * (0.6 * base + 0.4 * rng.next_normal())
                    })
                    .collect()
            })
            .collect()
    }

    fn hessian_of(x: &[Vec<f64>], c: usize) -> Mat {
        let mut h = Mat::zeros(c, c);
        for row in x {
            for i in 0..c {
                for j in 0..c {
                    h[(i, j)] += row[i] * row[j];
                }
            }
        }
        for v in h.data.iter_mut() {
            *v /= x.len() as f64;
        }
        h
    }

    fn proxy_loss(w: &Mat, q: &QuantizedLinear, x: &[Vec<f64>]) -> f64 {
        // ‖X ΔW‖² — the objective GPTQ actually minimizes.
        let dw = {
            let deq = q.dequant();
            Mat::from_fn(w.rows, w.cols, |r, c| deq[(r, c)] - w[(r, c)])
        };
        let mut total = 0.0;
        for row in x {
            let y = dw.apply_right(row);
            total += y.iter().map(|v| v * v).sum::<f64>();
        }
        total
    }

    #[test]
    fn gptq_beats_rtn_on_proxy_loss() {
        let c = 32;
        let hcols = 16;
        let mut rng = SplitMix64::new(7);
        let w = Mat::from_fn(c, hcols, |_, _| rng.next_normal());
        let x = correlated_inputs(128, c, 8);
        let hess = hessian_of(&x, c);
        let q_gptq = gptq_quantize(&w, &hess, 2, 8, true);
        let q_rtn = rtn_quantize(&w, 2, 8, true);
        let l_gptq = proxy_loss(&w, &q_gptq, &x);
        let l_rtn = proxy_loss(&w, &q_rtn, &x);
        assert!(
            l_gptq < l_rtn,
            "GPTQ {l_gptq:.4} should beat RTN {l_rtn:.4} on ‖XΔW‖²"
        );
    }

    #[test]
    fn identity_hessian_reduces_to_groupwise_rtn_error_level() {
        // With H = I there is no cross-channel signal; GPTQ error should
        // be close to RTN's (it cannot be dramatically worse).
        let c = 16;
        let mut rng = SplitMix64::new(9);
        let w = Mat::from_fn(c, 8, |_, _| rng.next_normal());
        let q = gptq_quantize(&w, &Mat::identity(c), 4, 8, false);
        let rtn = rtn_quantize(&w, 4, 8, false);
        assert!(q.mse(&w) <= rtn.mse(&w) * 1.5 + 1e-9);
    }

    /// Reusing one factor across linears is exactly the direct path.
    #[test]
    fn factored_path_matches_direct() {
        let c = 32;
        let mut rng = SplitMix64::new(12);
        let w = Mat::from_fn(c, 8, |_, _| rng.next_normal());
        let w2 = Mat::from_fn(c, 8, |_, _| rng.next_normal());
        let hess = hessian_of(&correlated_inputs(64, c, 13), c);
        let factor = gptq_factor(&hess);
        for weight in [&w, &w2] {
            let direct = gptq_quantize(weight, &hess, 2, 8, true);
            let shared = gptq_quantize_factored(weight, &factor, 2, 8, true);
            assert_eq!(direct.codes, shared.codes);
            assert_eq!(direct.scale, shared.scale);
            assert_eq!(direct.zero, shared.zero);
        }
    }

    #[test]
    fn codes_in_range_and_shape() {
        let c = 16;
        let mut rng = SplitMix64::new(10);
        let w = Mat::from_fn(c, 4, |_, _| rng.next_normal());
        let x = correlated_inputs(64, c, 11);
        let q = gptq_quantize(&w, &hessian_of(&x, c), 2, 4, true);
        assert_eq!(q.codes.len(), c * 4);
        assert!(q.codes.iter().all(|&v| (0..4).contains(&v)));
    }
}
