//! Native end-to-end quantization pipeline: fuse rotations → GPTQ → pack.
//!
//! A Rust mirror of `python/compile/quantize.py` over an fp checkpoint
//! blob — downstream users can produce new quantized variants without
//! the Python toolchain (`gsr quantize-native`). It is also the second,
//! independent implementation of the paper's R1–R4 fusion rules: the
//! Fig.-1 invariance test below checks `forward(fuse(params)) ≡
//! forward(params)` natively, with no JAX in the loop.
//!
//! Calibration here is identity-Hessian GPTQ (per-channel error feedback
//! without cross-channel reordering); the Python path remains the
//! reference for Hessian-calibrated GPTQ.

use std::collections::BTreeMap;

use super::{gptq_quantize, QuantizedLinear};
use crate::model::config::{ModelCfg, R4Kind, LINEARS};
use crate::model::weights::{FpParams, QuantLayer, QuantParams};
use crate::rng::SplitMix64;
use crate::transform::{block_diag, build_r1, hadamard, rht, Mat, R1Kind};

/// The shared rotation set for one variant.
pub struct RotationSet {
    pub r1: Mat,
    pub r2: Mat,
    pub r3: Mat,
    pub r4: Mat,
    pub r4_signs: Vec<f64>,
    pub r4_kind: R4Kind,
}

/// Build rotations deterministically (seed-pinned like the Python path).
pub fn build_rotations(cfg: &ModelCfg, r1_kind: R1Kind, r4_kind: R4Kind, seed: u64) -> RotationSet {
    let mut rng = SplitMix64::new(seed);
    let r1 = build_r1(r1_kind, cfg.d_model, cfg.group, &mut rng);
    let r2 = rht(cfg.head_dim(), &mut rng);
    let r3 = rht(cfg.head_dim(), &mut rng);
    let (r4, r4_signs) = match r4_kind {
        R4Kind::GH => {
            let signs: Vec<f64> = (0..cfg.d_ffn).map(|_| rng.next_sign()).collect();
            let mut h = hadamard(cfg.d_ffn);
            for r in 0..cfg.d_ffn {
                for (c, &s) in signs.iter().enumerate() {
                    h[(r, c)] *= s;
                }
            }
            (h, signs)
        }
        R4Kind::LH => {
            let signs: Vec<f64> = (0..cfg.group).map(|_| rng.next_sign()).collect();
            let mut b = hadamard(cfg.group);
            for r in 0..cfg.group {
                for (c, &s) in signs.iter().enumerate() {
                    b[(r, c)] *= s;
                }
            }
            (block_diag(&b, cfg.d_ffn), signs)
        }
    };
    RotationSet { r1, r2, r3, r4, r4_signs, r4_kind }
}

fn to_mat(w: &[f32], rows: usize, cols: usize) -> Mat {
    assert_eq!(w.len(), rows * cols);
    Mat { data: w.iter().map(|&v| v as f64).collect(), rows, cols }
}

fn to_f32(m: &Mat) -> Vec<f32> {
    m.data.iter().map(|&v| v as f32).collect()
}

fn scale_rows(mut m: Mat, gamma: &[f32]) -> Mat {
    for r in 0..m.rows {
        let g = gamma[r] as f64;
        for v in m.row_mut(r) {
            *v *= g;
        }
    }
    m
}

/// Fused, rotated dense weights for one variant (mirror of
/// `model.fuse_rotations` + `fuse_r4`). Returns
/// `(embed', lm_head', per-layer {name → Mat})`.
pub fn fuse_rotations(
    fp: &FpParams,
    cfg: &ModelCfg,
    rots: &RotationSet,
) -> (Mat, Mat, Vec<BTreeMap<String, Mat>>) {
    let d = cfg.d_model;
    let r1 = &rots.r1;
    let r1t = r1.transpose();
    // B2 = I_heads ⊗ R2.
    let b2 = {
        let mut m = Mat::zeros(d, d);
        let dh = cfg.head_dim();
        for h in 0..cfg.n_heads {
            for r in 0..dh {
                for c in 0..dh {
                    m[(h * dh + r, h * dh + c)] = rots.r2[(r, c)];
                }
            }
        }
        m
    };
    let embed = to_mat(&fp.embed, cfg.vocab, d).matmul(r1);
    let lm_head = r1t.matmul(&scale_rows(to_mat(&fp.lm_head, d, cfg.vocab), &fp.ln_f));
    let r4t = rots.r4.transpose();
    let layers = fp
        .layers
        .iter()
        .map(|layer| {
            let g1 = &layer.ln1;
            let g2 = &layer.ln2;
            let mut map = BTreeMap::new();
            map.insert("wq".into(), r1t.matmul(&scale_rows(to_mat(&layer.wq, d, d), g1)));
            map.insert("wk".into(), r1t.matmul(&scale_rows(to_mat(&layer.wk, d, d), g1)));
            map.insert(
                "wv".into(),
                r1t.matmul(&scale_rows(to_mat(&layer.wv, d, d), g1)).matmul(&b2),
            );
            map.insert("wo".into(), b2.transpose().matmul(&to_mat(&layer.wo, d, d)).matmul(r1));
            map.insert(
                "wgate".into(),
                r1t.matmul(&scale_rows(to_mat(&layer.wgate, d, cfg.d_ffn), g2)),
            );
            map.insert(
                "wup".into(),
                r1t.matmul(&scale_rows(to_mat(&layer.wup, d, cfg.d_ffn), g2)),
            );
            map.insert(
                "wdown".into(),
                r4t.matmul(&to_mat(&layer.wdown, cfg.d_ffn, d)).matmul(r1),
            );
            map
        })
        .collect();
    (embed, lm_head, layers)
}

/// Fused-but-unquantized variant params (exact fp equivalence — Fig. 1).
pub fn fuse_to_dense(fp: &FpParams, cfg: &ModelCfg, rots: &RotationSet) -> QuantParams {
    let (embed, lm_head, layers) = fuse_rotations(fp, cfg, rots);
    QuantParams {
        embed: to_f32(&embed),
        lm_head: to_f32(&lm_head),
        r3: to_f32(&rots.r3),
        r4_signs: rots.r4_signs.iter().map(|&v| v as f32).collect(),
        r4_kind: rots.r4_kind,
        layers: layers
            .into_iter()
            .map(|map| QuantLayer {
                ascale_attn: vec![1.0; cfg.d_model],
                ascale_o: vec![1.0; cfg.d_model],
                ascale_ffn: vec![1.0; cfg.d_model],
                ascale_down: vec![1.0; cfg.d_ffn],
                dense: map.iter().map(|(k, m)| (k.clone(), to_f32(m))).collect(),
            })
            .collect(),
    }
}

/// Full native W2 quantization: fuse → identity-Hessian GPTQ per linear
/// → dequantized dense variant params (runnable via the native forward).
/// Returns the params and the total squared weight-reconstruction error
/// (the SSE metric reported in EXPERIMENTS.md).
pub fn quantize_native(
    fp: &FpParams,
    cfg: &ModelCfg,
    rots: &RotationSet,
    bits: u32,
) -> (QuantParams, f64, Vec<QuantizedLinear>) {
    let (embed, lm_head, fused_layers) = fuse_rotations(fp, cfg, rots);
    let mut sse = 0.0;
    let mut qlinears = Vec::new();
    let layers = fused_layers
        .into_iter()
        .map(|map| {
            let mut dense = BTreeMap::new();
            for name in LINEARS {
                let w = &map[name];
                let q = gptq_quantize(w, &Mat::identity(w.rows), bits, cfg.group, true);
                let deq = q.dequant();
                for (a, b) in deq.data.iter().zip(&w.data) {
                    sse += (a - b) * (a - b);
                }
                dense.insert(name.to_string(), to_f32(&deq));
                qlinears.push(q);
            }
            QuantLayer {
                ascale_attn: vec![1.0; cfg.d_model],
                ascale_o: vec![1.0; cfg.d_model],
                ascale_ffn: vec![1.0; cfg.d_model],
                ascale_down: vec![1.0; cfg.d_ffn],
                dense,
            }
        })
        .collect();
    (
        QuantParams {
            embed: to_f32(&embed),
            lm_head: to_f32(&lm_head),
            r3: to_f32(&rots.r3),
            r4_signs: rots.r4_signs.iter().map(|&v| v as f32).collect(),
            r4_kind: rots.r4_kind,
            layers,
        },
        sse,
        qlinears,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DenseModel;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn random_fp(cfg: &ModelCfg, seed: u64) -> FpParams {
        let mut rng = SplitMix64::new(seed);
        let mut dense = |c: usize, h: usize| -> Vec<f32> {
            (0..c * h).map(|_| (rng.next_normal() / (c as f64).sqrt()) as f32).collect()
        };
        let layers = (0..cfg.n_layers)
            .map(|_| crate::model::weights::FpLayer {
                ln1: (0..cfg.d_model).map(|i| 1.0 + 0.1 * (i % 5) as f32).collect(),
                ln2: (0..cfg.d_model).map(|i| 1.0 + 0.05 * (i % 7) as f32).collect(),
                wq: dense(cfg.d_model, cfg.d_model),
                wk: dense(cfg.d_model, cfg.d_model),
                wv: dense(cfg.d_model, cfg.d_model),
                wo: dense(cfg.d_model, cfg.d_model),
                wgate: dense(cfg.d_model, cfg.d_ffn),
                wup: dense(cfg.d_model, cfg.d_ffn),
                wdown: dense(cfg.d_ffn, cfg.d_model),
            })
            .collect();
        FpParams {
            embed: dense(cfg.vocab, cfg.d_model),
            lm_head: dense(cfg.d_model, cfg.vocab),
            ln_f: vec![1.0; cfg.d_model],
            layers,
        }
    }

    /// Fig. 1, natively: fused/rotated forward ≡ fp forward, all kinds.
    #[test]
    fn fig1_invariance_native() {
        let cfg = tiny_cfg();
        let fp = random_fp(&cfg, 3);
        let tokens: Vec<i32> = (0..12).map(|i| (i * 7 % 64) as i32).collect();
        let fp_model = DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() };
        let expect = fp_model.forward(&tokens);
        for r1_kind in R1Kind::ALL {
            for r4_kind in [R4Kind::GH, R4Kind::LH] {
                let rots = build_rotations(&cfg, r1_kind, r4_kind, 99);
                let qp = fuse_to_dense(&fp, &cfg, &rots);
                let qmodel = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None };
                let got = qmodel.forward(&tokens);
                let worst = expect
                    .iter()
                    .zip(&got)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(
                    worst < 2e-3,
                    "{r1_kind}/{r4_kind:?}: rotated forward diverges by {worst}"
                );
            }
        }
    }

    /// Native W2 quantization runs end-to-end and degrades gracefully.
    #[test]
    fn quantize_native_end_to_end() {
        let cfg = tiny_cfg();
        let fp = random_fp(&cfg, 5);
        let rots = build_rotations(&cfg, R1Kind::GSR, R4Kind::GH, 7);
        let (qp, sse, qlinears) = quantize_native(&fp, &cfg, &rots, 2);
        assert!(sse > 0.0);
        assert_eq!(qlinears.len(), cfg.n_layers * LINEARS.len());
        let tokens: Vec<i32> = (0..10).map(|i| (i % 64) as i32).collect();
        let model = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None };
        let logits = model.forward(&tokens);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    /// Local rotations beat global on SSE for outlier-row weights —
    /// the Table-1 mechanism, natively.
    #[test]
    fn local_rotation_reduces_sse_with_outlier_gamma() {
        let cfg = tiny_cfg();
        let mut fp = random_fp(&cfg, 11);
        // Outlier γ rows (the massive-channel substitution).
        for layer in fp.layers.iter_mut() {
            layer.ln1[3] = 9.0;
            layer.ln1[17] = 12.0;
            layer.ln2[8] = 10.0;
        }
        let sse_of = |kind: R1Kind| {
            let rots = build_rotations(&cfg, kind, R4Kind::GH, 13);
            quantize_native(&fp, &cfg, &rots, 2).1
        };
        let gh = sse_of(R1Kind::GH);
        let gsr = sse_of(R1Kind::GSR);
        let lh = sse_of(R1Kind::LH);
        assert!(
            gsr < gh && lh < gh,
            "local (LH {lh:.1}, GSR {gsr:.1}) must beat global (GH {gh:.1})"
        );
    }
}
