//! Native end-to-end quantization pipeline: fuse rotations → GPTQ → pack.
//!
//! A Rust mirror of `python/compile/quantize.py` over an fp checkpoint
//! blob — downstream users can produce new quantized variants without
//! the Python toolchain (`gsr quantize-native`). It is also the second,
//! independent implementation of the paper's R1–R4 fusion rules: the
//! Fig.-1 invariance test below checks `forward(fuse(params)) ≡
//! forward(params)` natively, with no JAX in the loop.
//!
//! Two configuration surfaces coexist:
//!
//! * [`RotationSet`] / [`build_rotations`] — the legacy uniform
//!   configuration (one R1/R4 for the whole model, block = quant group).
//! * [`RotationPlan`] / [`build_plan_rotations`] — a **per-layer**
//!   assignment of `(R1 kind, R1 block, R4 kind, R4 block)` produced by
//!   the `gsr search` subsystem. Identical specs share one built matrix
//!   (`Arc` dedup); consecutive layers with different R1 specs get an
//!   explicit residual-stream change of basis `R_{l-1}ᵀ R_l`, which is
//!   what keeps Fig.-1 invariance exact for heterogeneous plans.
//!
//! GPTQ runs identity-Hessian by default; the `*_with` variants accept a
//! `calib::HessianSet` (captured by `gsr calibrate` in the same rotated
//! basis this pipeline fuses into) and become Hessian-calibrated GPTQ —
//! the paper's measured setting, natively.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use super::gptq::{gptq_factor, gptq_quantize_factored, GptqFactor};
use super::QuantizedLinear;
use crate::config::Json;
use crate::model::config::{ModelCfg, R4Kind, LINEARS};
use crate::model::kernels::{BasisFast, KernelMode, PackedLinear, R1Desc};
use crate::model::weights::{FpParams, LayerR4, QuantLayer, QuantParams};
use crate::rng::SplitMix64;
use crate::transform::{
    is_pow2, mask_angles, rht, try_block_diag, try_build_parametric, try_build_r1, try_hadamard,
    Mat, R1Kind,
};

// ---------------------------------------------------------------------------
// Rotation specs and plans
// ---------------------------------------------------------------------------

/// One layer's rotation configuration inside a [`RotationPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RotationSpec {
    pub r1: R1Kind,
    /// Walsh/Hadamard block size for local R1 kinds; ignored (and
    /// canonicalized to `d_model`) for global kinds.
    pub r1_block: usize,
    pub r4: R4Kind,
    /// Online-R4 block: `d_ffn` for GH, the local block size for LH.
    pub r4_block: usize,
    /// Packed per-stage angle codes for parametric R1 kinds (GIV/BFLY):
    /// byte `s` is stage `s`'s 8-bit angle (`θ = code · 2π/256`).
    /// Always 0 (canonicalized) for non-parametric kinds, so every
    /// pre-existing spec compares, hashes, and fingerprints unchanged.
    pub r1_angles: u64,
}

impl RotationSpec {
    /// The paper's fixed configuration (GSR @ quant group, global R4)
    /// — the baseline every searched plan is measured against.
    pub fn baseline(cfg: &ModelCfg) -> Self {
        Self {
            r1: R1Kind::GSR,
            r1_block: cfg.group,
            r4: R4Kind::GH,
            r4_block: cfg.d_ffn,
            r1_angles: 0,
        }
    }

    /// Canonical form used as the build/dedup key: global R1 kinds pin
    /// `r1_block = d_model`, GH R4 pins `r4_block = d_ffn`, and the
    /// angle word is masked to the live stages (zero when the kind
    /// carries no angles).
    pub fn canonical(mut self, cfg: &ModelCfg) -> Self {
        if !self.r1.is_local() {
            self.r1_block = cfg.d_model;
        }
        if self.r4 == R4Kind::GH {
            self.r4_block = cfg.d_ffn;
        }
        self.r1_angles = if self.r1.is_parametric() {
            mask_angles(self.r1, self.r1_block, self.r1_angles)
        } else {
            0
        };
        self
    }

    /// Geometry check against a model config (early, clear errors — the
    /// search grid probes arbitrary block sizes).
    pub fn validate(&self, cfg: &ModelCfg) -> Result<(), String> {
        if self.r1.is_local() {
            if !is_pow2(self.r1_block) {
                return Err(format!("R1 block must be a power of two, got {}", self.r1_block));
            }
            if self.r1_block > cfg.d_model || cfg.d_model % self.r1_block != 0 {
                return Err(format!(
                    "R1 block {} must divide d_model {}",
                    self.r1_block, cfg.d_model
                ));
            }
            if self.r1.is_parametric() && self.r1_block < 2 {
                return Err(format!(
                    "parametric R1 {} needs block >= 2, got {}",
                    self.r1, self.r1_block
                ));
            }
        } else if !is_pow2(cfg.d_model) {
            return Err(format!("global R1 needs a power-of-two d_model, got {}", cfg.d_model));
        }
        match self.r4 {
            R4Kind::GH => {
                if !is_pow2(cfg.d_ffn) {
                    return Err(format!("global R4 needs a power-of-two d_ffn, got {}", cfg.d_ffn));
                }
            }
            R4Kind::LH => {
                if !is_pow2(self.r4_block) || cfg.d_ffn % self.r4_block != 0 {
                    return Err(format!(
                        "R4 block {} must be a power of two dividing d_ffn {}",
                        self.r4_block, cfg.d_ffn
                    ));
                }
            }
        }
        Ok(())
    }

    /// Short human label, e.g. `GSR/64+r4GH` (used by the eval tables).
    /// Parametric kinds append the packed angle word in hex, e.g.
    /// `GIV/64:2020202020202020+r4GH`.
    pub fn label(&self) -> String {
        let r1 = if self.r1.is_parametric() {
            format!("{}/{}:{:x}", self.r1, self.r1_block, self.r1_angles)
        } else if self.r1.is_local() {
            format!("{}/{}", self.r1, self.r1_block)
        } else {
            self.r1.to_string()
        };
        let r4 = if self.r4 == R4Kind::LH {
            format!("{}@{}", self.r4.as_str(), self.r4_block)
        } else {
            self.r4.as_str().to_string()
        };
        format!("{r1}+r4{r4}")
    }
}

/// A per-layer rotation assignment for a whole model — the unit the
/// `gsr search` subsystem emits, `quantize-native --plan` consumes, and
/// `config::Json` round-trips to disk.
#[derive(Debug, Clone, PartialEq)]
pub struct RotationPlan {
    /// Seed every spec-keyed matrix build derives from.
    pub seed: u64,
    pub layers: Vec<RotationSpec>,
}

impl RotationPlan {
    /// The same spec for every layer (legacy variants as a plan).
    pub fn uniform(spec: RotationSpec, n_layers: usize, seed: u64) -> Self {
        Self { seed, layers: vec![spec; n_layers] }
    }

    /// Does every layer share one spec?
    pub fn is_uniform(&self) -> bool {
        self.layers.windows(2).all(|w| w[0] == w[1])
    }

    pub fn validate(&self, cfg: &ModelCfg) -> Result<(), String> {
        if self.layers.len() != cfg.n_layers {
            return Err(format!(
                "plan has {} layer specs, model has {} layers",
                self.layers.len(),
                cfg.n_layers
            ));
        }
        for (l, spec) in self.layers.iter().enumerate() {
            spec.validate(cfg).map_err(|e| format!("layer {l}: {e}"))?;
        }
        Ok(())
    }

    /// Stable 64-bit fingerprint of the rotation **basis** this plan
    /// builds: a SplitMix64 chain over the build seed and every layer's
    /// spec fields. Calibration artifacts (`calib::HessianSet`) are
    /// keyed on it so activations captured in one basis can never be
    /// silently consumed under another. Canonicalize specs before
    /// fingerprinting if they may carry ignored block fields.
    pub fn fingerprint(&self) -> u64 {
        let mut acc = SplitMix64::new(self.seed ^ 0x6773_7248_6573_7321).next_u64();
        for spec in &self.layers {
            let fields = (spec.r1 as u64)
                | ((spec.r4 as u64) << 4)
                | ((spec.r1_block as u64) << 8)
                | ((spec.r4_block as u64) << 36);
            acc = SplitMix64::new(acc ^ fields).next_u64();
            // Chained only when nonzero so every pre-existing
            // (angle-free) plan keeps its historical fingerprint —
            // calibration artifacts captured before the parametric
            // kinds existed stay consumable.
            if spec.r1_angles != 0 {
                acc = SplitMix64::new(acc ^ spec.r1_angles ^ 0x6773_725F_616E_676C).next_u64();
            }
        }
        acc
    }

    // -- JSON round-trip ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            // The seed is a full u64; JSON numbers are f64 (exact only
            // below 2^53), so it travels as a decimal string to keep the
            // bit-identical rebuild guarantee for every seed.
            ("seed", Json::str(&self.seed.to_string())),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("r1", Json::str(s.r1.as_str())),
                                ("r1_block", Json::num(s.r1_block as f64)),
                                ("r4", Json::str(s.r4.as_str())),
                                ("r4_block", Json::num(s.r4_block as f64)),
                                // Full u64 like the seed: decimal string.
                                ("r1_angles", Json::str(&s.r1_angles.to_string())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let seed_val = j.at("seed")?;
        let seed = match seed_val {
            Json::Str(s) => s
                .parse::<u64>()
                .map_err(|_| format!("bad plan seed {s:?} (want a decimal u64)"))?,
            // Back-compat: accept plain numbers (exact below 2^53).
            _ => seed_val.as_usize().ok_or("plan seed must be a number or decimal string")?
                as u64,
        };
        let layers = j
            .at("layers")?
            .as_arr()
            .ok_or("plan layers must be an array")?
            .iter()
            .map(|l| -> Result<RotationSpec, String> {
                // Absent in plans written before the parametric kinds
                // existed — default to 0 (no angles).
                let r1_angles = match l.at("r1_angles") {
                    Err(_) => 0,
                    Ok(Json::Str(s)) => s
                        .parse::<u64>()
                        .map_err(|_| format!("bad r1_angles {s:?} (want a decimal u64)"))?,
                    Ok(v) => v.as_usize().ok_or("r1_angles must be a number or decimal string")?
                        as u64,
                };
                Ok(RotationSpec {
                    r1: R1Kind::parse(l.at("r1")?.as_str().ok_or("r1")?)
                        .ok_or("bad r1 kind (GH|GW|LH|GSR|GIV|BFLY)")?,
                    r1_block: l.at("r1_block")?.as_usize().ok_or("r1_block")?,
                    r4: R4Kind::parse(l.at("r4")?.as_str().ok_or("r4")?)
                        .ok_or("bad r4 kind (GH|LH)")?,
                    r4_block: l.at("r4_block")?.as_usize().ok_or("r4_block")?,
                    r1_angles,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Self { seed, layers })
    }

    pub fn save(&self, path: &Path) -> Result<(), String> {
        self.to_json().to_file(path)
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        Self::from_json(&Json::from_file(path)?)
    }
}

// ---------------------------------------------------------------------------
// Built rotations
// ---------------------------------------------------------------------------

/// The shared rotation set for one legacy (uniform) variant.
pub struct RotationSet {
    pub r1: Mat,
    pub r2: Mat,
    pub r3: Mat,
    pub r4: Mat,
    pub r4_signs: Vec<f64>,
    pub r4_kind: R4Kind,
}

/// Signed (randomized) R4 of the requested kind/block over `d_ffn`.
/// Public so the search objective scores candidates with exactly the
/// matrices the quantization pipeline will build.
pub fn build_r4(
    cfg: &ModelCfg,
    kind: R4Kind,
    block: usize,
    rng: &mut SplitMix64,
) -> Result<(Mat, Vec<f64>), String> {
    match kind {
        R4Kind::GH => {
            let signs: Vec<f64> = (0..cfg.d_ffn).map(|_| rng.next_sign()).collect();
            let mut h = try_hadamard(cfg.d_ffn)?;
            for r in 0..cfg.d_ffn {
                for (c, &s) in signs.iter().enumerate() {
                    h[(r, c)] *= s;
                }
            }
            Ok((h, signs))
        }
        R4Kind::LH => {
            if !is_pow2(block) || cfg.d_ffn % block != 0 {
                return Err(format!(
                    "R4 block {block} must be a power of two dividing d_ffn {}",
                    cfg.d_ffn
                ));
            }
            let signs: Vec<f64> = (0..block).map(|_| rng.next_sign()).collect();
            let mut b = try_hadamard(block)?;
            for r in 0..block {
                for (c, &s) in signs.iter().enumerate() {
                    b[(r, c)] *= s;
                }
            }
            Ok((try_block_diag(&b, cfg.d_ffn)?, signs))
        }
    }
}

/// Build rotations deterministically (seed-pinned like the Python path).
pub fn build_rotations(cfg: &ModelCfg, r1_kind: R1Kind, r4_kind: R4Kind, seed: u64) -> RotationSet {
    let mut rng = SplitMix64::new(seed);
    let r1 = try_build_r1(r1_kind, cfg.d_model, cfg.group, &mut rng)
        .unwrap_or_else(|e| panic!("{e}"));
    let r2 = rht(cfg.head_dim(), &mut rng);
    let r3 = rht(cfg.head_dim(), &mut rng);
    let r4_block = if r4_kind == R4Kind::GH { cfg.d_ffn } else { cfg.group };
    let (r4, r4_signs) =
        build_r4(cfg, r4_kind, r4_block, &mut rng).unwrap_or_else(|e| panic!("{e}"));
    RotationSet { r1, r2, r3, r4, r4_signs, r4_kind }
}

/// One layer's built rotation matrices. Layers with identical canonical
/// specs share the same `Arc`s — one build per distinct configuration.
#[derive(Clone)]
pub struct LayerRotations {
    pub spec: RotationSpec,
    pub r1: Arc<Mat>,
    pub r4: Arc<Mat>,
    pub r4_signs: Arc<Vec<f64>>,
}

/// Built rotations for a whole plan: per-layer R1/R4 plus the shared
/// head rotations R2/R3.
pub struct PlanRotations {
    pub plan: RotationPlan,
    pub r2: Mat,
    pub r3: Mat,
    pub layers: Vec<LayerRotations>,
    /// Number of distinct (deduplicated) spec builds.
    pub distinct: usize,
}

fn keyed_seed(fields: u64, seed: u64) -> u64 {
    SplitMix64::new(seed ^ 0x6773_725F_706C_616E).next_u64()
        ^ SplitMix64::new(fields).next_u64()
}

/// Deterministic, layer-independent sub-seed for a spec's **R1** build.
/// Keyed only on `(r1, r1_block)`: specs differing just in R4 share the
/// exact same R1 matrix, which lets the search score the R1-dependent
/// work once per block size, and lets a plan reloaded from disk rebuild
/// bit-identical rotations.
pub fn r1_seed(spec: &RotationSpec, seed: u64) -> u64 {
    keyed_seed((spec.r1 as u64) | ((spec.r1_block as u64) << 8), seed)
}

/// Deterministic sub-seed for a spec's **R4** build (keyed on
/// `(r4, r4_block)` only; see [`r1_seed`]).
pub fn r4_seed(spec: &RotationSpec, seed: u64) -> u64 {
    // Low bits tag the R4 field layout apart from R1's.
    keyed_seed(0x5234 | ((spec.r4 as u64) << 16) | ((spec.r4_block as u64) << 24), seed)
}

/// Build one canonical spec's **R1** matrix exactly as the quantization
/// pipeline will: parametric kinds (GIV/BFLY) are pure functions of
/// `(kind, block, r1_angles)` — no RNG, so a plan reloaded from disk
/// rebuilds bit-identically from the spec alone — while the legacy
/// kinds draw from the [`r1_seed`]-keyed stream. Public because the
/// search objective must score candidates with these exact matrices.
pub fn build_spec_r1(cfg: &ModelCfg, key: &RotationSpec, seed: u64) -> Result<Mat, String> {
    if key.r1.is_parametric() {
        try_build_parametric(key.r1, cfg.d_model, key.r1_block, key.r1_angles)
    } else {
        let mut rng = SplitMix64::new(r1_seed(key, seed));
        try_build_r1(key.r1, cfg.d_model, key.r1_block, &mut rng)
    }
}

/// Build all rotation matrices for `plan`, deduplicating identical
/// canonical specs so each distinct configuration is constructed once.
pub fn build_plan_rotations(cfg: &ModelCfg, plan: &RotationPlan) -> Result<PlanRotations, String> {
    plan.validate(cfg)?;
    let mut rng = SplitMix64::new(plan.seed);
    let r2 = rht(cfg.head_dim(), &mut rng);
    let r3 = rht(cfg.head_dim(), &mut rng);
    let mut cache: BTreeMap<RotationSpec, LayerRotations> = BTreeMap::new();
    let mut layers = Vec::with_capacity(plan.layers.len());
    for spec in &plan.layers {
        let key = spec.canonical(cfg);
        if let Some(hit) = cache.get(&key) {
            layers.push(hit.clone());
            continue;
        }
        let r1 = build_spec_r1(cfg, &key, plan.seed)?;
        let mut r4_rng = SplitMix64::new(r4_seed(&key, plan.seed));
        let (r4, signs) = build_r4(cfg, key.r4, key.r4_block, &mut r4_rng)?;
        let built = LayerRotations {
            spec: key,
            r1: Arc::new(r1),
            r4: Arc::new(r4),
            r4_signs: Arc::new(signs),
        };
        cache.insert(key, built.clone());
        layers.push(built);
    }
    Ok(PlanRotations { plan: plan.clone(), r2, r3, distinct: cache.len(), layers })
}

// ---------------------------------------------------------------------------
// Fusion
// ---------------------------------------------------------------------------

fn to_mat(w: &[f32], rows: usize, cols: usize) -> Mat {
    assert_eq!(w.len(), rows * cols);
    Mat { data: w.iter().map(|&v| v as f64).collect(), rows, cols }
}

fn to_f32(m: &Mat) -> Vec<f32> {
    m.data.iter().map(|&v| v as f32).collect()
}

fn scale_rows(mut m: Mat, gamma: &[f32]) -> Mat {
    for r in 0..m.rows {
        let g = gamma[r] as f64;
        for v in m.row_mut(r) {
            *v *= g;
        }
    }
    m
}

/// `I_heads ⊗ R2`.
fn expand_b2(cfg: &ModelCfg, r2: &Mat) -> Mat {
    let d = cfg.d_model;
    let dh = cfg.head_dim();
    let mut m = Mat::zeros(d, d);
    for h in 0..cfg.n_heads {
        for r in 0..dh {
            for c in 0..dh {
                m[(h * dh + r, h * dh + c)] = r2[(r, c)];
            }
        }
    }
    m
}

/// Fuse one transformer layer's seven linears against its rotations.
fn fuse_layer(
    layer: &crate::model::weights::FpLayer,
    cfg: &ModelCfg,
    r1: &Mat,
    r4: &Mat,
    b2: &Mat,
) -> BTreeMap<String, Mat> {
    let d = cfg.d_model;
    let r1t = r1.transpose();
    let r4t = r4.transpose();
    let g1 = &layer.ln1;
    let g2 = &layer.ln2;
    let mut map = BTreeMap::new();
    map.insert("wq".into(), r1t.matmul(&scale_rows(to_mat(&layer.wq, d, d), g1)));
    map.insert("wk".into(), r1t.matmul(&scale_rows(to_mat(&layer.wk, d, d), g1)));
    map.insert("wv".into(), r1t.matmul(&scale_rows(to_mat(&layer.wv, d, d), g1)).matmul(b2));
    map.insert("wo".into(), b2.transpose().matmul(&to_mat(&layer.wo, d, d)).matmul(r1));
    map.insert("wgate".into(), r1t.matmul(&scale_rows(to_mat(&layer.wgate, d, cfg.d_ffn), g2)));
    map.insert("wup".into(), r1t.matmul(&scale_rows(to_mat(&layer.wup, d, cfg.d_ffn), g2)));
    map.insert("wdown".into(), r4t.matmul(&to_mat(&layer.wdown, cfg.d_ffn, d)).matmul(r1));
    map
}

/// Fused, rotated dense weights for one legacy variant (mirror of
/// `model.fuse_rotations` + `fuse_r4`). Returns
/// `(embed', lm_head', per-layer {name → Mat})`.
pub fn fuse_rotations(
    fp: &FpParams,
    cfg: &ModelCfg,
    rots: &RotationSet,
) -> (Mat, Mat, Vec<BTreeMap<String, Mat>>) {
    let d = cfg.d_model;
    let r1 = &rots.r1;
    let b2 = expand_b2(cfg, &rots.r2);
    let embed = to_mat(&fp.embed, cfg.vocab, d).matmul(r1);
    let lm_head =
        r1.transpose().matmul(&scale_rows(to_mat(&fp.lm_head, d, cfg.vocab), &fp.ln_f));
    let layers = fp
        .layers
        .iter()
        .map(|layer| fuse_layer(layer, cfg, r1, &rots.r4, &b2))
        .collect();
    (embed, lm_head, layers)
}

/// Fused rotated dense weights under a (possibly heterogeneous) plan.
///
/// The residual stream runs in layer 0's R1 basis after the embedding,
/// transitions via `R_{l-1}ᵀ R_l` wherever consecutive layers pick a
/// different R1, and ends in the last layer's basis, absorbed by the
/// fused lm_head. Returns `(embed', lm_head', per-layer {name → Mat},
/// per-layer basis transitions)`.
pub fn fuse_rotations_plan(
    fp: &FpParams,
    cfg: &ModelCfg,
    rots: &PlanRotations,
) -> (Mat, Mat, Vec<BTreeMap<String, Mat>>, Vec<Option<Mat>>) {
    assert_eq!(fp.layers.len(), rots.layers.len(), "plan/model layer mismatch");
    let d = cfg.d_model;
    let b2 = expand_b2(cfg, &rots.r2);
    let first_r1: &Mat = &rots.layers[0].r1;
    let last_r1: &Mat = &rots.layers[rots.layers.len() - 1].r1;
    let embed = to_mat(&fp.embed, cfg.vocab, d).matmul(first_r1);
    let lm_head =
        last_r1.transpose().matmul(&scale_rows(to_mat(&fp.lm_head, d, cfg.vocab), &fp.ln_f));
    let mut maps = Vec::with_capacity(fp.layers.len());
    let mut transitions = Vec::with_capacity(fp.layers.len());
    for (l, layer) in fp.layers.iter().enumerate() {
        let lr = &rots.layers[l];
        let trans = if l == 0 {
            None
        } else {
            let prev = &rots.layers[l - 1];
            if Arc::ptr_eq(&prev.r1, &lr.r1) || prev.r1.as_ref() == lr.r1.as_ref() {
                None
            } else {
                Some(prev.r1.transpose().matmul(lr.r1.as_ref()))
            }
        };
        transitions.push(trans);
        maps.push(fuse_layer(layer, cfg, lr.r1.as_ref(), lr.r4.as_ref(), &b2));
    }
    (embed, lm_head, maps, transitions)
}

fn unit_layer_scales(cfg: &ModelCfg, dense: BTreeMap<String, Vec<f32>>) -> QuantLayer {
    QuantLayer {
        ascale_attn: vec![1.0; cfg.d_model],
        ascale_o: vec![1.0; cfg.d_model],
        ascale_ffn: vec![1.0; cfg.d_model],
        ascale_down: vec![1.0; cfg.d_ffn],
        dense,
        basis_change: None,
        r4: None,
        packed: BTreeMap::new(),
        basis_fast: None,
    }
}

/// Fast-path descriptor for the shared head rotation R3 (`rht(d_head)`
/// — always a randomized Hadamard, recovered and verified exactly).
fn r3_fast_of(r3: &Mat) -> Option<R1Desc> {
    R1Desc::from_mat(R1Kind::GH, r3.rows, r3)
}

/// Attach the packed-domain form of every quantized linear to its
/// layer, in the layer-major [`LINEARS`] order `qlinears` was filled
/// in. The dense tensors stay resident for the reference path; linears
/// whose bit width has no packed layout are simply skipped.
fn attach_packed(layers: &mut [QuantLayer], qlinears: &[QuantizedLinear]) {
    for (l, layer) in layers.iter_mut().enumerate() {
        for (i, name) in LINEARS.iter().enumerate() {
            let q = &qlinears[l * LINEARS.len() + i];
            if let Some(pl) = PackedLinear::from_qlinear(q) {
                layer.packed.insert(name.to_string(), pl);
            }
        }
    }
}

/// Fused-but-unquantized variant params (exact fp equivalence — Fig. 1).
pub fn fuse_to_dense(fp: &FpParams, cfg: &ModelCfg, rots: &RotationSet) -> QuantParams {
    let (embed, lm_head, layers) = fuse_rotations(fp, cfg, rots);
    QuantParams {
        embed: to_f32(&embed),
        lm_head: to_f32(&lm_head),
        r3: to_f32(&rots.r3),
        r4_signs: rots.r4_signs.iter().map(|&v| v as f32).collect(),
        r4_kind: rots.r4_kind,
        layers: layers
            .into_iter()
            .map(|map| {
                unit_layer_scales(cfg, map.iter().map(|(k, m)| (k.clone(), to_f32(m))).collect())
            })
            .collect(),
        kernels: KernelMode::default(),
        r3_fast: r3_fast_of(&rots.r3),
    }
}

/// Assemble heterogeneous-plan `QuantParams` from fused globals plus
/// per-layer dense maps — shared by the exact-dense and GPTQ paths.
fn plan_params(
    cfg: &ModelCfg,
    rots: &PlanRotations,
    embed: &Mat,
    lm_head: &Mat,
    dense_layers: Vec<BTreeMap<String, Vec<f32>>>,
    transitions: Vec<Option<Mat>>,
) -> QuantParams {
    QuantParams {
        embed: to_f32(embed),
        lm_head: to_f32(lm_head),
        r3: to_f32(&rots.r3),
        r4_signs: rots.layers[0].r4_signs.iter().map(|&v| v as f32).collect(),
        r4_kind: rots.layers[0].spec.r4,
        layers: dense_layers
            .into_iter()
            .zip(transitions)
            .enumerate()
            .map(|(l, (dense, trans))| {
                let mut ql = unit_layer_scales(cfg, dense);
                if trans.is_some() {
                    // Fast form of the basis change: the two structured
                    // factors applied as transforms instead of their
                    // dense product. Canonical specs carry the block.
                    let (prev, next) = (&rots.layers[l - 1], &rots.layers[l]);
                    ql.basis_fast = BasisFast::from_mats(
                        prev.spec.r1,
                        prev.spec.r1_block,
                        prev.r1.as_ref(),
                        next.spec.r1,
                        next.spec.r1_block,
                        next.r1.as_ref(),
                    );
                }
                ql.basis_change = trans.map(|t| to_f32(&t));
                ql.r4 = Some(LayerR4 {
                    kind: rots.layers[l].spec.r4,
                    signs: rots.layers[l].r4_signs.iter().map(|&v| v as f32).collect(),
                });
                ql
            })
            .collect(),
        kernels: KernelMode::default(),
        r3_fast: r3_fast_of(&rots.r3),
    }
}

/// Plan analogue of [`fuse_to_dense`]: exact fp equivalence with
/// heterogeneous per-layer rotations (Fig. 1 with a plan).
pub fn fuse_to_dense_plan(fp: &FpParams, cfg: &ModelCfg, rots: &PlanRotations) -> QuantParams {
    let (embed, lm_head, layers, transitions) = fuse_rotations_plan(fp, cfg, rots);
    let dense: Vec<BTreeMap<String, Vec<f32>>> = layers
        .into_iter()
        .map(|map| map.iter().map(|(k, m)| (k.clone(), to_f32(m))).collect())
        .collect();
    plan_params(cfg, rots, &embed, &lm_head, dense, transitions)
}

// ---------------------------------------------------------------------------
// Quantization
// ---------------------------------------------------------------------------

/// Identity-Hessian GPTQ factors for the two linear input widths,
/// built once per model (only when no calibration is supplied — the
/// factor depends only on the dimension) and shared by every layer.
fn identity_factors(cfg: &ModelCfg) -> (GptqFactor, GptqFactor) {
    (gptq_factor(&Mat::identity(cfg.d_model)), gptq_factor(&Mat::identity(cfg.d_ffn)))
}

/// GPTQ every linear of one fused layer map; returns the dequantized
/// dense map, accumulating SSE and the quantized linears. With
/// `hessians` the real per-linear activation Hessian replaces the
/// identity (Hessian-calibrated GPTQ); without, the shared `identity`
/// factors reproduce the legacy identity-Hessian behavior exactly.
fn quantize_layer_map(
    map: &BTreeMap<String, Mat>,
    cfg: &ModelCfg,
    bits: u32,
    hessians: Option<(&crate::calib::LayerHessians, u64)>,
    identity: Option<&(GptqFactor, GptqFactor)>,
    sse: &mut f64,
    qlinears: &mut Vec<QuantizedLinear>,
) -> BTreeMap<String, Vec<f32>> {
    use crate::model::forward::TapSite;

    // One O(C³) Hessian factorization per tap site, shared across the
    // linears that read it (wq/wk/wv share AttnIn, wgate/wup share
    // FfnIn) — 4 factorizations per layer instead of 7. Uncalibrated
    // layers reuse the two model-wide identity factors.
    let site_factors: Option<Vec<(TapSite, GptqFactor)>> = hessians.map(|(lh, tokens)| {
        TapSite::ALL
            .iter()
            .map(|&site| (site, gptq_factor(&lh.site(site).to_mat(tokens))))
            .collect()
    });
    let mut dense = BTreeMap::new();
    for name in LINEARS {
        let w = &map[name];
        let site = crate::calib::LayerHessians::site_of_linear(name);
        let factor = match &site_factors {
            Some(factors) => {
                &factors
                    .iter()
                    .find(|(s, _)| *s == site)
                    .expect("every tap site is factored")
                    .1
            }
            None => {
                let id = identity.expect("identity factors required without calibration");
                if site == TapSite::DownIn {
                    &id.1
                } else {
                    &id.0
                }
            }
        };
        let q = gptq_quantize_factored(w, factor, bits, cfg.group, true);
        let deq = q.dequant();
        for (a, b) in deq.data.iter().zip(&w.data) {
            *sse += (a - b) * (a - b);
        }
        dense.insert(name.to_string(), to_f32(&deq));
        qlinears.push(q);
    }
    dense
}

/// Full native W2 quantization: fuse → identity-Hessian GPTQ per linear
/// → dequantized dense variant params (runnable via the native forward).
/// Returns the params and the total squared weight-reconstruction error
/// (the SSE metric reported in EXPERIMENTS.md).
pub fn quantize_native(
    fp: &FpParams,
    cfg: &ModelCfg,
    rots: &RotationSet,
    bits: u32,
) -> (QuantParams, f64, Vec<QuantizedLinear>) {
    quantize_native_with(fp, cfg, rots, bits, None)
        .expect("identity-Hessian path has no failure mode")
}

/// [`quantize_native`] with an optional calibration artifact: when
/// `calib` is present every linear is GPTQ-quantized against its real
/// activation Hessian (captured by `gsr calibrate` in the same rotated
/// basis this pipeline fuses into). The caller is responsible for basis
/// agreement (`HessianSet::check_basis`); geometry and checkpoint
/// identity are checked here.
pub fn quantize_native_with(
    fp: &FpParams,
    cfg: &ModelCfg,
    rots: &RotationSet,
    bits: u32,
    calib: Option<&crate::calib::HessianSet>,
) -> Result<(QuantParams, f64, Vec<QuantizedLinear>), String> {
    if let Some(set) = calib {
        set.check_model(cfg)?;
        set.check_checkpoint(fp)?;
    }
    let (embed, lm_head, fused_layers) = fuse_rotations(fp, cfg, rots);
    let identity = if calib.is_none() { Some(identity_factors(cfg)) } else { None };
    let mut sse = 0.0;
    let mut qlinears = Vec::new();
    let mut layers: Vec<QuantLayer> = fused_layers
        .into_iter()
        .enumerate()
        .map(|(l, map)| {
            let hess = calib.map(|set| (&set.layers[l], set.tokens));
            let dense = quantize_layer_map(
                &map,
                cfg,
                bits,
                hess,
                identity.as_ref(),
                &mut sse,
                &mut qlinears,
            );
            unit_layer_scales(cfg, dense)
        })
        .collect();
    attach_packed(&mut layers, &qlinears);
    Ok((
        QuantParams {
            embed: to_f32(&embed),
            lm_head: to_f32(&lm_head),
            r3: to_f32(&rots.r3),
            r4_signs: rots.r4_signs.iter().map(|&v| v as f32).collect(),
            r4_kind: rots.r4_kind,
            layers,
            kernels: KernelMode::default(),
            r3_fast: r3_fast_of(&rots.r3),
        },
        sse,
        qlinears,
    ))
}

/// Plan analogue of [`quantize_native`]: heterogeneous per-layer
/// rotations, same identity-Hessian GPTQ per linear.
pub fn quantize_native_plan(
    fp: &FpParams,
    cfg: &ModelCfg,
    rots: &PlanRotations,
    bits: u32,
) -> (QuantParams, f64, Vec<QuantizedLinear>) {
    quantize_native_plan_with(fp, cfg, rots, bits, None)
        .expect("identity-Hessian path has no failure mode")
}

/// [`quantize_native_plan`] with an optional calibration artifact (see
/// [`quantize_native_with`]).
pub fn quantize_native_plan_with(
    fp: &FpParams,
    cfg: &ModelCfg,
    rots: &PlanRotations,
    bits: u32,
    calib: Option<&crate::calib::HessianSet>,
) -> Result<(QuantParams, f64, Vec<QuantizedLinear>), String> {
    let (qp, sse, qlinears, _) = quantize_native_plan_telemetry(fp, cfg, rots, bits, calib)?;
    Ok((qp, sse, qlinears))
}

/// Per-layer quantization telemetry: the chosen rotation configuration,
/// the layer's proxy quantization error, and outlier statistics of the
/// fused (γ-absorbed, rotated) weights the quantizer actually saw — the
/// paper's per-layer error claim, directly observable per layer.
#[derive(Debug, Clone)]
pub struct LayerQuantTelemetry {
    pub layer: usize,
    /// The rotation configuration the plan assigned to this layer.
    pub spec: RotationSpec,
    /// Sum of squared dequantization error across the layer's linears.
    pub sse: f64,
    /// Weight count across the layer's linears.
    pub weights: usize,
    /// Largest `|w|` across the layer's fused weights (outlier gauge).
    pub max_abs_weight: f64,
    /// RMS of the layer's fused weights (`max_abs / rms` spikes when
    /// massive channels survive the rotation).
    pub rms_weight: f64,
}

impl LayerQuantTelemetry {
    /// Mean squared dequantization error per weight.
    pub fn mse(&self) -> f64 {
        if self.weights == 0 {
            0.0
        } else {
            self.sse / self.weights as f64
        }
    }
}

/// [`quantize_native_plan_with`] plus per-layer telemetry (proxy
/// MSE, chosen [`RotationSpec`], weight-outlier stats) recorded while
/// quantizing — one entry per layer, in layer order.
pub fn quantize_native_plan_telemetry(
    fp: &FpParams,
    cfg: &ModelCfg,
    rots: &PlanRotations,
    bits: u32,
    calib: Option<&crate::calib::HessianSet>,
) -> Result<(QuantParams, f64, Vec<QuantizedLinear>, Vec<LayerQuantTelemetry>), String> {
    if let Some(set) = calib {
        set.check_model(cfg)?;
        set.check_checkpoint(fp)?;
    }
    let (embed, lm_head, fused_layers, transitions) = fuse_rotations_plan(fp, cfg, rots);
    let identity = if calib.is_none() { Some(identity_factors(cfg)) } else { None };
    let mut sse = 0.0;
    let mut qlinears = Vec::new();
    let mut telemetry = Vec::with_capacity(fused_layers.len());
    let dense: Vec<BTreeMap<String, Vec<f32>>> = fused_layers
        .iter()
        .enumerate()
        .map(|(l, map)| {
            let before = sse;
            let hess = calib.map(|set| (&set.layers[l], set.tokens));
            let d =
                quantize_layer_map(map, cfg, bits, hess, identity.as_ref(), &mut sse, &mut qlinears);
            let mut weights = 0usize;
            let mut max_abs = 0f64;
            let mut sumsq = 0f64;
            for m in map.values() {
                weights += m.data.len();
                for &w in &m.data {
                    max_abs = max_abs.max(w.abs());
                    sumsq += w * w;
                }
            }
            telemetry.push(LayerQuantTelemetry {
                layer: l,
                spec: rots.layers[l].spec,
                sse: sse - before,
                weights,
                max_abs_weight: max_abs,
                rms_weight: if weights == 0 { 0.0 } else { (sumsq / weights as f64).sqrt() },
            });
            d
        })
        .collect();
    let mut qp = plan_params(cfg, rots, &embed, &lm_head, dense, transitions);
    attach_packed(&mut qp.layers, &qlinears);
    Ok((qp, sse, qlinears, telemetry))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DenseModel;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn random_fp(cfg: &ModelCfg, seed: u64) -> FpParams {
        let mut rng = SplitMix64::new(seed);
        let mut dense = |c: usize, h: usize| -> Vec<f32> {
            (0..c * h).map(|_| (rng.next_normal() / (c as f64).sqrt()) as f32).collect()
        };
        let layers = (0..cfg.n_layers)
            .map(|_| crate::model::weights::FpLayer {
                ln1: (0..cfg.d_model).map(|i| 1.0 + 0.1 * (i % 5) as f32).collect(),
                ln2: (0..cfg.d_model).map(|i| 1.0 + 0.05 * (i % 7) as f32).collect(),
                wq: dense(cfg.d_model, cfg.d_model),
                wk: dense(cfg.d_model, cfg.d_model),
                wv: dense(cfg.d_model, cfg.d_model),
                wo: dense(cfg.d_model, cfg.d_model),
                wgate: dense(cfg.d_model, cfg.d_ffn),
                wup: dense(cfg.d_model, cfg.d_ffn),
                wdown: dense(cfg.d_ffn, cfg.d_model),
            })
            .collect();
        FpParams {
            embed: dense(cfg.vocab, cfg.d_model),
            lm_head: dense(cfg.d_model, cfg.vocab),
            ln_f: vec![1.0; cfg.d_model],
            layers,
        }
    }

    fn hetero_plan(seed: u64) -> RotationPlan {
        RotationPlan {
            seed,
            layers: vec![
                RotationSpec {
                    r1: R1Kind::GSR,
                    r1_block: 8,
                    r4: R4Kind::GH,
                    r4_block: 64,
                    r1_angles: 0,
                },
                RotationSpec {
                    r1: R1Kind::GH,
                    r1_block: 32,
                    r4: R4Kind::LH,
                    r4_block: 16,
                    r1_angles: 0,
                },
            ],
        }
    }

    fn parametric_plan(seed: u64) -> RotationPlan {
        RotationPlan {
            seed,
            layers: vec![
                RotationSpec {
                    r1: R1Kind::GIV,
                    r1_block: 16,
                    r4: R4Kind::GH,
                    r4_block: 64,
                    r1_angles: 0x2A17_0040_8020_1103,
                },
                RotationSpec {
                    r1: R1Kind::BFLY,
                    r1_block: 32,
                    r4: R4Kind::LH,
                    r4_block: 16,
                    r1_angles: 0x0102_0304_05,
                },
            ],
        }
    }

    /// Fig. 1, natively: fused/rotated forward ≡ fp forward, all kinds.
    #[test]
    fn fig1_invariance_native() {
        let cfg = tiny_cfg();
        let fp = random_fp(&cfg, 3);
        let tokens: Vec<i32> = (0..12).map(|i| (i * 7 % 64) as i32).collect();
        let fp_model = DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() };
        let expect = fp_model.forward(&tokens);
        for r1_kind in R1Kind::ALL {
            for r4_kind in [R4Kind::GH, R4Kind::LH] {
                let rots = build_rotations(&cfg, r1_kind, r4_kind, 99);
                let qp = fuse_to_dense(&fp, &cfg, &rots);
                let qmodel = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None };
                let got = qmodel.forward(&tokens);
                let worst = expect
                    .iter()
                    .zip(&got)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(
                    worst < 2e-3,
                    "{r1_kind}/{r4_kind:?}: rotated forward diverges by {worst}"
                );
            }
        }
    }

    /// Fig. 1 with a *heterogeneous* plan: per-layer R1 specs with an
    /// explicit residual-stream basis transition still reproduce the fp
    /// forward exactly (to float tolerance).
    #[test]
    fn fig1_invariance_heterogeneous_plan() {
        let cfg = tiny_cfg();
        let fp = random_fp(&cfg, 3);
        let tokens: Vec<i32> = (0..12).map(|i| (i * 7 % 64) as i32).collect();
        let expect = DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() }.forward(&tokens);
        let rots = build_plan_rotations(&cfg, &hetero_plan(7)).unwrap();
        let qp = fuse_to_dense_plan(&fp, &cfg, &rots);
        // Layer 1 switches R1 → it must carry a basis change; layer 0 not.
        assert!(qp.layers[0].basis_change.is_none());
        assert!(qp.layers[1].basis_change.is_some());
        assert!(qp.layers.iter().all(|l| l.r4.is_some()));
        let got = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None }
            .forward(&tokens);
        let worst =
            expect.iter().zip(&got).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(worst < 2e-3, "heterogeneous plan diverges by {worst}");
    }

    /// A uniform plan needs no basis transitions and shares one build.
    #[test]
    fn uniform_plan_dedups_and_skips_transitions() {
        let cfg = tiny_cfg();
        let plan = RotationPlan::uniform(RotationSpec::baseline(&cfg), cfg.n_layers, 5);
        assert!(plan.is_uniform());
        let rots = build_plan_rotations(&cfg, &plan).unwrap();
        assert_eq!(rots.distinct, 1);
        assert!(Arc::ptr_eq(&rots.layers[0].r1, &rots.layers[1].r1));
        let fp = random_fp(&cfg, 9);
        let qp = fuse_to_dense_plan(&fp, &cfg, &rots);
        assert!(qp.layers.iter().all(|l| l.basis_change.is_none()));
    }

    /// Serialize → reload → rebuild: matrices are bit-identical.
    #[test]
    fn plan_roundtrip_rebuilds_bit_identical_matrices() {
        let cfg = tiny_cfg();
        let plan = hetero_plan(2025);
        let text = plan.to_json().to_string_pretty();
        let reloaded = RotationPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(plan, reloaded);
        let a = build_plan_rotations(&cfg, &plan).unwrap();
        let b = build_plan_rotations(&cfg, &reloaded).unwrap();
        assert_eq!(a.r2, b.r2);
        assert_eq!(a.r3, b.r3);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.r1.data, lb.r1.data, "r1 must rebuild bit-identically");
            assert_eq!(la.r4.data, lb.r4.data, "r4 must rebuild bit-identically");
            assert_eq!(la.r4_signs.as_ref(), lb.r4_signs.as_ref());
        }
    }

    /// Plan validation catches geometry errors early with layer context.
    #[test]
    fn plan_validation_reports_bad_layers() {
        let cfg = tiny_cfg();
        let mut plan = RotationPlan::uniform(RotationSpec::baseline(&cfg), cfg.n_layers, 1);
        plan.layers[1].r1_block = 24;
        let err = build_plan_rotations(&cfg, &plan).unwrap_err();
        assert!(err.contains("layer 1"), "{err}");
        plan.layers.pop();
        assert!(plan.validate(&cfg).is_err());
    }

    /// Native W2 quantization runs end-to-end and degrades gracefully.
    #[test]
    fn quantize_native_end_to_end() {
        let cfg = tiny_cfg();
        let fp = random_fp(&cfg, 5);
        let rots = build_rotations(&cfg, R1Kind::GSR, R4Kind::GH, 7);
        let (qp, sse, qlinears) = quantize_native(&fp, &cfg, &rots, 2);
        assert!(sse > 0.0);
        assert_eq!(qlinears.len(), cfg.n_layers * LINEARS.len());
        let tokens: Vec<i32> = (0..10).map(|i| (i % 64) as i32).collect();
        let model = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None };
        let logits = model.forward(&tokens);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    /// Heterogeneous-plan quantization runs end-to-end too.
    #[test]
    fn quantize_native_plan_end_to_end() {
        let cfg = tiny_cfg();
        let fp = random_fp(&cfg, 5);
        let rots = build_plan_rotations(&cfg, &hetero_plan(7)).unwrap();
        let (qp, sse, qlinears) = quantize_native_plan(&fp, &cfg, &rots, 2);
        assert!(sse > 0.0);
        assert_eq!(qlinears.len(), cfg.n_layers * LINEARS.len());
        let tokens: Vec<i32> = (0..10).map(|i| (i % 64) as i32).collect();
        let model = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None };
        let logits = model.forward(&tokens);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    /// Plan fingerprints key on seed and every spec field — the property
    /// the calibration artifact relies on.
    #[test]
    fn plan_fingerprint_keys_on_seed_and_specs() {
        let plan = hetero_plan(7);
        assert_eq!(plan.fingerprint(), hetero_plan(7).fingerprint());
        assert_ne!(plan.fingerprint(), hetero_plan(8).fingerprint());
        let mut other = hetero_plan(7);
        other.layers[1].r1_block = 16;
        assert_ne!(plan.fingerprint(), other.fingerprint());
        let mut r4flip = hetero_plan(7);
        r4flip.layers[0].r4 = R4Kind::LH;
        r4flip.layers[0].r4_block = 16;
        assert_ne!(plan.fingerprint(), r4flip.fingerprint());
    }

    /// Angle words are part of the basis identity: flipping one stage
    /// code changes the fingerprint, while all-zero angle words leave
    /// pre-existing plan fingerprints untouched.
    #[test]
    fn plan_fingerprint_keys_on_angles() {
        let plan = parametric_plan(7);
        assert_eq!(plan.fingerprint(), parametric_plan(7).fingerprint());
        let mut other = parametric_plan(7);
        other.layers[0].r1_angles ^= 0x01;
        assert_ne!(plan.fingerprint(), other.fingerprint());
        // Angle-free plans fingerprint exactly as before the field
        // existed (the chain only extends on nonzero words).
        let legacy = hetero_plan(7);
        assert!(legacy.layers.iter().all(|s| s.r1_angles == 0));
        assert_eq!(legacy.fingerprint(), hetero_plan(7).fingerprint());
    }

    /// Fig. 1 with parametric (GIV/BFLY) layers: searched-angle
    /// rotations are exactly orthogonal, so the fused forward still
    /// reproduces the fp forward, including the basis transition
    /// between the two parametric kinds.
    #[test]
    fn fig1_invariance_parametric_plan() {
        let cfg = tiny_cfg();
        let fp = random_fp(&cfg, 3);
        let tokens: Vec<i32> = (0..12).map(|i| (i * 7 % 64) as i32).collect();
        let expect = DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() }.forward(&tokens);
        let rots = build_plan_rotations(&cfg, &parametric_plan(7)).unwrap();
        let qp = fuse_to_dense_plan(&fp, &cfg, &rots);
        assert!(qp.layers[1].basis_change.is_some());
        let got = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None }
            .forward(&tokens);
        let worst =
            expect.iter().zip(&got).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(worst < 2e-3, "parametric plan diverges by {worst}");
    }

    /// Parametric plans round-trip through JSON with bit-identical
    /// rebuilds (pure function of the spec — no RNG in the build), and
    /// plans saved before `r1_angles` existed still load (default 0).
    #[test]
    fn parametric_plan_roundtrip_and_back_compat() {
        let cfg = tiny_cfg();
        let plan = parametric_plan(2025);
        let text = plan.to_json().to_string_pretty();
        let reloaded = RotationPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(plan, reloaded);
        let a = build_plan_rotations(&cfg, &plan).unwrap();
        let b = build_plan_rotations(&cfg, &reloaded).unwrap();
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.r1.data, lb.r1.data, "parametric r1 must rebuild bit-identically");
        }
        // A pre-angle plan JSON (no r1_angles key) parses with angles 0.
        let legacy = r#"{"seed":"7","layers":[
            {"r1":"GSR","r1_block":8,"r4":"GH","r4_block":64},
            {"r1":"GH","r1_block":32,"r4":"LH","r4_block":16}]}"#;
        let parsed = RotationPlan::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert_eq!(parsed, hetero_plan(7));
    }

    /// Calibrated GPTQ consumes real Hessians: the quantization visibly
    /// differs from the identity-Hessian run and still yields a finite,
    /// runnable model.
    #[test]
    fn quantize_native_plan_calibrated_end_to_end() {
        use crate::calib::{capture_hessians, checkpoint_fingerprint, CaptureKey};
        use crate::data::{draw_token_windows, CorpusGenerator};

        let cfg = tiny_cfg();
        let fp = random_fp(&cfg, 5);
        let plan = RotationPlan::uniform(RotationSpec::baseline(&cfg), cfg.n_layers, 7);
        let rots = build_plan_rotations(&cfg, &plan).unwrap();
        let dense = fuse_to_dense_plan(&fp, &cfg, &rots);
        let corpus = CorpusGenerator::new(42).generate(2048);
        let seqs = draw_token_windows(&corpus, 8, 16, cfg.vocab, 3);
        let key = CaptureKey {
            calib_seed: 3,
            basis_fingerprint: plan.fingerprint(),
            checkpoint_fingerprint: checkpoint_fingerprint(&fp),
            plan_json: String::new(),
        };
        let set = capture_hessians(&cfg, &dense, &seqs, 0, &key);
        assert!(set.check_basis(plan.fingerprint()).is_ok());
        assert!(set.check_checkpoint(&fp).is_ok());
        // A different checkpoint with the same shapes is refused.
        let other_fp = random_fp(&cfg, 6);
        assert!(
            quantize_native_plan_with(&other_fp, &cfg, &rots, 2, Some(&set)).is_err(),
            "checkpoint mismatch must be rejected"
        );

        let (qp_id, sse_id, ql_id) = quantize_native_plan(&fp, &cfg, &rots, 2);
        let (qp_cal, sse_cal, ql_cal) =
            quantize_native_plan_with(&fp, &cfg, &rots, 2, Some(&set)).unwrap();
        assert!(sse_id > 0.0 && sse_cal > 0.0);
        assert_eq!(ql_cal.len(), ql_id.len());
        // The Hessian must actually steer the codes somewhere.
        let differs = ql_id
            .iter()
            .zip(&ql_cal)
            .any(|(a, b)| a.codes != b.codes);
        assert!(differs, "calibrated GPTQ produced identical codes to identity GPTQ");
        let tokens: Vec<i32> = (0..10).map(|i| (i % 64) as i32).collect();
        for qp in [qp_id, qp_cal] {
            let model = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None };
            assert!(model.forward(&tokens).iter().all(|v| v.is_finite()));
        }
    }

    /// Geometry mismatches are reported, not silently accepted.
    #[test]
    fn calibrated_quantize_rejects_wrong_geometry() {
        let cfg = tiny_cfg();
        let fp = random_fp(&cfg, 5);
        let rots = build_rotations(&cfg, R1Kind::GSR, R4Kind::GH, 7);
        let mut other = cfg.clone();
        other.n_layers = 5;
        let set = crate::calib::HessianSet::new(&other, &crate::calib::CaptureKey::default());
        assert!(quantize_native_with(&fp, &cfg, &rots, 2, Some(&set)).is_err());
    }

    /// Local rotations beat global on SSE for outlier-row weights —
    /// the Table-1 mechanism, natively.
    #[test]
    fn local_rotation_reduces_sse_with_outlier_gamma() {
        let cfg = tiny_cfg();
        let mut fp = random_fp(&cfg, 11);
        // Outlier γ rows (the massive-channel substitution).
        for layer in fp.layers.iter_mut() {
            layer.ln1[3] = 9.0;
            layer.ln1[17] = 12.0;
            layer.ln2[8] = 10.0;
        }
        let sse_of = |kind: R1Kind| {
            let rots = build_rotations(&cfg, kind, R4Kind::GH, 13);
            quantize_native(&fp, &cfg, &rots, 2).1
        };
        let gh = sse_of(R1Kind::GH);
        let gsr = sse_of(R1Kind::GSR);
        let lh = sse_of(R1Kind::LH);
        assert!(
            gsr < gh && lh < gh,
            "local (LH {lh:.1}, GSR {gsr:.1}) must beat global (GH {gh:.1})"
        );
    }
}
