//! Native group quantizers — RTN, GPTQ, MSE clipping, bit packing.
//!
//! Mirrors `python/compile/gptq.py` so the Rust side can (a) verify
//! artifacts produced by the Python build path, (b) run the analysis
//! benches (sequency variance → quantization error, Fig. 2 outlier
//! spread) natively, and (c) serve as a standalone quantization library
//! for downstream users.
//!
//! Conventions: a linear is `out = x @ W`, `W ∈ R^{C×H}` (C input
//! channels, H output channels); quantization groups span `G`
//! consecutive **input** channels per output channel (the grouping the
//! paper's Observation #1 reasons about).

pub mod gptq;
pub mod linalg;
pub mod pack;
pub mod pipeline;
pub mod rtn;

pub use gptq::{gptq_factor, gptq_quantize, gptq_quantize_factored, GptqFactor};
pub use pipeline::{
    build_plan_rotations, build_rotations, build_spec_r1, fuse_rotations, fuse_rotations_plan,
    fuse_to_dense, fuse_to_dense_plan, quantize_native, quantize_native_plan,
    quantize_native_plan_telemetry, quantize_native_plan_with, quantize_native_with,
    LayerQuantTelemetry, LayerRotations, PlanRotations, RotationPlan, RotationSet, RotationSpec,
};
pub use pack::{pack2, pack4, unpack2, unpack4};
pub use rtn::{fake_quant_sym, group_params, rtn_quantize};

use crate::transform::Mat;

/// A group-quantized linear layer: integer codes + per-group affine.
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    /// Codes in `[0, 2^bits)`, row-major `[C, H]`.
    pub codes: Vec<i32>,
    /// Per-group scales, row-major `[C/G, H]`.
    pub scale: Vec<f64>,
    /// Per-group zero points, row-major `[C/G, H]`.
    pub zero: Vec<f64>,
    pub c: usize,
    pub h: usize,
    pub group: usize,
    pub bits: u32,
}

impl QuantizedLinear {
    /// Expand codes back to a dense `[C, H]` matrix.
    pub fn dequant(&self) -> Mat {
        let mut w = Mat::zeros(self.c, self.h);
        let n_groups = self.c / self.group;
        for g in 0..n_groups {
            for r in 0..self.group {
                let row = g * self.group + r;
                for col in 0..self.h {
                    let code = self.codes[row * self.h + col] as f64;
                    let s = self.scale[g * self.h + col];
                    let z = self.zero[g * self.h + col];
                    w[(row, col)] = (code - z) * s;
                }
            }
        }
        w
    }

    /// Mean-squared reconstruction error against the original weight.
    pub fn mse(&self, w: &Mat) -> f64 {
        assert_eq!((w.rows, w.cols), (self.c, self.h));
        let deq = self.dequant();
        let mut sum = 0.0;
        for (a, b) in deq.data.iter().zip(&w.data) {
            sum += (a - b) * (a - b);
        }
        sum / (self.c * self.h) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dequant_shape_and_affine() {
        let q = QuantizedLinear {
            codes: vec![0, 3, 1, 2],
            scale: vec![0.5, 2.0],
            zero: vec![1.0, 0.0],
            c: 2,
            h: 2,
            group: 2,
            bits: 2,
        };
        let w = q.dequant();
        assert_eq!(w[(0, 0)], (0.0 - 1.0) * 0.5);
        assert_eq!(w[(0, 1)], (3.0 - 0.0) * 2.0);
        assert_eq!(w[(1, 0)], (1.0 - 1.0) * 0.5);
        assert_eq!(w[(1, 1)], (2.0 - 0.0) * 2.0);
    }
}
