//! Minimal dense linear algebra for GPTQ (Cholesky, inversion).
//!
//! Sizes here are at most `d_ffn × d_ffn` (512²) and this runs at
//! build/analysis time only, so clarity beats asymptotics.

use crate::transform::Mat;

/// Cholesky factor `L` (lower-triangular) with `A = L Lᵀ`.
/// Returns `None` if `A` is not positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Inverse of a symmetric positive-definite matrix via Cholesky.
pub fn spd_inverse(a: &Mat) -> Option<Mat> {
    let l = cholesky(a)?;
    let n = a.rows;
    // Invert L (lower-triangular) by forward substitution.
    let mut linv = Mat::zeros(n, n);
    for i in 0..n {
        linv[(i, i)] = 1.0 / l[(i, i)];
        for j in 0..i {
            let mut sum = 0.0;
            for k in j..i {
                sum += l[(i, k)] * linv[(k, j)];
            }
            linv[(i, j)] = -sum / l[(i, i)];
        }
    }
    // A⁻¹ = L⁻ᵀ L⁻¹.
    let mut inv = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut sum = 0.0;
            for k in i.max(j)..n {
                sum += linv[(k, i)] * linv[(k, j)];
            }
            inv[(i, j)] = sum;
        }
    }
    Some(inv)
}

/// Upper-triangular `U` with `A = Uᵀ U` — `cholesky(A, upper=True)` as
/// GPTQ applies it to the inverse Hessian. Simply the transpose of the
/// lower factor: `A = L Lᵀ = (Lᵀ)ᵀ (Lᵀ)`.
pub fn cholesky_upper(a: &Mat) -> Option<Mat> {
    Some(cholesky(a)?.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = SplitMix64::new(seed);
        let b = Mat::from_fn(n, n, |_, _| rng.next_normal());
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64; // well-conditioned
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(16, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let a = random_spd(12, 2);
        let inv = spd_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        for i in 0..12 {
            for j in 0..12 {
                let t = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - t).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_upper_reconstructs() {
        let a = random_spd(10, 3);
        let u = cholesky_upper(&a).unwrap();
        // U must be upper-triangular…
        for i in 0..10 {
            for j in 0..i {
                assert!(u[(i, j)].abs() < 1e-12, "not upper at ({i},{j})");
            }
        }
        // …and satisfy A = Uᵀ U.
        let rec = u.transpose().matmul(&u);
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::identity(4);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_none());
    }
}
