//! SplitMix64 — deterministic, trivially portable PRNG.
//!
//! Bit-for-bit mirror of `python/compile/corpus.py::SplitMix64`; the
//! corpus generator and the zero-shot task suite depend on both sides
//! producing identical streams (asserted against `artifacts/corpus.bin`
//! by the integration tests).

/// SplitMix64 PRNG state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the generator. The same seed yields the same stream as the
    /// Python implementation.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform draw in `[0, n)` (modular; bias negligible for n ≪ 2⁶⁴).
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in `[0, 1)` with a 53-bit mantissa.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Rademacher ±1 draw.
    pub fn next_sign(&mut self) -> f64 {
        if self.next_below(2) == 0 {
            -1.0
        } else {
            1.0
        }
    }

    /// Standard normal via Box–Muller (used by analysis/bench workload
    /// generators; not part of the cross-language contract).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_values_match_reference() {
        // First outputs for seed 0 (the published SplitMix64 vectors;
        // cross-checked against the Python implementation).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn signs_are_pm_one() {
        let mut r = SplitMix64::new(3);
        let mut seen = [false; 2];
        for _ in 0..64 {
            let s = r.next_sign();
            assert!(s == 1.0 || s == -1.0);
            seen[(s > 0.0) as usize] = true;
        }
        assert!(seen[0] && seen[1]);
    }
}
