//! Evaluation engines: perplexity (WikiText-2 stand-in) and zero-shot
//! task accuracy, plus the paper-layout report tables.

pub mod ppl;
pub mod report;
pub mod tables;
pub mod zeroshot;

pub use ppl::{log_softmax_nll, PplEngine};
pub use report::Table;
pub use tables::{eval_model, eval_variant, EvalOpts};
pub use zeroshot::ZeroShotEngine;

/// Anything that turns a `[batch, seq]` token matrix into
/// `[batch, seq, vocab]` logits. Implemented by the PJRT runner wrapper
/// and by the native reference model (tests / fallback).
pub trait LogitModel {
    fn batch(&self) -> usize;
    fn seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// `tokens.len() == batch()*seq()`; returns row-major logits.
    fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String>;
}

/// PJRT-backed model (engine + resident variant).
pub struct PjrtModel<'a> {
    pub engine: &'a crate::runtime::Engine,
    pub runner: &'a crate::runtime::VariantRunner,
}

impl LogitModel for PjrtModel<'_> {
    fn batch(&self) -> usize {
        self.runner.batch
    }
    fn seq(&self) -> usize {
        self.runner.seq
    }
    fn vocab(&self) -> usize {
        self.runner.vocab
    }
    fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
        self.runner.forward(self.engine, tokens)
    }
}

/// Native reference model adapter (single-sequence loop).
pub struct NativeModel<'a> {
    pub model: &'a crate::model::DenseModel,
    pub batch: usize,
    pub seq: usize,
}

impl LogitModel for NativeModel<'_> {
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn vocab(&self) -> usize {
        self.model.cfg().vocab
    }
    fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
        let mut out = Vec::with_capacity(self.batch * self.seq * self.vocab());
        for b in 0..self.batch {
            let seq_tokens = &tokens[b * self.seq..(b + 1) * self.seq];
            out.extend(self.model.forward(seq_tokens));
        }
        Ok(out)
    }
}
