//! Evaluation engines: perplexity (WikiText-2 stand-in) and zero-shot
//! task accuracy, plus the paper-layout report tables.
//!
//! Every engine scores a [`crate::exec::Backend`] — the unified batched
//! execution contract — so the same PPL/zero-shot code runs against the
//! PJRT graphs (`exec::PjrtBackend`) and the multi-threaded native
//! engine (`exec::NativeBackend`), including heterogeneous searched-plan
//! variants PJRT cannot serve.
//!
//! Determinism: engines submit full and partial batches but never pad
//! with fabricated rows, and the native backend's per-sequence logits
//! are bit-identical to the serial forward — so a reported PPL is the
//! same number for any `--threads` and any batch geometry (pinned by
//! `tests/serve_native.rs`).

pub mod ppl;
pub mod report;
pub mod tables;
pub mod zeroshot;

pub use ppl::{log_softmax_nll, PplEngine};
pub use report::Table;
pub use tables::{eval_model, eval_variant, EvalOpts};
pub use zeroshot::ZeroShotEngine;

pub use crate::exec::Backend;
