//! Perplexity engine — the paper's WikiText-2 PPL measurement, on the
//! held-out split of the synthetic corpus (context length = the graph's
//! fixed `seq`, matching the paper's fixed-context protocol).

use crate::exec::Backend;

/// Sum of next-token NLLs for one sequence's logits.
///
/// `logits`: `[seq, vocab]` for tokens `w[0..seq]`; `targets`:
/// `w[1..seq+1]`. Positions beyond `n_predict` are ignored (padding).
pub fn log_softmax_nll(logits: &[f32], vocab: usize, targets: &[i32], n_predict: usize) -> f64 {
    let mut total = 0.0f64;
    for (pos, &target) in targets.iter().enumerate().take(n_predict) {
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
        let logsum: f64 = row.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>().ln() + max;
        total += logsum - row[target as usize] as f64;
    }
    total
}

/// Windowed perplexity evaluation.
pub struct PplEngine {
    /// Max number of windows to evaluate (caps eval cost); 0 = all.
    pub max_windows: usize,
}

#[derive(Debug, Clone)]
pub struct PplResult {
    pub ppl: f64,
    pub nll_sum: f64,
    pub tokens: usize,
    pub windows: usize,
}

impl PplEngine {
    pub fn new(max_windows: usize) -> Self {
        Self { max_windows }
    }

    /// Evaluate byte perplexity of `model` on `text`.
    ///
    /// Windows of `seq+1` tokens, stride `seq` (every byte predicted
    /// exactly once); windows are packed into `[rows ≤ batch, seq]`
    /// calls — the final batch stays partial, never padded, so no
    /// forward pass is spent on rows whose NLL would be discarded.
    pub fn evaluate(&self, model: &dyn Backend, text: &[u8]) -> Result<PplResult, String> {
        let (b, s, v) = (model.batch(), model.seq(), model.vocab());
        let tokens: Vec<i32> = text.iter().map(|&x| x as i32).collect();
        let mut windows: Vec<&[i32]> = Vec::new();
        let mut start = 0;
        while start + s + 1 <= tokens.len() {
            windows.push(&tokens[start..start + s + 1]);
            start += s;
        }
        if self.max_windows > 0 {
            windows.truncate(self.max_windows);
        }
        if windows.is_empty() {
            return Err("text shorter than one window".into());
        }
        let mut nll_sum = 0.0f64;
        let mut n_tokens = 0usize;
        for chunk in windows.chunks(b) {
            let mut batch_tokens = Vec::with_capacity(chunk.len() * s);
            for w in chunk {
                batch_tokens.extend_from_slice(&w[..s]);
            }
            let logits = model.forward_batch(&batch_tokens)?;
            for (i, w) in chunk.iter().enumerate() {
                let row_logits = &logits[i * s * v..(i + 1) * s * v];
                nll_sum += log_softmax_nll(row_logits, v, &w[1..], s);
                n_tokens += s;
            }
        }
        Ok(PplResult {
            ppl: (nll_sum / n_tokens as f64).exp(),
            nll_sum,
            tokens: n_tokens,
            windows: windows.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Uniform {
        vocab: usize,
    }

    impl Backend for Uniform {
        fn batch(&self) -> usize {
            2
        }
        fn seq(&self) -> usize {
            8
        }
        fn vocab(&self) -> usize {
            self.vocab
        }
        fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
            Ok(vec![0.0; tokens.len() * self.vocab])
        }
    }

    #[test]
    fn uniform_model_ppl_equals_vocab() {
        let m = Uniform { vocab: 16 };
        let text: Vec<u8> = (0..64).map(|i| (i % 16) as u8).collect();
        let r = PplEngine::new(0).evaluate(&m, &text).unwrap();
        assert!((r.ppl - 16.0).abs() < 1e-6, "ppl {}", r.ppl);
    }

    #[test]
    fn nll_prefers_correct_token() {
        // Logits strongly favoring target 3 at every position.
        let vocab = 4;
        let mut logits = vec![0f32; 2 * vocab];
        logits[3] = 10.0;
        logits[vocab + 3] = 10.0;
        let good = log_softmax_nll(&logits, vocab, &[3, 3], 2);
        let bad = log_softmax_nll(&logits, vocab, &[0, 0], 2);
        assert!(good < bad);
        assert!(good < 0.1);
    }

    #[test]
    fn max_windows_caps_work() {
        let m = Uniform { vocab: 16 };
        let text: Vec<u8> = vec![0; 1000];
        let r = PplEngine::new(3).evaluate(&m, &text).unwrap();
        assert_eq!(r.windows, 3);
        assert_eq!(r.tokens, 3 * 8);
    }
}
