//! Console report tables in the paper's layout.

/// A simple aligned table (console + markdown rendering).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned plain-text rendering.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavored markdown rendering (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float with fixed decimals, NaN-safe.
pub fn fmt(v: f64, decimals: usize) -> String {
    if v.is_nan() {
        "–".to_string()
    } else {
        format!("{v:.decimals$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["Method", "PPL"]);
        t.row(vec!["QuaRot".into(), "20.29".into()]);
        t.row(vec!["GSR".into(), "11.59".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("QuaRot"));
        let lines: Vec<&str> = r.lines().collect();
        // Header and rows align on the second column.
        let col = lines[1].find("PPL").unwrap();
        assert_eq!(lines[3].find("20.29"), Some(col));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.starts_with("| a | b |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
