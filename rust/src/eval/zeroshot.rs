//! Zero-shot multiple-choice evaluation (lm-eval methodology).
//!
//! Each choice is scored by the **length-normalized log-likelihood** of
//! its bytes given the context; the argmax choice is the prediction.
//! This is exactly how lm-eval scores ARC/HellaSwag/PIQA/…, which the
//! paper's Tables 3–4 report.

use super::ppl::log_softmax_nll;
use crate::data::tasks::{Task, TaskKind};
use crate::exec::Backend;

/// Zero-shot engine over a task suite.
pub struct ZeroShotEngine;

#[derive(Debug, Clone)]
pub struct TaskScore {
    pub kind: TaskKind,
    pub correct: usize,
    pub total: usize,
}

impl TaskScore {
    pub fn accuracy(&self) -> f64 {
        100.0 * self.correct as f64 / self.total.max(1) as f64
    }
}

impl ZeroShotEngine {
    /// Score one task: returns the predicted choice index.
    pub fn predict(model: &dyn Backend, task: &Task) -> Result<usize, String> {
        let (b, s, v) = (model.batch(), model.seq(), model.vocab());
        assert!(task.choices.len() <= b, "choices exceed graph batch");
        // One partial [choices, seq] call: row i = context ‖ choice_i —
        // no forward pass is spent on batch rows with no choice in them.
        let mut batch_tokens = vec![0i32; task.choices.len() * s];
        let mut spans = Vec::with_capacity(task.choices.len());
        for (i, choice) in task.choices.iter().enumerate() {
            let mut seq_bytes = task.context.clone();
            seq_bytes.extend_from_slice(choice);
            // Left-truncate if too long (keep the ending: the choice).
            let full: Vec<i32> = seq_bytes.iter().map(|&x| x as i32).collect();
            let take = full.len().min(s);
            let slice = &full[full.len() - take..];
            batch_tokens[i * s..i * s + take].copy_from_slice(slice);
            // Positions predicting choice bytes: the last `chlen` targets.
            let chlen = choice.len().min(take.saturating_sub(1));
            spans.push((take, chlen));
        }
        // Right-padding inside a used row does not affect its scored
        // prefix positions (causal attention).
        let logits = model.forward_batch(&batch_tokens)?;
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (i, choice) in task.choices.iter().enumerate() {
            let (take, chlen) = spans[i];
            if chlen == 0 {
                continue;
            }
            let row_logits = &logits[i * s * v..(i + 1) * s * v];
            // Targets for positions [take-1-chlen .. take-1) are the
            // choice bytes; compute NLL over just that span.
            let start = take - 1 - chlen;
            let targets: Vec<i32> = (0..chlen)
                .map(|j| batch_tokens[i * s + start + 1 + j])
                .collect();
            let nll = log_softmax_nll(&row_logits[start * v..], v, &targets, chlen);
            let score = -(nll / chlen as f64); // length-normalized
            if score > best.0 {
                best = (score, i);
            }
            let _ = choice;
        }
        Ok(best.1)
    }

    /// Accuracy over a batch of tasks of one kind.
    pub fn score_tasks(model: &dyn Backend, tasks: &[Task]) -> Result<TaskScore, String> {
        let mut correct = 0;
        for t in tasks {
            if Self::predict(model, t)? == t.answer {
                correct += 1;
            }
        }
        Ok(TaskScore {
            kind: tasks.first().map(|t| t.kind).unwrap_or(TaskKind::NextWord),
            correct,
            total: tasks.len(),
        })
    }

    /// Full suite: per-task accuracies plus macro average.
    pub fn score_suite(
        model: &dyn Backend,
        suite: &[(TaskKind, Vec<Task>)],
    ) -> Result<(Vec<TaskScore>, f64), String> {
        let mut scores = Vec::new();
        for (_, tasks) in suite {
            scores.push(Self::score_tasks(model, tasks)?);
        }
        let avg = scores.iter().map(|s| s.accuracy()).sum::<f64>() / scores.len().max(1) as f64;
        Ok((scores, avg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskSuite;
    use crate::data::SEED_CORPUS;

    /// Oracle model: assigns high logit to the next byte of the gold
    /// continuation of the most recent task fed in. Simplest check that
    /// the scorer identifies the intended answer: a bigram-table model
    /// over the corpus grammar.
    struct BigramOracle {
        table: Vec<[f32; 256]>,
    }

    impl BigramOracle {
        fn new() -> Self {
            // Count byte bigrams over a corpus sample.
            let text = crate::data::CorpusGenerator::new(SEED_CORPUS).generate(1 << 16);
            let mut counts = vec![[1f32; 256]; 256];
            for w in text.windows(2) {
                counts[w[0] as usize][w[1] as usize] += 1.0;
            }
            let table = counts
                .into_iter()
                .map(|row| {
                    let sum: f32 = row.iter().sum();
                    let mut out = [0f32; 256];
                    for (o, c) in out.iter_mut().zip(row.iter()) {
                        *o = (c / sum).ln();
                    }
                    out
                })
                .collect();
            Self { table }
        }
    }

    impl Backend for BigramOracle {
        fn batch(&self) -> usize {
            4
        }
        fn seq(&self) -> usize {
            128
        }
        fn vocab(&self) -> usize {
            256
        }
        fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
            let v = 256;
            let mut out = vec![0f32; tokens.len() * v];
            for (pos, &tok) in tokens.iter().enumerate() {
                out[pos * v..(pos + 1) * v].copy_from_slice(&self.table[tok as usize]);
            }
            Ok(out)
        }
    }

    #[test]
    fn bigram_oracle_beats_chance_on_suite() {
        let model = BigramOracle::new();
        let suite = TaskSuite::new(SEED_CORPUS).suite(20);
        let (scores, avg) = ZeroShotEngine::score_suite(&model, &suite).unwrap();
        assert_eq!(scores.len(), 8);
        // A byte-bigram model has no grammar knowledge; with rank- and
        // length-matched distractors it sits near the ~31% chance floor
        // (the real signal needs the trained LM — see runtime_e2e).
        assert!((20.0..50.0).contains(&avg), "avg {avg}");
    }

    #[test]
    fn predict_returns_valid_index() {
        let model = BigramOracle::new();
        let mut gen = TaskSuite::new(SEED_CORPUS);
        for (_, tasks) in gen.suite(3) {
            for t in tasks {
                let p = ZeroShotEngine::predict(&model, &t).unwrap();
                assert!(p < t.choices.len());
            }
        }
    }
}
