//! Paper-table generation: the code behind Tables 1–4 and the analysis
//! benches. Shared by the `gsr` CLI, the examples and `cargo bench`.

use std::path::Path;

use super::report::{fmt, Table};
use super::{PplEngine, ZeroShotEngine};
use crate::data::tasks::TaskSuite;
use crate::exec::{Backend, PjrtBackend};
use crate::runtime::{Artifacts, Engine};

/// Evaluation knobs (trade precision for wall-clock).
#[derive(Debug, Clone, Copy)]
pub struct EvalOpts {
    /// PPL windows (0 = all of the test split).
    pub windows: usize,
    /// Zero-shot instances per task family (0 = skip zero-shot).
    pub tasks_per_kind: usize,
}

impl Default for EvalOpts {
    fn default() -> Self {
        Self { windows: 24, tasks_per_kind: 12 }
    }
}

/// PPL + zero-shot of one resident model.
pub struct VariantEval {
    pub ppl: f64,
    pub zero_shot_avg: f64,
    pub per_task: Vec<(String, f64)>,
}

pub fn eval_model(
    model: &dyn Backend,
    arts: &Artifacts,
    opts: EvalOpts,
) -> Result<VariantEval, String> {
    let ppl = PplEngine::new(opts.windows).evaluate(model, arts.test_split())?.ppl;
    let (mut zero_shot_avg, mut per_task) = (f64::NAN, Vec::new());
    if opts.tasks_per_kind > 0 {
        let suite = TaskSuite::new(arts.corpus_seed()).suite(opts.tasks_per_kind);
        let (scores, avg) = ZeroShotEngine::score_suite(model, &suite)?;
        zero_shot_avg = avg;
        per_task = scores
            .iter()
            .map(|s| (s.kind.name().to_string(), s.accuracy()))
            .collect();
    }
    Ok(VariantEval { ppl, zero_shot_avg, per_task })
}

/// Evaluate a named variant (PJRT path). `"fp"` = the W16A16 reference.
pub fn eval_variant(
    engine: &mut Engine,
    arts: &Artifacts,
    name: &str,
    opts: EvalOpts,
) -> Result<VariantEval, String> {
    let runner = crate::exec::load_runner(engine, arts, name)?;
    let model = PjrtBackend { engine, runner: &runner };
    eval_model(&model, arts, opts)
}

/// Table 1: PPL + averaged zero-shot for every method × bits × R1.
pub fn table1(artifacts: &Path, opts: EvalOpts, verbose: bool) -> Result<Table, String> {
    let arts = Artifacts::load(artifacts)?;
    let mut engine = Engine::new()?;
    let mut table = Table::new(
        "Table 1 — PPL (synthetic WikiText-2 stand-in) and 0-shot avg",
        &["Method", "Bits", "R1", "PPL↓", "0-shot↑"],
    );
    let fp = eval_variant(&mut engine, &arts, "fp", opts)?;
    table.row(vec!["-".into(), "W16A16".into(), "-".into(), fmt(fp.ppl, 2), fmt(fp.zero_shot_avg, 2)]);
    for method in ["quarot", "spinquant", "ostquant"] {
        for bits in ["w2a16", "w2a4"] {
            for r1 in ["gh", "gw", "lh", "gsr"] {
                let name = format!("{method}_{bits}_{r1}_r4gh");
                if arts.variant(&name).is_none() {
                    continue;
                }
                let ev = eval_variant(&mut engine, &arts, &name, opts)?;
                if verbose {
                    eprintln!("[table1] {name}: ppl={:.2} 0shot={:.2}", ev.ppl, ev.zero_shot_avg);
                }
                table.row(vec![
                    method.to_string(),
                    bits.to_uppercase(),
                    r1.to_uppercase(),
                    fmt(ev.ppl, 2),
                    fmt(ev.zero_shot_avg, 2),
                ]);
            }
        }
    }
    Ok(table)
}

/// Table 2: R1 × R4 local-rotation ablation (QuaRot, W2 and W2A4).
pub fn table2(artifacts: &Path, opts: EvalOpts) -> Result<Table, String> {
    let arts = Artifacts::load(artifacts)?;
    let mut engine = Engine::new()?;
    let mut table = Table::new(
        "Table 2 — local rotation on R4 (QuaRot)",
        &["R1", "R4", "PPL (W2)", "PPL† (W2A4)"],
    );
    for (r1, r4) in [("lh", "gh"), ("lh", "lh"), ("gsr", "gh"), ("gsr", "lh")] {
        let w2 = eval_variant(
            &mut engine,
            &arts,
            &format!("quarot_w2a16_{r1}_r4{r4}"),
            EvalOpts { tasks_per_kind: 0, ..opts },
        )?;
        let w2a4 = eval_variant(
            &mut engine,
            &arts,
            &format!("quarot_w2a4_{r1}_r4{r4}"),
            EvalOpts { tasks_per_kind: 0, ..opts },
        )?;
        table.row(vec![
            r1.to_uppercase(),
            r4.to_uppercase(),
            fmt(w2.ppl, 2),
            fmt(w2a4.ppl, 2),
        ]);
    }
    Ok(table)
}

/// Tables 3/4: per-task zero-shot breakdown for one method.
pub fn table3(artifacts: &Path, method: &str, opts: EvalOpts) -> Result<Table, String> {
    let arts = Artifacts::load(artifacts)?;
    let mut engine = Engine::new()?;
    let suite = TaskSuite::new(arts.corpus_seed()).suite(opts.tasks_per_kind.max(1));
    let task_names: Vec<String> =
        suite.iter().map(|(k, _)| k.name().to_string()).collect();
    let mut headers: Vec<&str> = vec!["Bits", "R1"];
    let name_refs: Vec<&str> = task_names.iter().map(|s| s.as_str()).collect();
    headers.extend(name_refs);
    headers.push("Avg.");
    let mut table = Table::new(
        &format!("Table 3/4 — per-task zero-shot accuracy ({method})"),
        &headers,
    );
    let mut add_row = |bits: &str, r1: &str, ev: &VariantEval| {
        let mut row = vec![bits.to_string(), r1.to_string()];
        row.extend(ev.per_task.iter().map(|(_, acc)| fmt(*acc, 1)));
        row.push(fmt(ev.zero_shot_avg, 2));
        table.row(row);
    };
    let fp = eval_variant(&mut engine, &arts, "fp", opts)?;
    add_row("16-16", "-", &fp);
    for bits in ["w2a16", "w2a4"] {
        for r1 in ["gh", "gw", "lh", "gsr"] {
            let name = format!("{method}_{bits}_{r1}_r4gh");
            if arts.variant(&name).is_none() {
                continue;
            }
            let ev = eval_variant(&mut engine, &arts, &name, opts)?;
            add_row(if bits == "w2a16" { "2-16" } else { "2-4" }, &r1.to_uppercase(), &ev);
        }
    }
    Ok(table)
}

/// §3.2 sequency-variance analysis table (native, no PJRT).
pub fn sequency_table(n: usize, group: usize) -> Table {
    let mut table = Table::new(
        "§3.2 — column-group sequency variance and rotated-weight quant error",
        &["R1", "mean seq. variance", "group-RTN MSE (structured W)"],
    );
    for r in crate::analysis::sequency_variance_report(n, group, 64, 2, 7) {
        table.row(vec![
            r.kind.to_string(),
            fmt(r.mean_group_variance, 2),
            format!("{:.3e}", r.rotated_quant_mse),
        ]);
    }
    table
}

/// Human label of the GPTQ calibration mode for eval reports — derived
/// from the mode actually in effect, so report lines can never misstate
/// the method (the old output hardcoded "identity-Hessian GPTQ").
pub fn calib_label(calib: Option<&crate::calib::HessianSet>) -> String {
    match calib {
        Some(set) => format!("Hessian-calibrated GPTQ, {} calib tokens", set.tokens),
        None => "identity-Hessian GPTQ".to_string(),
    }
}

/// Compressed label for a (possibly heterogeneous) rotation plan:
/// uniform plans render like classic variants (`GSR/64+r4GH ×4`),
/// heterogeneous ones list per-layer specs.
pub fn plan_summary(plan: &crate::quant::RotationPlan) -> String {
    if plan.layers.is_empty() {
        return "empty plan".to_string();
    }
    if plan.is_uniform() {
        format!("{} ×{}", plan.layers[0].label(), plan.layers.len())
    } else {
        let parts: Vec<String> = plan
            .layers
            .iter()
            .enumerate()
            .map(|(l, s)| format!("L{l}:{}", s.label()))
            .collect();
        format!("hetero[{}]", parts.join(" "))
    }
}

/// Per-layer `gsr search` report: searched spec vs the fixed-GSR
/// baseline, on measured group-RTN MSE.
pub fn search_table(outcome: &crate::search::SearchOutcome) -> Table {
    let mut table = Table::new(
        "gsr search — per-layer rotation plan vs fixed GSR baseline",
        &["Layer", "Spec", "group-RTN MSE", "baseline (GSR)", "Δ%", "seq.var", "cands"],
    );
    for r in &outcome.layers {
        let delta = if r.baseline.quant_mse > 0.0 {
            100.0 * (r.best.quant_mse - r.baseline.quant_mse) / r.baseline.quant_mse
        } else {
            0.0
        };
        table.row(vec![
            r.layer.to_string(),
            r.best.spec.label(),
            format!("{:.4e}", r.best.quant_mse),
            format!("{:.4e}", r.baseline.quant_mse),
            fmt(delta, 2),
            fmt(r.best.seq_variance, 2),
            r.evaluated.to_string(),
        ]);
    }
    table
}

/// Fig. 2 outlier-spread table (native, no PJRT).
pub fn fig2_table(n: usize, group: usize) -> Table {
    let mut table = Table::new(
        "Fig. 2 — outlier energy spread: global vs local rotation",
        &["R1", "participation ratio", "in-group energy"],
    );
    for s in crate::analysis::outlier_spread(n, group, 3) {
        table.row(vec![
            s.kind.to_string(),
            fmt(s.participation_ratio, 1),
            fmt(s.in_group_energy, 3),
        ]);
    }
    table
}
