//! §3.2 quantification: sequency arrangement vs group quantization error.
//!
//! The paper's Observation #1: under group quantization, *column group*
//! `n` of the front rotation `R_f` alone determines rotated-weight group
//! `n` (`W' = R_fᵀ W`, rows `nG..(n+1)G` of `W'` come from columns
//! `nG..(n+1)G` of `R_f`). The Walsh ordering minimizes the intra-group
//! variance of the sequencies of those columns; this module measures
//! both that variance and the downstream group-quantization error on
//! structured weights, for each R1 kind.

use crate::quant::rtn_quantize;
use crate::rng::SplitMix64;
use crate::transform::{build_r1, Mat, R1Kind};

/// Intra-group sequency variance of the *columns* of a rotation matrix
/// (the quantity the paper argues Walsh minimizes), one value per group.
///
/// Column sequency = sign-flip count down the column; for the symmetric
/// Hadamard matrix this equals the row sequency. For block-diagonal
/// rotations the per-block column pattern repeats; zero-padding outside
/// the block does not flip signs.
///
/// Errors (instead of panicking) when `group` does not evenly tile the
/// columns — the `gsr search` grid probes arbitrary block sizes and must
/// be able to survive the invalid ones.
pub fn column_group_sequency_variance(r: &Mat, group: usize) -> Result<Vec<f64>, String> {
    if group == 0 || r.cols % group != 0 {
        return Err(format!(
            "sequency group {group} must be nonzero and divide the rotation's {} columns",
            r.cols
        ));
    }
    let n = r.rows;
    Ok((0..r.cols / group)
        .map(|g| {
            let seqs: Vec<f64> = (g * group..(g + 1) * group)
                .map(|c| {
                    let col: Vec<f64> = (0..n).map(|row| r[(row, c)]).collect();
                    // Count flips over the nonzero support (block-diag
                    // columns are zero outside their block).
                    let nz: Vec<f64> = col.iter().copied().filter(|v| *v != 0.0).collect();
                    nz.windows(2)
                        .filter(|w| (w[0] >= 0.0) != (w[1] >= 0.0))
                        .count() as f64
                })
                .collect();
            let mean = seqs.iter().sum::<f64>() / seqs.len() as f64;
            seqs.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / seqs.len() as f64
        })
        .collect())
}

/// Group-RTN MSE of an already-rotated weight (groups along rows) — the
/// measured quantization-error proxy the `gsr search` objective and the
/// §3.2 sweep share.
pub fn group_rtn_mse(w: &Mat, group: usize, bits: u32) -> f64 {
    rtn_quantize(w, bits, group, true).mse(w)
}

/// diag(H)-weighted group-RTN MSE: each row's squared error is weighted
/// by that input channel's calibration energy `row_weights[r]`
/// (diagonal of the activation Hessian in the same basis as `w`), so
/// the proxy tracks `‖X ΔW‖²` instead of `‖ΔW‖²`. Weights are
/// normalized internally — uniform weights reproduce [`group_rtn_mse`]
/// exactly, and an all-zero weight vector falls back to it.
pub fn group_rtn_mse_weighted(w: &Mat, group: usize, bits: u32, row_weights: &[f64]) -> f64 {
    assert_eq!(row_weights.len(), w.rows, "one weight per input channel");
    let q = rtn_quantize(w, bits, group, true);
    let deq = q.dequant();
    let mut num = 0.0;
    let mut wsum = 0.0;
    for r in 0..w.rows {
        let wt = row_weights[r].max(0.0);
        wsum += wt;
        if wt == 0.0 {
            continue;
        }
        let sse: f64 = deq
            .row(r)
            .iter()
            .zip(w.row(r))
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        num += wt * sse;
    }
    if wsum <= 0.0 {
        return group_rtn_mse(w, group, bits);
    }
    num / (wsum * w.cols as f64)
}

/// Group-RTN MSE of `R1ᵀ W` for a given rotation matrix.
pub fn rotated_group_rtn_mse(w: &Mat, r1: &Mat, group: usize, bits: u32) -> f64 {
    let rotated = r1.transpose().matmul(w);
    group_rtn_mse(&rotated, group, bits)
}

/// Report row for one R1 kind.
#[derive(Debug, Clone)]
pub struct SequencyReport {
    pub kind: R1Kind,
    /// Mean intra-group column-sequency variance.
    pub mean_group_variance: f64,
    /// Group-RTN quantization MSE of the rotated structured weight.
    pub rotated_quant_mse: f64,
}

/// Synthetic *structured* weight: smooth low-frequency channel profile +
/// a few outlier input channels — the regime where sequency arrangement
/// matters (isotropic Gaussian weights are rotation-invariant in
/// distribution and show no effect; trained LLM weights are not
/// isotropic).
pub fn structured_weight(c: usize, h: usize, seed: u64) -> Mat {
    let mut rng = SplitMix64::new(seed);
    let mut w = Mat::zeros(c, h);
    // Low-frequency profile across input channels per output channel.
    for col in 0..h {
        let phase = rng.next_f64() * std::f64::consts::TAU;
        let freq = 1.0 + rng.next_f64() * 3.0;
        let amp = 0.5 + rng.next_f64();
        for row in 0..c {
            let tgrid = row as f64 / c as f64;
            w[(row, col)] =
                amp * (freq * std::f64::consts::TAU * tgrid + phase).sin() + 0.3 * rng.next_normal();
        }
    }
    // Outlier channels (massive-activation analogue on the weight side).
    for _ in 0..(c / 32).max(1) {
        let row = rng.next_below(c as u64) as usize;
        for col in 0..h {
            w[(row, col)] *= 6.0;
        }
    }
    w
}

/// Full §3.2 sweep: for each R1 kind, the sequency variance of its
/// column groups and the group-quant MSE of `R1ᵀ W` on a structured W.
pub fn sequency_variance_report(
    n: usize,
    group: usize,
    h: usize,
    bits: u32,
    seed: u64,
) -> Vec<SequencyReport> {
    let w = structured_weight(n, h, seed);
    R1Kind::ALL
        .iter()
        .map(|&kind| {
            let mut rng = SplitMix64::new(seed + 77);
            let r1 = build_r1(kind, n, group, &mut rng);
            let vars = column_group_sequency_variance(&r1, group)
                .expect("report geometry: group divides n");
            let mean_var = vars.iter().sum::<f64>() / vars.len() as f64;
            SequencyReport {
                kind,
                mean_group_variance: mean_var,
                rotated_quant_mse: rotated_group_rtn_mse(&w, &r1, group, bits),
            }
        })
        .collect()
}

/// Group-quant error of `R1ᵀ W` for an arbitrary provided weight.
pub fn group_quant_error_by_rotation(w: &Mat, group: usize, bits: u32, seed: u64) -> Vec<(R1Kind, f64)> {
    R1Kind::ALL
        .iter()
        .map(|&kind| {
            let mut rng = SplitMix64::new(seed);
            let r1 = build_r1(kind, w.rows, group, &mut rng);
            (kind, rotated_group_rtn_mse(w, &r1, group, bits))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walsh_has_lower_group_variance_than_hadamard() {
        // The paper's §3.2 claim, verified directly on the matrices.
        let (n, g) = (256, 64);
        let mut rng = SplitMix64::new(1);
        let gh = build_r1(R1Kind::GH, n, g, &mut rng);
        let gw = build_r1(R1Kind::GW, n, g, &mut rng);
        let vh = column_group_sequency_variance(&gh, g).unwrap();
        let vw = column_group_sequency_variance(&gw, g).unwrap();
        let mh = vh.iter().sum::<f64>() / vh.len() as f64;
        let mw = vw.iter().sum::<f64>() / vw.len() as f64;
        assert!(mw < mh, "walsh {mw} should be < hadamard {mh}");
    }

    #[test]
    fn gsr_has_lowest_or_near_lowest_variance() {
        let reports = sequency_variance_report(256, 64, 64, 2, 3);
        let gsr = reports.iter().find(|r| r.kind == R1Kind::GSR).unwrap();
        let gh = reports.iter().find(|r| r.kind == R1Kind::GH).unwrap();
        assert!(gsr.mean_group_variance < gh.mean_group_variance);
    }

    #[test]
    fn structured_weight_has_outliers() {
        let w = structured_weight(128, 32, 5);
        let mean_abs: f64 =
            w.data.iter().map(|v| v.abs()).sum::<f64>() / w.data.len() as f64;
        let max_abs = w.data.iter().fold(0f64, |m, v| m.max(v.abs()));
        assert!(max_abs > 4.0 * mean_abs, "needs outlier structure");
    }

    #[test]
    fn report_covers_all_kinds() {
        let reports = sequency_variance_report(128, 32, 16, 2, 9);
        assert_eq!(reports.len(), 4);
    }

    #[test]
    fn weighted_mse_reduces_to_unweighted_on_uniform_weights() {
        let w = structured_weight(64, 16, 11);
        let plain = group_rtn_mse(&w, 16, 2);
        let uniform = group_rtn_mse_weighted(&w, 16, 2, &[3.5; 64]);
        assert!((plain - uniform).abs() < 1e-12, "{plain} vs {uniform}");
        // Degenerate all-zero weights fall back instead of dividing by 0.
        let zero = group_rtn_mse_weighted(&w, 16, 2, &[0.0; 64]);
        assert!((plain - zero).abs() < 1e-12);
    }

    #[test]
    fn weighted_mse_tracks_where_the_energy_is() {
        // Put all calibration energy on the rows where quantization is
        // accurate vs where it is bad: the weighted numbers must differ
        // and order accordingly.
        let w = structured_weight(64, 16, 13);
        let q = rtn_quantize(&w, 2, 16, true);
        let deq = q.dequant();
        let row_sse: Vec<f64> = (0..64)
            .map(|r| {
                deq.row(r)
                    .iter()
                    .zip(w.row(r))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum()
            })
            .collect();
        let mut order: Vec<usize> = (0..64).collect();
        order.sort_by(|&a, &b| row_sse[a].total_cmp(&row_sse[b]));
        let mut on_best = vec![0.0; 64];
        let mut on_worst = vec![0.0; 64];
        for &r in &order[..8] {
            on_best[r] = 1.0;
        }
        for &r in &order[56..] {
            on_worst[r] = 1.0;
        }
        let best = group_rtn_mse_weighted(&w, 16, 2, &on_best);
        let worst = group_rtn_mse_weighted(&w, 16, 2, &on_worst);
        assert!(
            best < worst,
            "weighting must follow activation energy: {best} !< {worst}"
        );
    }

    #[test]
    fn non_divisible_group_is_an_error_not_a_panic() {
        let mut rng = SplitMix64::new(2);
        let r = build_r1(R1Kind::GW, 64, 16, &mut rng);
        let err = column_group_sequency_variance(&r, 24).unwrap_err();
        assert!(err.contains("24"), "{err}");
        assert!(column_group_sequency_variance(&r, 0).is_err());
        assert!(column_group_sequency_variance(&r, 16).is_ok());
    }
}
