//! Fig. 2 quantification: outlier-energy spread, global vs local rotation.
//!
//! The paper's Fig. 2 is schematic: a global rotation "spreads outlier
//! effects widely", a local (block-diagonal) rotation "confines outlier
//! effects within each block". We make that measurable: inject a unit
//! outlier at channel `c`, rotate, and report (a) the *participation
//! ratio* of the resulting energy distribution (≈ number of channels the
//! energy spread across) and (b) the fraction of energy that stayed
//! inside the source channel's quantization group.

use crate::rng::SplitMix64;
use crate::transform::{build_r1, Mat, R1Kind};

/// Spread metrics for one rotation kind.
#[derive(Debug, Clone)]
pub struct OutlierSpread {
    pub kind: R1Kind,
    /// Participation ratio (Σe)²/Σe² of per-channel energy, averaged
    /// over source channels. 1 = untouched; n = spread over everything.
    pub participation_ratio: f64,
    /// Mean fraction of outlier energy remaining inside the source
    /// channel's own group after rotation (1.0 for block-diagonal).
    pub in_group_energy: f64,
}

/// Measure spread for one rotation matrix.
pub fn spread_of(r: &Mat, group: usize) -> (f64, f64) {
    let n = r.rows;
    let mut pr_sum = 0.0;
    let mut ig_sum = 0.0;
    for src in 0..n {
        // Outlier e_src rotated: energy lands on row `src` of R (x→xR).
        let energies: Vec<f64> = (0..n).map(|j| r[(src, j)] * r[(src, j)]).collect();
        let sum: f64 = energies.iter().sum();
        let sum_sq: f64 = energies.iter().map(|e| e * e).sum();
        pr_sum += sum * sum / sum_sq.max(1e-300);
        let g = src / group;
        let in_group: f64 = energies[g * group..(g + 1) * group].iter().sum();
        ig_sum += in_group / sum.max(1e-300);
    }
    (pr_sum / n as f64, ig_sum / n as f64)
}

/// Fig.-2 sweep over all four R1 kinds.
pub fn outlier_spread(n: usize, group: usize, seed: u64) -> Vec<OutlierSpread> {
    R1Kind::ALL
        .iter()
        .map(|&kind| {
            let mut rng = SplitMix64::new(seed);
            let r = build_r1(kind, n, group, &mut rng);
            let (pr, ig) = spread_of(&r, group);
            OutlierSpread { kind, participation_ratio: pr, in_group_energy: ig }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_spreads_everywhere_local_confines() {
        let spreads = outlier_spread(256, 64, 11);
        let get = |k: R1Kind| spreads.iter().find(|s| s.kind == k).unwrap();
        // Hadamard-family rows are flat ±1/√n → PR = block size exactly.
        assert!((get(R1Kind::GH).participation_ratio - 256.0).abs() < 1e-6);
        assert!((get(R1Kind::GSR).participation_ratio - 64.0).abs() < 1e-6);
        // Local rotations keep all energy in-group; global spread leaks
        // all but 1/N of it.
        assert!((get(R1Kind::GSR).in_group_energy - 1.0).abs() < 1e-9);
        assert!((get(R1Kind::LH).in_group_energy - 1.0).abs() < 1e-9);
        assert!(get(R1Kind::GH).in_group_energy < 0.3);
        assert!(get(R1Kind::GW).in_group_energy < 0.3);
    }

    #[test]
    fn identity_has_pr_one() {
        let (pr, ig) = spread_of(&Mat::identity(64), 16);
        assert!((pr - 1.0).abs() < 1e-12);
        assert!((ig - 1.0).abs() < 1e-12);
    }
}
