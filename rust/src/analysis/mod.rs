//! Analyses backing the paper's arguments: sequency variance (§3.2) and
//! outlier-energy spread under global vs local rotation (Fig. 2).

pub mod outliers;
pub mod sequency;

pub use outliers::{outlier_spread, OutlierSpread};
pub use sequency::{
    column_group_sequency_variance, group_quant_error_by_rotation, group_rtn_mse,
    group_rtn_mse_weighted, rotated_group_rtn_mse, sequency_variance_report, SequencyReport,
};
