//! Analyses backing the paper's arguments: sequency variance (§3.2) and
//! outlier-energy spread under global vs local rotation (Fig. 2).

pub mod outliers;
pub mod sequency;

pub use outliers::{outlier_spread, OutlierSpread};
pub use sequency::{group_quant_error_by_rotation, sequency_variance_report, SequencyReport};
