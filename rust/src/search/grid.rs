//! Candidate grid for the rotation search.

use crate::model::config::{ModelCfg, R4Kind};
use crate::quant::RotationSpec;
use crate::transform::R1Kind;

/// Grid axes (CLI-tunable via `gsr search --r1/--blocks/--r4`).
#[derive(Debug, Clone)]
pub struct GridCfg {
    pub r1_kinds: Vec<R1Kind>,
    /// Local-rotation block sizes to probe. Entries that do not fit the
    /// model geometry are dropped (never a panic — see
    /// `transform::try_build_r1`).
    pub blocks: Vec<usize>,
    pub r4_kinds: Vec<R4Kind>,
}

impl Default for GridCfg {
    fn default() -> Self {
        Self {
            r1_kinds: R1Kind::ALL.to_vec(),
            blocks: vec![32, 64, 128, 256],
            r4_kinds: vec![R4Kind::GH, R4Kind::LH],
        }
    }
}

/// Enumerate candidate specs: `R1Kind × block × R4Kind`, canonicalized
/// and deduplicated (global R1 kinds collapse the block axis),
/// geometry-invalid candidates dropped, and the fixed-GSR baseline
/// forced to slot 0 so a searched plan can never lose to it.
pub fn candidate_grid(cfg: &ModelCfg, grid: &GridCfg) -> Vec<RotationSpec> {
    let mut out = vec![RotationSpec::baseline(cfg).canonical(cfg)];
    for &r1 in &grid.r1_kinds {
        for &block in &grid.blocks {
            for &r4 in &grid.r4_kinds {
                let r4_block = match r4 {
                    R4Kind::GH => cfg.d_ffn,
                    R4Kind::LH => cfg.group,
                };
                let spec = RotationSpec { r1, r1_block: block, r4, r4_block }.canonical(cfg);
                if spec.validate(cfg).is_err() || out.contains(&spec) {
                    continue;
                }
                out.push(spec);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelCfg {
        ModelCfg::default() // d_model 256, d_ffn 512, group 64
    }

    #[test]
    fn baseline_is_first_and_unique() {
        let grid = candidate_grid(&cfg(), &GridCfg::default());
        let baseline = RotationSpec::baseline(&cfg());
        assert_eq!(grid[0], baseline);
        assert_eq!(grid.iter().filter(|&&s| s == baseline).count(), 1);
    }

    #[test]
    fn global_kinds_collapse_block_axis() {
        let grid = candidate_grid(&cfg(), &GridCfg::default());
        let gh: Vec<_> = grid.iter().filter(|s| s.r1 == R1Kind::GH).collect();
        // 4 block values collapse to one GH spec per R4 kind.
        assert_eq!(gh.len(), 2);
        assert!(gh.iter().all(|s| s.r1_block == cfg().d_model));
    }

    #[test]
    fn invalid_blocks_are_dropped_not_fatal() {
        let g = GridCfg { blocks: vec![24, 7, 512], ..GridCfg::default() };
        let grid = candidate_grid(&cfg(), &g);
        // No local spec survives (24/7 non-pow2 or non-divisor, 512 >
        // d_model), but globals and the baseline do.
        assert!(grid
            .iter()
            .skip(1)
            .all(|s| !s.r1.is_local() || s.r1_block <= cfg().d_model));
        assert!(grid.iter().any(|s| s.r1 == R1Kind::GW));
        let locals: Vec<_> =
            grid.iter().skip(1).filter(|s| s.r1.is_local()).collect();
        assert!(locals.is_empty(), "invalid blocks must be filtered: {locals:?}");
    }

    #[test]
    fn no_duplicate_specs() {
        let grid = candidate_grid(&cfg(), &GridCfg::default());
        for (i, a) in grid.iter().enumerate() {
            for b in &grid[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }
}
