//! Candidate grid for the rotation search.

use std::collections::BTreeSet;

use crate::model::config::{ModelCfg, R4Kind};
use crate::quant::RotationSpec;
use crate::transform::{default_angles, R1Kind};

/// Grid axes (CLI-tunable via `gsr search --r1/--blocks/--r4`).
#[derive(Debug, Clone)]
pub struct GridCfg {
    pub r1_kinds: Vec<R1Kind>,
    /// Local-rotation block sizes to probe. Entries that do not fit the
    /// model geometry are dropped (never a panic — see
    /// `transform::try_build_r1`).
    pub blocks: Vec<usize>,
    pub r4_kinds: Vec<R4Kind>,
}

impl Default for GridCfg {
    fn default() -> Self {
        Self {
            // The paper's four kinds plus the parametric GIV/BFLY
            // families — the full searchable space.
            r1_kinds: R1Kind::EXTENDED.to_vec(),
            blocks: vec![32, 64, 128, 256],
            r4_kinds: vec![R4Kind::GH, R4Kind::LH],
        }
    }
}

/// Enumerate candidate specs: `R1Kind × block × R4Kind`, canonicalized
/// and deduplicated (global R1 kinds collapse the block axis),
/// geometry-invalid candidates dropped, and the fixed-GSR baseline
/// forced to slot 0 so a searched plan can never lose to it.
/// Parametric kinds (GIV/BFLY) enter the grid at their default angle
/// initialization; the scorer's coordinate descent refines the angles
/// per layer. Dedup is a set keyed on the canonical spec (the grid
/// grows superlinearly with the new axes; the old `Vec::contains` scan
/// was O(n²)).
pub fn candidate_grid(cfg: &ModelCfg, grid: &GridCfg) -> Vec<RotationSpec> {
    let baseline = RotationSpec::baseline(cfg).canonical(cfg);
    let mut seen: BTreeSet<RotationSpec> = BTreeSet::new();
    seen.insert(baseline);
    let mut out = vec![baseline];
    for &r1 in &grid.r1_kinds {
        for &block in &grid.blocks {
            for &r4 in &grid.r4_kinds {
                let r4_block = match r4 {
                    R4Kind::GH => cfg.d_ffn,
                    R4Kind::LH => cfg.group,
                };
                let spec = RotationSpec {
                    r1,
                    r1_block: block,
                    r4,
                    r4_block,
                    r1_angles: default_angles(r1, block),
                }
                .canonical(cfg);
                if spec.validate(cfg).is_err() || !seen.insert(spec) {
                    continue;
                }
                out.push(spec);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelCfg {
        ModelCfg::default() // d_model 256, d_ffn 512, group 64
    }

    #[test]
    fn baseline_is_first_and_unique() {
        let grid = candidate_grid(&cfg(), &GridCfg::default());
        let baseline = RotationSpec::baseline(&cfg());
        assert_eq!(grid[0], baseline);
        assert_eq!(grid.iter().filter(|&&s| s == baseline).count(), 1);
    }

    #[test]
    fn global_kinds_collapse_block_axis() {
        let grid = candidate_grid(&cfg(), &GridCfg::default());
        let gh: Vec<_> = grid.iter().filter(|s| s.r1 == R1Kind::GH).collect();
        // 4 block values collapse to one GH spec per R4 kind.
        assert_eq!(gh.len(), 2);
        assert!(gh.iter().all(|s| s.r1_block == cfg().d_model));
    }

    #[test]
    fn invalid_blocks_are_dropped_not_fatal() {
        let g = GridCfg { blocks: vec![24, 7, 512], ..GridCfg::default() };
        let grid = candidate_grid(&cfg(), &g);
        // No local spec survives (24/7 non-pow2 or non-divisor, 512 >
        // d_model), but globals and the baseline do.
        assert!(grid
            .iter()
            .skip(1)
            .all(|s| !s.r1.is_local() || s.r1_block <= cfg().d_model));
        assert!(grid.iter().any(|s| s.r1 == R1Kind::GW));
        let locals: Vec<_> =
            grid.iter().skip(1).filter(|s| s.r1.is_local()).collect();
        assert!(locals.is_empty(), "invalid blocks must be filtered: {locals:?}");
    }

    #[test]
    fn no_duplicate_specs() {
        let grid = candidate_grid(&cfg(), &GridCfg::default());
        for (i, a) in grid.iter().enumerate() {
            for b in &grid[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn parametric_kinds_enter_with_default_angles() {
        let grid = candidate_grid(&cfg(), &GridCfg::default());
        let parametric: Vec<_> = grid.iter().filter(|s| s.r1.is_parametric()).collect();
        // Every (kind, block, R4) combination survives: 2 kinds × 4
        // blocks × 2 R4 kinds.
        assert_eq!(parametric.len(), 16, "{parametric:?}");
        for s in &parametric {
            assert_eq!(
                s.r1_angles,
                default_angles(s.r1, s.r1_block),
                "{}: grid must seed default angles",
                s.label()
            );
            assert_ne!(s.r1_angles, 0, "default init must carry live stages");
        }
        // Non-parametric specs never carry angle bits.
        assert!(grid
            .iter()
            .filter(|s| !s.r1.is_parametric())
            .all(|s| s.r1_angles == 0));
    }

    /// The set-backed dedup must behave exactly like the old linear
    /// scan: first occurrence wins, later duplicates are dropped.
    #[test]
    fn duplicate_axes_collapse_once() {
        let g = GridCfg {
            r1_kinds: vec![R1Kind::GSR, R1Kind::GSR, R1Kind::GH, R1Kind::GH],
            blocks: vec![64, 64, 128],
            r4_kinds: vec![R4Kind::GH, R4Kind::GH],
        };
        let grid = candidate_grid(&cfg(), &g);
        // baseline (GSR/64+GH) + GSR/128 + GH — duplicates all collapse.
        assert_eq!(grid.len(), 3, "{grid:?}");
    }
}
