//! Parallel greedy planner.
//!
//! Per layer, the candidate grid is embarrassingly parallel: each
//! `(layer, candidate)` cell builds its rotations, fuses them into the
//! layer's weights and measures group-RTN error independently. The
//! planner flattens the cells into one work list, fans it out over
//! `std::thread::scope` workers, then reduces each layer to its argmin.
//! The fixed-GSR baseline occupies grid slot 0, so the searched plan is
//! ≤ the baseline on **every** layer by construction.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use std::collections::BTreeMap;

use super::grid::{candidate_grid, GridCfg};
use super::objective::{
    rotated_diag, rotated_full, score_r1_group, CalibWeights, CandidateScore, LayerCalib,
    LayerWeights, Objective, ProxyKind,
};
use crate::model::config::{ModelCfg, R4Kind};
use crate::model::weights::FpParams;
use crate::quant::pipeline::{build_r4, r4_seed};
use crate::quant::{RotationPlan, RotationSpec};
use crate::rng::SplitMix64;
use crate::transform::{Mat, R1Kind};

/// Search configuration (`gsr search` flags map 1:1 onto this).
#[derive(Debug, Clone)]
pub struct SearchCfg {
    pub grid: GridCfg,
    /// Weight bits of the proxy quantizer.
    pub bits: u32,
    /// Max candidates evaluated per layer (0 = whole grid). The
    /// baseline always stays inside the budget.
    pub budget: usize,
    /// Worker threads (0 = available parallelism).
    pub threads: usize,
    /// Seed for the spec-keyed rotation builds, recorded in the plan.
    pub seed: u64,
    /// Hessian proxy (`--proxy diag|full`; full requires calibration).
    pub proxy: ProxyKind,
}

impl Default for SearchCfg {
    fn default() -> Self {
        Self {
            grid: GridCfg::default(),
            bits: 2,
            budget: 0,
            threads: 0,
            seed: 2025,
            proxy: ProxyKind::Diag,
        }
    }
}

/// Resolve a `--threads` request: 0 means one worker per available
/// core. One policy, one place — shared with `Args::opt_threads`.
pub use crate::config::cli::resolve_threads;

/// Outcome for one layer.
#[derive(Debug, Clone)]
pub struct LayerSearchResult {
    pub layer: usize,
    pub best: CandidateScore,
    /// The fixed-GSR reference, measured on the same weights.
    pub baseline: CandidateScore,
    /// Candidates successfully scored.
    pub evaluated: usize,
    /// Candidates that failed geometry checks (skipped, not fatal).
    pub skipped: usize,
}

/// Full search outcome: the plan plus per-layer diagnostics.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub plan: RotationPlan,
    pub layers: Vec<LayerSearchResult>,
}

impl SearchOutcome {
    /// Layers where the searched spec is *strictly* better.
    pub fn improved_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.best.quant_mse < l.baseline.quant_mse).count()
    }

    pub fn mean_mse(&self) -> f64 {
        self.layers.iter().map(|l| l.best.quant_mse).sum::<f64>()
            / self.layers.len().max(1) as f64
    }

    pub fn mean_baseline_mse(&self) -> f64 {
        self.layers.iter().map(|l| l.baseline.quant_mse).sum::<f64>()
            / self.layers.len().max(1) as f64
    }
}

/// Search a per-layer rotation plan for `fp`, minimizing measured
/// group-RTN quantization error layer by layer.
pub fn search_plan(
    fp: &FpParams,
    cfg: &ModelCfg,
    scfg: &SearchCfg,
) -> Result<SearchOutcome, String> {
    search_plan_calibrated(fp, cfg, scfg, None)
}

/// [`search_plan`] under the calibration-aware objective: with `calib`,
/// every candidate's group-RTN MSE is weighted by the input-channel
/// activation energy of that candidate's basis (`gsr search --calib`).
/// The fixed-GSR baseline sits in every layer's grid and is scored under
/// the same objective, so the searched plan still cannot lose to it.
pub fn search_plan_calibrated(
    fp: &FpParams,
    cfg: &ModelCfg,
    scfg: &SearchCfg,
    calib: Option<&CalibWeights>,
) -> Result<SearchOutcome, String> {
    if let Some(c) = calib {
        if c.layers.len() != cfg.n_layers {
            return Err(format!(
                "calibration covers {} layers, model has {}",
                c.layers.len(),
                cfg.n_layers
            ));
        }
        if c.checkpoint != 0 && c.checkpoint != crate::calib::checkpoint_fingerprint(fp) {
            return Err(
                "calibration was captured on a different checkpoint than the one \
                 being searched — re-run `gsr calibrate` on this checkpoint"
                    .to_string(),
            );
        }
    }
    if scfg.proxy == ProxyKind::Full && calib.is_none() {
        return Err(
            "--proxy full needs a calibration artifact (--calib): the full-Hessian \
             quadratic form has no uncalibrated fallback"
                .to_string(),
        );
    }
    let mut candidates = candidate_grid(cfg, &scfg.grid);
    if candidates.is_empty() {
        return Err("empty candidate grid".to_string());
    }
    if scfg.budget > 0 && candidates.len() > scfg.budget {
        candidates.truncate(scfg.budget); // baseline is slot 0, never cut
    }
    let obj = Objective { bits: scfg.bits, group: cfg.group, seed: scfg.seed, proxy: scfg.proxy };
    let layer_weights: Vec<LayerWeights> =
        fp.layers.iter().map(|l| LayerWeights::from_layer(l, cfg)).collect();
    if layer_weights.is_empty() {
        return Err("model has no layers to search".to_string());
    }

    // Group candidates by canonical (r1, r1_block, r1_angles),
    // preserving grid order (the baseline sits in group 0, slot 0): R4
    // variants inside a group share the dominant R1-side scoring work,
    // including one angle-descent run per parametric group.
    let mut groups: Vec<Vec<RotationSpec>> = Vec::new();
    {
        let mut index: BTreeMap<(R1Kind, usize, u64), usize> = BTreeMap::new();
        for &spec in &candidates {
            let key = spec.canonical(cfg);
            match index.get(&(key.r1, key.r1_block, key.r1_angles)).copied() {
                Some(i) => groups[i].push(spec),
                None => {
                    index.insert((key.r1, key.r1_block, key.r1_angles), groups.len());
                    groups.push(vec![spec]);
                }
            }
        }
    }

    // Calibrated mode: precompute each layer's down-projection weights
    // once per distinct canonical R4 — they are identical for every R1
    // group, and the O(d_ffn³) basis change would otherwise be
    // recomputed per (R1 group × R4 spec). Only the cache matching the
    // active proxy is built: diag weights for Diag, the full rotated
    // `R4ᵀ H R4` matrices for Full.
    let mut r4_keys: Vec<(R4Kind, usize)> = Vec::new();
    for spec in &candidates {
        let k = spec.canonical(cfg);
        if !r4_keys.contains(&(k.r4, k.r4_block)) {
            r4_keys.push((k.r4, k.r4_block));
        }
    }
    // r4_seed keys on the R4 fields alone, so any R1 fields yield the
    // exact matrix the scorer builds.
    let probe_r4 = |r4: R4Kind, r4_block: usize| -> Option<Mat> {
        let probe = RotationSpec {
            r1: R1Kind::GSR,
            r1_block: cfg.group,
            r4,
            r4_block,
            r1_angles: 0,
        };
        let mut rng = SplitMix64::new(r4_seed(&probe, scfg.seed));
        build_r4(cfg, r4, r4_block, &mut rng).ok().map(|(m, _)| m)
    };
    let down_diags: Option<Vec<BTreeMap<(R4Kind, usize), Vec<f64>>>> =
        calib.filter(|_| scfg.proxy == ProxyKind::Diag).map(|c| {
            c.layers
                .iter()
                .map(|bh| {
                    let mut per_layer = BTreeMap::new();
                    for &(r4, r4_block) in &r4_keys {
                        if let Some(m) = probe_r4(r4, r4_block) {
                            per_layer.insert((r4, r4_block), rotated_diag(&bh.down, &m));
                        }
                    }
                    per_layer
                })
                .collect()
        });
    let down_mats: Option<Vec<BTreeMap<(R4Kind, usize), Mat>>> =
        calib.filter(|_| scfg.proxy == ProxyKind::Full).map(|c| {
            c.layers
                .iter()
                .map(|bh| {
                    let mut per_layer = BTreeMap::new();
                    for &(r4, r4_block) in &r4_keys {
                        if let Some(m) = probe_r4(r4, r4_block) {
                            per_layer.insert((r4, r4_block), rotated_full(&bh.down, &m));
                        }
                    }
                    per_layer
                })
                .collect()
        });

    // One (layer, r1-group) cell per work item.
    let work: Vec<(usize, usize)> = (0..layer_weights.len())
        .flat_map(|l| (0..groups.len()).map(move |g| (l, g)))
        .collect();
    let cursor = AtomicUsize::new(0);
    let cells: Mutex<Vec<Option<Vec<Result<CandidateScore, String>>>>> =
        Mutex::new(vec![None; work.len()]);
    let n_threads = resolve_threads(scfg.threads).min(work.len());
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                let (l, g) = work[i];
                let lcal = calib.map(|c| LayerCalib {
                    base: &c.layers[l],
                    down_diags: down_diags.as_ref().map(|d| &d[l]),
                    down_mats: down_mats.as_ref().map(|d| &d[l]),
                });
                let scores = score_r1_group(&groups[g], &layer_weights[l], cfg, &obj, lcal);
                cells.lock().unwrap()[i] = Some(scores);
            });
        }
    });
    // A worker panic propagates out of thread::scope before this line,
    // so poisoning cannot actually be observed here.
    let cells = cells.into_inner().unwrap_or_else(|p| p.into_inner());

    // Reduce: per-layer argmin; the baseline (grid slot 0) seeds `best`,
    // so on exact ties the plan keeps the paper-default spec.
    let baseline_key = candidates[0].canonical(cfg);
    let n_groups = groups.len();
    let mut layers = Vec::with_capacity(layer_weights.len());
    let mut specs = Vec::with_capacity(layer_weights.len());
    for l in 0..layer_weights.len() {
        let mut flat: Vec<CandidateScore> = Vec::with_capacity(candidates.len());
        let (mut evaluated, mut skipped) = (0usize, 0usize);
        for g in 0..n_groups {
            match &cells[l * n_groups + g] {
                None => skipped += groups[g].len(),
                Some(scores) => {
                    for sc in scores {
                        match sc {
                            Ok(s) => {
                                evaluated += 1;
                                flat.push(*s);
                            }
                            Err(_) => skipped += 1,
                        }
                    }
                }
            }
        }
        let baseline = flat
            .iter()
            .find(|s| s.spec == baseline_key)
            .copied()
            .ok_or_else(|| format!("baseline not scored on layer {l}"))?;
        let mut best = baseline;
        for s in &flat {
            if s.quant_mse < best.quant_mse {
                best = *s;
            }
        }
        specs.push(best.spec);
        layers.push(LayerSearchResult { layer: l, best, baseline, evaluated, skipped });
    }
    Ok(SearchOutcome { plan: RotationPlan { seed: scfg.seed, layers: specs }, layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::R4Kind;
    use crate::quant::build_plan_rotations;
    use crate::transform::R1Kind;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 3,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn tiny_grid() -> GridCfg {
        GridCfg {
            r1_kinds: R1Kind::ALL.to_vec(),
            blocks: vec![4, 8, 16, 32],
            r4_kinds: vec![R4Kind::GH, R4Kind::LH],
        }
    }

    /// The acceptance property: per-layer MSE ≤ the fixed-GSR baseline
    /// everywhere, and the emitted plan is valid/buildable.
    #[test]
    fn searched_plan_never_loses_to_baseline() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 11);
        let scfg = SearchCfg { grid: tiny_grid(), threads: 2, ..SearchCfg::default() };
        let out = search_plan(&fp, &cfg, &scfg).unwrap();
        assert_eq!(out.plan.layers.len(), cfg.n_layers);
        for l in &out.layers {
            assert!(
                l.best.quant_mse <= l.baseline.quant_mse,
                "layer {}: searched {} > baseline {}",
                l.layer,
                l.best.quant_mse,
                l.baseline.quant_mse
            );
            assert!(l.evaluated > 1, "grid must actually be explored");
        }
        assert!(out.mean_mse() <= out.mean_baseline_mse());
        build_plan_rotations(&cfg, &out.plan).expect("searched plan must build");
    }

    /// With outlier-structured weights the search finds a strict win on
    /// at least one layer (the headline claim of the subsystem). Checked
    /// across a few checkpoints so the property, not one lucky draw, is
    /// what's asserted.
    #[test]
    fn search_strictly_improves_somewhere_on_structured_weights() {
        let cfg = tiny_cfg();
        let scfg = SearchCfg { grid: tiny_grid(), threads: 0, ..SearchCfg::default() };
        let improved = [42u64, 43, 44].iter().any(|&s| {
            let fp = FpParams::synthetic(&cfg, s);
            search_plan(&fp, &cfg, &scfg).unwrap().improved_layers() >= 1
        });
        assert!(improved, "no strict improvement on any of three structured checkpoints");
    }

    /// Budget 1 degenerates to the baseline plan.
    #[test]
    fn budget_one_degenerates_to_baseline() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 7);
        let scfg =
            SearchCfg { grid: tiny_grid(), budget: 1, threads: 1, ..SearchCfg::default() };
        let out = search_plan(&fp, &cfg, &scfg).unwrap();
        let baseline = RotationSpec::baseline(&cfg).canonical(&cfg);
        assert!(out.plan.layers.iter().all(|&s| s == baseline));
        assert_eq!(out.improved_layers(), 0);
    }

    /// Calibrated search keeps the unbeatable-baseline property: the
    /// fixed-GSR spec is scored under the same diag(H)-weighted
    /// objective inside every layer's grid.
    #[test]
    fn calibrated_search_never_loses_to_baseline() {
        use crate::calib::{capture_hessians, checkpoint_fingerprint, CaptureKey};
        use crate::data::{draw_token_windows, CorpusGenerator};
        use crate::quant::fuse_to_dense_plan;

        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 19);
        let scfg = SearchCfg { grid: tiny_grid(), threads: 2, ..SearchCfg::default() };
        let plan =
            RotationPlan::uniform(RotationSpec::baseline(&cfg), cfg.n_layers, scfg.seed);
        let rots = build_plan_rotations(&cfg, &plan).unwrap();
        let dense = fuse_to_dense_plan(&fp, &cfg, &rots);
        let corpus = CorpusGenerator::new(23).generate(2048);
        let seqs = draw_token_windows(&corpus, 6, 12, cfg.vocab, 7);
        let key = CaptureKey {
            calib_seed: 7,
            basis_fingerprint: plan.fingerprint(),
            checkpoint_fingerprint: checkpoint_fingerprint(&fp),
            plan_json: plan.to_json().to_string_pretty(),
        };
        let set = capture_hessians(&cfg, &dense, &seqs, 0, &key);
        let calib = CalibWeights::from_hessian_set(&set, &cfg).unwrap();
        let out = search_plan_calibrated(&fp, &cfg, &scfg, Some(&calib)).unwrap();
        for l in &out.layers {
            assert!(
                l.best.quant_mse <= l.baseline.quant_mse,
                "layer {}: calibrated searched {} > baseline {}",
                l.layer,
                l.best.quant_mse,
                l.baseline.quant_mse
            );
        }
        build_plan_rotations(&cfg, &out.plan).expect("calibrated plan must build");
        // The planner's down-diag cache must not change scores: an
        // uncached rescore of the winning spec is bit-identical.
        let lw0 = LayerWeights::from_layer(&fp.layers[0], &cfg);
        let obj =
            Objective { bits: scfg.bits, group: cfg.group, seed: scfg.seed, proxy: scfg.proxy };
        let rescore = crate::search::objective::score_candidate(
            &out.layers[0].best.spec,
            &lw0,
            &cfg,
            &obj,
            Some(LayerCalib::uncached(&calib.layers[0])),
        )
        .unwrap();
        assert_eq!(
            rescore.quant_mse.to_bits(),
            out.layers[0].best.quant_mse.to_bits(),
            "cached and uncached calibrated scores must agree exactly"
        );
        // The weighting must be able to change the searched outcome or
        // at least the measured numbers.
        let plain = search_plan(&fp, &cfg, &scfg).unwrap();
        let differs = out
            .layers
            .iter()
            .zip(&plain.layers)
            .any(|(a, b)| a.best.quant_mse.to_bits() != b.best.quant_mse.to_bits());
        assert!(differs, "calibrated objective scored identically to the plain one");
    }

    /// Thread count must not change the outcome (determinism).
    #[test]
    fn thread_count_does_not_change_result() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 13);
        let mk = |threads| {
            let scfg = SearchCfg { grid: tiny_grid(), threads, ..SearchCfg::default() };
            search_plan(&fp, &cfg, &scfg).unwrap().plan
        };
        assert_eq!(mk(1), mk(4));
    }

    fn extended_grid() -> GridCfg {
        GridCfg {
            r1_kinds: vec![R1Kind::GSR, R1Kind::GIV, R1Kind::BFLY],
            blocks: vec![8, 16],
            r4_kinds: vec![R4Kind::GH],
        }
    }

    fn captured(cfg: &ModelCfg, fp: &FpParams, seed: u64) -> CalibWeights {
        use crate::calib::{capture_hessians, checkpoint_fingerprint, CaptureKey};
        use crate::data::{draw_token_windows, CorpusGenerator};
        use crate::quant::fuse_to_dense_plan;

        let plan = RotationPlan::uniform(RotationSpec::baseline(cfg), cfg.n_layers, seed);
        let rots = build_plan_rotations(cfg, &plan).unwrap();
        let dense = fuse_to_dense_plan(fp, cfg, &rots);
        let corpus = CorpusGenerator::new(23).generate(2048);
        let seqs = draw_token_windows(&corpus, 6, 12, cfg.vocab, 7);
        let key = CaptureKey {
            calib_seed: 7,
            basis_fingerprint: plan.fingerprint(),
            checkpoint_fingerprint: checkpoint_fingerprint(fp),
            plan_json: plan.to_json().to_string_pretty(),
        };
        let set = capture_hessians(cfg, &dense, &seqs, 0, &key);
        CalibWeights::from_hessian_set(&set, cfg).unwrap()
    }

    /// `--proxy full` without calibration is refused up front.
    #[test]
    fn full_proxy_without_calib_is_an_error() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 11);
        let scfg =
            SearchCfg { grid: tiny_grid(), proxy: ProxyKind::Full, ..SearchCfg::default() };
        let err = search_plan(&fp, &cfg, &scfg).unwrap_err();
        assert!(err.contains("--calib"), "{err}");
    }

    /// The acceptance property under the full-Hessian proxy and the
    /// expanded (GIV/BFLY) grid: the searched plan's proxy objective is
    /// ≤ the fixed-GSR baseline on every layer, the plan builds, and
    /// the full-proxy down-matrix cache never changes a score (uncached
    /// rescore of the winner is bit-identical).
    #[test]
    fn full_proxy_expanded_grid_never_loses_to_baseline() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 19);
        let scfg = SearchCfg {
            grid: extended_grid(),
            threads: 2,
            proxy: ProxyKind::Full,
            ..SearchCfg::default()
        };
        let calib = captured(&cfg, &fp, scfg.seed);
        let out = search_plan_calibrated(&fp, &cfg, &scfg, Some(&calib)).unwrap();
        for l in &out.layers {
            assert!(
                l.best.quant_mse <= l.baseline.quant_mse,
                "layer {}: full-proxy searched {} > baseline {}",
                l.layer,
                l.best.quant_mse,
                l.baseline.quant_mse
            );
        }
        build_plan_rotations(&cfg, &out.plan).expect("full-proxy plan must build");
        let lw0 = LayerWeights::from_layer(&fp.layers[0], &cfg);
        let obj =
            Objective { bits: scfg.bits, group: cfg.group, seed: scfg.seed, proxy: scfg.proxy };
        let rescore = crate::search::objective::score_candidate(
            &out.layers[0].best.spec,
            &lw0,
            &cfg,
            &obj,
            Some(LayerCalib::uncached(&calib.layers[0])),
        )
        .unwrap();
        assert_eq!(
            rescore.quant_mse.to_bits(),
            out.layers[0].best.quant_mse.to_bits(),
            "cached and uncached full-proxy scores must agree exactly"
        );
    }

    /// Diag proxy over the expanded grid: parametric candidates descend
    /// their angles, the baseline stays unbeatable, and the whole run is
    /// deterministic — same seed/corpus/config twice (and across thread
    /// counts) yields the identical plan and fingerprint.
    #[test]
    fn expanded_grid_descent_is_deterministic_and_never_loses() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 11);
        let mk = |threads| {
            let scfg = SearchCfg {
                grid: extended_grid(),
                threads,
                ..SearchCfg::default()
            };
            search_plan(&fp, &cfg, &scfg).unwrap()
        };
        let a = mk(1);
        let b = mk(3);
        assert_eq!(a.plan, b.plan, "thread count changed the descended plan");
        assert_eq!(a.plan.fingerprint(), mk(1).plan.fingerprint(), "rerun changed the plan");
        for l in &a.layers {
            assert!(l.best.quant_mse <= l.baseline.quant_mse, "layer {}", l.layer);
        }
        // Any parametric winner must carry canonical (masked) angles.
        for s in &a.plan.layers {
            if s.r1.is_parametric() {
                assert_eq!(
                    s.r1_angles,
                    crate::transform::mask_angles(s.r1, s.r1_block, s.r1_angles),
                    "winner carries dead angle bytes"
                );
            }
        }
        build_plan_rotations(&cfg, &a.plan).expect("descended plan must build");
    }
}
