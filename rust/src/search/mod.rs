//! `gsr search` — training-free per-layer rotation auto-configuration.
//!
//! The paper's core claim is that rotation quality is configurable "for
//! free": GSR's block-diagonal Walsh blocks trade outlier isolation
//! against mixing, and the best block size is not one-size-fits-all.
//! Related work buys per-layer adaptivity with *training* (SpinQuant's
//! learned rotations, DartQuant's rotational distribution calibration);
//! this subsystem recovers most of that win training-free by searching
//! over `R1Kind × block size × R4Kind` per layer, scoring candidates by
//! the **measured** group-RTN quantization error on that layer's actual
//! (γ-fused) weights — the same proxy `analysis::sequency` uses for the
//! §3.2 argument.
//!
//! Pipeline:
//!
//! 1. [`grid`] enumerates the candidate [`RotationSpec`]s (invalid
//!    geometry dropped early, fixed-GSR baseline always kept).
//! 2. [`objective`] scores one candidate on one layer's weights.
//! 3. [`planner`] fans the layer × candidate cells out over a scoped
//!    thread pool and keeps the per-layer argmin, which can never lose
//!    to the baseline because the baseline is in every layer's grid.
//!
//! The result is a [`RotationPlan`] that round-trips through JSON
//! (`rotation_plan.json`) into `gsr quantize-native --plan` and the
//! heterogeneous fusion path in `quant::pipeline`.
//!
//! With `gsr search --calib` the objective runs in **calibration-aware**
//! mode: a `calib::HessianSet` is un-rotated into the base basis
//! ([`CalibWeights`]) and every candidate's error is weighted by the
//! input-channel activation energy of *that candidate's* basis, so the
//! search minimizes a diagonal proxy of the `‖X ΔW‖²` objective the
//! Hessian-calibrated GPTQ pipeline actually optimizes.
//!
//! `gsr search --proxy full` upgrades that to the **full-Hessian**
//! quadratic form `tr(ΔWᵀ·RᵀHR·ΔW)` ([`ProxyKind::Full`]): the rotated
//! Hessian `RᵀHR` is hoisted once per distinct rotation (mirroring the
//! diagonal cache) so the O(d³) work is paid per candidate, not per
//! layer×candidate cell. The full proxy has no uncalibrated fallback —
//! it is an error without `--calib`.
//!
//! Parametric candidates (`GIV` Givens chains, `BFLY` butterfly
//! factorizations) carry per-stage angle codes in the spec itself;
//! the objective refines them by training-free coordinate descent
//! before scoring, so angle optimization is also a pure function of
//! `(checkpoint, cfg, spec, seed)`.
//!
//! Determinism: every candidate score is a pure function of
//! `(checkpoint, cfg, spec, seed)` — rotation builds are seeded by the
//! spec itself and scores are reduced per layer in grid order, so the
//! emitted plan is identical for any `--threads` value and any
//! scheduling of the layer × candidate cells.

pub mod grid;
pub mod objective;
pub mod planner;

pub use grid::{candidate_grid, GridCfg};
pub use objective::{
    hessian_rtn_mse, rotated_diag, rotated_full, score_candidate, score_r1_group, BaseHessians,
    CalibWeights, CandidateScore, LayerCalib, LayerWeights, Objective, ProxyKind,
};
pub use planner::{
    search_plan, search_plan_calibrated, LayerSearchResult, SearchCfg, SearchOutcome,
};

pub use crate::quant::{RotationPlan, RotationSpec};
