//! Proxy objectives: measured group-RTN error on a layer's actual fused
//! weights, plus the §3.2 sequency-variance diagnostic.
//!
//! The quantity minimized is exactly what the quantizer will see. For
//! each candidate `(R1, block, R4)` we build the real rotation matrices
//! (same spec-keyed seed stream as `quant::pipeline`), fuse them into
//! the layer's weights the way `fuse_rotations_plan` does —
//! `R1ᵀ diag(γ) W` for the stream-consuming linears, `R4ᵀ W_down R1`
//! for the down projection — and take the element-weighted mean
//! group-RTN MSE (`analysis::sequency::group_rtn_mse`). `wo` is skipped:
//! its input channels see B2 (shared across candidates), so it cannot
//! discriminate between them.

use crate::analysis::sequency::{column_group_sequency_variance, group_rtn_mse};
use crate::model::config::ModelCfg;
use crate::model::weights::FpLayer;
use crate::quant::pipeline::{build_r4, r1_seed, r4_seed};
use crate::quant::RotationSpec;
use crate::rng::SplitMix64;
use crate::transform::{try_build_r1, Mat};

/// Quantization geometry the objective measures against.
#[derive(Debug, Clone, Copy)]
pub struct Objective {
    pub bits: u32,
    /// Quantization group size (independent of the rotation block —
    /// decoupling the two is the point of the search).
    pub group: usize,
    /// Seed for spec-keyed rotation builds (must match the plan seed so
    /// the scored matrices are the ones the pipeline will build).
    pub seed: u64,
}

/// One layer's weights in objective form.
pub struct LayerWeights {
    /// `diag(γ) W` for wq/wk/wv (ln1) and wgate/wup (ln2), horizontally
    /// concatenated into `[d_model, 3d + 2f]`; quantization groups run
    /// along the shared input-channel axis, exactly as in the fused
    /// pipeline.
    pub stream: Mat,
    /// `W_down` as `[d_ffn, d_model]`.
    pub wdown: Mat,
}

impl LayerWeights {
    pub fn from_layer(layer: &FpLayer, cfg: &ModelCfg) -> Self {
        let d = cfg.d_model;
        let f = cfg.d_ffn;
        let mut stream = Mat::zeros(d, 3 * d + 2 * f);
        let mut col0 = 0;
        let parts: [(&Vec<f32>, usize, &Vec<f32>); 5] = [
            (&layer.wq, d, &layer.ln1),
            (&layer.wk, d, &layer.ln1),
            (&layer.wv, d, &layer.ln1),
            (&layer.wgate, f, &layer.ln2),
            (&layer.wup, f, &layer.ln2),
        ];
        for (w, h, gamma) in parts {
            for r in 0..d {
                let g = gamma[r] as f64;
                for c in 0..h {
                    stream[(r, col0 + c)] = g * w[r * h + c] as f64;
                }
            }
            col0 += h;
        }
        let wdown = Mat {
            data: layer.wdown.iter().map(|&v| v as f64).collect(),
            rows: f,
            cols: d,
        };
        Self { stream, wdown }
    }
}

/// Score of one candidate on one layer.
#[derive(Debug, Clone, Copy)]
pub struct CandidateScore {
    pub spec: RotationSpec,
    /// Element-weighted mean group-RTN MSE over all scored fused weights.
    pub quant_mse: f64,
    /// Mean intra-group column-sequency variance of the candidate R1
    /// (diagnostic; reported, not optimized).
    pub seq_variance: f64,
}

/// Score a group of candidates sharing one canonical `(r1, r1_block)`:
/// the R1-dependent work (rotation build, stream rotation + MSE,
/// sequency variance — the dominant cost) is done **once**; each spec
/// adds only its R4 term. R1 builds are seeded by `r1_seed`, which keys
/// on `(r1, r1_block)` alone, so the shared matrix is exactly the one
/// the pipeline will build for every spec in the group. Geometry errors
/// come back as per-spec `Err` (the planner counts them as skipped).
pub fn score_r1_group(
    specs: &[RotationSpec],
    lw: &LayerWeights,
    cfg: &ModelCfg,
    obj: &Objective,
) -> Vec<Result<CandidateScore, String>> {
    let key0 = match specs.first() {
        Some(s) => s.canonical(cfg),
        None => return Vec::new(),
    };
    let shared = (|| -> Result<(Mat, f64, f64), String> {
        let mut rng = SplitMix64::new(r1_seed(&key0, obj.seed));
        let r1 = try_build_r1(key0.r1, cfg.d_model, key0.r1_block, &mut rng)?;
        let rotated_stream = r1.transpose().matmul(&lw.stream);
        let mse_s = group_rtn_mse(&rotated_stream, obj.group, obj.bits);
        let vars = column_group_sequency_variance(&r1, obj.group)?;
        let seq_variance = vars.iter().sum::<f64>() / vars.len() as f64;
        Ok((r1, mse_s, seq_variance))
    })();
    let (r1, mse_s, seq_variance) = match shared {
        Ok(v) => v,
        Err(e) => return specs.iter().map(|_| Err(e.clone())).collect(),
    };
    specs
        .iter()
        .map(|spec| {
            spec.validate(cfg)?;
            let key = spec.canonical(cfg);
            debug_assert_eq!(
                (key.r1, key.r1_block),
                (key0.r1, key0.r1_block),
                "score_r1_group specs must share one canonical R1"
            );
            let mut rng = SplitMix64::new(r4_seed(&key, obj.seed));
            let (r4, _signs) = build_r4(cfg, key.r4, key.r4_block, &mut rng)?;
            let rotated_down = r4.transpose().matmul(&lw.wdown).matmul(&r1);
            let mse_d = group_rtn_mse(&rotated_down, obj.group, obj.bits);
            let (ns, nd) = (lw.stream.data.len() as f64, lw.wdown.data.len() as f64);
            let quant_mse = (mse_s * ns + mse_d * nd) / (ns + nd);
            Ok(CandidateScore { spec: key, quant_mse, seq_variance })
        })
        .collect()
}

/// Measure one candidate on one layer's actual weights (singleton form
/// of [`score_r1_group`]).
pub fn score_candidate(
    spec: &RotationSpec,
    lw: &LayerWeights,
    cfg: &ModelCfg,
    obj: &Objective,
) -> Result<CandidateScore, String> {
    spec.validate(cfg)?;
    score_r1_group(std::slice::from_ref(spec), lw, cfg, obj)
        .pop()
        .expect("singleton group yields one score")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::R4Kind;
    use crate::model::weights::FpParams;
    use crate::transform::R1Kind;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn stream_concat_carries_gamma() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 3);
        let lw = LayerWeights::from_layer(&fp.layers[0], &cfg);
        let d = cfg.d_model;
        assert_eq!((lw.stream.rows, lw.stream.cols), (d, 3 * d + 2 * cfg.d_ffn));
        // First block is diag(ln1) · wq.
        let g0 = fp.layers[0].ln1[0] as f64;
        let expect = g0 * fp.layers[0].wq[0] as f64;
        assert!((lw.stream[(0, 0)] - expect).abs() < 1e-12);
    }

    #[test]
    fn scoring_is_deterministic_and_finite() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 5);
        let lw = LayerWeights::from_layer(&fp.layers[1], &cfg);
        let obj = Objective { bits: 2, group: cfg.group, seed: 9 };
        let spec = RotationSpec::baseline(&cfg);
        let a = score_candidate(&spec, &lw, &cfg, &obj).unwrap();
        let b = score_candidate(&spec, &lw, &cfg, &obj).unwrap();
        assert_eq!(a.quant_mse.to_bits(), b.quant_mse.to_bits());
        assert!(a.quant_mse.is_finite() && a.quant_mse > 0.0);
        assert!(a.seq_variance.is_finite());
    }

    #[test]
    fn bad_geometry_is_an_error_not_a_panic() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 5);
        let lw = LayerWeights::from_layer(&fp.layers[0], &cfg);
        let obj = Objective { bits: 2, group: cfg.group, seed: 9 };
        let bad = RotationSpec {
            r1: R1Kind::GSR,
            r1_block: 24,
            r4: R4Kind::GH,
            r4_block: cfg.d_ffn,
        };
        assert!(score_candidate(&bad, &lw, &cfg, &obj).is_err());
    }
}
