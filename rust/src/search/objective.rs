//! Proxy objectives: measured group-RTN error on a layer's actual fused
//! weights, plus the §3.2 sequency-variance diagnostic.
//!
//! The quantity minimized is exactly what the quantizer will see. For
//! each candidate `(R1, block, R4)` we build the real rotation matrices
//! (same spec-keyed seed stream as `quant::pipeline`), fuse them into
//! the layer's weights the way `fuse_rotations_plan` does —
//! `R1ᵀ diag(γ) W` for the stream-consuming linears, `R4ᵀ W_down R1`
//! for the down projection — and take the element-weighted mean
//! group-RTN MSE (`analysis::sequency::group_rtn_mse`). `wo` is skipped:
//! its input channels see B2 (shared across candidates), so it cannot
//! discriminate between them.
//!
//! **Calibrated mode** (`gsr search --calib`): a captured
//! [`crate::calib::HessianSet`] is un-rotated into the base basis once
//! ([`CalibWeights`]), and each candidate's group-RTN MSE is weighted by
//! that candidate basis's input-channel energy `diag(R_cᵀ H R_c)` — the
//! diagonal proxy of the `‖X ΔW‖²` objective calibrated GPTQ actually
//! minimizes, so the search optimizes what the quantizer will see.

use crate::analysis::sequency::{
    column_group_sequency_variance, group_rtn_mse, group_rtn_mse_weighted,
};
use crate::calib::HessianSet;
use crate::config::Json;
use crate::model::config::{ModelCfg, R4Kind};
use crate::model::weights::FpLayer;
use crate::quant::pipeline::{build_plan_rotations, build_r4, build_spec_r1, r4_seed};
use crate::quant::{rtn_quantize, RotationPlan, RotationSpec};
use crate::rng::SplitMix64;
use crate::transform::parametric::{stage_code, with_stage_code};
use crate::transform::{
    angle_stages, apply_parametric_t, default_angles, try_build_parametric, Mat,
};

/// Which `‖X ΔW‖²` surrogate ranks the candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProxyKind {
    /// diag(RᵀHR)-weighted group-RTN MSE (cheap, the historical
    /// default; identical to the uncalibrated objective when no
    /// Hessians are supplied).
    #[default]
    Diag,
    /// Full quadratic form `tr(ΔWᵀ · RᵀHR · ΔW)` — keeps the Hessian's
    /// off-diagonal structure, closing the known diag-only proxy gap.
    /// Requires calibration; the O(d³) basis change is hoisted once per
    /// distinct rotation (per R1 group / per cached R4).
    Full,
}

impl ProxyKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ProxyKind::Diag => "diag",
            ProxyKind::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Option<ProxyKind> {
        match s.to_ascii_lowercase().as_str() {
            "diag" => Some(ProxyKind::Diag),
            "full" => Some(ProxyKind::Full),
            _ => None,
        }
    }
}

/// Quantization geometry the objective measures against.
#[derive(Debug, Clone, Copy)]
pub struct Objective {
    pub bits: u32,
    /// Quantization group size (independent of the rotation block —
    /// decoupling the two is the point of the search).
    pub group: usize,
    /// Seed for spec-keyed rotation builds (must match the plan seed so
    /// the scored matrices are the ones the pipeline will build).
    pub seed: u64,
    /// Hessian proxy the candidates are ranked under ([`ProxyKind`]).
    pub proxy: ProxyKind,
}

/// One layer's base-basis (un-rotated) activation Hessians — the
/// calibration signal the diag(H)-weighted proxy consumes. `wo` has no
/// entry because the objective skips it (its basis is candidate-
/// invariant).
#[derive(Debug, Clone)]
pub struct BaseHessians {
    /// Post-ln1 residual-stream Hessian (`wq`/`wk`/`wv` inputs), `[d, d]`.
    pub attn: Mat,
    /// Post-ln2 residual-stream Hessian (`wgate`/`wup` inputs), `[d, d]`.
    pub ffn: Mat,
    /// Pre-R4 FFN activation Hessian (`wdown` input), `[f, f]`.
    pub down: Mat,
}

/// Calibration weights for the whole model, in the base basis so any
/// candidate rotation can be scored: the capture basis satisfies
/// `H_rot = Rᵀ H_base R` (RMSNorm commutes with orthogonal R1, so the
/// rotated stream is exactly the base stream times R), hence
/// `H_base = R H_rot Rᵀ` and a candidate's weights are
/// `diag(R_cᵀ H_base R_c)`.
#[derive(Debug, Clone)]
pub struct CalibWeights {
    /// Activation rows behind the estimate (diagnostic).
    pub tokens: u64,
    /// Checkpoint fingerprint carried over from the artifact (0 =
    /// unknown); the planner verifies it against the searched model.
    pub checkpoint: u64,
    pub layers: Vec<BaseHessians>,
}

impl CalibWeights {
    /// Un-rotate a captured [`HessianSet`] using the capture plan
    /// embedded in the artifact.
    pub fn from_hessian_set(set: &HessianSet, cfg: &ModelCfg) -> Result<Self, String> {
        set.check_model(cfg)?;
        if set.plan_json.is_empty() {
            return Err(
                "Hessian artifact carries no capture plan — it was taken in-process \
                 and cannot be re-based for the search objective"
                    .to_string(),
            );
        }
        let plan = RotationPlan::from_json(&Json::parse(&set.plan_json)?)?;
        set.check_basis(plan.fingerprint())?;
        let rots = build_plan_rotations(cfg, &plan)?;
        let layers = (0..cfg.n_layers)
            .map(|l| {
                let lr = &rots.layers[l];
                let unrot = |h: &Mat, r: &Mat| r.matmul(h).matmul(&r.transpose());
                BaseHessians {
                    attn: unrot(&set.hessian_mat(l, "wq"), lr.r1.as_ref()),
                    ffn: unrot(&set.hessian_mat(l, "wgate"), lr.r1.as_ref()),
                    down: unrot(&set.hessian_mat(l, "wdown"), lr.r4.as_ref()),
                }
            })
            .collect();
        Ok(Self { tokens: set.tokens, checkpoint: set.checkpoint_fingerprint, layers })
    }
}

/// `diag(Rᵀ H R)` without materializing the rotated Hessian: one matmul
/// plus a column-wise contraction.
pub fn rotated_diag(h: &Mat, r: &Mat) -> Vec<f64> {
    debug_assert_eq!((h.rows, h.cols), (r.rows, r.rows));
    let t = h.matmul(r);
    (0..r.cols)
        .map(|j| (0..r.rows).map(|i| r[(i, j)] * t[(i, j)]).sum())
        .collect()
}

/// Per-layer calibration handle for scoring: the base Hessians plus
/// optional caches of down-projection weights per canonical
/// `(r4, r4_block)` — diag weights for [`ProxyKind::Diag`], fully
/// rotated `R4ᵀ H R4` matrices for [`ProxyKind::Full`]. The planner
/// fills the cache matching the active proxy once per layer so the
/// O(d_ffn³) work is done once per distinct R4, not once per
/// (R1 group × R4 spec); a missing entry falls back to the direct
/// computation, bit-identically.
#[derive(Clone, Copy)]
pub struct LayerCalib<'a> {
    pub base: &'a BaseHessians,
    pub down_diags: Option<&'a std::collections::BTreeMap<(R4Kind, usize), Vec<f64>>>,
    pub down_mats: Option<&'a std::collections::BTreeMap<(R4Kind, usize), Mat>>,
}

impl<'a> LayerCalib<'a> {
    /// Uncached handle (used by `score_candidate` one-offs and tests).
    pub fn uncached(base: &'a BaseHessians) -> Self {
        Self { base, down_diags: None, down_mats: None }
    }
}

/// Full-Hessian RTN proxy: `tr(ΔWᵀ H ΔW) / |W|` where `ΔW` is the
/// group-RTN dequantization error of `w` and `h` is the activation
/// Hessian **in the same (rotated) basis as `w`'s rows**. This is the
/// exact quadratic form `‖X ΔW‖²` (per element) that calibrated GPTQ
/// minimizes — off-diagonal Hessian structure included, unlike the
/// diag proxy.
pub fn hessian_rtn_mse(w: &Mat, h: &Mat, group: usize, bits: u32) -> f64 {
    debug_assert_eq!((h.rows, h.cols), (w.rows, w.rows));
    let deq = rtn_quantize(w, bits, group, true).dequant();
    let dw = Mat {
        data: deq.data.iter().zip(&w.data).map(|(a, b)| a - b).collect(),
        rows: w.rows,
        cols: w.cols,
    };
    let hdw = h.matmul(&dw);
    let quad: f64 = dw.data.iter().zip(&hdw.data).map(|(a, b)| a * b).sum();
    quad / w.data.len() as f64
}

/// Dense `Rᵀ H R` — O(d³), hoisted by the callers (once per R1 group in
/// the shared section, once per distinct R4 in the planner cache).
pub fn rotated_full(h: &Mat, r: &Mat) -> Mat {
    r.transpose().matmul(&h.matmul(r))
}

/// One layer's weights in objective form.
pub struct LayerWeights {
    /// `diag(γ) W` for wq/wk/wv (ln1) and wgate/wup (ln2), horizontally
    /// concatenated into `[d_model, 3d + 2f]`; quantization groups run
    /// along the shared input-channel axis, exactly as in the fused
    /// pipeline.
    pub stream: Mat,
    /// Column where the ln2 (wgate/wup) block starts inside `stream`.
    pub ffn_col0: usize,
    /// `W_down` as `[d_ffn, d_model]`.
    pub wdown: Mat,
}

impl LayerWeights {
    pub fn from_layer(layer: &FpLayer, cfg: &ModelCfg) -> Self {
        let d = cfg.d_model;
        let f = cfg.d_ffn;
        let mut stream = Mat::zeros(d, 3 * d + 2 * f);
        let mut col0 = 0;
        let parts: [(&Vec<f32>, usize, &Vec<f32>); 5] = [
            (&layer.wq, d, &layer.ln1),
            (&layer.wk, d, &layer.ln1),
            (&layer.wv, d, &layer.ln1),
            (&layer.wgate, f, &layer.ln2),
            (&layer.wup, f, &layer.ln2),
        ];
        for (w, h, gamma) in parts {
            for r in 0..d {
                let g = gamma[r] as f64;
                for c in 0..h {
                    stream[(r, col0 + c)] = g * w[r * h + c] as f64;
                }
            }
            col0 += h;
        }
        let wdown = Mat {
            data: layer.wdown.iter().map(|&v| v as f64).collect(),
            rows: f,
            cols: d,
        };
        Self { stream, ffn_col0: 3 * d, wdown }
    }
}

/// Copy a contiguous column range out of a matrix.
fn col_slice(m: &Mat, c0: usize, c1: usize) -> Mat {
    Mat::from_fn(m.rows, c1 - c0, |r, c| m[(r, c0 + c)])
}

/// Score of one candidate on one layer.
#[derive(Debug, Clone, Copy)]
pub struct CandidateScore {
    pub spec: RotationSpec,
    /// Element-weighted mean group-RTN MSE over all scored fused
    /// weights; diag(H)-weighted when calibration is active.
    pub quant_mse: f64,
    /// Mean intra-group column-sequency variance of the candidate R1
    /// (diagnostic; reported, not optimized).
    pub seq_variance: f64,
}

/// Score a group of candidates sharing one canonical
/// `(r1, r1_block, r1_angles)`: the R1-dependent work (rotation build,
/// stream rotation + MSE, sequency variance — the dominant cost) is
/// done **once**; each spec adds only its R4 term. R1 matrices come
/// from [`build_spec_r1`] — the exact ones the pipeline will build for
/// every spec in the group. With `calib`, every MSE term is weighted by
/// that candidate basis's input-channel energy (diag proxy) or the full
/// rotated Hessian quadratic form (full proxy). Geometry errors come
/// back as per-spec `Err` (the planner counts them as skipped).
///
/// **Angle coordinate descent**: when the group's R1 kind is parametric
/// (GIV/BFLY) and arrives at its grid-default angle initialization, a
/// deterministic training-free coordinate descent over the per-stage
/// angles runs first, and the whole group is scored — and reported —
/// at the descended angles. Descent is a pure function of
/// `(layer weights, cfg, obj, calib, key)`, so re-scoring any reported
/// spec (its angles are then non-default) reproduces the reported score
/// bit-for-bit without re-entering the descent.
pub fn score_r1_group(
    specs: &[RotationSpec],
    lw: &LayerWeights,
    cfg: &ModelCfg,
    obj: &Objective,
    calib: Option<LayerCalib>,
) -> Vec<Result<CandidateScore, String>> {
    let key0 = match specs.first() {
        Some(s) => s.canonical(cfg),
        None => return Vec::new(),
    };
    if key0.r1.is_parametric()
        && key0.validate(cfg).is_ok()
        && key0.r1_angles == default_angles(key0.r1, key0.r1_block)
    {
        let angles = descend_angles(lw, cfg, obj, calib, &key0);
        let descended: Vec<RotationSpec> = specs
            .iter()
            .map(|s| {
                let mut c = s.canonical(cfg);
                c.r1_angles = angles;
                c
            })
            .collect();
        return score_r1_group_inner(&descended, lw, cfg, obj, calib);
    }
    score_r1_group_inner(specs, lw, cfg, obj, calib)
}

fn score_r1_group_inner(
    specs: &[RotationSpec],
    lw: &LayerWeights,
    cfg: &ModelCfg,
    obj: &Objective,
    calib: Option<LayerCalib>,
) -> Vec<Result<CandidateScore, String>> {
    let key0 = match specs.first() {
        Some(s) => s.canonical(cfg),
        None => return Vec::new(),
    };
    if obj.proxy == ProxyKind::Full && calib.is_none() {
        let e = "full-Hessian proxy requires calibration (--calib)".to_string();
        return specs.iter().map(|_| Err(e.clone())).collect();
    }
    // The full proxy's rotated stream Hessians, hoisted once per group.
    let shared = (|| -> Result<(Mat, f64, f64), String> {
        let r1 = build_spec_r1(cfg, &key0, obj.seed)?;
        let rotated_stream = r1.transpose().matmul(&lw.stream);
        let mse_s = match (obj.proxy, calib) {
            (_, None) => group_rtn_mse(&rotated_stream, obj.group, obj.bits),
            (ProxyKind::Diag, Some(lc)) => {
                // Split the stream at the ln1/ln2 boundary: each half is
                // weighted by its own site's rotated Hessian diagonal,
                // then recombined by element count.
                let wa = rotated_diag(&lc.base.attn, &r1);
                let wf = rotated_diag(&lc.base.ffn, &r1);
                let attn = col_slice(&rotated_stream, 0, lw.ffn_col0);
                let ffn = col_slice(&rotated_stream, lw.ffn_col0, rotated_stream.cols);
                let (na, nf) = (attn.data.len() as f64, ffn.data.len() as f64);
                let mse_a = group_rtn_mse_weighted(&attn, obj.group, obj.bits, &wa);
                let mse_f = group_rtn_mse_weighted(&ffn, obj.group, obj.bits, &wf);
                (mse_a * na + mse_f * nf) / (na + nf)
            }
            (ProxyKind::Full, Some(lc)) => {
                let ha = rotated_full(&lc.base.attn, &r1);
                let hf = rotated_full(&lc.base.ffn, &r1);
                let attn = col_slice(&rotated_stream, 0, lw.ffn_col0);
                let ffn = col_slice(&rotated_stream, lw.ffn_col0, rotated_stream.cols);
                let (na, nf) = (attn.data.len() as f64, ffn.data.len() as f64);
                let mse_a = hessian_rtn_mse(&attn, &ha, obj.group, obj.bits);
                let mse_f = hessian_rtn_mse(&ffn, &hf, obj.group, obj.bits);
                (mse_a * na + mse_f * nf) / (na + nf)
            }
        };
        let vars = column_group_sequency_variance(&r1, obj.group)?;
        let seq_variance = vars.iter().sum::<f64>() / vars.len() as f64;
        Ok((r1, mse_s, seq_variance))
    })();
    let (r1, mse_s, seq_variance) = match shared {
        Ok(v) => v,
        Err(e) => return specs.iter().map(|_| Err(e.clone())).collect(),
    };
    specs
        .iter()
        .map(|spec| {
            spec.validate(cfg)?;
            let key = spec.canonical(cfg);
            debug_assert_eq!(
                (key.r1, key.r1_block, key.r1_angles),
                (key0.r1, key0.r1_block, key0.r1_angles),
                "score_r1_group specs must share one canonical R1"
            );
            let mut rng = SplitMix64::new(r4_seed(&key, obj.seed));
            let (r4, _signs) = build_r4(cfg, key.r4, key.r4_block, &mut rng)?;
            let rotated_down = r4.transpose().matmul(&lw.wdown).matmul(&r1);
            let mse_d = match (obj.proxy, calib) {
                (_, None) => group_rtn_mse(&rotated_down, obj.group, obj.bits),
                (ProxyKind::Diag, Some(lc)) => {
                    let cached =
                        lc.down_diags.and_then(|m| m.get(&(key.r4, key.r4_block)));
                    let computed;
                    let wd: &[f64] = match cached {
                        Some(v) => v,
                        None => {
                            computed = rotated_diag(&lc.base.down, &r4);
                            &computed
                        }
                    };
                    group_rtn_mse_weighted(&rotated_down, obj.group, obj.bits, wd)
                }
                (ProxyKind::Full, Some(lc)) => {
                    let cached = lc.down_mats.and_then(|m| m.get(&(key.r4, key.r4_block)));
                    let computed;
                    let hd: &Mat = match cached {
                        Some(m) => m,
                        None => {
                            computed = rotated_full(&lc.base.down, &r4);
                            &computed
                        }
                    };
                    hessian_rtn_mse(&rotated_down, hd, obj.group, obj.bits)
                }
            };
            let (ns, nd) = (lw.stream.data.len() as f64, lw.wdown.data.len() as f64);
            let quant_mse = (mse_s * ns + mse_d * nd) / (ns + nd);
            Ok(CandidateScore { spec: key, quant_mse, seq_variance })
        })
        .collect()
}

/// Angle codes the coarse pass probes per stage (every 1/8 turn).
const COARSE_CODES: [u8; 8] = [0, 32, 64, 96, 128, 160, 192, 224];
/// Hill-climb step schedule after the coarse pass (code units).
const REFINE_STEPS: [u8; 5] = [16, 8, 4, 2, 1];

/// Training-free coordinate descent over a parametric R1's per-stage
/// angle codes, minimizing a cheap **surrogate** of the group objective:
/// the (diag-weighted when calibrated) group-RTN MSE of the rotated
/// stream. The R4-side term is deliberately excluded — it is shared-R1
/// per group and second-order in the angles — and the surrogate stays
/// diag-weighted even under the full proxy (the full quadratic form
/// still ranks the *final* candidates; the surrogate only steers the
/// angles). Each trial applies the rotation with O(stages · n · cols)
/// stage ops instead of dense matmuls.
///
/// Deterministic by construction: fixed probe order, strict-improvement
/// acceptance, no RNG — same `(lw, cfg, obj, calib, key)` always yields
/// the same angles.
fn descend_angles(
    lw: &LayerWeights,
    cfg: &ModelCfg,
    obj: &Objective,
    calib: Option<LayerCalib>,
    key: &RotationSpec,
) -> u64 {
    let (kind, block) = (key.r1, key.r1_block);
    let eval = |angles: u64| -> f64 {
        let mut rs = lw.stream.clone();
        apply_parametric_t(kind, block, angles, &mut rs);
        match calib {
            None => group_rtn_mse(&rs, obj.group, obj.bits),
            Some(lc) => {
                // diag(RᵀHR) via stage ops: t = RᵀH in O(stages·n²),
                // then diag[j] = Σ_i t[j,i]·R[i,j] against the dense R
                // (itself built with stage ops on the identity).
                let r = try_build_parametric(kind, cfg.d_model, block, angles)
                    .expect("descent key was validated");
                let diag_of = |h: &Mat| -> Vec<f64> {
                    let mut t = h.clone();
                    apply_parametric_t(kind, block, angles, &mut t);
                    (0..r.cols)
                        .map(|j| (0..r.rows).map(|i| t[(j, i)] * r[(i, j)]).sum())
                        .collect()
                };
                let wa = diag_of(&lc.base.attn);
                let wf = diag_of(&lc.base.ffn);
                let attn = col_slice(&rs, 0, lw.ffn_col0);
                let ffn = col_slice(&rs, lw.ffn_col0, rs.cols);
                let (na, nf) = (attn.data.len() as f64, ffn.data.len() as f64);
                let mse_a = group_rtn_mse_weighted(&attn, obj.group, obj.bits, &wa);
                let mse_f = group_rtn_mse_weighted(&ffn, obj.group, obj.bits, &wf);
                (mse_a * na + mse_f * nf) / (na + nf)
            }
        }
    };
    let mut best_angles = key.r1_angles;
    let mut best = eval(best_angles);
    for stage in 0..angle_stages(kind, block) {
        for code in COARSE_CODES {
            let cand = with_stage_code(best_angles, stage, code);
            let score = eval(cand);
            if score < best {
                best = score;
                best_angles = cand;
            }
        }
        for step in REFINE_STEPS {
            for delta in [step, step.wrapping_neg()] {
                // Wrapping byte arithmetic = exact 2π periodicity.
                let code = stage_code(best_angles, stage).wrapping_add(delta);
                let cand = with_stage_code(best_angles, stage, code);
                let score = eval(cand);
                if score < best {
                    best = score;
                    best_angles = cand;
                }
            }
        }
    }
    best_angles
}

/// Measure one candidate on one layer's actual weights (singleton form
/// of [`score_r1_group`]).
pub fn score_candidate(
    spec: &RotationSpec,
    lw: &LayerWeights,
    cfg: &ModelCfg,
    obj: &Objective,
    calib: Option<LayerCalib>,
) -> Result<CandidateScore, String> {
    spec.validate(cfg)?;
    score_r1_group(std::slice::from_ref(spec), lw, cfg, obj, calib)
        .pop()
        .expect("singleton group yields one score")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::R4Kind;
    use crate::model::weights::FpParams;
    use crate::transform::R1Kind;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn captured_calib(cfg: &ModelCfg, fp: &FpParams) -> CalibWeights {
        use crate::calib::{capture_hessians, checkpoint_fingerprint, CaptureKey};
        use crate::data::{draw_token_windows, CorpusGenerator};
        use crate::quant::fuse_to_dense_plan;

        let plan = RotationPlan::uniform(RotationSpec::baseline(cfg), cfg.n_layers, 21);
        let rots = build_plan_rotations(cfg, &plan).unwrap();
        let dense = fuse_to_dense_plan(fp, cfg, &rots);
        let corpus = CorpusGenerator::new(17).generate(2048);
        let seqs = draw_token_windows(&corpus, 6, 16, cfg.vocab, 5);
        let key = CaptureKey {
            calib_seed: 5,
            basis_fingerprint: plan.fingerprint(),
            checkpoint_fingerprint: checkpoint_fingerprint(fp),
            plan_json: plan.to_json().to_string_pretty(),
        };
        let set = capture_hessians(cfg, &dense, &seqs, 0, &key);
        CalibWeights::from_hessian_set(&set, cfg).unwrap()
    }

    #[test]
    fn stream_concat_carries_gamma() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 3);
        let lw = LayerWeights::from_layer(&fp.layers[0], &cfg);
        let d = cfg.d_model;
        assert_eq!((lw.stream.rows, lw.stream.cols), (d, 3 * d + 2 * cfg.d_ffn));
        assert_eq!(lw.ffn_col0, 3 * d);
        // First block is diag(ln1) · wq.
        let g0 = fp.layers[0].ln1[0] as f64;
        let expect = g0 * fp.layers[0].wq[0] as f64;
        assert!((lw.stream[(0, 0)] - expect).abs() < 1e-12);
    }

    #[test]
    fn scoring_is_deterministic_and_finite() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 5);
        let lw = LayerWeights::from_layer(&fp.layers[1], &cfg);
        let obj = Objective { bits: 2, group: cfg.group, seed: 9, proxy: ProxyKind::Diag };
        let spec = RotationSpec::baseline(&cfg);
        let a = score_candidate(&spec, &lw, &cfg, &obj, None).unwrap();
        let b = score_candidate(&spec, &lw, &cfg, &obj, None).unwrap();
        assert_eq!(a.quant_mse.to_bits(), b.quant_mse.to_bits());
        assert!(a.quant_mse.is_finite() && a.quant_mse > 0.0);
        assert!(a.seq_variance.is_finite());
    }

    #[test]
    fn bad_geometry_is_an_error_not_a_panic() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 5);
        let lw = LayerWeights::from_layer(&fp.layers[0], &cfg);
        let obj = Objective { bits: 2, group: cfg.group, seed: 9, proxy: ProxyKind::Diag };
        let bad = RotationSpec {
            r1: R1Kind::GSR,
            r1_block: 24,
            r4: R4Kind::GH,
            r4_block: cfg.d_ffn,
            r1_angles: 0,
        };
        assert!(score_candidate(&bad, &lw, &cfg, &obj, None).is_err());
    }

    #[test]
    fn rotated_diag_matches_dense_rotation() {
        let mut rng = SplitMix64::new(4);
        let x = Mat::from_fn(8, 8, |_, _| rng.next_normal());
        // Symmetric PSD-ish H.
        let h = x.matmul(&x.transpose());
        let r = crate::transform::rht(8, &mut rng);
        let fast = rotated_diag(&h, &r);
        let dense = r.transpose().matmul(&h).matmul(&r);
        for (j, v) in fast.iter().enumerate() {
            assert!((v - dense[(j, j)]).abs() < 1e-9, "col {j}: {v} vs {}", dense[(j, j)]);
        }
    }

    #[test]
    fn calibrated_scoring_is_finite_deterministic_and_distinct() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 5);
        let calib = captured_calib(&cfg, &fp);
        let lw = LayerWeights::from_layer(&fp.layers[0], &cfg);
        let obj = Objective { bits: 2, group: cfg.group, seed: 21, proxy: ProxyKind::Diag };
        let spec = RotationSpec::baseline(&cfg);
        let lc = LayerCalib::uncached(&calib.layers[0]);
        let a = score_candidate(&spec, &lw, &cfg, &obj, Some(lc)).unwrap();
        let b = score_candidate(&spec, &lw, &cfg, &obj, Some(lc)).unwrap();
        assert_eq!(a.quant_mse.to_bits(), b.quant_mse.to_bits());
        assert!(a.quant_mse.is_finite() && a.quant_mse > 0.0);
        // Real activation energy is not uniform across channels, so the
        // calibrated score must differ from the unweighted one.
        let plain = score_candidate(&spec, &lw, &cfg, &obj, None).unwrap();
        assert!(
            (a.quant_mse - plain.quant_mse).abs() > 1e-15,
            "diag(H) weighting had no effect: {} vs {}",
            a.quant_mse,
            plain.quant_mse
        );
    }

    /// The full quadratic form agrees with the diag proxy when H is
    /// diagonal (sanity anchor for `hessian_rtn_mse`).
    #[test]
    fn full_proxy_reduces_to_weighted_mse_on_diagonal_hessian() {
        let mut rng = SplitMix64::new(8);
        let w = Mat::from_fn(16, 12, |_, _| rng.next_normal());
        let diag: Vec<f64> = (0..16).map(|i| 0.5 + (i % 4) as f64).collect();
        let mut h = Mat::zeros(16, 16);
        for (i, &d) in diag.iter().enumerate() {
            h[(i, i)] = d;
        }
        let full = hessian_rtn_mse(&w, &h, 8, 2);
        // Weighted MSE normalizes by Σw·cols; the quadratic form by the
        // element count — rescale to compare.
        let weighted = group_rtn_mse_weighted(&w, 8, 2, &diag);
        let wsum: f64 = diag.iter().sum();
        let rescaled = weighted * (wsum * w.cols as f64) / w.data.len() as f64;
        assert!(
            (full - rescaled).abs() < 1e-12 * full.abs().max(1.0),
            "diagonal-H full proxy diverges: {full} vs {rescaled}"
        );
    }

    /// Full-proxy scoring: requires calibration, is deterministic, and
    /// differs from the diag proxy (off-diagonal structure matters).
    #[test]
    fn full_proxy_scoring_requires_calib_and_is_deterministic() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 5);
        let calib = captured_calib(&cfg, &fp);
        let lw = LayerWeights::from_layer(&fp.layers[0], &cfg);
        let obj = Objective { bits: 2, group: cfg.group, seed: 21, proxy: ProxyKind::Full };
        let spec = RotationSpec::baseline(&cfg);
        assert!(score_candidate(&spec, &lw, &cfg, &obj, None).is_err());
        let lc = LayerCalib::uncached(&calib.layers[0]);
        let a = score_candidate(&spec, &lw, &cfg, &obj, Some(lc)).unwrap();
        let b = score_candidate(&spec, &lw, &cfg, &obj, Some(lc)).unwrap();
        assert_eq!(a.quant_mse.to_bits(), b.quant_mse.to_bits());
        assert!(a.quant_mse.is_finite() && a.quant_mse > 0.0);
        let diag_obj = Objective { proxy: ProxyKind::Diag, ..obj };
        let d = score_candidate(&spec, &lw, &cfg, &diag_obj, Some(lc)).unwrap();
        assert!(
            (a.quant_mse - d.quant_mse).abs() > 1e-15,
            "full proxy identical to diag proxy: {}",
            a.quant_mse
        );
    }

    /// Angle descent: deterministic, never worse than the default-angle
    /// initialization, and the reported spec re-scores bit-identically
    /// (the search-correctness contract).
    #[test]
    fn angle_descent_is_deterministic_and_never_hurts() {
        use crate::transform::default_angles;

        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 5);
        let lw = LayerWeights::from_layer(&fp.layers[0], &cfg);
        let obj = Objective { bits: 2, group: cfg.group, seed: 9, proxy: ProxyKind::Diag };
        for kind in [R1Kind::GIV, R1Kind::BFLY] {
            let seeded = RotationSpec {
                r1: kind,
                r1_block: 16,
                r4: R4Kind::GH,
                r4_block: cfg.d_ffn,
                r1_angles: default_angles(kind, 16),
            };
            let a = score_candidate(&seeded, &lw, &cfg, &obj, None).unwrap();
            let b = score_candidate(&seeded, &lw, &cfg, &obj, None).unwrap();
            assert_eq!(a.spec, b.spec, "{kind}: descent must be deterministic");
            assert_eq!(a.quant_mse.to_bits(), b.quant_mse.to_bits());
            // Re-scoring the descended spec skips descent yet lands on
            // the identical score.
            let rescored = score_candidate(&a.spec, &lw, &cfg, &obj, None).unwrap();
            assert_eq!(a.quant_mse.to_bits(), rescored.quant_mse.to_bits(), "{kind}");
            // Descent never loses to the frozen default initialization
            // (score the default angles via a group that must NOT
            // trigger descent: perturb one dead... there are none, so
            // compare against the inner score of the default spec).
            let frozen = score_r1_group_inner(
                std::slice::from_ref(&seeded),
                &lw,
                &cfg,
                &obj,
                None,
            )
            .pop()
            .unwrap()
            .unwrap();
            assert!(
                a.quant_mse <= frozen.quant_mse,
                "{kind}: descent made things worse: {} > {}",
                a.quant_mse,
                frozen.quant_mse
            );
        }
    }
}
