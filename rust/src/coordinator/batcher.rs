//! Dynamic batching: group requests up to the graph batch size, flushing
//! on size or deadline — the standard continuous-batching trade-off
//! (throughput vs tail latency) at the scale of this testbed.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Flush policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max items per batch (the compiled graph's batch dimension).
    pub max_batch: usize,
    /// Max time the oldest item may wait before a partial flush.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 4, max_wait: Duration::from_millis(5) }
    }
}

/// Accumulates items and decides when a batch is ready.
///
/// Each item carries its enqueue time, so the deadline always tracks
/// the oldest *remaining* item: flushing a full batch does not restart
/// the clock for what stays behind, and no item can wait longer than
/// `max_wait` past its own enqueue under sustained load.
///
/// ```
/// use gsr::coordinator::{BatchPolicy, DynamicBatcher};
/// use std::time::{Duration, Instant};
/// let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(1) };
/// let mut b = DynamicBatcher::new(policy);
/// b.push("a");
/// assert!(!b.ready(Instant::now())); // under-full, deadline far away
/// b.push("b");
/// assert!(b.ready(Instant::now())); // full batch flushes immediately
/// assert_eq!(b.take_batch(), vec!["a", "b"]);
/// ```
pub struct DynamicBatcher<T> {
    policy: BatchPolicy,
    pending: VecDeque<(Instant, T)>,
}

impl<T> DynamicBatcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, pending: VecDeque::new() }
    }

    pub fn push(&mut self, item: T) {
        self.pending.push_back((Instant::now(), item));
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Is a batch ready under the policy?
    pub fn ready(&self, now: Instant) -> bool {
        if self.pending.len() >= self.policy.max_batch {
            return true;
        }
        match self.pending.front() {
            Some((t0, _)) => now.saturating_duration_since(*t0) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Time until the deadline flush (None if empty).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending.front().map(|(t0, _)| {
            let elapsed = now.saturating_duration_since(*t0);
            self.policy.max_wait.saturating_sub(elapsed)
        })
    }

    /// Take up to `max_batch` items (FIFO). The remainder keeps its
    /// original enqueue times — deadlines carry over, never reset.
    pub fn take_batch(&mut self) -> Vec<T> {
        let n = self.pending.len().min(self.policy.max_batch);
        self.pending.drain(..n).map(|(_, item)| item).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_on_size() {
        let mut b = DynamicBatcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(10) });
        b.push(1);
        b.push(2);
        assert!(!b.ready(Instant::now()));
        b.push(3);
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) });
        b.push("x");
        assert!(!b.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(2));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec!["x"]);
    }

    #[test]
    fn fifo_order_and_remainder() {
        let mut b = DynamicBatcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(1) });
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.take_batch(), vec![0, 1]);
        assert_eq!(b.take_batch(), vec![2, 3]);
        assert_eq!(b.len(), 1);
    }

    /// Regression: taking a full batch must NOT restart the remainder's
    /// deadline. Items enqueued before the flush keep their original
    /// enqueue time, so an already-overdue remainder flushes immediately
    /// instead of waiting another `max_wait` (previously the wait could
    /// grow without bound under sustained load).
    #[test]
    fn deadline_tracks_oldest_remaining_item() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(40),
        });
        b.push(1);
        b.push(2);
        b.push(3);
        std::thread::sleep(Duration::from_millis(45));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec![1, 2]);
        // Item 3 has already waited past max_wait: still ready, zero
        // time to deadline — its clock did not restart at the flush.
        assert!(b.ready(Instant::now()), "remainder deadline must carry over");
        assert_eq!(b.time_to_deadline(Instant::now()), Some(Duration::ZERO));
    }

    #[test]
    fn never_drops_or_duplicates() {
        // Property-style: random pushes/takes preserve the multiset.
        let mut rng = crate::rng::SplitMix64::new(42);
        let mut b = DynamicBatcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(1) });
        let mut pushed = 0u64;
        let mut taken: Vec<u64> = Vec::new();
        for _ in 0..200 {
            if rng.next_below(2) == 0 {
                b.push(pushed);
                pushed += 1;
            } else if !b.is_empty() {
                taken.extend(b.take_batch());
            }
        }
        while !b.is_empty() {
            taken.extend(b.take_batch());
        }
        let expect: Vec<u64> = (0..pushed).collect();
        assert_eq!(taken, expect, "FIFO without loss/dup");
    }
}
