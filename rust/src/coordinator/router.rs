//! Request routing across model variants.
//!
//! Routes by explicit variant name or by policy over a variant pool
//! (round-robin / least-loaded). Pure state machine — no PJRT types —
//! so it is fully unit/property-testable.

use std::collections::BTreeMap;

/// Routing policy for requests that do not pin a variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

/// Tracks registered variants and in-flight counts.
pub struct Router {
    policy: RoutePolicy,
    variants: Vec<String>,
    in_flight: BTreeMap<String, usize>,
    next_rr: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Self { policy, variants: Vec::new(), in_flight: BTreeMap::new(), next_rr: 0 }
    }

    pub fn register(&mut self, name: &str) {
        if !self.variants.iter().any(|v| v == name) {
            self.variants.push(name.to_string());
            self.in_flight.insert(name.to_string(), 0);
        }
    }

    pub fn variants(&self) -> &[String] {
        &self.variants
    }

    /// Pick a target for a request. `pinned` wins if registered.
    pub fn route(&mut self, pinned: Option<&str>) -> Option<String> {
        if let Some(p) = pinned {
            if self.variants.iter().any(|v| v == p) {
                self.dispatch(p.to_string());
                return Some(p.to_string());
            }
            return None;
        }
        if self.variants.is_empty() {
            return None;
        }
        let chosen = match self.policy {
            RoutePolicy::RoundRobin => {
                let v = self.variants[self.next_rr % self.variants.len()].clone();
                self.next_rr += 1;
                v
            }
            RoutePolicy::LeastLoaded => self
                .variants
                .iter()
                .min_by_key(|v| self.in_flight[*v])
                .cloned()
                .unwrap(),
        };
        self.dispatch(chosen.clone());
        Some(chosen)
    }

    fn dispatch(&mut self, name: String) {
        *self.in_flight.entry(name).or_insert(0) += 1;
    }

    /// Mark a request complete.
    pub fn complete(&mut self, name: &str) {
        if let Some(c) = self.in_flight.get_mut(name) {
            *c = c.saturating_sub(1);
        }
    }

    pub fn in_flight(&self, name: &str) -> usize {
        self.in_flight.get(name).copied().unwrap_or(0)
    }

    pub fn total_in_flight(&self) -> usize {
        self.in_flight.values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        r.register("a");
        r.register("b");
        let picks: Vec<String> = (0..4).map(|_| r.route(None).unwrap()).collect();
        assert_eq!(picks, vec!["a", "b", "a", "b"]);
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(RoutePolicy::LeastLoaded);
        r.register("a");
        r.register("b");
        let first = r.route(None).unwrap();
        let second = r.route(None).unwrap();
        assert_ne!(first, second, "second pick must go to the idle variant");
        r.complete(&first);
        assert_eq!(r.route(None).unwrap(), first);
    }

    #[test]
    fn pinned_routing_and_unknown() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        r.register("gsr");
        assert_eq!(r.route(Some("gsr")).as_deref(), Some("gsr"));
        assert_eq!(r.route(Some("nope")), None);
        assert_eq!(r.in_flight("gsr"), 1);
    }

    #[test]
    fn in_flight_accounting_never_negative() {
        let mut r = Router::new(RoutePolicy::LeastLoaded);
        r.register("a");
        r.complete("a"); // complete before dispatch
        assert_eq!(r.in_flight("a"), 0);
        r.route(Some("a"));
        r.complete("a");
        r.complete("a");
        assert_eq!(r.in_flight("a"), 0);
        assert_eq!(r.total_in_flight(), 0);
    }

    #[test]
    fn register_idempotent() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        r.register("a");
        r.register("a");
        assert_eq!(r.variants().len(), 1);
    }
}
