//! The serving loop: an executor thread owning a [`BackendSet`], fed by
//! per-variant batched queues.
//!
//! `Server::start_set` spawns one executor thread that builds and owns
//! the backend set (PJRT handles never cross threads, so the PJRT set is
//! constructed *inside* the thread; the native set may be built anywhere
//! and moved in). Clients submit `Request`s over an mpsc sender and
//! receive `Response`s on their own per-request channel. A
//! `DynamicBatcher` per variant packs score requests up to the backend's
//! `[batch, seq]` shape; under-full flushes run as partial batches (no
//! compute on padding rows). Malformed requests — longer than the
//! backend's `seq`, out-of-vocab token ids, unknown variants — are
//! rejected individually at enqueue with a clear error, never silently
//! truncated and never able to fail a batch they were packed with.
//!
//! ## Generation
//!
//! [`GenerateRequest`]s run greedy incremental decoding on backends
//! that support it: the executor prefills the prompt once
//! (`Backend::start_generation`), then interleaves *batched decode
//! rounds* — up to `batch` active sequences of a variant step together
//! per round — with normal queue service. Sequences complete
//! individually (on `max_new` or a stop token) and reply immediately;
//! the round simply shrinks. Decode logits are bit-identical to a full
//! re-forward of the prefix, so a greedy decode is reproducible no
//! matter how rounds were batched. Shutdown drains scoring queues and
//! runs every active generation to completion before reporting metrics.

use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::metrics::Metrics;
use crate::exec::{greedy_argmax, Backend, BackendSet, Generation, NativeSet, PjrtSet};

/// A scoring request: tokens (≤ seq) for one sequence; the server
/// returns per-position logits for exactly the positions sent.
pub struct Request {
    /// Variant name ("fp" for the reference model).
    pub variant: String,
    /// Token sequence, length ≤ backend seq (right-padded internally).
    pub tokens: Vec<i32>,
    /// Reply channel.
    pub reply: mpsc::Sender<Response>,
}

/// Response: logits `[len(tokens), vocab]` for the request's sequence.
pub struct Response {
    pub logits: Result<Vec<f32>, String>,
}

/// A greedy-decoding request: prefill `prompt`, then decode up to
/// `max_new` tokens incrementally (KV-cached, never re-running the
/// prefix). `prompt.len() + max_new` must fit the backend's `seq` — the
/// per-sequence cache capacity.
pub struct GenerateRequest {
    /// Variant name ("fp" for the reference model).
    pub variant: String,
    /// Prompt tokens (non-empty, each in `0..vocab`).
    pub prompt: Vec<i32>,
    /// Maximum tokens to generate (≥ 1).
    pub max_new: usize,
    /// Optional stop token: generation ends *without emitting it* when
    /// greedy decoding produces this id.
    pub stop: Option<i32>,
    /// Reply channel.
    pub reply: mpsc::Sender<GenerateResponse>,
}

/// Response to a [`GenerateRequest`].
pub struct GenerateResponse {
    pub result: Result<Generated, String>,
}

/// A completed greedy generation.
#[derive(Debug, Clone)]
pub struct Generated {
    /// Emitted tokens, in order (stop token excluded).
    pub tokens: Vec<i32>,
    /// Prompt length the decode started from.
    pub prompt_len: usize,
}

enum Job {
    Score(Request, Instant),
    Generate(GenerateRequest, Instant),
    Shutdown(mpsc::Sender<Metrics>),
}

/// One in-flight generation owned by the executor.
struct ActiveGen {
    /// Index into the executor's `queues` (variant identity).
    variant_idx: usize,
    gen: Generation,
    prompt_len: usize,
    /// Token to feed the next decode round (last greedy pick).
    next_token: i32,
    /// Emitted tokens so far.
    produced: Vec<i32>,
    max_new: usize,
    stop: Option<i32>,
    reply: mpsc::Sender<GenerateResponse>,
    t0: Instant,
}

/// Handle to the running server.
pub struct Server {
    tx: mpsc::Sender<Job>,
    handle: Option<JoinHandle<()>>,
}

/// Cloneable submission handle — hand one to each client thread
/// (`mpsc::Sender` is `Send`, so clones cross threads freely).
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Job>,
}

fn submit_on(tx: &mpsc::Sender<Job>, req: Request) -> Result<(), String> {
    tx.send(Job::Score(req, Instant::now())).map_err(|_| "server stopped".to_string())
}

fn score_on(tx: &mpsc::Sender<Job>, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
    let (reply, rx) = mpsc::channel();
    submit_on(tx, Request { variant: variant.to_string(), tokens, reply })?;
    rx.recv().map_err(|_| "no response".to_string())?.logits
}

fn submit_generate_on(tx: &mpsc::Sender<Job>, req: GenerateRequest) -> Result<(), String> {
    tx.send(Job::Generate(req, Instant::now())).map_err(|_| "server stopped".to_string())
}

fn generate_on(
    tx: &mpsc::Sender<Job>,
    variant: &str,
    prompt: Vec<i32>,
    max_new: usize,
    stop: Option<i32>,
) -> Result<Generated, String> {
    let (reply, rx) = mpsc::channel();
    submit_generate_on(
        tx,
        GenerateRequest { variant: variant.to_string(), prompt, max_new, stop, reply },
    )?;
    rx.recv().map_err(|_| "no response".to_string())?.result
}

impl ServerHandle {
    /// Submit a scoring request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<(), String> {
        submit_on(&self.tx, req)
    }

    /// Convenience: synchronous score of one sequence.
    pub fn score(&self, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
        score_on(&self.tx, variant, tokens)
    }

    /// Submit a generation request (non-blocking).
    pub fn submit_generate(&self, req: GenerateRequest) -> Result<(), String> {
        submit_generate_on(&self.tx, req)
    }

    /// Convenience: synchronous greedy generation of one sequence.
    pub fn generate(
        &self,
        variant: &str,
        prompt: Vec<i32>,
        max_new: usize,
        stop: Option<i32>,
    ) -> Result<Generated, String> {
        generate_on(&self.tx, variant, prompt, max_new, stop)
    }
}

impl Server {
    /// Start the executor over the PJRT runtime with the given variants
    /// resident (compiled graphs + uploaded weights).
    pub fn start(
        artifacts_dir: &Path,
        variant_names: &[String],
        policy: BatchPolicy,
    ) -> Result<Self, String> {
        let dir = artifacts_dir.to_path_buf();
        let names: Vec<String> = variant_names.to_vec();
        Self::start_set(move || PjrtSet::load(&dir, &names), policy)
    }

    /// Start the executor over a prebuilt native backend set — serves
    /// fp, quantized and heterogeneous searched-plan variants with no
    /// PJRT involvement.
    pub fn start_native(set: NativeSet, policy: BatchPolicy) -> Result<Self, String> {
        if set.is_empty() {
            return Err("native backend set is empty".to_string());
        }
        Self::start_set(move || Ok(set), policy)
    }

    /// Start the executor over any [`BackendSet`]. `build` runs on the
    /// executor thread, so non-`Send` sets (PJRT) work; its error is
    /// propagated out of `start_set` via a ready handshake.
    pub fn start_set<V, F>(build: F, policy: BatchPolicy) -> Result<Self, String>
    where
        V: BackendSet + 'static,
        F: FnOnce() -> Result<V, String> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let handle = std::thread::spawn(move || match build() {
            Err(e) => {
                let _ = ready_tx.send(Err(e));
            }
            Ok(set) => {
                let _ = ready_tx.send(Ok(()));
                executor_loop(set, rx, policy);
            }
        });
        ready_rx
            .recv()
            .map_err(|e| format!("executor died during setup: {e}"))??;
        Ok(Self { tx, handle: Some(handle) })
    }

    /// Cloneable submission handle for concurrent client threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { tx: self.tx.clone() }
    }

    /// Submit a scoring request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<(), String> {
        submit_on(&self.tx, req)
    }

    /// Convenience: synchronous score of one sequence.
    pub fn score(&self, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
        score_on(&self.tx, variant, tokens)
    }

    /// Submit a generation request (non-blocking).
    pub fn submit_generate(&self, req: GenerateRequest) -> Result<(), String> {
        submit_generate_on(&self.tx, req)
    }

    /// Convenience: synchronous greedy generation of one sequence.
    pub fn generate(
        &self,
        variant: &str,
        prompt: Vec<i32>,
        max_new: usize,
        stop: Option<i32>,
    ) -> Result<Generated, String> {
        generate_on(&self.tx, variant, prompt, max_new, stop)
    }

    /// Stop and collect metrics.
    pub fn shutdown(mut self) -> Metrics {
        let (mtx, mrx) = mpsc::channel();
        let _ = self.tx.send(Job::Shutdown(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

/// One resident variant's queue plus the backend geometry probed at
/// startup, so malformed requests are rejected at enqueue — a doomed
/// request never waits out `max_wait` or occupies a batch slot.
struct VariantQueue {
    name: String,
    seq: usize,
    vocab: usize,
    /// Effective decode-round width (policy clamped to backend batch).
    cap: usize,
    /// Probed once: does the backend implement prefill/decode?
    generation: bool,
    backend_label: String,
    q: DynamicBatcher<(Request, Instant)>,
}

impl VariantQueue {
    /// Validate a request against static data: length, token range.
    /// Malformed requests are refused individually with a clear error —
    /// never clipped (wrong-but-plausible logits for PPL clients) and
    /// never allowed near a batch they could fail wholesale.
    fn admit(&self, req: &Request) -> Result<(), String> {
        if req.tokens.is_empty() {
            return Err("scoring request needs at least one token".to_string());
        }
        if req.tokens.len() > self.seq {
            return Err(format!(
                "request has {} tokens but backend {} serves seq {}; \
                 split the request instead of truncating",
                req.tokens.len(),
                self.backend_label,
                self.seq
            ));
        }
        self.check_tokens(&req.tokens)
    }

    /// Validate a generation request: backend support, prompt + budget
    /// versus the per-sequence KV-cache capacity (= backend seq), token
    /// ranges. Rejections happen before prefill ever runs.
    fn admit_generate(&self, req: &GenerateRequest) -> Result<(), String> {
        if !self.generation {
            return Err(format!(
                "backend {} does not support incremental decoding; \
                 use a native variant for generate requests",
                self.backend_label
            ));
        }
        if req.prompt.is_empty() {
            return Err("generation needs a non-empty prompt".to_string());
        }
        if req.max_new == 0 {
            return Err("generation needs max_new >= 1".to_string());
        }
        // Peak cache occupancy is `prompt + max_new - 1`: the final
        // emitted token is returned to the client, never fed back into
        // the cache — so a request may use every cache slot.
        if req.prompt.len() + req.max_new > self.seq + 1 {
            return Err(format!(
                "prompt of {} tokens + max_new {} needs {} kv cache slots but \
                 backend {} has {}; shorten the prompt or the budget",
                req.prompt.len(),
                req.max_new,
                req.prompt.len() + req.max_new - 1,
                self.backend_label,
                self.seq
            ));
        }
        self.check_tokens(&req.prompt)?;
        if let Some(stop) = req.stop {
            self.check_tokens(&[stop])
                .map_err(|e| format!("stop token invalid: {e}"))?;
        }
        Ok(())
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<(), String> {
        crate::model::tokens_in_vocab(tokens, self.vocab)
    }
}

fn executor_loop<V: BackendSet>(set: V, rx: mpsc::Receiver<Job>, policy: BatchPolicy) {
    // Per-variant queue, its max_batch clamped to the backend's actual
    // batch capacity so one flush never overflows one forward call.
    let mut queues: Vec<VariantQueue> = Vec::new();
    for name in set.names() {
        let mut cap = policy.max_batch.max(1);
        let (mut seq, mut vocab, mut generation) = (0, 0, false);
        let mut backend_label = String::new();
        set.run(&name, &mut |backend| {
            cap = cap.min(backend.batch()).max(1);
            seq = backend.seq();
            vocab = backend.vocab();
            generation = backend.supports_generation();
            backend_label = backend.name().to_string();
        });
        let q = DynamicBatcher::new(BatchPolicy { max_batch: cap, ..policy });
        queues.push(VariantQueue { name, seq, vocab, cap, generation, backend_label, q });
    }
    let mut metrics = Metrics::default();
    let mut active: Vec<ActiveGen> = Vec::new();
    loop {
        // Wait bounded by the nearest batch deadline — or not at all
        // while generations are active: decode rounds are the idle work.
        let timeout = if active.is_empty() {
            queues
                .iter()
                .filter_map(|vq| vq.q.time_to_deadline(Instant::now()))
                .min()
                .unwrap_or(Duration::from_millis(50))
        } else {
            Duration::ZERO
        };
        let first = match rx.recv_timeout(timeout) {
            Ok(job) => Some(job),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        // Admit the received job plus everything already queued behind
        // it (non-blocking drain): a burst reaches the batchers in one
        // loop turn instead of trickling in one job per decode round.
        for job in first.into_iter().chain(std::iter::from_fn(|| rx.try_recv().ok())) {
            match handle_job(job, &set, &mut queues, &mut active, &mut metrics) {
                Flow::Continue => {}
                Flow::Stop => return,
            }
        }
        let now = Instant::now();
        for vq in queues.iter_mut() {
            while vq.q.ready(now) {
                dispatch(&set, &vq.name, vq.q.take_batch(), &mut metrics);
            }
        }
        // One decode round per loop turn keeps generation throughput
        // high while queued scoring work still gets serviced between
        // rounds.
        decode_round(&set, &queues, &mut active, &mut metrics);
    }
}

enum Flow {
    Continue,
    Stop,
}

/// Admit one incoming job: enqueue/reject a score request, prefill or
/// reject a generate request, or drain-and-stop on shutdown.
fn handle_job<V: BackendSet>(
    job: Job,
    set: &V,
    queues: &mut [VariantQueue],
    active: &mut Vec<ActiveGen>,
    metrics: &mut Metrics,
) -> Flow {
    match job {
        Job::Score(req, t0) => {
            match queues.iter_mut().find(|vq| vq.name == req.variant) {
                Some(vq) => match vq.admit(&req) {
                    Ok(()) => vq.q.push((req, t0)),
                    Err(e) => {
                        metrics.rejected += 1;
                        let _ = req.reply.send(Response { logits: Err(e) });
                    }
                },
                None => {
                    metrics.rejected += 1;
                    let _ = req.reply.send(Response {
                        logits: Err(format!("variant {} not resident", req.variant)),
                    });
                }
            }
            Flow::Continue
        }
        Job::Generate(req, t0) => {
            match queues.iter().position(|vq| vq.name == req.variant) {
                Some(idx) => match queues[idx].admit_generate(&req) {
                    Ok(()) => {
                        let name = queues[idx].name.clone();
                        start_generation(set, idx, &name, req, t0, active, metrics);
                    }
                    Err(e) => {
                        metrics.rejected += 1;
                        let _ = req.reply.send(GenerateResponse { result: Err(e) });
                    }
                },
                None => {
                    metrics.rejected += 1;
                    let _ = req.reply.send(GenerateResponse {
                        result: Err(format!("variant {} not resident", req.variant)),
                    });
                }
            }
            Flow::Continue
        }
        Job::Shutdown(mtx) => {
            // Drain everything before stopping: queued score batches,
            // then active generations to completion.
            for vq in queues.iter_mut() {
                while !vq.q.is_empty() {
                    dispatch(set, &vq.name, vq.q.take_batch(), metrics);
                }
            }
            while !active.is_empty() {
                decode_round(set, queues, active, metrics);
            }
            let _ = mtx.send(metrics.clone());
            Flow::Stop
        }
    }
}

/// Prefill one admitted generation and either complete it immediately
/// (first pick hits `stop`, or `max_new == 1`) or add it to the active
/// set for batched decode rounds.
fn start_generation<V: BackendSet>(
    set: &V,
    variant_idx: usize,
    name: &str,
    req: GenerateRequest,
    t0: Instant,
    active: &mut Vec<ActiveGen>,
    metrics: &mut Metrics,
) {
    let mut res: Option<Result<(Generation, Vec<f32>), String>> = None;
    set.run(name, &mut |backend| {
        res = Some(backend.start_generation(&req.prompt));
    });
    let (gen, last_logits) = match res {
        Some(Ok(pair)) => pair,
        Some(Err(e)) => {
            metrics.generation_failures += 1;
            let _ = req.reply.send(GenerateResponse { result: Err(e) });
            return;
        }
        None => {
            metrics.generation_failures += 1;
            let _ = req.reply.send(GenerateResponse {
                result: Err(format!("variant {name} not resident")),
            });
            return;
        }
    };
    let first = greedy_argmax(&last_logits);
    let mut ag = ActiveGen {
        variant_idx,
        gen,
        prompt_len: req.prompt.len(),
        next_token: first,
        produced: Vec::new(),
        max_new: req.max_new,
        stop: req.stop,
        reply: req.reply,
        t0,
    };
    if ag.stop == Some(first) {
        finish_generation(ag, metrics);
        return;
    }
    ag.produced.push(first);
    if ag.produced.len() >= ag.max_new {
        finish_generation(ag, metrics);
        return;
    }
    active.push(ag);
}

/// Reply with a finished generation and account it.
fn finish_generation(ag: ActiveGen, metrics: &mut Metrics) {
    metrics.record_generation(ag.produced.len() as u64, ag.t0.elapsed());
    let _ = ag.reply.send(GenerateResponse {
        result: Ok(Generated { tokens: ag.produced, prompt_len: ag.prompt_len }),
    });
}

/// One batched decode round: for each variant with active sequences,
/// step up to `cap` of them together through `Backend::decode_batch`,
/// then greedily pick each sequence's next token, completing sequences
/// individually as they hit `max_new` or their stop token.
fn decode_round<V: BackendSet>(
    set: &V,
    queues: &[VariantQueue],
    active: &mut Vec<ActiveGen>,
    metrics: &mut Metrics,
) {
    if active.is_empty() {
        return;
    }
    for (qi, vq) in queues.iter().enumerate() {
        // Pull this round's group from the *front* of `active` (stable
        // FIFO partition): survivors re-enter at the tail, so when more
        // sequences are active than fit one round, slots round-robin
        // fairly instead of favoring the newest arrivals. Selection
        // order never affects logits — decode is per-sequence
        // deterministic — only scheduling fairness.
        let mut group: Vec<ActiveGen> = Vec::new();
        let mut rest: Vec<ActiveGen> = Vec::with_capacity(active.len());
        for ag in active.drain(..) {
            if ag.variant_idx == qi && group.len() < vq.cap {
                group.push(ag);
            } else {
                rest.push(ag);
            }
        }
        active.append(&mut rest);
        if group.is_empty() {
            continue;
        }
        let tokens: Vec<i32> = group.iter().map(|a| a.next_token).collect();
        let mut res: Option<Result<Vec<Result<Vec<f32>, String>>, String>> = None;
        let t_exec = Instant::now();
        set.run(&vq.name, &mut |backend| {
            let gens: Vec<&mut Generation> = group.iter_mut().map(|a| &mut a.gen).collect();
            res = Some(backend.decode_batch(gens, &tokens));
        });
        let exec_elapsed = t_exec.elapsed();
        let rows = match res {
            Some(Ok(rows)) => rows,
            other => {
                // Call-level backend error (or vanished variant): fail
                // the whole round's sequences rather than looping
                // forever.
                let e = match other {
                    Some(Err(e)) => e,
                    _ => format!("variant {} not resident", vq.name),
                };
                for ag in group {
                    metrics.generation_failures += 1;
                    let _ = ag.reply.send(GenerateResponse { result: Err(e.clone()) });
                }
                continue;
            }
        };
        // Account the round over the sequences that actually stepped.
        let stepped: Vec<bool> = rows.iter().map(|r| r.is_ok()).collect();
        let seqs = stepped.iter().filter(|&&ok| ok).count();
        let cache_tokens: u64 = group
            .iter()
            .zip(&stepped)
            .filter(|(_, &ok)| ok)
            .map(|(a, _)| a.gen.len() as u64)
            .sum();
        if seqs > 0 {
            metrics.record_decode(seqs, cache_tokens, exec_elapsed);
        }
        for (mut ag, row) in group.into_iter().zip(rows) {
            let logits = match row {
                Ok(logits) => logits,
                Err(e) => {
                    // Per-sequence failure: only this generation ends;
                    // its round-mates' results stand.
                    metrics.generation_failures += 1;
                    let _ = ag.reply.send(GenerateResponse { result: Err(e) });
                    continue;
                }
            };
            let tok = greedy_argmax(&logits);
            if ag.stop == Some(tok) {
                finish_generation(ag, metrics);
                continue;
            }
            ag.produced.push(tok);
            if ag.produced.len() >= ag.max_new {
                finish_generation(ag, metrics);
            } else {
                ag.next_token = tok;
                active.push(ag);
            }
        }
    }
}

/// Route one flushed batch to its backend (`Option` shuttle because
/// `BackendSet::run` takes an `FnMut` callback).
fn dispatch<V: BackendSet>(
    set: &V,
    name: &str,
    batch: Vec<(Request, Instant)>,
    metrics: &mut Metrics,
) {
    let mut slot = Some(batch);
    let found = set.run(name, &mut |backend| {
        if let Some(batch) = slot.take() {
            run_batch(backend, batch, metrics);
        }
    });
    if !found {
        for (req, _) in slot.take().into_iter().flatten() {
            metrics.rejected += 1;
            let _ = req.reply.send(Response {
                logits: Err(format!("variant {name} not resident")),
            });
        }
    }
}

fn run_batch(backend: &dyn Backend, batch: Vec<(Request, Instant)>, metrics: &mut Metrics) {
    if batch.is_empty() {
        return;
    }
    let (b, s, v) = (backend.batch(), backend.seq(), backend.vocab());
    debug_assert!(batch.len() <= b, "batcher flushed more than the backend batch");
    // Requests were validated at enqueue (`VariantQueue::admit`), so
    // every one fits. Pack exactly `batch.len()` rows — backends take
    // partial batches, so an under-full flush never pays for the
    // forward pass of padding rows it doesn't need.
    let rows = batch.len();
    let mut tokens = vec![0i32; rows * s];
    let mut lens = Vec::with_capacity(rows);
    for (i, (req, _)) in batch.iter().enumerate() {
        tokens[i * s..i * s + req.tokens.len()].copy_from_slice(&req.tokens);
        lens.push(req.tokens.len());
    }
    let t_exec = Instant::now();
    let result = backend.forward_batch(&tokens);
    let exec_elapsed = t_exec.elapsed();
    let n_tokens: u64 = lens.iter().sum::<usize>() as u64;
    for (i, (req, t0)) in batch.into_iter().enumerate() {
        let logits = match &result {
            Ok(all) => Ok(all[i * s * v..(i * s + lens[i]) * v].to_vec()),
            Err(e) => Err(e.clone()),
        };
        let _ = req.reply.send(Response { logits });
        metrics.record_request(t0.elapsed());
    }
    metrics.record_batch(rows, n_tokens, exec_elapsed);
}
