//! The serving loop: an executor thread owning a [`BackendSet`], fed by
//! per-variant batched queues.
//!
//! `Server::start_set` spawns one executor thread that builds and owns
//! the backend set (PJRT handles never cross threads, so the PJRT set is
//! constructed *inside* the thread; the native set may be built anywhere
//! and moved in). Clients submit `Request`s over an mpsc sender and
//! receive `Response`s on their own per-request channel. A
//! `DynamicBatcher` per variant packs score requests up to the backend's
//! `[batch, seq]` shape; under-full flushes run as partial batches (no
//! compute on padding rows). Malformed requests — longer than the
//! backend's `seq`, out-of-vocab token ids, unknown variants — are
//! rejected individually at enqueue with a clear error (and a per-reason
//! rejection counter), never silently truncated and never able to fail
//! a batch they were packed with.
//!
//! ## Generation: paged KV + continuous batching
//!
//! [`GenerateRequest`]s run incremental decoding through the paged
//! generation contract. Each variant that supports it owns a
//! [`BlockPool`]; a sequence is admitted when its *peak* occupancy
//! (`prompt + max_new − 1`) fits the pool's **total** token inventory —
//! not when that many slots are contiguously free — and starts with
//! zero granted blocks. Every loop turn runs one *continuous-batching
//! round* per variant, composed by the deterministic FIFO+budget policy
//! in [`crate::sched`]:
//!
//! * sequences with one pending token step together through
//!   `Backend::decode_batch` (up to the round budget, admission order);
//! * at most **one** bounded prefill chunk (the oldest sequence still
//!   feeding its prompt or recomputing after preemption) rides along
//!   per round, so long prompts never convoy decodes — new sequences
//!   join the running round as soon as they are admitted;
//! * when the pool runs dry, the youngest block-holding sequence of the
//!   variant is preempted (blocks reclaimed, recompute-on-resume) in
//!   favor of an older one — the oldest sequence can always take the
//!   whole pool, so admission implies eventual completion.
//!
//! Picks go through the per-request [`Sampler`]: greedy by default,
//! temperature / top-k / top-p with a private seeded stream otherwise.
//! Decode logits are bit-identical to a full re-forward of the prefix
//! for any block layout, chunking, thread count and round composition,
//! and the sampler consumes exactly one draw per pick — so every
//! generation (greedy *or* sampled) replays bit-identically under any
//! co-scheduled load. Emitted tokens also stream to the optional
//! [`GenerateRequest::stream`] channel at pick time (once — preemption
//! recomputes caches, never re-picks). Shutdown drains scoring queues
//! and runs every active generation to completion before reporting
//! metrics.
//!
//! ## Speculative decoding
//!
//! With [`SchedConfig::speculate`] set, a cheap resident variant (the
//! *draft*, typically a 2-bit quantization of the same checkpoint)
//! proposes up to `k` greedy tokens per round and the request's target
//! variant verifies them in one [`Backend::verify_draft`] forward —
//! `k + 1` logit rows for the price of one cached pass. Acceptance
//! replays the request's own [`Sampler`] against those rows, consuming
//! exactly one draw per emitted token in stream order, so greedy *and*
//! sampled speculative generations are token-for-token identical to
//! non-speculative decode; speculation changes how many forwards run,
//! never what is emitted. The first mismatching row's pick *is* the
//! correction token; positions past it roll back bit-exactly and their
//! tail blocks return to the pool. Both KV caches draw blocks from the
//! target variant's pool (the draft's geometry is validated at executor
//! start), admission counts both caches' peak demand, and preemption
//! reclaims both. Requests *targeting* the draft variant itself decode
//! plainly.
//!
//! ## Observability
//!
//! The executor records into an [`Obs`](crate::obs::Obs) bundle when
//! started through the `_obs` constructors: registry-backed counters,
//! gauges and fixed-bucket latency histograms (Prometheus-exposable)
//! plus typed flight-recorder events for every admission, rejection,
//! prefill chunk, decode round, preemption/resume pair, block grant
//! and batch execution. The default constructors wire a private
//! bundle, so instrumentation left in the hot paths costs one relaxed
//! atomic load per event while tracing is disabled.

use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::metrics::{Metrics, RejectReason, ServingMetrics};
use crate::exec::{greedy_argmax, Backend, BackendSet, Generation, NativeSet, PjrtSet};
use crate::obs::{Obs, RequestKind, TraceEvent, TraceHandle};
use crate::sched::{compose_round, BlockPool, Sampler, SamplingParams, SchedConfig};

/// The executor's recording bundle: registry-backed metric handles
/// plus this thread's flight-recorder ring. Every method is `&self`
/// (atomic cells / per-shard ring), so it threads through the round
/// helpers without borrow gymnastics.
struct Telemetry {
    m: ServingMetrics,
    tr: TraceHandle,
}

/// A scoring request: tokens (≤ seq) for one sequence; the server
/// returns per-position logits for exactly the positions sent.
pub struct Request {
    /// Variant name ("fp" for the reference model).
    pub variant: String,
    /// Token sequence, length ≤ backend seq (right-padded internally).
    pub tokens: Vec<i32>,
    /// Reply channel.
    pub reply: mpsc::Sender<Response>,
}

/// Response: logits `[len(tokens), vocab]` for the request's sequence.
pub struct Response {
    pub logits: Result<Vec<f32>, String>,
}

/// An incremental-decoding request: prefill `prompt` (chunked, paged),
/// then decode up to `max_new` tokens. Admission requires the peak KV
/// occupancy `prompt.len() + max_new − 1` to fit the variant's block
/// pool (its total token inventory) — not to be contiguously free.
pub struct GenerateRequest {
    /// Variant name ("fp" for the reference model).
    pub variant: String,
    /// Prompt tokens (non-empty, each in `0..vocab`).
    pub prompt: Vec<i32>,
    /// Maximum tokens to generate (≥ 1).
    pub max_new: usize,
    /// Optional stop token: generation ends *without emitting it* when
    /// decoding picks this id.
    pub stop: Option<i32>,
    /// Sampling configuration ([`SamplingParams::greedy`] for greedy).
    pub sampling: SamplingParams,
    /// Optional streaming channel: every emitted token is sent here at
    /// pick time, exactly once (a dropped receiver never stalls the
    /// scheduler). The final [`GenerateResponse`] still carries the
    /// full sequence.
    pub stream: Option<mpsc::Sender<i32>>,
    /// Reply channel.
    pub reply: mpsc::Sender<GenerateResponse>,
}

/// Response to a [`GenerateRequest`].
pub struct GenerateResponse {
    pub result: Result<Generated, String>,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Generated {
    /// Emitted tokens, in order (stop token excluded).
    pub tokens: Vec<i32>,
    /// Prompt length the decode started from.
    pub prompt_len: usize,
}

enum Job {
    Score(Request, Instant),
    Generate(GenerateRequest, Instant),
    Shutdown(mpsc::Sender<Metrics>),
}

/// One in-flight generation owned by the executor.
///
/// The sequence's *feed stream* is `prompt ++ produced`; `gen.len()`
/// counts how much of it the KV cache has absorbed. One pending token
/// means decode-ready; more means prefill (fresh prompt or
/// recompute-on-resume after preemption — the `Sampler` and `produced`
/// survive preemption untouched, which is what makes resumed picks
/// bit-identical).
struct SeqState {
    /// Admission id — the FIFO key (monotone per executor).
    id: u64,
    /// Index into the executor's `queues` (variant identity).
    variant_idx: usize,
    /// Set while the sequence's blocks are reclaimed (preemption);
    /// cleared — emitting the paired resume trace event — at its next
    /// successful capacity grant.
    preempted: bool,
    prompt: Vec<i32>,
    /// Emitted tokens so far.
    produced: Vec<i32>,
    max_new: usize,
    stop: Option<i32>,
    sampler: Sampler,
    gen: Generation,
    /// Draft-variant KV cache for speculative decoding — `None` when
    /// speculation is off or the request targets the draft variant
    /// itself (it then decodes plainly). Invariant between rounds: the
    /// draft has absorbed at most `prompt.len() + produced.len() - 1`
    /// feed tokens (never the pending one).
    draft: Option<Generation>,
    reply: mpsc::Sender<GenerateResponse>,
    stream: Option<mpsc::Sender<i32>>,
    t0: Instant,
}

impl SeqState {
    /// Tokens of `prompt ++ produced` the cache has not absorbed yet.
    fn pending(&self) -> usize {
        self.prompt.len() + self.produced.len() - self.gen.len()
    }

    /// Feed tokens absorbed by the draft cache (0 without one).
    fn draft_len(&self) -> usize {
        self.draft.as_ref().map_or(0, |g| g.len())
    }

    /// Block-granted capacity of the draft cache (0 without one).
    fn draft_capacity(&self) -> usize {
        self.draft.as_ref().map_or(0, |g| g.capacity())
    }

    /// Feed-stream token at absolute position `pos`.
    fn feed_at(&self, pos: usize) -> i32 {
        if pos < self.prompt.len() {
            self.prompt[pos]
        } else {
            self.produced[pos - self.prompt.len()]
        }
    }
}

/// What one scheduling round decided for a member sequence.
enum Fate {
    /// Still running — goes back into the active set.
    Active,
    /// Completed this round (blocks already back in the pool).
    Done,
    /// Failed this round (blocks already back in the pool).
    Failed(String),
}

/// Handle to the running server.
pub struct Server {
    tx: mpsc::Sender<Job>,
    handle: Option<JoinHandle<()>>,
}

/// Cloneable submission handle — hand one to each client thread
/// (`mpsc::Sender` is `Send`, so clones cross threads freely).
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Job>,
}

fn submit_on(tx: &mpsc::Sender<Job>, req: Request) -> Result<(), String> {
    tx.send(Job::Score(req, Instant::now())).map_err(|_| "server stopped".to_string())
}

fn score_on(tx: &mpsc::Sender<Job>, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
    let (reply, rx) = mpsc::channel();
    submit_on(tx, Request { variant: variant.to_string(), tokens, reply })?;
    rx.recv().map_err(|_| "no response".to_string())?.logits
}

fn submit_generate_on(tx: &mpsc::Sender<Job>, req: GenerateRequest) -> Result<(), String> {
    tx.send(Job::Generate(req, Instant::now())).map_err(|_| "server stopped".to_string())
}

fn generate_with_on(
    tx: &mpsc::Sender<Job>,
    variant: &str,
    prompt: Vec<i32>,
    max_new: usize,
    stop: Option<i32>,
    sampling: SamplingParams,
) -> Result<Generated, String> {
    let (reply, rx) = mpsc::channel();
    submit_generate_on(
        tx,
        GenerateRequest {
            variant: variant.to_string(),
            prompt,
            max_new,
            stop,
            sampling,
            stream: None,
            reply,
        },
    )?;
    rx.recv().map_err(|_| "no response".to_string())?.result
}

impl ServerHandle {
    /// Submit a scoring request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<(), String> {
        submit_on(&self.tx, req)
    }

    /// Convenience: synchronous score of one sequence.
    pub fn score(&self, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
        score_on(&self.tx, variant, tokens)
    }

    /// Submit a generation request (non-blocking).
    pub fn submit_generate(&self, req: GenerateRequest) -> Result<(), String> {
        submit_generate_on(&self.tx, req)
    }

    /// Convenience: synchronous greedy generation of one sequence.
    pub fn generate(
        &self,
        variant: &str,
        prompt: Vec<i32>,
        max_new: usize,
        stop: Option<i32>,
    ) -> Result<Generated, String> {
        generate_with_on(&self.tx, variant, prompt, max_new, stop, SamplingParams::greedy())
    }

    /// Convenience: synchronous generation with explicit sampling.
    pub fn generate_with(
        &self,
        variant: &str,
        prompt: Vec<i32>,
        max_new: usize,
        stop: Option<i32>,
        sampling: SamplingParams,
    ) -> Result<Generated, String> {
        generate_with_on(&self.tx, variant, prompt, max_new, stop, sampling)
    }

    /// Submit a generation whose tokens stream back as they are picked.
    /// Returns the token receiver and the final-result receiver; tokens
    /// arrive exactly once each, in order, ahead of the final reply.
    pub fn generate_stream(
        &self,
        variant: &str,
        prompt: Vec<i32>,
        max_new: usize,
        stop: Option<i32>,
        sampling: SamplingParams,
    ) -> Result<(mpsc::Receiver<i32>, mpsc::Receiver<GenerateResponse>), String> {
        let (stream_tx, stream_rx) = mpsc::channel();
        let (reply, reply_rx) = mpsc::channel();
        submit_generate_on(
            &self.tx,
            GenerateRequest {
                variant: variant.to_string(),
                prompt,
                max_new,
                stop,
                sampling,
                stream: Some(stream_tx),
                reply,
            },
        )?;
        Ok((stream_rx, reply_rx))
    }
}

impl Server {
    /// Start the executor over the PJRT runtime with the given variants
    /// resident (compiled graphs + uploaded weights).
    pub fn start(
        artifacts_dir: &Path,
        variant_names: &[String],
        policy: BatchPolicy,
    ) -> Result<Self, String> {
        let dir = artifacts_dir.to_path_buf();
        let names: Vec<String> = variant_names.to_vec();
        Self::start_set(move || PjrtSet::load(&dir, &names), policy)
    }

    /// Start the executor over a prebuilt native backend set — serves
    /// fp, quantized and heterogeneous searched-plan variants with no
    /// PJRT involvement. Paged generation uses [`SchedConfig::default`];
    /// see [`Server::start_native_sched`] to configure it.
    pub fn start_native(set: NativeSet, policy: BatchPolicy) -> Result<Self, String> {
        Self::start_native_sched(set, policy, SchedConfig::default())
    }

    /// [`Server::start_native`] with an explicit scheduler
    /// configuration (page size, pool size, prefill chunk).
    pub fn start_native_sched(
        set: NativeSet,
        policy: BatchPolicy,
        sched: SchedConfig,
    ) -> Result<Self, String> {
        Self::start_native_obs(set, policy, sched, &Obs::new())
    }

    /// [`Server::start_native_sched`] recording into the given
    /// observability bundle: metric families register on
    /// `obs.registry` (Prometheus-exposable, snapshot-dumpable) and
    /// trace events land in `obs.recorder` — a relaxed-load no-op
    /// unless the recorder was enabled.
    pub fn start_native_obs(
        set: NativeSet,
        policy: BatchPolicy,
        sched: SchedConfig,
        obs: &Obs,
    ) -> Result<Self, String> {
        if set.is_empty() {
            return Err("native backend set is empty".to_string());
        }
        Self::start_set_obs(move || Ok(set), policy, sched, obs)
    }

    /// Start the executor over any [`BackendSet`] with the default
    /// scheduler configuration. `build` runs on the executor thread, so
    /// non-`Send` sets (PJRT) work; its error is propagated out of
    /// `start_set` via a ready handshake.
    pub fn start_set<V, F>(build: F, policy: BatchPolicy) -> Result<Self, String>
    where
        V: BackendSet + 'static,
        F: FnOnce() -> Result<V, String> + Send + 'static,
    {
        Self::start_set_sched(build, policy, SchedConfig::default())
    }

    /// [`Server::start_set`] with an explicit scheduler configuration.
    pub fn start_set_sched<V, F>(
        build: F,
        policy: BatchPolicy,
        sched: SchedConfig,
    ) -> Result<Self, String>
    where
        V: BackendSet + 'static,
        F: FnOnce() -> Result<V, String> + Send + 'static,
    {
        Self::start_set_obs(build, policy, sched, &Obs::new())
    }

    /// [`Server::start_set_sched`] recording into the given
    /// observability bundle (see [`Server::start_native_obs`]).
    pub fn start_set_obs<V, F>(
        build: F,
        policy: BatchPolicy,
        sched: SchedConfig,
        obs: &Obs,
    ) -> Result<Self, String>
    where
        V: BackendSet + 'static,
        F: FnOnce() -> Result<V, String> + Send + 'static,
    {
        let obs = obs.clone();
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let handle = std::thread::spawn(move || match build() {
            Err(e) => {
                let _ = ready_tx.send(Err(e));
            }
            Ok(set) => {
                let _ = ready_tx.send(Ok(()));
                executor_loop(set, rx, policy, sched, &obs);
            }
        });
        ready_rx
            .recv()
            .map_err(|e| format!("executor died during setup: {e}"))??;
        Ok(Self { tx, handle: Some(handle) })
    }

    /// Cloneable submission handle for concurrent client threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { tx: self.tx.clone() }
    }

    /// Submit a scoring request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<(), String> {
        submit_on(&self.tx, req)
    }

    /// Convenience: synchronous score of one sequence.
    pub fn score(&self, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
        score_on(&self.tx, variant, tokens)
    }

    /// Submit a generation request (non-blocking).
    pub fn submit_generate(&self, req: GenerateRequest) -> Result<(), String> {
        submit_generate_on(&self.tx, req)
    }

    /// Convenience: synchronous greedy generation of one sequence.
    pub fn generate(
        &self,
        variant: &str,
        prompt: Vec<i32>,
        max_new: usize,
        stop: Option<i32>,
    ) -> Result<Generated, String> {
        generate_with_on(&self.tx, variant, prompt, max_new, stop, SamplingParams::greedy())
    }

    /// Convenience: synchronous generation with explicit sampling.
    pub fn generate_with(
        &self,
        variant: &str,
        prompt: Vec<i32>,
        max_new: usize,
        stop: Option<i32>,
        sampling: SamplingParams,
    ) -> Result<Generated, String> {
        generate_with_on(&self.tx, variant, prompt, max_new, stop, sampling)
    }

    /// Stop and collect metrics.
    pub fn shutdown(mut self) -> Metrics {
        let (mtx, mrx) = mpsc::channel();
        let _ = self.tx.send(Job::Shutdown(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

/// One resident variant's queue plus the backend geometry probed at
/// startup, so malformed requests are rejected at enqueue — a doomed
/// request never waits out `max_wait` or occupies a batch slot.
struct VariantQueue {
    name: String,
    seq: usize,
    vocab: usize,
    /// Effective round width (policy clamped to backend batch).
    cap: usize,
    /// Probed once: does the backend implement prefill/decode?
    generation: bool,
    backend_label: String,
    /// Block inventory for paged generation (`None` when the backend
    /// has no paged decode path — generate requests are then rejected).
    pool: Option<BlockPool>,
    /// Max tokens per prefill chunk (from [`SchedConfig`]).
    prefill_chunk: usize,
    /// `Some(k)` when generations on this queue run speculative
    /// draft/verify rounds (speculation resolved and this queue is not
    /// the draft itself) — admission then counts both caches' peak.
    spec_k: Option<usize>,
    /// Queued score requests with submit time and trace-span id.
    q: DynamicBatcher<(Request, Instant, u64)>,
}

impl VariantQueue {
    /// Validate a request against static data: length, token range.
    /// Malformed requests are refused individually with a clear error —
    /// never clipped (wrong-but-plausible logits for PPL clients) and
    /// never allowed near a batch they could fail wholesale.
    fn admit(&self, req: &Request) -> Result<(), (RejectReason, String)> {
        if req.tokens.is_empty() {
            return Err((
                RejectReason::ZeroLength,
                "scoring request needs at least one token".to_string(),
            ));
        }
        if req.tokens.len() > self.seq {
            return Err((
                RejectReason::TooLong,
                format!(
                    "request has {} tokens but backend {} serves seq {}; \
                     split the request instead of truncating",
                    req.tokens.len(),
                    self.backend_label,
                    self.seq
                ),
            ));
        }
        self.check_tokens(&req.tokens).map_err(|e| (RejectReason::BadToken, e))
    }

    /// Validate a generation request: backend support, peak occupancy
    /// versus the block pool's total inventory, token ranges.
    /// Rejections happen before any block is granted.
    fn admit_generate(&self, req: &GenerateRequest) -> Result<(), (RejectReason, String)> {
        if !self.generation || self.pool.is_none() {
            return Err((
                RejectReason::UnknownVariant,
                format!(
                    "backend {} does not support incremental decoding; \
                     use a native variant for generate requests",
                    self.backend_label
                ),
            ));
        }
        if req.prompt.is_empty() {
            return Err((
                RejectReason::ZeroLength,
                "generation needs a non-empty prompt".to_string(),
            ));
        }
        if req.max_new == 0 {
            return Err((RejectReason::ZeroLength, "generation needs max_new >= 1".to_string()));
        }
        // Peak cache occupancy is `prompt + max_new - 1`: the final
        // emitted token is returned to the client, never fed back into
        // the cache. Admission bounds it by the pool's *total* token
        // inventory — the request need not fit right now (preemption
        // frees blocks), it must only be completable alone.
        let peak = req.prompt.len() + req.max_new - 1;
        let budget = self.pool.as_ref().map_or(0, |p| p.total_tokens());
        if peak > budget {
            return Err((
                RejectReason::CachePressure,
                format!(
                    "prompt of {} tokens + max_new {} needs {} kv cache slots but \
                     backend {}'s block pool holds {}; shorten the prompt or the \
                     budget, or raise --kv-blocks",
                    req.prompt.len(),
                    req.max_new,
                    peak,
                    self.backend_label,
                    budget
                ),
            ));
        }
        // Speculating doubles the cache footprint: the target's peak is
        // unchanged (verify never absorbs past `prompt + max_new − 1` —
        // the draft length is capped by the emission budget), but the
        // draft cache trails one token behind it, and both draw blocks
        // from this variant's pool. Block granularity makes the two
        // peaks round up independently.
        if let Some(k) = self.spec_k {
            let page = self.pool.as_ref().map_or(1, |p| p.page_size());
            let total = self.pool.as_ref().map_or(0, |p| p.total_blocks());
            let target_blocks = crate::sched::blocks_for(peak, page);
            let draft_blocks = crate::sched::blocks_for(peak.saturating_sub(1), page);
            if target_blocks + draft_blocks > total {
                return Err((
                    RejectReason::CachePressure,
                    format!(
                        "speculative generation (k={k}) needs {} kv blocks at peak \
                         ({target_blocks} target + {draft_blocks} draft) but backend {}'s \
                         block pool holds {total}; shorten the prompt or the budget, \
                         raise --kv-blocks, or drop --speculate",
                        target_blocks + draft_blocks,
                        self.backend_label,
                    ),
                ));
            }
        }
        self.check_tokens(&req.prompt).map_err(|e| (RejectReason::BadToken, e))?;
        if let Some(stop) = req.stop {
            self.check_tokens(&[stop])
                .map_err(|e| (RejectReason::BadToken, format!("stop token invalid: {e}")))?;
        }
        Ok(())
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<(), String> {
        crate::model::tokens_in_vocab(tokens, self.vocab)
    }
}

fn executor_loop<V: BackendSet>(
    set: V,
    rx: mpsc::Receiver<Job>,
    policy: BatchPolicy,
    sched: SchedConfig,
    obs: &Obs,
) {
    let tel = Telemetry {
        m: ServingMetrics::new(&obs.registry),
        tr: obs.recorder.handle("executor"),
    };
    // Per-variant queue, its max_batch clamped to the backend's actual
    // batch capacity so one flush never overflows one forward call.
    let mut queues: Vec<VariantQueue> = Vec::new();
    for name in set.names() {
        let mut cap = policy.max_batch.max(1);
        let (mut seq, mut vocab, mut generation) = (0, 0, false);
        let mut backend_label = String::new();
        let mut geometry: Option<(usize, usize)> = None;
        let mut kernel_stats = None;
        set.run(&name, &mut |backend| {
            cap = cap.min(backend.batch()).max(1);
            seq = backend.seq();
            vocab = backend.vocab();
            generation = backend.supports_generation();
            backend_label = backend.name().to_string();
            geometry = backend.kv_block_geometry();
            kernel_stats = backend.kernel_stats();
        });
        // Kernel-path telemetry is a static property of the resident
        // model — probed once, exported per variant, and aggregated
        // into the report's fast-mode dense-fallback warning.
        if let Some(stats) = kernel_stats {
            tel.m.record_kernel_path(&name, &stats);
            tel.tr.record(TraceEvent::KernelPath {
                variant: name.clone(),
                mode: stats.mode.as_str(),
                packed: stats.packed_linears,
                dense_fallbacks: stats.dense_fallbacks,
            });
        }
        // Mint the block pool for paged generation: the configured
        // count, or auto-sized to match the old contiguous capacity
        // (`cap` sequences of `seq` tokens each).
        let pool = match geometry {
            Some((nl, w)) if generation => {
                Some(BlockPool::new(nl, w, sched.page_size, sched.pool_blocks(cap, seq)))
            }
            _ => None,
        };
        let q = DynamicBatcher::new(BatchPolicy { max_batch: cap, ..policy });
        queues.push(VariantQueue {
            name,
            seq,
            vocab,
            cap,
            generation,
            backend_label,
            pool,
            prefill_chunk: sched.prefill_chunk,
            spec_k: None,
            q,
        });
    }
    for vq in &queues {
        if let Some(pool) = &vq.pool {
            tel.m.add_kv_blocks_total(pool.total_blocks() as u64);
        }
    }
    // Resolve speculation once against the resident set. A failed
    // resolution is kept, not swallowed: every generate request is then
    // rejected with the resolution error, so a typo'd draft name can
    // never silently serve non-speculative rounds.
    let spec = resolve_spec(&sched, &queues);
    if let Ok(Some(sp)) = &spec {
        for (qi, vq) in queues.iter_mut().enumerate() {
            if qi != sp.draft_qi && vq.pool.is_some() {
                vq.spec_k = Some(sp.k);
            }
        }
    }
    let mut active: Vec<SeqState> = Vec::new();
    let mut next_seq_id: u64 = 0;
    loop {
        // Wait bounded by the nearest batch deadline — or not at all
        // while generations are active: scheduling rounds are the idle
        // work.
        let timeout = if active.is_empty() {
            queues
                .iter()
                .filter_map(|vq| vq.q.time_to_deadline(Instant::now()))
                .min()
                .unwrap_or(Duration::from_millis(50))
        } else {
            Duration::ZERO
        };
        let first = match rx.recv_timeout(timeout) {
            Ok(job) => Some(job),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        // Admit the received job plus everything already queued behind
        // it (non-blocking drain): a burst reaches the batchers — and
        // the running generation rounds — in one loop turn.
        for job in first.into_iter().chain(std::iter::from_fn(|| rx.try_recv().ok())) {
            let flow =
                handle_job(job, &set, &mut queues, &mut active, &mut next_seq_id, &spec, &tel);
            match flow {
                Flow::Continue => {}
                Flow::Stop => return,
            }
        }
        let now = Instant::now();
        for vq in queues.iter_mut() {
            while vq.q.ready(now) {
                dispatch(&set, &vq.name, vq.q.take_batch(), &tel);
            }
        }
        // One continuous-batching round per loop turn keeps generation
        // throughput high while queued scoring work still gets serviced
        // between rounds.
        generation_round(&set, &mut queues, &mut active, spec_of(&spec), &tel);
    }
}

/// Speculation resolved against the resident set at executor start.
#[derive(Debug, Clone, Copy)]
struct SpecResolved {
    /// Index of the draft variant's queue in the executor's `queues`.
    draft_qi: usize,
    /// Draft tokens proposed per draft/verify round.
    k: usize,
}

/// The round-time view of the resolution: `None` both when speculation
/// is off and when it failed to resolve (no sequence was admitted).
fn spec_of(spec: &Result<Option<SpecResolved>, String>) -> Option<SpecResolved> {
    spec.as_ref().ok().copied().flatten()
}

/// Resolve `--speculate` against the probed queues: the draft variant
/// must be resident with a paged generation path, and its KV geometry
/// must match every pooled variant's — draft caches are granted blocks
/// from the *target* variant's pool, so the shapes have to line up.
fn resolve_spec(
    sched: &SchedConfig,
    queues: &[VariantQueue],
) -> Result<Option<SpecResolved>, String> {
    let Some(cfg) = &sched.speculate else {
        return Ok(None);
    };
    let Some(draft_qi) = queues.iter().position(|vq| vq.name == cfg.draft) else {
        return Err(format!("--speculate: draft variant {} is not resident", cfg.draft));
    };
    let Some(dpool) = &queues[draft_qi].pool else {
        return Err(format!(
            "--speculate: draft variant {} does not support paged generation",
            cfg.draft
        ));
    };
    for vq in queues {
        if let Some(pool) = &vq.pool {
            if pool.geometry() != dpool.geometry() {
                return Err(format!(
                    "--speculate: draft variant {} kv geometry {:?} does not match \
                     variant {} geometry {:?}; draft and target must share the model shape",
                    cfg.draft,
                    dpool.geometry(),
                    vq.name,
                    pool.geometry(),
                ));
            }
        }
    }
    Ok(Some(SpecResolved { draft_qi, k: cfg.k }))
}

enum Flow {
    Continue,
    Stop,
}

/// Admit one incoming job: enqueue/reject a score request, admit/reject
/// a generate request into the active set, or drain-and-stop on
/// shutdown.
fn handle_job<V: BackendSet>(
    job: Job,
    set: &V,
    queues: &mut [VariantQueue],
    active: &mut Vec<SeqState>,
    next_seq_id: &mut u64,
    spec: &Result<Option<SpecResolved>, String>,
    tel: &Telemetry,
) -> Flow {
    let reject_trace = |variant: &str, reason: &'static str| {
        if tel.tr.enabled() {
            tel.tr.record(TraceEvent::RequestRejected { variant: variant.to_string(), reason });
        }
    };
    match job {
        Job::Score(req, t0) => {
            match queues.iter_mut().find(|vq| vq.name == req.variant) {
                Some(vq) => match vq.admit(&req) {
                    Ok(()) => {
                        *next_seq_id += 1;
                        let id = *next_seq_id;
                        if tel.tr.enabled() {
                            tel.tr.record(TraceEvent::RequestAdmitted {
                                id,
                                variant: req.variant.clone(),
                                kind: RequestKind::Score,
                                tokens: req.tokens.len(),
                            });
                        }
                        vq.q.push((req, t0, id));
                    }
                    Err((reason, e)) => {
                        tel.m.record_rejection(reason);
                        reject_trace(&req.variant, reason.as_str());
                        let _ = req.reply.send(Response { logits: Err(e) });
                    }
                },
                None => {
                    tel.m.record_rejection(RejectReason::UnknownVariant);
                    reject_trace(&req.variant, RejectReason::UnknownVariant.as_str());
                    let _ = req.reply.send(Response {
                        logits: Err(format!("variant {} not resident", req.variant)),
                    });
                }
            }
            Flow::Continue
        }
        Job::Generate(req, t0) => {
            let Some(idx) = queues.iter().position(|vq| vq.name == req.variant) else {
                tel.m.record_rejection(RejectReason::UnknownVariant);
                reject_trace(&req.variant, RejectReason::UnknownVariant.as_str());
                let _ = req.reply.send(GenerateResponse {
                    result: Err(format!("variant {} not resident", req.variant)),
                });
                return Flow::Continue;
            };
            // Speculation that failed to resolve refuses every generate
            // loudly: silently serving non-speculative rounds would make
            // a typo'd --speculate indistinguishable from a working one.
            if let Err(e) = spec {
                tel.m.record_rejection(RejectReason::UnknownVariant);
                reject_trace(&req.variant, RejectReason::UnknownVariant.as_str());
                let _ = req.reply.send(GenerateResponse { result: Err(e.clone()) });
                return Flow::Continue;
            }
            if let Err((reason, e)) = queues[idx].admit_generate(&req) {
                tel.m.record_rejection(reason);
                reject_trace(&req.variant, reason.as_str());
                let _ = req.reply.send(GenerateResponse { result: Err(e) });
                return Flow::Continue;
            }
            // Open the zero-capacity paged generation now; blocks are
            // granted by the scheduling rounds as the sequence runs.
            let page = queues[idx].pool.as_ref().map_or(1, |p| p.page_size());
            // A speculative target also opens its draft-variant cache —
            // same page size, blocks granted from the target's pool.
            let mut draft: Option<Generation> = None;
            if let Ok(Some(sp)) = spec {
                if sp.draft_qi != idx {
                    let mut dres: Option<Result<Generation, String>> = None;
                    set.run(&queues[sp.draft_qi].name, &mut |backend| {
                        dres = Some(backend.start_paged_generation(page));
                    });
                    match dres {
                        Some(Ok(g)) => draft = Some(g),
                        Some(Err(e)) => {
                            tel.m.record_generation_failure();
                            reject_trace(&req.variant, "generation_start_failed");
                            let _ = req.reply.send(GenerateResponse { result: Err(e) });
                            return Flow::Continue;
                        }
                        None => {
                            tel.m.record_rejection(RejectReason::UnknownVariant);
                            reject_trace(&req.variant, RejectReason::UnknownVariant.as_str());
                            let _ = req.reply.send(GenerateResponse {
                                result: Err(format!(
                                    "draft variant {} not resident",
                                    queues[sp.draft_qi].name
                                )),
                            });
                            return Flow::Continue;
                        }
                    }
                }
            }
            let mut res: Option<Result<Generation, String>> = None;
            set.run(&queues[idx].name, &mut |backend| {
                res = Some(backend.start_paged_generation(page));
            });
            match res {
                Some(Ok(gen)) => {
                    *next_seq_id += 1;
                    let id = *next_seq_id;
                    if tel.tr.enabled() {
                        tel.tr.record(TraceEvent::RequestAdmitted {
                            id,
                            variant: req.variant.clone(),
                            kind: RequestKind::Generate,
                            tokens: req.prompt.len(),
                        });
                    }
                    active.push(SeqState {
                        id,
                        variant_idx: idx,
                        preempted: false,
                        prompt: req.prompt,
                        produced: Vec::new(),
                        max_new: req.max_new,
                        stop: req.stop,
                        sampler: Sampler::new(&req.sampling),
                        gen,
                        draft,
                        reply: req.reply,
                        stream: req.stream,
                        t0,
                    });
                }
                Some(Err(e)) => {
                    tel.m.record_generation_failure();
                    reject_trace(&req.variant, "generation_start_failed");
                    let _ = req.reply.send(GenerateResponse { result: Err(e) });
                }
                None => {
                    tel.m.record_rejection(RejectReason::UnknownVariant);
                    reject_trace(&req.variant, RejectReason::UnknownVariant.as_str());
                    let _ = req.reply.send(GenerateResponse {
                        result: Err(format!("variant {} not resident", req.variant)),
                    });
                }
            }
            Flow::Continue
        }
        Job::Shutdown(mtx) => {
            // Drain everything before stopping: queued score batches,
            // then active generations to completion.
            for vq in queues.iter_mut() {
                while !vq.q.is_empty() {
                    dispatch(set, &vq.name, vq.q.take_batch(), tel);
                }
            }
            while !active.is_empty() {
                generation_round(set, queues, active, spec_of(spec), tel);
            }
            let _ = mtx.send(tel.m.snapshot());
            Flow::Stop
        }
    }
}

/// Preempt the youngest block-holding member past `i` — reclaiming its
/// target *and* draft caches, so a victim never strands draft blocks —
/// and return the blocks to the pool. `Ok(false)` when no member past
/// `i` holds blocks (only older peers do — the requester must defer).
fn preempt_youngest(
    backend: &dyn Backend,
    draft_backend: Option<&dyn Backend>,
    pool: &mut BlockPool,
    members: &mut [SeqState],
    i: usize,
    tel: &Telemetry,
) -> Result<bool, String> {
    // Members are FIFO-sorted, so the youngest victim is the highest
    // index past `i` still holding blocks in either cache.
    let Some(j) = (i + 1..members.len())
        .rev()
        .find(|&j| members[j].gen.capacity() > 0 || members[j].draft_capacity() > 0)
    else {
        return Ok(false);
    };
    let cached = members[j].gen.len() + members[j].draft_len();
    let mut blocks = backend.reclaim_kv_blocks(&mut members[j].gen)?;
    if let (Some(db), Some(dgen)) = (draft_backend, members[j].draft.as_mut()) {
        blocks.extend(db.reclaim_kv_blocks(dgen)?);
    }
    tel.m.record_preemption(blocks.len() as u64, cached as u64);
    members[j].preempted = true;
    tel.tr.record(TraceEvent::Preempted { id: members[j].id, blocks: blocks.len(), cached });
    for b in blocks {
        pool.release(b);
    }
    Ok(true)
}

/// Grow `members[i]`'s cache to absorb `extra` more tokens: grant free
/// blocks lowest-id-first; when the pool runs dry, preempt the
/// *youngest* block-holding member younger than `members[i]`
/// (recompute-on-resume). Returns `Ok(false)` when capacity cannot be
/// assured this round (only older members hold the blocks — the
/// requester defers and retries once they complete or release).
fn ensure_capacity(
    backend: &dyn Backend,
    draft_backend: Option<&dyn Backend>,
    pool: &mut BlockPool,
    members: &mut [SeqState],
    i: usize,
    extra: usize,
    tel: &Telemetry,
) -> Result<bool, String> {
    let need = members[i].gen.len() + extra;
    let mut granted = 0usize;
    while members[i].gen.capacity() < need {
        if let Some(block) = pool.alloc() {
            backend.grant_kv_block(&mut members[i].gen, block)?;
            granted += 1;
            continue;
        }
        if !preempt_youngest(backend, draft_backend, pool, members, i, tel)? {
            if granted > 0 {
                tel.tr.record(TraceEvent::BlocksGranted { id: members[i].id, blocks: granted });
            }
            return Ok(false);
        }
    }
    if granted > 0 {
        tel.tr.record(TraceEvent::BlocksGranted { id: members[i].id, blocks: granted });
    }
    if members[i].preempted {
        members[i].preempted = false;
        tel.tr.record(TraceEvent::Resumed { id: members[i].id });
    }
    Ok(true)
}

/// [`ensure_capacity`] for the *draft* cache of a speculative member:
/// same pool, same youngest-first preemption, blocks granted through
/// the draft backend so the geometry check runs against the right
/// cache.
fn ensure_draft_capacity(
    backend: &dyn Backend,
    draft_backend: &dyn Backend,
    pool: &mut BlockPool,
    members: &mut [SeqState],
    i: usize,
    extra: usize,
    tel: &Telemetry,
) -> Result<bool, String> {
    let need = members[i].draft_len() + extra;
    let mut granted = 0usize;
    while members[i].draft_capacity() < need {
        if let Some(block) = pool.alloc() {
            let dgen = members[i].draft.as_mut().expect("speculative member has a draft cache");
            draft_backend.grant_kv_block(dgen, block)?;
            granted += 1;
            continue;
        }
        if !preempt_youngest(backend, Some(draft_backend), pool, members, i, tel)? {
            if granted > 0 {
                tel.tr.record(TraceEvent::BlocksGranted { id: members[i].id, blocks: granted });
            }
            return Ok(false);
        }
    }
    if granted > 0 {
        tel.tr.record(TraceEvent::BlocksGranted { id: members[i].id, blocks: granted });
    }
    Ok(true)
}

/// Return every block of `members[i]` to the pool (completion/failure)
/// — the draft cache included, when the member has one.
fn reclaim_to_pool(
    backend: &dyn Backend,
    draft_backend: Option<&dyn Backend>,
    pool: &mut BlockPool,
    members: &mut [SeqState],
    i: usize,
) {
    if let Ok(blocks) = backend.reclaim_kv_blocks(&mut members[i].gen) {
        for b in blocks {
            pool.release(b);
        }
    }
    if let (Some(db), Some(dgen)) = (draft_backend, members[i].draft.as_mut()) {
        if let Ok(blocks) = db.reclaim_kv_blocks(dgen) {
            for b in blocks {
                pool.release(b);
            }
        }
    }
}

/// Sample the next token for `s` from `logits` (the last fed
/// position's). Returns `true` when the sequence is finished — stop
/// token picked (not emitted) or `max_new` reached. Emitted tokens
/// stream out exactly once, at pick time.
fn apply_pick(s: &mut SeqState, logits: &[f32]) -> bool {
    let tok = s.sampler.pick(logits);
    if s.stop == Some(tok) {
        return true;
    }
    s.produced.push(tok);
    if let Some(stream) = &s.stream {
        let _ = stream.send(tok);
    }
    s.produced.len() >= s.max_new
}

/// One continuous-batching round per variant: compose the round
/// (deterministic FIFO+budget), assure block capacity (preempting
/// youngest-first under pressure), step the decode group through
/// `decode_batch`, run at most one prefill chunk, then sample and
/// complete sequences whose feed caught up.
fn generation_round<V: BackendSet>(
    set: &V,
    queues: &mut [VariantQueue],
    active: &mut Vec<SeqState>,
    spec: Option<SpecResolved>,
    tel: &Telemetry,
) {
    if active.is_empty() {
        return;
    }
    for qi in 0..queues.len() {
        // Rounds on every queue but the draft's own run speculatively:
        // resolve the draft queue's name before borrowing this one.
        let spec_draft: Option<(String, usize)> = match spec {
            Some(sp) if sp.draft_qi != qi => Some((queues[sp.draft_qi].name.clone(), sp.k)),
            _ => None,
        };
        let vq = &mut queues[qi];
        // Extract this variant's sequences and restore admission order
        // (ids are monotone, so the sort is the FIFO ground truth no
        // matter how `active` got shuffled).
        let mut members: Vec<SeqState> = Vec::new();
        let mut rest: Vec<SeqState> = Vec::with_capacity(active.len());
        for s in active.drain(..) {
            if s.variant_idx == qi {
                members.push(s);
            } else {
                rest.push(s);
            }
        }
        active.append(&mut rest);
        if members.is_empty() {
            continue;
        }
        members.sort_by_key(|s| s.id);
        let mut fates: Vec<Fate> = members.iter().map(|_| Fate::Active).collect();
        let Some(mut pool) = vq.pool.take() else {
            // Unreachable via admission (generate requires a pool), but
            // never loop forever on it: fail the stranded sequences.
            for f in fates.iter_mut() {
                *f = Fate::Failed(format!("variant {} has no paged kv pool", vq.name));
            }
            settle_round(members, fates, active, tel);
            continue;
        };
        let plan = {
            let descs: Vec<crate::sched::SeqDesc> = members
                .iter()
                .map(|s| crate::sched::SeqDesc { id: s.id, pending: s.pending() })
                .collect();
            compose_round(&descs, vq.cap, vq.prefill_chunk)
        };
        let prefill_chunk = vq.prefill_chunk;
        let found = match &spec_draft {
            None => set.run(&vq.name, &mut |backend| {
                run_variant_round(
                    backend,
                    None,
                    &vq.name,
                    &plan,
                    &mut pool,
                    &mut members,
                    &mut fates,
                    prefill_chunk,
                    tel,
                );
            }),
            Some((draft_name, k)) => {
                // Nested lookups hand the round both backends at once;
                // `run` takes `&self`, so the borrows compose.
                let mut draft_found = false;
                let target_found = set.run(&vq.name, &mut |backend| {
                    draft_found = set.run(draft_name, &mut |draft| {
                        run_variant_round(
                            backend,
                            Some((draft, *k)),
                            &vq.name,
                            &plan,
                            &mut pool,
                            &mut members,
                            &mut fates,
                            prefill_chunk,
                            tel,
                        );
                    });
                });
                target_found && draft_found
            }
        };
        if !found {
            for f in fates.iter_mut() {
                if matches!(f, Fate::Active) {
                    *f = Fate::Failed(format!("variant {} not resident", vq.name));
                }
            }
        }
        tel.m.bump_kv_blocks_peak(pool.peak() as u64);
        vq.pool = Some(pool);
        settle_round(members, fates, active, tel);
    }
}

/// Execute one composed round against the backend (single `run`
/// callback: grants, preemptions, decode batch or speculative
/// draft/verify steps, prefill chunk, picks). `spec` carries the draft
/// backend and per-round draft length when this variant's rounds
/// speculate.
#[allow(clippy::too_many_arguments)]
fn run_variant_round(
    backend: &dyn Backend,
    spec: Option<(&dyn Backend, usize)>,
    variant: &str,
    plan: &crate::sched::RoundPlan,
    pool: &mut BlockPool,
    members: &mut [SeqState],
    fates: &mut [Fate],
    prefill_chunk: usize,
    tel: &Telemetry,
) {
    let draft_backend = spec.map(|(b, _)| b);
    // --- Decode group: assure capacity in FIFO order. A member whose
    // pending changed (preempted by an older peer's grant) drops out of
    // this round; one that cannot get a block defers to the next.
    let mut decode_idx: Vec<usize> = Vec::new();
    for &id in &plan.decode {
        let Some(i) = members.iter().position(|s| s.id == id) else {
            continue;
        };
        if !matches!(fates[i], Fate::Active) || members[i].pending() != 1 {
            continue;
        }
        // Speculative members run their own draft/verify step; a member
        // with one token left decodes plainly instead (a draft round
        // cannot beat a single forward).
        if let Some((db, k)) = spec {
            if members[i].draft.is_some() && members[i].produced.len() + 1 < members[i].max_new {
                spec_step(backend, db, k, pool, members, i, fates, prefill_chunk, tel);
                continue;
            }
        }
        match ensure_capacity(backend, draft_backend, pool, members, i, 1, tel) {
            Ok(true) => decode_idx.push(i),
            Ok(false) => {}
            Err(e) => {
                reclaim_to_pool(backend, draft_backend, pool, members, i);
                fates[i] = Fate::Failed(e);
            }
        }
    }
    // Preemption during later assurance may have grown an earlier
    // member's pending past 1 — drop it; it prefills next round.
    decode_idx.retain(|&i| members[i].pending() == 1);
    if !decode_idx.is_empty() {
        let mut tokens: Vec<i32> = Vec::with_capacity(decode_idx.len());
        for &i in &decode_idx {
            tokens.push(members[i].feed_at(members[i].gen.len()));
        }
        let t_exec = Instant::now();
        let res = {
            // `iter_mut` hands out disjoint `&mut` rows; `decode_idx`
            // is ascending, so the filtered order matches `tokens`.
            let gens: Vec<&mut Generation> = members
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| decode_idx.binary_search(i).is_ok())
                .map(|(_, s)| &mut s.gen)
                .collect();
            backend.decode_batch(gens, &tokens)
        };
        let exec_elapsed = t_exec.elapsed();
        match res {
            Ok(rows) => {
                let stepped: Vec<bool> = rows.iter().map(|r| r.is_ok()).collect();
                let seqs = stepped.iter().filter(|&&ok| ok).count();
                let cache_tokens: u64 = decode_idx
                    .iter()
                    .zip(&stepped)
                    .filter(|(_, &ok)| ok)
                    .map(|(&i, _)| members[i].gen.len() as u64)
                    .sum();
                let mut emitted = 0u64;
                for (&i, row) in decode_idx.iter().zip(rows) {
                    match row {
                        Ok(logits) => {
                            let before = members[i].produced.len();
                            let done = apply_pick(&mut members[i], &logits);
                            emitted += (members[i].produced.len() - before) as u64;
                            if done {
                                reclaim_to_pool(backend, draft_backend, pool, members, i);
                                fates[i] = Fate::Done;
                            }
                        }
                        Err(e) => {
                            reclaim_to_pool(backend, draft_backend, pool, members, i);
                            fates[i] = Fate::Failed(e);
                        }
                    }
                }
                if seqs > 0 {
                    tel.m.record_decode(seqs, emitted, cache_tokens, exec_elapsed);
                    if tel.tr.enabled() {
                        tel.tr.record(TraceEvent::DecodeRound {
                            variant: variant.to_string(),
                            seqs,
                            dur_us: exec_elapsed.as_micros() as u64,
                        });
                    }
                }
            }
            Err(e) => {
                // Call-level backend error: fail the whole group rather
                // than looping forever.
                for &i in &decode_idx {
                    reclaim_to_pool(backend, draft_backend, pool, members, i);
                    fates[i] = Fate::Failed(e.clone());
                }
            }
        }
    }
    // --- One prefill chunk: the oldest member still feeding. Re-derive
    // it (the composed target may have been preempted or failed above;
    // pending also moves), keeping the chunk bound from the plan.
    let Some((_, chunk_max)) = plan.prefill else {
        return;
    };
    let mut next_prefill = None;
    for (i, s) in members.iter().enumerate() {
        if matches!(fates[i], Fate::Active) && s.pending() > 1 {
            next_prefill = Some(i);
            break;
        }
    }
    let Some(i) = next_prefill else { return };
    let chunk_len = members[i].pending().min(chunk_max.max(1));
    match ensure_capacity(backend, draft_backend, pool, members, i, chunk_len, tel) {
        Ok(true) => {}
        Ok(false) => return,
        Err(e) => {
            reclaim_to_pool(backend, draft_backend, pool, members, i);
            fates[i] = Fate::Failed(e);
            return;
        }
    }
    let start = members[i].gen.len();
    let tokens: Vec<i32> = (start..start + chunk_len).map(|p| members[i].feed_at(p)).collect();
    let t_exec = Instant::now();
    let res = backend.prefill_chunk(&mut members[i].gen, &tokens);
    let exec_elapsed = t_exec.elapsed();
    tel.m.record_prefill(chunk_len as u64, exec_elapsed);
    tel.tr.record(TraceEvent::PrefillChunk {
        id: members[i].id,
        tokens: chunk_len,
        cached: members[i].gen.len(),
        dur_us: exec_elapsed.as_micros() as u64,
    });
    match res {
        Ok(logits) => {
            // Chunk reached the end of the feed stream → a pick is due
            // from the last position's logits.
            if members[i].pending() == 0 && apply_pick(&mut members[i], &logits) {
                reclaim_to_pool(backend, draft_backend, pool, members, i);
                fates[i] = Fate::Done;
                return;
            }
        }
        Err(e) => {
            reclaim_to_pool(backend, draft_backend, pool, members, i);
            fates[i] = Fate::Failed(e);
            return;
        }
    }
    // A speculative member rides a draft catch-up chunk along with its
    // target prefill, so the draft cache is warm (one behind the feed)
    // by the time the sequence turns decode-ready.
    if let Some(db) = draft_backend {
        if members[i].draft.is_some() {
            draft_catchup(backend, db, pool, members, i, fates, chunk_max, tel);
        }
    }
}

/// Absorb up to `chunk_max` feed tokens into `members[i]`'s draft
/// cache, stopping one short of the feed end — the pending token is
/// only ever fed by a draft *decode*, mirroring the target's own
/// prefill discipline. Returns `false` when the chunk could not run
/// this round (capacity deferral or failure — fates already set).
#[allow(clippy::too_many_arguments)]
fn draft_catchup(
    backend: &dyn Backend,
    draft_backend: &dyn Backend,
    pool: &mut BlockPool,
    members: &mut [SeqState],
    i: usize,
    fates: &mut [Fate],
    chunk_max: usize,
    tel: &Telemetry,
) -> bool {
    let feed_len = members[i].prompt.len() + members[i].produced.len();
    let start = members[i].draft_len();
    let lag = feed_len.saturating_sub(start);
    debug_assert!(lag >= 1, "draft cache may never absorb the pending feed token");
    if lag <= 1 {
        return true;
    }
    let chunk_len = (lag - 1).min(chunk_max.max(1));
    match ensure_draft_capacity(backend, draft_backend, pool, members, i, chunk_len, tel) {
        Ok(true) => {}
        Ok(false) => return false,
        Err(e) => {
            reclaim_to_pool(backend, Some(draft_backend), pool, members, i);
            fates[i] = Fate::Failed(e);
            return false;
        }
    }
    let tokens: Vec<i32> = (start..start + chunk_len).map(|p| members[i].feed_at(p)).collect();
    let t_exec = Instant::now();
    let dgen = members[i].draft.as_mut().expect("speculative member has a draft cache");
    let res = draft_backend.prefill_chunk(dgen, &tokens);
    let exec_elapsed = t_exec.elapsed();
    tel.m.record_prefill(chunk_len as u64, exec_elapsed);
    tel.tr.record(TraceEvent::PrefillChunk {
        id: members[i].id,
        tokens: chunk_len,
        cached: members[i].draft_len(),
        dur_us: exec_elapsed.as_micros() as u64,
    });
    match res {
        Ok(_) => true,
        Err(e) => {
            reclaim_to_pool(backend, Some(draft_backend), pool, members, i);
            fates[i] = Fate::Failed(e);
            false
        }
    }
}

/// One speculative draft/verify step for decode-ready member `i`.
///
/// The draft variant proposes up to `k` greedy tokens beyond the
/// pending one; the target absorbs the pending token plus every draft
/// in a single [`Backend::verify_draft`] forward (`k_eff + 1` logit
/// rows) and the member's own sampler replays its picks against those
/// rows — exactly one draw per emitted token, in stream order, so the
/// emitted sequence is token-for-token identical to plain decode. The
/// first mismatching row's pick *is* the correction token; positions
/// past the last kept token roll back bit-exactly in both caches and
/// freed tail blocks return to the pool.
#[allow(clippy::too_many_arguments)]
fn spec_step(
    backend: &dyn Backend,
    draft_backend: &dyn Backend,
    k: usize,
    pool: &mut BlockPool,
    members: &mut [SeqState],
    i: usize,
    fates: &mut [Fate],
    prefill_chunk: usize,
    tel: &Telemetry,
) {
    // Catch the draft cache up to one-behind the feed stream (bounded
    // chunk per round; recompute-on-resume after preemption lands here
    // too). Still behind afterwards → draft again next round.
    if !draft_catchup(backend, draft_backend, pool, members, i, fates, prefill_chunk, tel) {
        return;
    }
    let feed_len = members[i].prompt.len() + members[i].produced.len();
    if members[i].draft_len() + 1 < feed_len {
        return;
    }
    // Never draft past the emission budget: the round emits at most
    // `k_eff` accepted drafts plus one pick, so the verify forward
    // never absorbs beyond the plain-decode peak occupancy.
    let remaining = members[i].max_new - members[i].produced.len();
    let k_eff = k.min(remaining - 1);
    debug_assert!(k_eff >= 1, "caller guarantees a spec member has at least 2 tokens to go");
    // Assure BOTH caches before any forward runs: a capacity deferral
    // must leave no half-drafted state behind.
    match ensure_capacity(backend, Some(draft_backend), pool, members, i, k_eff + 1, tel) {
        Ok(true) => {}
        Ok(false) => return,
        Err(e) => {
            reclaim_to_pool(backend, Some(draft_backend), pool, members, i);
            fates[i] = Fate::Failed(e);
            return;
        }
    }
    match ensure_draft_capacity(backend, draft_backend, pool, members, i, k_eff, tel) {
        Ok(true) => {}
        Ok(false) => return,
        Err(e) => {
            reclaim_to_pool(backend, Some(draft_backend), pool, members, i);
            fates[i] = Fate::Failed(e);
            return;
        }
    }
    // Draft k_eff tokens greedily off the draft cache, feeding the
    // pending token first, then each proposal back in.
    let base = members[i].gen.len();
    let t_draft = Instant::now();
    let mut drafted: Vec<i32> = Vec::with_capacity(k_eff);
    let mut feed = members[i].feed_at(base);
    for _ in 0..k_eff {
        let dgen = members[i].draft.as_mut().expect("speculative member has a draft cache");
        match draft_backend.decode(dgen, feed) {
            Ok(logits) => {
                let d = greedy_argmax(&logits);
                drafted.push(d);
                feed = d;
            }
            Err(e) => {
                reclaim_to_pool(backend, Some(draft_backend), pool, members, i);
                fates[i] = Fate::Failed(e);
                return;
            }
        }
    }
    let draft_elapsed = t_draft.elapsed();
    // Verify: one target forward absorbs the pending token plus every
    // draft and returns one logit row per absorbed position.
    let mut verify_tokens = Vec::with_capacity(k_eff + 1);
    verify_tokens.push(members[i].feed_at(base));
    verify_tokens.extend_from_slice(&drafted);
    let t_verify = Instant::now();
    let rows = match backend.verify_draft(&mut members[i].gen, &verify_tokens) {
        Ok(rows) => rows,
        Err(e) => {
            reclaim_to_pool(backend, Some(draft_backend), pool, members, i);
            fates[i] = Fate::Failed(e);
            return;
        }
    };
    let verify_elapsed = t_verify.elapsed();
    let vocab = rows.len() / verify_tokens.len();
    // Acceptance: replay the member's own sampler row by row. Row `j`
    // holds the target's distribution after absorbing `verify_tokens[j]`
    // — exactly what plain decode would have sampled from — and its
    // pick is compared against the next drafted token. A mismatch
    // emits the pick itself and stops; surviving all `k_eff` rows
    // earns a bonus pick from the final row.
    let before = members[i].produced.len();
    let mut accepted = 0usize;
    let mut finished = false;
    for (j, row) in rows.chunks_exact(vocab).enumerate() {
        let emitted_before = members[i].produced.len();
        let done = apply_pick(&mut members[i], row);
        let pick = members[i].produced.get(emitted_before).copied();
        let matched = j < k_eff && pick == Some(drafted[j]);
        if matched {
            accepted += 1;
        }
        if done {
            finished = true;
            break;
        }
        if !matched {
            break;
        }
    }
    let emitted = members[i].produced.len() - before;
    tel.m.record_spec_round(
        k_eff as u64,
        accepted as u64,
        emitted as u64,
        draft_elapsed,
        verify_elapsed,
    );
    if tel.tr.enabled() {
        tel.tr.record(TraceEvent::SpecRound {
            id: members[i].id,
            drafted: k_eff,
            accepted,
            emitted,
            draft_us: draft_elapsed.as_micros() as u64,
            verify_us: verify_elapsed.as_micros() as u64,
        });
    }
    if finished {
        reclaim_to_pool(backend, Some(draft_backend), pool, members, i);
        fates[i] = Fate::Done;
        return;
    }
    // Roll both caches back to the last kept position and release the
    // freed tail blocks; the round's final pick becomes the pending
    // token the next round absorbs.
    let keep = base + 1 + accepted;
    match backend.rollback_generation(&mut members[i].gen, keep) {
        Ok(freed) => {
            for b in freed {
                pool.release(b);
            }
        }
        Err(e) => {
            reclaim_to_pool(backend, Some(draft_backend), pool, members, i);
            fates[i] = Fate::Failed(e);
            return;
        }
    }
    let draft_keep = members[i].draft_len().min(keep);
    let dgen = members[i].draft.as_mut().expect("speculative member has a draft cache");
    match draft_backend.rollback_generation(dgen, draft_keep) {
        Ok(freed) => {
            for b in freed {
                pool.release(b);
            }
        }
        Err(e) => {
            reclaim_to_pool(backend, Some(draft_backend), pool, members, i);
            fates[i] = Fate::Failed(e);
        }
    }
}

/// Apply round fates: reply to completed/failed sequences, return
/// survivors to the active set.
fn settle_round(
    members: Vec<SeqState>,
    fates: Vec<Fate>,
    active: &mut Vec<SeqState>,
    tel: &Telemetry,
) {
    for (s, fate) in members.into_iter().zip(fates) {
        match fate {
            Fate::Active => active.push(s),
            Fate::Done => {
                tel.m.record_generation(s.produced.len() as u64, s.t0.elapsed());
                tel.tr
                    .record(TraceEvent::RequestCompleted { id: s.id, produced: s.produced.len() });
                let _ = s.reply.send(GenerateResponse {
                    result: Ok(Generated { tokens: s.produced, prompt_len: s.prompt.len() }),
                });
            }
            Fate::Failed(e) => {
                tel.m.record_generation_failure();
                if tel.tr.enabled() {
                    tel.tr.record(TraceEvent::RequestFailed { id: s.id, error: e.clone() });
                }
                let _ = s.reply.send(GenerateResponse { result: Err(e) });
            }
        }
    }
}

/// Route one flushed batch to its backend (`Option` shuttle because
/// `BackendSet::run` takes an `FnMut` callback).
fn dispatch<V: BackendSet>(
    set: &V,
    name: &str,
    batch: Vec<(Request, Instant, u64)>,
    tel: &Telemetry,
) {
    let mut slot = Some(batch);
    let found = set.run(name, &mut |backend| {
        if let Some(batch) = slot.take() {
            run_batch(backend, name, batch, tel);
        }
    });
    if !found {
        for (req, _, id) in slot.take().into_iter().flatten() {
            tel.m.record_rejection(RejectReason::UnknownVariant);
            if tel.tr.enabled() {
                tel.tr.record(TraceEvent::RequestFailed {
                    id,
                    error: format!("variant {name} not resident"),
                });
            }
            let _ = req.reply.send(Response {
                logits: Err(format!("variant {name} not resident")),
            });
        }
    }
}

fn run_batch(
    backend: &dyn Backend,
    variant: &str,
    batch: Vec<(Request, Instant, u64)>,
    tel: &Telemetry,
) {
    if batch.is_empty() {
        return;
    }
    let (b, s, v) = (backend.batch(), backend.seq(), backend.vocab());
    debug_assert!(batch.len() <= b, "batcher flushed more than the backend batch");
    // Requests were validated at enqueue (`VariantQueue::admit`), so
    // every one fits. Pack exactly `batch.len()` rows — backends take
    // partial batches, so an under-full flush never pays for the
    // forward pass of padding rows it doesn't need.
    let rows = batch.len();
    let mut tokens = vec![0i32; rows * s];
    let mut lens = Vec::with_capacity(rows);
    for (i, (req, _, _)) in batch.iter().enumerate() {
        tokens[i * s..i * s + req.tokens.len()].copy_from_slice(&req.tokens);
        lens.push(req.tokens.len());
    }
    let t_exec = Instant::now();
    let result = backend.forward_batch(&tokens);
    let exec_elapsed = t_exec.elapsed();
    let n_tokens: u64 = lens.iter().sum::<usize>() as u64;
    if tel.tr.enabled() {
        tel.tr.record(TraceEvent::BatchExec {
            variant: variant.to_string(),
            rows,
            tokens: n_tokens as usize,
            dur_us: exec_elapsed.as_micros() as u64,
        });
    }
    for (i, (req, t0, id)) in batch.into_iter().enumerate() {
        let logits = match &result {
            Ok(all) => Ok(all[i * s * v..(i * s + lens[i]) * v].to_vec()),
            Err(e) => Err(e.clone()),
        };
        let _ = req.reply.send(Response { logits });
        tel.m.record_request(t0.elapsed());
        match &result {
            Ok(_) => tel.tr.record(TraceEvent::RequestCompleted { id, produced: lens[i] }),
            Err(e) => {
                if tel.tr.enabled() {
                    tel.tr.record(TraceEvent::RequestFailed { id, error: e.clone() });
                }
            }
        }
    }
    tel.m.record_batch(rows, n_tokens, exec_elapsed);
}
