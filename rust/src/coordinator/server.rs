//! The serving loop: executor thread owning PJRT, fed by a batched queue.
//!
//! `Server::start` spawns one executor thread that owns the `Engine` and
//! all requested `VariantRunner`s (PJRT handles never cross threads).
//! Clients submit `Request`s over an mpsc sender and receive `Response`s
//! on their own per-request channel. A `DynamicBatcher` per variant
//! packs score requests into the graph's fixed `[batch, seq]` shape;
//! under-full batches are padded (pad rows discarded).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::metrics::Metrics;
use crate::runtime::{Artifacts, Engine, VariantRunner};

/// A scoring request: tokens (≤ seq) for one sequence; the server returns
/// per-position logits of the final `n_last` positions to keep responses
/// small (PPL/zero-shot clients only need targeted positions).
pub struct Request {
    /// Variant name ("fp" for the reference model).
    pub variant: String,
    /// Token sequence, length ≤ graph seq (right-padded internally).
    pub tokens: Vec<i32>,
    /// Reply channel.
    pub reply: mpsc::Sender<Response>,
}

/// Response: logits `[len(tokens), vocab]` for the request's sequence.
pub struct Response {
    pub logits: Result<Vec<f32>, String>,
}

enum Job {
    Score(Request, Instant),
    Shutdown(mpsc::Sender<Metrics>),
}

/// Handle to the running server.
pub struct Server {
    tx: mpsc::Sender<Job>,
    handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the executor with the given variants resident.
    pub fn start(
        artifacts_dir: &Path,
        variant_names: &[String],
        policy: BatchPolicy,
    ) -> Result<Self, String> {
        let (tx, rx) = mpsc::channel::<Job>();
        let dir = artifacts_dir.to_path_buf();
        let names: Vec<String> = variant_names.to_vec();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let handle = std::thread::spawn(move || {
            let setup = (|| -> Result<(Engine, Artifacts, BTreeMap<String, VariantRunner>), String> {
                let arts = Artifacts::load(&dir)?;
                let mut engine = Engine::new()?;
                let mut runners = BTreeMap::new();
                for name in &names {
                    let runner = if name == "fp" {
                        VariantRunner::load_fp(&mut engine, &arts)?
                    } else {
                        let meta = arts
                            .variant(name)
                            .ok_or_else(|| format!("unknown variant {name}"))?
                            .clone();
                        VariantRunner::load(&mut engine, &arts, &meta)?
                    };
                    runners.insert(name.clone(), runner);
                }
                Ok((engine, arts, runners))
            })();
            match setup {
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
                Ok((engine, _arts, runners)) => {
                    let _ = ready_tx.send(Ok(()));
                    executor_loop(engine, runners, rx, policy);
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|e| format!("executor died during setup: {e}"))??;
        Ok(Self { tx, handle: Some(handle) })
    }

    /// Submit a scoring request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<(), String> {
        self.tx
            .send(Job::Score(req, Instant::now()))
            .map_err(|_| "server stopped".to_string())
    }

    /// Convenience: synchronous score of one sequence.
    pub fn score(&self, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
        let (reply, rx) = mpsc::channel();
        self.submit(Request { variant: variant.to_string(), tokens, reply })?;
        rx.recv().map_err(|_| "no response".to_string())?.logits
    }

    /// Stop and collect metrics.
    pub fn shutdown(mut self) -> Metrics {
        let (mtx, mrx) = mpsc::channel();
        let _ = self.tx.send(Job::Shutdown(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

fn executor_loop(
    engine: Engine,
    runners: BTreeMap<String, VariantRunner>,
    rx: mpsc::Receiver<Job>,
    policy: BatchPolicy,
) {
    let mut queues: BTreeMap<String, DynamicBatcher<(Request, Instant)>> = runners
        .keys()
        .map(|k| (k.clone(), DynamicBatcher::new(policy)))
        .collect();
    let mut metrics = Metrics::default();
    loop {
        // Wait bounded by the nearest batch deadline.
        let timeout = queues
            .values()
            .filter_map(|q| q.time_to_deadline(Instant::now()))
            .min()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Job::Score(req, t0)) => {
                if let Some(q) = queues.get_mut(&req.variant) {
                    q.push((req, t0));
                } else {
                    let _ = req.reply.send(Response {
                        logits: Err(format!("variant {} not resident", req.variant)),
                    });
                }
            }
            Ok(Job::Shutdown(mtx)) => {
                // Drain everything before stopping.
                for (name, q) in queues.iter_mut() {
                    while !q.is_empty() {
                        run_batch(&engine, &runners[name], q.take_batch(), &mut metrics);
                    }
                }
                let _ = mtx.send(metrics);
                return;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
        let now = Instant::now();
        for (name, q) in queues.iter_mut() {
            while q.ready(now) {
                run_batch(&engine, &runners[name], q.take_batch(), &mut metrics);
            }
        }
    }
}

fn run_batch(
    engine: &Engine,
    runner: &VariantRunner,
    batch: Vec<(Request, Instant)>,
    metrics: &mut Metrics,
) {
    if batch.is_empty() {
        return;
    }
    let (b, s, v) = (runner.batch, runner.seq, runner.vocab);
    let mut tokens = vec![0i32; b * s];
    let mut lens = Vec::with_capacity(batch.len());
    for (i, (req, _)) in batch.iter().enumerate() {
        let take = req.tokens.len().min(s);
        tokens[i * s..i * s + take].copy_from_slice(&req.tokens[..take]);
        lens.push(take);
    }
    let t_exec = Instant::now();
    let result = runner.forward(engine, &tokens);
    let n_tokens: u64 = lens.iter().sum::<usize>() as u64;
    let n_requests = batch.len();
    for (i, (req, t0)) in batch.into_iter().enumerate() {
        let logits = match &result {
            Ok(all) => Ok(all[i * s * v..(i * s + lens[i]) * v].to_vec()),
            Err(e) => Err(e.clone()),
        };
        let _ = req.reply.send(Response { logits });
        metrics.request_latency.record(t0.elapsed());
        metrics.requests += 1;
    }
    metrics.batches += 1;
    metrics.tokens += n_tokens;
    metrics.batch_sizes.push(n_requests);
    let _ = t_exec;
}
