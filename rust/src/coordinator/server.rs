//! The serving loop: an executor thread owning a [`BackendSet`], fed by
//! per-variant batched queues.
//!
//! `Server::start_set` spawns one executor thread that builds and owns
//! the backend set (PJRT handles never cross threads, so the PJRT set is
//! constructed *inside* the thread; the native set may be built anywhere
//! and moved in). Clients submit `Request`s over an mpsc sender and
//! receive `Response`s on their own per-request channel. A
//! `DynamicBatcher` per variant packs score requests up to the backend's
//! `[batch, seq]` shape; under-full flushes run as partial batches (no
//! compute on padding rows). Malformed requests — longer than the
//! backend's `seq`, out-of-vocab token ids, unknown variants — are
//! rejected individually at enqueue with a clear error, never silently
//! truncated and never able to fail a batch they were packed with.

use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::metrics::Metrics;
use crate::exec::{Backend, BackendSet, NativeSet, PjrtSet};

/// A scoring request: tokens (≤ seq) for one sequence; the server
/// returns per-position logits for exactly the positions sent.
pub struct Request {
    /// Variant name ("fp" for the reference model).
    pub variant: String,
    /// Token sequence, length ≤ backend seq (right-padded internally).
    pub tokens: Vec<i32>,
    /// Reply channel.
    pub reply: mpsc::Sender<Response>,
}

/// Response: logits `[len(tokens), vocab]` for the request's sequence.
pub struct Response {
    pub logits: Result<Vec<f32>, String>,
}

enum Job {
    Score(Request, Instant),
    Shutdown(mpsc::Sender<Metrics>),
}

/// Handle to the running server.
pub struct Server {
    tx: mpsc::Sender<Job>,
    handle: Option<JoinHandle<()>>,
}

/// Cloneable submission handle — hand one to each client thread
/// (`mpsc::Sender` is `Send`, so clones cross threads freely).
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Job>,
}

fn submit_on(tx: &mpsc::Sender<Job>, req: Request) -> Result<(), String> {
    tx.send(Job::Score(req, Instant::now())).map_err(|_| "server stopped".to_string())
}

fn score_on(tx: &mpsc::Sender<Job>, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
    let (reply, rx) = mpsc::channel();
    submit_on(tx, Request { variant: variant.to_string(), tokens, reply })?;
    rx.recv().map_err(|_| "no response".to_string())?.logits
}

impl ServerHandle {
    /// Submit a scoring request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<(), String> {
        submit_on(&self.tx, req)
    }

    /// Convenience: synchronous score of one sequence.
    pub fn score(&self, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
        score_on(&self.tx, variant, tokens)
    }
}

impl Server {
    /// Start the executor over the PJRT runtime with the given variants
    /// resident (compiled graphs + uploaded weights).
    pub fn start(
        artifacts_dir: &Path,
        variant_names: &[String],
        policy: BatchPolicy,
    ) -> Result<Self, String> {
        let dir = artifacts_dir.to_path_buf();
        let names: Vec<String> = variant_names.to_vec();
        Self::start_set(move || PjrtSet::load(&dir, &names), policy)
    }

    /// Start the executor over a prebuilt native backend set — serves
    /// fp, quantized and heterogeneous searched-plan variants with no
    /// PJRT involvement.
    pub fn start_native(set: NativeSet, policy: BatchPolicy) -> Result<Self, String> {
        if set.is_empty() {
            return Err("native backend set is empty".to_string());
        }
        Self::start_set(move || Ok(set), policy)
    }

    /// Start the executor over any [`BackendSet`]. `build` runs on the
    /// executor thread, so non-`Send` sets (PJRT) work; its error is
    /// propagated out of `start_set` via a ready handshake.
    pub fn start_set<V, F>(build: F, policy: BatchPolicy) -> Result<Self, String>
    where
        V: BackendSet + 'static,
        F: FnOnce() -> Result<V, String> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let handle = std::thread::spawn(move || match build() {
            Err(e) => {
                let _ = ready_tx.send(Err(e));
            }
            Ok(set) => {
                let _ = ready_tx.send(Ok(()));
                executor_loop(set, rx, policy);
            }
        });
        ready_rx
            .recv()
            .map_err(|e| format!("executor died during setup: {e}"))??;
        Ok(Self { tx, handle: Some(handle) })
    }

    /// Cloneable submission handle for concurrent client threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { tx: self.tx.clone() }
    }

    /// Submit a scoring request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<(), String> {
        submit_on(&self.tx, req)
    }

    /// Convenience: synchronous score of one sequence.
    pub fn score(&self, variant: &str, tokens: Vec<i32>) -> Result<Vec<f32>, String> {
        score_on(&self.tx, variant, tokens)
    }

    /// Stop and collect metrics.
    pub fn shutdown(mut self) -> Metrics {
        let (mtx, mrx) = mpsc::channel();
        let _ = self.tx.send(Job::Shutdown(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

/// One resident variant's queue plus the backend geometry probed at
/// startup, so malformed requests are rejected at enqueue — a doomed
/// request never waits out `max_wait` or occupies a batch slot.
struct VariantQueue {
    name: String,
    seq: usize,
    vocab: usize,
    backend_label: String,
    q: DynamicBatcher<(Request, Instant)>,
}

impl VariantQueue {
    /// Validate a request against static data: length, token range.
    /// Malformed requests are refused individually with a clear error —
    /// never clipped (wrong-but-plausible logits for PPL clients) and
    /// never allowed near a batch they could fail wholesale.
    fn admit(&self, req: &Request) -> Result<(), String> {
        if req.tokens.len() > self.seq {
            return Err(format!(
                "request has {} tokens but backend {} serves seq {}; \
                 split the request instead of truncating",
                req.tokens.len(),
                self.backend_label,
                self.seq
            ));
        }
        if let Some(&bad) = req.tokens.iter().find(|&&t| t < 0 || t as usize >= self.vocab) {
            return Err(format!("token id {bad} outside vocab 0..{}", self.vocab));
        }
        Ok(())
    }
}

fn executor_loop<V: BackendSet>(set: V, rx: mpsc::Receiver<Job>, policy: BatchPolicy) {
    // Per-variant queue, its max_batch clamped to the backend's actual
    // batch capacity so one flush never overflows one forward call.
    let mut queues: Vec<VariantQueue> = Vec::new();
    for name in set.names() {
        let mut cap = policy.max_batch.max(1);
        let (mut seq, mut vocab, mut backend_label) = (0, 0, String::new());
        set.run(&name, &mut |backend| {
            cap = cap.min(backend.batch()).max(1);
            seq = backend.seq();
            vocab = backend.vocab();
            backend_label = backend.name().to_string();
        });
        let q = DynamicBatcher::new(BatchPolicy { max_batch: cap, ..policy });
        queues.push(VariantQueue { name, seq, vocab, backend_label, q });
    }
    let mut metrics = Metrics::default();
    loop {
        // Wait bounded by the nearest batch deadline.
        let timeout = queues
            .iter()
            .filter_map(|vq| vq.q.time_to_deadline(Instant::now()))
            .min()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Job::Score(req, t0)) => {
                match queues.iter_mut().find(|vq| vq.name == req.variant) {
                    Some(vq) => match vq.admit(&req) {
                        Ok(()) => vq.q.push((req, t0)),
                        Err(e) => {
                            metrics.rejected += 1;
                            let _ = req.reply.send(Response { logits: Err(e) });
                        }
                    },
                    None => {
                        metrics.rejected += 1;
                        let _ = req.reply.send(Response {
                            logits: Err(format!("variant {} not resident", req.variant)),
                        });
                    }
                }
            }
            Ok(Job::Shutdown(mtx)) => {
                // Drain everything before stopping.
                for vq in queues.iter_mut() {
                    while !vq.q.is_empty() {
                        dispatch(&set, &vq.name, vq.q.take_batch(), &mut metrics);
                    }
                }
                let _ = mtx.send(metrics);
                return;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
        let now = Instant::now();
        for vq in queues.iter_mut() {
            while vq.q.ready(now) {
                dispatch(&set, &vq.name, vq.q.take_batch(), &mut metrics);
            }
        }
    }
}

/// Route one flushed batch to its backend (`Option` shuttle because
/// `BackendSet::run` takes an `FnMut` callback).
fn dispatch<V: BackendSet>(
    set: &V,
    name: &str,
    batch: Vec<(Request, Instant)>,
    metrics: &mut Metrics,
) {
    let mut slot = Some(batch);
    let found = set.run(name, &mut |backend| {
        if let Some(batch) = slot.take() {
            run_batch(backend, batch, metrics);
        }
    });
    if !found {
        for (req, _) in slot.take().into_iter().flatten() {
            metrics.rejected += 1;
            let _ = req.reply.send(Response {
                logits: Err(format!("variant {name} not resident")),
            });
        }
    }
}

fn run_batch(backend: &dyn Backend, batch: Vec<(Request, Instant)>, metrics: &mut Metrics) {
    if batch.is_empty() {
        return;
    }
    let (b, s, v) = (backend.batch(), backend.seq(), backend.vocab());
    debug_assert!(batch.len() <= b, "batcher flushed more than the backend batch");
    // Requests were validated at enqueue (`VariantQueue::admit`), so
    // every one fits. Pack exactly `batch.len()` rows — backends take
    // partial batches, so an under-full flush never pays for the
    // forward pass of padding rows it doesn't need.
    let rows = batch.len();
    let mut tokens = vec![0i32; rows * s];
    let mut lens = Vec::with_capacity(rows);
    for (i, (req, _)) in batch.iter().enumerate() {
        tokens[i * s..i * s + req.tokens.len()].copy_from_slice(&req.tokens);
        lens.push(req.tokens.len());
    }
    let t_exec = Instant::now();
    let result = backend.forward_batch(&tokens);
    let exec_elapsed = t_exec.elapsed();
    let n_tokens: u64 = lens.iter().sum::<usize>() as u64;
    for (i, (req, t0)) in batch.into_iter().enumerate() {
        let logits = match &result {
            Ok(all) => Ok(all[i * s * v..(i * s + lens[i]) * v].to_vec()),
            Err(e) => Err(e.clone()),
        };
        let _ = req.reply.send(Response { logits });
        metrics.record_request(t0.elapsed());
    }
    metrics.record_batch(rows, n_tokens, exec_elapsed);
}
