//! L3 coordinator: request router, dynamic batcher, executor, metrics.
//!
//! Serving shape (vLLM-router-like, scaled to one host):
//!
//! ```text
//!  clients ──▶ Router ──▶ per-variant queue ──▶ DynamicBatcher ──▶
//!              Executor thread (owns a BackendSet: PJRT engine+variants
//!              or native models on a shared worker pool) ──▶
//!              response channels
//!
//!  generate ──▶ admit (peak fits the variant's BlockPool) ──▶
//!              continuous-batching rounds: decode-ready sequences step
//!              together while one bounded prefill chunk trickles in;
//!              KV lives in fixed-size blocks granted on demand and
//!              preempted youngest-first under pressure ──▶ sampled
//!              picks (per-request seeded stream) ──▶ stream + reply
//! ```
//!
//! The executor is generic over [`crate::exec::BackendSet`]: the PJRT
//! set is built inside the executor thread (PJRT handles are not
//! `Send`/`Sync`-safe to share), while the native set — a pure-Rust
//! multi-threaded engine — can be built anywhere and moved in, and is
//! the only path that serves heterogeneous searched rotation plans or
//! incremental generation. Python is never involved on the request
//! path. Scheduling mechanisms (block pool, round policy, sampler) live
//! in [`crate::sched`]; the [`server`] executor composes them.
//!
//! Determinism: scoring logits are bit-identical to the serial forward
//! for any batch composition and thread count, and generations — greedy
//! *and* sampled — are bit-reproducible: decode logits equal a full
//! re-forward of the prefix at every step for any block layout or
//! prefill chunking, and each request samples from its own seeded
//! stream (one draw per pick), so batching rounds differently,
//! preempting, or co-scheduling other traffic can never change what a
//! request returns. Partial batches execute without padding-row
//! compute; malformed requests are rejected individually at admission
//! (counted per reason under `Metrics::rejected`), never silently
//! truncated, and can never fail a batch they were packed with.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use metrics::{LatencyHistogram, Metrics, RejectReason, ServingMetrics};
pub use router::{RoutePolicy, Router};
pub use server::{
    Generated, GenerateRequest, GenerateResponse, Request, Response, Server, ServerHandle,
};
