//! L3 coordinator: request router, dynamic batcher, executor, metrics.
//!
//! Serving shape (vLLM-router-like, scaled to one host):
//!
//! ```text
//!  clients ──▶ Router ──▶ per-variant queue ──▶ DynamicBatcher ──▶
//!              Executor thread (owns a BackendSet: PJRT engine+variants
//!              or native models on a shared worker pool) ──▶
//!              response channels
//! ```
//!
//! The executor is generic over [`crate::exec::BackendSet`]: the PJRT
//! set is built inside the executor thread (PJRT handles are not
//! `Send`/`Sync`-safe to share), while the native set — a pure-Rust
//! multi-threaded engine — can be built anywhere and moved in, and is
//! the only path that serves heterogeneous searched rotation plans.
//! Python is never involved on the request path.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use metrics::{LatencyHistogram, Metrics};
pub use router::{RoutePolicy, Router};
pub use server::{Request, Response, Server, ServerHandle};
