//! L3 coordinator: request router, dynamic batcher, executor, metrics.
//!
//! Serving shape (vLLM-router-like, scaled to a single CPU PJRT device):
//!
//! ```text
//!  clients ──▶ Router ──▶ per-variant queue ──▶ DynamicBatcher ──▶
//!              Executor thread (owns Engine + resident variants) ──▶
//!              response channels
//! ```
//!
//! PJRT handles are not `Send`/`Sync`-safe to share, so a single executor
//! thread owns the `Engine` and all `VariantRunner`s; the router and
//! batcher run on the calling/side threads and communicate over std
//! mpsc channels. Python is never involved: the executor only replays
//! AOT artifacts.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use metrics::{LatencyHistogram, Metrics};
pub use router::{Router, RoutePolicy};
pub use server::{Server, Request, Response};
