//! L3 coordinator: request router, dynamic batcher, executor, metrics.
//!
//! Serving shape (vLLM-router-like, scaled to one host):
//!
//! ```text
//!  clients ──▶ Router ──▶ per-variant queue ──▶ DynamicBatcher ──▶
//!              Executor thread (owns a BackendSet: PJRT engine+variants
//!              or native models on a shared worker pool) ──▶
//!              response channels
//!
//!  generate ──▶ admit ──▶ prefill (KV cache) ──▶ batched decode rounds
//!              (active sequences of a variant step together; each
//!               completes individually on max_new / stop) ──▶ reply
//! ```
//!
//! The executor is generic over [`crate::exec::BackendSet`]: the PJRT
//! set is built inside the executor thread (PJRT handles are not
//! `Send`/`Sync`-safe to share), while the native set — a pure-Rust
//! multi-threaded engine — can be built anywhere and moved in, and is
//! the only path that serves heterogeneous searched rotation plans or
//! incremental generation. Python is never involved on the request
//! path.
//!
//! Determinism: scoring logits are bit-identical to the serial forward
//! for any batch composition and thread count, and greedy generations
//! are bit-reproducible — decode logits equal a full re-forward of the
//! prefix at every step, so batching rounds differently (or not at all)
//! can never change what a request returns. Partial batches execute
//! without padding-row compute; malformed requests are rejected
//! individually at admission (counted in `Metrics::rejected`), never
//! silently truncated, and can never fail a batch they were packed
//! with.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use metrics::{LatencyHistogram, Metrics};
pub use router::{RoutePolicy, Router};
pub use server::{
    Generated, GenerateRequest, GenerateResponse, Request, Response, Server, ServerHandle,
};
