//! Serving metrics: latency histogram + throughput counters.

use std::time::Duration;

/// Log₂-bucketed latency histogram (µs granularity, 1µs … ~17min).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: [u64; 30],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { buckets: [0; 30], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(29);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Total recorded time — what throughput rates divide by.
    pub fn total(&self) -> Duration {
        Duration::from_micros(self.sum_us)
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }
}

/// Why a request was refused without execution — one bucket per
/// admission rule, so load-shedding is diagnosable from the report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Scoring request longer than the backend's `seq`.
    TooLong,
    /// A token id (prompt, scoring, or stop) outside the vocab.
    BadToken,
    /// Variant not resident, or it cannot serve this request type.
    UnknownVariant,
    /// Empty token list / empty prompt / `max_new == 0`.
    ZeroLength,
    /// Generation whose peak KV occupancy exceeds the block pool's
    /// total token inventory — it could never complete, even alone.
    CachePressure,
}

/// Aggregate serving metrics.
///
/// Two latency views: `request_latency` is queue-to-reply per request
/// (what a client feels), `exec_latency` is the backend's execution
/// time per call — scoring batches and prefill chunks — (what the
/// executor pays); the gap between them is the batching wait the
/// policy trades for throughput.
///
/// Generation adds its own family: `decode_latency` is the backend time
/// of one *batched decode round* (the per-step number a serving loop
/// tunes), `generated_tokens` counts emitted tokens, and the cache
/// gauges track KV occupancy — so decode tok/s is reported directly
/// instead of being inferred from prefill batch latency. The paged
/// scheduler adds block-pool gauges and preemption/eviction/recompute
/// counters.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub request_latency: LatencyHistogram,
    pub exec_latency: LatencyHistogram,
    /// Per-step backend latency of batched decode rounds.
    pub decode_latency: LatencyHistogram,
    pub batch_sizes: Vec<usize>,
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    /// Requests refused without execution — always the sum of the
    /// per-reason counters below.
    pub rejected: u64,
    pub rejected_too_long: u64,
    pub rejected_bad_token: u64,
    pub rejected_unknown_variant: u64,
    pub rejected_zero_length: u64,
    pub rejected_cache_pressure: u64,
    /// Completed generation requests (also counted in `requests`).
    pub generations: u64,
    /// Generations that failed *after* admission (prefill or decode
    /// error). Together with `generations` and `rejected`, every
    /// submitted generation is accounted exactly once.
    pub generation_failures: u64,
    /// Tokens emitted to generation clients (stop tokens excluded).
    pub generated_tokens: u64,
    /// Batched decode rounds executed.
    pub decode_steps: u64,
    /// Sequence-steps across all decode rounds (= tokens decoded,
    /// including a final stop token that is not emitted).
    pub decode_seqs: u64,
    /// Sum over decode rounds of the round's total KV-cache occupancy
    /// (tokens); `/ decode_steps` = mean cached tokens per round.
    pub cache_tokens: u64,
    /// Largest single-round KV-cache occupancy seen (tokens).
    pub cache_tokens_peak: u64,
    /// Prefill chunks executed by the continuous-batching scheduler.
    pub prefill_chunks: u64,
    /// Prompt/recompute tokens absorbed through prefill chunks.
    pub prefill_tokens: u64,
    /// Block-pool inventory (blocks), summed over paged variants.
    pub kv_blocks_total: u64,
    /// High-water mark of granted blocks across all pools.
    pub kv_blocks_peak: u64,
    /// Sequences preempted (blocks reclaimed, recompute-on-resume).
    pub preemptions: u64,
    /// Blocks taken back by preemption/eviction (completions excluded).
    pub evicted_blocks: u64,
    /// Cached tokens invalidated by preemption — the recompute debt
    /// paid back through later prefill chunks.
    pub recomputed_tokens: u64,
}

impl Metrics {
    /// Account one executed batch: its size, the real (unpadded) token
    /// count, and the backend forward latency.
    pub fn record_batch(&mut self, batch_size: usize, tokens: u64, exec: Duration) {
        self.batches += 1;
        self.tokens += tokens;
        self.batch_sizes.push(batch_size);
        self.exec_latency.record(exec);
    }

    /// Account one completed request and its queue-to-reply latency.
    pub fn record_request(&mut self, latency: Duration) {
        self.requests += 1;
        self.request_latency.record(latency);
    }

    /// Account one rejected request under its reason bucket (the
    /// aggregate `rejected` stays the sum of the buckets).
    pub fn record_rejection(&mut self, reason: RejectReason) {
        self.rejected += 1;
        match reason {
            RejectReason::TooLong => self.rejected_too_long += 1,
            RejectReason::BadToken => self.rejected_bad_token += 1,
            RejectReason::UnknownVariant => self.rejected_unknown_variant += 1,
            RejectReason::ZeroLength => self.rejected_zero_length += 1,
            RejectReason::CachePressure => self.rejected_cache_pressure += 1,
        }
    }

    /// Account one prefill chunk: `tokens` absorbed in `exec` backend
    /// time (prefill execution shares the `exec_latency` histogram with
    /// scoring batches — both are per-call backend time).
    pub fn record_prefill(&mut self, tokens: u64, exec: Duration) {
        self.prefill_chunks += 1;
        self.prefill_tokens += tokens;
        self.exec_latency.record(exec);
    }

    /// Account one preemption: a sequence lost `blocks` granted blocks
    /// and `cached_tokens` cached positions (to be recomputed on
    /// resume).
    pub fn record_preemption(&mut self, blocks: u64, cached_tokens: u64) {
        self.preemptions += 1;
        self.evicted_blocks += blocks;
        self.recomputed_tokens += cached_tokens;
    }

    /// Account one batched decode round: `seqs` sequences stepped
    /// together, holding `cache_tokens` total cached tokens afterwards,
    /// in `exec` backend time.
    pub fn record_decode(&mut self, seqs: usize, cache_tokens: u64, exec: Duration) {
        self.decode_steps += 1;
        self.decode_seqs += seqs as u64;
        self.cache_tokens += cache_tokens;
        self.cache_tokens_peak = self.cache_tokens_peak.max(cache_tokens);
        self.decode_latency.record(exec);
    }

    /// Account one completed generation: `emitted` tokens delivered to
    /// the client, `latency` submit-to-reply.
    pub fn record_generation(&mut self, emitted: u64, latency: Duration) {
        self.generations += 1;
        self.generated_tokens += emitted;
        self.record_request(latency);
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Decoded sequence-steps per second of backend decode time — the
    /// serving-side decode throughput (0 when nothing was generated).
    pub fn decode_tok_per_s(&self) -> f64 {
        let secs = self.decode_latency.total().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.decode_seqs as f64 / secs
    }

    pub fn report(&self, wall: Duration) -> String {
        let mut out = format!(
            "requests={} rejected={} batches={} mean_batch={:.2} tokens={} \
             throughput={:.0} tok/s req p50={:?} p99={:?} max={:?} \
             exec p50={:?} p99={:?} max={:?}",
            self.requests,
            self.rejected,
            self.batches,
            self.mean_batch_size(),
            self.tokens,
            self.tokens as f64 / wall.as_secs_f64().max(1e-9),
            self.request_latency.quantile(0.5),
            self.request_latency.quantile(0.99),
            self.request_latency.max(),
            self.exec_latency.quantile(0.5),
            self.exec_latency.quantile(0.99),
            self.exec_latency.max(),
        );
        if self.rejected > 0 {
            out.push_str(&format!(
                " | rejected: too_long={} bad_token={} unknown_variant={} \
                 zero_length={} cache_pressure={}",
                self.rejected_too_long,
                self.rejected_bad_token,
                self.rejected_unknown_variant,
                self.rejected_zero_length,
                self.rejected_cache_pressure,
            ));
        }
        if self.decode_steps > 0 || self.generations > 0 || self.generation_failures > 0 {
            let steps = self.decode_steps.max(1) as f64;
            out.push_str(&format!(
                " | gen: completed={} failed={} emitted={} decode={:.0} tok/s \
                 steps={} mean_step_seqs={:.2} step p50={:?} p99={:?} max={:?} \
                 cache mean={:.0} peak={} tokens prefill chunks={} tokens={}",
                self.generations,
                self.generation_failures,
                self.generated_tokens,
                self.decode_tok_per_s(),
                self.decode_steps,
                self.decode_seqs as f64 / steps,
                self.decode_latency.quantile(0.5),
                self.decode_latency.quantile(0.99),
                self.decode_latency.max(),
                self.cache_tokens as f64 / steps,
                self.cache_tokens_peak,
                self.prefill_chunks,
                self.prefill_tokens,
            ));
        }
        if self.kv_blocks_total > 0 {
            out.push_str(&format!(
                " | paged: pool={} blocks peak={} preemptions={} \
                 evicted_blocks={} recomputed_tokens={}",
                self.kv_blocks_total,
                self.kv_blocks_peak,
                self.preemptions,
                self.evicted_blocks,
                self.recomputed_tokens,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.record(Duration::from_micros(us));
            }
        }
        assert_eq!(h.count(), 100);
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(0.999));
    }

    #[test]
    fn mean_batch() {
        let mut m = Metrics::default();
        m.record_batch(4, 512, Duration::from_millis(3));
        m.record_batch(2, 256, Duration::from_millis(2));
        for _ in 0..6 {
            m.record_request(Duration::from_millis(4));
        }
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-12);
        assert_eq!(m.requests, 6);
        assert_eq!(m.tokens, 768);
        assert_eq!(m.batches, 2);
        assert_eq!(m.exec_latency.count(), 2);
        assert_eq!(m.request_latency.count(), 6);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn decode_metrics_accumulate() {
        let mut m = Metrics::default();
        assert_eq!(m.decode_tok_per_s(), 0.0, "no decode yet");
        m.record_decode(3, 30, Duration::from_millis(10));
        m.record_decode(2, 24, Duration::from_millis(10));
        m.record_generation(4, Duration::from_millis(25));
        m.record_generation(1, Duration::from_millis(30));
        assert_eq!(m.decode_steps, 2);
        assert_eq!(m.decode_seqs, 5);
        assert_eq!(m.cache_tokens, 54);
        assert_eq!(m.cache_tokens_peak, 30);
        assert_eq!(m.generations, 2);
        assert_eq!(m.generated_tokens, 5);
        assert_eq!(m.requests, 2, "generations count as requests");
        // 5 sequence-steps over 20ms of decode time = 250 tok/s.
        assert!((m.decode_tok_per_s() - 250.0).abs() < 1.0);
        assert!(m.report(Duration::from_millis(40)).contains("gen:"));
        let quiet = Metrics::default();
        assert!(!quiet.report(Duration::from_millis(1)).contains("gen:"));
    }

    #[test]
    fn rejection_reasons_sum_to_aggregate() {
        let mut m = Metrics::default();
        m.record_rejection(RejectReason::TooLong);
        m.record_rejection(RejectReason::BadToken);
        m.record_rejection(RejectReason::BadToken);
        m.record_rejection(RejectReason::UnknownVariant);
        m.record_rejection(RejectReason::ZeroLength);
        m.record_rejection(RejectReason::CachePressure);
        assert_eq!(m.rejected, 6);
        assert_eq!(
            m.rejected_too_long
                + m.rejected_bad_token
                + m.rejected_unknown_variant
                + m.rejected_zero_length
                + m.rejected_cache_pressure,
            m.rejected
        );
        let report = m.report(Duration::from_millis(1));
        assert!(report.contains("bad_token=2"), "{report}");
        assert!(report.contains("cache_pressure=1"), "{report}");
        assert!(!Metrics::default().report(Duration::from_millis(1)).contains("too_long"));
    }

    #[test]
    fn report_surfaces_quantiles_and_paged_counters() {
        let mut m = Metrics::default();
        m.record_batch(2, 64, Duration::from_millis(2));
        m.record_request(Duration::from_millis(3));
        m.record_decode(2, 20, Duration::from_millis(1));
        m.record_prefill(16, Duration::from_millis(2));
        m.record_preemption(2, 24);
        m.kv_blocks_total = 8;
        m.kv_blocks_peak = 5;
        let report = m.report(Duration::from_millis(10));
        for needle in [
            "req p50=",
            "exec p50=",
            "step p50=",
            "p99=",
            "paged: pool=8",
            "preemptions=1",
            "evicted_blocks=2",
            "recomputed_tokens=24",
            "prefill chunks=1 tokens=16",
        ] {
            assert!(report.contains(needle), "missing {needle} in {report}");
        }
        assert_eq!(m.prefill_chunks, 1);
        assert_eq!(m.exec_latency.count(), 2, "prefill shares exec latency");
        let quiet = Metrics::default().report(Duration::from_millis(1));
        assert!(!quiet.contains("paged:"), "{quiet}");
    }
}
