//! Serving metrics: registry-backed counters/gauges/histograms plus
//! the aggregate snapshot the shutdown report is rendered from.
//!
//! The executor records into [`ServingMetrics`] — cheap atomic handles
//! registered on an [`obs::Registry`](crate::obs::Registry), so the
//! same cells feed the Prometheus exposition (`--metrics-addr`), the
//! JSON snapshot (`--metrics-dump`) and the human-readable [`Metrics`]
//! report. Every latency family is a fixed-bucket histogram: memory
//! stays constant under sustained traffic (no raw-sample vectors).

use std::sync::Arc;
use std::time::Duration;

use crate::model::{FastPathStats, KernelMode};
use crate::obs::registry::{Counter, Gauge, Histogram, Registry};
pub use crate::obs::LatencyHistogram;

/// Why a request was refused without execution — one bucket per
/// admission rule, so load-shedding is diagnosable from the report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Scoring request longer than the backend's `seq`.
    TooLong,
    /// A token id (prompt, scoring, or stop) outside the vocab.
    BadToken,
    /// Variant not resident, or it cannot serve this request type.
    UnknownVariant,
    /// Empty token list / empty prompt / `max_new == 0`.
    ZeroLength,
    /// Generation whose peak KV occupancy exceeds the block pool's
    /// total token inventory — it could never complete, even alone.
    CachePressure,
}

impl RejectReason {
    /// Stable label used in metrics and trace events.
    pub fn as_str(self) -> &'static str {
        match self {
            RejectReason::TooLong => "too_long",
            RejectReason::BadToken => "bad_token",
            RejectReason::UnknownVariant => "unknown_variant",
            RejectReason::ZeroLength => "zero_length",
            RejectReason::CachePressure => "cache_pressure",
        }
    }
}

/// Aggregate serving metrics.
///
/// Two latency views: `request_latency` is queue-to-reply per request
/// (what a client feels), `exec_latency` is the backend's execution
/// time per call — scoring batches and prefill chunks — (what the
/// executor pays); the gap between them is the batching wait the
/// policy trades for throughput.
///
/// Generation adds its own family: `decode_latency` is the backend time
/// of one *batched decode round* (the per-step number a serving loop
/// tunes), `generated_tokens` counts emitted tokens, and the cache
/// gauges track KV occupancy — so decode tok/s is reported directly
/// instead of being inferred from prefill batch latency. The paged
/// scheduler adds block-pool gauges and preemption/eviction/recompute
/// counters, and speculative decoding its draft/accept/reject token
/// counters plus the draft/verify latency split.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub request_latency: LatencyHistogram,
    pub exec_latency: LatencyHistogram,
    /// Per-step backend latency of batched decode rounds.
    pub decode_latency: LatencyHistogram,
    /// Backend time of draft-variant forwards in speculative rounds
    /// (draft catch-up chunks are accounted as prefill instead).
    pub draft_latency: LatencyHistogram,
    /// Backend time of target verify forwards in speculative rounds.
    pub verify_latency: LatencyHistogram,
    /// Rows across all executed scoring batches (`/ batches` = mean
    /// batch size; bounded accounting, no per-batch samples kept).
    pub batch_rows: u64,
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    /// Requests refused without execution — always the sum of the
    /// per-reason counters below.
    pub rejected: u64,
    pub rejected_too_long: u64,
    pub rejected_bad_token: u64,
    pub rejected_unknown_variant: u64,
    pub rejected_zero_length: u64,
    pub rejected_cache_pressure: u64,
    /// Completed generation requests (also counted in `requests`).
    pub generations: u64,
    /// Generations that failed *after* admission (prefill or decode
    /// error). Together with `generations` and `rejected`, every
    /// submitted generation is accounted exactly once.
    pub generation_failures: u64,
    /// Tokens emitted to generation clients (stop tokens excluded).
    pub generated_tokens: u64,
    /// Batched decode rounds executed.
    pub decode_steps: u64,
    /// Sequence-steps across all decode rounds, including steps whose
    /// pick was a stop token and therefore emitted nothing.
    pub decode_seqs: u64,
    /// Tokens *emitted* by decode rounds and speculative verify rounds
    /// — the numerator of [`Metrics::decode_tok_per_s`]. Unlike
    /// `decode_seqs`, stop picks and rejected drafts never count here.
    pub decode_emitted: u64,
    /// Speculative draft/verify rounds executed.
    pub spec_rounds: u64,
    /// Draft tokens proposed across speculative rounds.
    pub drafted_tokens: u64,
    /// Drafted tokens the target verified and accepted (emitted).
    pub accepted_draft_tokens: u64,
    /// Drafted tokens the target rejected — compute spent drafting them
    /// is wasted, and they are *never* counted as generated output.
    pub rejected_draft_tokens: u64,
    /// Sum over decode rounds of the round's total KV-cache occupancy
    /// (tokens); `/ decode_steps` = mean cached tokens per round.
    pub cache_tokens: u64,
    /// Largest single-round KV-cache occupancy seen (tokens).
    pub cache_tokens_peak: u64,
    /// Prefill chunks executed by the continuous-batching scheduler.
    pub prefill_chunks: u64,
    /// Prompt/recompute tokens absorbed through prefill chunks.
    pub prefill_tokens: u64,
    /// Block-pool inventory (blocks), summed over paged variants.
    pub kv_blocks_total: u64,
    /// High-water mark of granted blocks across all pools.
    pub kv_blocks_peak: u64,
    /// Sequences preempted (blocks reclaimed, recompute-on-resume).
    pub preemptions: u64,
    /// Blocks taken back by preemption/eviction (completions excluded).
    pub evicted_blocks: u64,
    /// Cached tokens invalidated by preemption — the recompute debt
    /// paid back through later prefill chunks.
    pub recomputed_tokens: u64,
    /// Variants running the fast kernel path.
    pub fast_variants: u64,
    /// Per-linear dense fallbacks across fast-mode variants (structure
    /// recognition declined; the dense reference matmul runs instead).
    pub fast_dense_fallbacks: u64,
}

impl Metrics {
    /// Account one executed batch: its size, the real (unpadded) token
    /// count, and the backend forward latency.
    pub fn record_batch(&mut self, batch_size: usize, tokens: u64, exec: Duration) {
        self.batches += 1;
        self.batch_rows += batch_size as u64;
        self.tokens += tokens;
        self.exec_latency.record(exec);
    }

    /// Account one completed request and its queue-to-reply latency.
    pub fn record_request(&mut self, latency: Duration) {
        self.requests += 1;
        self.request_latency.record(latency);
    }

    /// Account one rejected request under its reason bucket (the
    /// aggregate `rejected` stays the sum of the buckets).
    pub fn record_rejection(&mut self, reason: RejectReason) {
        self.rejected += 1;
        match reason {
            RejectReason::TooLong => self.rejected_too_long += 1,
            RejectReason::BadToken => self.rejected_bad_token += 1,
            RejectReason::UnknownVariant => self.rejected_unknown_variant += 1,
            RejectReason::ZeroLength => self.rejected_zero_length += 1,
            RejectReason::CachePressure => self.rejected_cache_pressure += 1,
        }
    }

    /// Account one prefill chunk: `tokens` absorbed in `exec` backend
    /// time (prefill execution shares the `exec_latency` histogram with
    /// scoring batches — both are per-call backend time).
    pub fn record_prefill(&mut self, tokens: u64, exec: Duration) {
        self.prefill_chunks += 1;
        self.prefill_tokens += tokens;
        self.exec_latency.record(exec);
    }

    /// Account one preemption: a sequence lost `blocks` granted blocks
    /// and `cached_tokens` cached positions (to be recomputed on
    /// resume).
    pub fn record_preemption(&mut self, blocks: u64, cached_tokens: u64) {
        self.preemptions += 1;
        self.evicted_blocks += blocks;
        self.recomputed_tokens += cached_tokens;
    }

    /// Account one batched decode round: `seqs` sequences stepped
    /// together, `emitted` of their picks appended to client output
    /// (stop picks excluded), holding `cache_tokens` total cached
    /// tokens afterwards, in `exec` backend time.
    pub fn record_decode(&mut self, seqs: usize, emitted: u64, cache_tokens: u64, exec: Duration) {
        self.decode_steps += 1;
        self.decode_seqs += seqs as u64;
        self.decode_emitted += emitted;
        self.cache_tokens += cache_tokens;
        self.cache_tokens_peak = self.cache_tokens_peak.max(cache_tokens);
        self.decode_latency.record(exec);
    }

    /// Account one speculative draft/verify round: `drafted` tokens
    /// proposed in `draft` backend time, `accepted` of them kept by the
    /// target's verify forward (`verify` backend time), and `emitted`
    /// tokens appended to client output (accepted drafts plus the
    /// target's own pick, minus any stop pick).
    pub fn record_spec_round(
        &mut self,
        drafted: u64,
        accepted: u64,
        emitted: u64,
        draft: Duration,
        verify: Duration,
    ) {
        self.spec_rounds += 1;
        self.drafted_tokens += drafted;
        self.accepted_draft_tokens += accepted;
        self.rejected_draft_tokens += drafted - accepted;
        self.decode_emitted += emitted;
        self.draft_latency.record(draft);
        self.verify_latency.record(verify);
    }

    /// Account one completed generation: `emitted` tokens delivered to
    /// the client, `latency` submit-to-reply.
    pub fn record_generation(&mut self, emitted: u64, latency: Duration) {
        self.generations += 1;
        self.generated_tokens += emitted;
        self.record_request(latency);
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batch_rows as f64 / self.batches as f64
    }

    /// Emitted tokens per second of backend decode-side time — the
    /// serving decode throughput (0 when nothing was generated). The
    /// numerator counts only tokens delivered to clients; the
    /// denominator includes plain decode rounds plus speculative draft
    /// and verify forwards, so drafted-then-rejected tokens can only
    /// *lower* this number, never inflate it.
    pub fn decode_tok_per_s(&self) -> f64 {
        let spent = self.decode_latency.total()
            + self.draft_latency.total()
            + self.verify_latency.total();
        let secs = spent.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.decode_emitted as f64 / secs
    }

    /// Fraction of drafted tokens the target accepted (0 when no
    /// speculative rounds ran).
    pub fn draft_acceptance(&self) -> f64 {
        if self.drafted_tokens == 0 {
            return 0.0;
        }
        self.accepted_draft_tokens as f64 / self.drafted_tokens as f64
    }

    pub fn report(&self, wall: Duration) -> String {
        let mut out = format!(
            "requests={} rejected={} batches={} mean_batch={:.2} tokens={} \
             throughput={:.0} tok/s req p50={:?} p99={:?} max={:?} \
             exec p50={:?} p99={:?} max={:?}",
            self.requests,
            self.rejected,
            self.batches,
            self.mean_batch_size(),
            self.tokens,
            self.tokens as f64 / wall.as_secs_f64().max(1e-9),
            self.request_latency.quantile(0.5),
            self.request_latency.quantile(0.99),
            self.request_latency.max(),
            self.exec_latency.quantile(0.5),
            self.exec_latency.quantile(0.99),
            self.exec_latency.max(),
        );
        if self.rejected > 0 {
            out.push_str(&format!(
                " | rejected: too_long={} bad_token={} unknown_variant={} \
                 zero_length={} cache_pressure={}",
                self.rejected_too_long,
                self.rejected_bad_token,
                self.rejected_unknown_variant,
                self.rejected_zero_length,
                self.rejected_cache_pressure,
            ));
        }
        if self.decode_steps > 0 || self.generations > 0 || self.generation_failures > 0 {
            let steps = self.decode_steps.max(1) as f64;
            out.push_str(&format!(
                " | gen: completed={} failed={} emitted={} decode={:.0} tok/s \
                 steps={} mean_step_seqs={:.2} step p50={:?} p99={:?} max={:?} \
                 cache mean={:.0} peak={} tokens prefill chunks={} tokens={}",
                self.generations,
                self.generation_failures,
                self.generated_tokens,
                self.decode_tok_per_s(),
                self.decode_steps,
                self.decode_seqs as f64 / steps,
                self.decode_latency.quantile(0.5),
                self.decode_latency.quantile(0.99),
                self.decode_latency.max(),
                self.cache_tokens as f64 / steps,
                self.cache_tokens_peak,
                self.prefill_chunks,
                self.prefill_tokens,
            ));
        }
        if self.spec_rounds > 0 {
            out.push_str(&format!(
                " | spec: rounds={} drafted={} accepted={} rejected={} \
                 acceptance={:.1}% draft p50={:?} verify p50={:?}",
                self.spec_rounds,
                self.drafted_tokens,
                self.accepted_draft_tokens,
                self.rejected_draft_tokens,
                100.0 * self.draft_acceptance(),
                self.draft_latency.quantile(0.5),
                self.verify_latency.quantile(0.5),
            ));
        }
        if self.kv_blocks_total > 0 {
            out.push_str(&format!(
                " | paged: pool={} blocks peak={} preemptions={} \
                 evicted_blocks={} recomputed_tokens={}",
                self.kv_blocks_total,
                self.kv_blocks_peak,
                self.preemptions,
                self.evicted_blocks,
                self.recomputed_tokens,
            ));
        }
        if self.fast_variants > 0 {
            out.push_str(&format!(" | kernels: fast_variants={}", self.fast_variants));
            if self.fast_dense_fallbacks > 0 {
                out.push_str(&format!(
                    " WARNING dense_fallbacks={} (fast mode is running dense \
                     per-linear fallbacks; check packed/rotation recognition)",
                    self.fast_dense_fallbacks,
                ));
            }
        }
        out
    }
}

/// Registry-backed recording handles the executor thread writes into.
///
/// Every method takes `&self` (atomic cells), the names below form the
/// Prometheus exposition, and [`ServingMetrics::snapshot`] materializes
/// the same cells as a [`Metrics`] aggregate for the shutdown report.
pub struct ServingMetrics {
    registry: Arc<Registry>,
    requests: Counter,
    batches: Counter,
    batch_rows: Counter,
    tokens: Counter,
    rejected_too_long: Counter,
    rejected_bad_token: Counter,
    rejected_unknown_variant: Counter,
    rejected_zero_length: Counter,
    rejected_cache_pressure: Counter,
    generations: Counter,
    generation_failures: Counter,
    generated_tokens: Counter,
    decode_steps: Counter,
    decode_seqs: Counter,
    decode_emitted: Counter,
    spec_rounds: Counter,
    spec_drafted: Counter,
    spec_accepted: Counter,
    spec_rejected: Counter,
    cache_tokens: Counter,
    cache_tokens_peak: Gauge,
    prefill_chunks: Counter,
    prefill_tokens: Counter,
    kv_blocks_total: Gauge,
    kv_blocks_peak: Gauge,
    preemptions: Counter,
    evicted_blocks: Counter,
    recomputed_tokens: Counter,
    fast_variants: Gauge,
    fast_dense_fallbacks: Counter,
    request_latency: Histogram,
    exec_latency: Histogram,
    decode_latency: Histogram,
    draft_latency: Histogram,
    verify_latency: Histogram,
    spec_acceptance_pct: Histogram,
}

impl ServingMetrics {
    /// Register every serving family on `registry` and return the
    /// recording handles.
    pub fn new(registry: &Arc<Registry>) -> ServingMetrics {
        let r = registry;
        let reject = |reason: &str| {
            r.counter_with(
                "gsr_rejected_total",
                "Requests refused at admission, by reason",
                &[("reason", reason)],
            )
        };
        ServingMetrics {
            registry: Arc::clone(registry),
            requests: r.counter("gsr_requests_total", "Completed requests (scores + generations)"),
            batches: r.counter("gsr_batches_total", "Scoring batches executed"),
            batch_rows: r.counter("gsr_batch_rows_total", "Rows across executed scoring batches"),
            tokens: r.counter("gsr_tokens_total", "Real (unpadded) tokens scored"),
            rejected_too_long: reject("too_long"),
            rejected_bad_token: reject("bad_token"),
            rejected_unknown_variant: reject("unknown_variant"),
            rejected_zero_length: reject("zero_length"),
            rejected_cache_pressure: reject("cache_pressure"),
            generations: r.counter("gsr_generations_total", "Completed generation requests"),
            generation_failures: r
                .counter("gsr_generation_failures_total", "Generations failed after admission"),
            generated_tokens: r
                .counter("gsr_generated_tokens_total", "Tokens emitted to generation clients"),
            decode_steps: r.counter("gsr_decode_steps_total", "Batched decode rounds executed"),
            decode_seqs: r
                .counter("gsr_decode_seqs_total", "Sequence-steps across decode rounds"),
            decode_emitted: r.counter(
                "gsr_decode_emitted_total",
                "Tokens emitted by decode and speculative verify rounds",
            ),
            spec_rounds: r
                .counter("gsr_spec_rounds_total", "Speculative draft/verify rounds executed"),
            spec_drafted: r
                .counter("gsr_spec_drafted_total", "Draft tokens proposed by the draft variant"),
            spec_accepted: r
                .counter("gsr_spec_accepted_total", "Drafted tokens accepted by target verify"),
            spec_rejected: r
                .counter("gsr_spec_rejected_total", "Drafted tokens rejected by target verify"),
            cache_tokens: r
                .counter("gsr_cache_tokens_total", "Sum of per-round KV occupancy (tokens)"),
            cache_tokens_peak: r
                .gauge("gsr_cache_tokens_peak", "Largest single-round KV occupancy (tokens)"),
            prefill_chunks: r.counter("gsr_prefill_chunks_total", "Prefill chunks executed"),
            prefill_tokens: r
                .counter("gsr_prefill_tokens_total", "Tokens absorbed through prefill chunks"),
            kv_blocks_total: r.gauge("gsr_kv_blocks", "Block-pool inventory across variants"),
            kv_blocks_peak: r
                .gauge("gsr_kv_blocks_peak", "High-water mark of granted KV blocks"),
            preemptions: r.counter("gsr_preemptions_total", "Sequences preempted"),
            evicted_blocks: r
                .counter("gsr_evicted_blocks_total", "Blocks reclaimed by preemption"),
            recomputed_tokens: r
                .counter("gsr_recomputed_tokens_total", "Cached tokens invalidated by preemption"),
            fast_variants: r
                .gauge("gsr_fast_variants", "Variants running the fast kernel path"),
            fast_dense_fallbacks: r.counter(
                "gsr_dense_fallbacks",
                "Per-linear dense fallbacks across fast-mode variants",
            ),
            request_latency: r
                .histogram("gsr_request_latency_us", "Queue-to-reply latency per request (us)"),
            exec_latency: r.histogram(
                "gsr_exec_latency_us",
                "Backend execution latency per call: scoring batches and prefill chunks (us)",
            ),
            decode_latency: r
                .histogram("gsr_decode_latency_us", "Backend latency per batched decode round (us)"),
            draft_latency: r.histogram(
                "gsr_draft_latency_us",
                "Draft-variant forward latency per speculative round (us)",
            ),
            verify_latency: r.histogram(
                "gsr_verify_latency_us",
                "Target verify forward latency per speculative round (us)",
            ),
            spec_acceptance_pct: r.histogram(
                "gsr_spec_acceptance_pct",
                "Per-round draft acceptance rate (percent of drafted tokens kept)",
            ),
        }
    }

    /// The registry these handles live on (for exposition/dumping).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// See [`Metrics::record_batch`].
    pub fn record_batch(&self, batch_size: usize, tokens: u64, exec: Duration) {
        self.batches.inc();
        self.batch_rows.add(batch_size as u64);
        self.tokens.add(tokens);
        self.exec_latency.record(exec);
    }

    /// See [`Metrics::record_request`].
    pub fn record_request(&self, latency: Duration) {
        self.requests.inc();
        self.request_latency.record(latency);
    }

    /// See [`Metrics::record_rejection`].
    pub fn record_rejection(&self, reason: RejectReason) {
        match reason {
            RejectReason::TooLong => self.rejected_too_long.inc(),
            RejectReason::BadToken => self.rejected_bad_token.inc(),
            RejectReason::UnknownVariant => self.rejected_unknown_variant.inc(),
            RejectReason::ZeroLength => self.rejected_zero_length.inc(),
            RejectReason::CachePressure => self.rejected_cache_pressure.inc(),
        }
    }

    /// See [`Metrics::record_prefill`].
    pub fn record_prefill(&self, tokens: u64, exec: Duration) {
        self.prefill_chunks.inc();
        self.prefill_tokens.add(tokens);
        self.exec_latency.record(exec);
    }

    /// See [`Metrics::record_preemption`].
    pub fn record_preemption(&self, blocks: u64, cached_tokens: u64) {
        self.preemptions.inc();
        self.evicted_blocks.add(blocks);
        self.recomputed_tokens.add(cached_tokens);
    }

    /// See [`Metrics::record_decode`].
    pub fn record_decode(&self, seqs: usize, emitted: u64, cache_tokens: u64, exec: Duration) {
        self.decode_steps.inc();
        self.decode_seqs.add(seqs as u64);
        self.decode_emitted.add(emitted);
        self.cache_tokens.add(cache_tokens);
        self.cache_tokens_peak.set_max(cache_tokens);
        self.decode_latency.record(exec);
    }

    /// See [`Metrics::record_spec_round`]; additionally records the
    /// round's acceptance percentage into `gsr_spec_acceptance_pct`.
    pub fn record_spec_round(
        &self,
        drafted: u64,
        accepted: u64,
        emitted: u64,
        draft: Duration,
        verify: Duration,
    ) {
        self.spec_rounds.inc();
        self.spec_drafted.add(drafted);
        self.spec_accepted.add(accepted);
        self.spec_rejected.add(drafted - accepted);
        self.decode_emitted.add(emitted);
        self.draft_latency.record(draft);
        self.verify_latency.record(verify);
        if drafted > 0 {
            self.spec_acceptance_pct.record_us(100 * accepted / drafted);
        }
    }

    /// See [`Metrics::record_generation`].
    pub fn record_generation(&self, emitted: u64, latency: Duration) {
        self.generations.inc();
        self.generated_tokens.add(emitted);
        self.record_request(latency);
    }

    /// Account one generation that failed after admission.
    pub fn record_generation_failure(&self) {
        self.generation_failures.inc();
    }

    /// Add a variant's block-pool inventory to the paged gauge.
    pub fn add_kv_blocks_total(&self, blocks: u64) {
        self.kv_blocks_total.add(blocks);
    }

    /// Raise the granted-blocks high-water mark.
    pub fn bump_kv_blocks_peak(&self, peak: u64) {
        self.kv_blocks_peak.set_max(peak);
    }

    /// Record a variant's kernel-path selection: in fast mode the
    /// per-linear dense fallbacks are exported under a labeled counter
    /// (`gsr_dense_fallbacks_by_variant{variant=...,mode=...}`) and
    /// aggregated for the report's fast-mode warning.
    pub fn record_kernel_path(&self, variant: &str, stats: &FastPathStats) {
        let mode = stats.mode.as_str();
        self.registry
            .counter_with(
                "gsr_dense_fallbacks_by_variant",
                "Per-linear dense fallbacks on the fast kernel path, by variant",
                &[("variant", variant), ("mode", mode)],
            )
            .add(stats.dense_fallbacks as u64);
        if stats.mode == KernelMode::Fast {
            self.fast_variants.add(1);
            self.fast_dense_fallbacks.add(stats.dense_fallbacks as u64);
        }
    }

    /// Materialize every cell as a plain [`Metrics`] aggregate.
    pub fn snapshot(&self) -> Metrics {
        let rejected_too_long = self.rejected_too_long.get();
        let rejected_bad_token = self.rejected_bad_token.get();
        let rejected_unknown_variant = self.rejected_unknown_variant.get();
        let rejected_zero_length = self.rejected_zero_length.get();
        let rejected_cache_pressure = self.rejected_cache_pressure.get();
        Metrics {
            request_latency: self.request_latency.snapshot(),
            exec_latency: self.exec_latency.snapshot(),
            decode_latency: self.decode_latency.snapshot(),
            draft_latency: self.draft_latency.snapshot(),
            verify_latency: self.verify_latency.snapshot(),
            batch_rows: self.batch_rows.get(),
            requests: self.requests.get(),
            batches: self.batches.get(),
            tokens: self.tokens.get(),
            rejected: rejected_too_long
                + rejected_bad_token
                + rejected_unknown_variant
                + rejected_zero_length
                + rejected_cache_pressure,
            rejected_too_long,
            rejected_bad_token,
            rejected_unknown_variant,
            rejected_zero_length,
            rejected_cache_pressure,
            generations: self.generations.get(),
            generation_failures: self.generation_failures.get(),
            generated_tokens: self.generated_tokens.get(),
            decode_steps: self.decode_steps.get(),
            decode_seqs: self.decode_seqs.get(),
            decode_emitted: self.decode_emitted.get(),
            spec_rounds: self.spec_rounds.get(),
            drafted_tokens: self.spec_drafted.get(),
            accepted_draft_tokens: self.spec_accepted.get(),
            rejected_draft_tokens: self.spec_rejected.get(),
            cache_tokens: self.cache_tokens.get(),
            cache_tokens_peak: self.cache_tokens_peak.get(),
            prefill_chunks: self.prefill_chunks.get(),
            prefill_tokens: self.prefill_tokens.get(),
            kv_blocks_total: self.kv_blocks_total.get(),
            kv_blocks_peak: self.kv_blocks_peak.get(),
            preemptions: self.preemptions.get(),
            evicted_blocks: self.evicted_blocks.get(),
            recomputed_tokens: self.recomputed_tokens.get(),
            fast_variants: self.fast_variants.get(),
            fast_dense_fallbacks: self.fast_dense_fallbacks.get(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.record(Duration::from_micros(us));
            }
        }
        assert_eq!(h.count(), 100);
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(0.999));
    }

    #[test]
    fn mean_batch() {
        let mut m = Metrics::default();
        m.record_batch(4, 512, Duration::from_millis(3));
        m.record_batch(2, 256, Duration::from_millis(2));
        for _ in 0..6 {
            m.record_request(Duration::from_millis(4));
        }
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-12);
        assert_eq!(m.requests, 6);
        assert_eq!(m.tokens, 768);
        assert_eq!(m.batches, 2);
        assert_eq!(m.batch_rows, 6);
        assert_eq!(m.exec_latency.count(), 2);
        assert_eq!(m.request_latency.count(), 6);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn decode_metrics_accumulate() {
        let mut m = Metrics::default();
        assert_eq!(m.decode_tok_per_s(), 0.0, "no decode yet");
        m.record_decode(3, 3, 30, Duration::from_millis(10));
        // One of the two picks was a stop token: 2 seq-steps, 1 emitted.
        m.record_decode(2, 1, 24, Duration::from_millis(10));
        m.record_generation(4, Duration::from_millis(25));
        m.record_generation(1, Duration::from_millis(30));
        assert_eq!(m.decode_steps, 2);
        assert_eq!(m.decode_seqs, 5);
        assert_eq!(m.decode_emitted, 4, "stop pick emits nothing");
        assert_eq!(m.cache_tokens, 54);
        assert_eq!(m.cache_tokens_peak, 30);
        assert_eq!(m.generations, 2);
        assert_eq!(m.generated_tokens, 5);
        assert_eq!(m.requests, 2, "generations count as requests");
        // 4 *emitted* tokens over 20ms of decode time = 200 tok/s — the
        // non-emitting stop step no longer inflates throughput.
        assert!((m.decode_tok_per_s() - 200.0).abs() < 1.0);
        assert!(m.report(Duration::from_millis(40)).contains("gen:"));
        let quiet = Metrics::default();
        assert!(!quiet.report(Duration::from_millis(1)).contains("gen:"));
    }

    #[test]
    fn spec_metrics_accumulate_and_report() {
        let mut m = Metrics::default();
        // Round 1: 4 drafted, 4 accepted, bonus pick => 5 emitted.
        m.record_spec_round(4, 4, 5, Duration::from_millis(5), Duration::from_millis(10));
        // Round 2: 4 drafted, 1 accepted, correction pick => 2 emitted.
        m.record_spec_round(4, 1, 2, Duration::from_millis(5), Duration::from_millis(20));
        assert_eq!(m.spec_rounds, 2);
        assert_eq!(m.drafted_tokens, 8);
        assert_eq!(m.accepted_draft_tokens, 5);
        assert_eq!(m.rejected_draft_tokens, 3);
        assert_eq!(m.decode_emitted, 7);
        assert!((m.draft_acceptance() - 5.0 / 8.0).abs() < 1e-12);
        // Throughput charges draft + verify time: 7 tokens over 40ms.
        assert!((m.decode_tok_per_s() - 175.0).abs() < 1.0);
        let report = m.report(Duration::from_millis(50));
        for needle in ["spec: rounds=2", "drafted=8", "accepted=5", "rejected=3", "acceptance=62.5%"]
        {
            assert!(report.contains(needle), "missing {needle} in {report}");
        }
        let quiet = Metrics::default().report(Duration::from_millis(1));
        assert!(!quiet.contains("spec:"), "{quiet}");
    }

    #[test]
    fn rejection_reasons_sum_to_aggregate() {
        let mut m = Metrics::default();
        m.record_rejection(RejectReason::TooLong);
        m.record_rejection(RejectReason::BadToken);
        m.record_rejection(RejectReason::BadToken);
        m.record_rejection(RejectReason::UnknownVariant);
        m.record_rejection(RejectReason::ZeroLength);
        m.record_rejection(RejectReason::CachePressure);
        assert_eq!(m.rejected, 6);
        assert_eq!(
            m.rejected_too_long
                + m.rejected_bad_token
                + m.rejected_unknown_variant
                + m.rejected_zero_length
                + m.rejected_cache_pressure,
            m.rejected
        );
        let report = m.report(Duration::from_millis(1));
        assert!(report.contains("bad_token=2"), "{report}");
        assert!(report.contains("cache_pressure=1"), "{report}");
        assert!(!Metrics::default().report(Duration::from_millis(1)).contains("too_long"));
    }

    #[test]
    fn report_surfaces_quantiles_and_paged_counters() {
        let mut m = Metrics::default();
        m.record_batch(2, 64, Duration::from_millis(2));
        m.record_request(Duration::from_millis(3));
        m.record_decode(2, 2, 20, Duration::from_millis(1));
        m.record_prefill(16, Duration::from_millis(2));
        m.record_preemption(2, 24);
        m.kv_blocks_total = 8;
        m.kv_blocks_peak = 5;
        let report = m.report(Duration::from_millis(10));
        for needle in [
            "req p50=",
            "exec p50=",
            "step p50=",
            "p99=",
            "paged: pool=8",
            "preemptions=1",
            "evicted_blocks=2",
            "recomputed_tokens=24",
            "prefill chunks=1 tokens=16",
        ] {
            assert!(report.contains(needle), "missing {needle} in {report}");
        }
        assert_eq!(m.prefill_chunks, 1);
        assert_eq!(m.exec_latency.count(), 2, "prefill shares exec latency");
        let quiet = Metrics::default().report(Duration::from_millis(1));
        assert!(!quiet.contains("paged:"), "{quiet}");
    }

    #[test]
    fn serving_metrics_snapshot_matches_plain_recording() {
        let registry = Arc::new(Registry::new());
        let s = ServingMetrics::new(&registry);
        s.record_batch(4, 512, Duration::from_millis(3));
        s.record_request(Duration::from_millis(4));
        s.record_rejection(RejectReason::BadToken);
        s.record_prefill(16, Duration::from_millis(2));
        s.record_preemption(2, 24);
        s.record_decode(3, 3, 30, Duration::from_millis(10));
        s.record_spec_round(4, 2, 3, Duration::from_millis(2), Duration::from_millis(6));
        s.record_generation(5, Duration::from_millis(25));
        s.record_generation_failure();
        s.add_kv_blocks_total(8);
        s.bump_kv_blocks_peak(5);
        let m = s.snapshot();
        assert_eq!(m.batches, 1);
        assert_eq!(m.batch_rows, 4);
        assert_eq!(m.tokens, 512);
        assert_eq!(m.requests, 2, "score reply + finished generation");
        assert_eq!(m.rejected, 1);
        assert_eq!(m.rejected_bad_token, 1);
        assert_eq!(m.prefill_chunks, 1);
        assert_eq!(m.prefill_tokens, 16);
        assert_eq!(m.preemptions, 1);
        assert_eq!(m.evicted_blocks, 2);
        assert_eq!(m.recomputed_tokens, 24);
        assert_eq!(m.decode_steps, 1);
        assert_eq!(m.decode_seqs, 3);
        assert_eq!(m.decode_emitted, 6, "3 decode picks + 3 spec emissions");
        assert_eq!(m.spec_rounds, 1);
        assert_eq!(m.drafted_tokens, 4);
        assert_eq!(m.accepted_draft_tokens, 2);
        assert_eq!(m.rejected_draft_tokens, 2);
        assert_eq!(m.draft_latency.count(), 1);
        assert_eq!(m.verify_latency.count(), 1);
        assert_eq!(m.cache_tokens_peak, 30);
        assert_eq!(m.generations, 1);
        assert_eq!(m.generation_failures, 1);
        assert_eq!(m.generated_tokens, 5);
        assert_eq!(m.kv_blocks_total, 8);
        assert_eq!(m.kv_blocks_peak, 5);
        assert_eq!(m.exec_latency.count(), 2, "batch + prefill share exec latency");
        // The same cells feed the Prometheus exposition.
        let text = registry.expose_prometheus();
        for family in [
            "# TYPE gsr_requests_total counter",
            "# TYPE gsr_request_latency_us histogram",
            "gsr_rejected_total{reason=\"bad_token\"} 1",
            "gsr_kv_blocks 8",
            "gsr_spec_drafted_total 4",
            "gsr_spec_rejected_total 2",
            "# TYPE gsr_spec_acceptance_pct histogram",
        ] {
            assert!(text.contains(family), "missing {family} in exposition");
        }
    }

    #[test]
    fn fast_fallback_warning_in_report() {
        let mut m = Metrics::default();
        m.fast_variants = 1;
        let clean = m.report(Duration::from_millis(1));
        assert!(clean.contains("kernels: fast_variants=1"), "{clean}");
        assert!(!clean.contains("WARNING"), "{clean}");
        m.fast_dense_fallbacks = 3;
        let warn = m.report(Duration::from_millis(1));
        assert!(warn.contains("WARNING dense_fallbacks=3"), "{warn}");
        let quiet = Metrics::default().report(Duration::from_millis(1));
        assert!(!quiet.contains("kernels:"), "{quiet}");
    }
}
