//! Streaming activation capture: corpus sequences → per-linear Hessians.
//!
//! Runs the native rotated forward (`model::forward::forward_quant_tapped`)
//! over calibration sequences with taps at every linear's input and
//! accumulates `XᵀX` into mergeable per-thread partials. The fan-out
//! mirrors the search planner's worker model (`std::thread::scope` over
//! an atomic cursor), but the unit of work is a **partial**, not a
//! sequence: partial `p` owns sequences `p, p + N, p + 2N, …` for a
//! fixed partial count `N`, and partials merge in index order — so the
//! captured Hessians are bit-identical for any `--threads` value.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::hessian::{CaptureKey, HessianSet};
use crate::config::cli::resolve_threads;
use crate::model::config::ModelCfg;
use crate::model::forward::{forward_quant_tapped, ActivationTap, TapSite};
use crate::model::weights::QuantParams;

/// Calibration knobs (`gsr calibrate` flags map 1:1 onto this).
#[derive(Debug, Clone, Copy)]
pub struct CalibCfg {
    /// Number of corpus sequences to stream.
    pub n_seqs: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
    /// Seed for drawing sequence offsets (recorded in the artifact).
    pub seed: u64,
    /// Worker threads (0 = available parallelism).
    pub threads: usize,
}

impl Default for CalibCfg {
    fn default() -> Self {
        Self { n_seqs: 32, seq_len: 64, seed: 0xCA11B, threads: 0 }
    }
}

/// Number of mergeable partials, fixed independently of the worker
/// count so the merged result does not depend on `--threads`.
const N_PARTIALS: usize = 8;

/// Tap that accumulates every recorded activation row into a partial
/// [`HessianSet`].
struct SetTap<'a> {
    set: &'a mut HessianSet,
}

impl ActivationTap for SetTap<'_> {
    fn record(&mut self, layer: usize, site: TapSite, rows: &[f32], width: usize) {
        let acc = self.set.layers[layer].site_mut(site);
        for row in rows.chunks(width) {
            acc.add_row(row);
        }
    }
}

/// Stream `seqs` through the fused rotated forward of `params` and
/// accumulate per-linear input Hessians.
///
/// `params` should be the **exact-dense** fusion (`fuse_to_dense` /
/// `fuse_to_dense_plan`) of the checkpoint named by
/// `key.checkpoint_fingerprint`, under the rotation basis named by
/// `key.basis_fingerprint`: with no fake-quant in the loop the tapped
/// activations are exactly the rotated-basis fp activations.
pub fn capture_hessians(
    cfg: &ModelCfg,
    params: &QuantParams,
    seqs: &[Vec<i32>],
    threads: usize,
    key: &CaptureKey,
) -> HessianSet {
    let n_partials = N_PARTIALS.min(seqs.len()).max(1);
    let cursor = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<HessianSet>>> = Mutex::new((0..n_partials).map(|_| None).collect());
    let n_threads = resolve_threads(threads).min(n_partials);
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| loop {
                let p = cursor.fetch_add(1, Ordering::Relaxed);
                if p >= n_partials {
                    break;
                }
                let mut part = HessianSet::new(cfg, key);
                let mut idx = p;
                while idx < seqs.len() {
                    let seq = &seqs[idx];
                    if !seq.is_empty() {
                        let mut tap = SetTap { set: &mut part };
                        let _ = forward_quant_tapped(cfg, params, None, seq, &mut tap);
                        part.tokens += seq.len() as u64;
                    }
                    idx += n_partials;
                }
                slots.lock().unwrap()[p] = Some(part);
            });
        }
    });
    // A worker panic propagates out of thread::scope before this line.
    let slots = slots.into_inner().unwrap_or_else(|p| p.into_inner());
    let mut out = HessianSet::new(cfg, key);
    for part in slots.into_iter().flatten() {
        out.merge(&part);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::draw_token_windows;
    use crate::model::weights::FpParams;
    use crate::quant::{build_plan_rotations, fuse_to_dense_plan, RotationPlan, RotationSpec};

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn captured_set(cfg: &ModelCfg, threads: usize) -> HessianSet {
        let fp = FpParams::synthetic(cfg, 3);
        let plan = RotationPlan::uniform(RotationSpec::baseline(cfg), cfg.n_layers, 11);
        let rots = build_plan_rotations(cfg, &plan).unwrap();
        let params = fuse_to_dense_plan(&fp, cfg, &rots);
        let corpus = crate::data::CorpusGenerator::new(5).generate(2048);
        let seqs = draw_token_windows(&corpus, 6, 12, cfg.vocab, 9);
        let key = CaptureKey {
            calib_seed: 9,
            basis_fingerprint: plan.fingerprint(),
            checkpoint_fingerprint: crate::calib::checkpoint_fingerprint(&fp),
            plan_json: String::new(),
        };
        capture_hessians(cfg, &params, &seqs, threads, &key)
    }

    #[test]
    fn capture_counts_tokens_and_fills_all_sites() {
        let cfg = tiny_cfg();
        let set = captured_set(&cfg, 2);
        assert_eq!(set.tokens, 6 * 12);
        assert_eq!(set.layers.len(), cfg.n_layers);
        for l in 0..cfg.n_layers {
            for site in TapSite::ALL {
                let acc = set.layers[l].site(site);
                let diag_sum: f64 = (0..acc.dim).map(|i| acc.data[i * acc.dim + i]).sum();
                assert!(
                    diag_sum > 0.0,
                    "layer {l} site {site:?} saw no activation energy"
                );
            }
        }
    }

    #[test]
    fn capture_is_bit_deterministic_across_thread_counts() {
        let cfg = tiny_cfg();
        let a = captured_set(&cfg, 1);
        let b = captured_set(&cfg, 4);
        assert_eq!(a, b, "thread count must not change the captured Hessians");
    }

    #[test]
    fn hessians_are_psd_on_diagonal_and_symmetric_after_to_mat() {
        let cfg = tiny_cfg();
        let set = captured_set(&cfg, 0);
        let m = set.hessian_mat(1, "wdown");
        assert_eq!((m.rows, m.cols), (cfg.d_ffn, cfg.d_ffn));
        for i in 0..m.rows {
            assert!(m[(i, i)] >= 0.0);
            for j in 0..m.cols {
                assert_eq!(m[(i, j)].to_bits(), m[(j, i)].to_bits());
            }
        }
    }
}
