//! Streaming activation capture: corpus sequences → per-linear Hessians.
//!
//! Runs the native rotated forward with taps at every linear's input
//! (`model::forward::forward_quant_tapped_with`) over calibration
//! sequences and accumulates `XᵀX` into mergeable partials. Capture is
//! scheduled on the same [`exec::ExecPool`](crate::exec::ExecPool) that
//! serves batched scoring — long-lived workers with reusable scratch
//! buffers — but the unit of work is a **partial**, not a sequence:
//! partial `p` owns sequences `p, p + N, p + 2N, …` for a fixed partial
//! count `N`, and partials merge in index order — so the captured
//! Hessians are bit-identical for any `--threads` value.

use std::sync::Arc;

use super::hessian::{CaptureKey, HessianSet};
use crate::exec::NativeBackend;
use crate::model::config::ModelCfg;
use crate::model::forward::{forward_quant_tapped_with, ActivationTap, TapSite};
use crate::model::weights::QuantParams;
use crate::model::DenseModel;

/// Calibration knobs (`gsr calibrate` flags map 1:1 onto this).
#[derive(Debug, Clone, Copy)]
pub struct CalibCfg {
    /// Number of corpus sequences to stream.
    pub n_seqs: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
    /// Seed for drawing sequence offsets (recorded in the artifact).
    pub seed: u64,
    /// Worker threads (0 = available parallelism).
    pub threads: usize,
}

impl Default for CalibCfg {
    fn default() -> Self {
        Self { n_seqs: 32, seq_len: 64, seed: 0xCA11B, threads: 0 }
    }
}

/// Number of mergeable partials, fixed independently of the worker
/// count so the merged result does not depend on `--threads`.
const N_PARTIALS: usize = 8;

/// Tap that accumulates every recorded activation row into a partial
/// [`HessianSet`].
struct SetTap<'a> {
    set: &'a mut HessianSet,
}

impl ActivationTap for SetTap<'_> {
    fn record(&mut self, layer: usize, site: TapSite, rows: &[f32], width: usize) {
        let acc = self.set.layers[layer].site_mut(site);
        for row in rows.chunks(width) {
            acc.add_row(row);
        }
    }
}

/// Stream `seqs` through the backend's fused rotated model with
/// activation taps and accumulate per-linear input Hessians, scheduling
/// the partials on the backend's worker pool.
///
/// The backend must hold a `DenseModel::Quant` — the **exact-dense**
/// fusion (`fuse_to_dense` / `fuse_to_dense_plan`) of the checkpoint
/// named by `key.checkpoint_fingerprint`, under the rotation basis named
/// by `key.basis_fingerprint`. The capture always runs without
/// fake-quant (`a_bits = None`), so the tapped activations are exactly
/// the rotated-basis fp activations.
pub fn capture_hessians_on(
    backend: &NativeBackend,
    seqs: Arc<Vec<Vec<i32>>>,
    key: &CaptureKey,
) -> Result<HessianSet, String> {
    let model = Arc::clone(backend.model());
    if !matches!(&*model, DenseModel::Quant { .. }) {
        return Err("calibration capture needs a fused (quant-layout) model".to_string());
    }
    let cfg = model.cfg().clone();
    // Validate up front, like `forward_batch`: a bad token id must be
    // this call's error, not a panic that kills a shared pool worker.
    for seq in seqs.iter() {
        crate::model::tokens_in_vocab(seq, cfg.vocab)
            .map_err(|e| format!("calibration sequence: {e}"))?;
    }
    let n_partials = N_PARTIALS.min(seqs.len()).max(1);
    let jobs: Vec<_> = (0..n_partials)
        .map(|p| {
            let model = Arc::clone(&model);
            let seqs = Arc::clone(&seqs);
            let cfg = cfg.clone();
            let key = key.clone();
            move |scratch: &mut crate::model::ForwardScratch| {
                let params = match &*model {
                    DenseModel::Quant { params, .. } => params,
                    DenseModel::Fp { .. } => unreachable!("checked above"),
                };
                let mut part = HessianSet::new(&cfg, &key);
                let mut idx = p;
                while idx < seqs.len() {
                    let seq = &seqs[idx];
                    if !seq.is_empty() {
                        let mut tap = SetTap { set: &mut part };
                        let _ =
                            forward_quant_tapped_with(&cfg, params, None, seq, &mut tap, scratch);
                        part.tokens += seq.len() as u64;
                    }
                    idx += n_partials;
                }
                part
            }
        })
        .collect();
    // `run_jobs` returns partials in index order regardless of which
    // worker ran what — the merge below is therefore deterministic.
    let parts = backend.pool().run_jobs(jobs)?;
    let mut out = HessianSet::new(&cfg, key);
    for part in &parts {
        out.merge(part);
    }
    Ok(out)
}

/// Convenience wrapper over [`capture_hessians_on`] for callers that
/// hold raw borrowed data: clones the params and sequences into a
/// backend with its own pool (the `_on` form is the zero-copy path).
pub fn capture_hessians(
    cfg: &ModelCfg,
    params: &QuantParams,
    seqs: &[Vec<i32>],
    threads: usize,
    key: &CaptureKey,
) -> HessianSet {
    let model = Arc::new(DenseModel::Quant {
        cfg: cfg.clone(),
        params: params.clone(),
        a_bits: None,
    });
    let seq_len = seqs.iter().map(|s| s.len()).max().unwrap_or(1).max(1);
    let backend = NativeBackend::new(model, 1, seq_len, threads);
    capture_hessians_on(&backend, Arc::new(seqs.to_vec()), key)
        .expect("capture on a fused quant model cannot fail")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::draw_token_windows;
    use crate::model::weights::FpParams;
    use crate::quant::{build_plan_rotations, fuse_to_dense_plan, RotationPlan, RotationSpec};

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn captured_set(cfg: &ModelCfg, threads: usize) -> HessianSet {
        let fp = FpParams::synthetic(cfg, 3);
        let plan = RotationPlan::uniform(RotationSpec::baseline(cfg), cfg.n_layers, 11);
        let rots = build_plan_rotations(cfg, &plan).unwrap();
        let params = fuse_to_dense_plan(&fp, cfg, &rots);
        let corpus = crate::data::CorpusGenerator::new(5).generate(2048);
        let seqs = draw_token_windows(&corpus, 6, 12, cfg.vocab, 9);
        let key = CaptureKey {
            calib_seed: 9,
            basis_fingerprint: plan.fingerprint(),
            checkpoint_fingerprint: crate::calib::checkpoint_fingerprint(&fp),
            plan_json: String::new(),
        };
        capture_hessians(cfg, &params, &seqs, threads, &key)
    }

    #[test]
    fn capture_counts_tokens_and_fills_all_sites() {
        let cfg = tiny_cfg();
        let set = captured_set(&cfg, 2);
        assert_eq!(set.tokens, 6 * 12);
        assert_eq!(set.layers.len(), cfg.n_layers);
        for l in 0..cfg.n_layers {
            for site in TapSite::ALL {
                let acc = set.layers[l].site(site);
                let diag_sum: f64 = (0..acc.dim).map(|i| acc.data[i * acc.dim + i]).sum();
                assert!(
                    diag_sum > 0.0,
                    "layer {l} site {site:?} saw no activation energy"
                );
            }
        }
    }

    #[test]
    fn capture_is_bit_deterministic_across_thread_counts() {
        let cfg = tiny_cfg();
        let a = captured_set(&cfg, 1);
        let b = captured_set(&cfg, 4);
        assert_eq!(a, b, "thread count must not change the captured Hessians");
    }

    /// Capture through a shared serving backend agrees exactly with the
    /// standalone wrapper — calibration and scoring really share one
    /// execution engine.
    #[test]
    fn capture_on_serving_backend_matches_wrapper() {
        let cfg = tiny_cfg();
        let fp = FpParams::synthetic(&cfg, 3);
        let plan = RotationPlan::uniform(RotationSpec::baseline(&cfg), cfg.n_layers, 11);
        let rots = build_plan_rotations(&cfg, &plan).unwrap();
        let params = fuse_to_dense_plan(&fp, &cfg, &rots);
        let corpus = crate::data::CorpusGenerator::new(5).generate(2048);
        let seqs = draw_token_windows(&corpus, 6, 12, cfg.vocab, 9);
        let key = CaptureKey {
            calib_seed: 9,
            basis_fingerprint: plan.fingerprint(),
            checkpoint_fingerprint: crate::calib::checkpoint_fingerprint(&fp),
            plan_json: String::new(),
        };
        let model = Arc::new(DenseModel::Quant {
            cfg: cfg.clone(),
            params: params.clone(),
            a_bits: None,
        });
        use crate::exec::Backend as _;
        let backend = NativeBackend::new(model, 2, 12, 3);
        // The backend also serves scoring before and after the capture.
        let tokens: Vec<i32> = (0..24).map(|i| (i % 64) as i32).collect();
        let before = backend.forward_batch(&tokens).unwrap();
        let via_backend = capture_hessians_on(&backend, Arc::new(seqs.clone()), &key).unwrap();
        let after = backend.forward_batch(&tokens).unwrap();
        assert_eq!(before, after, "capture must not disturb scoring");
        let via_wrapper = capture_hessians(&cfg, &params, &seqs, 2, &key);
        assert_eq!(via_backend, via_wrapper);
    }

    #[test]
    fn hessians_are_psd_on_diagonal_and_symmetric_after_to_mat() {
        let cfg = tiny_cfg();
        let set = captured_set(&cfg, 0);
        let m = set.hessian_mat(1, "wdown");
        assert_eq!((m.rows, m.cols), (cfg.d_ffn, cfg.d_ffn));
        for i in 0..m.rows {
            assert!(m[(i, i)] >= 0.0);
            for j in 0..m.cols {
                assert_eq!(m[(i, j)].to_bits(), m[(j, i)].to_bits());
            }
        }
    }
}
