//! Streaming activation Hessians and their on-disk artifact.
//!
//! A Hessian here is the GPTQ calibration statistic `H = XᵀX` over the
//! rows of every activation matrix a fused linear consumes, accumulated
//! **in the rotated basis that linear quantizes in** (see
//! `model::forward::TapSite`). Accumulators are mergeable partials:
//! capture fans sequences out over worker threads, each worker owns a
//! partial, and partials merge in a fixed order (addition is
//! commutative, so any merge order agrees up to fp associativity — a
//! property the proptests pin down).
//!
//! The [`HessianSet`] artifact is versioned and keyed by model geometry,
//! the calibration seed, a fingerprint of the rotation basis it was
//! captured in, and a fingerprint of the checkpoint it was streamed
//! from — so a calibration run is reusable across `gsr quantize-native
//! --calib` and `gsr search --calib` invocations but can never be
//! silently applied to a mismatched basis or checkpoint.

use std::fs;
use std::path::Path;

use crate::model::config::ModelCfg;
use crate::model::forward::TapSite;
use crate::model::weights::FpParams;
use crate::rng::SplitMix64;
use crate::transform::Mat;

/// Artifact magic + version (bump on any layout change).
const MAGIC: [u8; 4] = *b"GSRH";
const VERSION: u32 = 1;

/// Order-sensitive 64-bit fingerprint over every tensor of an fp
/// checkpoint — the third component of the calibration-artifact key.
/// Geometry and rotation basis alone cannot distinguish two different
/// checkpoints with identical shapes, and activations captured on one
/// checkpoint must never silently calibrate another.
pub fn checkpoint_fingerprint(fp: &FpParams) -> u64 {
    fn fold(acc: u64, t: &[f32]) -> u64 {
        let mut h = acc ^ t.len() as u64;
        for &v in t {
            h = h.rotate_left(25) ^ u64::from(v.to_bits()).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        }
        SplitMix64::new(h).next_u64()
    }
    let mut acc = SplitMix64::new(0xC4EC_4B01_F1D6_E521).next_u64();
    acc = fold(acc, &fp.embed);
    acc = fold(acc, &fp.lm_head);
    acc = fold(acc, &fp.ln_f);
    for layer in &fp.layers {
        for t in [
            &layer.ln1, &layer.ln2, &layer.wq, &layer.wk, &layer.wv, &layer.wo, &layer.wgate,
            &layer.wup, &layer.wdown,
        ] {
            acc = fold(acc, t);
        }
    }
    acc
}

/// Provenance stamped into a captured [`HessianSet`]: the calibration
/// seed, the rotation-basis fingerprint (with the plan that built it),
/// and the checkpoint fingerprint.
#[derive(Debug, Clone, Default)]
pub struct CaptureKey {
    /// Seed the calibration sequences were drawn with.
    pub calib_seed: u64,
    /// `RotationPlan::fingerprint()` of the capture basis.
    pub basis_fingerprint: u64,
    /// [`checkpoint_fingerprint`] of the checkpoint being streamed;
    /// `0` = unknown (ad-hoc in-process capture, checkpoint unchecked).
    pub checkpoint_fingerprint: u64,
    /// JSON of the capture `RotationPlan` (empty for ad-hoc captures
    /// that never leave the basis they were taken in).
    pub plan_json: String,
}

/// One streaming `XᵀX` accumulator over `dim`-wide activation rows.
///
/// Only the upper triangle is accumulated (the statistic is symmetric);
/// [`HessianAccum::to_mat`] mirrors it into a full matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct HessianAccum {
    pub dim: usize,
    /// Row-major `[dim, dim]`, lower triangle identically zero.
    pub data: Vec<f64>,
}

impl HessianAccum {
    pub fn new(dim: usize) -> Self {
        Self { dim, data: vec![0.0; dim * dim] }
    }

    /// Rank-1 update `H += x xᵀ` (upper triangle).
    pub fn add_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.dim);
        let d = self.dim;
        for (i, &xi) in row.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let xi = xi as f64;
            let out = &mut self.data[i * d + i..(i + 1) * d];
            for (o, &xj) in out.iter_mut().zip(&row[i..]) {
                *o += xi * xj as f64;
            }
        }
    }

    /// Elementwise sum with another partial (commutative; associative up
    /// to fp rounding).
    pub fn merge(&mut self, other: &HessianAccum) {
        assert_eq!(self.dim, other.dim, "Hessian partial dim mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Symmetrized full matrix, averaged over `tokens` rows (GPTQ is
    /// invariant to the overall Hessian scale; averaging just keeps the
    /// numbers in a friendly range).
    pub fn to_mat(&self, tokens: u64) -> Mat {
        let d = self.dim;
        let norm = 1.0 / tokens.max(1) as f64;
        let mut m = Mat::zeros(d, d);
        for i in 0..d {
            for j in i..d {
                let v = self.data[i * d + j] * norm;
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }
}

/// The four per-layer activation Hessians, one per [`TapSite`].
#[derive(Debug, Clone, PartialEq)]
pub struct LayerHessians {
    pub attn_in: HessianAccum,
    pub o_in: HessianAccum,
    pub ffn_in: HessianAccum,
    pub down_in: HessianAccum,
}

impl LayerHessians {
    pub fn new(cfg: &ModelCfg) -> Self {
        Self {
            attn_in: HessianAccum::new(cfg.d_model),
            o_in: HessianAccum::new(cfg.d_model),
            ffn_in: HessianAccum::new(cfg.d_model),
            down_in: HessianAccum::new(cfg.d_ffn),
        }
    }

    pub fn site(&self, site: TapSite) -> &HessianAccum {
        match site {
            TapSite::AttnIn => &self.attn_in,
            TapSite::OIn => &self.o_in,
            TapSite::FfnIn => &self.ffn_in,
            TapSite::DownIn => &self.down_in,
        }
    }

    pub fn site_mut(&mut self, site: TapSite) -> &mut HessianAccum {
        match site {
            TapSite::AttnIn => &mut self.attn_in,
            TapSite::OIn => &mut self.o_in,
            TapSite::FfnIn => &mut self.ffn_in,
            TapSite::DownIn => &mut self.down_in,
        }
    }

    /// The tap site whose activations feed a named linear.
    pub fn site_of_linear(name: &str) -> TapSite {
        match name {
            "wq" | "wk" | "wv" => TapSite::AttnIn,
            "wo" => TapSite::OIn,
            "wgate" | "wup" => TapSite::FfnIn,
            "wdown" => TapSite::DownIn,
            other => panic!("unknown linear {other}"),
        }
    }

    /// The accumulator for a named linear's input channels.
    pub fn for_linear(&self, name: &str) -> &HessianAccum {
        self.site(Self::site_of_linear(name))
    }

    pub fn merge(&mut self, other: &LayerHessians) {
        self.attn_in.merge(&other.attn_in);
        self.o_in.merge(&other.o_in);
        self.ffn_in.merge(&other.ffn_in);
        self.down_in.merge(&other.down_in);
    }
}

/// A full calibration artifact: per-layer activation Hessians plus the
/// provenance needed to reuse them safely (model geometry, calibration
/// seed, rotation-basis fingerprint, checkpoint fingerprint, and the
/// capture plan itself so the search objective can change basis).
#[derive(Debug, Clone, PartialEq)]
pub struct HessianSet {
    pub d_model: usize,
    pub d_ffn: usize,
    pub n_layers: usize,
    /// Seed the calibration sequences were drawn with.
    pub calib_seed: u64,
    /// `RotationPlan::fingerprint()` of the basis the activations were
    /// captured in.
    pub basis_fingerprint: u64,
    /// [`checkpoint_fingerprint`] of the captured checkpoint (0 =
    /// unknown, checkpoint unchecked).
    pub checkpoint_fingerprint: u64,
    /// JSON of the capture [`RotationPlan`] (empty for ad-hoc in-process
    /// captures that never leave the basis they were taken in).
    pub plan_json: String,
    /// Total activation rows accumulated per site.
    pub tokens: u64,
    pub layers: Vec<LayerHessians>,
}

impl HessianSet {
    pub fn new(cfg: &ModelCfg, key: &CaptureKey) -> Self {
        Self {
            d_model: cfg.d_model,
            d_ffn: cfg.d_ffn,
            n_layers: cfg.n_layers,
            calib_seed: key.calib_seed,
            basis_fingerprint: key.basis_fingerprint,
            checkpoint_fingerprint: key.checkpoint_fingerprint,
            plan_json: key.plan_json.clone(),
            tokens: 0,
            layers: (0..cfg.n_layers).map(|_| LayerHessians::new(cfg)).collect(),
        }
    }

    /// Merge another partial captured under the same key.
    pub fn merge(&mut self, other: &HessianSet) {
        assert_eq!(
            (self.d_model, self.d_ffn, self.n_layers),
            (other.d_model, other.d_ffn, other.n_layers),
            "Hessian partial geometry mismatch"
        );
        assert_eq!(self.basis_fingerprint, other.basis_fingerprint, "basis mismatch in merge");
        self.tokens += other.tokens;
        for (a, b) in self.layers.iter_mut().zip(&other.layers) {
            a.merge(b);
        }
    }

    /// Checkpoint check: the consumer's fp checkpoint must be the one
    /// the activations were streamed from (skipped for ad-hoc captures
    /// that never recorded one).
    pub fn check_checkpoint(&self, fp: &FpParams) -> Result<(), String> {
        if self.checkpoint_fingerprint == 0 {
            return Ok(());
        }
        let got = checkpoint_fingerprint(fp);
        if self.checkpoint_fingerprint != got {
            return Err(format!(
                "Hessian artifact was captured on checkpoint {:016x}, but the model \
                 being quantized is {got:016x} — re-run `gsr calibrate` on this checkpoint",
                self.checkpoint_fingerprint
            ));
        }
        Ok(())
    }

    /// Geometry check against the model about to consume these Hessians.
    pub fn check_model(&self, cfg: &ModelCfg) -> Result<(), String> {
        if (self.d_model, self.d_ffn, self.n_layers) != (cfg.d_model, cfg.d_ffn, cfg.n_layers) {
            return Err(format!(
                "Hessian artifact was captured for d_model={} d_ffn={} n_layers={}, \
                 model is d_model={} d_ffn={} n_layers={}",
                self.d_model, self.d_ffn, self.n_layers, cfg.d_model, cfg.d_ffn, cfg.n_layers
            ));
        }
        if self.tokens == 0 {
            return Err(
                "Hessian artifact holds zero activation rows — re-run `gsr calibrate` \
                 with a non-empty corpus"
                    .to_string(),
            );
        }
        Ok(())
    }

    /// Basis check: the consumer's rotation plan must be the one the
    /// activations were captured under.
    pub fn check_basis(&self, fingerprint: u64) -> Result<(), String> {
        if self.basis_fingerprint != fingerprint {
            return Err(format!(
                "Hessian artifact basis fingerprint {:016x} does not match the \
                 requested rotation basis {:016x} — re-run `gsr calibrate` with the \
                 same plan/flags you are quantizing with",
                self.basis_fingerprint, fingerprint
            ));
        }
        Ok(())
    }

    /// Averaged, symmetrized Hessian for one layer's named linear.
    pub fn hessian_mat(&self, layer: usize, linear: &str) -> Mat {
        self.layers[layer].for_linear(linear).to_mat(self.tokens)
    }

    // -- binary artifact ---------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<(), String> {
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        for v in [
            self.d_model as u64,
            self.d_ffn as u64,
            self.n_layers as u64,
            self.calib_seed,
            self.basis_fingerprint,
            self.checkpoint_fingerprint,
            self.tokens,
            self.plan_json.len() as u64,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(self.plan_json.as_bytes());
        for layer in &self.layers {
            for site in TapSite::ALL {
                let acc = layer.site(site);
                out.extend_from_slice(&(acc.dim as u64).to_le_bytes());
                // The accumulator's lower triangle is identically zero
                // (see `HessianAccum`), so only the dim·(dim+1)/2 upper
                // entries travel to disk — half the artifact size.
                for i in 0..acc.dim {
                    for v in &acc.data[i * acc.dim + i..(i + 1) * acc.dim] {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        fs::write(path, &out).map_err(|e| format!("{path:?}: {e}"))
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8], String> {
            let end = pos.checked_add(n).filter(|&e| e <= bytes.len());
            if end.is_none() {
                return Err(format!("Hessian artifact truncated at byte {}", *pos));
            }
            let s = &bytes[*pos..*pos + n];
            *pos += n;
            Ok(s)
        }
        fn read_u64(bytes: &[u8], pos: &mut usize) -> Result<u64, String> {
            Ok(u64::from_le_bytes(take(bytes, pos, 8)?.try_into().unwrap()))
        }
        fn read_acc(bytes: &[u8], pos: &mut usize, expect: usize) -> Result<HessianAccum, String> {
            let dim = read_u64(bytes, pos)? as usize;
            if dim != expect {
                return Err(format!("Hessian block dim {dim}, expected {expect}"));
            }
            // Upper triangle only on disk; the lower stays zero exactly
            // as the streaming accumulator leaves it.
            let raw = take(bytes, pos, dim * (dim + 1) / 2 * 8)?;
            let mut vals = raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap()));
            let mut data = vec![0.0; dim * dim];
            for i in 0..dim {
                for j in i..dim {
                    data[i * dim + j] = vals.next().expect("triangle length checked");
                }
            }
            Ok(HessianAccum { dim, data })
        }

        let bytes = fs::read(path).map_err(|e| format!("{path:?}: {e}"))?;
        let mut pos = 0usize;
        if take(&bytes, &mut pos, 4)? != &MAGIC[..] {
            return Err("not a Hessian artifact (bad magic; expected GSRH)".into());
        }
        let ver = u32::from_le_bytes(take(&bytes, &mut pos, 4)?.try_into().unwrap());
        if ver != VERSION {
            return Err(format!("Hessian artifact version {ver}, this build reads {VERSION}"));
        }
        let d_model = read_u64(&bytes, &mut pos)? as usize;
        let d_ffn = read_u64(&bytes, &mut pos)? as usize;
        let n_layers = read_u64(&bytes, &mut pos)? as usize;
        let calib_seed = read_u64(&bytes, &mut pos)?;
        let basis_fingerprint = read_u64(&bytes, &mut pos)?;
        let checkpoint_fingerprint = read_u64(&bytes, &mut pos)?;
        let tokens = read_u64(&bytes, &mut pos)?;
        let plan_len = read_u64(&bytes, &mut pos)? as usize;
        // Corrupt-header guard: every block the header promises must fit
        // in the file, BEFORE any allocation sized from header fields —
        // a bit-flipped count must come back as Err, never a panic or an
        // oversized allocation. Sites store their upper triangle only.
        fn tri(d: usize) -> Option<usize> {
            d.checked_add(1).and_then(|d1| d.checked_mul(d1)).map(|x| x / 2)
        }
        let per_layer = tri(d_model)
            .and_then(|td| td.checked_mul(3))
            .and_then(|t3| tri(d_ffn).and_then(|tf| t3.checked_add(tf)))
            .and_then(|e| e.checked_mul(8))
            .and_then(|e| e.checked_add(4 * 8))
            .ok_or("Hessian artifact header dims overflow")?;
        let body = n_layers
            .checked_mul(per_layer)
            .ok_or("Hessian artifact header dims overflow")?;
        let promised = pos
            .checked_add(plan_len)
            .and_then(|p| p.checked_add(body))
            .ok_or("Hessian artifact header sizes overflow")?;
        if promised != bytes.len() {
            return Err(format!(
                "Hessian artifact header promises {promised} bytes, file has {}",
                bytes.len()
            ));
        }
        let plan_json = String::from_utf8(take(&bytes, &mut pos, plan_len)?.to_vec())
            .map_err(|_| "Hessian artifact plan is not UTF-8".to_string())?;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let attn_in = read_acc(&bytes, &mut pos, d_model)?;
            let o_in = read_acc(&bytes, &mut pos, d_model)?;
            let ffn_in = read_acc(&bytes, &mut pos, d_model)?;
            let down_in = read_acc(&bytes, &mut pos, d_ffn)?;
            layers.push(LayerHessians { attn_in, o_in, ffn_in, down_in });
        }
        if pos != bytes.len() {
            return Err(format!("Hessian artifact has {} trailing bytes", bytes.len() - pos));
        }
        Ok(Self {
            d_model,
            d_ffn,
            n_layers,
            calib_seed,
            basis_fingerprint,
            checkpoint_fingerprint,
            plan_json,
            tokens,
            layers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 16,
            group: 4,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn accum_matches_dense_xtx() {
        let rows = [[1.0f32, -2.0, 0.5], [0.0, 3.0, 1.0], [2.0, 0.0, -1.0]];
        let mut acc = HessianAccum::new(3);
        for r in &rows {
            acc.add_row(r);
        }
        let m = acc.to_mat(rows.len() as u64);
        for i in 0..3 {
            for j in 0..3 {
                let expect: f64 = rows
                    .iter()
                    .map(|r| r[i] as f64 * r[j] as f64)
                    .sum::<f64>()
                    / rows.len() as f64;
                assert!((m[(i, j)] - expect).abs() < 1e-12, "({i},{j})");
            }
        }
        // PSD diagonal.
        for i in 0..3 {
            assert!(m[(i, i)] >= 0.0);
        }
    }

    #[test]
    fn merge_is_elementwise_sum() {
        let mut a = HessianAccum::new(4);
        let mut b = HessianAccum::new(4);
        a.add_row(&[1.0, 0.0, 2.0, -1.0]);
        b.add_row(&[0.5, 1.5, 0.0, 2.0]);
        let mut both = HessianAccum::new(4);
        both.add_row(&[1.0, 0.0, 2.0, -1.0]);
        both.add_row(&[0.5, 1.5, 0.0, 2.0]);
        a.merge(&b);
        for (x, y) in a.data.iter().zip(&both.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn set_roundtrips_through_disk_bit_exact() {
        let cfg = tiny_cfg();
        let key = CaptureKey {
            calib_seed: 7,
            basis_fingerprint: 0xDEAD_BEEF,
            checkpoint_fingerprint: 0xFEED_F00D,
            plan_json: "{\"seed\":\"7\"}".to_string(),
        };
        let mut set = HessianSet::new(&cfg, &key);
        set.tokens = 5;
        for l in 0..cfg.n_layers {
            for site in TapSite::ALL {
                let acc = set.layers[l].site_mut(site);
                let dim = acc.dim;
                let row: Vec<f32> = (0..dim).map(|i| (i as f32 - 1.5) * 0.3).collect();
                acc.add_row(&row);
            }
        }
        let path = std::env::temp_dir().join("gsr_hessian_roundtrip_test.bin");
        set.save(&path).unwrap();
        let back = HessianSet::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(set, back, "artifact must round-trip bit-exactly");
    }

    #[test]
    fn load_rejects_garbage_and_checks_key() {
        let path = std::env::temp_dir().join("gsr_hessian_garbage_test.bin");
        std::fs::write(&path, b"definitely not a hessian artifact").unwrap();
        assert!(HessianSet::load(&path).is_err());
        let _ = std::fs::remove_file(&path);

        let cfg = tiny_cfg();
        let key = CaptureKey { basis_fingerprint: 42, ..CaptureKey::default() };
        let mut set = HessianSet::new(&cfg, &key);
        // Empty capture is itself a key violation.
        assert!(set.check_model(&cfg).is_err());
        set.tokens = 1;
        assert!(set.check_model(&cfg).is_ok());
        let mut other = cfg.clone();
        other.d_ffn *= 2;
        assert!(set.check_model(&other).is_err());
        assert!(set.check_basis(42).is_ok());
        let err = set.check_basis(43).unwrap_err();
        assert!(err.contains("fingerprint"), "{err}");
    }

    /// Corrupt header fields must come back as Err, never a panic or an
    /// absurd allocation (the loader's clean-rejection contract).
    #[test]
    fn load_rejects_corrupt_header_without_panicking() {
        let cfg = tiny_cfg();
        let mut set = HessianSet::new(&cfg, &CaptureKey::default());
        set.tokens = 1;
        let path = std::env::temp_dir().join("gsr_hessian_corrupt_header_test.bin");
        set.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Header layout after magic+version (byte 8): d_model, d_ffn,
        // n_layers, calib_seed, basis, checkpoint, tokens, plan_len.
        for (offset, val) in [
            (8 + 16, u64::MAX),     // n_layers bit-flipped huge
            (8, u64::MAX / 2),      // d_model huge → dim math must not overflow
            (8 + 56, u64::MAX - 7), // plan_len huge → promised-size overflow
        ] {
            let mut bad = good.clone();
            bad[offset..offset + 8].copy_from_slice(&val.to_le_bytes());
            std::fs::write(&path, &bad).unwrap();
            assert!(HessianSet::load(&path).is_err(), "offset {offset} must be rejected");
        }
        // Truncation with an intact header is also an error.
        std::fs::write(&path, &good[..good.len() - 9]).unwrap();
        assert!(HessianSet::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
