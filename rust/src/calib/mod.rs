//! `gsr calibrate` — streaming activation Hessians for calibrated GPTQ
//! and the calibration-aware rotation search.
//!
//! The paper's GSR rotations are training-free, but their downstream
//! quantizer (GPTQ) is calibration-based: its error-feedback step is
//! weighted by the inverse Cholesky factor of `H = XᵀX` over real
//! activations. The native pipeline historically fed GPTQ an *identity*
//! Hessian; this subsystem closes that gap end to end:
//!
//! 1. [`capture`] streams held-out corpus sequences through the native
//!    fused forward with per-linear taps (q/k/v, o, gate/up, down — in
//!    the rotated basis each linear actually quantizes in) and
//!    accumulates streaming `XᵀX` in mergeable per-thread partials.
//! 2. [`hessian`] holds the accumulators and the versioned binary
//!    artifact ([`HessianSet`]), keyed by model geometry + calibration
//!    seed + rotation-basis fingerprint so one calibration run is safely
//!    reusable.
//! 3. Consumers: `quant::pipeline::quantize_native_plan_with` feeds the
//!    captured Hessians to `gptq_quantize`, and
//!    `search::CalibWeights` un-rotates them into the base basis so the
//!    `gsr search` objective can weight group-RTN error by the
//!    input-channel energy `diag(R_cᵀ H R_c)` of *any* candidate basis.
//!
//! CLI surface: `gsr calibrate [--synthetic] [--plan F] [--seqs N]
//! [--seq-len N] [--out hessians.bin]`, then `--calib hessians.bin` on
//! `quantize-native` and `search`.
//!
//! Determinism: capture accumulates into a **fixed number** of partials
//! (independent of `--threads`) and merges them in index order, so the
//! resulting `HessianSet` is bit-identical for any worker count — the
//! same guarantee the execution layer gives logits. An artifact is
//! keyed by model geometry + calibration seed + rotation-basis
//! fingerprint + checkpoint fingerprint, so a stale or mismatched
//! artifact is rejected at load/use instead of silently skewing GPTQ.

pub mod capture;
pub mod hessian;

pub use capture::{capture_hessians, capture_hessians_on, CalibCfg};
pub use hessian::{
    checkpoint_fingerprint, CaptureKey, HessianAccum, HessianSet, LayerHessians,
};
