//! PJRT runtime: load AOT artifacts, compile HLO text, execute.
//!
//! The only layer that touches the `xla` crate. Python produced the
//! artifacts once (`make artifacts`); from here on the binary is
//! self-contained: `Artifacts` (manifest + blobs) → `Engine` (PJRT CPU
//! client + compiled executables) → `VariantRunner` (weights resident as
//! device buffers, uploaded once, reused across every execute call).

pub mod artifact;
pub mod pjrt;

pub use artifact::{Artifacts, VariantMeta};
pub use pjrt::{Engine, VariantRunner};
