//! PJRT execution engine (xla crate, CPU client).
//!
//! Pattern from /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Weight tensors are uploaded to device
//! buffers **once per variant** (`VariantRunner`) and reused across all
//! execute calls via `execute_b` — only the token batch is re-uploaded
//! per call (the L3 hot-path optimization measured in EXPERIMENTS §Perf).

use std::collections::HashMap;
use std::path::Path;

use super::artifact::{Artifacts, VariantMeta};
use crate::model::config::{Dtype, ParamSpec};

/// PJRT CPU engine with a compile cache keyed by graph name.
pub struct Engine {
    pub client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    pub fn new() -> Result<Self, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        Ok(Self { client, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) a graph from its HLO text file.
    pub fn load_graph(&mut self, name: &str, path: &Path) -> Result<(), String> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().ok_or("bad path")?)
            .map_err(|e| format!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| format!("compile {name}: {e}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn executable(&self, name: &str) -> Option<&xla::PjRtLoadedExecutable> {
        self.executables.get(name)
    }

    /// Upload a weights blob as per-parameter device buffers (spec order).
    pub fn upload_blob(
        &self,
        blob: &[u8],
        spec: &[ParamSpec],
    ) -> Result<Vec<xla::PjRtBuffer>, String> {
        let expect: usize = spec.iter().map(|s| s.nbytes()).sum();
        if blob.len() != expect {
            return Err(format!("blob {} bytes, spec wants {expect}", blob.len()));
        }
        let mut buffers = Vec::with_capacity(spec.len());
        let mut off = 0;
        for s in spec {
            let nb = s.nbytes();
            let chunk = &blob[off..off + nb];
            let buf = match s.dtype {
                Dtype::F32 => {
                    let data: Vec<f32> = chunk
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect();
                    self.client
                        .buffer_from_host_buffer(&data, &s.shape, None)
                        .map_err(|e| format!("upload {}: {e}", s.name))?
                }
                Dtype::U8 => self
                    .client
                    .buffer_from_host_buffer(chunk, &s.shape, None)
                    .map_err(|e| format!("upload {}: {e}", s.name))?,
            };
            buffers.push(buf);
            off += nb;
        }
        Ok(buffers)
    }

    /// Upload an `[B, T]` i32 token batch.
    pub fn upload_tokens(&self, tokens: &[i32], b: usize, t: usize) -> Result<xla::PjRtBuffer, String> {
        assert_eq!(tokens.len(), b * t);
        self.client
            .buffer_from_host_buffer(tokens, &[b, t], None)
            .map_err(|e| format!("upload tokens: {e}"))
    }
}

/// A model variant resident on device: compiled graph + weight buffers.
pub struct VariantRunner {
    pub graph: String,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    weights: Vec<xla::PjRtBuffer>,
}

impl VariantRunner {
    /// Load a quantized variant: ensure its graph is compiled, read the
    /// weights blob, upload every parameter once.
    pub fn load(engine: &mut Engine, arts: &Artifacts, meta: &VariantMeta) -> Result<Self, String> {
        engine.load_graph(&meta.graph, &arts.hlo_path(&meta.graph)?)?;
        let spec = arts.graph_spec(&meta.graph)?;
        let blob = std::fs::read(arts.weights_path(meta)).map_err(|e| format!("weights: {e}"))?;
        let weights = engine.upload_blob(&blob, &spec)?;
        Ok(Self {
            graph: meta.graph.clone(),
            batch: arts.batch,
            seq: arts.seq,
            vocab: arts.cfg.vocab,
            weights,
        })
    }

    /// Load the fp (W16A16) reference model.
    pub fn load_fp(engine: &mut Engine, arts: &Artifacts) -> Result<Self, String> {
        engine.load_graph("fp", &arts.hlo_path("fp")?)?;
        let spec = arts.graph_spec("fp")?;
        let blob = std::fs::read(arts.fp_weights_path()).map_err(|e| format!("fp weights: {e}"))?;
        let weights = engine.upload_blob(&blob, &spec)?;
        Ok(Self {
            graph: "fp".to_string(),
            batch: arts.batch,
            seq: arts.seq,
            vocab: arts.cfg.vocab,
            weights,
        })
    }

    /// Execute on a `[batch, seq]` token batch → logits
    /// `[batch * seq * vocab]` (row-major).
    pub fn forward(&self, engine: &Engine, tokens: &[i32]) -> Result<Vec<f32>, String> {
        let exe = engine.executable(&self.graph).ok_or("graph not compiled")?;
        let tok_buf = engine.upload_tokens(tokens, self.batch, self.seq)?;
        // Parameter order: tokens first, then the flat weight list —
        // matching make_quant_forward/make_fp_forward in model.py.
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
        args.push(&tok_buf);
        args.extend(self.weights.iter());
        let result = exe.execute_b(&args).map_err(|e| format!("execute: {e}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| format!("to_literal: {e}"))?;
        // Graphs are lowered with return_tuple=True → 1-tuple.
        let out = literal.to_tuple1().map_err(|e| format!("tuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))
    }
}
