//! Artifact-directory model: manifest, corpus splits, variant registry.

use std::fs;
use std::path::{Path, PathBuf};

use crate::config::Json;
use crate::model::config::{ModelCfg, ParamSpec, R4Kind};

/// One quantized variant's provenance (from `variants/*/meta.json`,
/// summarized into the manifest).
#[derive(Debug, Clone)]
pub struct VariantMeta {
    pub name: String,
    pub method: String,
    pub bits: String,
    pub r1: String,
    pub r4: String,
    /// Graph key in the manifest (`w2a16_r4gh`, …).
    pub graph: String,
    /// Weights blob path relative to the artifact dir.
    pub weights: String,
    /// Python-side sanity PPL recorded at build time.
    pub sanity_ppl: f64,
}

impl VariantMeta {
    pub fn r4_kind(&self) -> R4Kind {
        R4Kind::parse(&self.r4).expect("bad r4 in manifest")
    }

    pub fn a_bits(&self) -> Option<u32> {
        match self.bits.as_str() {
            "w2a16" => None,
            "w2a4" => Some(4),
            other => panic!("unknown bits config {other}"),
        }
    }
}

/// Loaded artifact directory.
pub struct Artifacts {
    pub dir: PathBuf,
    pub cfg: ModelCfg,
    pub batch: usize,
    pub seq: usize,
    pub variants: Vec<VariantMeta>,
    manifest: Json,
    corpus: Vec<u8>,
    pub train_end: usize,
}

impl Artifacts {
    pub fn load(dir: &Path) -> Result<Self, String> {
        let manifest_path = dir.join("manifest.json");
        let text = fs::read_to_string(&manifest_path)
            .map_err(|e| format!("{manifest_path:?}: {e} — run `make artifacts` first"))?;
        let manifest = Json::parse(&text)?;
        let cfg = ModelCfg::from_json(manifest.at("cfg")?)?;
        let batch = manifest.at("batch")?.as_usize().ok_or("batch")?;
        let seq = manifest.at("seq")?.as_usize().ok_or("seq")?;
        let corpus_rel = manifest.at("corpus")?.at("path")?.as_str().ok_or("corpus.path")?;
        let corpus = fs::read(dir.join(corpus_rel)).map_err(|e| format!("corpus: {e}"))?;
        let train_end = manifest.at("corpus")?.at("train_end")?.as_usize().ok_or("train_end")?;
        let variants = manifest
            .at("variants")?
            .as_arr()
            .ok_or("variants")?
            .iter()
            .map(|v| {
                Ok(VariantMeta {
                    name: v.at("name")?.as_str().ok_or("name")?.to_string(),
                    method: v.at("method")?.as_str().ok_or("method")?.to_string(),
                    bits: v.at("bits")?.as_str().ok_or("bits")?.to_string(),
                    r1: v.at("r1")?.as_str().ok_or("r1")?.to_string(),
                    r4: v.at("r4")?.as_str().ok_or("r4")?.to_string(),
                    graph: v.at("graph")?.as_str().ok_or("graph")?.to_string(),
                    weights: v.at("weights")?.as_str().ok_or("weights")?.to_string(),
                    sanity_ppl: v.at("sanity_ppl")?.as_f64().unwrap_or(f64::NAN),
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Self { dir: dir.to_path_buf(), cfg, batch, seq, variants, manifest, corpus, train_end })
    }

    /// Full corpus bytes.
    pub fn corpus(&self) -> &[u8] {
        &self.corpus
    }

    /// Held-out test split (never seen in training or calibration).
    pub fn test_split(&self) -> &[u8] {
        &self.corpus[self.train_end..]
    }

    /// Calibration split: the training prefix of the corpus. `gsr
    /// calibrate` draws its activation-capture sequences here so GPTQ
    /// never calibrates on the tokens PPL is measured on.
    pub fn calib_split(&self) -> &[u8] {
        &self.corpus[..self.train_end]
    }

    pub fn corpus_seed(&self) -> u64 {
        self.manifest
            .at("corpus")
            .and_then(|c| c.at("seed"))
            .ok()
            .and_then(|s| s.as_f64())
            .map(|f| f as u64)
            .unwrap_or(crate::data::SEED_CORPUS)
    }

    /// HLO text path for a graph key (`fp`, `w2a16_r4gh`, …).
    pub fn hlo_path(&self, graph: &str) -> Result<PathBuf, String> {
        let rel = self
            .manifest
            .at("graphs")?
            .at(graph)?
            .at("hlo")?
            .as_str()
            .ok_or("hlo path")?;
        Ok(self.dir.join(rel))
    }

    /// Parameter spec for a graph, as recorded in the manifest.
    pub fn graph_spec(&self, graph: &str) -> Result<Vec<ParamSpec>, String> {
        let arr = self
            .manifest
            .at("graphs")?
            .at(graph)?
            .at("params")?
            .as_arr()
            .ok_or("params")?;
        ModelCfg::spec_from_json(arr)
    }

    pub fn graph_names(&self) -> Vec<String> {
        self.manifest
            .at("graphs")
            .ok()
            .and_then(|g| g.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    pub fn variant(&self, name: &str) -> Option<&VariantMeta> {
        self.variants.iter().find(|v| v.name == name)
    }

    pub fn weights_path(&self, v: &VariantMeta) -> PathBuf {
        self.dir.join(&v.weights)
    }

    pub fn fp_weights_path(&self) -> PathBuf {
        let rel = self
            .manifest
            .at("fp_weights")
            .ok()
            .and_then(|v| v.as_str())
            .unwrap_or("model_fp.bin");
        self.dir.join(rel)
    }
}
