//! PJRT implementations of the execution contract.
//!
//! [`PjrtBackend`] is a borrowed view pairing the compile-cache
//! [`Engine`] with one resident [`VariantRunner`] — the shape the eval
//! tables use, where one engine hosts many variants in sequence.
//! [`PjrtSet`] owns the engine plus every resident runner for the
//! serving executor; `run` materializes a short-lived view per call.
//! PJRT handles never cross threads: a `PjrtSet` is built *inside* the
//! executor thread (see `coordinator::Server::start`).

use std::collections::BTreeMap;
use std::path::Path;

use super::{Backend, BackendSet};
use crate::runtime::{Artifacts, Engine, VariantRunner};

/// PJRT-backed model view (engine + one resident variant).
pub struct PjrtBackend<'a> {
    pub engine: &'a Engine,
    pub runner: &'a VariantRunner,
}

impl Backend for PjrtBackend<'_> {
    fn batch(&self) -> usize {
        self.runner.batch
    }

    fn seq(&self) -> usize {
        self.runner.seq
    }

    fn vocab(&self) -> usize {
        self.runner.vocab
    }

    fn name(&self) -> &str {
        "pjrt"
    }

    fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
        let (b, s, v) = (self.runner.batch, self.runner.seq, self.runner.vocab);
        let rows = super::batch_rows(tokens.len(), b, s)?;
        if rows == b {
            return self.runner.forward(self.engine, tokens);
        }
        // The compiled graph has a fixed [batch, seq] shape: pad the
        // partial batch, run, and truncate the result to the real rows.
        let mut padded = vec![0i32; b * s];
        padded[..tokens.len()].copy_from_slice(tokens);
        let mut out = self.runner.forward(self.engine, &padded)?;
        out.truncate(rows * s * v);
        Ok(out)
    }
}

/// Resolve one variant name to a resident runner: `"fp"` is the W16A16
/// reference graph, anything else a quantized variant from the
/// manifest. The single copy of this rule — eval and serving both load
/// through it.
pub fn load_runner(
    engine: &mut Engine,
    arts: &Artifacts,
    name: &str,
) -> Result<VariantRunner, String> {
    if name == "fp" {
        VariantRunner::load_fp(engine, arts)
    } else {
        let meta = arts
            .variant(name)
            .ok_or_else(|| format!("unknown variant {name}"))?
            .clone();
        VariantRunner::load(engine, arts, &meta)
    }
}

/// One PJRT engine with every requested variant resident — the serving
/// executor's backend set ("fp" = the W16A16 reference graph).
pub struct PjrtSet {
    engine: Engine,
    runners: BTreeMap<String, VariantRunner>,
}

impl PjrtSet {
    /// Compile graphs and upload weights for each named variant.
    pub fn load(artifacts_dir: &Path, names: &[String]) -> Result<Self, String> {
        let arts = Artifacts::load(artifacts_dir)?;
        let mut engine = Engine::new()?;
        let mut runners = BTreeMap::new();
        for name in names {
            runners.insert(name.clone(), load_runner(&mut engine, &arts, name)?);
        }
        Ok(Self { engine, runners })
    }
}

impl BackendSet for PjrtSet {
    fn names(&self) -> Vec<String> {
        self.runners.keys().cloned().collect()
    }

    fn run(&self, name: &str, f: &mut dyn FnMut(&dyn Backend)) -> bool {
        match self.runners.get(name) {
            Some(runner) => {
                f(&PjrtBackend { engine: &self.engine, runner });
                true
            }
            None => false,
        }
    }
}
