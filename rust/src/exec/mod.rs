//! Unified batched execution: one [`Backend`] trait serving eval,
//! calibration and the coordinator.
//!
//! Everything that turns a `[batch, seq]` token matrix into
//! `[batch, seq, vocab]` logits lives behind [`Backend`]:
//!
//! * [`NativeBackend`] — the pure-Rust engine: a persistent
//!   [`ExecPool`] of worker threads, each owning a reusable
//!   [`model::ForwardScratch`](crate::model::ForwardScratch), fans the
//!   batch rows out and reassembles them in order. Per-sequence logits
//!   are **bit-identical** to the serial `DenseModel::forward` for any
//!   batch composition and any thread count (each row is computed by
//!   one worker with the exact single-sequence arithmetic). This is the
//!   only path that can serve heterogeneous searched `RotationPlan`
//!   variants today.
//! * [`PjrtBackend`] — a view over the PJRT `Engine` + resident
//!   `VariantRunner` replaying the AOT graphs.
//!
//! The serving coordinator is generic over a [`BackendSet`] — a named
//! collection of resident backends — with [`PjrtSet`] (one engine, many
//! graph variants) and [`NativeSet`] (many native models, optionally
//! sharing one pool) as the two implementations.

pub mod native;
pub mod pjrt;

pub use native::{ExecPool, NativeBackend, NativeSet};
pub use pjrt::{load_runner, PjrtBackend, PjrtSet};

/// Anything that turns a `[batch, seq]` token matrix into
/// `[batch, seq, vocab]` logits — the single execution contract shared
/// by `eval` (PPL / zero-shot), `calib` and the serving coordinator.
pub trait Backend {
    /// Batch capacity of one `forward_batch` call.
    fn batch(&self) -> usize;
    /// Sequence length of one `forward_batch` call.
    fn seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Short human label for reports ("native", "pjrt", …).
    fn name(&self) -> &str {
        "backend"
    }
    /// `tokens.len() == rows * seq()` for some `1 ≤ rows ≤ batch()`;
    /// returns row-major `[rows, seq, vocab]` logits. Partial batches
    /// are first-class so under-full flushes never pay for padding
    /// rows; a backend with a fixed graph shape (PJRT) pads internally
    /// and truncates its result.
    fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String>;
}

/// A named collection of resident [`Backend`]s — what the serving
/// executor owns. `run` uses a callback (rather than returning
/// `&dyn Backend`) so implementations may materialize short-lived views
/// over shared state, as [`PjrtSet`] does over its single `Engine`.
pub trait BackendSet {
    /// Resident variant names, in stable order.
    fn names(&self) -> Vec<String>;
    /// Run `f` against the named backend; `false` if not resident.
    fn run(&self, name: &str, f: &mut dyn FnMut(&dyn Backend)) -> bool;
}
