//! Unified batched execution: one [`Backend`] trait serving eval,
//! calibration and the coordinator.
//!
//! Everything that turns a `[batch, seq]` token matrix into
//! `[batch, seq, vocab]` logits lives behind [`Backend`]:
//!
//! * [`NativeBackend`] — the pure-Rust engine: a persistent
//!   [`ExecPool`] of worker threads, each owning a reusable
//!   [`model::ForwardScratch`](crate::model::ForwardScratch), fans the
//!   batch rows out and reassembles them in order. Per-sequence logits
//!   are **bit-identical** to the serial `DenseModel::forward` for any
//!   batch composition and any thread count (each row is computed by
//!   one worker with the exact single-sequence arithmetic). This is the
//!   only path that can serve heterogeneous searched `RotationPlan`
//!   variants today.
//! * [`PjrtBackend`] — a view over the PJRT `Engine` + resident
//!   `VariantRunner` replaying the AOT graphs.
//!
//! The serving coordinator is generic over a [`BackendSet`] — a named
//! collection of resident backends — with [`PjrtSet`] (one engine, many
//! graph variants) and [`NativeSet`] (many native models, optionally
//! sharing one pool) as the two implementations.
//!
//! ## Determinism contract
//!
//! Every native result is a pure function of `(model, tokens)`: batch
//! composition, partial-batch packing, `--threads`, shard counts and
//! scheduling order never leak into logits. Partial batches (`rows <
//! batch`) are first-class — the native engine computes only the rows
//! it is given, PJRT pads internally and truncates.
//!
//! ## Incremental generation
//!
//! Backends that can decode incrementally (today: [`NativeBackend`])
//! implement the prefill/decode contract: [`Backend::start_generation`]
//! runs the prompt once and returns an opaque per-sequence
//! [`Generation`] (a KV cache underneath), then each
//! [`Backend::decode`] / [`Backend::decode_batch`] step absorbs one
//! token per sequence in `O(1)` forward cost instead of re-running the
//! whole prefix. Decode logits are **bit-identical to a full
//! re-forward** of the prefix at every step, for any thread count —
//! greedy decodes are therefore reproducible across every execution
//! strategy. Backends without the contract return a clear error
//! (`supports_generation` lets callers probe up front).
//!
//! ## Paged generation
//!
//! On top of the contiguous contract, backends may implement the
//! *paged* variant the continuous-batching scheduler drives:
//! [`Backend::start_paged_generation`] opens a generation over an
//! empty block-table cache, [`Backend::grant_kv_block`] /
//! [`Backend::reclaim_kv_blocks`] move fixed-size
//! [`KvBlock`](crate::model::KvBlock)s between the scheduler's pool and
//! the sequence, and [`Backend::prefill_chunk`] absorbs bounded prompt
//! chunks. Decode steps reuse the same [`Backend::decode`] /
//! [`Backend::decode_batch`] calls — the block layout is invisible to
//! the math, so paged decode logits are bit-identical to the contiguous
//! path.
//!
//! ## Speculative decoding
//!
//! [`Backend::verify_draft`] absorbs the pending token plus `k` drafted
//! tokens in one cached forward and returns one `[vocab]` logit row per
//! position, each bit-identical to the corresponding one-token decode;
//! [`Backend::rollback_generation`] truncates the cache back to the
//! last accepted position (returning wholly-dead paged tail blocks for
//! the scheduler's pool). Together they let a cheap quantized draft
//! variant propose tokens the target variant verifies in one batched
//! step, with output provably identical to non-speculative decode.

pub mod native;
pub mod pjrt;

use crate::model::KvBlock;
use std::any::Any;

pub use native::{ExecPool, NativeBackend, NativeSet};
pub use pjrt::{load_runner, PjrtBackend, PjrtSet};

/// Anything that turns a `[batch, seq]` token matrix into
/// `[batch, seq, vocab]` logits — the single execution contract shared
/// by `eval` (PPL / zero-shot), `calib` and the serving coordinator.
pub trait Backend {
    /// Batch capacity of one `forward_batch` call.
    fn batch(&self) -> usize;
    /// Sequence length of one `forward_batch` call.
    fn seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Short human label for reports ("native", "pjrt", …).
    fn name(&self) -> &str {
        "backend"
    }
    /// `tokens.len() == rows * seq()` for some `1 ≤ rows ≤ batch()`;
    /// returns row-major `[rows, seq, vocab]` logits. Partial batches
    /// are first-class so under-full flushes never pay for padding
    /// rows; a backend with a fixed graph shape (PJRT) pads internally
    /// and truncates its result.
    fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String>;

    /// Does this backend implement the incremental prefill/decode
    /// contract below? Callers that need generation should probe this
    /// once instead of relying on the default methods' errors.
    fn supports_generation(&self) -> bool {
        false
    }

    /// Prefill: run `prompt` once, filling a fresh per-sequence
    /// [`Generation`] whose cache holds up to `seq()` tokens. Returns
    /// the state plus the last prompt position's `[vocab]` logits (what
    /// greedy decoding samples the first new token from).
    fn start_generation(&self, _prompt: &[i32]) -> Result<(Generation, Vec<f32>), String> {
        Err(format!("the {} backend does not support incremental decoding", self.name()))
    }

    /// One decode step: absorb `token` at position `gen.len()` and
    /// return that position's `[vocab]` logits — bit-identical to a
    /// full re-forward over the whole prefix.
    fn decode(&self, _gen: &mut Generation, _token: i32) -> Result<Vec<f32>, String> {
        Err(format!("the {} backend does not support incremental decoding", self.name()))
    }

    /// One decode step for several sequences at once (`tokens[i]` feeds
    /// `gens[i]`); backends parallelize across sequences where they
    /// can. Per-sequence logits match [`Backend::decode`] bit-for-bit.
    ///
    /// Failures are per-sequence: the outer `Err` is reserved for
    /// call-level problems (shape mismatch, dead pool), while one bad
    /// sequence yields its own inner `Err` — its cache untouched —
    /// without discarding its round-mates' results. A sequence's
    /// `Generation` advances exactly when its inner result is `Ok`.
    fn decode_batch(
        &self,
        gens: Vec<&mut Generation>,
        tokens: &[i32],
    ) -> Result<Vec<Result<Vec<f32>, String>>, String> {
        if gens.len() != tokens.len() {
            return Err(format!(
                "decode_batch got {} sequences but {} tokens",
                gens.len(),
                tokens.len()
            ));
        }
        Ok(gens.into_iter().zip(tokens).map(|(g, &t)| self.decode(g, t)).collect())
    }

    /// Model geometry `(n_layers, d_model)` for minting
    /// [`KvBlock`](crate::model::KvBlock)s this backend's paged caches
    /// accept; `None` when the backend cannot decode through a block
    /// table (the paged methods below then return errors).
    fn kv_block_geometry(&self) -> Option<(usize, usize)> {
        None
    }

    /// Open a generation over an empty **paged** cache with
    /// `page`-token blocks and zero capacity — no tokens are absorbed
    /// and no storage is reserved. The caller grows capacity with
    /// [`Backend::grant_kv_block`] and feeds the prompt through
    /// [`Backend::prefill_chunk`], so admission can start on the first
    /// free block instead of reserving peak occupancy up front.
    fn start_paged_generation(&self, _page: usize) -> Result<Generation, String> {
        Err(format!("the {} backend does not support paged decoding", self.name()))
    }

    /// Extend `gen`'s paged cache by one granted block (capacity grows
    /// by the block's page size). The default implementation errors —
    /// and drops the block — so callers must only grant to backends
    /// whose [`Backend::kv_block_geometry`] is `Some`.
    fn grant_kv_block(&self, _gen: &mut Generation, _block: KvBlock) -> Result<(), String> {
        Err(format!("the {} backend does not support paged decoding", self.name()))
    }

    /// Take every block back from `gen`'s paged cache (completion,
    /// preemption or eviction); the generation drops to zero length and
    /// capacity, and its rows are recomputed on resume, never migrated.
    fn reclaim_kv_blocks(&self, _gen: &mut Generation) -> Result<Vec<KvBlock>, String> {
        Err(format!("the {} backend does not support paged decoding", self.name()))
    }

    /// Absorb a bounded prompt/recompute chunk at positions
    /// `gen.len()..` and return the **last** absorbed position's
    /// `[vocab]` logits — bit-identical to the same positions of a full
    /// forward, whatever the chunking. On error the cache is rolled
    /// back to its pre-call state.
    fn prefill_chunk(&self, _gen: &mut Generation, _tokens: &[i32]) -> Result<Vec<f32>, String> {
        Err(format!("the {} backend does not support paged decoding", self.name()))
    }

    /// Speculative verification step: absorb `tokens` — the pending
    /// (picked-but-unfed) token followed by the drafted continuation —
    /// in **one** cached forward and return row-major
    /// `[tokens.len(), vocab]` logits, one row per absorbed position.
    /// Row `i` is bit-identical to the logits a one-token
    /// [`Backend::decode`] of `tokens[i]` at that position would
    /// return, so the caller can replay the exact non-speculative
    /// sampling decision against each row. The generation advances by
    /// `tokens.len()`; after deciding how many draft tokens survive,
    /// the caller discards the rejected suffix with
    /// [`Backend::rollback_generation`]. On error the cache is rolled
    /// back to its pre-call state.
    fn verify_draft(&self, _gen: &mut Generation, _tokens: &[i32]) -> Result<Vec<f32>, String> {
        Err(format!("the {} backend does not support speculative decoding", self.name()))
    }

    /// Roll `gen`'s cache back to `len` absorbed tokens, discarding
    /// every row past that point (the rejected draft tokens of a
    /// [`Backend::verify_draft`] round). Rollback is exact: subsequent
    /// decode logits are bit-identical to never having absorbed the
    /// discarded rows. For paged caches, granted tail blocks left with
    /// no live rows are returned so the scheduler can release them to
    /// its pool; contiguous caches return an empty vec.
    fn rollback_generation(
        &self,
        _gen: &mut Generation,
        _len: usize,
    ) -> Result<Vec<KvBlock>, String> {
        Err(format!("the {} backend does not support speculative decoding", self.name()))
    }

    /// Kernel-path selection stats for this backend's resident model
    /// ([`FastPathStats`](crate::model::FastPathStats)): which
    /// structures the fast path consumes directly and how many dense
    /// fallbacks it takes. `None` when the backend has no kernel-mode
    /// notion (fp models, PJRT graphs). Probed once at executor start
    /// for the kernel-path telemetry.
    fn kernel_stats(&self) -> Option<crate::model::FastPathStats> {
        None
    }
}

/// Opaque per-sequence incremental-generation state (a KV cache plus
/// whatever else the owning backend needs). Created by
/// [`Backend::start_generation`], advanced by [`Backend::decode`]; the
/// caller owns it, so one backend can drive any number of concurrent
/// sequences without internal bookkeeping.
pub struct Generation {
    state: Box<dyn Any + Send>,
    len: usize,
    capacity: usize,
}

impl Generation {
    /// Wrap backend-specific state; `len` counts the prompt tokens
    /// already cached, `capacity` the cache's token limit.
    pub fn new(state: Box<dyn Any + Send>, len: usize, capacity: usize) -> Self {
        Self { state, len, capacity }
    }

    /// Tokens absorbed so far (prompt + decoded) — the cache occupancy.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Cache capacity in tokens (the owning backend's `seq()`).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Decode steps left before the cache is full.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// Downcast to the owning backend's state type (`None` means this
    /// state belongs to a different backend implementation).
    pub fn state_mut<T: Any>(&mut self) -> Option<&mut T> {
        self.state.downcast_mut::<T>()
    }

    /// Record `n` newly cached tokens.
    pub fn advance(&mut self, n: usize) {
        self.len += n;
    }

    /// Reset the tracked cache occupancy/capacity — backends call this
    /// when paged storage is granted or reclaimed so the wrapper's
    /// bookkeeping follows the cache it wraps.
    pub fn set_occupancy(&mut self, len: usize, capacity: usize) {
        self.len = len;
        self.capacity = capacity;
    }
}

/// Validate a `forward_batch` token block against a backend's
/// `(batch, seq)` shape and return the row count — the single shape
/// rule every backend implementation enforces, so partial-batch
/// validation and its wording can never diverge between backends.
pub fn batch_rows(tokens_len: usize, batch: usize, seq: usize) -> Result<usize, String> {
    if tokens_len == 0 || tokens_len % seq != 0 || tokens_len / seq > batch {
        return Err(format!(
            "forward_batch wants rows*{seq} tokens for 1..={batch} rows, got {tokens_len}"
        ));
    }
    Ok(tokens_len / seq)
}

/// First-maximum argmax over one position's logits — the single greedy
/// sampling rule shared by the coordinator, tests and benches. Ties
/// break to the lowest token id, so bit-identical logits always yield
/// identical decodes.
///
/// ```
/// use gsr::exec::greedy_argmax;
/// assert_eq!(greedy_argmax(&[0.1, 0.9, 0.9, 0.2]), 1); // first max wins
/// ```
pub fn greedy_argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &l) in logits.iter().enumerate() {
        if l > logits[best] {
            best = i;
        }
    }
    // Empty logits degrade to token 0 (backends always return vocab ≥ 1).
    best as i32
}

/// A named collection of resident [`Backend`]s — what the serving
/// executor owns. `run` uses a callback (rather than returning
/// `&dyn Backend`) so implementations may materialize short-lived views
/// over shared state, as [`PjrtSet`] does over its single `Engine`.
pub trait BackendSet {
    /// Resident variant names, in stable order.
    fn names(&self) -> Vec<String>;
    /// Run `f` against the named backend; `false` if not resident.
    fn run(&self, name: &str, f: &mut dyn FnMut(&dyn Backend)) -> bool;
}
