//! The batched, multi-threaded native execution engine.
//!
//! An [`ExecPool`] owns long-lived worker threads, each with one
//! reusable [`ForwardScratch`] — steady-state execution allocates
//! nothing per call beyond the returned logits. [`NativeBackend`] fans
//! a `[batch, seq]` token block out over the pool (one job per row) and
//! reassembles rows in order; because every row runs the exact
//! single-sequence arithmetic of `DenseModel::forward`, the per-sequence
//! logits are bit-identical to the serial path for any batch
//! composition and any `--threads` value (tested below and in
//! `tests/serve_native.rs`).
//!
//! The pool deliberately executes opaque jobs (`FnOnce(&mut
//! ForwardScratch)`) rather than only token rows: the calibration
//! subsystem schedules whole capture *partials* on the same workers
//! (`calib::capture_hessians_on`), so one thread pool serves scoring,
//! eval and calibration without re-spawning threads per call.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::{Backend, BackendSet};
use crate::config::cli::resolve_threads;
use crate::model::{DenseModel, ForwardScratch};

type Job = Box<dyn FnOnce(&mut ForwardScratch) + Send + 'static>;

/// Persistent worker pool with per-thread reusable scratch buffers.
pub struct ExecPool {
    /// `Mutex` (not bare `Sender`) so the pool is `Sync` and can be
    /// shared behind an `Arc` by several backends; `None` after drop.
    tx: Mutex<Option<Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ExecPool {
    /// Spawn `threads` workers (0 = available parallelism).
    pub fn new(threads: usize) -> Self {
        let threads = resolve_threads(threads);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || {
                    let mut scratch = ForwardScratch::new();
                    loop {
                        // Lock only around recv; the job itself runs
                        // unlocked so workers proceed concurrently.
                        let job = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break, // a sibling poisoned the lock
                        };
                        match job {
                            Ok(job) => job(&mut scratch),
                            Err(_) => break, // pool dropped
                        }
                    }
                })
            })
            .collect();
        Self { tx: Mutex::new(Some(tx)), workers, threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `jobs` on the pool and return their results **in job order**
    /// (scheduling order never leaks into results — the determinism
    /// contract every caller relies on).
    pub fn run_jobs<R, F>(&self, jobs: Vec<F>) -> Result<Vec<R>, String>
    where
        R: Send + 'static,
        F: FnOnce(&mut ForwardScratch) -> R + Send + 'static,
    {
        let n = jobs.len();
        let (rtx, rrx) = channel::<(usize, R)>();
        {
            let guard = self.tx.lock().map_err(|_| "execution pool lock poisoned".to_string())?;
            let tx = guard.as_ref().ok_or_else(|| "execution pool stopped".to_string())?;
            for (i, job) in jobs.into_iter().enumerate() {
                let rtx = rtx.clone();
                tx.send(Box::new(move |scratch: &mut ForwardScratch| {
                    let _ = rtx.send((i, job(scratch)));
                }))
                .map_err(|_| "execution pool stopped".to_string())?;
            }
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx
                .recv()
                .map_err(|_| "a native execution worker died (panic during forward)".to_string())?;
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.ok_or_else(|| "missing job result".to_string()))
            .collect()
    }
}

impl Default for ExecPool {
    fn default() -> Self {
        Self::new(0)
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        if let Ok(guard) = self.tx.get_mut() {
            guard.take(); // close the channel → workers drain and exit
        }
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

/// Batched native execution of one [`DenseModel`] (fp, quantized, or a
/// heterogeneous searched-plan variant — anything the native forward
/// runs).
pub struct NativeBackend {
    model: Arc<DenseModel>,
    pool: Arc<ExecPool>,
    label: &'static str,
    batch: usize,
    seq: usize,
}

impl NativeBackend {
    /// Backend with its own worker pool (`threads` 0 = all cores).
    pub fn new(model: Arc<DenseModel>, batch: usize, seq: usize, threads: usize) -> Self {
        Self::with_pool(model, batch, seq, Arc::new(ExecPool::new(threads)))
    }

    /// Backend sharing an existing pool — how a multi-variant
    /// [`NativeSet`] keeps one set of workers for all residents.
    pub fn with_pool(
        model: Arc<DenseModel>,
        batch: usize,
        seq: usize,
        pool: Arc<ExecPool>,
    ) -> Self {
        assert!(batch > 0, "backend batch must be positive");
        assert!(seq > 0, "backend seq must be positive");
        let label = match &*model {
            DenseModel::Fp { .. } => "native-fp",
            DenseModel::Quant { .. } => "native-quant",
        };
        Self { model, pool, label, batch, seq }
    }

    pub fn model(&self) -> &Arc<DenseModel> {
        &self.model
    }

    pub fn pool(&self) -> &Arc<ExecPool> {
        &self.pool
    }
}

impl Backend for NativeBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn vocab(&self) -> usize {
        self.model.cfg().vocab
    }

    fn name(&self) -> &str {
        self.label
    }

    fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
        let (b, s, v) = (self.batch, self.seq, self.vocab());
        if tokens.is_empty() || tokens.len() % s != 0 || tokens.len() / s > b {
            return Err(format!(
                "forward_batch wants rows*{s} tokens for 1..={b} rows, got {}",
                tokens.len()
            ));
        }
        let rows = tokens.len() / s;
        // Validate up front: a bad token id must surface as an error on
        // this call, not a panic that kills a pool worker.
        if let Some(&bad) = tokens.iter().find(|&&t| t < 0 || t as usize >= v) {
            return Err(format!("token id {bad} outside vocab 0..{v}"));
        }
        let shared = Arc::new(tokens.to_vec());
        let jobs: Vec<_> = (0..rows)
            .map(|row| {
                let model = Arc::clone(&self.model);
                let toks = Arc::clone(&shared);
                move |scratch: &mut ForwardScratch| {
                    model.forward_with(&toks[row * s..(row + 1) * s], scratch)
                }
            })
            .collect();
        let row_logits = self.pool.run_jobs(jobs)?;
        let mut out = Vec::with_capacity(rows * s * v);
        for row in row_logits {
            debug_assert_eq!(row.len(), s * v);
            out.extend_from_slice(&row);
        }
        Ok(out)
    }
}

/// Named native backends, typically sharing one [`ExecPool`].
#[derive(Default)]
pub struct NativeSet {
    backends: BTreeMap<String, NativeBackend>,
}

impl NativeSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, backend: NativeBackend) {
        self.backends.insert(name.to_string(), backend);
    }

    pub fn get(&self, name: &str) -> Option<&NativeBackend> {
        self.backends.get(name)
    }

    pub fn len(&self) -> usize {
        self.backends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }
}

impl BackendSet for NativeSet {
    fn names(&self) -> Vec<String> {
        self.backends.keys().cloned().collect()
    }

    fn run(&self, name: &str, f: &mut dyn FnMut(&dyn Backend)) -> bool {
        match self.backends.get(name) {
            Some(b) => {
                f(b);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{FpParams, ModelCfg};

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn tiny_model() -> Arc<DenseModel> {
        let cfg = tiny_cfg();
        Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: FpParams::synthetic(&cfg, 3) })
    }

    #[test]
    fn batched_rows_bit_identical_to_serial_for_any_threads() {
        let model = tiny_model();
        let (b, s) = (4, 12);
        let tokens: Vec<i32> = (0..b * s).map(|i| ((i * 7 + 3) % 64) as i32).collect();
        let expect: Vec<Vec<f32>> = (0..b)
            .map(|row| model.forward(&tokens[row * s..(row + 1) * s]))
            .collect();
        for threads in [1, 2, 4] {
            let backend = NativeBackend::new(Arc::clone(&model), b, s, threads);
            let out = backend.forward_batch(&tokens).unwrap();
            let v = backend.vocab();
            for (row, want) in expect.iter().enumerate() {
                let got = &out[row * s * v..(row + 1) * s * v];
                assert_eq!(got.len(), want.len());
                for (a, e) in got.iter().zip(want) {
                    assert_eq!(
                        a.to_bits(),
                        e.to_bits(),
                        "row {row} diverges from serial forward at {threads} threads"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_batch_validates_shape_and_tokens() {
        let backend = NativeBackend::new(tiny_model(), 2, 8, 1);
        assert!(backend.forward_batch(&[0i32; 7]).is_err(), "wrong length must error");
        let mut bad = vec![0i32; 16];
        bad[5] = 64; // == vocab → out of range
        let err = backend.forward_batch(&bad).unwrap_err();
        assert!(err.contains("outside vocab"), "{err}");
        // The pool must survive the rejected call.
        assert!(backend.forward_batch(&[1i32; 16]).is_ok());
    }

    #[test]
    fn shared_pool_serves_multiple_backends() {
        let pool = Arc::new(ExecPool::new(2));
        let model = tiny_model();
        let a = NativeBackend::with_pool(Arc::clone(&model), 1, 6, Arc::clone(&pool));
        let b = NativeBackend::with_pool(Arc::clone(&model), 2, 6, Arc::clone(&pool));
        let t1: Vec<i32> = (0..6).map(|i| i as i32).collect();
        let t2: Vec<i32> = (0..12).map(|i| (i % 5) as i32).collect();
        let ra = a.forward_batch(&t1).unwrap();
        let rb = b.forward_batch(&t2).unwrap();
        assert_eq!(ra.len(), 6 * 64);
        assert_eq!(rb.len(), 12 * 64);
        let mut set = NativeSet::new();
        set.insert("a", a);
        set.insert("b", b);
        assert_eq!(set.names(), vec!["a".to_string(), "b".to_string()]);
        let mut seen = 0usize;
        assert!(set.run("a", &mut |bk| seen = bk.batch()));
        assert_eq!(seen, 1);
        assert!(!set.run("missing", &mut |_| {}));
    }

    #[test]
    fn run_jobs_returns_results_in_job_order() {
        let pool = ExecPool::new(4);
        let jobs: Vec<_> = (0..32usize)
            .map(|i| move |_scratch: &mut ForwardScratch| i * i)
            .collect();
        let out = pool.run_jobs(jobs).unwrap();
        let expect: Vec<usize> = (0..32).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }
}
