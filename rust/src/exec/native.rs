//! The batched, multi-threaded native execution engine.
//!
//! An [`ExecPool`] owns long-lived worker threads, each with one
//! reusable [`ForwardScratch`] — steady-state execution allocates
//! nothing per call beyond the returned logits. [`NativeBackend`] fans
//! a `[batch, seq]` token block out over the pool (one job per row) and
//! reassembles rows in order; because every row runs the exact
//! single-sequence arithmetic of `DenseModel::forward`, the per-sequence
//! logits are bit-identical to the serial path for any batch
//! composition and any `--threads` value (tested below and in
//! `tests/serve_native.rs`).
//!
//! The pool deliberately executes opaque jobs (`FnOnce(&mut
//! ForwardScratch)`) rather than only token rows: the calibration
//! subsystem schedules whole capture *partials* on the same workers
//! (`calib::capture_hessians_on`), so one thread pool serves scoring,
//! eval and calibration without re-spawning threads per call. The
//! scoped variant ([`ExecPool::run_scoped`]) additionally lets jobs
//! borrow the caller's stack frame, which is how a *single* decode
//! step parallelizes **within** a sequence: `NativeBackend`'s
//! generation path shards each linear's output columns and each
//! attention call's heads across the same workers (`model::DecodePar`),
//! while batched decode rounds fall back to one-job-per-sequence. All
//! strategies are bit-identical — logits are a pure function of
//! `(model, tokens)`, never of thread count or shard layout.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::{Backend, BackendSet, Generation};
use crate::config::cli::resolve_threads;
use crate::model::{
    DecodePar, DenseModel, ForwardScratch, KernelMode, KvBlock, KvCache, ShardJob, ShardRunner,
};

type Job = Box<dyn FnOnce(&mut ForwardScratch) + Send + 'static>;

/// Persistent worker pool with per-thread reusable scratch buffers.
pub struct ExecPool {
    /// `Mutex` (not bare `Sender`) so the pool is `Sync` and can be
    /// shared behind an `Arc` by several backends; `None` after drop.
    tx: Mutex<Option<Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ExecPool {
    /// Spawn `threads` workers (0 = available parallelism).
    pub fn new(threads: usize) -> Self {
        let threads = resolve_threads(threads);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || {
                    let mut scratch = ForwardScratch::new();
                    loop {
                        // Lock only around recv; the job itself runs
                        // unlocked so workers proceed concurrently.
                        let job = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break, // a sibling poisoned the lock
                        };
                        match job {
                            Ok(job) => job(&mut scratch),
                            Err(_) => break, // pool dropped
                        }
                    }
                })
            })
            .collect();
        Self { tx: Mutex::new(Some(tx)), workers, threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `jobs` on the pool and return their results **in job order**
    /// (scheduling order never leaks into results — the determinism
    /// contract every caller relies on).
    pub fn run_jobs<R, F>(&self, jobs: Vec<F>) -> Result<Vec<R>, String>
    where
        R: Send + 'static,
        F: FnOnce(&mut ForwardScratch) -> R + Send + 'static,
    {
        self.run_scoped(jobs)
    }

    /// [`ExecPool::run_jobs`] for jobs that **borrow the caller's stack
    /// frame** (`'env` instead of `'static`) — what lets a decode step
    /// shard one matmul's columns or one attention call's heads over
    /// the pool without `Arc`-wrapping every tensor it touches.
    ///
    /// Soundness: this call does not return while any enqueued job can
    /// still run. Every job sends its `(index, result)` on a private
    /// channel whose senders exist only inside job closures — running
    /// jobs drop theirs on completion or unwind, and jobs still queued
    /// when the pool's job receiver disconnects are discarded by the
    /// channel, dropping theirs too. So `recv()` on the result channel
    /// disconnects exactly when every enqueued job has finished or been
    /// destroyed; both exit paths below block on that, and only then do
    /// the `'env` borrows go dead and the function return.
    pub fn run_scoped<'env, R, F>(&self, jobs: Vec<F>) -> Result<Vec<R>, String>
    where
        R: Send + 'env,
        F: FnOnce(&mut ForwardScratch) -> R + Send + 'env,
    {
        let n = jobs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (rtx, rrx) = channel::<(usize, R)>();
        let mut enqueue_err = None;
        {
            let guard = self.tx.lock().map_err(|_| "execution pool lock poisoned".to_string())?;
            let tx = guard.as_ref().ok_or_else(|| "execution pool stopped".to_string())?;
            for (i, job) in jobs.into_iter().enumerate() {
                let rtx = rtx.clone();
                let wrapped: Box<dyn FnOnce(&mut ForwardScratch) + Send + 'env> =
                    Box::new(move |scratch| {
                        let _ = rtx.send((i, job(scratch)));
                    });
                // SAFETY: the trait objects differ only in lifetime
                // bound. Both exit paths below block until the result
                // channel disconnects, which cannot happen before every
                // transmuted job (running or queued) has been consumed
                // or destroyed — so no `'env` borrow outlives this call.
                let wrapped: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce(&mut ForwardScratch) + Send + 'env>, Job>(
                        wrapped,
                    )
                };
                if tx.send(wrapped).is_err() {
                    enqueue_err = Some("execution pool stopped".to_string());
                    break;
                }
            }
        }
        drop(rtx);
        if let Some(e) = enqueue_err {
            // Dead pool (send fails only once the job receiver is gone,
            // i.e. every worker has exited): any already-sent job has
            // either run, unwound, or been discarded with the queue.
            // Drain until the result channel disconnects so no borrow
            // can outlive this frame, then report the failure.
            while rrx.recv().is_ok() {}
            return Err(e);
        }
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx
                .recv()
                .map_err(|_| "a native execution worker died (panic during forward)".to_string())?;
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.ok_or_else(|| "missing job result".to_string()))
            .collect()
    }
}

/// The pool is the forward pass's intra-sequence shard executor: each
/// shard of a decode-step linear / attention call runs as one scoped
/// job. Results come back in job order, so reassembly — and therefore
/// every logit bit — is independent of scheduling.
impl ShardRunner for ExecPool {
    fn run<'env>(&self, jobs: Vec<ShardJob<'env>>) -> Result<Vec<Vec<f32>>, String> {
        self.run_scoped(
            jobs.into_iter().map(|job| move |_scratch: &mut ForwardScratch| job()).collect(),
        )
    }
}

impl Default for ExecPool {
    fn default() -> Self {
        Self::new(0)
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        if let Ok(guard) = self.tx.get_mut() {
            guard.take(); // close the channel → workers drain and exit
        }
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

/// Batched native execution of one [`DenseModel`] (fp, quantized, or a
/// heterogeneous searched-plan variant — anything the native forward
/// runs).
pub struct NativeBackend {
    model: Arc<DenseModel>,
    pool: Arc<ExecPool>,
    label: &'static str,
    batch: usize,
    seq: usize,
}

impl NativeBackend {
    /// Backend with its own worker pool (`threads` 0 = all cores).
    pub fn new(model: Arc<DenseModel>, batch: usize, seq: usize, threads: usize) -> Self {
        Self::with_pool(model, batch, seq, Arc::new(ExecPool::new(threads)))
    }

    /// Backend sharing an existing pool — how a multi-variant
    /// [`NativeSet`] keeps one set of workers for all residents.
    pub fn with_pool(
        model: Arc<DenseModel>,
        batch: usize,
        seq: usize,
        pool: Arc<ExecPool>,
    ) -> Self {
        assert!(batch > 0, "backend batch must be positive");
        assert!(seq > 0, "backend seq must be positive");
        let label = match &*model {
            DenseModel::Fp { .. } => "native-fp",
            DenseModel::Quant { params, .. } if params.kernels == KernelMode::Fast => {
                "native-quant-fast"
            }
            DenseModel::Quant { .. } => "native-quant",
        };
        Self { model, pool, label, batch, seq }
    }

    pub fn model(&self) -> &Arc<DenseModel> {
        &self.model
    }

    pub fn pool(&self) -> &Arc<ExecPool> {
        &self.pool
    }

    /// Intra-sequence parallelism for single-sequence prefill/decode:
    /// shard the step's linears and attention over the pool. `None` on
    /// a one-worker pool (nothing to win). Never changes logits.
    fn decode_par(&self) -> Option<DecodePar<'_>> {
        let threads = self.pool.threads();
        (threads > 1).then(|| DecodePar { runner: &*self.pool, shards: threads })
    }

    fn validate_tokens(&self, tokens: &[i32]) -> Result<(), String> {
        crate::model::tokens_in_vocab(tokens, self.vocab())
    }
}

/// Per-sequence native generation state behind [`Generation`]: the KV
/// cache plus a dedicated scratch, so a sequence can decode on any
/// thread without touching backend-global state.
struct NativeGen {
    /// The exact model that filled this cache. Decoding through a
    /// different backend — even one with identical geometry — would
    /// silently mix weights with a foreign cache, so ownership is
    /// checked by pointer identity on every step.
    model: Arc<DenseModel>,
    cache: KvCache,
    scratch: ForwardScratch,
}

/// The one ownership rule for generation state: the state must be
/// native *and* born from this backend's exact model.
fn owned_state<'g>(
    gen: &'g mut Generation,
    model: &Arc<DenseModel>,
) -> Result<&'g mut NativeGen, String> {
    gen.state_mut::<NativeGen>()
        .filter(|st| Arc::ptr_eq(&st.model, model))
        .ok_or_else(|| "generation was started on a different backend".to_string())
}

impl Backend for NativeBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn vocab(&self) -> usize {
        self.model.cfg().vocab
    }

    fn name(&self) -> &str {
        self.label
    }

    fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
        let (b, s, v) = (self.batch, self.seq, self.vocab());
        let rows = super::batch_rows(tokens.len(), b, s)?;
        // Validate up front: a bad token id must surface as an error on
        // this call, not a panic that kills a pool worker.
        self.validate_tokens(tokens)?;
        // Scoped jobs borrow the caller's token slice and the model
        // directly — no per-call copy, no Arc traffic.
        let model: &DenseModel = &self.model;
        let jobs: Vec<_> = (0..rows)
            .map(|row| {
                move |scratch: &mut ForwardScratch| {
                    model.forward_with(&tokens[row * s..(row + 1) * s], scratch)
                }
            })
            .collect();
        let row_logits = self.pool.run_scoped(jobs)?;
        let mut out = Vec::with_capacity(rows * s * v);
        for row in row_logits {
            debug_assert_eq!(row.len(), s * v);
            out.extend_from_slice(&row);
        }
        Ok(out)
    }

    fn supports_generation(&self) -> bool {
        true
    }

    /// Prefill with intra-sequence parallelism: the prompt's linears
    /// column-shard and its attention head-shards across the pool. The
    /// cache holds up to `seq()` tokens (prompt + decoded).
    fn start_generation(&self, prompt: &[i32]) -> Result<(Generation, Vec<f32>), String> {
        let v = self.vocab();
        if prompt.is_empty() {
            return Err("generation needs a non-empty prompt".to_string());
        }
        if prompt.len() > self.seq {
            return Err(format!(
                "prompt of {} tokens exceeds the {}-token kv cache; raise --seq or trim it",
                prompt.len(),
                self.seq
            ));
        }
        self.validate_tokens(prompt)?;
        let mut state = NativeGen {
            model: Arc::clone(&self.model),
            cache: KvCache::new(self.model.cfg(), self.seq),
            scratch: ForwardScratch::new(),
        };
        let logits = self.model.forward_cached_par(
            prompt,
            &mut state.cache,
            &mut state.scratch,
            self.decode_par().as_ref(),
        )?;
        // The prefill sized every scratch buffer to the whole prompt
        // (including a `prompt × vocab` f64 accumulator); decode steps
        // only ever need single-row buffers, so drop the prefill-sized
        // allocations instead of carrying them for the generation's
        // lifetime.
        state.scratch = ForwardScratch::new();
        let last = logits[(prompt.len() - 1) * v..].to_vec();
        Ok((Generation::new(Box::new(state), prompt.len(), self.seq), last))
    }

    /// Single-sequence decode step, intra-sequence parallel: the hot
    /// loop's matmuls and attention split across the pool workers while
    /// staying bit-identical to the serial step (and to a full
    /// re-forward of the prefix).
    fn decode(&self, gen: &mut Generation, token: i32) -> Result<Vec<f32>, String> {
        self.validate_tokens(&[token])?;
        let par = self.decode_par();
        let state = owned_state(gen, &self.model)?;
        let out = self.model.forward_cached_par(
            &[token],
            &mut state.cache,
            &mut state.scratch,
            par.as_ref(),
        )?;
        gen.advance(1);
        Ok(out)
    }

    /// Batched decode round: one pool job per sequence (each runs the
    /// serial cached step on a worker-owned scratch — nesting shard
    /// jobs inside pool jobs could deadlock the fixed-size pool). A
    /// single sequence falls back to the intra-parallel
    /// [`Backend::decode`]. Both strategies are bit-identical, so the
    /// coordinator may mix them freely as load changes. Failures are
    /// per-sequence (inner `Result`): a bad sequence — foreign state,
    /// full cache — neither advances nor disturbs its round-mates.
    fn decode_batch(
        &self,
        gens: Vec<&mut Generation>,
        tokens: &[i32],
    ) -> Result<Vec<Result<Vec<f32>, String>>, String> {
        if gens.len() != tokens.len() {
            return Err(format!(
                "decode_batch got {} sequences but {} tokens",
                gens.len(),
                tokens.len()
            ));
        }
        if gens.len() <= 1 {
            return Ok(gens.into_iter().zip(tokens).map(|(g, &t)| self.decode(g, t)).collect());
        }
        let model: &Arc<DenseModel> = &self.model;
        let vocab = self.vocab();
        let jobs: Vec<_> = gens
            .into_iter()
            .zip(tokens.iter().copied())
            .map(|(g, tok)| {
                move |scratch: &mut ForwardScratch| -> Result<Vec<f32>, String> {
                    crate::model::tokens_in_vocab(&[tok], vocab)?;
                    let st = owned_state(g, model)?;
                    // Worker-owned scratch: bit-transparent (scratch
                    // reuse never changes logits) and allocation-free.
                    let out = model.forward_cached(&[tok], &mut st.cache, scratch)?;
                    // Advance inside the job, only on success, so
                    // `Generation::len` stays in sync with its cache.
                    g.advance(1);
                    Ok(out)
                }
            })
            .collect();
        self.pool.run_scoped(jobs)
    }

    fn kv_block_geometry(&self) -> Option<(usize, usize)> {
        let cfg = self.model.cfg();
        Some((cfg.n_layers, cfg.d_model))
    }

    fn kernel_stats(&self) -> Option<crate::model::FastPathStats> {
        match &*self.model {
            DenseModel::Quant { params, .. } => Some(params.fast_path_stats()),
            DenseModel::Fp { .. } => None,
        }
    }

    /// Open a zero-capacity paged generation. No tokens are absorbed
    /// and no storage is reserved — the scheduler grants blocks and
    /// feeds the prompt through [`Backend::prefill_chunk`].
    fn start_paged_generation(&self, page: usize) -> Result<Generation, String> {
        let state = NativeGen {
            model: Arc::clone(&self.model),
            cache: KvCache::paged(self.model.cfg(), page),
            scratch: ForwardScratch::new(),
        };
        Ok(Generation::new(Box::new(state), 0, 0))
    }

    fn grant_kv_block(&self, gen: &mut Generation, block: KvBlock) -> Result<(), String> {
        let state = owned_state(gen, &self.model)?;
        state.cache.grant(block)?;
        let (len, cap) = (state.cache.len(), state.cache.capacity());
        gen.set_occupancy(len, cap);
        Ok(())
    }

    fn reclaim_kv_blocks(&self, gen: &mut Generation) -> Result<Vec<KvBlock>, String> {
        let state = owned_state(gen, &self.model)?;
        let blocks = state.cache.reclaim_blocks();
        gen.set_occupancy(0, 0);
        Ok(blocks)
    }

    /// Absorb one bounded prompt/recompute chunk, intra-sequence
    /// parallel like [`Backend::start_generation`]'s prefill. Returns
    /// the last absorbed position's logits.
    fn prefill_chunk(&self, gen: &mut Generation, tokens: &[i32]) -> Result<Vec<f32>, String> {
        let v = self.vocab();
        if tokens.is_empty() {
            return Err("prefill chunk needs at least one token".to_string());
        }
        self.validate_tokens(tokens)?;
        let par = self.decode_par();
        let state = owned_state(gen, &self.model)?;
        let logits = self.model.forward_cached_par(
            tokens,
            &mut state.cache,
            &mut state.scratch,
            par.as_ref(),
        )?;
        // Multi-token chunks size scratch to the chunk (including a
        // `chunk × vocab` f64 accumulator); decode needs single-row
        // buffers only, so drop the chunk-sized allocations.
        if tokens.len() > 1 {
            state.scratch = ForwardScratch::new();
        }
        gen.advance(tokens.len());
        Ok(logits[(tokens.len() - 1) * v..].to_vec())
    }

    /// Speculative verify: absorb the pending token plus the drafted
    /// continuation in one cached forward and return **every** absorbed
    /// position's logits (row-major `[tokens.len(), vocab]`), each row
    /// bit-identical to a one-token [`Backend::decode`] at the same
    /// position — so acceptance decisions replay the non-speculative
    /// sampling decision exactly.
    fn verify_draft(&self, gen: &mut Generation, tokens: &[i32]) -> Result<Vec<f32>, String> {
        if tokens.is_empty() {
            return Err("verify_draft needs at least one token".to_string());
        }
        self.validate_tokens(tokens)?;
        let par = self.decode_par();
        let state = owned_state(gen, &self.model)?;
        let logits = self.model.forward_cached_par(
            tokens,
            &mut state.cache,
            &mut state.scratch,
            par.as_ref(),
        )?;
        // Multi-token verifies size scratch to the verify batch
        // (including a `k × vocab` f64 accumulator); steady-state decode
        // needs single-row buffers only, so drop the batch-sized
        // allocations.
        if tokens.len() > 1 {
            state.scratch = ForwardScratch::new();
        }
        gen.advance(tokens.len());
        Ok(logits)
    }

    fn rollback_generation(
        &self,
        gen: &mut Generation,
        len: usize,
    ) -> Result<Vec<KvBlock>, String> {
        let state = owned_state(gen, &self.model)?;
        state.cache.rollback(len)?;
        let freed = state.cache.release_tail_blocks();
        let (len, cap) = (state.cache.len(), state.cache.capacity());
        gen.set_occupancy(len, cap);
        Ok(freed)
    }
}

/// Named native backends, typically sharing one [`ExecPool`].
#[derive(Default)]
pub struct NativeSet {
    backends: BTreeMap<String, NativeBackend>,
}

impl NativeSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, backend: NativeBackend) {
        self.backends.insert(name.to_string(), backend);
    }

    pub fn get(&self, name: &str) -> Option<&NativeBackend> {
        self.backends.get(name)
    }

    pub fn len(&self) -> usize {
        self.backends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }
}

impl BackendSet for NativeSet {
    fn names(&self) -> Vec<String> {
        self.backends.keys().cloned().collect()
    }

    fn run(&self, name: &str, f: &mut dyn FnMut(&dyn Backend)) -> bool {
        match self.backends.get(name) {
            Some(b) => {
                f(b);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{FpParams, ModelCfg};

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            group: 16,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn tiny_model() -> Arc<DenseModel> {
        let cfg = tiny_cfg();
        Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: FpParams::synthetic(&cfg, 3) })
    }

    #[test]
    fn batched_rows_bit_identical_to_serial_for_any_threads() {
        let model = tiny_model();
        let (b, s) = (4, 12);
        let tokens: Vec<i32> = (0..b * s).map(|i| ((i * 7 + 3) % 64) as i32).collect();
        let expect: Vec<Vec<f32>> = (0..b)
            .map(|row| model.forward(&tokens[row * s..(row + 1) * s]))
            .collect();
        for threads in [1, 2, 4] {
            let backend = NativeBackend::new(Arc::clone(&model), b, s, threads);
            let out = backend.forward_batch(&tokens).unwrap();
            let v = backend.vocab();
            for (row, want) in expect.iter().enumerate() {
                let got = &out[row * s * v..(row + 1) * s * v];
                assert_eq!(got.len(), want.len());
                for (a, e) in got.iter().zip(want) {
                    assert_eq!(
                        a.to_bits(),
                        e.to_bits(),
                        "row {row} diverges from serial forward at {threads} threads"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_batch_validates_shape_and_tokens() {
        let backend = NativeBackend::new(tiny_model(), 2, 8, 1);
        assert!(backend.forward_batch(&[0i32; 7]).is_err(), "wrong length must error");
        let mut bad = vec![0i32; 16];
        bad[5] = 64; // == vocab → out of range
        let err = backend.forward_batch(&bad).unwrap_err();
        assert!(err.contains("outside vocab"), "{err}");
        // The pool must survive the rejected call.
        assert!(backend.forward_batch(&[1i32; 16]).is_ok());
    }

    #[test]
    fn shared_pool_serves_multiple_backends() {
        let pool = Arc::new(ExecPool::new(2));
        let model = tiny_model();
        let a = NativeBackend::with_pool(Arc::clone(&model), 1, 6, Arc::clone(&pool));
        let b = NativeBackend::with_pool(Arc::clone(&model), 2, 6, Arc::clone(&pool));
        let t1: Vec<i32> = (0..6).map(|i| i as i32).collect();
        let t2: Vec<i32> = (0..12).map(|i| (i % 5) as i32).collect();
        let ra = a.forward_batch(&t1).unwrap();
        let rb = b.forward_batch(&t2).unwrap();
        assert_eq!(ra.len(), 6 * 64);
        assert_eq!(rb.len(), 12 * 64);
        let mut set = NativeSet::new();
        set.insert("a", a);
        set.insert("b", b);
        assert_eq!(set.names(), vec!["a".to_string(), "b".to_string()]);
        let mut seen = 0usize;
        assert!(set.run("a", &mut |bk| seen = bk.batch()));
        assert_eq!(seen, 1);
        assert!(!set.run("missing", &mut |_| {}));
    }

    #[test]
    fn run_jobs_returns_results_in_job_order() {
        let pool = ExecPool::new(4);
        let jobs: Vec<_> = (0..32usize)
            .map(|i| move |_scratch: &mut ForwardScratch| i * i)
            .collect();
        let out = pool.run_jobs(jobs).unwrap();
        let expect: Vec<usize> = (0..32).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    /// Scoped jobs may borrow the caller's stack frame; results still
    /// come back in job order.
    #[test]
    fn run_scoped_jobs_borrow_environment() {
        let pool = ExecPool::new(3);
        let data: Vec<f64> = (0..64).map(|i| i as f64 * 0.5).collect();
        let chunks: Vec<&[f64]> = data.chunks(16).collect();
        let jobs: Vec<_> = chunks
            .iter()
            .map(|chunk| {
                let chunk: &[f64] = chunk;
                move |_scratch: &mut ForwardScratch| chunk.iter().sum::<f64>()
            })
            .collect();
        let sums = pool.run_scoped(jobs).unwrap();
        let expect: Vec<f64> = chunks.iter().map(|c| c.iter().sum()).collect();
        assert_eq!(sums, expect);
    }

    /// The generation contract end to end on the backend: prefill +
    /// per-token decode logits are bit-identical to a full re-forward
    /// of the prefix, for one worker and for many (intra-sequence
    /// sharding active).
    #[test]
    fn generation_bit_identical_to_full_forward_for_any_threads() {
        let model = tiny_model();
        let (vocab, seq) = (64usize, 14usize);
        let prompt: Vec<i32> = (0..6).map(|i| ((i * 11 + 2) % 64) as i32).collect();
        let cont: Vec<i32> = (0..6).map(|i| ((i * 17 + 9) % 64) as i32).collect();
        for threads in [1, 3] {
            let backend = NativeBackend::new(Arc::clone(&model), 2, seq, threads);
            let (mut gen, last) = backend.start_generation(&prompt).unwrap();
            let full = model.forward(&prompt);
            assert_eq!(last.len(), vocab);
            for (a, b) in last.iter().zip(&full[(prompt.len() - 1) * vocab..]) {
                assert_eq!(a.to_bits(), b.to_bits(), "prefill logits diverge at t={threads}");
            }
            let mut prefix = prompt.clone();
            for &tok in &cont {
                let got = backend.decode(&mut gen, tok).unwrap();
                prefix.push(tok);
                let full = model.forward(&prefix);
                let want = &full[(prefix.len() - 1) * vocab..];
                for (a, b) in got.iter().zip(want) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "decode at len {} diverges at t={threads}",
                        prefix.len()
                    );
                }
                assert_eq!(gen.len(), prefix.len());
            }
        }
    }

    /// Batched decode (one pool job per sequence) matches per-sequence
    /// decode bit-for-bit, and sequences at different lengths coexist.
    #[test]
    fn decode_batch_matches_single_sequence_decode() {
        let model = tiny_model();
        let backend = NativeBackend::new(Arc::clone(&model), 4, 16, 3);
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|s| (0..3 + s).map(|i| ((i * 7 + s * 5 + 1) % 64) as i32).collect())
            .collect();
        let steps: Vec<Vec<i32>> =
            (0..3).map(|s| (0..4).map(|i| ((i * 13 + s * 3 + 2) % 64) as i32).collect()).collect();
        // Reference: each sequence decoded alone.
        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for (prompt, toks) in prompts.iter().zip(&steps) {
            let (mut gen, _) = backend.start_generation(prompt).unwrap();
            want.push(toks.iter().map(|&t| backend.decode(&mut gen, t).unwrap()).collect());
        }
        // Batched: all sequences step together.
        let mut gens: Vec<Generation> = prompts
            .iter()
            .map(|p| backend.start_generation(p).unwrap().0)
            .collect();
        for step in 0..4 {
            let toks: Vec<i32> = steps.iter().map(|s| s[step]).collect();
            let got = backend.decode_batch(gens.iter_mut().collect(), &toks).unwrap();
            for (s, row) in got.iter().enumerate() {
                let row = row.as_ref().expect("per-sequence decode must succeed");
                for (a, b) in row.iter().zip(&want[s][step]) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "batched decode diverges (seq {s}, step {step})"
                    );
                }
            }
        }
        for (s, gen) in gens.iter().enumerate() {
            assert_eq!(gen.len(), prompts[s].len() + 4);
        }
    }

    /// decode_batch failures are per-sequence: a foreign Generation
    /// fails alone, its round-mates' steps stand and stay decodable.
    #[test]
    fn decode_batch_failures_are_per_sequence() {
        let backend = NativeBackend::new(tiny_model(), 4, 12, 2);
        let (mut good1, _) = backend.start_generation(&[1, 2, 3]).unwrap();
        let mut foreign = Generation::new(Box::new(42u32), 1, 12);
        let (mut good2, _) = backend.start_generation(&[4, 5]).unwrap();
        let rows = backend
            .decode_batch(vec![&mut good1, &mut foreign, &mut good2], &[7, 8, 9])
            .unwrap();
        assert!(rows[0].is_ok() && rows[2].is_ok(), "round-mates must survive");
        assert!(rows[1].as_ref().unwrap_err().contains("different backend"));
        assert_eq!((good1.len(), foreign.len(), good2.len()), (4, 1, 3));
        assert!(backend.decode(&mut good1, 1).is_ok(), "survivors keep decoding");
    }

    /// The speculative contract on a contiguous cache: `verify_draft`'s
    /// rows are bit-identical to sequential one-token decodes of the
    /// same tokens, and after `rollback_generation` a resumed decode is
    /// bit-identical to never having absorbed the rejected suffix.
    #[test]
    fn verify_draft_rows_match_decode_and_rollback_is_exact() {
        let model = tiny_model();
        let vocab = 64usize;
        let prompt: Vec<i32> = (0..5).map(|i| ((i * 11 + 2) % 64) as i32).collect();
        let draft: Vec<i32> = vec![9, 21, 33, 45];
        for threads in [1, 3] {
            let backend = NativeBackend::new(Arc::clone(&model), 2, 16, threads);
            // Reference: sequential decodes of the same tokens.
            let (mut refgen, _) = backend.start_generation(&prompt).unwrap();
            let want: Vec<Vec<f32>> =
                draft.iter().map(|&t| backend.decode(&mut refgen, t).unwrap()).collect();
            // One verify forward returns the same rows, bit for bit.
            let (mut gen, _) = backend.start_generation(&prompt).unwrap();
            let rows = backend.verify_draft(&mut gen, &draft).unwrap();
            assert_eq!(rows.len(), draft.len() * vocab);
            assert_eq!(gen.len(), prompt.len() + draft.len());
            for (i, want_row) in want.iter().enumerate() {
                for (a, b) in rows[i * vocab..(i + 1) * vocab].iter().zip(want_row) {
                    assert_eq!(a.to_bits(), b.to_bits(), "verify row {i} diverges (t={threads})");
                }
            }
            // Roll back past the first two tokens; a decode of a
            // *different* continuation matches a fresh generation that
            // never drafted.
            let keep = prompt.len() + 2;
            let freed = backend.rollback_generation(&mut gen, keep).unwrap();
            assert!(freed.is_empty(), "contiguous rollback frees no blocks");
            assert_eq!(gen.len(), keep);
            let got = backend.decode(&mut gen, 50).unwrap();
            let mut clean_prefix = prompt.clone();
            clean_prefix.extend(&draft[..2]);
            let (mut clean, _) = backend.start_generation(&clean_prefix).unwrap();
            let want = backend.decode(&mut clean, 50).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "post-rollback decode diverges");
            }
            // Rolling forward is refused; the state stays usable.
            assert!(backend.rollback_generation(&mut gen, 100).is_err());
            assert!(backend.decode(&mut gen, 1).is_ok());
        }
    }

    /// Generation misuse errors cleanly: empty/oversized prompts, bad
    /// tokens, cache exhaustion — and the pool survives all of it.
    #[test]
    fn generation_validates_inputs() {
        let backend = NativeBackend::new(tiny_model(), 2, 6, 2);
        assert!(backend.start_generation(&[]).is_err(), "empty prompt");
        assert!(backend.start_generation(&[0i32; 7]).is_err(), "prompt beyond cache");
        assert!(backend.start_generation(&[0, 64]).is_err(), "bad token id");
        let (mut gen, _) = backend.start_generation(&[1, 2, 3, 4]).unwrap();
        assert_eq!(gen.remaining(), 2);
        assert!(backend.decode(&mut gen, 64).is_err(), "bad decode token");
        backend.decode(&mut gen, 5).unwrap();
        backend.decode(&mut gen, 6).unwrap();
        let err = backend.decode(&mut gen, 7).unwrap_err();
        assert!(err.contains("kv cache full"), "{err}");
        // The backend still serves scoring and fresh generations.
        assert!(backend.forward_batch(&[1i32; 6]).is_ok());
        let (mut gen2, _) = backend.start_generation(&[1, 2]).unwrap();
        // Ownership is by model identity, not geometry: a different
        // backend over an identically-shaped model must refuse the
        // state instead of silently decoding a foreign cache.
        let other = NativeBackend::new(tiny_model(), 2, 6, 1);
        let err = other.decode(&mut gen2, 1).unwrap_err();
        assert!(err.contains("different backend"), "{err}");
        assert!(backend.decode(&mut gen2, 1).is_ok(), "the owner still decodes");
    }
}
