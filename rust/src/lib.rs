//! # GSR — Grouped Sequency-arranged Rotation
//!
//! Reproduction of *"Grouped Sequency-arranged Rotation: Optimizing Rotation
//! Transformation for Quantization for Free"* (ACL 2025 SRW) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (grouped Walsh–Hadamard transform, group
//!   quantization, dequant-matmul) authored in `python/compile/kernels/`
//!   and AOT-lowered to HLO text.
//! * **L2** — a Llama-style mini transformer in JAX whose quantized
//!   forward pass is exported per bit-config (`w2a16`, `w2a4`).
//! * **L3** — this crate: the native rotation/quantization library, the
//!   PJRT runtime that loads the AOT artifacts, and the serving/eval
//!   coordinator. Python never runs on the request path.
//!
//! The public API is organised bottom-up:
//!
//! * [`transform`] — Hadamard/Walsh construction, sequency math, RHT,
//!   block-diagonal (local) rotations, fast WHT.
//! * [`quant`] — RTN / GPTQ group quantizers, MSE clipping, bit packing.
//! * [`model`] — model configuration and a pure-Rust fp32 reference
//!   forward used to validate the PJRT path, plus the KV-cached
//!   incremental forward behind generation.
//! * [`data`] — synthetic corpus generation, byte tokenizer, zero-shot
//!   task suite.
//! * [`runtime`] — PJRT client wrapper: load HLO text, upload weights,
//!   execute.
//! * [`exec`] — the unified batched execution layer: one `Backend`
//!   trait with a multi-threaded native engine (persistent worker pool,
//!   per-thread scratch, bit-deterministic batching) and the PJRT
//!   runner view, plus the incremental prefill/decode generation
//!   contract; serves eval, calibration and the coordinator.
//! * [`coordinator`] — request router, dynamic batcher, variant registry,
//!   batched greedy generation, metrics.
//! * [`eval`] — perplexity and zero-shot evaluation engines + report
//!   tables matching the paper's layout.
//! * [`analysis`] — sequency-variance and outlier-spread analyses backing
//!   the paper's §3.2 argument and Fig. 2.
//! * [`calib`] — the `gsr calibrate` subsystem: streaming activation
//!   Hessians captured from the rotated forward, persisted as a
//!   reusable artifact, consumed by Hessian-calibrated GPTQ and the
//!   calibration-aware `gsr search` objective.
//! * [`sched`] — paged-KV serving primitives: the block pool behind the
//!   paged `KvCache`, the continuous-batching round policy, and the
//!   deterministic (seeded, replayable) temperature/top-k/top-p sampler.
//! * [`obs`] — observability: the metrics registry (counters / gauges /
//!   fixed-bucket histograms, Prometheus exposition, JSON snapshots)
//!   and the flight recorder (typed per-thread event rings exported as
//!   Chrome trace JSON or JSONL via `--trace`).
//! * [`search`] — the `gsr search` subsystem: a training-free per-layer
//!   rotation auto-configuration search (candidate grid × proxy
//!   objectives × parallel planner) producing a [`quant`] `RotationPlan`.

pub mod analysis;
pub mod calib;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exec;
pub mod model;
pub mod obs;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod search;
pub mod transform;

/// Crate-wide result type (std-only; no external error crate offline).
pub type Result<T> = std::result::Result<T, Box<dyn std::error::Error + Send + Sync>>;
