//! Observability: metrics registry + flight recorder.
//!
//! Two halves, one [`Obs`] bundle threaded through the serving stack:
//!
//! * [`registry`] — named counters/gauges/fixed-bucket histograms with
//!   label support, Prometheus text exposition (served over HTTP by
//!   [`http::MetricsServer`] behind `--metrics-addr`) and JSON
//!   snapshots (`--metrics-dump`). The coordinator's human-readable
//!   report is built from the same cells, so both views always agree.
//! * [`trace`] — the flight recorder: per-thread bounded ring buffers
//!   of typed events (admission, prefill chunks, decode rounds,
//!   preemption/resume, block grants, kernel-path selection, per-layer
//!   quantize/search telemetry), off by default and costing one relaxed
//!   atomic load when disabled. `--trace <path>` exports Chrome
//!   trace-event JSON (Perfetto-loadable) or JSONL; `gsr trace <file>`
//!   summarizes an export.

pub mod http;
pub mod registry;
pub mod trace;

use std::sync::Arc;

pub use http::MetricsServer;
pub use registry::{Counter, Gauge, Histogram, LatencyHistogram, Registry};
pub use trace::{FlightRecorder, RequestKind, TraceEvent, TraceHandle, TraceRecord};

/// The observability bundle handed to servers and pipelines: a metrics
/// registry plus a flight recorder. Cloning shares both halves.
#[derive(Clone, Default)]
pub struct Obs {
    pub registry: Arc<Registry>,
    pub recorder: Arc<FlightRecorder>,
}

impl Obs {
    /// A fresh registry and a disabled recorder.
    pub fn new() -> Obs {
        Obs::default()
    }
}
