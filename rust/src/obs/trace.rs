//! Flight recorder: lock-light per-thread ring buffers of typed
//! serving/quantization events, exportable as Chrome trace-event JSON
//! (loadable in Perfetto / `chrome://tracing`) or JSONL.
//!
//! Recording is off by default. A [`TraceHandle`] checks one relaxed
//! `AtomicBool` and returns before constructing anything when tracing
//! is disabled, so instrumentation left in hot paths costs a load and
//! a branch. When enabled, each handle appends to its own bounded ring
//! (registered per thread/component); at capacity the oldest record is
//! dropped and counted, never blocking the recording thread on export.
//!
//! Timestamps are microseconds from the recorder's epoch, taken from a
//! single monotonic [`Instant`], so records within one shard are
//! non-decreasing in time.

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::Json;

/// Default per-shard ring capacity (records kept per thread).
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// What kind of request a span belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestKind {
    /// Full-sequence scoring (`serve`).
    Score,
    /// Incremental generation (`generate`).
    Generate,
}

impl RequestKind {
    pub fn as_str(self) -> &'static str {
        match self {
            RequestKind::Score => "score",
            RequestKind::Generate => "generate",
        }
    }
}

/// A typed flight-recorder event. Request-scoped events carry the
/// executor-assigned request id so spans can be stitched back together.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A request passed admission and entered its variant queue.
    RequestAdmitted { id: u64, variant: String, kind: RequestKind, tokens: usize },
    /// A request failed admission (labeled by the rejection reason).
    RequestRejected { variant: String, reason: &'static str },
    /// A request replied successfully; closes its span.
    RequestCompleted { id: u64, produced: usize },
    /// A request replied with an error; closes its span.
    RequestFailed { id: u64, error: String },
    /// One chunked-prefill step absorbed `tokens` prompt tokens.
    PrefillChunk { id: u64, tokens: usize, cached: usize, dur_us: u64 },
    /// One continuous-batching decode round stepped `seqs` sequences.
    DecodeRound { variant: String, seqs: usize, dur_us: u64 },
    /// One scoring batch executed on the backend.
    BatchExec { variant: String, rows: usize, tokens: usize, dur_us: u64 },
    /// KV blocks granted to a sequence from the pool.
    BlocksGranted { id: u64, blocks: usize },
    /// A sequence was preempted: blocks evicted, cached tokens lost.
    Preempted { id: u64, blocks: usize, cached: usize },
    /// A previously preempted sequence started recomputing.
    Resumed { id: u64 },
    /// One speculative draft/verify round: `drafted` tokens proposed by
    /// the draft variant, `accepted` of them kept after target
    /// verification, `emitted` tokens appended to the output (accepted
    /// drafts plus the target's own pick).
    SpecRound { id: u64, drafted: usize, accepted: usize, emitted: usize, draft_us: u64, verify_us: u64 },
    /// Kernel-path selection for a variant at executor start.
    KernelPath { variant: String, mode: &'static str, packed: usize, dense_fallbacks: usize },
    /// One layer quantized: chosen rotation spec and proxy error.
    QuantLayer { layer: usize, spec: String, mse: f64 },
    /// One layer searched: winning spec vs the fixed-GSR baseline.
    SearchLayer { layer: usize, spec: String, mse: f64, baseline_mse: f64 },
}

impl TraceEvent {
    /// Short event name (Chrome trace `name`, JSONL `event` field).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::RequestAdmitted { .. } => "request_admitted",
            TraceEvent::RequestRejected { .. } => "request_rejected",
            TraceEvent::RequestCompleted { .. } => "request_completed",
            TraceEvent::RequestFailed { .. } => "request_failed",
            TraceEvent::PrefillChunk { .. } => "prefill_chunk",
            TraceEvent::DecodeRound { .. } => "decode_round",
            TraceEvent::BatchExec { .. } => "batch_exec",
            TraceEvent::BlocksGranted { .. } => "blocks_granted",
            TraceEvent::Preempted { .. } => "preempted",
            TraceEvent::Resumed { .. } => "resumed",
            TraceEvent::SpecRound { .. } => "spec_round",
            TraceEvent::KernelPath { .. } => "kernel_path",
            TraceEvent::QuantLayer { .. } => "quant_layer",
            TraceEvent::SearchLayer { .. } => "search_layer",
        }
    }

    /// Request id for request-scoped events (span stitching).
    pub fn request_id(&self) -> Option<u64> {
        match self {
            TraceEvent::RequestAdmitted { id, .. }
            | TraceEvent::RequestCompleted { id, .. }
            | TraceEvent::RequestFailed { id, .. }
            | TraceEvent::PrefillChunk { id, .. }
            | TraceEvent::BlocksGranted { id, .. }
            | TraceEvent::Preempted { id, .. }
            | TraceEvent::Resumed { id }
            | TraceEvent::SpecRound { id, .. } => Some(*id),
            _ => None,
        }
    }

    fn args(&self) -> Vec<(&'static str, Json)> {
        let n = |v: usize| Json::num(v as f64);
        let id = |v: u64| Json::num(v as f64);
        match self {
            TraceEvent::RequestAdmitted { id: i, variant, kind, tokens } => vec![
                ("id", id(*i)),
                ("variant", Json::str(variant)),
                ("kind", Json::str(kind.as_str())),
                ("tokens", n(*tokens)),
            ],
            TraceEvent::RequestRejected { variant, reason } => {
                vec![("variant", Json::str(variant)), ("reason", Json::str(reason))]
            }
            TraceEvent::RequestCompleted { id: i, produced } => {
                vec![("id", id(*i)), ("produced", n(*produced))]
            }
            TraceEvent::RequestFailed { id: i, error } => {
                vec![("id", id(*i)), ("error", Json::str(error))]
            }
            TraceEvent::PrefillChunk { id: i, tokens, cached, dur_us } => vec![
                ("id", id(*i)),
                ("tokens", n(*tokens)),
                ("cached", n(*cached)),
                ("dur_us", id(*dur_us)),
            ],
            TraceEvent::DecodeRound { variant, seqs, dur_us } => vec![
                ("variant", Json::str(variant)),
                ("seqs", n(*seqs)),
                ("dur_us", id(*dur_us)),
            ],
            TraceEvent::BatchExec { variant, rows, tokens, dur_us } => vec![
                ("variant", Json::str(variant)),
                ("rows", n(*rows)),
                ("tokens", n(*tokens)),
                ("dur_us", id(*dur_us)),
            ],
            TraceEvent::BlocksGranted { id: i, blocks } => {
                vec![("id", id(*i)), ("blocks", n(*blocks))]
            }
            TraceEvent::Preempted { id: i, blocks, cached } => {
                vec![("id", id(*i)), ("blocks", n(*blocks)), ("cached", n(*cached))]
            }
            TraceEvent::Resumed { id: i } => vec![("id", id(*i))],
            TraceEvent::SpecRound { id: i, drafted, accepted, emitted, draft_us, verify_us } => {
                vec![
                    ("id", id(*i)),
                    ("drafted", n(*drafted)),
                    ("accepted", n(*accepted)),
                    ("emitted", n(*emitted)),
                    ("draft_us", id(*draft_us)),
                    ("verify_us", id(*verify_us)),
                ]
            }
            TraceEvent::KernelPath { variant, mode, packed, dense_fallbacks } => vec![
                ("variant", Json::str(variant)),
                ("mode", Json::str(mode)),
                ("packed", n(*packed)),
                ("dense_fallbacks", n(*dense_fallbacks)),
            ],
            TraceEvent::QuantLayer { layer, spec, mse } => {
                vec![("layer", n(*layer)), ("spec", Json::str(spec)), ("mse", Json::num(*mse))]
            }
            TraceEvent::SearchLayer { layer, spec, mse, baseline_mse } => vec![
                ("layer", n(*layer)),
                ("spec", Json::str(spec)),
                ("mse", Json::num(*mse)),
                ("baseline_mse", Json::num(*baseline_mse)),
            ],
        }
    }
}

/// A timestamped record: microseconds from the recorder epoch plus the
/// typed event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    pub ts_us: u64,
    pub event: TraceEvent,
}

#[derive(Debug)]
struct Shard {
    label: String,
    dropped: AtomicU64,
    records: Mutex<VecDeque<TraceRecord>>,
}

/// The flight recorder: an enable flag, a monotonic epoch, and one
/// bounded ring buffer per registered handle.
#[derive(Debug)]
pub struct FlightRecorder {
    enabled: AtomicBool,
    epoch: Instant,
    capacity: usize,
    shards: Mutex<Vec<Arc<Shard>>>,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::with_capacity(DEFAULT_TRACE_CAPACITY)
    }
}

impl FlightRecorder {
    /// A disabled recorder with the default per-shard capacity.
    pub fn new() -> FlightRecorder {
        FlightRecorder::default()
    }

    /// A disabled recorder keeping at most `capacity` records per shard.
    pub fn with_capacity(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            capacity: capacity.max(1),
            shards: Mutex::new(Vec::new()),
        }
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Register a new per-thread/per-component ring and return its
    /// recording handle. `label` names the track in exported traces.
    pub fn handle(self: &Arc<Self>, label: &str) -> TraceHandle {
        let shard = Arc::new(Shard {
            label: label.to_string(),
            dropped: AtomicU64::new(0),
            records: Mutex::new(VecDeque::new()),
        });
        self.shards.lock().unwrap().push(Arc::clone(&shard));
        TraceHandle { recorder: Arc::clone(self), shard }
    }

    /// All recorded events, one `(label, dropped, records)` triple per
    /// shard in registration order.
    pub fn snapshot(&self) -> Vec<(String, u64, Vec<TraceRecord>)> {
        let shards = self.shards.lock().unwrap();
        shards
            .iter()
            .map(|s| {
                let records = s.records.lock().unwrap().iter().cloned().collect();
                (s.label.clone(), s.dropped.load(Ordering::Relaxed), records)
            })
            .collect()
    }

    /// Total records dropped to ring-capacity pressure across shards.
    pub fn dropped_total(&self) -> u64 {
        self.shards.lock().unwrap().iter().map(|s| s.dropped.load(Ordering::Relaxed)).sum()
    }

    /// Export as a Chrome trace-event JSON object (`traceEvents`
    /// array), loadable in Perfetto or `chrome://tracing`. Request
    /// spans become async begin/end pairs keyed by request id; timed
    /// events (`prefill_chunk`, `decode_round`, `batch_exec`,
    /// `spec_round`) become complete (`"X"`) slices; the rest become
    /// instants.
    pub fn export_chrome(&self) -> Json {
        let mut events = Vec::new();
        for (tid, (label, _dropped, records)) in self.snapshot().into_iter().enumerate() {
            let tid = tid + 1;
            events.push(Json::obj(vec![
                ("ph", Json::str("M")),
                ("name", Json::str("thread_name")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64)),
                ("args", Json::obj(vec![("name", Json::str(&label))])),
            ]));
            for r in records {
                events.push(chrome_event(tid, &r));
            }
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
        ])
    }

    /// Export as JSONL: one JSON object per record with `ts_us`,
    /// `thread`, `event` and the event's fields inlined.
    pub fn export_jsonl(&self) -> String {
        let mut out = String::new();
        for (label, _dropped, records) in self.snapshot() {
            for r in records {
                let mut obj = BTreeMap::new();
                obj.insert("ts_us".to_string(), Json::num(r.ts_us as f64));
                obj.insert("thread".to_string(), Json::str(&label));
                obj.insert("event".to_string(), Json::str(r.event.name()));
                for (k, v) in r.event.args() {
                    obj.insert(k.to_string(), v);
                }
                out.push_str(&Json::Obj(obj).to_string_compact());
                out.push('\n');
            }
        }
        out
    }

    /// Write the trace to `path`: `.jsonl` selects JSONL, anything
    /// else the Chrome trace-event JSON.
    pub fn write(&self, path: &Path) -> Result<(), String> {
        if path.extension().and_then(|e| e.to_str()) == Some("jsonl") {
            std::fs::write(path, self.export_jsonl()).map_err(|e| format!("{path:?}: {e}"))
        } else {
            self.export_chrome().to_file(path)
        }
    }
}

fn chrome_event(tid: usize, r: &TraceRecord) -> Json {
    let ts = r.ts_us as f64;
    let args: BTreeMap<String, Json> =
        r.event.args().into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    let base = |ph: &str, name: &str| {
        vec![
            ("ph", Json::str(ph)),
            ("name", Json::str(name)),
            ("cat", Json::str("gsr")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::Obj(args.clone())),
        ]
    };
    match &r.event {
        TraceEvent::RequestAdmitted { id, .. } => {
            let mut e = base("b", "request");
            e.push(("ts", Json::num(ts)));
            e.push(("id", Json::str(&id.to_string())));
            Json::obj(e)
        }
        TraceEvent::RequestCompleted { id, .. } | TraceEvent::RequestFailed { id, .. } => {
            let mut e = base("e", "request");
            e.push(("ts", Json::num(ts)));
            e.push(("id", Json::str(&id.to_string())));
            Json::obj(e)
        }
        TraceEvent::PrefillChunk { dur_us, .. }
        | TraceEvent::DecodeRound { dur_us, .. }
        | TraceEvent::BatchExec { dur_us, .. } => {
            let mut e = base("X", r.event.name());
            e.push(("ts", Json::num(r.ts_us.saturating_sub(*dur_us) as f64)));
            e.push(("dur", Json::num(*dur_us as f64)));
            Json::obj(e)
        }
        TraceEvent::SpecRound { draft_us, verify_us, .. } => {
            let dur = draft_us + verify_us;
            let mut e = base("X", r.event.name());
            e.push(("ts", Json::num(r.ts_us.saturating_sub(dur) as f64)));
            e.push(("dur", Json::num(dur as f64)));
            Json::obj(e)
        }
        _ => {
            let mut e = base("i", r.event.name());
            e.push(("ts", Json::num(ts)));
            e.push(("s", Json::str("t")));
            Json::obj(e)
        }
    }
}

/// A cheap cloneable recording handle bound to one ring buffer.
#[derive(Debug, Clone)]
pub struct TraceHandle {
    recorder: Arc<FlightRecorder>,
    shard: Arc<Shard>,
}

impl TraceHandle {
    /// Append an event (no-op unless the recorder is enabled).
    pub fn record(&self, event: TraceEvent) {
        if !self.recorder.enabled.load(Ordering::Relaxed) {
            return;
        }
        let ts_us = self.recorder.epoch.elapsed().as_micros() as u64;
        let mut ring = self.shard.records.lock().unwrap();
        if ring.len() >= self.recorder.capacity {
            ring.pop_front();
            self.shard.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(TraceRecord { ts_us, event });
    }

    /// Whether recording is currently enabled (lets callers skip
    /// argument construction for expensive events).
    pub fn enabled(&self) -> bool {
        self.recorder.is_enabled()
    }
}

/// Summarize a trace file (Chrome JSON or JSONL) for `gsr trace`:
/// event counts by name, span balance, threads and time range.
pub fn inspect(path: &Path) -> Result<String, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
    let mut by_name: BTreeMap<String, usize> = BTreeMap::new();
    let mut opened: BTreeMap<String, i64> = BTreeMap::new();
    let mut threads: BTreeMap<String, usize> = BTreeMap::new();
    let mut total = 0usize;
    let mut ts_min = f64::INFINITY;
    let mut ts_max = f64::NEG_INFINITY;
    let mut seen_ts = false;
    let trimmed = text.trim_start();
    let chrome = trimmed.starts_with('{');
    if chrome {
        let root = Json::parse(&text).map_err(|e| format!("{path:?}: {e}"))?;
        let events = root.at("traceEvents")?.as_arr().ok_or("traceEvents is not an array")?;
        let mut names: BTreeMap<u64, String> = BTreeMap::new();
        for e in events {
            let ph = e.get("ph").and_then(|p| p.as_str()).unwrap_or("");
            if ph == "M" {
                if let (Some(tid), Some(name)) = (
                    e.get("tid").and_then(|t| t.as_f64()),
                    e.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str()),
                ) {
                    names.insert(tid as u64, name.to_string());
                }
                continue;
            }
            total += 1;
            let name = e.get("name").and_then(|n| n.as_str()).unwrap_or("?").to_string();
            *by_name.entry(name).or_default() += 1;
            if let Some(tid) = e.get("tid").and_then(|t| t.as_f64()) {
                let tid = tid as u64;
                let label = names.get(&tid).cloned().unwrap_or_else(|| format!("tid {tid}"));
                *threads.entry(label).or_default() += 1;
            }
            if let Some(ts) = e.get("ts").and_then(|t| t.as_f64()) {
                seen_ts = true;
                ts_min = ts_min.min(ts);
                ts_max = ts_max.max(ts);
            }
            if ph == "b" || ph == "e" {
                let id = e.get("id").and_then(|i| i.as_str()).unwrap_or("?").to_string();
                *opened.entry(id).or_default() += if ph == "b" { 1 } else { -1 };
            }
        }
    } else {
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let e = Json::parse(line).map_err(|err| format!("{path:?}: {err}"))?;
            total += 1;
            let name = e.get("event").and_then(|n| n.as_str()).unwrap_or("?").to_string();
            *by_name.entry(name.clone()).or_default() += 1;
            if let Some(t) = e.get("thread").and_then(|t| t.as_str()) {
                *threads.entry(t.to_string()).or_default() += 1;
            }
            if let Some(ts) = e.get("ts_us").and_then(|t| t.as_f64()) {
                seen_ts = true;
                ts_min = ts_min.min(ts);
                ts_max = ts_max.max(ts);
            }
            if let Some(id) = e.get("id").and_then(|i| i.as_f64()) {
                let key = (id as u64).to_string();
                match name.as_str() {
                    "request_admitted" => *opened.entry(key).or_default() += 1,
                    "request_completed" | "request_failed" => *opened.entry(key).or_default() -= 1,
                    _ => {}
                }
            }
        }
    }
    let unclosed = opened.values().filter(|&&n| n != 0).count();
    let mut out = String::new();
    out.push_str(&format!(
        "{} trace: {total} events, {} threads",
        if chrome { "chrome" } else { "jsonl" },
        threads.len()
    ));
    if seen_ts {
        out.push_str(&format!(", span {:.1} ms", (ts_max - ts_min) / 1000.0));
    }
    out.push('\n');
    for (t, n) in &threads {
        out.push_str(&format!("  thread {t}: {n} events\n"));
    }
    for (name, n) in &by_name {
        out.push_str(&format!("  {name}: {n}\n"));
    }
    out.push_str(&format!("  request spans: {} tracked, {unclosed} unclosed\n", opened.len()));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = Arc::new(FlightRecorder::new());
        let h = rec.handle("t");
        h.record(TraceEvent::Resumed { id: 1 });
        assert!(rec.snapshot()[0].2.is_empty());
        assert!(!h.enabled());
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let rec = Arc::new(FlightRecorder::with_capacity(4));
        rec.enable();
        let h = rec.handle("t");
        for i in 0..10 {
            h.record(TraceEvent::Resumed { id: i });
        }
        let (_, dropped, records) = &rec.snapshot()[0];
        assert_eq!(records.len(), 4);
        assert_eq!(*dropped, 6);
        // Oldest dropped first: the survivors are the last four.
        assert_eq!(records[0].event, TraceEvent::Resumed { id: 6 });
    }

    #[test]
    fn timestamps_are_monotone_per_shard() {
        let rec = Arc::new(FlightRecorder::new());
        rec.enable();
        let h = rec.handle("t");
        for i in 0..100 {
            h.record(TraceEvent::Resumed { id: i });
        }
        let records = &rec.snapshot()[0].2;
        for w in records.windows(2) {
            assert!(w[0].ts_us <= w[1].ts_us);
        }
    }

    #[test]
    fn chrome_export_pairs_spans_and_parses() {
        let rec = Arc::new(FlightRecorder::new());
        rec.enable();
        let h = rec.handle("executor");
        h.record(TraceEvent::RequestAdmitted {
            id: 1,
            variant: "fp".into(),
            kind: RequestKind::Generate,
            tokens: 4,
        });
        h.record(TraceEvent::PrefillChunk { id: 1, tokens: 4, cached: 0, dur_us: 120 });
        h.record(TraceEvent::DecodeRound { variant: "fp".into(), seqs: 1, dur_us: 80 });
        h.record(TraceEvent::RequestCompleted { id: 1, produced: 3 });
        let text = rec.export_chrome().to_string_pretty();
        let back = Json::parse(&text).unwrap();
        let events = back.at("traceEvents").unwrap().as_arr().unwrap();
        let phs: Vec<&str> =
            events.iter().filter_map(|e| e.get("ph").and_then(|p| p.as_str())).collect();
        assert_eq!(phs, vec!["M", "b", "X", "X", "e"]);
        // The begin/end pair shares the request id.
        let b = &events[1];
        let e = &events[4];
        assert_eq!(b.get("id").unwrap().as_str(), Some("1"));
        assert_eq!(e.get("id").unwrap().as_str(), Some("1"));
    }

    #[test]
    fn jsonl_lines_parse_and_inspect_summarizes() {
        let rec = Arc::new(FlightRecorder::new());
        rec.enable();
        let h = rec.handle("executor");
        h.record(TraceEvent::RequestAdmitted {
            id: 7,
            variant: "fp".into(),
            kind: RequestKind::Score,
            tokens: 8,
        });
        h.record(TraceEvent::RequestCompleted { id: 7, produced: 0 });
        let jsonl = rec.export_jsonl();
        assert_eq!(jsonl.lines().count(), 2);
        for line in jsonl.lines() {
            let e = Json::parse(line).unwrap();
            assert!(e.get("ts_us").is_some());
            assert_eq!(e.at("thread").unwrap().as_str(), Some("executor"));
        }
        let dir = std::env::temp_dir().join("gsr_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.jsonl");
        rec.write(&p).unwrap();
        let summary = inspect(&p).unwrap();
        assert!(summary.contains("request_admitted: 1"), "{summary}");
        assert!(summary.contains("0 unclosed"), "{summary}");
        std::fs::remove_file(&p).ok();
    }
}
