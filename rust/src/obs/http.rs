//! Minimal Prometheus scrape endpoint on `std::net::TcpListener`.
//!
//! One background thread accepts connections and answers every request
//! with the registry's current text exposition — no routing, no HTTP
//! parsing beyond draining the request head, no external dependencies.
//! Shutdown is cooperative: `Drop` sets a stop flag and wakes the
//! accept loop with a self-connection.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry::Registry;

/// A running metrics endpoint; scrape it with
/// `curl http://<addr>/metrics` (any path answers the same).
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`, port 0 picks a free port)
    /// and serve `registry`'s Prometheus exposition until dropped.
    pub fn serve(addr: &str, registry: Arc<Registry>) -> Result<MetricsServer, String> {
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let local = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_bg = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("gsr-metrics".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_bg.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        let _ = answer(stream, &registry);
                    }
                }
            })
            .map_err(|e| format!("spawn metrics thread: {e}"))?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

fn answer(mut stream: TcpStream, registry: &Registry) -> std::io::Result<()> {
    // Drain (best-effort) the request head so the client can write it
    // fully, then reply unconditionally with the exposition.
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut buf = [0u8; 4096];
    let _ = stream.read(&mut buf);
    let body = registry.expose_prometheus();
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_exposition_and_shuts_down() {
        let registry = Arc::new(Registry::new());
        registry.counter("gsr_requests_total", "requests served").add(5);
        let srv = MetricsServer::serve("127.0.0.1:0", Arc::clone(&registry)).unwrap();
        let mut conn = TcpStream::connect(srv.addr()).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut text = String::new();
        conn.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
        assert!(text.contains("# TYPE gsr_requests_total counter"), "{text}");
        assert!(text.contains("gsr_requests_total 5"), "{text}");
        drop(srv); // must not hang
    }
}
