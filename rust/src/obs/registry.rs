//! Metrics registry: named counters, gauges and fixed-bucket histograms
//! with label support, Prometheus text exposition and JSON snapshots.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`'d
//! atomic cells acquired once at registration; the hot path is a single
//! relaxed atomic op with no lock. The registry's `Mutex` is touched
//! only when a handle is created and when the registry is exposed or
//! snapshotted — never per sample.
//!
//! Histograms use fixed log2 microsecond buckets (bounded memory under
//! sustained traffic, unlike raw-sample vectors): bucket `i` covers
//! `[2^i, 2^(i+1))` µs, and a quantile estimate returns the bucket's
//! upper bound, so `estimate / exact ∈ [1, 2]` — pinned by a unit test
//! against exact quantiles below.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::Json;

/// Number of log2 µs histogram buckets: bucket `i` holds samples in
/// `[2^i, 2^(i+1))` µs, and the last bucket absorbs everything from
/// 2^29 µs (≈ 9 minutes) up.
pub const HIST_BUCKETS: usize = 30;

fn bucket_index(us: u64) -> usize {
    (63 - us.max(1).leading_zeros() as usize).min(HIST_BUCKETS - 1)
}

/// Upper bound (µs) of histogram bucket `i`.
pub fn bucket_bound_us(i: usize) -> u64 {
    1u64 << (i + 1)
}

/// A monotonically increasing counter handle (relaxed atomic add).
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge handle: a settable value (also supports monotone-max and
/// add for resource totals assembled from parts).
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if `v` is larger (peak tracking).
    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Shared histogram cell: fixed log2-µs buckets plus count/sum/max.
#[derive(Debug, Default)]
struct HistCell {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

/// A fixed-bucket latency histogram handle (relaxed atomics; bounded
/// memory regardless of sample count).
#[derive(Debug, Clone, Default)]
pub struct Histogram(Arc<HistCell>);

impl Histogram {
    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn record_us(&self, us: u64) {
        let c = &self.0;
        c.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        c.sum_us.fetch_add(us, Ordering::Relaxed);
        c.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Materialize a plain (non-atomic) snapshot for reporting.
    pub fn snapshot(&self) -> LatencyHistogram {
        let c = &self.0;
        let mut buckets = [0u64; HIST_BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(c.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        LatencyHistogram {
            buckets,
            count: c.count.load(Ordering::Relaxed),
            sum_us: c.sum_us.load(Ordering::Relaxed),
            max_us: c.max_us.load(Ordering::Relaxed),
        }
    }
}

/// A plain fixed-bucket latency histogram: the snapshot form of
/// [`Histogram`], and the type the serving report computes quantiles
/// from. Memory is constant (30 buckets) no matter how many samples
/// are recorded.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean recorded latency (zero when empty).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(self.sum_us / self.count)
        }
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Sum of all recorded latencies.
    pub fn total(&self) -> Duration {
        Duration::from_micros(self.sum_us)
    }

    /// Quantile estimate: the upper bound of the bucket containing the
    /// `q`-quantile sample. Log2 buckets bound the overestimate to at
    /// most 2× the exact order statistic (and never undershoot it).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return Duration::from_micros(bucket_bound_us(i));
            }
        }
        self.max()
    }

    /// Per-bucket counts (for exposition and tests).
    pub fn buckets(&self) -> &[u64; HIST_BUCKETS] {
        &self.buckets
    }
}

/// Metric kind, as exposed in the Prometheus `# TYPE` line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

enum Cell {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

type LabelSet = Vec<(String, String)>;

struct Family {
    help: String,
    kind: Kind,
    cells: BTreeMap<LabelSet, Cell>,
}

/// A named-metric registry. Registration returns cheap cloneable
/// handles; re-registering the same name + labels returns a handle to
/// the same underlying cell, so instrumentation sites never need to
/// coordinate. Registering an existing name with a different kind is a
/// programming error and panics.
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register (or look up) an unlabeled counter.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        self.counter_with(name, help, &[])
    }

    /// Register (or look up) a counter cell with the given labels.
    pub fn counter_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        match self.cell(name, help, Kind::Counter, labels) {
            Cell::Counter(c) => c,
            _ => unreachable!("kind checked in cell()"),
        }
    }

    /// Register (or look up) an unlabeled gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        self.gauge_with(name, help, &[])
    }

    /// Register (or look up) a gauge cell with the given labels.
    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.cell(name, help, Kind::Gauge, labels) {
            Cell::Gauge(g) => g,
            _ => unreachable!("kind checked in cell()"),
        }
    }

    /// Register (or look up) an unlabeled histogram.
    pub fn histogram(&self, name: &str, help: &str) -> Histogram {
        self.histogram_with(name, help, &[])
    }

    /// Register (or look up) a histogram cell with the given labels.
    pub fn histogram_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Histogram {
        match self.cell(name, help, Kind::Histogram, labels) {
            Cell::Histogram(h) => h,
            _ => unreachable!("kind checked in cell()"),
        }
    }

    fn cell(&self, name: &str, help: &str, kind: Kind, labels: &[(&str, &str)]) -> Cell {
        assert!(valid_name(name), "invalid metric name {name:?}");
        for (k, _) in labels {
            assert!(valid_name(k), "invalid label name {k:?}");
        }
        let mut key: LabelSet =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        key.sort();
        let mut families = self.families.lock().unwrap();
        let fam = families.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            cells: BTreeMap::new(),
        });
        assert!(
            fam.kind == kind,
            "metric {name:?} registered as {} and {}",
            fam.kind.as_str(),
            kind.as_str()
        );
        let cell = fam.cells.entry(key).or_insert_with(|| match kind {
            Kind::Counter => Cell::Counter(Counter::default()),
            Kind::Gauge => Cell::Gauge(Gauge::default()),
            Kind::Histogram => Cell::Histogram(Histogram::default()),
        });
        match cell {
            Cell::Counter(c) => Cell::Counter(c.clone()),
            Cell::Gauge(g) => Cell::Gauge(g.clone()),
            Cell::Histogram(h) => Cell::Histogram(h.clone()),
        }
    }

    /// Render the whole registry in the Prometheus text exposition
    /// format (`text/plain; version=0.0.4`): `# HELP` / `# TYPE` per
    /// family, cumulative `_bucket{le=...}` + `_sum` + `_count` for
    /// histograms (sums in microseconds, matching the `_us` suffix of
    /// the family names).
    pub fn expose_prometheus(&self) -> String {
        let families = self.families.lock().unwrap();
        let mut out = String::new();
        for (name, fam) in families.iter() {
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {}\n", fam.kind.as_str()));
            for (labels, cell) in fam.cells.iter() {
                match cell {
                    Cell::Counter(c) => {
                        out.push_str(&format!("{name}{} {}\n", render_labels(labels, None), c.get()))
                    }
                    Cell::Gauge(g) => {
                        out.push_str(&format!("{name}{} {}\n", render_labels(labels, None), g.get()))
                    }
                    Cell::Histogram(h) => {
                        let snap = h.snapshot();
                        let mut cum = 0u64;
                        for (i, &b) in snap.buckets().iter().enumerate() {
                            cum += b;
                            let le = bucket_bound_us(i).to_string();
                            out.push_str(&format!(
                                "{name}_bucket{} {cum}\n",
                                render_labels(labels, Some(&le))
                            ));
                        }
                        out.push_str(&format!(
                            "{name}_bucket{} {}\n",
                            render_labels(labels, Some("+Inf")),
                            snap.count()
                        ));
                        out.push_str(&format!(
                            "{name}_sum{} {}\n",
                            render_labels(labels, None),
                            snap.total().as_micros()
                        ));
                        out.push_str(&format!(
                            "{name}_count{} {}\n",
                            render_labels(labels, None),
                            snap.count()
                        ));
                    }
                }
            }
        }
        out
    }

    /// Snapshot every metric as JSON (for `--metrics-dump`).
    pub fn snapshot_json(&self) -> Json {
        let families = self.families.lock().unwrap();
        let mut root = BTreeMap::new();
        for (name, fam) in families.iter() {
            let mut values = Vec::new();
            for (labels, cell) in fam.cells.iter() {
                let mut entry = BTreeMap::new();
                let mut lbl = BTreeMap::new();
                for (k, v) in labels {
                    lbl.insert(k.clone(), Json::str(v));
                }
                entry.insert("labels".to_string(), Json::Obj(lbl));
                match cell {
                    Cell::Counter(c) => {
                        entry.insert("value".to_string(), Json::num(c.get() as f64));
                    }
                    Cell::Gauge(g) => {
                        entry.insert("value".to_string(), Json::num(g.get() as f64));
                    }
                    Cell::Histogram(h) => {
                        let snap = h.snapshot();
                        entry.insert("count".to_string(), Json::num(snap.count() as f64));
                        entry.insert(
                            "sum_us".to_string(),
                            Json::num(snap.total().as_micros() as f64),
                        );
                        entry.insert(
                            "max_us".to_string(),
                            Json::num(snap.max().as_micros() as f64),
                        );
                        let b: Vec<f64> = snap.buckets().iter().map(|&x| x as f64).collect();
                        entry.insert("buckets".to_string(), Json::arr_f64(&b));
                    }
                }
                values.push(Json::Obj(entry));
            }
            root.insert(
                name.clone(),
                Json::obj(vec![
                    ("type", Json::str(fam.kind.as_str())),
                    ("help", Json::str(&fam.help)),
                    ("values", Json::Arr(values)),
                ]),
            );
        }
        Json::Obj(root)
    }

    /// Write the JSON snapshot to `path`.
    pub fn write_snapshot(&self, path: &Path) -> Result<(), String> {
        self.snapshot_json().to_file(path)
    }
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.bytes().next().map(|b| b.is_ascii_alphabetic() || b == b'_').unwrap_or(false)
        && name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_')
}

fn render_labels(labels: &LabelSet, le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("gsr_requests_total", "requests");
        c.inc();
        c.add(2);
        // Re-registration returns a handle to the same cell.
        assert_eq!(r.counter("gsr_requests_total", "requests").get(), 3);
        let g = r.gauge("gsr_kv_blocks", "pool size");
        g.set(5);
        g.set_max(3);
        assert_eq!(g.get(), 5);
        g.set_max(9);
        assert_eq!(g.get(), 9);
    }

    #[test]
    fn labeled_cells_are_distinct() {
        let r = Registry::new();
        let a = r.counter_with("gsr_rejected_total", "rejections", &[("reason", "too_long")]);
        let b = r.counter_with("gsr_rejected_total", "rejections", &[("reason", "bad_token")]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 1);
        assert_eq!(b.get(), 2);
        let text = r.expose_prometheus();
        assert!(text.contains("gsr_rejected_total{reason=\"too_long\"} 1"));
        assert!(text.contains("gsr_rejected_total{reason=\"bad_token\"} 2"));
    }

    #[test]
    #[should_panic(expected = "registered as")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("gsr_x", "x");
        r.gauge("gsr_x", "x");
    }

    #[test]
    fn histogram_exposition_is_cumulative() {
        let r = Registry::new();
        let h = r.histogram("gsr_lat_us", "latency");
        h.record_us(1); // bucket 0 (le=2)
        h.record_us(3); // bucket 1 (le=4)
        h.record_us(3);
        let text = r.expose_prometheus();
        assert!(text.contains("# TYPE gsr_lat_us histogram"));
        assert!(text.contains("gsr_lat_us_bucket{le=\"2\"} 1"));
        assert!(text.contains("gsr_lat_us_bucket{le=\"4\"} 3"));
        assert!(text.contains("gsr_lat_us_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("gsr_lat_us_sum 7"));
        assert!(text.contains("gsr_lat_us_count 3"));
    }

    #[test]
    fn quantile_estimate_within_2x_of_exact() {
        // The satellite contract: log2 buckets never undershoot the
        // exact order statistic and overshoot by at most 2x.
        let mut h = LatencyHistogram::default();
        let mut exact: Vec<u64> = Vec::new();
        let mut x = 9u64;
        for _ in 0..10_000 {
            // Deterministic pseudo-random spread across several decades.
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let us = 1 + (x >> 33) % 1_000_000;
            exact.push(us);
            h.record(Duration::from_micros(us));
        }
        exact.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999] {
            let target = ((q * exact.len() as f64).ceil() as usize).clamp(1, exact.len());
            let exact_q = exact[target - 1];
            let est = h.quantile(q).as_micros() as u64;
            assert!(est >= exact_q, "q={q}: estimate {est} under exact {exact_q}");
            assert!(est <= 2 * exact_q, "q={q}: estimate {est} above 2x exact {exact_q}");
        }
    }

    #[test]
    fn snapshot_json_parses_back() {
        let r = Registry::new();
        r.counter("gsr_a_total", "a").add(7);
        r.histogram("gsr_b_us", "b").record_us(100);
        let text = r.snapshot_json().to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.at("gsr_a_total").unwrap().at("type").unwrap().as_str(), Some("counter"));
        let vals = back.at("gsr_b_us").unwrap().at("values").unwrap().as_arr().unwrap();
        assert_eq!(vals[0].at("count").unwrap().as_usize(), Some(1));
    }
}
