//! Sylvester-construction Hadamard matrices (paper Eq. 1).

use super::{is_pow2, Mat};

/// Orthonormal Sylvester Hadamard matrix of size `n` (power of two).
///
/// Natural (Hadamard) ordering: `H_{2^k} = H_2 ⊗ H_{2^{k-1}}`. Entry
/// `(i, j)` is `(-1)^{popcount(i & j)} / sqrt(n)` — the closed form of
/// the recursive doubling, used directly here.
pub fn hadamard(n: usize) -> Mat {
    assert!(is_pow2(n), "Hadamard size must be a power of two, got {n}");
    let scale = 1.0 / (n as f64).sqrt();
    Mat::from_fn(n, n, |i, j| {
        let sign = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
        sign * scale
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h2_matches_definition() {
        let h = hadamard(2);
        let s = 1.0 / 2f64.sqrt();
        assert_eq!(h.data, vec![s, s, s, -s]);
    }

    #[test]
    fn orthonormal_up_to_512() {
        for k in 0..=9 {
            let n = 1 << k;
            assert!(
                hadamard(n).orthogonality_defect() < 1e-10,
                "defect at n={n}"
            );
        }
    }

    #[test]
    fn symmetric() {
        let h = hadamard(64);
        for i in 0..64 {
            for j in 0..64 {
                assert_eq!(h[(i, j)], h[(j, i)]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        hadamard(12);
    }
}
