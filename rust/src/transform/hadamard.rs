//! Sylvester-construction Hadamard matrices (paper Eq. 1).

use super::{is_pow2, Mat};

/// Fallible Hadamard constructor: explicit, early error for invalid
/// sizes instead of a deep panic — the `gsr search` grid probes
/// arbitrary block sizes and must survive the invalid ones.
pub fn try_hadamard(n: usize) -> Result<Mat, String> {
    if !is_pow2(n) {
        return Err(format!("Hadamard size must be a power of two, got {n}"));
    }
    let scale = 1.0 / (n as f64).sqrt();
    Ok(Mat::from_fn(n, n, |i, j| {
        let sign = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
        sign * scale
    }))
}

/// Orthonormal Sylvester Hadamard matrix of size `n` (power of two).
///
/// Natural (Hadamard) ordering: `H_{2^k} = H_2 ⊗ H_{2^{k-1}}`. Entry
/// `(i, j)` is `(-1)^{popcount(i & j)} / sqrt(n)` — the closed form of
/// the recursive doubling, used directly here. Panics on invalid sizes;
/// use [`try_hadamard`] where the size is untrusted.
pub fn hadamard(n: usize) -> Mat {
    try_hadamard(n).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h2_matches_definition() {
        let h = hadamard(2);
        let s = 1.0 / 2f64.sqrt();
        assert_eq!(h.data, vec![s, s, s, -s]);
    }

    #[test]
    fn orthonormal_up_to_512() {
        for k in 0..=9 {
            let n = 1 << k;
            assert!(
                hadamard(n).orthogonality_defect() < 1e-10,
                "defect at n={n}"
            );
        }
    }

    #[test]
    fn symmetric() {
        let h = hadamard(64);
        for i in 0..64 {
            for j in 0..64 {
                assert_eq!(h[(i, j)], h[(j, i)]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        hadamard(12);
    }

    #[test]
    fn try_constructor_errors_early_on_bad_sizes() {
        let err = try_hadamard(12).unwrap_err();
        assert!(err.contains("power of two") && err.contains("12"), "{err}");
        assert!(try_hadamard(0).is_err());
        assert!(try_hadamard(64).is_ok());
    }
}
