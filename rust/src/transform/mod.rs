//! Rotation-matrix construction and fast transforms (paper §2.1, §3.1).
//!
//! Native mirror of `python/compile/rotation.py`: Sylvester Hadamard,
//! sequency-ordered Walsh, randomized Hadamard (RHT), block-diagonal
//! (local) rotations including the paper's GSR, plus the O(n log n)
//! in-place fast Walsh–Hadamard transform used by the analysis and bench
//! layers.

pub mod blockdiag;
pub mod fwht;
pub mod hadamard;
pub mod rht;
pub mod sequency;
pub mod walsh;

pub use blockdiag::{block_diag, build_r1, try_block_diag, try_build_r1, R1Kind};
pub use fwht::{fwht, fwht_batch, grouped_fwht, grouped_fwht_batch};
pub use hadamard::{hadamard, try_hadamard};
pub use rht::rht;
pub use sequency::{sequency_of_natural_row, sequency_of_row, walsh_permutation};
pub use walsh::{try_walsh, walsh};

/// Dense row-major f64 matrix — small build/analysis-time object
/// (rotation matrices are at most `d_ffn × d_ffn` here).
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Row-major data, `rows * cols` long.
    pub data: Vec<f64>,
    pub rows: usize,
    pub cols: usize,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![0.0; rows * cols], rows, cols }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = f(r, c);
            }
        }
        m
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self[(c, r)])
    }

    /// Dense matmul (naive; build-time sizes only).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Max |AAᵀ − I| — orthonormality defect.
    pub fn orthogonality_defect(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        let aat = self.matmul(&self.transpose());
        let mut worst = 0.0f64;
        for i in 0..self.rows {
            for j in 0..self.cols {
                let target = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((aat[(i, j)] - target).abs());
            }
        }
        worst
    }

    /// `x @ self` for a single row vector `x` (length `rows`).
    pub fn apply_right(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for (k, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            for (o, &m) in out.iter_mut().zip(self.row(k)) {
                *o += xv * m;
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

/// `true` iff `n` is a positive power of two (transform size contract).
pub fn is_pow2(n: usize) -> bool {
    n > 0 && n & (n - 1) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_orthogonal() {
        assert_eq!(Mat::identity(8).orthogonality_defect(), 0.0);
    }

    #[test]
    fn matmul_identity() {
        let m = Mat::from_fn(4, 4, |r, c| (r * 4 + c) as f64);
        let i = Mat::identity(4);
        assert_eq!(m.matmul(&i), m);
        assert_eq!(i.matmul(&m), m);
    }

    #[test]
    fn apply_right_matches_matmul() {
        let m = Mat::from_fn(3, 3, |r, c| (r + 2 * c) as f64);
        let x = [1.0, -2.0, 0.5];
        let y = m.apply_right(&x);
        for c in 0..3 {
            let expect: f64 = (0..3).map(|r| x[r] * m[(r, c)]).sum();
            assert!((y[c] - expect).abs() < 1e-12);
        }
    }
}
