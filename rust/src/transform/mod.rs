//! Rotation-matrix construction and fast transforms (paper §2.1, §3.1).
//!
//! Native mirror of `python/compile/rotation.py`: Sylvester Hadamard,
//! sequency-ordered Walsh, randomized Hadamard (RHT), block-diagonal
//! (local) rotations including the paper's GSR, plus the O(n log n)
//! in-place fast Walsh–Hadamard transform used by the analysis and bench
//! layers.

pub mod blockdiag;
pub mod fwht;
pub mod hadamard;
pub mod parametric;
pub mod rht;
pub mod sequency;
pub mod walsh;

pub use blockdiag::{block_diag, build_r1, try_block_diag, try_build_r1, R1Kind};
pub use parametric::{
    angle_stages, apply_parametric_t, default_angles, mask_angles, try_build_parametric,
};
pub use fwht::{fwht, fwht_batch, grouped_fwht, grouped_fwht_batch};
pub use hadamard::{hadamard, try_hadamard};
pub use rht::rht;
pub use sequency::{sequency_of_natural_row, sequency_of_row, walsh_permutation};
pub use walsh::{try_walsh, walsh};

/// Dense row-major f64 matrix — small build/analysis-time object
/// (rotation matrices are at most `d_ffn × d_ffn` here).
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Row-major data, `rows * cols` long.
    pub data: Vec<f64>,
    pub rows: usize,
    pub cols: usize,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![0.0; rows * cols], rows, cols }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = f(r, c);
            }
        }
        m
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self[(c, r)])
    }

    /// Dense matmul, cache-blocked over `(k, j)`.
    ///
    /// The k/j tile of `other` (≤ `MM_BK × MM_BJ` f64s, ~64 KB) stays
    /// cache-resident while every output row sweeps over it, cutting
    /// B-matrix memory traffic by ~`MM_BK`× versus the naive row-major
    /// walk once `other` outgrows L2 — the regime the search objective's
    /// `R1ᵀ·stream` products and the calibration subsystem's Hessian
    /// basis changes (`R H Rᵀ` at `d_ffn × d_ffn`) live in. Zero entries
    /// of `self` are still skipped, which keeps block-diagonal R1
    /// products cheap. Per output element the summation order is k
    /// ascending, identical to the naive loop, so results are
    /// bit-for-bit unchanged. Measured win: `benches/transform_perf.rs`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        const MM_BK: usize = 64;
        const MM_BJ: usize = 128;
        let (n, m) = (self.cols, other.cols);
        let mut out = Mat::zeros(self.rows, m);
        for kb in (0..n).step_by(MM_BK) {
            let ke = (kb + MM_BK).min(n);
            for jb in (0..m).step_by(MM_BJ) {
                let je = (jb + MM_BJ).min(m);
                for i in 0..self.rows {
                    let arow = &self.data[i * n..(i + 1) * n];
                    let orow = &mut out.data[i * m + jb..i * m + je];
                    for (k, &a) in arow.iter().enumerate().take(ke).skip(kb) {
                        if a == 0.0 {
                            continue;
                        }
                        let brow = &other.data[k * m + jb..k * m + je];
                        for (o, &b) in orow.iter_mut().zip(brow) {
                            *o += a * b;
                        }
                    }
                }
            }
        }
        out
    }

    /// Max |AAᵀ − I| — orthonormality defect.
    pub fn orthogonality_defect(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        let aat = self.matmul(&self.transpose());
        let mut worst = 0.0f64;
        for i in 0..self.rows {
            for j in 0..self.cols {
                let target = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((aat[(i, j)] - target).abs());
            }
        }
        worst
    }

    /// `x @ self` for a single row vector `x` (length `rows`).
    pub fn apply_right(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for (k, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            for (o, &m) in out.iter_mut().zip(self.row(k)) {
                *o += xv * m;
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

/// `true` iff `n` is a positive power of two (transform size contract).
pub fn is_pow2(n: usize) -> bool {
    n > 0 && n & (n - 1) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_orthogonal() {
        assert_eq!(Mat::identity(8).orthogonality_defect(), 0.0);
    }

    #[test]
    fn matmul_identity() {
        let m = Mat::from_fn(4, 4, |r, c| (r * 4 + c) as f64);
        let i = Mat::identity(4);
        assert_eq!(m.matmul(&i), m);
        assert_eq!(i.matmul(&m), m);
    }

    /// The cache-blocked matmul must agree with a naive triple loop,
    /// including at sizes that do not align with the tile edges.
    #[test]
    fn blocked_matmul_matches_naive_reference() {
        let naive = |a: &Mat, b: &Mat| -> Mat {
            let mut out = Mat::zeros(a.rows, b.cols);
            for i in 0..a.rows {
                for k in 0..a.cols {
                    for j in 0..b.cols {
                        out[(i, j)] += a[(i, k)] * b[(k, j)];
                    }
                }
            }
            out
        };
        let mut state = 1u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((state >> 33) as f64 / (1u64 << 31) as f64) - 1.0
        };
        for (r, n, c) in [(3, 5, 7), (65, 130, 129), (1, 64, 200), (70, 1, 3)] {
            let a = Mat::from_fn(r, n, |_, _| next());
            let b = Mat::from_fn(n, c, |_, _| next());
            let fast = a.matmul(&b);
            let slow = naive(&a, &b);
            for (x, y) in fast.data.iter().zip(&slow.data) {
                assert!((x - y).abs() < 1e-12, "blocked matmul diverges: {x} vs {y}");
            }
        }
    }

    #[test]
    fn apply_right_matches_matmul() {
        let m = Mat::from_fn(3, 3, |r, c| (r + 2 * c) as f64);
        let x = [1.0, -2.0, 0.5];
        let y = m.apply_right(&x);
        for c in 0..3 {
            let expect: f64 = (0..3).map(|r| x[r] * m[(r, c)]).sum();
            assert!((y[c] - expect).abs() < 1e-12);
        }
    }
}
