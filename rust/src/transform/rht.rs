//! Randomized Hadamard Transform (QuIP# / QuaRot incoherence processing).

use super::{hadamard, Mat};
use crate::rng::SplitMix64;

/// `H · diag(s)` with iid Rademacher signs drawn from `rng`.
///
/// Column sign flips keep the *row* sequency arrangement intact (paper
/// §3.2 "Comparing RHT and Walsh") — randomization and sequency
/// re-ordering are independent axes.
pub fn rht(n: usize, rng: &mut SplitMix64) -> Mat {
    let mut h = hadamard(n);
    let signs: Vec<f64> = (0..n).map(|_| rng.next_sign()).collect();
    for r in 0..n {
        for (c, &s) in signs.iter().enumerate() {
            h[(r, c)] *= s;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::sequency::sequency_of_row;

    #[test]
    fn orthonormal() {
        let mut rng = SplitMix64::new(1);
        assert!(rht(64, &mut rng).orthogonality_defect() < 1e-10);
    }

    #[test]
    fn row_sequency_distribution_varies_but_entries_are_pm() {
        let mut rng = SplitMix64::new(2);
        let m = rht(32, &mut rng);
        let v = 1.0 / (32f64).sqrt();
        for x in &m.data {
            assert!((x.abs() - v).abs() < 1e-12);
        }
        // Sign flips perturb individual row sequencies but the matrix
        // remains a signed Hadamard (entries ±1/√n).
        let _ = sequency_of_row(m.row(0));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = rht(16, &mut SplitMix64::new(9));
        let b = rht(16, &mut SplitMix64::new(9));
        assert_eq!(a, b);
    }
}
