//! Walsh (sequency-ordered Hadamard) matrices — the paper's key object.

use super::{hadamard::try_hadamard, sequency::walsh_permutation, Mat};

/// Fallible Walsh constructor — explicit early error for non-power-of-
/// two sizes (see [`try_hadamard`]).
pub fn try_walsh(n: usize) -> Result<Mat, String> {
    let h = try_hadamard(n)?;
    let perm = walsh_permutation(n);
    let mut w = Mat::zeros(n, n);
    for (dst, &src) in perm.iter().enumerate() {
        w.row_mut(dst).copy_from_slice(h.row(src));
    }
    Ok(w)
}

/// Orthonormal Walsh matrix: the Sylvester Hadamard rows re-ordered to
/// ascending sequency. Row `i` has exactly `i` sign flips.
///
/// This is the training-free drop-in the paper proposes for R1: same row
/// set as the Hadamard matrix, but the arrangement clusters similar
/// "frequencies" so each column group of the front rotation applies
/// filters with low intra-group sequency variance (paper §3.2).
/// Panics on invalid sizes; use [`try_walsh`] where the size is untrusted.
pub fn walsh(n: usize) -> Mat {
    try_walsh(n).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::hadamard::hadamard;
    use crate::transform::sequency::sequency_of_row;

    #[test]
    fn row_i_has_sequency_i() {
        for &n in &[2usize, 16, 64, 256] {
            let w = walsh(n);
            for i in 0..n {
                assert_eq!(sequency_of_row(w.row(i)), i as u32, "n={n} row {i}");
            }
        }
    }

    #[test]
    fn orthonormal() {
        assert!(walsh(128).orthogonality_defect() < 1e-10);
    }

    #[test]
    fn same_row_set_as_hadamard() {
        // Every Walsh row must be some Hadamard row (the re-ordering
        // claim: "same set of sequency filters, different arrangement").
        let n = 32;
        let h = hadamard(n);
        let w = walsh(n);
        for i in 0..n {
            let found = (0..n).any(|j| {
                w.row(i)
                    .iter()
                    .zip(h.row(j))
                    .all(|(a, b)| (a - b).abs() < 1e-12)
            });
            assert!(found, "walsh row {i} not found in hadamard rows");
        }
    }

    #[test]
    fn try_constructor_errors_on_non_pow2() {
        let err = try_walsh(24).unwrap_err();
        assert!(err.contains("power of two"), "{err}");
        assert!(try_walsh(32).is_ok());
    }

    #[test]
    fn first_row_is_constant() {
        let w = walsh(64);
        let v = 1.0 / 8.0;
        assert!(w.row(0).iter().all(|&x| (x - v).abs() < 1e-12));
    }
}
