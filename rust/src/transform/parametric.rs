//! Parametric (angle-carrying) orthogonal rotation families for the
//! search grid: Givens chains (ParoQuant-style pairwise rotations over a
//! fixed brick-wall pairing) and butterfly factorizations
//! (ButterflyQuant-style log₂(n) stages of 2×2 orthogonal blocks).
//!
//! Both families are **block-diagonal** (local) like GSR/LH, and both
//! are pure functions of `(kind, block, angles)` — no RNG — so a plan
//! reloaded from disk rebuilds bit-identical matrices from the spec
//! alone. Angles are tied per stage and quantized to 8 bits
//! (`θ = code · 2π/256`), with up to [`MAX_STAGES`] stage codes packed
//! little-endian into one `u64` (byte `s` = stage `s`); wrapping byte
//! arithmetic is exact because the angle domain is 2π-periodic.
//!
//! Every matrix here is a product of exact 2×2 rotations, hence exactly
//! orthogonal for *any* angle packing — the property the search relies
//! on (candidates never need re-orthonormalization) and the one the
//! property suite pins at random angles.

use super::{is_pow2, Mat};
use crate::transform::R1Kind;

/// Maximum optimizable stages per candidate (one packed byte each).
pub const MAX_STAGES: usize = 8;

/// Initialization code for every stage: 32/256 of a turn = π/4, where a
/// 2×2 rotation has equal-magnitude entries (Hadamard-like mixing).
pub const DEFAULT_ANGLE_CODE: u8 = 32;

/// Number of angle-carrying stages for `(kind, block)`; 0 for
/// non-parametric kinds or degenerate blocks.
pub fn angle_stages(kind: R1Kind, block: usize) -> usize {
    if block < 2 || !is_pow2(block) {
        return 0;
    }
    match kind {
        // Brick-wall chain: alternating even/odd adjacent pairings.
        R1Kind::GIV => block.min(MAX_STAGES),
        // One stage per butterfly span 1, 2, 4, … up to the block size.
        R1Kind::BFLY => (block.trailing_zeros() as usize).min(MAX_STAGES),
        _ => 0,
    }
}

/// The packed all-π/4 initialization the grid seeds candidates with.
pub fn default_angles(kind: R1Kind, block: usize) -> u64 {
    let mut out = 0u64;
    for s in 0..angle_stages(kind, block) {
        out |= (DEFAULT_ANGLE_CODE as u64) << (8 * s);
    }
    out
}

/// Zero the dead bytes beyond the stage count (canonicalization: two
/// packings that build the same matrix must compare equal).
pub fn mask_angles(kind: R1Kind, block: usize, angles: u64) -> u64 {
    let stages = angle_stages(kind, block);
    if stages >= MAX_STAGES {
        angles
    } else {
        angles & ((1u64 << (8 * stages)) - 1)
    }
}

/// Stage `s`'s angle code out of a packed `u64`.
pub fn stage_code(angles: u64, stage: usize) -> u8 {
    (angles >> (8 * stage)) as u8
}

/// Replace stage `s`'s angle code inside a packed `u64`.
pub fn with_stage_code(angles: u64, stage: usize, code: u8) -> u64 {
    (angles & !(0xFFu64 << (8 * stage))) | ((code as u64) << (8 * stage))
}

/// Decode an 8-bit angle code: `θ = code · 2π/256`.
pub fn angle_theta(code: u8) -> f64 {
    code as f64 * (std::f64::consts::PI / 128.0)
}

/// Index pairs one stage rotates, within a single block.
///
/// * GIV stage `s`: adjacent pairs starting at offset `s % 2`
///   (`(0,1),(2,3),…` on even stages; `(1,2),(3,4),…,(block-1,0)` with
///   wrap on odd stages) — the brick-wall chain.
/// * BFLY stage `s`: span-`2^s` butterflies `(i, i + 2^s)` for every
///   `i` whose bit `s` is clear.
fn stage_pairs(kind: R1Kind, block: usize, stage: usize) -> Vec<(usize, usize)> {
    match kind {
        R1Kind::GIV => {
            let off = stage % 2;
            (0..block / 2).map(|k| ((off + 2 * k) % block, (off + 2 * k + 1) % block)).collect()
        }
        R1Kind::BFLY => {
            let span = 1usize << stage;
            (0..block).filter(|i| i & span == 0).map(|i| (i, i + span)).collect()
        }
        _ => Vec::new(),
    }
}

fn validate(kind: R1Kind, n: usize, block: usize) -> Result<(), String> {
    if !kind.is_parametric() {
        return Err(format!("{kind} is not a parametric rotation kind"));
    }
    if !is_pow2(block) || block < 2 {
        return Err(format!(
            "parametric rotation block must be a power of two >= 2, got {block}"
        ));
    }
    if block > n || n % block != 0 {
        return Err(format!("rotation block size {block} must divide dimension {n}"));
    }
    Ok(())
}

/// Dense `n×n` block-diagonal rotation for `(kind, block, angles)` —
/// a pure function of its arguments (the plan round-trip guarantee).
/// Stages multiply on the right: `R = G_0 · G_1 · … · G_{k-1}`.
pub fn try_build_parametric(
    kind: R1Kind,
    n: usize,
    block: usize,
    angles: u64,
) -> Result<Mat, String> {
    validate(kind, n, block)?;
    let mut m = Mat::identity(n);
    for s in 0..angle_stages(kind, block) {
        let theta = angle_theta(stage_code(angles, s));
        let (c, sn) = (theta.cos(), theta.sin());
        for (i, j) in stage_pairs(kind, block, s) {
            for b in 0..n / block {
                let (gi, gj) = (b * block + i, b * block + j);
                // Column op M ← M·G with G[i,i]=c, G[i,j]=s, G[j,i]=-s.
                for r in 0..n {
                    let (a, d) = (m[(r, gi)], m[(r, gj)]);
                    m[(r, gi)] = c * a - sn * d;
                    m[(r, gj)] = sn * a + c * d;
                }
            }
        }
    }
    Ok(m)
}

/// In-place `x ← Rᵀ·x` for `x: [n, cols]` without materializing `R`:
/// each stage is an O(n·cols) pairwise row update, so a full
/// application costs `stages · n · cols` instead of the `n²·cols`
/// dense matmul — the workhorse of the angle coordinate descent.
pub fn apply_parametric_t(kind: R1Kind, block: usize, angles: u64, x: &mut Mat) {
    let n = x.rows;
    debug_assert!(validate(kind, n, block).is_ok());
    for s in 0..angle_stages(kind, block) {
        let theta = angle_theta(stage_code(angles, s));
        let (c, sn) = (theta.cos(), theta.sin());
        for (i, j) in stage_pairs(kind, block, s) {
            for b in 0..n / block {
                let (gi, gj) = (b * block + i, b * block + j);
                // Row op X ← GᵀX: rows (i, j) mix, everything else fixed.
                let (lo, hi) = (gi.min(gj), gi.max(gj));
                let (head, tail) = x.data.split_at_mut(hi * x.cols);
                let ri = &mut head[lo * x.cols..lo * x.cols + x.cols];
                let rj = &mut tail[..x.cols];
                let (ra, rb) = if gi < gj { (ri, rj) } else { (rj, ri) };
                for (a, d) in ra.iter_mut().zip(rb.iter_mut()) {
                    let (va, vd) = (*a, *d);
                    *a = c * va - sn * vd;
                    *d = sn * va + c * vd;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    #[test]
    fn parametric_matrices_are_exactly_orthogonal() {
        let mut rng = SplitMix64::new(0xA11);
        for kind in [R1Kind::GIV, R1Kind::BFLY] {
            for block in [2usize, 8, 32] {
                for _ in 0..4 {
                    let angles = rng.next_u64();
                    let m = try_build_parametric(kind, 64, block, angles).unwrap();
                    let defect = m.orthogonality_defect();
                    assert!(defect < 1e-12, "{kind} block {block}: defect {defect}");
                    // Block-diagonal structure: off-block entries exact 0.
                    for r in 0..64 {
                        for c in 0..64 {
                            if r / block != c / block {
                                assert_eq!(m[(r, c)], 0.0, "{kind} ({r},{c})");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn apply_t_matches_dense_transpose_matmul() {
        let mut rng = SplitMix64::new(0xB22);
        for kind in [R1Kind::GIV, R1Kind::BFLY] {
            let block = 16;
            let angles = rng.next_u64();
            let r = try_build_parametric(kind, 32, block, angles).unwrap();
            let x = Mat::from_fn(32, 11, |_, _| rng.next_normal());
            let want = r.transpose().matmul(&x);
            let mut got = x.clone();
            apply_parametric_t(kind, block, angles, &mut got);
            for (a, b) in got.data.iter().zip(&want.data) {
                assert!((a - b).abs() < 1e-12, "{kind}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn default_angles_pack_pi_over_four_per_stage() {
        assert_eq!(angle_stages(R1Kind::BFLY, 64), 6);
        assert_eq!(angle_stages(R1Kind::GIV, 64), MAX_STAGES);
        assert_eq!(angle_stages(R1Kind::GSR, 64), 0);
        let a = default_angles(R1Kind::BFLY, 64);
        for s in 0..6 {
            assert_eq!(stage_code(a, s), DEFAULT_ANGLE_CODE);
        }
        assert_eq!(stage_code(a, 6), 0);
        assert_eq!(default_angles(R1Kind::GSR, 64), 0);
    }

    #[test]
    fn mask_zeroes_dead_stage_bytes_only() {
        let full = u64::MAX;
        let masked = mask_angles(R1Kind::BFLY, 4, full); // 2 stages
        assert_eq!(masked, 0xFFFF);
        assert_eq!(mask_angles(R1Kind::GIV, 1 << 12, full), full); // capped at 8
        assert_eq!(with_stage_code(masked, 1, 0x2A), 0x2AFF);
        // Masked and unmasked packings build the same matrix.
        let a = try_build_parametric(R1Kind::BFLY, 8, 4, full).unwrap();
        let b = try_build_parametric(R1Kind::BFLY, 8, 4, masked).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn zero_angles_build_identity() {
        for kind in [R1Kind::GIV, R1Kind::BFLY] {
            let m = try_build_parametric(kind, 16, 8, 0).unwrap();
            assert_eq!(m.data, Mat::identity(16).data, "{kind}");
        }
    }

    #[test]
    fn bad_geometry_is_an_error() {
        assert!(try_build_parametric(R1Kind::GIV, 64, 24, 0).is_err());
        assert!(try_build_parametric(R1Kind::BFLY, 64, 1, 0).is_err());
        assert!(try_build_parametric(R1Kind::GIV, 64, 128, 0).is_err());
        assert!(try_build_parametric(R1Kind::GSR, 64, 8, 0).is_err());
    }
}
